"""Resilience walkthrough — typed faults, retries, hedges, and the
degradation ladder under a seeded fault storm (src/repro/serving/
errors.py + resilience.py, DESIGN.md §7).

Two acts, both on the deterministic virtual clock so every number
printed here is reproducible to the byte:

  1. a single scheduler under a transient-fault storm: retryable
     faults re-enter their lane with the ORIGINAL arrival stamp and
     backed-off, jittered retry times — near-total recovery, and the
     telemetry reconstruction (telemetry/analysis.resilience_summary)
     agrees with the scheduler's own counters;
  2. the committed acceptance storm (`fleet_faultstorm`): 4 replicas,
     6% transient rate, one 6x-slow straggler, one permanently
     poisoned signature, 0.4% stuck requests — retries recover the
     transients, class timeouts reap the stuck, hedges beat the
     straggler, and the per-(replica, signature) breaker walks the
     poisoned signature down the executor ladder. Zero requests lost,
     zero served twice (EXPERIMENTS.md H14).

    PYTHONPATH=src python examples/serve_resilient.py
"""

import dataclasses

from repro.serving import (
    FaultPlan,
    FaultRule,
    RetryPolicy,
    ResiliencePolicy,
    fleet_preset,
    preset,
    simulate,
    simulate_fleet,
)
from repro.serving.simulator import reference_engine

# --- act 1: retries recover a transient storm ---------------------------
# The steady single-server scenario, with a 10% transient-fault rate
# injected on every dispatch and a 3-attempt retry budget. Faults and
# backoff jitter are counter-hashed, so this whole run is seeded.
cfg = dataclasses.replace(
    preset("steady", horizon_s=300.0, seed=0),
    resilience=ResiliencePolicy(
        retry=RetryPolicy(max_attempts=3, backoff_base_s=0.1, seed=0),
        service_timeout_s={"interactive": 4.0, "standard": 8.0, "batch": 20.0},
    ),
    fault_plan=FaultPlan(
        seed=0, rules=(FaultRule(kind="transient", rate=0.10),)
    ),
)
rep = simulate(reference_engine(), cfg)
s = rep.summary()
r = s["resilience"]
print("== act 1: single scheduler, 10% transient storm ==")
print(f"served={s['requests']['completed'] + s['requests']['demoted']} "
      f"retries={r['retries']} faults={r['faults']}")
print(f"faulted_requests={r['faulted_requests']} "
      f"recovered={r['recovered_requests']} "
      f"recovery_rate={r['recovery_rate']}")
print(f"conserved={s['requests']['conserved']} — retries age in place: "
      f"a retried request keeps its original arrival stamp, so "
      f"wait + service == finish - arrival exactly")

# --- act 2: the committed acceptance storm ------------------------------
# fleet_faultstorm is the golden scenario: every counter printed below
# is asserted byte-exactly in tests/test_resilience.py and gated in the
# serving_resilience section of BENCH_2.json.
rep = simulate_fleet(fleet_preset("fleet_faultstorm"))
s = rep.summary()
req, r = s["requests"], s["resilience"]
print("\n== act 2: fleet_faultstorm — 4 replicas, every fault kind ==")
print(f"arrived={req['arrived']} conserved={req['conserved']} "
      f"served_twice={req['served_twice']}")
print(f"retries={r['retries']} recovery_rate={r['recovery_rate']} "
      f"(acceptance: >= 0.9) timeouts={r['faults']['timeout']}")
print(f"hedges={r['hedges']} wins={r['hedge_wins']} "
      f"cancelled={r['hedge_cancelled']} — first completion wins, the "
      f"loser cancels through the ledger")
b = r["breaker"]
print(f"breaker: trips={b['trips']} restores={b['restores']} "
      f"probes={b['probes']} open={b['open_signatures']}")
print("rung mix (mode/executor of every served request):")
for rung, n in sorted(s["resilience"]["rungs"].items()):
    print(f"  {rung:<24} {n}")
print("the poisoned xla signature finishes its requests at the demoted "
      "streaming rung — the ladder routes around the permanent fault.")
