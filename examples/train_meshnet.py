"""End-to-end training driver: train MeshNet GWM for a few hundred steps on
the synthetic-MRI pipeline, with checkpointing, eval and the U-Net baseline
comparison (the paper's Table II experiment).

    PYTHONPATH=src python examples/train_meshnet.py [--steps 300]
"""

import argparse

import jax

from repro.core.meshnet import MeshNetConfig
from repro.data import mri
from repro.training import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--volume", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/meshnet_ckpt")
    ap.add_argument("--subvolumes", action="store_true", help="failsafe-style training")
    args = ap.parse_args()

    cfg = trainer.TrainConfig(
        model=MeshNetConfig(dropout_rate=0.1),
        data=mri.DataLoaderConfig(
            mri=mri.SyntheticMRIConfig(shape=(args.volume,) * 3),
            batch_size=args.batch,
            subvolumes=args.subvolumes,
            cube=24,
        ),
        steps=args.steps,
        eval_every=100,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
    )
    print(f"MeshNet GWM: {cfg.model.param_count()} params "
          f"({cfg.model.param_count() * 4 / 1e6:.3f} MB f32) — paper: 5598 / 0.022 MB")
    res = trainer.train(cfg)
    print(f"\nheld-out macro Dice after {args.steps} steps: {res.final_dice:.4f}")
    print(f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
