"""Fleet-scale serving walkthrough — N replica schedulers behind a
cache-affinity router (src/repro/serving/fleet.py, DESIGN.md §6).

Three acts, all on the deterministic virtual clock so every number
printed here is reproducible to the byte:

  1. the same diurnal overload that forces the single-server scheduler
     to shed ~19% of arrivals is absorbed by a 4-replica fleet —
     cache-affinity routing keeps each dispatch signature's compiled
     executable warm on the replica that owns it;
  2. a replica crashes mid-storm: its queued backlog AND the un-served
     tail of its in-flight batch are re-dispatched to survivors exactly
     once (zero lost, zero served twice);
  3. the SLO-attainment autoscaler rides one compressed virtual day,
     scaling 1 -> N up the morning ramp and draining back down after
     the evening tail.

    PYTHONPATH=src python examples/serve_fleet.py
"""

from repro.serving.fleet import fleet_preset, simulate_fleet

# --- act 1: wide beats deep under overload ------------------------------
# fleet_overload is the single-server killer storm (diurnal 12 Hz peak,
# depth-32 queues, 1 MiB admission) on 4 cache-affinity replicas.
rep = simulate_fleet(fleet_preset("fleet_overload"))
s = rep.summary()
req, aff = s["requests"], s["affinity"]
print("== fleet_overload: 4 replicas vs the diurnal storm ==")
print(f"arrived={req['arrived']} refused={req['refused']} "
      f"(single server refuses 693 of the same trace)")
print(f"interactive p99 = {s['classes']['interactive']['latency_ms']['p99']} ms "
      f"(acceptance: < 5000 ms)")
print(f"affinity: {aff['warm_hits']}/{aff['routes']} warm routes "
      f"(hit rate {aff['hit_rate']}), {aff['cold_compiles']} cold compiles "
      f"fleet-wide — round-robin would compile every signature on every replica")
print(f"conserved={req['conserved']} served_twice={req['served_twice']}")

# --- act 2: exactly-once failover ---------------------------------------
# fleet_failover crashes replica 1 at t=127 s — the middle of the second
# 40 Hz burst, when its queue is deepest and a batch is in flight.
rep = simulate_fleet(fleet_preset("fleet_failover"))
s = rep.summary()
req = s["requests"]
print("\n== fleet_failover: replica crash mid-burst ==")
crash = next(e for e in s["scale_events"] if e["action"] == "crash")
print(f"crash: replica {crash['replica']} at t={crash['t']} s "
      f"-> {crash['replicas_after']} survivors")
print(f"evacuated={req['evacuated']} (queued + truncated in-flight tail), "
      f"redispatched={req['redispatched']} — exactly once each")
print(f"zero lost: arrived={req['arrived']} == refused={req['refused']} "
      f"+ completed={req['completed']} + demoted={req['demoted']} "
      f"+ rejected={sum(req['rejected'].values())}")
print(f"served_twice={req['served_twice']} conserved={req['conserved']}")

# --- act 3: one autoscaled virtual day ----------------------------------
rep = simulate_fleet(fleet_preset("fleet_autoscale"))
s = rep.summary()
print("\n== fleet_autoscale: one compressed virtual day, 1..6 replicas ==")
for e in s["scale_events"]:
    print(f"  t={e['t']:7.1f}s  {e['action']:<6} replica {e['replica']} "
          f"-> {e['replicas_after']} routable")
print(f"peak_routable={s['replicas']['peak_routable']} "
      f"final_routable={s['replicas']['final_routable']} "
      f"interactive p99 = {s['classes']['interactive']['latency_ms']['p99']} ms")
