"""Generate text with any assigned architecture (reduced config on CPU) via
the continuous-batching LM engine — demonstrates the zoo + serving stack:

    PYTHONPATH=src python examples/generate_lm.py --arch rwkv6-3b
    PYTHONPATH=src python examples/generate_lm.py --arch jamba-1.5-large-398b
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as MD
from repro.serving.engine import LMEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=configs.ARCHS)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = dataclasses.replace(configs.get_smoke(args.arch), dtype=jnp.float32)
    print(f"{cfg.name}: pattern={cfg.block_pattern()} x {cfg.num_repeats} repeats")
    params = MD.init(jax.random.PRNGKey(0), cfg)
    engine = LMEngine(params, cfg, slots=2, max_seq=64, prefill_chunk=8)

    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        key, k = jax.random.split(key)
        n = int(jax.random.randint(k, (), 3, 10))
        reqs.append(
            Request(
                prompt=jax.random.randint(k, (n,), 0, cfg.vocab_size).tolist(),
                max_new_tokens=args.max_new,
                temperature=0.0 if i % 2 == 0 else 0.8,
                id=i,
            )
        )
    t0 = time.perf_counter()
    outs = engine.run(reqs)
    dt = time.perf_counter() - t0
    for c in outs:
        print(f"  req {c.id}: -> {c.tokens}")
    total = sum(len(c.tokens) for c in outs)
    print(f"{total} tokens in {dt:.1f}s ({total/dt:.1f} tok/s, reduced config, CPU)")


if __name__ == "__main__":
    main()
