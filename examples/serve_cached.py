"""Artifact-cache walkthrough — content addressing, integrity
quarantine, single-flight coalescing, and fail-open degradation
(src/repro/serving/cache.py, DESIGN.md §8).

Two acts, both deterministic to the byte:

  1. the cache tier in isolation: content addressing (identical voxel
     payloads collide, a one-voxel edit does not), a verified hit, an
     injected bit-flip caught by per-hit re-verification and
     quarantined — the corrupt bytes are NEVER served;
  2. the committed acceptance storm (`fleet_cached`): 4 replicas,
     bursty traffic with Zipf(1.1) content skew over 256 volumes, a
     2 MiB cache, 2% corrupt-entry faults, and a 60 s total outage —
     stampedes collapse onto single-flight leaders, corruption is
     quarantined with zero served, and the outage rides the fail-open
     breaker. Zero requests lost (EXPERIMENTS.md H15).

    PYTHONPATH=src python examples/serve_cached.py
"""

import numpy as np

from repro.serving import (
    ArtifactCache,
    CacheConfig,
    FaultPlan,
    FaultRule,
    artifact_key,
    content_hash,
    fleet_preset,
    simulate_fleet,
)
from repro.telemetry.record import StageTimes, TelemetryRecord

# --- act 1: content addressing + integrity ------------------------------
# Two separate uploads of the SAME voxel payload hash to the same
# artifact key — that collision is the whole point of content
# addressing. A one-voxel edit changes the key.
vol_a = np.random.default_rng(0).normal(size=(16, 16, 16)).astype(np.float32)
vol_b = vol_a.copy()
vol_c = vol_a.copy()
vol_c[3, 4, 5] += 1.0

key = artifact_key(content_hash(vol_a), "gwm_light", "fp32", "full")
print("== act 1: content addressing + integrity ==")
print(f"same payload, same key:    {key == artifact_key(content_hash(vol_b), 'gwm_light', 'fp32', 'full')}")
print(f"one voxel edited, new key: {key != artifact_key(content_hash(vol_c), 'gwm_light', 'fp32', 'full')}")

# Store one artifact, then let a seeded corrupt_entry fault flip a byte
# at rest (t1=0.5 gates the rule to store time only). The next lookup
# re-verifies, catches the flip, quarantines, and reports a plain miss
# — the request recomputes; no caller ever sees corrupt bytes.
cache = ArtifactCache(
    CacheConfig(),
    fault_plan=FaultPlan(
        seed=0, rules=(FaultRule(kind="corrupt_entry", rate=1.0, t1=0.5),)
    ),
)
rec = TelemetryRecord(
    model="gwm_light", mode="full", status="ok", times=StageTimes(),
    executor="xla", precision="fp32", params_bytes=22392, request_id=0,
)
cache.begin(key, replica=0, now=0.0, est_bytes=5000)
cache.complete(key, record=rec, shape=(16, 16, 16), now=0.0)  # poisoned at rest
poisoned = cache.lookup(key, now=1.0)
print(f"lookup after bit-flip:     status={poisoned.status!r} "
      f"(quarantined={cache.stats.quarantined}, "
      f"quarantined_served={cache.stats.quarantined_served})")

# Stored clean (the fault window is over), the hit verifies and serves.
cache.begin(key, replica=0, now=2.0, est_bytes=5000)
cache.complete(key, record=rec, shape=(16, 16, 16), now=2.0)
hit = cache.lookup(key, now=3.0)
print(f"clean store, next lookup:  status={hit.status!r} "
      f"payload_executor={cache.serve_payload(hit.entry)['executor']!r}")

# --- act 2: the committed acceptance storm ------------------------------
# fleet_cached is the golden scenario: every counter printed below is
# asserted byte-exactly in tests/test_fleet_golden.py and gated in the
# serving_cache section of BENCH_2.json.
rep = simulate_fleet(fleet_preset("fleet_cached"))
s = rep.summary()
req, c = s["requests"], s["cache"]
print("\n== act 2: fleet_cached — Zipf skew, corruption, and an outage ==")
print(f"arrived={req['arrived']} conserved={req['conserved']} "
      f"served_twice={req['served_twice']} — coalesced is the fifth "
      f"terminal state of the ledger")
print(f"hit_rate={c['hit_rate']} admission_hits={c['admission_hits']} "
      f"evictions={c['evictions']} (2 MiB under real byte pressure)")
print(f"coalesced={c['coalesced']} inflight_hits={c['inflight_hits']} "
      f"content_routes={c['content_routes']} — N identical in-flight "
      f"requests == 1 forward pass + N-1 byte-identical followers")
print(f"quarantined={c['quarantined']} quarantined_served="
      f"{c['quarantined_served']} — every bit-flip caught, corrupt "
      f"bytes NEVER reach a completion")
print(f"outage: unavailable={c['unavailable']} "
      f"breaker_trips={c['breaker_trips']} "
      f"breaker_skips={c['breaker_skips']} — the open breaker stops "
      f"consulting the dark tier; every outage-window request serves "
      f"via compute (fail-open, nothing lost)")
