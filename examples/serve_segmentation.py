"""Serve segmentation under load — the Brainchop deployment story on a
server, now through the continuous-batching request scheduler
(src/repro/serving/scheduler.py, DESIGN.md §5): requests are queued with
priority classes, priced against an HBM admission budget at their
storage policy, grouped by compatible (mode, executor, precision, shape)
signatures into shared-jit dispatch groups, and served with per-request
telemetry (queue wait, service time, batch size, demotions) — the
paper's Table III/IV dataset, grown a serving tier.

    PYTHONPATH=src python examples/serve_segmentation.py
"""

import jax

from repro.core import meshnet
from repro.core.meshnet import MeshNetConfig
from repro.core.pipeline import PipelineConfig
from repro.data import mri
from repro.serving.engine import SegmentationEngine
from repro.telemetry.budget import MemoryBudget

SHAPE = (32, 32, 32)

cfg = MeshNetConfig()
params = meshnet.init(jax.random.PRNGKey(0), cfg)
pc = PipelineConfig(model=cfg, volume_shape=SHAPE, min_component_size=8)

# A deliberately tight budget: streaming fits, the naive graph would not —
# exercising the engine's mode-selection (the paper's failsafe logic).
budget = MemoryBudget(8 * 1024 * 1024, name="tight")
engine = SegmentationEngine(params, pc, budget=budget)

key = jax.random.PRNGKey(1)
vols = []
for i in range(6):
    key, k = jax.random.split(key)
    vol, _ = mri.generate(k, mri.SyntheticMRIConfig(shape=SHAPE))
    vols.append(vol)

# --- queued serving -----------------------------------------------------
# submit_async enqueues (nothing runs yet); drain() forms dispatch groups:
# the four engine-default requests share one resolved signature -> ONE
# group, one jit-cache entry; the bf16 and int8w requests group apart.
# Per-request ``precision`` picks the storage policy (DESIGN.md §2.3) —
# weights are quantized once per policy and cached by the engine.
for i, vol in enumerate(vols[:4]):
    engine.submit_async(vol, priority="interactive" if i < 2 else "standard")
engine.submit_async(vols[4], precision="bf16")
engine.submit_async(vols[5], precision="int8w")

completions = engine.drain()
for c in completions:
    r = c.record
    print(f"request {c.id}: {c.outcome:9s} status={r.status:4s} "
          f"mode={r.mode:10s} executor={r.executor:12s} "
          f"precision={r.precision or '-':5s} class={r.priority_class:11s} "
          f"batch={r.batch_size} wait={r.queue_wait_s:.3f}s "
          f"service={r.service_s:.3f}s")

print(f"\nfleet success rate: {engine.log.success_rate()*100:.0f}% "
      f"({len(engine.log.records)} requests)")
stats = engine.scheduler().stats
print(f"conservation: admitted={stats.admitted} = completed={stats.completed} "
      f"+ demoted={stats.demoted} + rejected={stats.rejected_total()} "
      f"-> {stats.conserved()}")

# The fleet views (telemetry/analysis.py): per (executor, precision) cell
# and the per-priority-class queue/latency rollup.
from repro.telemetry import analysis  # noqa: E402

print("\nexecutor,precision,runs,ok_rate,hbm_bytes,collective_bytes,params_bytes")
for cell in analysis.precision_summary(engine.log.records):
    print(cell.row())

print("\nclass,requests,served,demoted,shed,ok_rate,p50_wait,p99_wait,"
      "p50_service,p99_service,mean_batch")
for row in analysis.class_summary(engine.log.records):
    print(row.row())

# --- batched submit (one launch per dispatch group) ---------------------
# A true N-volume batch axis runs through every executor: stacking volumes
# on a leading dim gives per-member logits identical to the unbatched
# forward, while each weight tensor streams from HBM once per LAUNCH
# instead of once per volume — modeled bytes are sub-additive in batch.
import jax.numpy as jnp  # noqa: E402

from repro.core import executors  # noqa: E402

batch = jnp.stack(vols[:4])  # (4, 32, 32, 32)
logits = executors.apply("xla", params, batch, cfg)
print(f"\nbatched forward: {batch.shape} -> {logits.shape} "
      f"(member 0 == solo forward: "
      f"{bool(jnp.array_equal(logits[0], executors.apply('xla', params, batch[:1], cfg)[0]))})")
b1 = executors.modeled_hbm_bytes("xla", cfg, SHAPE, batch=1)
b4 = executors.modeled_hbm_bytes("xla", cfg, SHAPE, batch=4)
print(f"modeled bytes: batch-4 launch {b4:,} < 4 serial forwards {4 * b1:,} "
      f"(weight stream amortized)")

# --- load simulation (deterministic, virtual clock) ---------------------
# The same scheduler under one simulated minute of bursty traffic — every
# number below is bit-reproducible (seeded arrivals, modeled service).
from repro.serving import simulator as sim  # noqa: E402

report = sim.simulate(
    sim.reference_engine(), sim.preset("burst", seed=0, horizon_s=60.0)
)
s = report.summary()
print(f"\nsimulated burst minute: arrived={s['requests']['arrived']} "
      f"served={s['requests']['completed'] + s['requests']['demoted']} "
      f"p50={s['latency_ms']['p50']:.0f}ms p99={s['latency_ms']['p99']:.0f}ms "
      f"mean_batch={s['mean_batch_size']}")

# Flip batched dispatch on (SchedulerConfig(batched_dispatch=True)) and
# each dispatch group serves as ONE batched launch — same trace, weights
# priced once per group, members share the launch's service interval:
cfgb = sim.preset("burst_batched", seed=0, horizon_s=60.0)
sb = sim.simulate(sim.reference_engine(), cfgb).summary()
print(f"same minute, batched dispatch: "
      f"p50={sb['latency_ms']['p50']:.0f}ms p99={sb['latency_ms']['p99']:.0f}ms "
      f"conserved={sb['requests']['conserved']}")
