"""Serve a segmentation model with batched requests — the Brainchop
deployment story on a server: the engine picks full-volume vs failsafe
sub-volume mode per request from the memory budget, dispatches inference
through the executor registry (core/executors.py — "auto" resolves to the
depth-first Pallas megakernel on TPU when its tile plan fits VMEM, else
the per-layer fused backend; XLA on CPU), runs the pipeline, and records
telemetry (success rate, stage timings, mode/executor served) like the
paper's Table III/IV dataset.

    PYTHONPATH=src python examples/serve_segmentation.py
"""

import jax

from repro.core import meshnet
from repro.core.meshnet import MeshNetConfig
from repro.core.pipeline import PipelineConfig
from repro.data import mri
from repro.serving.engine import SegmentationEngine
from repro.telemetry.budget import MemoryBudget

SHAPE = (32, 32, 32)

cfg = MeshNetConfig()
params = meshnet.init(jax.random.PRNGKey(0), cfg)
pc = PipelineConfig(model=cfg, volume_shape=SHAPE, min_component_size=8)

# A deliberately tight budget: streaming fits, the naive graph would not —
# exercising the engine's mode-selection (the paper's failsafe logic).
budget = MemoryBudget(8 * 1024 * 1024, name="tight")
engine = SegmentationEngine(params, pc, budget=budget)

key = jax.random.PRNGKey(1)
vols = []
for i in range(4):
    key, k = jax.random.split(key)
    vol, _ = mri.generate(k, mri.SyntheticMRIConfig(shape=SHAPE))
    vols.append(vol)

# Batched submission: requests run in order, and any that share a
# (mode, executor, precision, shape) reuse one compiled executable via the
# registry's jit cache. The last request pins the explicit streaming
# executor; the rest use the engine default ("auto"). Per-request
# ``precisions`` picks the storage policy (DESIGN.md §2.3): the bf16 and
# int8w requests stream 2x/4x fewer modeled HBM bytes — weights are
# quantized once per policy and cached by the engine.
results = engine.submit_many(
    vols,
    executors=[None, None, None, "streaming"],
    precisions=[None, "bf16", "int8w", None],
)
for i, res in enumerate(results):
    t = res.record.times
    print(f"request {i}: {res.record.status:4s} mode={res.record.mode:10s} "
          f"executor={res.record.executor:12s} "
          f"precision={res.record.precision:5s} "
          f"hbm~{(res.record.hbm_bytes_modeled or 0)/2**20:.0f}MiB "
          f"inference {t.inference:.2f}s postprocess {t.postprocessing:.2f}s")

print(f"\nfleet success rate: {engine.log.success_rate()*100:.0f}% "
      f"({len(engine.log.records)} requests)")

# The fleet view per (executor, precision) cell (telemetry/analysis.py):
from repro.telemetry import analysis  # noqa: E402

print("\nexecutor,precision,runs,ok_rate,hbm_bytes,collective_bytes,params_bytes")
for cell in analysis.precision_summary(engine.log.records):
    print(cell.row())
