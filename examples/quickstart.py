"""Quickstart: segment a (synthetic) T1 volume with MeshNet in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Mirrors what brainchop.org does in the browser: load a volume, conform it,
run the pre-trained full-volume GWM model, filter noise with connected
components, and report per-class volumes + Dice against ground truth.
"""

import jax
import jax.numpy as jnp

from repro.core import meshnet
from repro.core.meshnet import MeshNetConfig
from repro.core.pipeline import PipelineConfig, run
from repro.data import mri
from repro.training import losses, trainer

SHAPE = (32, 32, 32)

# 1. "Pre-trained model": a quick training run stands in for the paper's
#    HCP-trained weights (gated data — DESIGN.md §1).
print("training a small GWM MeshNet on synthetic volumes ...")
tcfg = trainer.TrainConfig(
    model=MeshNetConfig(),
    data=mri.DataLoaderConfig(mri=mri.SyntheticMRIConfig(shape=SHAPE), batch_size=2),
    steps=80,
    log_every=40,
)
result = trainer.train(tcfg, verbose=True)

# 2. A new "subject" arrives.
vol, truth = mri.generate(jax.random.PRNGKey(42), mri.SyntheticMRIConfig(shape=SHAPE))

# 3. Run the Brainchop pipeline: conform -> full-volume inference -> CC filter.
#    executor="auto" picks the depth-first Pallas megakernel on TPU (when
#    its tile plan fits VMEM, else the per-layer fused kernel) and XLA on
#    CPU; pass executor="pallas_megakernel" to force the tiled path anywhere.
pcfg = PipelineConfig(model=tcfg.model, volume_shape=SHAPE, mode="full", min_component_size=8)
out = run(pcfg, result.params, vol)
seg = out.segmentation

# 4. Report.
t = out.record.times
print(f"\nstatus={out.record.status}  preprocess {t.preprocessing:.2f}s  "
      f"inference {t.inference:.2f}s  postprocess {t.postprocessing:.2f}s")
for c, name in enumerate(["background", "gray matter", "white matter"]):
    print(f"  {name:12s}: {int((seg == c).sum()):7d} voxels")
dice = float(losses.dice_score(seg, truth, 3))
print(f"macro Dice vs ground truth: {dice:.3f}")
