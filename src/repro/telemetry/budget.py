"""Memory-budget simulator — the browser's failure modes, parameterised.

The paper's fail taxonomy (Table V): "Failed to compile fragment shader",
"Failed to link shaders", "Unable to create WebGL Texture" — all memory /
resource-limit manifestations. On TPU the corresponding wall is HBM bytes
per device (and VMEM per kernel block). This module prices each inference
strategy's peak working set against a configurable budget, so the
benchmark harness can re-run the paper's interventions (patching, cropping,
texture size) as budget sweeps and regenerate Tables V–VIII.

The budget model is deliberately analytic (bytes, not wall-clock): it is
the part of the paper we *simulate* because the actual gate (a fleet of
heterogeneous browsers) does not exist in this container. DESIGN.md §1.
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # annotation-only: a runtime import would cycle back
    # through repro.core (core.pipeline imports this module).
    from repro.core.meshnet import MeshNetConfig

# Browser-era texture sizes map to working-set budgets; TPU-era ladder:
V5E_HBM_BYTES = 16 * 1024**3  # 16 GB HBM per v5e chip
WEBGL_LIKE_BUDGETS = {
    # texture_size -> approx usable bytes (texture^2 * 4 bytes RGBA)
    8192: 8192**2 * 4,  # 256 MiB
    9159: 9159**2 * 4,
    13585: 13585**2 * 4,
    16384: 16384**2 * 4,  # 1 GiB
    32768: 32768**2 * 4,  # 4 GiB
}


class BudgetExceeded(Exception):
    def __init__(self, fail_type: str, need: int, have: int):
        super().__init__(f"{fail_type}: need {need} bytes, budget {have}")
        self.fail_type = fail_type
        self.need = need
        self.have = have


@dataclasses.dataclass(frozen=True)
class MemoryBudget:
    """A per-run memory budget in bytes (the simulated device)."""

    bytes_limit: int
    name: str = "custom"

    @staticmethod
    def unlimited() -> "MemoryBudget":
        return MemoryBudget(bytes_limit=1 << 62, name="unlimited")

    @staticmethod
    def from_texture_size(tex: int) -> "MemoryBudget":
        return MemoryBudget(WEBGL_LIKE_BUDGETS[tex], name=f"texture_{tex}")

    @staticmethod
    def v5e() -> "MemoryBudget":
        return MemoryBudget(V5E_HBM_BYTES, name="v5e_hbm")

    # --- pricing of each strategy's peak working set ------------------------

    def _check(self, need: int, fail_type: str) -> None:
        if need > self.bytes_limit:
            raise BudgetExceeded(fail_type, need, self.bytes_limit)

    def charge_inference(self, shape, model: MeshNetConfig, dtype_bytes: int = 4) -> int:
        """Naive full-volume inference: all layer activations live (what a
        graph executor without disposal would allocate) -> the failure mode
        the paper's layer-streaming avoids."""
        vox = math.prod(shape[:3])
        layers = len(model.dilations)
        need = vox * model.channels * dtype_bytes * (layers + 1)
        need += vox * model.num_classes * dtype_bytes
        self._check(need, "full_volume_oom")
        return need

    def charge_streaming(self, shape, model: MeshNetConfig, dtype_bytes: int = 4) -> int:
        """Layer-streamed full volume: two live activations + logits."""
        vox = math.prod(shape[:3])
        need = vox * model.channels * dtype_bytes * 2
        need += vox * model.num_classes * dtype_bytes
        self._check(need, "streaming_oom")
        return need

    def charge_subvolume(self, cube: int, overlap: int, model: MeshNetConfig, dtype_bytes: int = 4) -> int:
        """Failsafe mode: one padded cube streamed + full-volume logits
        accumulated on host (as Brainchop merges into a JS array)."""
        side = cube + 2 * overlap
        need = side**3 * model.channels * dtype_bytes * 2
        need += side**3 * model.num_classes * dtype_bytes
        self._check(need, "subvolume_oom")
        return need
