"""Modeled HBM bytes per MeshNet forward, per executor backend and
precision policy.

The TPU analogue of Brainchop's texture-bandwidth cost model: every
executor's schedule implies a deterministic amount of HBM traffic, and —
because MeshNet's narrow models are memory-bound (DESIGN.md §2) — that
byte count *is* the performance model. These functions price it
analytically (bytes, not wall-clock), the same methodology as the memory
budget model in telemetry/budget.py: the numbers drive the DESIGN.md §2
traffic table, the ``traffic`` benchmark section, the ``BENCH_2.json``
perf trajectory, and the per-run ``hbm_bytes_modeled`` telemetry field.

Modeling conventions (counted per forward):
  * every XLA op materialises its output: a pad is a read + padded write,
    an elementwise stage is a read + write round-trip;
  * a Pallas grid step re-fetches each of its input blocks — consecutive
    steps share nothing, so per-step window bytes multiply by the step
    count (this is what makes the 27-view schedule 27x and a haloed
    window ((b+2d)/b)^3 x);
  * weights are streamed once per grid step (tiny for MeshNet, but
    counted — at 16^3 benchmark volumes they are not negligible);
  * a batched forward (``batch=N``) re-reads and re-writes every data
    tensor per element but streams each weight tensor ONCE per launch:
    the batch loop is innermost in every backend's schedule (an XLA
    fusion keeps weights resident across the leading dim; the megakernel
    grid iterates batch inside the spatial tile), so
    ``bytes(batch=N) < N * bytes(batch=1)`` whenever the weight term is
    nonzero, with ``batch=1`` byte-identical to the pre-batching model;
  * scratch/VMEM traffic is free; only HBM crossings count.

Precision (kernels/quantize.py): every model takes the storage policy
and prices each tensor role at its width — activations (fp32 4 B / bf16
& int8w compute 2 B), weights (4/2/1 B), and for the megakernel the
input volume and inter-segment staging (down to 1 B under int8w). The
layer-wise backends (xla / pallas_fused / streaming) dequantize the
input up-front, so their volume crossings are priced at the activation
width; the megakernel is the backend whose schedule actually streams
int8 end-to-end, which is why the int8w gate (<= 0.4x fp32 at 256^3,
EXPERIMENTS.md H11) is stated on it. ``precision="fp32"`` reproduces the
pre-policy numbers bit-for-bit (the regression gate compares like-for-
like precision keys).

The pluggable executor registry wires these to its specs
(``core/executors.py``), so ``pipeline.run`` records bytes for whichever
(backend, precision) served a request without knowing how it is
scheduled.
"""

from __future__ import annotations

import math
from typing import Sequence

from repro.kernels import megakernel, quantize

Shape3 = Sequence[int]


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _vox(shape: Shape3) -> int:
    return math.prod(int(s) for s in shape)


def _widths(precision: str) -> tuple[int, int]:
    """(activation, weight) byte widths for the layer-wise schedules."""
    return quantize.act_bytes(precision), quantize.weight_bytes(precision)


def meshnet_xla_bytes(
    cfg, vol: Shape3, batch: int = 1, precision: str = "fp32"
) -> int:
    """Reference XLA graph: each layer is conv -> BN -> ReLU, three
    materialised stages (the "three HBM round-trips per layer" the fused
    path collapses, EXPERIMENTS.md §Perf H1). Conv itself is modeled at
    its traffic floor (read once, write once) — generous to XLA."""
    ab, wb = _widths(precision)
    v = _vox(vol)
    data = 0
    weights = 0
    cin = cfg.in_channels
    c = cfg.channels
    stages = 3 if cfg.use_batchnorm else 2  # conv, (bn,) relu
    for _ in cfg.dilations:
        data += v * (cin + c) * ab  # conv read + write
        data += (stages - 1) * 2 * v * c * ab  # bn/relu round-trips
        weights += 27 * cin * c * wb
        cin = c
    data += v * (c + cfg.num_classes) * ab  # 1x1x1 head
    return batch * data + weights


def dilated_conv_layer_bytes(
    vol: Shape3,
    cin: int,
    cout: int,
    dilation: int,
    block: int = 16,
    dtype_bytes: int = 4,
    weight_dtype_bytes: int | None = None,
) -> int:
    """One fused haloed-load conv call (kernels/dilated_conv3d.py, variant
    "halo"): the d-halo pad round-trip, one (block+2d)^3 window DMA per
    output block (+ the streamed weights at their own width), and the
    fused write. The per-layer term of ``meshnet_fused_bytes``; the
    kernels benchmark prices single conv rows with it."""
    p = [_ceil_to(v, block) for v in vol]
    ntiles = math.prod(pp // block for pp in p)
    total = _vox(vol) * cin * dtype_bytes  # halo pad read...
    total += math.prod(pp + 2 * dilation for pp in p) * cin * dtype_bytes  # + write
    window = (block + 2 * dilation) ** 3
    wgt = 27 * cin * cout * (weight_dtype_bytes or dtype_bytes)
    total += ntiles * (window * cin * dtype_bytes + wgt)
    total += math.prod(p) * cout * dtype_bytes  # fused conv+BN+ReLU write
    return total


def meshnet_fused_bytes(
    cfg, vol: Shape3, batch: int = 1, block: int = 16, precision: str = "fp32"
) -> int:
    """Per-layer fused Pallas path (ops.meshnet_apply): one
    ``dilated_conv_layer_bytes`` term per layer, then the head einsum.
    The per-layer weight stream (``ntiles * 27*cin*c*wb`` inside the
    layer term) is charged once per launch, not per batch element."""
    ab, wb = _widths(precision)
    data = 0
    weights = 0
    cin = cfg.in_channels
    c = cfg.channels
    for d in cfg.dilations:
        p = [_ceil_to(v, block) for v in vol]
        wgt_l = math.prod(pp // block for pp in p) * 27 * cin * c * wb
        data += (
            dilated_conv_layer_bytes(
                vol, cin, c, d, block, ab, weight_dtype_bytes=wb
            )
            - wgt_l
        )
        weights += wgt_l
        cin = c
    data += _vox(vol) * (c + cfg.num_classes) * ab  # head einsum
    return batch * data + weights


def meshnet_views_bytes(
    cfg, vol: Shape3, batch: int = 1, block: int = 16, precision: str = "fp32"
) -> int:
    """The pre-halo-load 27-view schedule (variant="views"): every grid
    step streams 27 full blocks regardless of dilation — the ~28x-off
    baseline the haloed load replaced (DESIGN.md §2)."""
    ab, wb = _widths(precision)
    data = 0
    weights = 0
    cin = cfg.in_channels
    c = cfg.channels
    for _ in cfg.dilations:
        p = [_ceil_to(v, block) for v in vol]
        ntiles = math.prod(pp // block for pp in p)
        data += _vox(vol) * cin * ab  # block-halo pad read
        data += math.prod(pp + 2 * block for pp in p) * cin * ab
        data += ntiles * 27 * block**3 * cin * ab
        weights += ntiles * 27 * cin * c * wb
        data += math.prod(p) * c * ab
        cin = c
    data += _vox(vol) * (c + cfg.num_classes) * ab
    return batch * data + weights


def meshnet_streaming_bytes(
    cfg, vol: Shape3, batch: int = 1, precision: str = "fp32"
) -> int:
    """Scan-over-layers schedule (core/streaming.py): a memory-floor
    path, not a traffic-optimal one — each scanned layer pads the carry
    by the max dilation and gathers 27 dynamic-slice taps, each tap a
    read + accumulator round-trip."""
    ab, wb = _widths(precision)
    v = _vox(vol)
    dmax = max(cfg.dilations)
    vp = math.prod(int(s) + 2 * dmax for s in vol)
    data = 0
    weights = 0
    cin = cfg.in_channels
    c = cfg.channels
    for i, _ in enumerate(cfg.dilations):
        if i == 0:
            # first layer runs unstacked, as the plain XLA block
            stages = 3 if cfg.use_batchnorm else 2
            data += v * (cin + c) * ab
            data += (stages - 1) * 2 * v * c * ab
        else:
            data += v * c * ab + vp * c * ab  # pad carry
            data += 27 * (vp + 2 * v) * c * ab  # taps + acc r/w
            data += 2 * v * c * ab  # bn+relu epilogue
        weights += 27 * cin * c * wb
        cin = c
    data += v * (c + cfg.num_classes) * ab
    return batch * data + weights


def meshnet_megakernel_bytes(
    cfg,
    vol: Shape3,
    batch: int = 1,
    precision: str = "fp32",
    vmem_budget: int | None = None,
) -> int:
    """Depth-first tiled megakernel: the planner's own traffic model
    (kernels/megakernel.py) — haloed tile reads per segment, one logits
    write, zero intra-segment activation traffic. The plan is
    re-optimized per precision (smaller working sets buy larger tiles)
    AND per batch size (the DP scales data terms by N while charging the
    weight stream once, so bigger batches favor halo-minimal tiles), and
    each tensor role is priced at its policy width, including the int8
    input and staging streams under "int8w"."""
    pln = megakernel.plan_for_config(
        cfg,
        tuple(int(s) for s in vol),
        vmem_budget=vmem_budget or megakernel.VMEM_BUDGET,
        precision=None if precision == "fp32" else precision,
        batch=batch,
    )
    return pln.hbm_bytes(batch=batch)


def meshnet_collective_bytes(
    cfg,
    vol: Shape3,
    num_devices: int,
    batch: int = 1,
    precision: str = "fp32",
) -> int:
    """Modeled inter-device (ICI) bytes of one Z-sharded forward
    (core/spatial_shard.py, DESIGN.md §2.2).

    Each of the ``num_devices - 1`` slab boundaries exchanges, summed over
    the layer-wise schedule, ``2 * sum(dilations)`` Z-slices of the hidden
    activation in each direction:

        per_boundary = 2 * sum(dilations) * H * W * C_hidden * act_bytes

    (the one-shot RF-radius fetch of the megakernel inner moves the same
    slice count once, at the input channel width — this single formula is
    the accounting convention for the whole family). Reduced precisions
    exchange bf16 slabs, so the halo bill halves with the activations.
    Zero at one device; monotone in device count
    (tests/test_properties.py)."""
    n = int(num_devices)
    if n <= 1:
        return 0
    ab = quantize.act_bytes(precision)
    _, h, w = (int(s) for s in vol)
    per_boundary = 2 * sum(cfg.dilations) * h * w * cfg.channels * ab
    return batch * (n - 1) * per_boundary


def meshnet_sharded_bytes(
    inner: str,
    cfg,
    vol: Shape3,
    num_devices: int,
    batch: int = 1,
    precision: str = "fp32",
) -> int:
    """Modeled HBM bytes of one Z-sharded forward: every device runs the
    inner schedule on its slab, so the total is ``n`` times the inner
    model priced at the per-device window. The megakernel inner plans on
    the slab plus its one-shot RF-radius halo (that window is what its
    tiles actually read); the layer-wise inners are priced at the bare
    slab — their halo traffic crosses ICI, not HBM, and is accounted by
    ``meshnet_collective_bytes``. Per-device HBM = this / n
    (EXPERIMENTS.md §Perf H10)."""
    n = int(num_devices)
    d, h, w = (int(s) for s in vol)
    if d % n:
        from repro.core.spatial_shard import ShardGeometryError

        raise ShardGeometryError(f"Z dim {d} not divisible by {n} slabs")
    dloc = d // n
    if inner == "pallas_megakernel":
        radius = sum(cfg.dilations)
        per_dev = meshnet_megakernel_bytes(
            cfg, (dloc + 2 * radius, h, w), batch=batch, precision=precision
        )
    else:
        fn = EXECUTOR_MODELS[inner]
        per_dev = fn(cfg, (dloc, h, w), batch=batch, precision=precision)
    return n * per_dev


#: executor name -> modeled-bytes fn, the mapping the registry wires up
#: (base backends; the sharded family prices itself via
#: ``meshnet_sharded_bytes`` with its inner name and slab count).
EXECUTOR_MODELS = {
    "xla": meshnet_xla_bytes,
    "pallas_fused": meshnet_fused_bytes,
    "streaming": meshnet_streaming_bytes,
    "pallas_megakernel": meshnet_megakernel_bytes,
}


def executor_hbm_bytes(
    name: str, cfg, vol: Shape3, batch: int = 1, precision: str = "fp32"
) -> int | None:
    """Modeled bytes for a registered executor, or None if unmodeled."""
    fn = EXECUTOR_MODELS.get(name)
    return None if fn is None else fn(cfg, vol, batch=batch, precision=precision)
