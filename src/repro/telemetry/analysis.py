"""Statistical analysis of telemetry — the paper's §IV toolkit.

Re-implements, over simulated fleet telemetry, the analyses the paper runs
over its 1336 browser sessions: success-rate contingency tables,
Chi-square tests for independence, statistical power, and IPTW (inverse
probability of treatment weighting) causal effect estimates for the
patching / cropping / texture-size interventions.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np
from scipy import stats


@dataclasses.dataclass
class ContingencyResult:
    table: np.ndarray  # 2x2 [treatment x outcome]
    chi2: float
    p_value: float
    success_rate_treated: float
    success_rate_control: float
    power: float

    def summary(self) -> str:
        return (
            f"chi2={self.chi2:.3f} p={self.p_value:.2e} "
            f"SR(treated)={self.success_rate_treated*100:.2f}% "
            f"SR(control)={self.success_rate_control*100:.2f}% power={self.power:.3f}"
        )


def contingency(treated_ok: int, treated_fail: int, control_ok: int, control_fail: int,
                alpha: float = 0.05) -> ContingencyResult:
    """Chi-square test for a 2x2 treatment/outcome table + power analysis
    (the paper: power 0.963 at alpha 0.05 for the full dataset)."""
    table = np.array([[treated_ok, treated_fail], [control_ok, control_fail]], float)
    if (table.sum(0) == 0).any() or (table.sum(1) == 0).any():
        # Degenerate margin (e.g. zero successes in both arms): no evidence.
        tr = treated_ok / max(treated_ok + treated_fail, 1)
        cr = control_ok / max(control_ok + control_fail, 1)
        return ContingencyResult(table, 0.0, 1.0, tr, cr, 0.0)
    chi2, p, _, _ = stats.chi2_contingency(table, correction=False)
    n = table.sum()
    w = math.sqrt(chi2 / n)  # effect size (phi)
    # power of chi-square test with df=1 at this effect size and sample size
    nc = n * w * w  # noncentrality
    crit = stats.chi2.ppf(1 - alpha, df=1)
    power = 1 - stats.ncx2.cdf(crit, df=1, nc=max(nc, 1e-9))
    tr = treated_ok / max(treated_ok + treated_fail, 1)
    cr = control_ok / max(control_ok + control_fail, 1)
    return ContingencyResult(table, float(chi2), float(p), tr, cr, float(power))


def iptw_ate(treatment: np.ndarray, outcome: np.ndarray, confounders: np.ndarray) -> float:
    """IPTW Average Treatment Effect:
        ATE = E[Y | do(T=1)] - E[Y | do(T=0)]
    with propensity scores from a logistic regression of T on confounders
    (fitted by Newton iterations — no sklearn dependency).
    """
    X = np.column_stack([np.ones(len(treatment)), confounders])
    beta = np.zeros(X.shape[1])
    for _ in range(50):
        p = 1.0 / (1.0 + np.exp(-X @ beta))
        W = p * (1 - p) + 1e-6
        grad = X.T @ (treatment - p)
        hess = (X * W[:, None]).T @ X + 1e-6 * np.eye(X.shape[1])
        step = np.linalg.solve(hess, grad)
        beta += step
        if np.abs(step).max() < 1e-8:
            break
    p = np.clip(1.0 / (1.0 + np.exp(-X @ beta)), 1e-3, 1 - 1e-3)
    w1 = treatment / p
    w0 = (1 - treatment) / (1 - p)
    ate = (w1 * outcome).sum() / w1.sum() - (w0 * outcome).sum() / w0.sum()
    return float(ate)


def regression_adjustment(treatment, outcome, confounders) -> float:
    """OLS effect of treatment on outcome controlling for confounders
    (the paper's 'regression adjustment' patching estimate)."""
    X = np.column_stack([np.ones(len(treatment)), treatment, confounders])
    coef, *_ = np.linalg.lstsq(X, outcome, rcond=None)
    return float(coef[1])


@dataclasses.dataclass
class PrecisionSummary:
    """Aggregate of one (executor, precision) serving cell."""

    executor: str
    precision: str
    runs: int
    ok_rate: float
    mean_hbm_bytes: float  # modeled, per run (0 when unmodeled)
    mean_collective_bytes: float
    mean_params_bytes: float

    def row(self) -> str:
        return (
            f"{self.executor},{self.precision},{self.runs},"
            f"{self.ok_rate:.3f},{self.mean_hbm_bytes:.0f},"
            f"{self.mean_collective_bytes:.0f},{self.mean_params_bytes:.0f}"
        )


@dataclasses.dataclass
class ClassSummary:
    """Aggregate of one serving priority class over scheduler-stamped
    telemetry (TelemetryRecord.priority_class etc., serving/scheduler.py).
    Times are whatever clock stamped the records — virtual seconds under
    the load simulator (deterministic), wall seconds in production."""

    priority_class: str
    requests: int
    served: int  # reached service (completed or demoted)
    demoted: int
    shed: dict  # typed pre-service rejections: fail_type -> count
    ok_rate: float  # of served requests
    p50_wait_s: float
    p99_wait_s: float
    p50_service_s: float
    p99_service_s: float
    mean_batch_size: float

    def row(self) -> str:
        return (
            f"{self.priority_class},{self.requests},{self.served},"
            f"{self.demoted},{sum(self.shed.values())},{self.ok_rate:.3f},"
            f"{self.p50_wait_s:.4f},{self.p99_wait_s:.4f},"
            f"{self.p50_service_s:.4f},{self.p99_service_s:.4f},"
            f"{self.mean_batch_size:.2f}"
        )


#: pre-service shed reasons the scheduler emits (vs execution failures).
SHED_TYPES = ("queue_full", "deadline_expired", "admission_oom")


def nearest_rank(values, q: float) -> float:
    """Deterministic nearest-rank percentile (no interpolation) — THE
    percentile of the serving stack: class_summary, the load simulator's
    summaries, and the golden serving traces all use this one function,
    so their numbers stay byte-stable and mutually consistent."""
    if not values:
        return 0.0
    s = sorted(values)
    rank = max(1, math.ceil(q / 100.0 * len(s)))
    return float(s[rank - 1])


def class_summary(records) -> list[ClassSummary]:
    """Per-priority-class queue/latency rollup over a telemetry log — the
    serving-tier SLO view: how long each class waited, how long service
    took, how much was demoted or shed. Records without a
    ``priority_class`` stamp (direct pipeline runs) are skipped. Sorted
    by class name for stable output."""
    by: dict[str, list] = {}
    for r in records:
        if r.priority_class is not None:
            by.setdefault(r.priority_class, []).append(r)
    out = []
    for name in sorted(by):
        rs = by[name]
        shed = {
            t: sum(1 for r in rs if r.fail_type == t)
            for t in SHED_TYPES
            if any(r.fail_type == t for r in rs)
        }
        served = [r for r in rs if r.fail_type not in SHED_TYPES]
        # wait percentiles over SERVED requests only: queue-full refusals
        # are stamped with zero wait at submit time and would drag the
        # percentiles down exactly when overload makes them matter
        waits = [r.queue_wait_s for r in served if r.queue_wait_s is not None]
        services = [r.service_s for r in served if r.service_s is not None]
        batches = [r.batch_size for r in served if r.batch_size is not None]
        out.append(
            ClassSummary(
                priority_class=name,
                requests=len(rs),
                served=len(served),
                demoted=sum(1 for r in served if r.demoted),
                shed=shed,
                ok_rate=sum(1 for r in served if r.status == "ok")
                / max(len(served), 1),
                p50_wait_s=nearest_rank(waits, 50),
                p99_wait_s=nearest_rank(waits, 99),
                p50_service_s=nearest_rank(services, 50),
                p99_service_s=nearest_rank(services, 99),
                mean_batch_size=float(np.mean(batches)) if batches else 0.0,
            )
        )
    return out


def slo_attainment(records, slo_s: dict) -> dict:
    """Fraction of each class's requests that got a SUCCESSFUL answer
    within the class's SLO bound, end to end (``queue_wait_s +
    service_s`` — the scheduler stamps wait up to the member's own
    service start, so the sum is exactly finish - arrival even deep
    inside a batch). Classes without a bound in ``slo_s`` are omitted;
    shed requests AND failed runs count as misses — either way the user
    spent their patience without an answer."""
    out: dict[str, float] = {}
    for s in class_summary(records):
        bound = slo_s.get(s.priority_class)
        if bound is None:
            continue
        rs = [r for r in records if r.priority_class == s.priority_class]
        met = sum(
            1
            for r in rs
            if r.status == "ok"
            and r.queue_wait_s is not None
            and r.service_s is not None
            and (r.queue_wait_s + r.service_s) <= bound
        )
        out[s.priority_class] = met / max(len(rs), 1)
    return out


@dataclasses.dataclass
class ReplicaSummary:
    """Aggregate of one fleet replica over replica-stamped telemetry
    (TelemetryRecord.replica_id, serving/fleet.py) — the per-server view
    of the fleet rollup: how much each replica served, how well, and how
    long its queue ran."""

    replica_id: int
    requests: int
    served: int  # reached service on this replica (completed or demoted)
    demoted: int
    shed: dict  # typed pre-service rejections on this replica
    ok_rate: float  # of served requests
    p50_wait_s: float
    p99_wait_s: float
    mean_batch_size: float

    def row(self) -> str:
        return (
            f"{self.replica_id},{self.requests},{self.served},{self.demoted},"
            f"{sum(self.shed.values())},{self.ok_rate:.3f},"
            f"{self.p50_wait_s:.4f},{self.p99_wait_s:.4f},{self.mean_batch_size:.2f}"
        )


def replica_summary(records) -> list[ReplicaSummary]:
    """Per-replica queue/latency rollup over a fleet telemetry stream —
    the horizontal cut ``class_summary`` doesn't see: a hot replica hides
    inside healthy fleet-wide percentiles, but not inside its own row.
    Records without a ``replica_id`` stamp (single-server or direct
    pipeline runs) are skipped. Sorted by replica id for stable output."""
    by: dict[int, list] = {}
    for r in records:
        if r.replica_id is not None:
            by.setdefault(r.replica_id, []).append(r)
    out = []
    for rid in sorted(by):
        rs = by[rid]
        shed = {
            t: sum(1 for r in rs if r.fail_type == t)
            for t in SHED_TYPES
            if any(r.fail_type == t for r in rs)
        }
        served = [r for r in rs if r.fail_type not in SHED_TYPES]
        waits = [r.queue_wait_s for r in served if r.queue_wait_s is not None]
        batches = [r.batch_size for r in served if r.batch_size is not None]
        out.append(
            ReplicaSummary(
                replica_id=rid,
                requests=len(rs),
                served=len(served),
                demoted=sum(1 for r in served if r.demoted),
                shed=shed,
                ok_rate=sum(1 for r in served if r.status == "ok")
                / max(len(served), 1),
                p50_wait_s=nearest_rank(waits, 50),
                p99_wait_s=nearest_rank(waits, 99),
                mean_batch_size=float(np.mean(batches)) if batches else 0.0,
            )
        )
    return out


#: execution-fault fail_types the resilience layer stamps
#: (serving/errors.py). Hardcoded strings rather than an import:
#: repro.serving imports repro.telemetry, so importing serving.errors
#: here would create an import cycle — the golden tests pin both sides.
FAULT_TYPES = ("transient_fault", "permanent_fault", "service_timeout")
#: the retryable subset — the recovery denominator: permanent faults are
#: unrecoverable BY DESIGN (the ladder routes around them instead), so
#: they must not dilute the retry layer's recovery rate.
RETRYABLE_TYPES = ("transient_fault", "service_timeout")


@dataclasses.dataclass
class ResilienceSummary:
    """Aggregate of the resilience layer's attempt stream — reconstructed
    from telemetry alone (TelemetryRecord.attempt, serving/errors.py fail
    types): every service attempt emits its own record, so grouping on
    (replica_id, request_id) and taking the highest attempt recovers each
    request's terminal state without consulting the scheduler."""

    requests: int  # scheduler-stamped requests seen (unique ids)
    attempts: int  # service-attempt records (>= requests)
    retries: int  # attempts beyond each request's first
    faults: dict  # fail_type -> attempt count, over FAULT_TYPES
    faulted_requests: int  # requests with >= 1 RETRYABLE faulted attempt
    recovered_requests: int  # faulted requests whose terminal attempt is ok
    recovery_rate: float  # recovered / faulted (1.0 when nothing faulted)

    def row(self) -> str:
        return (
            f"{self.requests},{self.attempts},{self.retries},"
            f"{sum(self.faults.values())},{self.faulted_requests},"
            f"{self.recovered_requests},{self.recovery_rate:.3f}"
        )


def resilience_summary(records) -> ResilienceSummary:
    """Fault/retry/recovery rollup over a telemetry log — the analysis
    face of serving/resilience.py. Records without a ``request_id`` stamp
    (direct pipeline runs) are skipped; pre-service sheds (``SHED_TYPES``)
    are not attempts and are skipped too."""
    by: dict[tuple, list] = {}
    for r in records:
        if r.request_id is None or r.fail_type in SHED_TYPES:
            continue
        by.setdefault((r.replica_id, r.request_id), []).append(r)
    attempts = sum(len(rs) for rs in by.values())
    faults = {
        t: sum(1 for rs in by.values() for r in rs if r.fail_type == t)
        for t in FAULT_TYPES
    }
    faulted = recovered = 0
    for rs in by.values():
        if not any(r.fail_type in RETRYABLE_TYPES for r in rs):
            continue
        faulted += 1
        terminal = max(rs, key=lambda r: r.attempt)
        if terminal.status == "ok":
            recovered += 1
    return ResilienceSummary(
        requests=len(by),
        attempts=attempts,
        retries=attempts - len(by),
        faults=faults,
        faulted_requests=faulted,
        recovered_requests=recovered,
        recovery_rate=recovered / faulted if faulted else 1.0,
    )


@dataclasses.dataclass
class CacheSummary:
    """Aggregate of the artifact-cache tier as seen from telemetry alone
    (TelemetryRecord.cache_hit, serving/cache.py): every cache-served
    answer carries the ``cache_hit`` stamp, admission hits pay the verify
    service, and coalesced followers ride their leader's record with
    zero service — so the split is recoverable without the cache object.
    Pass the cache's own ``summary()`` dict as ``store_stats`` to merge
    the store-side ledger (stores / quarantines / evictions / breaker)."""

    requests: int  # scheduler-stamped records seen
    cache_served: int  # records answered from the cache tier
    admission_hits: int  # clean artifact (or negative) hits at admission
    coalesced: int  # followers collapsed onto an in-flight leader
    negative_serves: int  # known-permanent failures answered from cache
    computed: int  # everything else — requests that touched the device
    cache_served_rate: float  # cache_served / requests
    store_stats: dict  # the cache's own counter ledger ({} if not given)

    def row(self) -> str:
        return (
            f"{self.requests},{self.cache_served},{self.admission_hits},"
            f"{self.coalesced},{self.negative_serves},{self.computed},"
            f"{self.cache_served_rate:.3f}"
        )


def cache_summary(records, store_stats: dict | None = None) -> CacheSummary:
    """Cache-tier rollup over a telemetry log — the analysis face of
    serving/cache.py. Records without a ``request_id`` stamp (direct
    pipeline runs) are skipped, as are pre-service sheds (``SHED_TYPES``
    — a refused request never consulted the cache's serving path).
    Coalesced followers are the cache-hit records with exactly zero
    service: the leader's artifact was handed over at completion time,
    no verify read was paid. ``store_stats`` (an
    ``ArtifactCache.summary()`` dict) is attached verbatim when given —
    counters like quarantines and evictions live only in the store."""
    rs = [
        r
        for r in records
        if r.request_id is not None and r.fail_type not in SHED_TYPES
    ]
    served = [r for r in rs if r.cache_hit]
    coalesced = sum(1 for r in served if r.service_s == 0.0)
    negative = sum(
        1 for r in served if r.extra is not None and r.extra.get("negative_cache")
    )
    return CacheSummary(
        requests=len(rs),
        cache_served=len(served),
        admission_hits=len(served) - coalesced,
        coalesced=coalesced,
        negative_serves=negative,
        computed=len(rs) - len(served),
        cache_served_rate=len(served) / max(len(rs), 1),
        store_stats=dict(store_stats) if store_stats else {},
    )


def precision_summary(records) -> list[PrecisionSummary]:
    """Per-(executor, precision) traffic/footprint aggregates over a
    telemetry log — the fleet view of the precision policy: which backend
    ran at which storage policy, how often it succeeded, and the modeled
    HBM / collective / weight bytes it moved (TelemetryRecord.precision
    and .params_bytes, stamped by core/pipeline.py). Sorted by descending
    run count so the dominant serving cell leads."""
    cells: dict = {}
    for r in records:
        key = (r.executor or "?", r.precision or "fp32")
        cells.setdefault(key, []).append(r)
    out = []
    for (executor, precision), rs in cells.items():
        ok = sum(1 for r in rs if r.status == "ok")
        out.append(
            PrecisionSummary(
                executor=executor,
                precision=precision,
                runs=len(rs),
                ok_rate=ok / len(rs),
                mean_hbm_bytes=float(
                    np.mean([r.hbm_bytes_modeled or 0 for r in rs])
                ),
                mean_collective_bytes=float(
                    np.mean([r.collective_bytes_modeled or 0 for r in rs])
                ),
                mean_params_bytes=float(
                    np.mean([r.params_bytes or 0 for r in rs])
                ),
            )
        )
    return sorted(out, key=lambda s: -s.runs)
