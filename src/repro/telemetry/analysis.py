"""Statistical analysis of telemetry — the paper's §IV toolkit.

Re-implements, over simulated fleet telemetry, the analyses the paper runs
over its 1336 browser sessions: success-rate contingency tables,
Chi-square tests for independence, statistical power, and IPTW (inverse
probability of treatment weighting) causal effect estimates for the
patching / cropping / texture-size interventions.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np
from scipy import stats


@dataclasses.dataclass
class ContingencyResult:
    table: np.ndarray  # 2x2 [treatment x outcome]
    chi2: float
    p_value: float
    success_rate_treated: float
    success_rate_control: float
    power: float

    def summary(self) -> str:
        return (
            f"chi2={self.chi2:.3f} p={self.p_value:.2e} "
            f"SR(treated)={self.success_rate_treated*100:.2f}% "
            f"SR(control)={self.success_rate_control*100:.2f}% power={self.power:.3f}"
        )


def contingency(treated_ok: int, treated_fail: int, control_ok: int, control_fail: int,
                alpha: float = 0.05) -> ContingencyResult:
    """Chi-square test for a 2x2 treatment/outcome table + power analysis
    (the paper: power 0.963 at alpha 0.05 for the full dataset)."""
    table = np.array([[treated_ok, treated_fail], [control_ok, control_fail]], float)
    if (table.sum(0) == 0).any() or (table.sum(1) == 0).any():
        # Degenerate margin (e.g. zero successes in both arms): no evidence.
        tr = treated_ok / max(treated_ok + treated_fail, 1)
        cr = control_ok / max(control_ok + control_fail, 1)
        return ContingencyResult(table, 0.0, 1.0, tr, cr, 0.0)
    chi2, p, _, _ = stats.chi2_contingency(table, correction=False)
    n = table.sum()
    w = math.sqrt(chi2 / n)  # effect size (phi)
    # power of chi-square test with df=1 at this effect size and sample size
    nc = n * w * w  # noncentrality
    crit = stats.chi2.ppf(1 - alpha, df=1)
    power = 1 - stats.ncx2.cdf(crit, df=1, nc=max(nc, 1e-9))
    tr = treated_ok / max(treated_ok + treated_fail, 1)
    cr = control_ok / max(control_ok + control_fail, 1)
    return ContingencyResult(table, float(chi2), float(p), tr, cr, float(power))


def iptw_ate(treatment: np.ndarray, outcome: np.ndarray, confounders: np.ndarray) -> float:
    """IPTW Average Treatment Effect:
        ATE = E[Y | do(T=1)] - E[Y | do(T=0)]
    with propensity scores from a logistic regression of T on confounders
    (fitted by Newton iterations — no sklearn dependency).
    """
    X = np.column_stack([np.ones(len(treatment)), confounders])
    beta = np.zeros(X.shape[1])
    for _ in range(50):
        p = 1.0 / (1.0 + np.exp(-X @ beta))
        W = p * (1 - p) + 1e-6
        grad = X.T @ (treatment - p)
        hess = (X * W[:, None]).T @ X + 1e-6 * np.eye(X.shape[1])
        step = np.linalg.solve(hess, grad)
        beta += step
        if np.abs(step).max() < 1e-8:
            break
    p = np.clip(1.0 / (1.0 + np.exp(-X @ beta)), 1e-3, 1 - 1e-3)
    w1 = treatment / p
    w0 = (1 - treatment) / (1 - p)
    ate = (w1 * outcome).sum() / w1.sum() - (w0 * outcome).sum() / w0.sum()
    return float(ate)


def regression_adjustment(treatment, outcome, confounders) -> float:
    """OLS effect of treatment on outcome controlling for confounders
    (the paper's 'regression adjustment' patching estimate)."""
    X = np.column_stack([np.ones(len(treatment)), treatment, confounders])
    coef, *_ = np.linalg.lstsq(X, outcome, rcond=None)
    return float(coef[1])


@dataclasses.dataclass
class PrecisionSummary:
    """Aggregate of one (executor, precision) serving cell."""

    executor: str
    precision: str
    runs: int
    ok_rate: float
    mean_hbm_bytes: float  # modeled, per run (0 when unmodeled)
    mean_collective_bytes: float
    mean_params_bytes: float

    def row(self) -> str:
        return (
            f"{self.executor},{self.precision},{self.runs},"
            f"{self.ok_rate:.3f},{self.mean_hbm_bytes:.0f},"
            f"{self.mean_collective_bytes:.0f},{self.mean_params_bytes:.0f}"
        )


def precision_summary(records) -> list[PrecisionSummary]:
    """Per-(executor, precision) traffic/footprint aggregates over a
    telemetry log — the fleet view of the precision policy: which backend
    ran at which storage policy, how often it succeeded, and the modeled
    HBM / collective / weight bytes it moved (TelemetryRecord.precision
    and .params_bytes, stamped by core/pipeline.py). Sorted by descending
    run count so the dominant serving cell leads."""
    cells: dict = {}
    for r in records:
        key = (r.executor or "?", r.precision or "fp32")
        cells.setdefault(key, []).append(r)
    out = []
    for (executor, precision), rs in cells.items():
        ok = sum(1 for r in rs if r.status == "ok")
        out.append(
            PrecisionSummary(
                executor=executor,
                precision=precision,
                runs=len(rs),
                ok_rate=ok / len(rs),
                mean_hbm_bytes=float(
                    np.mean([r.hbm_bytes_modeled or 0 for r in rs])
                ),
                mean_collective_bytes=float(
                    np.mean([r.collective_bytes_modeled or 0 for r in rs])
                ),
                mean_params_bytes=float(
                    np.mean([r.params_bytes or 0 for r in rs])
                ),
            )
        )
    return sorted(out, key=lambda s: -s.runs)
