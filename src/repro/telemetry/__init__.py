"""Telemetry substrate: per-stage timing records, the memory-budget
simulator standing in for the browser's WebGL limits, and the statistical
analysis used to regenerate the paper's Tables V–VIII."""

from repro.telemetry.record import StageTimes, TelemetryRecord, TelemetryLog
from repro.telemetry.budget import MemoryBudget, BudgetExceeded

__all__ = [
    "StageTimes",
    "TelemetryRecord",
    "TelemetryLog",
    "MemoryBudget",
    "BudgetExceeded",
]
