"""Telemetry records — the schema of the paper's Tables III/IV.

Brainchop collects anonymized per-run telemetry (stage timings, model,
status, failure type). We keep the same columns so the analysis code in
telemetry/analysis.py can regenerate the paper's contingency tables from
simulated runs.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional


@dataclasses.dataclass
class StageTimes:
    """Per-stage wall times in seconds (Table IV columns)."""

    preprocessing: float = 0.0
    cropping: float = 0.0
    inference: float = 0.0
    merging: float = 0.0
    postprocessing: float = 0.0

    def total(self) -> float:
        return (
            self.preprocessing
            + self.cropping
            + self.inference
            + self.merging
            + self.postprocessing
        )


@dataclasses.dataclass
class TelemetryRecord:
    model: str
    mode: str  # full | subvolume | streaming
    status: str  # ok | fail
    times: StageTimes
    # which forward backend ran (core/executors.py): xla | pallas_fused |
    # pallas_megakernel | streaming — the server-side analogue of the paper
    # logging the WebGL vs WASM backend per run.
    executor: Optional[str] = None
    # modeled HBM bytes the executor's schedule moves for this run's
    # inference (telemetry/traffic.py) — the TPU analogue of the paper
    # tracking texture bandwidth per backend.
    hbm_bytes_modeled: Optional[int] = None
    # modeled inter-device (ICI) bytes of the run's halo exchanges — 0 for
    # single-device executors, the traffic.meshnet_collective_bytes model
    # for the sharded family (core/spatial_shard.py, DESIGN.md §2.2).
    collective_bytes_modeled: Optional[int] = None
    # storage policy the forward ran under (kernels/quantize.py:
    # fp32 | bf16 | int8w) — the server-side analogue of the paper logging
    # the client's texture precision; hbm/collective bytes above are
    # priced at this policy's widths.
    precision: Optional[str] = None
    # bytes of the (possibly quantized) weight pytree the executor
    # streams — 4x smaller under int8w (quantize.model_params_bytes).
    params_bytes: Optional[int] = None
    fail_type: Optional[str] = None
    crop_size: Optional[tuple] = None
    # device context (the simulator's stand-ins for GPU card / texture size)
    memory_budget_bytes: Optional[int] = None
    extra: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d)


class TelemetryLog:
    """Append-only JSONL log + in-memory list (the 1336-sample dataset
    analogue)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.records: list[TelemetryRecord] = []

    def append(self, rec: TelemetryRecord) -> None:
        self.records.append(rec)
        if self.path:
            with open(self.path, "a") as f:
                f.write(rec.to_json() + "\n")

    def success_rate(self) -> float:
        if not self.records:
            return 0.0
        ok = sum(1 for r in self.records if r.status == "ok")
        return ok / len(self.records)

    def by(self, key) -> dict:
        out: dict = {}
        for r in self.records:
            out.setdefault(key(r), []).append(r)
        return out
