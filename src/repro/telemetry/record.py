"""Telemetry records — the schema of the paper's Tables III/IV.

Brainchop collects anonymized per-run telemetry (stage timings, model,
status, failure type). We keep the same columns so the analysis code in
telemetry/analysis.py can regenerate the paper's contingency tables from
simulated runs.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional


@dataclasses.dataclass
class StageTimes:
    """Per-stage wall times in seconds (Table IV columns)."""

    preprocessing: float = 0.0
    cropping: float = 0.0
    inference: float = 0.0
    merging: float = 0.0
    postprocessing: float = 0.0

    def total(self) -> float:
        return (
            self.preprocessing
            + self.cropping
            + self.inference
            + self.merging
            + self.postprocessing
        )


@dataclasses.dataclass
class TelemetryRecord:
    model: str
    mode: str  # full | subvolume | streaming
    status: str  # ok | fail
    times: StageTimes
    # which forward backend ran (core/executors.py): xla | pallas_fused |
    # pallas_megakernel | streaming — the server-side analogue of the paper
    # logging the WebGL vs WASM backend per run.
    executor: Optional[str] = None
    # modeled HBM bytes the executor's schedule moves for this run's
    # inference (telemetry/traffic.py) — the TPU analogue of the paper
    # tracking texture bandwidth per backend.
    hbm_bytes_modeled: Optional[int] = None
    # modeled inter-device (ICI) bytes of the run's halo exchanges — 0 for
    # single-device executors, the traffic.meshnet_collective_bytes model
    # for the sharded family (core/spatial_shard.py, DESIGN.md §2.2).
    collective_bytes_modeled: Optional[int] = None
    # storage policy the forward ran under (kernels/quantize.py:
    # fp32 | bf16 | int8w) — the server-side analogue of the paper logging
    # the client's texture precision; hbm/collective bytes above are
    # priced at this policy's widths.
    precision: Optional[str] = None
    # bytes of the (possibly quantized) weight pytree the executor
    # streams — 4x smaller under int8w (quantize.model_params_bytes).
    params_bytes: Optional[int] = None
    fail_type: Optional[str] = None
    crop_size: Optional[tuple] = None
    # device context (the simulator's stand-ins for GPU card / texture size)
    memory_budget_bytes: Optional[int] = None
    # ---- serving-path fields (serving/scheduler.py) --------------------
    # Stamped by the request scheduler on queued requests; None on direct
    # pipeline runs. Under the deterministic load simulator these are
    # *virtual-clock* seconds (serving/simulator.py), which is what makes
    # the fleet latency rollups bit-reproducible in CI.
    request_id: Optional[int] = None
    # arrival time of the request on the scheduler's clock
    arrival_s: Optional[float] = None
    # time spent queued before its batch started service
    queue_wait_s: Optional[float] = None
    # modeled (virtual clock) or measured (real clock) service time
    service_s: Optional[float] = None
    # how many requests shared this request's dispatch group (>= 1)
    batch_size: Optional[int] = None
    # admission class the scheduler served it under
    priority_class: Optional[str] = None
    # True when HBM-budget admission shed the request to the sub-volume
    # failsafe (the paper's patching intervention, applied as backpressure)
    demoted: bool = False
    # True when the content-addressed artifact cache (serving/cache.py)
    # served this request in O(hash) without touching a device — the
    # record's service_s is the cache lookup+verify cost, not a forward.
    # Coalesced followers of a single-flight leader are also stamped True.
    cache_hit: bool = False
    # which fleet replica served (or shed) the request — stamped by the
    # fleet layer (serving/fleet.py); None outside fleet serving. A
    # request re-dispatched after a replica crash carries the replica
    # that finally SERVED it, never the one that lost it.
    replica_id: Optional[int] = None
    # which service attempt this record describes (0 = first try): the
    # resilience layer (serving/resilience.py) re-serves retryable
    # faults, and every attempt emits its own record — grouping on
    # (replica_id, request_id) and taking the last attempt reconstructs
    # each request's terminal state from the stream alone.
    attempt: int = 0
    extra: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d)


class TelemetryLog:
    """Append-only JSONL log + in-memory list (the 1336-sample dataset
    analogue)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.records: list[TelemetryRecord] = []

    def append(self, rec: TelemetryRecord) -> None:
        self.records.append(rec)
        if self.path:
            with open(self.path, "a") as f:
                f.write(rec.to_json() + "\n")

    def success_rate(self) -> float:
        if not self.records:
            return 0.0
        ok = sum(1 for r in self.records if r.status == "ok")
        return ok / len(self.records)

    def by(self, key) -> dict:
        out: dict = {}
        for r in self.records:
            out.setdefault(key(r), []).append(r)
        return out
