"""Continuous-batching request scheduler in front of ``SegmentationEngine``.

``SegmentationEngine.submit_many`` is a synchronous for-loop: fine for a
notebook, useless as the serving tier the ROADMAP aims at ("heavy traffic
from millions of users"). This module adds the admission layer cloud-side
medical-image services need (CHIPS, arXiv:1710.00734) in front of the
executor stack PR 1-4 built:

  * a **request queue** with arrival timestamps and bounded depth —
    overflow is a *typed* rejection (``QueueFullError``), the serving
    analogue of the paper's "Unable to create WebGL Texture";
  * **priority / deadline classes** (``PriorityClass``): lower priority
    number is served first, FIFO within a class; a class deadline turns
    queue-time overload into typed ``deadline_expired`` shedding;
  * **HBM-budget-aware admission**: every request's working set is priced
    *before* dispatch via the ``telemetry/budget.py`` models at the
    request's resolved precision (bf16 requests cost half the fp32
    bytes), and a dispatch group is only grown while the summed working
    sets fit ``SchedulerConfig.admission_hbm_bytes``. A request too large
    even alone is **demoted** to the sub-volume failsafe (the paper's
    patching intervention, applied as backpressure) or, failing that,
    rejected with ``admission_oom``;
  * **dynamic grouping**: queued requests sharing a resolved
    ``(mode, executor, devices, precision, shape)`` signature are
    dispatched as ONE group — one jit-cache entry, one prepared weight
    pytree, one mesh — so mixed fleets interleave instead of thrashing
    the compile cache. Signatures are resolved once per unique request
    shape/policy and cached (``stats.resolutions`` counts the misses;
    tests assert the dedupe);
  * **per-request telemetry stamping**: arrival, queue wait, service
    time, batch size, priority class and demotion land on the same
    ``TelemetryRecord`` the pipeline already emits, so the fleet rollups
    in ``telemetry/analysis.py`` see scheduling and execution in one
    stream.

The scheduler is clock-agnostic: pass any object with ``now() -> float``.
Production uses the process monotonic clock; the deterministic load
simulator (``serving/simulator.py``) passes a virtual clock and a
byte-deterministic service-time model, which is how every latency number
it reports is bit-reproducible in CI on CPU. DESIGN.md §5.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Optional

from repro.serving.errors import (  # noqa: F401  (QueueFullError re-exported)
    EXECUTION_FAULT_TYPES,
    PERMANENT_FAULT,
    QueueFullError,
    RETRYABLE_FAIL_TYPES,
    SERVICE_TIMEOUT,
    TRANSIENT_FAULT,
    PermanentExecutorError,
    ResilienceConfigError,
    TransientExecutorError,
    classify,
)
from repro.telemetry.budget import BudgetExceeded, MemoryBudget
from repro.telemetry.record import StageTimes, TelemetryRecord


@dataclasses.dataclass(frozen=True)
class PriorityClass:
    """One admission class. ``priority`` orders dispatch (lower first);
    ``deadline_s`` bounds *queue* time — a request still queued past its
    deadline is shed with a typed ``deadline_expired`` rejection rather
    than served uselessly late. ``None`` never expires."""

    name: str
    priority: int
    deadline_s: Optional[float] = None


#: default class ladder: interactive requests preempt batch work and are
#: shed rather than served seconds late; batch work waits indefinitely.
DEFAULT_CLASSES = {
    "interactive": PriorityClass("interactive", 0, deadline_s=30.0),
    "standard": PriorityClass("standard", 1, deadline_s=120.0),
    "batch": PriorityClass("batch", 2, deadline_s=None),
}


@dataclasses.dataclass(frozen=True)
class GroupKey:
    """The compatibility signature of a dispatch group: requests sharing
    it hit one compiled executable (the registry's jit cache keys on
    executor/precision + traced shape) and one prepared weight pytree."""

    mode: str
    executor: str
    devices: Optional[int]
    precision: str
    shape: tuple


@dataclasses.dataclass
class ServeRequest:
    """One queued segmentation request (internal to the scheduler)."""

    id: int
    vol: Any
    priority_class: PriorityClass
    arrival_s: float
    deadline_s: Optional[float]  # absolute, on the scheduler's clock
    # raw per-request overrides (None = engine defaults)
    mode: Optional[str]
    executor: Optional[str]
    devices: Optional[int]
    precision: Optional[str]
    # resolved admission signature (None for garbage volumes, which are
    # dispatched solo so their typed failure cannot poison a group)
    key: Optional[GroupKey] = None
    bytes_priced: int = 0
    demoted: bool = False
    # resilience state (serving/resilience.py). ``base_key`` is the
    # signature as admitted, BEFORE any breaker demotion — the breaker's
    # ledger key and the rung half-open probes retry; ``attempt`` counts
    # completed service attempts (0 == first try); ``not_before_s`` is
    # the retry-backoff gate (the request stays queued but is not
    # batchable until then — its ORIGINAL arrival stamp is untouched, so
    # deadlines and FIFO order stay honest); ``probe`` marks a half-open
    # breaker probe serving at the base rung; ``faults`` counts the
    # retryable faults this request has absorbed (recovery accounting).
    base_key: Optional[GroupKey] = None
    base_bytes: int = 0
    attempt: int = 0
    not_before_s: float = 0.0
    probe: bool = False
    faults: int = 0
    # content-addressed cache state (serving/cache.py): the artifact key
    # this request LEADS for — set when the admission consult missed and
    # this request registered the single-flight in-flight entry; its
    # terminal record is stored under this key and its followers complete
    # with it. None for non-leaders (hits, followers, uncacheable).
    cache_key: Optional[str] = None


@dataclasses.dataclass
class SchedulerConfig:
    """Admission policy knobs.

    ``admission_hbm_bytes=None`` disables the batch-level budget (each
    request still gets the engine's per-request budget-driven mode
    selection) — the configuration ``submit_many`` uses to keep its
    legacy semantics. ``max_queue_depth=None`` is an unbounded queue.

    ``native_shapes`` picks the serving geometry: ``False`` (default,
    the engine's legacy contract) conforms every volume to the engine
    card's ``volume_shape``, so admission prices THAT shape — the one
    the pipeline actually serves; ``True`` serves each request at its
    own volume geometry (the simulator's heterogeneous-fleet mode),
    pricing, grouping, and executing per request shape.

    ``batched_dispatch`` turns a dispatch group into ONE batched kernel
    launch instead of back-to-back member forwards (opt-in: the legacy
    serialized semantics — and their golden traces — are the default).
    When on: admission prices a request's working set INCLUDING one
    weight-pytree copy, and group growth charges the weights once per
    group rather than once per member (a single batched launch streams
    them once — the per-member sum double-counts); on the modeled path
    (``execute=False`` + a service model) the whole group serves in one
    launch whose duration comes from the batch-N traffic model (weight
    stream amortized, telemetry/traffic.py), every member stamped with
    the launch's shared service interval while ``queue_wait_s +
    service_s == finish - arrival`` still holds exactly per member.
    With ``execute=True`` members still run serially through the
    pipeline (conform/postprocess are per-volume); the group keeps the
    shared compiled executable, and true batched execution is available
    at the executor layer (``executors.apply`` with a leading batch
    dim).
    """

    max_queue_depth: Optional[int] = 64
    admission_hbm_bytes: Optional[int] = None
    max_batch_requests: int = 8
    classes: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_CLASSES))
    allow_demotion: bool = True
    native_shapes: bool = False
    batched_dispatch: bool = False


@dataclasses.dataclass
class SchedulerStats:
    """Conservation ledger. Terminal states are disjoint:

        admitted == completed + demoted + rejected + evacuated + coalesced
        (after drain)

    ``completed`` counts requests that reached service in their admitted
    mode (whatever their pipeline status — a typed *execution* failure is
    still a served request); ``demoted`` counts requests served after
    shed-to-subvolume demotion; ``rejected`` counts requests shed before
    service, by typed reason. ``refused`` counts ``QueueFullError``
    submissions that were never admitted (outside the conservation sum).
    """

    admitted: int = 0
    completed: int = 0
    demoted: int = 0
    rejected: dict = dataclasses.field(default_factory=dict)
    refused: int = 0
    # requests admitted here but handed BACK to the caller before service
    # (fleet failover / drain re-dispatch, serving/fleet.py) — a fourth
    # terminal state of THIS scheduler; the fleet ledger tracks where the
    # request completed instead.
    evacuated: int = 0
    batches: int = 0
    grouped_requests: int = 0
    resolutions: int = 0
    max_queue_depth: int = 0
    # resilience counters (serving/resilience.py). Retried attempts are
    # NOT terminal states: a request that faults and re-enters its lane
    # is still exactly one of completed/demoted/rejected/evacuated in
    # the conservation sum above — these count events, not requests,
    # except the last pair which counts terminal requests for the
    # recovery rate (recovered/faulted).
    retries: int = 0
    transient_faults: int = 0
    permanent_faults: int = 0
    timeouts: int = 0
    faulted_requests: int = 0
    recovered_requests: int = 0
    # artifact-cache counters (serving/cache.py). ``coalesced`` is a
    # FIFTH terminal state in the conservation sum: a request admitted
    # here that completed by attaching to an identical in-flight
    # leader's artifact (single-flight stampede collapsing) — it never
    # entered the queue and never touched a device. ``cache_hits``
    # counts admission-time completions served straight from a verified
    # (or negative-cached) artifact; those are ordinary ``completed``
    # requests, stamped ``cache_hit`` in telemetry.
    coalesced: int = 0
    cache_hits: int = 0

    def rejected_total(self) -> int:
        return sum(self.rejected.values())

    def conserved(self) -> bool:
        return self.admitted == (
            self.completed
            + self.demoted
            + self.rejected_total()
            + self.evacuated
            + self.coalesced
        )


@dataclasses.dataclass
class Batch:
    """One dispatch group: compatible requests served back-to-back."""

    requests: list
    start_s: float


@dataclasses.dataclass
class Completion:
    """Terminal record of one admitted request."""

    id: int
    outcome: str  # completed | demoted | rejected
    record: TelemetryRecord
    result: Any  # PipelineResult | None (rejections / modeled runs)
    arrival_s: float
    finish_s: float


class _MonotonicClock:
    """Production clock: the process monotonic timer."""

    def now(self) -> float:
        return time.monotonic()


class RequestScheduler:
    """Continuous-batching admission in front of one ``SegmentationEngine``.

    ``clock`` is any object with ``now() -> float`` (default: process
    monotonic time). ``service_model`` maps a finished request's
    telemetry record to a *virtual* service duration (see
    ``simulator.ServiceModel``); without one, service time is measured
    from the clock. ``execute=False`` skips the real pipeline and
    synthesizes records from the analytic models — the pure
    discrete-event mode the load simulator's large sweeps use.
    """

    def __init__(
        self,
        engine,
        cfg: Optional[SchedulerConfig] = None,
        *,
        clock=None,
        service_model=None,
        execute: bool = True,
        resilience=None,
        fault_plan=None,
        replica_id: int = 0,
        cache=None,
    ):
        self.engine = engine
        self.cfg = cfg or SchedulerConfig()
        self.clock = clock or _MonotonicClock()
        self.service_model = service_model
        self.execute = execute
        # content-addressed artifact cache (serving/cache.py), consulted
        # at admission: a verified hit completes in O(hash) without
        # touching a device; a miss may register this request as the
        # single-flight leader; identical concurrent requests attach to
        # the leader as followers (``_followers``) and complete with its
        # artifact. Shared across replicas by the fleet layer — the
        # instance IS the shared tier.
        self.cache = cache
        self._followers: dict[str, list[ServeRequest]] = {}
        self._model_fp: Optional[str] = None
        # resilience policy (serving/resilience.py): retry budgets,
        # per-class service timeouts, and the breaker-driven degradation
        # ladder. ``fault_plan`` is the seeded injector the deterministic
        # fault harness uses; ``replica_id`` keys injection decisions and
        # backoff jitter so fleet replicas de-correlate.
        self.resilience = resilience
        self.fault_plan = fault_plan
        self.replica_id = replica_id
        if resilience is not None:
            resilience.validate_against(self.cfg.classes, fault_plan)
        elif fault_plan is not None and fault_plan.has_stuck():
            raise ResilienceConfigError(
                "FaultPlan injects stuck-forever faults but no "
                "ResiliencePolicy (service timeouts) is configured"
            )
        self.breaker = None
        if resilience is not None and resilience.breaker is not None:
            from repro.serving.resilience import SignatureBreaker

            self.breaker = SignatureBreaker(resilience.breaker)
        self.queue: list[ServeRequest] = []
        self.completions: list[Completion] = []
        self.stats = SchedulerStats()
        self._seq = 0
        self._drained = 0  # completions already handed out by drain()
        # resolved signature cache: (shape, mode, executor, devices,
        # precision) -> (GroupKey, priced bytes). One resolution per
        # unique signature across the scheduler's lifetime — this is the
        # dedupe submit_many lacked (ISSUE 5 satellite).
        self._sig_cache: dict[tuple, tuple[GroupKey, int]] = {}

    # ------------------------------------------------------------ admission

    def submit(
        self,
        vol,
        *,
        priority: str = "standard",
        mode: Optional[str] = None,
        executor: Optional[str] = None,
        devices: Optional[int] = None,
        precision: Optional[str] = None,
        arrival_s: Optional[float] = None,
        force: bool = False,
    ) -> int:
        """Enqueue one request; returns its id. Raises ``QueueFullError``
        at the depth limit (the refusal is counted and a typed telemetry
        record is logged, so the fleet view sees shed load).

        ``force=True`` bypasses the depth limit — the fleet router's
        failover re-dispatch path (serving/fleet.py), where a request
        already admitted by a crashed replica must land SOMEWHERE or the
        exactly-once guarantee becomes at-most-once. The overshoot is
        bounded by the dead replica's in-flight count."""
        now = self.clock.now() if arrival_s is None else float(arrival_s)
        cls = self.cfg.classes[priority]
        rid = self._seq
        self._seq += 1
        if (
            not force
            and self.cfg.max_queue_depth is not None
            and len(self.queue) >= self.cfg.max_queue_depth
        ):
            self.stats.refused += 1
            self._log_shed(rid, cls, now, "queue_full")
            raise QueueFullError(len(self.queue), self.cfg.max_queue_depth)
        req = ServeRequest(
            id=rid,
            vol=vol,
            priority_class=cls,
            arrival_s=now,
            deadline_s=None if cls.deadline_s is None else now + cls.deadline_s,
            mode=mode,
            executor=executor,
            devices=devices,
            precision=precision,
        )
        req.key, req.bytes_priced = self._resolve(req)
        req.base_key, req.base_bytes = req.key, req.bytes_priced
        self.stats.admitted += 1
        if self._consult_cache(req, now, force=force):
            return rid  # terminal at admission: hit, negative, or follower
        self.queue.append(req)
        self.stats.max_queue_depth = max(self.stats.max_queue_depth, len(self.queue))
        return rid

    def _resolve(self, req: ServeRequest) -> tuple[Optional[GroupKey], int]:
        """Resolve the request's admission signature — mode (the engine's
        budget-driven failsafe selection), executor name, device count,
        storage policy, shape — and price its working set at that policy.
        Cached per unique raw signature: N same-shaped requests cost ONE
        mode resolution and ONE budget pricing, not N."""
        shape = getattr(req.vol, "shape", None)
        if shape is None or len(tuple(shape)) != 3:
            # Garbage volume: no signature to group on; dispatched solo so
            # its typed failure is isolated from well-formed requests.
            return None, 0
        shape = tuple(int(s) for s in shape)
        raw = (shape, req.mode, req.executor, req.devices, req.precision)
        hit = self._sig_cache.get(raw)
        if hit is None:
            self.stats.resolutions += 1
            hit = self._resolve_uncached(req, shape)
            self._sig_cache[raw] = hit
        return hit

    def _resolve_uncached(self, req, shape) -> tuple[GroupKey, int]:
        from repro.core import executors
        from repro.kernels import quantize

        eng = self.engine
        # the geometry this request will actually be served at: its own
        # under native_shapes, else the engine card's conform target —
        # admission must price what the pipeline executes, not the raw
        # input (which conform reshapes anyway).
        if not self.cfg.native_shapes:
            shape = tuple(int(s) for s in eng.cfg.volume_shape)
        precision = quantize.resolve_precision(
            req.precision or eng.precision, eng.cfg.model
        )
        mode = req.mode or eng.pick_mode(shape, precision)
        work_shape = (
            (eng.cfg.cube + 2 * eng.cfg.overlap,) * 3
            if mode == "subvolume"
            else shape
        )
        exec_name = executors.resolve(
            req.executor or eng.cfg.executor, eng.cfg.model, work_shape, precision
        )
        devices = req.devices if req.devices is not None else eng.devices
        if devices is not None:
            # mirror pipeline.run's device-count rewrap so the admission
            # signature names the backend that will actually execute (an
            # explicitly "@n"-pinned name still wins over the default)
            inner = executors.inner_of(exec_name)
            parsed = executors.parse_sharded(exec_name)
            pinned = parsed is not None and parsed[1] is not None
            if devices > 1 and executors.shardable(inner) and not pinned:
                exec_name = executors.ensure_sharded(inner, devices)
            elif devices <= 1:
                exec_name = inner
        key = GroupKey(
            mode=mode,
            executor=exec_name,
            devices=devices,
            precision=precision,
            shape=shape,
        )
        return key, self._price(mode, shape, precision)

    def _price(self, mode: str, shape, precision: str) -> int:
        """Working-set bytes of one request in ``mode`` at ``precision`` —
        the telemetry/budget.py models charged against an unlimited
        budget (so the *pricing* never raises; the admission comparison
        below is what enforces the configured limit). Under
        ``batched_dispatch`` the price additionally carries one weight-
        pytree copy: a solo launch keeps the weights resident alongside
        the activations, and pricing them here is what lets group growth
        charge them ONCE per group (``_group_weight_bytes``) instead of
        once per member."""
        from repro.kernels import quantize

        unl = MemoryBudget.unlimited()
        ab = quantize.act_bytes(precision)
        cfg = self.engine.cfg
        if mode == "subvolume":
            need = unl.charge_subvolume(
                cfg.cube, cfg.overlap, cfg.model, dtype_bytes=ab
            )
        elif mode == "streaming":
            need = unl.charge_streaming(shape, cfg.model, dtype_bytes=ab)
        else:
            need = unl.charge_inference(shape, cfg.model, dtype_bytes=ab)
        if self.cfg.batched_dispatch:
            need += quantize.model_params_bytes(cfg.model, precision)
        return need

    def _group_weight_bytes(self, key) -> int:
        """The weight-pytree bytes shared by every member of a batched
        dispatch group (all members carry the group key's precision).
        Zero under serialized dispatch, where ``_price`` never charged
        weights in the first place."""
        if not self.cfg.batched_dispatch or key is None:
            return 0
        from repro.kernels import quantize

        return quantize.model_params_bytes(self.engine.cfg.model, key.precision)

    # ------------------------------------------------------- artifact cache

    def _consult_cache(self, req: ServeRequest, now: float, force: bool) -> bool:
        """Admission-time cache consult. Returns True when the request is
        TERMINAL already — served from a verified artifact (``completed``
        + ``cache_hits``), from a negative-cached verdict, or attached as
        a single-flight follower (completes with its leader) — and must
        not enter the queue. Returns False on miss/bypass/unavailable:
        the request serves via compute, fail-open, possibly as the new
        in-flight leader. ``force`` marks failover/hedge copies: they may
        take a clean hit (terminal is safe anywhere) but never lead or
        follow — single-flight coupling across exactly-once copies would
        tangle the fleet ledger's cancellation paths."""
        if self.cache is None or req.key is None:
            return False
        from repro.serving import cache as cache_mod
        from repro.serving.errors import CacheCorruptionError

        content = cache_mod.content_hash(req.vol)
        if content is None:
            return False  # no content identity: uncacheable
        if self._model_fp is None:
            self._model_fp = cache_mod.model_fingerprint(self.engine.cfg.model)
        ckey = cache_mod.artifact_key(
            content, self._model_fp, req.key.precision, req.key.mode
        )
        look = self.cache.lookup(
            ckey,
            now=now,
            replica=self.replica_id,
            request_id=req.id,
            group_key=req.key,
        )
        if look.status in ("unavailable", "bypass"):
            return False  # fail open: compute path, no single-flight
        if look.status == "hit":
            try:
                payload = self.cache.serve_payload(look.entry)
            except CacheCorruptionError:
                # double-guard breach path: recompute instead of serving
                look = cache_mod.Lookup(
                    status="miss", slow_factor=look.slow_factor
                )
            else:
                self._complete_from_cache(
                    req, payload, look, now, result=look.entry.result
                )
                return True
        if look.status == "negative":
            self._complete_from_cache(
                req, None, look, now, fail_type=look.entry.fail_type
            )
            return True
        if look.status == "inflight":
            if not force and look.owner == self.replica_id:
                req.cache_key = ckey
                self._followers.setdefault(ckey, []).append(req)
                return True
            return False  # a peer's leader: compute independently
        if look.status == "miss" and not force:
            self.cache.begin(
                ckey,
                replica=self.replica_id,
                now=now,
                est_bytes=cache_mod.artifact_bytes_modeled(req.key.shape),
            )
            req.cache_key = ckey
        if look.slow_factor > 1.0:
            # a slow consult delays THIS request's batch eligibility by
            # the inflated verify cost — latency degradation, fail-open
            req.not_before_s = max(
                req.not_before_s,
                now + self.cache.cfg.verify_s * look.slow_factor,
            )
        return False

    def _complete_from_cache(
        self,
        req: ServeRequest,
        payload: Optional[dict],
        look,
        now: float,
        *,
        fail_type: Optional[str] = None,
        result=None,
    ) -> None:
        """Terminal completion at admission, O(hash): the verified
        artifact's metadata (or the negative-cached fault verdict)
        becomes this request's record, stamped ``cache_hit`` — no queue,
        no batch, no device. ``wait + service == finish - arrival``
        holds with wait == 0 and service == the (possibly slowed)
        verify cost."""
        service = self.cache.cfg.verify_s * look.slow_factor
        finish = now + service
        negative = payload is None
        rec = TelemetryRecord(
            model=self.engine.cfg.name,
            mode=(payload or {}).get("mode") or req.key.mode,
            status="fail" if negative else "ok",
            times=StageTimes(),
            executor=(payload or {}).get("executor") or req.key.executor,
            precision=(payload or {}).get("precision") or req.key.precision,
            params_bytes=(payload or {}).get("params_bytes"),
            fail_type=fail_type,
            request_id=req.id,
            arrival_s=req.arrival_s,
            queue_wait_s=0.0,
            service_s=service,
            batch_size=1,
            priority_class=req.priority_class.name,
            cache_hit=True,
            extra=(
                {"negative_cache": True}
                if negative
                else {"artifact_checksum": look.entry.checksum[:16]}
            ),
        )
        self.engine.log.append(rec)
        self.stats.completed += 1
        self.stats.cache_hits += 1
        self.completions.append(
            Completion(
                id=req.id,
                outcome="completed",
                record=rec,
                result=result,
                arrival_s=req.arrival_s,
                finish_s=finish,
            )
        )

    def _complete_cache_leader(self, req: ServeRequest, rec, result, finish: float) -> None:
        """Fold a single-flight leader's terminal record into the cache
        and complete every attached follower with the SAME artifact —
        outcome ``coalesced``, stamped ``cache_hit``, byte-identical
        payload (one shared record template, one shared result object,
        one artifact checksum). N identical concurrent requests ==
        1 device execution + N-1 coalesced completions.

        Two guards before anything is stored or coalesced:

        * a record whose (mode, precision) differ from the admission
          form the artifact key was derived from must NOT be stored
          under that key (``_release_stale_lead`` catches the demotion
          and ladder paths at mutation time; this is the backstop for
          any path that changes the effective form later);
        * a retryable-class terminal failure (exhausted transient
          budget, service timeout) is one leader's bad luck, not a
          property of the content — followers re-enter the queue with
          their OWN retry budgets instead of being stamped failed, so
          one unlucky leader cannot amplify into N request failures.
          (A permanent fault DOES coalesce: the verdict is content-
          determined and would be negative-cached for all of them.)"""
        stale = req.base_key is not None and (rec.mode, rec.precision) != (
            req.base_key.mode,
            req.base_key.precision,
        )
        retryable_failure = (
            rec.status == "fail" and rec.fail_type in RETRYABLE_FAIL_TYPES
        )
        if stale or retryable_failure:
            self._release_lead(req)
            return
        ckey = req.cache_key
        checksum = self.cache.complete(
            ckey,
            now=finish,
            record=rec,
            result=result,
            shape=req.key.shape if req.key is not None else (0, 0, 0),
            replica=self.replica_id,
            request_id=req.id,
        )
        if checksum is not None:
            rec.extra = {**rec.extra, "artifact_checksum": checksum[:16]}
        for f in self._followers.pop(ckey, []):
            frec = dataclasses.replace(
                rec,
                request_id=f.id,
                arrival_s=f.arrival_s,
                queue_wait_s=max(0.0, finish - f.arrival_s),
                service_s=0.0,
                cache_hit=True,
                attempt=0,
            )
            self.engine.log.append(frec)
            self.stats.coalesced += 1
            self.completions.append(
                Completion(
                    id=f.id,
                    outcome="coalesced",
                    record=frec,
                    result=result,
                    arrival_s=f.arrival_s,
                    finish_s=finish,
                )
            )

    # ------------------------------------------------------------ dispatch

    def _seed_index(self, ready: list[int]) -> int:
        """Oldest ready request of the highest-priority class (FIFO within
        a class; ids break arrival ties deterministically). ``ready``
        indexes the queue entries not gated by retry backoff."""
        return min(
            ready,
            key=lambda i: (
                self.queue[i].priority_class.priority,
                self.queue[i].arrival_s,
                self.queue[i].id,
            ),
        )

    def _shed_expired(self, now: float) -> None:
        for req in [r for r in self.queue if r.deadline_s is not None and now > r.deadline_s]:
            self.queue.remove(req)
            self._reject(req, "deadline_expired", now)

    def _reject(self, req: ServeRequest, reason: str, now: float) -> None:
        self.stats.rejected[reason] = self.stats.rejected.get(reason, 0) + 1
        rec = self._log_shed(req.id, req.priority_class, req.arrival_s, reason, now=now)
        self.completions.append(
            Completion(
                id=req.id,
                outcome="rejected",
                record=rec,
                result=None,
                arrival_s=req.arrival_s,
                finish_s=now,
            )
        )
        # a shed single-flight leader must not strand its followers: the
        # pin is released and the followers re-enter the queue to serve
        # independently (they may themselves be shed on the next pass)
        self._release_lead(req)

    def _release_lead(self, req: ServeRequest) -> None:
        """Release a leader's single-flight pin without completing it:
        the pending placeholder is abandoned (bytes credited back) and
        every attached follower re-enters the queue as an independent
        compute-path request. Safe to call on non-leaders (no-op)."""
        if req.cache_key is None or self.cache is None:
            return
        ckey, req.cache_key = req.cache_key, None
        if self.cache.inflight_owner(ckey) == self.replica_id:
            self.cache.abandon(ckey)
        for f in self._followers.pop(ckey, []):
            f.cache_key = None
            self.queue.append(f)
        if self.queue:
            self.stats.max_queue_depth = max(
                self.stats.max_queue_depth, len(self.queue)
            )

    def _log_shed(self, rid, cls, arrival, reason, now=None):
        """Typed telemetry for a request shed before service."""
        now = arrival if now is None else now
        rec = TelemetryRecord(
            model=self.engine.cfg.name,
            mode="none",
            status="fail",
            times=StageTimes(),
            fail_type=reason,
            request_id=rid,
            arrival_s=arrival,
            queue_wait_s=max(0.0, now - arrival),
            priority_class=cls.name,
        )
        self.engine.log.append(rec)
        return rec

    def next_batch(self, now: Optional[float] = None) -> Optional[Batch]:
        """Form the next dispatch group at time ``now``: shed expired
        deadlines, pick the seed (priority order, FIFO within class),
        apply HBM admission (demote or reject an over-budget seed), then
        grow the group with same-class, same-signature requests while the
        summed working sets fit the admission budget."""
        now = self.clock.now() if now is None else now
        while True:
            self._shed_expired(now)
            ready = [
                i for i, r in enumerate(self.queue) if r.not_before_s <= now
            ]
            if not ready:
                # empty queue, or every queued request is in retry
                # backoff — next_ready_s() tells event loops when to wake
                return None
            seed = self.queue.pop(self._seed_index(ready))
            self._apply_breaker(seed, now)
            cap = self.cfg.admission_hbm_bytes
            if cap is not None and seed.key is not None and seed.bytes_priced > cap:
                form = self._demoted_form(seed)
                if form is None or form[1] > cap:
                    self._reject(seed, "admission_oom", now)
                    continue  # try the next seed
                self._apply_demotion(seed, *form)
            members = [seed]
            total = seed.bytes_priced
            # Batched dispatch prices the GROUP as one launch: every
            # member's bytes_priced carries one weight-pytree copy (see
            # _price), but a single batched launch streams the weights
            # once, so growth charges each joiner its marginal bytes
            # (bts - w_shared).  The seed's copy stays in ``total``.
            w_shared = self._group_weight_bytes(seed.key)
            if seed.key is not None:
                for req in [r for r in self.queue]:
                    if len(members) >= self.cfg.max_batch_requests:
                        break
                    if req.not_before_s > now:
                        continue  # still gated by retry backoff
                    # a candidate is judged at the form it would actually
                    # serve in: its breaker rung first (PEEKED, so no
                    # probe slot is claimed for a request we may not
                    # take), then — if over the cap — its DEMOTED form,
                    # so the requests an overload demotes still batch
                    # together instead of each paying a solo dispatch
                    key, bts, via_demotion = req.key, req.bytes_priced, False
                    if self.breaker is not None and req.base_key is not None:
                        key, bts = self._breaker_form(
                            req, self.breaker.peek_rung(req.base_key, now)
                        )
                    if cap is not None and key is not None and bts > cap:
                        form = self._demoted_form(req)
                        if form is None or form[1] > cap:
                            continue  # unservable; rejected when seeded
                        key, bts = form
                        via_demotion = True
                    if (
                        key == seed.key
                        and req.priority_class.name == seed.priority_class.name
                        and (cap is None or total + (bts - w_shared) <= cap)
                    ):
                        self.queue.remove(req)
                        self._apply_breaker(req, now)
                        if via_demotion:
                            self._apply_demotion(req, key, bts)
                        members.append(req)
                        total += bts - w_shared
            members.sort(key=lambda r: (r.arrival_s, r.id))
            self.stats.batches += 1
            self.stats.grouped_requests += len(members) - 1
            return Batch(requests=members, start_s=now)

    def _demoted_form(self, req: ServeRequest) -> Optional[tuple[GroupKey, int]]:
        """The request's shed-to-subvolume form — (failsafe GroupKey,
        re-priced bytes) — WITHOUT mutating the request (candidates are
        previewed for grouping and only demoted if actually admitted).
        None when demotion is off or the request already runs
        sub-volume."""
        if not self.cfg.allow_demotion or req.key is None or req.key.mode == "subvolume":
            return None
        from repro.core import executors

        eng = self.engine
        work_shape = (eng.cfg.cube + 2 * eng.cfg.overlap,) * 3
        key = GroupKey(
            mode="subvolume",
            executor=executors.resolve(
                req.executor or eng.cfg.executor,
                eng.cfg.model,
                work_shape,
                req.key.precision,
            ),
            devices=req.key.devices,
            precision=req.key.precision,
            shape=req.key.shape,
        )
        return key, self._price("subvolume", req.key.shape, req.key.precision)

    def _apply_demotion(self, req: ServeRequest, key: GroupKey, bts: int) -> None:
        req.key = key
        req.bytes_priced = bts
        req.demoted = True
        self._release_stale_lead(req)

    def _release_stale_lead(self, req: ServeRequest) -> None:
        """A leader's artifact key was derived at admission from its
        resolved (mode, precision) — the axes cache.artifact_key bakes in
        BECAUSE they change the artifact. Admission demotion and the
        breaker ladder mutate ``req.key`` after that derivation, so a
        demoted or ladder-degraded leader would produce a different
        artifact than the key it pinned promises: release the lead
        (pin abandoned, followers re-queued as independent requests)
        so the wrong-key store can never land. No-op while the
        effective (mode, precision) still match the derivation basis
        (``base_key`` — the signature the admission consult keyed on)."""
        if req.cache_key is None or req.key is None or req.base_key is None:
            return
        if (req.key.mode, req.key.precision) != (
            req.base_key.mode,
            req.base_key.precision,
        ):
            self._release_lead(req)

    def _breaker_form(
        self, req: ServeRequest, rung: int
    ) -> tuple[GroupKey, int]:
        """The (key, priced bytes) ``req`` serves at ``rung`` steps down
        the degradation ladder from its BASE signature, re-resolved
        through the executor registry and re-priced for admission. Rung
        0 is the base form (a restored breaker or a half-open probe);
        the walk caps at the ladder's bottom rung."""
        if rung <= 0:
            return req.base_key, req.base_bytes
        from repro.serving.resilience import demote_rung

        key = req.base_key
        for _ in range(rung):
            nxt = demote_rung(key, self.engine)
            if nxt is None:
                break  # already at the sub-volume failsafe
            key = nxt
        return key, self._price(key.mode, key.shape, key.precision)

    def _apply_breaker(self, req: ServeRequest, now: float) -> None:
        """Pin the request to its breaker-effective form on admission to
        a batch: claims the half-open probe slot when this request is
        the probe, walks the ladder otherwise. ``demoted`` tracks
        whether the EFFECTIVE mode is the sub-volume failsafe, so ladder
        restores un-demote and ladder bottoms count as demotions — same
        outcome vocabulary as admission demotion."""
        if self.breaker is None or req.base_key is None:
            return
        rung, probe = self.breaker.effective_rung(req.base_key, now)
        req.key, req.bytes_priced = self._breaker_form(req, rung)
        req.probe = probe
        req.demoted = (
            req.key.mode == "subvolume" and req.base_key.mode != "subvolume"
        )
        self._release_stale_lead(req)

    # ------------------------------------------------------------ service

    def run_batch(self, batch: Batch, now: Optional[float] = None) -> float:
        """Serve one dispatch group. Members run back-to-back (the
        engine's executors serve one forward at a time; grouping buys the
        shared compile/weights, not parallelism). Each member's telemetry
        is stamped with queue wait, service time, and the group size; a
        member that *raises* (garbage volume, executor bug) gets a typed
        failure record classified along the transient/permanent axis
        (serving/errors.py) while the rest of the group completes.
        Returns the batch finish time."""
        t, unserved = self.run_batch_until(batch, None, now=now)
        assert not unserved  # until=None serves every member
        return t

    def run_batch_until(
        self, batch: Batch, until: Optional[float], now: Optional[float] = None
    ) -> tuple[float, list]:
        """``run_batch`` with a service horizon: serve members in order
        while each would *finish* by ``until`` (virtual seconds), then
        stop. Returns ``(finish_time, unserved_tail)`` — the tail members
        were never executed, logged, or counted (exactly-once safety: the
        fleet layer re-dispatches them after a replica crash, and they
        must not have been served here first; the caller owns their
        ``stats.evacuated`` accounting). ``until=None`` serves everything
        (== ``run_batch``).

        A finite ``until`` requires the modeled path (a service model and
        ``execute=False``): truncation must *predict* each member's
        duration before running it, and only the analytic models can —
        measured execution would have to run the member to time it,
        defeating the exactly-once point."""
        if until is not None and (self.execute or self.service_model is None):
            raise ValueError(
                "run_batch_until with a finite horizon requires the "
                "modeled path (execute=False and a service model)"
            )
        start = batch.start_s if now is None else now
        t = start
        if self.service_model is not None:
            t += self.service_model.batch_overhead_s
        if (
            self.cfg.batched_dispatch
            and self.service_model is not None
            and not self.execute
            and len(batch.requests) > 1
            and batch.requests[0].key is not None
        ):
            return self._run_batched_launch(batch, until, t)
        for idx, req in enumerate(batch.requests):
            if until is not None:
                # preview the member's modeled duration WITHOUT serving
                # it — _attempt_record/_attempt_service are pure, so the
                # preview matches the serve exactly, injected faults,
                # straggler factors and timeouts included
                preview, p_decision = self._attempt_record(req, t)
                p_service, _ = self._attempt_service(preview, p_decision, req)
                if t + p_service > until:
                    return t, list(batch.requests[idx:])
            result, rec, decision = self._serve_one(req, t)
            if self.service_model is not None:
                service, timed_out = self._attempt_service(rec, decision, req)
                if timed_out:
                    # the attempt is cancelled AT the bound: the member
                    # occupied the replica for exactly the timeout, and
                    # the fault is retryable (a retry lands on a fresh
                    # attempt — the CHIPS stuck-job discipline)
                    rec.status = "fail"
                    rec.fail_type = SERVICE_TIMEOUT
            else:
                service = max(0.0, self.clock.now() - t)
            finish = t + service
            rec.request_id = req.id
            rec.arrival_s = req.arrival_s
            # wait = until THIS member's forward starts (batch overhead
            # and predecessors' serialized service included), so
            # queue_wait_s + service_s == finish - arrival exactly — the
            # identity the SLO rollups in telemetry/analysis.py rely on.
            # Retried attempts keep the ORIGINAL arrival, so the identity
            # spans every attempt of a request, not just the first.
            rec.queue_wait_s = max(0.0, t - req.arrival_s)
            rec.service_s = service
            rec.batch_size = len(batch.requests)
            rec.priority_class = req.priority_class.name
            rec.demoted = req.demoted
            rec.attempt = req.attempt
            self._finish_attempt(req, rec, result, finish)
            t = finish
        return t, []

    def _run_batched_launch(
        self, batch: Batch, until: Optional[float], t: float
    ) -> tuple[float, list]:
        """Serve a dispatch group as ONE batched kernel launch (modeled
        path, ``batched_dispatch`` only). The launch's service interval
        comes from a single batch-N modeled record — the byte models
        amortize the weight stream across the batch, so the launch is
        strictly cheaper than N serialized dispatches whenever the
        weight term is nonzero. Every member shares that interval:
        ``queue_wait_s = t - arrival`` and ``service_s = launch_service``
        so ``queue_wait_s + service_s == finish - arrival`` holds exactly
        per member, the identity the SLO rollups rely on.

        Fault injection stays per member (a transient flip fails one
        member's record, not the group), but a straggler or stuck member
        slows the WHOLE launch — one kernel finishes when its slowest
        device does. The class service timeout (uniform across the
        group: membership requires equal priority class) clips the
        launch, failing the still-ok members with ``service_timeout``.
        Horizon truncation is all-or-nothing: a single kernel either
        fits before ``until`` or none of it runs, so the unserved tail
        is the entire group."""
        reqs = batch.requests
        n = len(reqs)
        attempts = [self._attempt_record(req, t) for req in reqs]
        launch = self._modeled_record(reqs[0], batch=n)
        service = self.service_model.service_s(launch)
        factor, stuck = 1.0, False
        for rec, decision in attempts:
            if decision is not None and rec.status == "ok":
                if decision.kind == "straggler":
                    factor = max(factor, decision.slow_factor)
                elif decision.kind == "stuck":
                    stuck = True
        service = math.inf if stuck else service * factor
        timeout = (
            None
            if self.resilience is None
            else self.resilience.timeout_for(reqs[0].priority_class.name)
        )
        timed_out = False
        if timeout is not None and service > timeout:
            service, timed_out = timeout, True
        if math.isinf(service):
            raise ResilienceConfigError(
                f"stuck fault on class {reqs[0].priority_class.name!r} "
                "with no service timeout configured"
            )
        finish = t + service
        if until is not None and finish > until:
            return t, list(reqs)
        for req, (rec, decision) in zip(reqs, attempts):
            self.engine.log.append(rec)
            if timed_out and rec.status == "ok":
                rec.status, rec.fail_type = "fail", SERVICE_TIMEOUT
            rec.request_id = req.id
            rec.arrival_s = req.arrival_s
            rec.queue_wait_s = max(0.0, t - req.arrival_s)
            rec.service_s = service
            rec.batch_size = n
            rec.priority_class = req.priority_class.name
            rec.demoted = req.demoted
            rec.attempt = req.attempt
            self._finish_attempt(req, rec, None, finish)
        return finish, []

    def _finish_attempt(self, req, rec, result, finish: float) -> None:
        """Fold one finished service attempt into breaker, retry, and
        conservation state. A retryable fault with budget remaining is
        NON-terminal: the request re-enters its signature lane (original
        arrival stamp, backoff-gated) and no Completion is appended —
        the conservation sum counts requests, not attempts. Everything
        else is terminal exactly as before."""
        is_fault = (
            rec.status == "fail" and rec.fail_type in EXECUTION_FAULT_TYPES
        )
        if is_fault:
            if rec.fail_type == TRANSIENT_FAULT:
                self.stats.transient_faults += 1
            elif rec.fail_type == PERMANENT_FAULT:
                self.stats.permanent_faults += 1
            else:
                self.stats.timeouts += 1
        if self.breaker is not None and req.base_key is not None:
            self.breaker.on_result(
                req.base_key, fault=is_fault, probe=req.probe, now=finish
            )
        retryable = (
            rec.status == "fail" and rec.fail_type in RETRYABLE_FAIL_TYPES
        )
        if retryable:
            req.faults += 1
        if (
            retryable
            and self.resilience is not None
            and req.attempt + 1 < self.resilience.retry.max_attempts
        ):
            req.attempt += 1
            req.probe = False
            req.not_before_s = finish + self.resilience.retry.backoff_s(
                req.attempt, self.replica_id, req.id
            )
            self.stats.retries += 1
            self.queue.append(req)
            self.stats.max_queue_depth = max(
                self.stats.max_queue_depth, len(self.queue)
            )
            return
        outcome = "demoted" if req.demoted else "completed"
        if req.demoted:
            self.stats.demoted += 1
        else:
            self.stats.completed += 1
        if req.faults:
            self.stats.faulted_requests += 1
            if rec.status == "ok":
                self.stats.recovered_requests += 1
        self.completions.append(
            Completion(
                id=req.id,
                outcome=outcome,
                record=rec,
                result=result,
                arrival_s=req.arrival_s,
                finish_s=finish,
            )
        )
        if req.cache_key is not None and self.cache is not None:
            # single-flight leader reached a terminal state: store (or
            # negative-cache) the artifact and coalesce its followers
            self._complete_cache_leader(req, rec, result, finish)

    def _fault_decision(self, req: ServeRequest, t: float):
        """The seeded injector's verdict for this attempt — pure in
        (plan seed, time, replica, effective signature, request id,
        attempt). Keyed on the EFFECTIVE key: a breaker-demoted
        signature escapes rules that match only its faulty rung, which
        is what lets the ladder route around a poisoned executor."""
        if self.fault_plan is None or req.key is None:
            return None
        return self.fault_plan.decide(
            t=t,
            replica=self.replica_id,
            key=req.key,
            request_id=req.id,
            attempt=req.attempt,
            priority=req.priority_class.name,
        )

    def _attempt_record(self, req: ServeRequest, t: float):
        """(modeled record, fault decision) for one attempt at ``t`` —
        no logging, no state: the truncation preview and the actual
        serve call this with identical arguments and must agree."""
        rec = self._modeled_record(req)
        decision = self._fault_decision(req, t)
        if decision is not None and rec.status == "ok":
            if decision.kind == "transient":
                rec.status, rec.fail_type = "fail", TRANSIENT_FAULT
            elif decision.kind == "permanent":
                rec.status, rec.fail_type = "fail", PERMANENT_FAULT
            if rec.status == "fail":
                rec.extra = {
                    "injected": decision.kind,
                    "rule": decision.rule_index,
                }
        return rec, decision

    def _attempt_service(self, rec, decision, req: ServeRequest):
        """(service_s, timed_out) for one modeled attempt: the service
        model's duration, inflated by an injected straggler factor,
        infinite for a stuck fault, then clipped at the class's service
        timeout. The clip IS the cancellation — the attempt holds the
        replica for exactly the bound. A stuck fault with no timeout is
        unservable and raises typed (also rejected at construction)."""
        service = self.service_model.service_s(rec)
        if decision is not None and rec.status == "ok":
            if decision.kind == "straggler":
                service *= decision.slow_factor
            elif decision.kind == "stuck":
                service = math.inf
        timeout = (
            None
            if self.resilience is None
            else self.resilience.timeout_for(req.priority_class.name)
        )
        if timeout is not None and service > timeout:
            return timeout, True
        if math.isinf(service):
            raise ResilienceConfigError(
                f"stuck fault on class {req.priority_class.name!r} with "
                "no service timeout configured"
            )
        return service, False

    def evacuate(self, now: Optional[float] = None) -> list:
        """Hand every queued request back to the caller (fleet failover /
        drain re-dispatch): the queue empties, each popped request counts
        as ``evacuated`` in the conservation ledger — admitted here,
        served elsewhere. Returns the requests in (arrival, id) order so
        re-dispatch preserves FIFO fairness at the target replica.

        Single-flight state is torn down with the queue: every follower
        is popped into the evacuation set (it re-dispatches as an
        independent request), and every in-flight cache pin this replica
        owns is abandoned — including pins of unserved batch-tail
        leaders the fleet evacuates separately — so a crashed replica
        can never leave a pinned placeholder that blocks eviction
        forever."""
        out = list(self.queue)
        self.queue.clear()
        if self.cache is not None:
            for lst in self._followers.values():
                for f in lst:
                    f.cache_key = None
                    out.append(f)
            self._followers.clear()
            for req in out:
                if req.cache_key is not None:
                    self.cache.abandon(req.cache_key)
                    req.cache_key = None
            for ckey, owner in list(self.cache.inflight.items()):
                if owner == self.replica_id:
                    self.cache.abandon(ckey)
        out.sort(key=lambda r: (r.arrival_s, r.id))
        self.stats.evacuated += len(out)
        return out

    def cancel(self, rid: int):
        """Remove ONE queued request before service — the fleet's
        hedge-loser cancellation (serving/fleet.py): its twin completed
        elsewhere, so this copy must never serve. Counted ``evacuated``
        in the conservation ledger (admitted here, resolved elsewhere —
        the same terminal state crash evacuation uses). Returns the
        request, or None when it is not queued (already served, shed,
        or never here) — in which case nothing changes. A cancelled
        single-flight leader releases its pin and re-queues its
        followers; a cancelled follower is plucked from its leader's
        list without disturbing the leader."""
        for req in self.queue:
            if req.id == rid:
                self.queue.remove(req)
                self.stats.evacuated += 1
                self._release_lead(req)
                return req
        for ckey in list(self._followers):
            for f in self._followers[ckey]:
                if f.id == rid:
                    self._followers[ckey].remove(f)
                    if not self._followers[ckey]:
                        del self._followers[ckey]
                    f.cache_key = None
                    self.stats.evacuated += 1
                    return f
        return None

    def next_ready_s(self, now: float) -> Optional[float]:
        """When every queued request is gated by retry backoff, the
        earliest ``not_before_s`` — the wake time event loops must
        advance to (the virtual clock cannot busy-wait). None when the
        queue is empty or some request is ready now."""
        if not self.queue:
            return None
        earliest = min(r.not_before_s for r in self.queue)
        return earliest if earliest > now else None

    def peek_signature(
        self,
        vol,
        *,
        mode: Optional[str] = None,
        executor: Optional[str] = None,
        devices: Optional[int] = None,
        precision: Optional[str] = None,
    ) -> tuple[Optional[GroupKey], int]:
        """Resolve the admission signature + priced bytes a request WOULD
        get, without enqueueing it — the fleet router's affinity key
        (serving/fleet.py steers same-signature requests to replicas with
        warm compiled executables). Shares the scheduler's resolution
        cache, so peeking then submitting costs one resolution."""
        probe = ServeRequest(
            id=-1,
            vol=vol,
            priority_class=PriorityClass("peek", 0),
            arrival_s=0.0,
            deadline_s=None,
            mode=mode,
            executor=executor,
            devices=devices,
            precision=precision,
        )
        return self._resolve(probe)

    def _serve_one(self, req: ServeRequest, t: float):
        """(PipelineResult | None, TelemetryRecord, FaultDecision | None)
        for one service attempt — real execution with typed-failure
        capture, or the modeled record of the pure discrete-event mode.
        Either way, raised exceptions are CLASSIFIED along the
        transient/permanent axis (serving/errors.py) instead of stamped
        with PR 5's blanket ``executor_error``, and the seeded fault
        plan can inject faults on this attempt."""
        key = req.key
        if not self.execute:
            rec, decision = self._attempt_record(req, t)
            self.engine.log.append(rec)
            return None, rec, decision
        decision = self._fault_decision(req, t)
        try:
            if decision is not None and decision.kind in ("transient", "permanent"):
                err = (
                    TransientExecutorError
                    if decision.kind == "transient"
                    else PermanentExecutorError
                )
                raise err(
                    f"injected {decision.kind} fault "
                    f"(rule {decision.rule_index})"
                )
            result = self.engine._run_request(
                req.vol,
                mode=key.mode if key else req.mode,
                executor=key.executor if key else req.executor,
                devices=key.devices if key else req.devices,
                precision=key.precision if key else req.precision,
                # native-shape mode serves the request at its own
                # geometry (the shape admission priced); legacy mode
                # leaves the engine to conform to its card's shape.
                volume_shape=key.shape
                if key and self.cfg.native_shapes
                else None,
            )
            return result, result.record, decision
        except Exception as e:  # fault isolation: one bad request != batch
            rec = TelemetryRecord(
                model=self.engine.cfg.name,
                mode=key.mode if key else "none",
                status="fail",
                times=StageTimes(),
                executor=key.executor if key else None,
                precision=key.precision if key else None,
                fail_type=classify(e),
                extra={"error": f"{type(e).__name__}: {e}"},
            )
            self.engine.log.append(rec)
            return None, rec, decision

    def _modeled_record(self, req: ServeRequest, batch: int = 1) -> TelemetryRecord:
        """Synthesized telemetry for ``execute=False`` runs: status and
        modeled bytes come from the same pre-flight models the pipeline
        uses, with zero wall-clock compute — the large-sweep mode of the
        load simulator.  ``batch > 1`` models the request as an N-volume
        batched launch: the byte models amortize the weight stream across
        the batch, which is what makes a single batched dispatch cheaper
        than N serialized ones."""
        from repro.core import executors
        from repro.kernels import quantize

        key = req.key
        if key is None:
            return TelemetryRecord(
                model=self.engine.cfg.name,
                mode="none",
                status="fail",
                times=StageTimes(),
                fail_type=PERMANENT_FAULT,
                extra={"error": "garbage volume (modeled)"},
            )
        cfg = self.engine.cfg
        rec = TelemetryRecord(
            model=cfg.name,
            mode=key.mode,
            status="ok",
            times=StageTimes(),
            executor=key.executor,
            precision=key.precision,
            params_bytes=quantize.model_params_bytes(cfg.model, key.precision),
        )
        try:
            if key.devices is not None and key.devices > 1:
                import jax

                if key.devices > jax.device_count():
                    from repro.core.spatial_shard import ShardGeometryError

                    raise ShardGeometryError(
                        f"sharded executor wants {key.devices} devices; "
                        f"host has {jax.device_count()}"
                    )
            if key.mode == "subvolume":
                ncubes = math.prod(-(-s // cfg.cube) for s in key.shape)
                cube_shape = (cfg.cube + 2 * cfg.overlap,) * 3
                per = executors.modeled_hbm_bytes(
                    key.executor,
                    cfg.model,
                    cube_shape,
                    batch=batch,
                    precision=key.precision,
                )
                rec.hbm_bytes_modeled = None if per is None else ncubes * per
                rec.collective_bytes_modeled = (
                    ncubes
                    * executors.modeled_collective_bytes(
                        key.executor,
                        cfg.model,
                        cube_shape,
                        batch=batch,
                        precision=key.precision,
                    )
                )
            else:
                rec.hbm_bytes_modeled = executors.modeled_hbm_bytes(
                    key.executor,
                    cfg.model,
                    key.shape,
                    batch=batch,
                    precision=key.precision,
                )
                rec.collective_bytes_modeled = executors.modeled_collective_bytes(
                    key.executor,
                    cfg.model,
                    key.shape,
                    batch=batch,
                    precision=key.precision,
                )
        except ValueError as e:
            from repro.core.spatial_shard import ShardGeometryError

            rec.status = "fail"
            rec.fail_type = (
                "shard_geometry" if isinstance(e, ShardGeometryError) else "vmem_oom"
            )
        return rec

    # ------------------------------------------------------------ draining

    def has_work(self) -> bool:
        return bool(self.queue)

    def drain(self) -> list[Completion]:
        """Serve until the queue is empty; returns the completions NEW
        since the previous drain (terminal states of every request
        admitted since then), id-ordered — so a submit/drain service
        loop never re-delivers a result. ``self.completions`` keeps the
        full ledger for the simulator and post-hoc analysis."""
        while True:
            batch = self.next_batch()
            if batch is None:
                if not self.queue:
                    break
                # every queued request is in retry backoff: pass the
                # time — a virtual clock jumps, the production clock
                # sleeps (drain is the synchronous service loop; the
                # simulator's event loops advance instead of blocking)
                wake = self.next_ready_s(self.clock.now())
                if wake is None:
                    continue  # raced: something became ready
                if hasattr(self.clock, "advance_to"):
                    self.clock.advance_to(wake)
                else:
                    time.sleep(max(0.0, wake - self.clock.now()))
                continue
            self.run_batch(batch)
        assert self.stats.conserved(), (
            f"conservation violated: {self.stats}"
        )
        fresh = self.completions[self._drained:]
        self._drained = len(self.completions)
        return sorted(fresh, key=lambda c: c.id)
