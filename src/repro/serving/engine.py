"""Batched serving engine — the deployment-side counterpart of Brainchop's
"serve a pre-trained model to whoever shows up" story, generalised to the
architecture zoo.

Two engines:

SegmentationEngine — batches incoming MRI volumes and runs the Brainchop
pipeline (conform -> crop -> MeshNet -> components), with the memory-budget
guard choosing full-volume vs failsafe sub-volume mode per request —
exactly the tool's client-side adaptation logic, server-side. Inference
dispatches through the executor registry (core/executors.py): the engine's
PipelineConfig carries a default backend ("auto" -> the sharded
depth-first megakernel on multi-device TPU when the per-slab tile plan
fits VMEM, the megakernel on one TPU device, else fused Pallas; XLA on
CPU), and both ``submit`` and the batched ``submit_many`` accept
per-request mode/executor/device-count overrides (the Z-slab count of the
sharded family, core/spatial_shard.py; the engine builds its mesh once at
construction); the chosen triple — plus the modeled HBM and collective
halo bytes the backend's schedule moves (telemetry/traffic.py) — is
recorded in each request's telemetry record. Requests sharing a (mode,
executor, devices, shape) reuse one compiled executable via the
registry's jit cache. The queued path — ``submit_async``/``drain``, and
``submit_many``'s dispatch — goes through the continuous-batching request
scheduler (serving/scheduler.py): bounded queue with typed
``QueueFullError`` backpressure, priority/deadline classes, HBM-priced
admission with shed-to-subvolume demotion, and dynamic grouping of
signature-compatible requests. One engine == one fleet replica: the
replicated serving tier (serving/fleet.py) builds N engines — each with
its own jit caches and prepared weight pytrees — and routes across them
by dispatch-signature cache affinity.

LMEngine — continuous-batching text generation for any ModelConfig:
chunked prefill (sequence patching, DESIGN.md §4), ring-buffer KV caches
for sliding-window configs, greedy/temperature sampling, per-slot EOS
retirement and slot reuse.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MD
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    id: int = 0


@dataclasses.dataclass
class Completion:
    id: int
    tokens: list[int]
    prefill_s: float
    decode_s: float


class LMEngine:
    """Static-slot continuous batching engine.

    ``slots`` concurrent sequences share one cache; finished slots are
    refilled from the queue. Prefill runs per-request in chunks of
    ``prefill_chunk`` (compiled once per chunk shape); decode advances all
    live slots in lock-step with a single compiled serve_step.
    """

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        slots: int = 4,
        max_seq: int = 512,
        prefill_chunk: int = 64,
        eos_id: int | None = None,
        rng: jax.Array | None = None,
    ):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.eos_id = eos_id
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.cache = MD.init_cache(cfg, slots, max_seq)
        self.pos = np.zeros((slots,), np.int32)  # per-slot next position
        self.live = np.zeros((slots,), bool)

        cfg_ = cfg

        @jax.jit
        def _decode(params, token, cache, pos):
            logits, cache = MD.decode_step(params, token, cache, pos, cfg_)
            return logits[:, -1], cache

        self._decode = _decode

    # --- prefill ------------------------------------------------------------

    def _prefill_one(self, slot: int, prompt: list[int]) -> None:
        """Feed a prompt token-by-token through decode_step (correct for
        every family incl. recurrent states). Chunk-level batching of the
        token loop is jit'd via lax.scan for throughput."""
        cfg = self.cfg

        @jax.jit
        def run_chunk(params, tokens, cache, start):
            def step(carry, tok):
                cache, pos = carry
                _, cache = MD.decode_step(params, tok[None, None], cache, pos, cfg)
                return (cache, pos + 1), None

            (cache, pos), _ = jax.lax.scan(step, (cache, start), tokens)
            return cache, pos

        # The engine cache is batched over slots; run the scan on a
        # single-slot view then write it back.
        one = jax.tree.map(lambda c: c[:, slot : slot + 1], self.cache)
        pos = jnp.asarray(self.pos[slot], jnp.int32)
        chunk = self.prefill_chunk
        toks = np.asarray(prompt, np.int32)
        for i in range(0, len(toks), chunk):
            part = toks[i : i + chunk]
            if len(part) < chunk:
                pad = np.zeros((chunk - len(part),), np.int32)
                padded = np.concatenate([part, pad])
                # run the valid prefix only, step-by-step for the tail
                for t in part:
                    _, one = self._decode_single(one, int(t), int(pos))
                    pos = pos + 1
            else:
                one, pos = run_chunk(self.params, jnp.asarray(part), one, pos)
        self.cache = jax.tree.map(
            lambda full, o: jax.lax.dynamic_update_slice_in_dim(full, o, slot, axis=1)
            if full.ndim > 1
            else full,
            self.cache,
            one,
        )
        self.pos[slot] = int(pos)

    def _decode_single(self, one_cache, token: int, pos: int):
        logits, cache = self._decode(
            self.params, jnp.asarray([[token]], jnp.int32), one_cache, jnp.asarray(pos, jnp.int32)
        )
        return logits, cache

    # --- main loop ------------------------------------------------------------

    def run(self, requests: list[Request]) -> list[Completion]:
        queue = list(requests)
        active: dict[int, dict] = {}
        done: list[Completion] = []

        def admit():
            for s in range(self.slots):
                if not self.live[s] and queue:
                    req = queue.pop(0)
                    t0 = time.perf_counter()
                    self.pos[s] = 0
                    self._reset_slot(s)
                    self._prefill_one(s, req.prompt[:-1])
                    active[s] = {
                        "req": req,
                        "out": [],
                        "next": req.prompt[-1],
                        "prefill_s": time.perf_counter() - t0,
                        "t0": time.perf_counter(),
                    }
                    self.live[s] = True

        admit()
        while active:
            tokens = np.zeros((self.slots, 1), np.int32)
            for s, st in active.items():
                tokens[s, 0] = st["next"]
            # lock-step decode: one compiled step for all slots. Each slot
            # has its own position; decode_step takes a scalar pos, so we
            # use the max and rely on per-slot ring indexing... positions
            # differ across slots, so instead advance slots individually
            # when their positions diverge, batched when aligned.
            groups: dict[int, list[int]] = {}
            for s in active:
                groups.setdefault(int(self.pos[s]), []).append(s)
            for pos, slot_ids in groups.items():
                logits, new_cache = self._decode(
                    self.params, jnp.asarray(tokens), self.cache, jnp.asarray(pos, jnp.int32)
                )
                # merge only the stepped slots' cache lanes back
                mask = np.zeros((self.slots,), bool)
                mask[slot_ids] = True
                m = jnp.asarray(mask)

                def merge(new, old):
                    bdim = 1 if new.ndim > 1 else 0
                    shape = [1] * new.ndim
                    shape[bdim] = self.slots
                    return jnp.where(m.reshape(shape), new, old) if new.shape[bdim] == self.slots else new

                self.cache = jax.tree.map(merge, new_cache, self.cache)
                lg = np.asarray(logits)
                for s in slot_ids:
                    st = active[s]
                    if st["req"].temperature > 0:
                        self.rng, k = jax.random.split(self.rng)
                        nxt = int(
                            jax.random.categorical(k, jnp.asarray(lg[s]) / st["req"].temperature)
                        )
                    else:
                        nxt = int(np.argmax(lg[s]))
                    st["out"].append(nxt)
                    st["next"] = nxt
                    self.pos[s] += 1
                    if (
                        len(st["out"]) >= st["req"].max_new_tokens
                        or (self.eos_id is not None and nxt == self.eos_id)
                        or self.pos[s] >= self.max_seq - 1
                    ):
                        done.append(
                            Completion(
                                id=st["req"].id,
                                tokens=st["out"],
                                prefill_s=st["prefill_s"],
                                decode_s=time.perf_counter() - st["t0"],
                            )
                        )
                        self.live[s] = False
                        del active[s]
            admit()
        return sorted(done, key=lambda c: c.id)

    def _reset_slot(self, s: int) -> None:
        fresh = MD.init_cache(self.cfg, 1, self.max_seq)
        self.cache = jax.tree.map(
            lambda full, fr: jax.lax.dynamic_update_slice_in_dim(full, fr, s, axis=1)
            if full.ndim > 1
            else full,
            self.cache,
            fresh,
        )


# ---------------------------------------------------------------- MRI side ---


class SegmentationEngine:
    """Server-side Brainchop: picks full-volume vs sub-volume ("failsafe")
    mode per request from the memory budget, then runs the pipeline through
    the chosen executor backend (core/executors.py).

    ``devices`` sets the engine's default Z-slab count for the sharded
    executor family (core/spatial_shard.py) — the mesh is built once at
    engine construction and shared by every request (the registry's mesh
    cache keys on the slab count, so per-request overrides that repeat a
    count also reuse one mesh and one compiled executable).

    ``precision`` sets the engine's default storage policy
    (kernels/quantize.py: "fp32" | "bf16" | "int8w" | "auto"); weights
    are quantized/cast ONCE per policy the first time a request uses it
    and the prepared pytree is cached, so int8w requests stream the same
    4x-smaller weights instead of re-quantizing per request
    (quantize.prepare_params is idempotent — executors accept either
    form)."""

    def __init__(
        self, params, pipeline_cfg, *, mask_model=None, budget=None, devices=None,
        precision=None,
    ):
        from repro.telemetry.budget import MemoryBudget

        self.params = params
        self.cfg = pipeline_cfg
        self.mask_model = mask_model
        self.budget = budget or MemoryBudget.v5e()
        self.devices = devices or getattr(pipeline_cfg, "shard_devices", None)
        self.precision = precision or getattr(pipeline_cfg, "precision", "auto")
        self._prepared: dict[str, Any] = {}
        if self.devices and self.devices > 1:
            # Build (and cache) the engine's Z mesh once, up front — not
            # lazily inside the first request's trace.
            from repro.core import spatial_shard

            spatial_shard.mesh_for(self.devices)
        from repro.telemetry.record import TelemetryLog

        self.log = TelemetryLog()
        self._scheduler = None  # lazy RequestScheduler (serving/scheduler.py)

    def _params_for(self, precision: str):
        """The weight pytree in ``precision`` storage, prepared once per
        policy and cached for every later request (the streamed-weight
        footprint is what TelemetryRecord.params_bytes tracks)."""
        from repro.kernels import quantize

        resolved = quantize.resolve_precision(precision, self.cfg.model)
        if resolved not in self._prepared:
            self._prepared[resolved] = quantize.prepare_params(
                self.params, self.cfg.model, resolved
            )
        return self._prepared[resolved]

    def pick_mode(self, volume_shape, precision: str | None = None) -> str:
        """Budget-driven failsafe selection, priced at the request's
        storage policy: a bf16/int8w request carries half the activation
        bytes, so a budget that demotes fp32 to the sub-volume failsafe
        can still serve it streaming (mirrors pipeline.run's charges)."""
        from repro.kernels import quantize
        from repro.telemetry.budget import BudgetExceeded

        resolved = quantize.resolve_precision(
            precision or self.precision, self.cfg.model
        )
        try:
            self.budget.charge_streaming(
                volume_shape, self.cfg.model,
                dtype_bytes=quantize.act_bytes(resolved),
            )
            return "streaming"
        except BudgetExceeded:
            return "subvolume"

    def submit(
        self,
        vol: jax.Array,
        *,
        mode: str | None = None,
        executor: str | None = None,
        devices: int | None = None,
        precision: str | None = None,
    ):
        """Run one volume synchronously. ``mode``/``executor``/``devices``
        /``precision`` override the engine's defaults for this request
        only; ``mode=None`` keeps the budget-driven failsafe selection,
        ``executor=None`` keeps the engine config's backend (``"auto"``
        resolves per host in the pipeline), ``devices=None`` keeps the
        engine's slab count (``devices=1`` forces single-device for this
        request), and ``precision=None`` keeps the engine's storage
        policy ("auto" resolves per device+model in the pipeline)."""
        return self._run_request(
            vol, mode=mode, executor=executor, devices=devices, precision=precision
        )

    def _run_request(
        self,
        vol: jax.Array,
        *,
        mode: str | None = None,
        executor: str | None = None,
        devices: int | None = None,
        precision: str | None = None,
        volume_shape: tuple | None = None,
    ):
        """The raw serve path behind ``submit`` and the scheduler: resolve
        defaults, run the pipeline, log telemetry. (The scheduler calls
        this per batch member so its typed fault isolation wraps exactly
        one request's execution.) ``volume_shape`` overrides the engine's
        conform target for this request — the scheduler's native-shape
        mode serves each request at its own geometry; ``None`` keeps the
        engine card's shape (every input is conformed to it)."""
        import dataclasses as dc

        from repro.core import pipeline as pl

        prec = precision or self.precision
        shape = tuple(volume_shape) if volume_shape else self.cfg.volume_shape
        mode = mode or self.pick_mode(shape, prec)
        cfg = dc.replace(
            self.cfg,
            volume_shape=shape,
            mode=mode,
            budget=self.budget,
            executor=executor or self.cfg.executor,
            shard_devices=devices if devices is not None else self.devices,
            precision=prec,
        )
        res = pl.run(cfg, self._params_for(prec), vol, mask_model=self.mask_model)
        self.log.append(res.record)
        return res

    # ---- queued serving (serving/scheduler.py) --------------------------

    def scheduler(self, scheduler_cfg=None, **kwargs):
        """The engine's request scheduler, created lazily (pass
        ``scheduler_cfg``/kwargs on FIRST use to configure it; see
        ``RequestScheduler``). ``submit_async``/``drain`` go through it.
        Raises if a configuration is passed after the scheduler already
        exists — silently returning the old instance would leave the
        caller believing their admission limits are active."""
        from repro.serving.scheduler import RequestScheduler

        if getattr(self, "_scheduler", None) is None:
            self._scheduler = RequestScheduler(self, scheduler_cfg, **kwargs)
        elif scheduler_cfg is not None or kwargs:
            raise ValueError(
                "engine.scheduler() was already created (a prior "
                "submit_async/scheduler call); configuration must be "
                "passed on first use"
            )
        return self._scheduler

    def submit_async(
        self,
        vol: jax.Array,
        *,
        priority: str = "standard",
        mode: str | None = None,
        executor: str | None = None,
        devices: int | None = None,
        precision: str | None = None,
    ) -> int:
        """Enqueue one request with the continuous-batching scheduler and
        return its request id — nothing executes until ``drain`` (or an
        explicit ``scheduler().next_batch``/``run_batch`` loop). Raises
        ``QueueFullError`` when the admission queue is at depth."""
        return self.scheduler().submit(
            vol,
            priority=priority,
            mode=mode,
            executor=executor,
            devices=devices,
            precision=precision,
        )

    def drain(self):
        """Serve every queued request (dynamic grouping, HBM-budget
        admission, priority order) and return the id-ordered
        ``Completion`` list — each with its outcome (completed | demoted
        | rejected), stamped telemetry record, and pipeline result."""
        return self.scheduler().drain()

    def submit_many(
        self,
        vols: list[jax.Array],
        *,
        modes: list[str | None] | None = None,
        executors: list[str | None] | None = None,
        devices: list[int | None] | None = None,
        precisions: list[str | None] | None = None,
    ) -> list:
        """Batched multi-volume submission with per-request mode/executor/
        device-count/precision selection.

        Results come back in submission order; a ``None`` entry in
        ``modes`` keeps the budget-driven failsafe selection, a ``None``
        entry in ``executors`` keeps the engine config's backend, a
        ``None`` entry in ``devices`` keeps the engine's slab count, and
        a ``None`` entry in ``precisions`` keeps the engine's storage
        policy.

        Dispatch goes through the request scheduler's grouping
        (serving/scheduler.py): requests sharing a resolved (mode,
        executor, devices, precision, shape) signature are served
        back-to-back as one group — the signature is resolved and priced
        ONCE per unique combination (not once per request), and the
        group shares one compiled executable via the registry's
        ``jitted_apply`` cache, one mesh via the slab-count mesh cache,
        and one prepared weight pytree per policy via the engine's
        cache. A request that *raises* (garbage volume, executor bug)
        yields a failed result typed by the fault taxonomy
        (serving/errors.py — ``transient_fault`` for declared-retryable
        executor errors, ``permanent_fault`` otherwise) while the rest
        of its group completes. Each telemetry record carries
        the mode/executor/precision that served it, the scheduler's
        queue/batch stamps, and the request's submission index in
        ``extra``.
        """
        from repro.core.pipeline import PipelineResult
        from repro.serving.scheduler import RequestScheduler, SchedulerConfig

        n = len(vols)
        if modes is not None and len(modes) != n:
            raise ValueError(f"modes must match len(vols): {len(modes)} != {n}")
        if executors is not None and len(executors) != n:
            raise ValueError(f"executors must match len(vols): {len(executors)} != {n}")
        if devices is not None and len(devices) != n:
            raise ValueError(f"devices must match len(vols): {len(devices)} != {n}")
        if precisions is not None and len(precisions) != n:
            raise ValueError(
                f"precisions must match len(vols): {len(precisions)} != {n}"
            )
        modes = modes if modes is not None else [None] * n
        execs = executors if executors is not None else [None] * n
        devs = devices if devices is not None else [None] * n
        precs = precisions if precisions is not None else [None] * n

        # Legacy semantics preserved: unbounded queue, no batch-level
        # admission budget (mode selection stays per-request via
        # pick_mode), and deadline-FREE classes (the default ladder's
        # wall-clock deadlines would shed the tail of a slow synchronous
        # batch — the old for-loop ran every request, so must this) —
        # the scheduler contributes grouping, resolution dedupe, and
        # fault isolation.
        from repro.serving.scheduler import DEFAULT_CLASSES, PriorityClass

        sched = RequestScheduler(
            self,
            SchedulerConfig(
                max_queue_depth=None,
                admission_hbm_bytes=None,
                max_batch_requests=max(n, 1),
                allow_demotion=False,
                classes={
                    name: PriorityClass(name, c.priority, deadline_s=None)
                    for name, c in DEFAULT_CLASSES.items()
                },
            ),
        )
        for i, vol in enumerate(vols):
            sched.submit(
                vol, mode=modes[i], executor=execs[i], devices=devs[i],
                precision=precs[i],
            )
        completions = sched.drain()
        results = []
        for i, comp in enumerate(completions):
            res = comp.result
            if res is None:  # typed failure synthesized by the scheduler
                res = PipelineResult(segmentation=None, record=comp.record)
            res.record.extra["request_index"] = i
            results.append(res)
        return results
