"""Resilience layer: retry/backoff, service timeouts, hedged re-dispatch,
an executor degradation ladder behind circuit breakers, and the seeded
fault-injection plans that make every failure scenario golden-testable.

Brainchop's core promise is *graceful degradation in a hostile runtime*:
when the fast path fails in the browser, the tool falls back (sub-volume
failsafe, slower backend) instead of failing the user. CHIPS (PAPERS.md,
arXiv:1710.00734) shows the same workload cloud-side, where transient
worker failures, stragglers, and stuck jobs are the operating norm. The
PR 5/6 serving stack survives whole-replica crashes with exactly-once
re-dispatch, but a single executor fault inside a batch was terminal on
the first attempt. This module supplies the missing policy vocabulary —
consumed by ``serving/scheduler.py`` (retries, timeouts, breakers) and
``serving/fleet.py`` (hedged re-dispatch):

  * **RetryPolicy** — per-class retry budgets with exponential backoff
    and *seeded deterministic jitter* (a counter-based hash, not a global
    RNG): a retried request re-enters its signature lane with the
    ORIGINAL arrival stamp, so deadlines and FIFO stay honest and
    ``wait + service == finish - arrival`` keeps holding exactly.
  * **Service timeouts** — a per-priority-class bound on one attempt's
    service time (virtual seconds under the simulator): a stuck batch
    member is cancelled at the bound, charged the bound, stamped
    ``service_timeout``, and retried like a transient fault.
  * **HedgePolicy** — when a queued request's age crosses a p99-derived
    threshold, the fleet dispatches a second copy to another replica;
    first completion wins, the loser is cancelled via the ledger
    (``completions_seen <= 1`` stays provable — zero double-serves).
  * **SignatureBreaker** — a per-(replica, signature) circuit breaker:
    ``trip_after`` consecutive executor faults demote the signature one
    rung down the degradation ladder (``LADDER``: megakernel ->
    pallas_fused -> xla -> streaming, then the sub-volume failsafe
    *mode*), re-resolving through the executor registry and re-pricing
    admission at the new rung; after ``cooldown_s`` a half-open probe
    retries the fast path and restores it on success.
  * **FaultPlan** — a seeded schedule of injected faults (transient
    raise, permanent raise, straggler slowdown, stuck-forever) keyed by
    (time-window, replica, signature): every injection decision is a
    pure function of (plan seed, replica, request id, attempt), so an
    entire fault storm is a byte-reproducible function of (code, seed) —
    the same discipline PR 5/6 established for load. Golden:
    tests/golden/fleet_faultstorm.json; DESIGN.md §7, EXPERIMENTS.md H14.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Optional

from repro.serving.errors import ResilienceConfigError

#: The executor degradation ladder, fastest rung first. A breaker trip
#: demotes a signature's executor to the next rung (sharded wrappers
#: demote their inner backend and keep the slab count); below the last
#: executor rung sits the sub-volume failsafe *mode* — the same bottom
#: rung Brainchop's client falls back to, and the same form admission
#: demotion already produces.
LADDER = ("pallas_megakernel", "pallas_fused", "xla", "streaming")

#: breaker states (per base signature, per scheduler == per replica).
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


def unit_hash(*parts) -> float:
    """Deterministic uniform draw in [0, 1) from integer/string parts —
    a counter-based hash (blake2b), NOT a stateful RNG: the same parts
    give the same draw on every platform and in any call order, which is
    what makes fault schedules and backoff jitter pure functions of
    (seed, request identity, attempt)."""
    h = hashlib.blake2b(repr(parts).encode("utf-8"), digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0**64


# ----------------------------------------------------------------- retry ---


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry budget + backoff shape for retryable faults (transient
    executor faults and service timeouts; permanent faults never retry).

    ``max_attempts`` counts TOTAL service attempts (1 == no retries).
    The k-th retry (k >= 1) waits

        backoff = min(backoff_max_s, backoff_base_s * backoff_mult**(k-1))
                  * (1 + jitter_frac * (2u - 1)),   u = unit_hash(...)

    i.e. exponential growth, capped, with +/-``jitter_frac`` seeded
    jitter so synchronized fault bursts de-correlate their retries
    without a shared RNG (DESIGN.md §7.2)."""

    max_attempts: int = 3
    backoff_base_s: float = 0.05
    backoff_mult: float = 2.0
    backoff_max_s: float = 2.0
    jitter_frac: float = 0.25
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ResilienceConfigError(
                f"max_attempts must be >= 1, got {self.max_attempts}"
            )
        if self.backoff_mult <= 0 or self.backoff_base_s < 0:
            raise ResilienceConfigError(
                "backoff_base_s must be >= 0 and backoff_mult > 0 "
                f"(got base={self.backoff_base_s}, mult={self.backoff_mult})"
            )
        if not 0.0 <= self.jitter_frac < 1.0:
            raise ResilienceConfigError(
                f"jitter_frac must be in [0, 1), got {self.jitter_frac}"
            )

    def backoff_s(self, attempt: int, replica_id: int, request_id: int) -> float:
        """Deterministic backoff before service attempt ``attempt``
        (>= 1): exponential in the attempt index, jittered by a pure
        hash of (seed, replica, request, attempt)."""
        raw = min(
            self.backoff_max_s,
            self.backoff_base_s * self.backoff_mult ** (attempt - 1),
        )
        u = unit_hash("backoff", self.seed, replica_id, request_id, attempt)
        return raw * (1.0 + self.jitter_frac * (2.0 * u - 1.0))


# --------------------------------------------------------------- hedging ---


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """Straggler hedging (fleet-level, serving/fleet.py): when a queued
    request's age exceeds ``max(min_age_s, p99_factor * p99)`` — p99
    taken over the last ``window`` served end-to-end latencies, once at
    least ``min_samples`` have been observed — a second copy is
    dispatched to another replica (never one already holding a copy).
    First completion wins; the loser is cancelled from its queue via the
    ledger. ``max_hedges`` bounds copies per request (1 == at most one
    hedge, i.e. two copies total)."""

    p99_factor: float = 3.0
    min_age_s: float = 1.0
    min_samples: int = 30
    window: int = 200
    max_hedges: int = 1

    def __post_init__(self):
        if self.p99_factor <= 0 or self.min_age_s < 0:
            raise ResilienceConfigError(
                "hedge p99_factor must be > 0 and min_age_s >= 0 "
                f"(got {self.p99_factor}, {self.min_age_s})"
            )
        if self.max_hedges < 1 or self.min_samples < 1 or self.window < 1:
            raise ResilienceConfigError(
                "hedge max_hedges/min_samples/window must all be >= 1"
            )


# ------------------------------------------------------- circuit breaker ---


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Per-(replica, signature) circuit-breaker law: ``trip_after``
    consecutive executor faults at the signature's current rung demote
    it one rung further down ``LADDER``; after ``cooldown_s`` the
    breaker half-opens and the next request of that signature probes the
    ORIGINAL (base) rung — success restores the fast path entirely,
    another fault re-opens for a fresh cooldown."""

    trip_after: int = 3
    cooldown_s: float = 30.0

    def __post_init__(self):
        if self.trip_after < 1 or self.cooldown_s < 0:
            raise ResilienceConfigError(
                "breaker trip_after must be >= 1 and cooldown_s >= 0 "
                f"(got {self.trip_after}, {self.cooldown_s})"
            )


@dataclasses.dataclass
class _BreakerEntry:
    """Mutable per-signature breaker state (keyed by the BASE GroupKey)."""

    rung: int = 0  # rungs below base the signature currently serves at
    consec_faults: int = 0  # consecutive faults at the current rung
    state: str = CLOSED
    opened_s: float = 0.0
    probing: bool = False  # a half-open probe is in flight at base rung


def signature_label(key) -> str:
    """Stable human-readable label of a dispatch signature for breaker
    transition logs and summaries."""
    shape = "x".join(str(s) for s in key.shape)
    return f"{key.mode}/{key.executor}/{key.precision}/{shape}"


class SignatureBreaker:
    """Circuit breakers for every dispatch signature of ONE scheduler
    (one scheduler == one fleet replica, so the keying is per
    (replica, signature) exactly as DESIGN.md §7.4 specifies). The
    scheduler consults ``effective_rung`` at batch formation and reports
    every execution result through ``on_result``; ``transitions`` is the
    state-change log the telemetry rollup surfaces."""

    def __init__(self, cfg: BreakerConfig):
        self.cfg = cfg
        self.entries: dict = {}  # base GroupKey -> _BreakerEntry
        self.transitions: list[dict] = []
        self.trips = 0
        self.restores = 0
        self.probes = 0

    def _log(self, key, entry: _BreakerEntry, to_state: str, now: float) -> None:
        entry.state = to_state
        self.transitions.append(
            {
                "t": round(float(now), 4),
                "signature": signature_label(key),
                "state": to_state,
                "rung": entry.rung,
            }
        )

    def _maybe_half_open(self, key, entry: _BreakerEntry, now: float) -> None:
        if (
            entry.state == OPEN
            and now - entry.opened_s >= self.cfg.cooldown_s
        ):
            self._log(key, entry, HALF_OPEN, now)

    def peek_rung(self, base_key, now: float) -> int:
        """The rung a request of this signature would serve at right now,
        WITHOUT claiming the half-open probe slot — what batch-formation
        uses to judge grouping candidates before admitting them."""
        entry = self.entries.get(base_key)
        if entry is None or entry.rung == 0:
            return 0
        self._maybe_half_open(base_key, entry, now)
        if entry.state == HALF_OPEN and not entry.probing:
            return 0  # the probe slot is free: this request would probe
        return entry.rung

    def effective_rung(self, base_key, now: float) -> tuple[int, bool]:
        """(rung, is_probe) for a request being admitted to a batch NOW.
        A half-open signature hands out exactly one probe slot: the probe
        serves at the base rung (0) and its result decides restore vs
        re-open; everyone else keeps the demoted rung meanwhile."""
        entry = self.entries.get(base_key)
        if entry is None or entry.rung == 0:
            return 0, False
        self._maybe_half_open(base_key, entry, now)
        if entry.state == HALF_OPEN and not entry.probing:
            entry.probing = True
            self.probes += 1
            return 0, True
        return entry.rung, False

    def on_result(
        self, base_key, *, fault: bool, probe: bool, now: float
    ) -> None:
        """Fold one execution result into the signature's breaker.
        ``fault`` is True for executor faults (transient, permanent, or
        a service timeout) — both flavours count toward the trip: a
        permanently-faulting signature must walk DOWN the ladder until
        it reaches a rung that serves, which is the whole point of
        degradation (requests complete slower instead of failing)."""
        entry = self.entries.get(base_key)
        if entry is None:
            if not fault:
                return
            entry = self.entries.setdefault(base_key, _BreakerEntry())
        if probe:
            entry.probing = False
            if fault:
                entry.opened_s = now  # fast path still broken: re-open
                self._log(base_key, entry, OPEN, now)
            else:
                entry.rung = 0  # fast path restored entirely
                entry.consec_faults = 0
                self.restores += 1
                self._log(base_key, entry, CLOSED, now)
            return
        if not fault:
            entry.consec_faults = 0
            return
        entry.consec_faults += 1
        if entry.consec_faults >= self.cfg.trip_after:
            entry.consec_faults = 0
            entry.rung += 1  # the ladder walk caps at its bottom rung
            entry.opened_s = now
            self.trips += 1
            self._log(base_key, entry, OPEN, now)

    def open_signatures(self) -> int:
        return sum(1 for e in self.entries.values() if e.rung > 0)

    def open_signature_labels(self) -> list:
        """Sorted labels of every signature currently held off its fast
        path (rung > 0) — the golden-trace face of the breaker state."""
        return sorted(
            signature_label(k)
            for k, e in self.entries.items()
            if e.rung > 0
        )


def demote_rung(key, engine):
    """ONE rung down the degradation ladder for ``key``, re-resolved
    through the executor registry — or None at the bottom. Executor
    rungs demote along ``LADDER`` (sharded wrappers demote their inner
    and keep the slab pin while the demoted inner still shards); past
    the last executor rung, the *mode* demotes to the sub-volume
    failsafe (the admission-demotion form, re-resolved at the cube
    geometry). The caller re-prices admission at the returned key."""
    from repro.core import executors

    inner = executors.inner_of(key.executor)
    parsed = executors.parse_sharded(key.executor)
    if inner in LADDER and LADDER.index(inner) + 1 < len(LADDER):
        nxt = LADDER[LADDER.index(inner) + 1]
        if parsed is not None and executors.shardable(nxt):
            name = executors.ensure_sharded(nxt, parsed[1])
        else:
            name = nxt
        name = executors.resolve(
            name, engine.cfg.model, key.shape, key.precision
        )
        return dataclasses.replace(key, executor=name)
    if key.mode != "subvolume":
        work = (engine.cfg.cube + 2 * engine.cfg.overlap,) * 3
        name = executors.resolve(
            inner if inner in LADDER else None,
            engine.cfg.model,
            work,
            key.precision,
        )
        return dataclasses.replace(key, mode="subvolume", executor=name)
    return None  # already at the bottom of the ladder


# --------------------------------------------------------- fault injection ---

FAULT_KINDS = ("transient", "permanent", "straggler", "stuck")

#: cache-tier fault kinds (serving/cache.py). These never fire on the
#: execution path — ``decide`` skips them and ``decide_cache`` sees only
#: them — so adding cache rules to a plan cannot perturb an existing
#: execution-fault storm's coins (byte-stable goldens).
#:
#:   * ``corrupt_entry``      — flip a byte of the stored artifact just
#:     before integrity verification: the checksum mismatch MUST be
#:     caught, quarantined, and transparently recomputed.
#:   * ``cache_unavailable``  — the tier does not answer: the consult
#:     degrades fail-open to the compute path and feeds the cache
#:     breaker.
#:   * ``slow_cache``         — the consult answers after
#:     ``slow_factor``x the modeled verify cost (a slow tier must
#:     degrade latency, never correctness).
CACHE_FAULT_KINDS = ("corrupt_entry", "cache_unavailable", "slow_cache")


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One line of a fault schedule: within virtual-time window
    ``[t0, t1)``, on ``replica`` (None = every replica), for requests
    whose dispatch signature matches the given filters (None = any),
    inject ``kind`` with probability ``rate`` per service attempt.
    ``slow_factor`` scales service time for ``straggler`` rules."""

    kind: str
    rate: float = 1.0
    t0: float = 0.0
    t1: float = math.inf
    replica: Optional[int] = None
    executor_substr: Optional[str] = None
    mode: Optional[str] = None
    shape: Optional[tuple] = None
    precision: Optional[str] = None
    priority: Optional[str] = None
    slow_factor: float = 4.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS + CACHE_FAULT_KINDS:
            raise ResilienceConfigError(
                f"unknown fault kind {self.kind!r}: "
                f"{FAULT_KINDS + CACHE_FAULT_KINDS}"
            )
        if not 0.0 <= self.rate <= 1.0:
            raise ResilienceConfigError(
                f"fault rate must be in [0, 1], got {self.rate}"
            )
        if self.slow_factor < 1.0:
            raise ResilienceConfigError(
                f"slow_factor must be >= 1, got {self.slow_factor}"
            )

    def matches(self, *, t, replica, key, priority) -> bool:
        if not (self.t0 <= t < self.t1):
            return False
        if self.replica is not None and replica != self.replica:
            return False
        if self.priority is not None and priority != self.priority:
            return False
        if key is None:
            # a consult with no dispatch signature (e.g. a fleet-level
            # cache peek): signature filters cannot match it
            return not (
                self.executor_substr is not None
                or self.mode is not None
                or self.shape is not None
                or self.precision is not None
            )
        if (
            self.executor_substr is not None
            and self.executor_substr not in key.executor
        ):
            return False
        if self.mode is not None and key.mode != self.mode:
            return False
        if self.shape is not None and tuple(self.shape) != tuple(key.shape):
            return False
        if self.precision is not None and key.precision != self.precision:
            return False
        return True


@dataclasses.dataclass(frozen=True)
class FaultDecision:
    """What the injector decided for one service attempt."""

    kind: str
    rule_index: int
    slow_factor: float = 1.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, declarative fault schedule. ``decide`` is a PURE
    function of (plan, service-start time, replica, signature, request
    id, attempt): the first rule that matches AND fires (its seeded
    coin, ``unit_hash(seed, rule, replica, request, attempt)``, lands
    under ``rate``) wins. Retried attempts re-roll the coin (the attempt
    index is in the hash), which is exactly what makes retry recovery
    measurable; the time window keys make storms startable/stoppable
    mid-trace. The whole scenario is byte-reproducible from (code,
    seed) — FaultPlans are config, never state."""

    seed: int = 0
    rules: tuple = ()

    def decide(
        self,
        *,
        t: float,
        replica: int,
        key,
        request_id: int,
        attempt: int,
        priority: Optional[str] = None,
    ) -> Optional[FaultDecision]:
        for i, rule in enumerate(self.rules):
            if rule.kind in CACHE_FAULT_KINDS:
                continue  # cache rules never fire on the execution path
            if not rule.matches(t=t, replica=replica, key=key, priority=priority):
                continue
            u = unit_hash("fault", self.seed, i, replica, request_id, attempt)
            if u < rule.rate:
                return FaultDecision(
                    kind=rule.kind,
                    rule_index=i,
                    slow_factor=rule.slow_factor
                    if rule.kind == "straggler"
                    else 1.0,
                )
        return None

    def decide_cache(
        self,
        *,
        t: float,
        replica: int,
        key,
        request_id: int,
        op: str,
    ) -> Optional[FaultDecision]:
        """The cache-tier twin of ``decide``: a PURE function of (plan,
        consult time, replica, signature, request id, op) over the
        CACHE_FAULT_KINDS rules only. ``op`` distinguishes lookups from
        stores in the coin (a request's lookup and its completion's
        store roll independently), with a distinct hash salt so cache
        storms can never collide with execution-fault coins. ``key``
        may be None for consults with no dispatch signature."""
        for i, rule in enumerate(self.rules):
            if rule.kind not in CACHE_FAULT_KINDS:
                continue  # execution rules never fire on the cache path
            if not rule.matches(t=t, replica=replica, key=key, priority=None):
                continue
            u = unit_hash("cachefault", self.seed, i, replica, request_id, op)
            if u < rule.rate:
                return FaultDecision(
                    kind=rule.kind,
                    rule_index=i,
                    slow_factor=rule.slow_factor
                    if rule.kind == "slow_cache"
                    else 1.0,
                )
        return None

    def has_stuck(self) -> bool:
        return any(r.kind == "stuck" for r in self.rules)

    def has_cache_rules(self) -> bool:
        return any(r.kind in CACHE_FAULT_KINDS for r in self.rules)


# ----------------------------------------------------------- policy bundle ---


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """The full resilience configuration one scheduler (and, via
    ``FleetConfig.resilience``, every replica plus the fleet's hedging
    loop) runs under. ``service_timeout_s`` maps priority-class name ->
    per-attempt service bound (classes absent from the map never time
    out); ``hedge=None`` disables hedging; ``breaker=None`` disables the
    degradation ladder."""

    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    service_timeout_s: dict = dataclasses.field(default_factory=dict)
    hedge: Optional[HedgePolicy] = None
    breaker: Optional[BreakerConfig] = dataclasses.field(
        default_factory=BreakerConfig
    )

    def timeout_for(self, priority_class: str) -> Optional[float]:
        return self.service_timeout_s.get(priority_class)

    def validate_against(self, classes: dict, fault_plan) -> None:
        """Reject configurations that cannot terminate: a FaultPlan with
        stuck-forever rules requires EVERY priority class to carry a
        service timeout, or a stuck request would occupy its replica
        until the end of time (typed ``ResilienceConfigError`` — the
        serving analogue of scale-to-zero being an outage)."""
        if fault_plan is None or not fault_plan.has_stuck():
            return
        missing = [
            name for name in classes if self.timeout_for(name) is None
        ]
        if missing:
            raise ResilienceConfigError(
                "FaultPlan injects stuck-forever faults but classes "
                f"{missing} have no service timeout; a stuck request "
                "would never be cancelled"
            )
