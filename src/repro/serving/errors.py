"""Typed fault taxonomy for the serving tier — one module, every error.

Brainchop survives a hostile runtime (the browser) by *naming* its
failures — "Unable to create WebGL Texture", sub-volume failsafes — and
CHIPS (PAPERS.md, arXiv:1710.00734) treats transient worker failures,
stragglers, and stuck jobs as the steady state of a cloud medical-image
service, not an exception path. The serving stack follows suit: every
error a scheduler, router, or executor can raise is a *typed* class
defined (or re-exported) here, and execution faults are split along the
one axis that changes scheduling policy — **can a retry help?**

  * ``TransientExecutorError`` — the fault is expected to clear on its
    own (preemption, OOM race, a flaky device, an interrupted DMA): the
    retry/backoff machinery in ``serving/resilience.py`` re-enqueues the
    request in its signature lane with the ORIGINAL arrival stamp.
  * ``PermanentExecutorError`` — retrying the same signature on the same
    rung reproduces the fault (a miscompiled executable, a poisoned
    weight cache, an unsupported shape): no retry; the circuit breaker
    demotes the signature's executor down the degradation ladder so
    later requests complete at a slower rung instead of failing.

``classify`` maps an arbitrary raised exception onto that axis (default
conservative: unknown exceptions are permanent — retrying an unknown
fault burns capacity exactly when the service is least healthy). The
scheduler's ``_serve_one`` stamps the result as the record's
``fail_type`` (``transient_fault`` | ``permanent_fault``), replacing the
blanket ``executor_error`` of PR 5.

Pre-service backpressure and configuration errors are re-exported from
their defining modules (or defined here when serving-owned) so call
sites import ONE module instead of spelunking the package. DESIGN.md §7.
"""

from __future__ import annotations

# Typed errors owned by other layers, re-exported for one-stop imports:
# the sharded executor family's geometry failures and the memory-budget
# model's admission failures both cross the serving boundary.
from repro.core.spatial_shard import ShardGeometryError  # noqa: F401
from repro.telemetry.budget import BudgetExceeded  # noqa: F401


class ServingError(Exception):
    """Base class of every serving-owned typed error."""


# --------------------------------------------------------- executor faults ---


class ExecutorFault(ServingError):
    """Base of the execution-fault taxonomy: a request reached service
    and the executor raised. Subclasses pick the retry policy."""


class TransientExecutorError(ExecutorFault):
    """A fault expected to clear on retry: device preemption, an HBM
    allocation race, an interrupted halo exchange. The retry policy
    (``serving/resilience.py``) backs off and re-enqueues."""


class PermanentExecutorError(ExecutorFault):
    """A fault that will reproduce on the same (executor, signature)
    rung: retrying is wasted work, but the circuit breaker can demote
    the signature one rung down the degradation ladder."""


# ------------------------------------------------------------ cache faults ---


class CacheFault(ServingError):
    """Base of the artifact-cache fault taxonomy (``serving/cache.py``).

    Cache faults are *never* request failures: the cache tier is an
    optimization in front of compute, so every cache fault degrades
    fail-open — a corrupt entry is quarantined and recomputed, an
    unavailable tier is bypassed straight to the device path. These
    classes exist so the degradation is **typed** (counted, breaker-
    visible, testable) instead of a silent ``except Exception``."""


class CacheCorruptionError(CacheFault):
    """An artifact's stored checksum no longer matches its bytes — bit
    rot, a torn write, or an injected ``corrupt_entry`` fault. The entry
    is quarantined (evicted + counted) and the request transparently
    recomputed; corrupt bytes must NEVER reach a completion."""

    def __init__(self, key: str, expected: str, actual: str):
        super().__init__(
            f"cache artifact {key[:16]}… failed integrity re-verification: "
            f"stored checksum {expected[:12]}… != recomputed {actual[:12]}…"
        )
        self.key = key
        self.expected = expected
        self.actual = actual


class CacheUnavailableError(CacheFault):
    """The cache tier did not answer (injected ``cache_unavailable``
    fault, or a real backend outage). The caller serves via compute —
    a retry of the *request* is pointless (compute already works), but
    the cache breaker uses consecutive unavailability to stop consulting
    the tier entirely until it recovers."""

    def __init__(self, reason: str = "cache tier unavailable"):
        super().__init__(reason)


#: fail_type stamps of the execution-fault taxonomy (TelemetryRecord).
TRANSIENT_FAULT = "transient_fault"
PERMANENT_FAULT = "permanent_fault"
#: a batch member cancelled by its priority class's service timeout —
#: scheduled like a transient fault (stuck-forever jobs are the CHIPS
#: straggler pathology; a retry lands on a healthy attempt).
SERVICE_TIMEOUT = "service_timeout"

#: fail types the retry policy treats as retryable.
RETRYABLE_FAIL_TYPES = frozenset({TRANSIENT_FAULT, SERVICE_TIMEOUT})
#: every execution-fault fail_type the resilience layer emits.
EXECUTION_FAULT_TYPES = frozenset(
    {TRANSIENT_FAULT, PERMANENT_FAULT, SERVICE_TIMEOUT}
)


def classify(exc: BaseException) -> str:
    """Map a raised exception to its ``fail_type`` stamp. Explicitly
    transient errors are ``transient_fault``; everything else —
    PermanentExecutorError, garbage-volume ValueErrors, geometry
    failures, unknown bugs — is ``permanent_fault``: retrying an
    unclassified fault spends capacity exactly when the service is
    least healthy, so unknown means permanent by default.

    ``BaseException``s that are not ``Exception``s — KeyboardInterrupt,
    SystemExit, GeneratorExit — are control flow, not faults: swallowing
    one as a ``permanent_fault`` record would turn Ctrl-C into a served
    "failure" and keep the process alive against the operator's explicit
    instruction. They re-raise."""
    if not isinstance(exc, Exception):
        raise exc
    if isinstance(exc, TransientExecutorError):
        return TRANSIENT_FAULT
    if isinstance(exc, CacheFault):
        # a cache fault that leaked to classify means fail-open is in
        # progress: recompute fixes corruption and the compute path does
        # not need the cache at all, so a retry genuinely helps
        return TRANSIENT_FAULT
    return PERMANENT_FAULT


# ------------------------------------------------------ admission / router ---


class QueueFullError(ServingError):
    """Typed backpressure: the admission queue is at its depth limit."""

    def __init__(self, depth: int, limit: int):
        super().__init__(f"serving queue full: {depth} queued, limit {limit}")
        self.depth = depth
        self.limit = limit


class NoReplicaAvailable(ServingError):
    """Typed router backpressure: no live, non-draining replica exists to
    take the request (all crashed, or all draining). The fleet analogue
    of the scheduler's ``QueueFullError``."""

    def __init__(self, total: int, draining: int, crashed: int):
        super().__init__(
            f"no routable replica: {total} total, {draining} draining, "
            f"{crashed} crashed"
        )
        self.total = total
        self.draining = draining
        self.crashed = crashed


class FleetConfigError(ValueError):
    """Typed rejection of an unservable fleet configuration — most
    importantly scale-to-zero (min_replicas < 1, or draining the last
    routable replica through the autoscaling path)."""


class ResilienceConfigError(ValueError):
    """Typed rejection of an unservable resilience configuration — e.g.
    a FaultPlan that injects stuck-forever faults into a priority class
    with no service timeout (the simulation would never terminate), or
    a retry budget with a non-positive backoff multiplier."""
