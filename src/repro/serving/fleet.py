"""Fleet-scale serving: replicated schedulers behind a cache-affinity router.

PR 5's single scheduler can only go *deeper* (a longer queue) under the
overload the ROADMAP's millions-of-users north star implies — its
committed overload p99 sits near 28 virtual seconds because one device
pool serves a 12 Hz diurnal peak alone. This module goes *wider*, the
cloud-service shape CHIPS (PAPERS.md, arXiv:1710.00734) describes for
medical-image workloads:

  * **Replicas** — N independent ``RequestScheduler``s, each owning its
    own engine and therefore its own device set, jit caches, and
    prepared-weight pytrees (``SegmentationEngine._prepared``). Nothing
    is shared between replicas except the virtual clock, exactly like
    separate servers share only NTP.
  * **Router** — pluggable policies over the routable (live,
    non-draining) replica set: ``round_robin``, ``least_loaded`` (min
    priced backlog bytes), ``join_shortest_queue``, and
    ``cache_affinity`` — the PR 5 dispatch signature (``GroupKey``:
    mode, executor, devices, precision, shape) is the affinity key, and
    requests are steered to replicas that already dispatched that
    signature, i.e. hold a **warm compiled executable** for it. A cold
    signature costs ``FleetServiceModel.cold_compile_s`` once per
    (replica, signature), so affinity is visible in the latency numbers,
    not just in a hit-rate counter.
  * **Failure & drain with exactly-once re-dispatch** — a crashed
    replica's queued requests AND the un-served tail of its in-flight
    batch (``RequestScheduler.run_batch_until`` never executes members
    that would finish past the crash) are re-routed to surviving
    replicas; the fleet ledger maps every fleet request id to exactly
    one terminal completion, so failover loses nothing and serves
    nothing twice. Draining is the graceful version: no new routes, the
    backlog is re-dispatched (or self-served when no peer exists), the
    in-flight batch finishes, then the replica retires.
  * **Diurnal autoscaler** — at a fixed virtual interval, SLO attainment
    of the guarded class over the last window decides scale-up; a clean
    window plus empty queues decides scale-down (drain the youngest
    replica), bounded by [min_replicas, max_replicas] with a cooldown.
    ``min_replicas >= 1`` is enforced with a typed ``FleetConfigError``:
    scale-to-zero is an outage, not a policy.

Everything runs on the shared ``VirtualClock``, so fleet p50/p99, shed
counts, affinity hit rates, and the autoscaler's event timeline are pure
functions of (code, seed): ``simulate_fleet`` summaries are byte-exact
golden traces (tests/golden/fleet_*.json) and gated BENCH_2.json rows
(``serving_fleet`` section, absolute tolerance). DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Callable, Optional

import numpy as np

from repro.serving.errors import (  # noqa: F401  (re-exported names)
    FleetConfigError,
    NoReplicaAvailable,
    QueueFullError,
)
from repro.serving.scheduler import (
    RequestScheduler,
    SchedulerConfig,
    ServeRequest,
)
from repro.serving.simulator import (
    ARRIVAL_PROCESSES,
    ServiceModel,
    VirtualClock,
    _make_volume,
    _pctls_ms,
    _round,
    _sample_mix,
    _ShapeStub,
    reference_engine,
    resilience_block,
    zipf_content_id,
)
from repro.telemetry.analysis import nearest_rank

#: router policies (see Fleet._pick). cache_affinity is the default the
#: presets commit to — it is the one that exploits the PR 5 signature
#: machinery instead of merely balancing load.
ROUTER_POLICIES = (
    "round_robin",
    "least_loaded",
    "join_shortest_queue",
    "cache_affinity",
)


@dataclasses.dataclass(frozen=True)
class FleetServiceModel(ServiceModel):
    """ServiceModel plus the fleet-visible compile cost: the FIRST batch
    of a given dispatch signature on a given replica stalls
    ``cold_compile_s`` virtual seconds (trace + compile + warm the jit
    cache); later batches of that signature on that replica are warm.
    This is the term cache-affinity routing exists to amortize — with N
    replicas and round-robin, every signature compiles ~N times."""

    cold_compile_s: float = 0.25


@dataclasses.dataclass
class AutoscalerConfig:
    """The control law (DESIGN.md §6.4): every ``interval_s`` virtual
    seconds, look at the guarded class's completions in the last window.

      attainment = fraction served end-to-end within ``slo_latency_s``
                   (shed/refused requests in the window count as misses)

      attainment < up_attainment  and replicas < max  -> add a replica
      attainment >= down_attainment (or an idle window) and every queue
      empty and replicas > min -> drain the youngest replica

    ``cooldown_s`` rate-limits actions so one bad window cannot flap the
    fleet. ``min_replicas`` must be >= 1 — scale-to-zero is rejected with
    a typed ``FleetConfigError`` at fleet construction."""

    interval_s: float = 60.0
    min_replicas: int = 1
    max_replicas: int = 8
    slo_class: str = "interactive"
    slo_latency_s: float = 2.0
    up_attainment: float = 0.9
    down_attainment: float = 0.98
    cooldown_s: float = 120.0


@dataclasses.dataclass(frozen=True)
class FleetEvent:
    """One planned operator/fault action: ``crash`` (kill mid-batch,
    evacuate + re-dispatch), ``drain`` (graceful removal), or ``add`` (a
    planned capacity bump). Part of FleetConfig, so failover scenarios
    are as seeded and reproducible as the traffic."""

    t: float
    action: str  # crash | drain | add
    replica: Optional[int] = None  # target id for crash/drain


@dataclasses.dataclass
class FleetConfig:
    """One fleet simulation: seeded arrivals over a scenario mix, routed
    across ``replicas`` schedulers (each configured by ``scheduler``),
    with an optional fault plan (``events``) and autoscaler."""

    name: str = "fleet"
    seed: int = 0
    horizon_s: float = 600.0
    process: str = "poisson"
    process_kwargs: dict = dataclasses.field(
        default_factory=lambda: {"rate_hz": 2.0}
    )
    mix: tuple = ()
    replicas: int = 2
    policy: str = "cache_affinity"
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    service: FleetServiceModel = dataclasses.field(default_factory=FleetServiceModel)
    autoscaler: Optional[AutoscalerConfig] = None
    events: tuple = ()
    execute: bool = False
    # resilience policy + seeded fault injection (serving/resilience.py):
    # every replica's scheduler runs under the same policy/plan (keyed by
    # its replica id, so injection decisions and backoff jitter differ
    # per replica); the fleet layer additionally runs the hedging loop
    # when ``resilience.hedge`` is set. Both None keeps PR 6 behavior —
    # and the committed fleet golden traces — bit-for-bit unchanged.
    resilience: Optional[object] = None
    fault_plan: Optional[object] = None
    # content-addressed artifact cache (serving/cache.py): a CacheConfig
    # here builds ONE ArtifactCache shared by every replica scheduler —
    # the fleet's shared cache tier in front of routing. None (default)
    # keeps every pre-cache scenario — and its golden trace — untouched.
    cache: Optional[object] = None
    # Zipf content-popularity skew over arriving volumes (see
    # simulator.zipf_content_id); None disables content identity.
    content_skew: Optional[float] = None
    content_universe: int = 64


@dataclasses.dataclass
class FleetRequest:
    """Fleet-ledger entry: ONE row per arriving request, whatever happens
    to it — the exactly-once bookkeeping. ``dispatches`` > 1 means
    failover re-dispatch moved it; ``completions_seen`` must end at <= 1
    (a request served twice would increment it twice)."""

    fid: int
    arrival_s: float
    priority: str
    replica: Optional[int] = None  # current/last owner
    dispatches: int = 0
    outcome: Optional[str] = None  # completed|demoted|rejected|refused|no_replica
    finish_s: Optional[float] = None
    completion: Optional[object] = None
    completions_seen: int = 0
    # live copies of this request across the fleet: (replica id, local
    # request id) -> is_hedge. Normally one entry; hedged re-dispatch
    # adds a second, and the first SERVED completion cancels the rest
    # via the ledger (the exactly-once race, DESIGN.md §7.3).
    copies: dict = dataclasses.field(default_factory=dict)
    hedges: int = 0  # hedge copies ever granted to this request


class Replica:
    """One fleet member: an engine (own jit caches / prepared weights)
    behind its own ``RequestScheduler``, plus the fleet-side state the
    router and event loop need — busy horizon, warm-signature set, and
    the drain/crash flags."""

    def __init__(self, rid: int, engine, fleet: "Fleet"):
        self.id = rid
        self.engine = engine
        self.sched = RequestScheduler(
            engine,
            fleet.cfg.scheduler,
            clock=fleet.clock,
            service_model=fleet.cfg.service,
            execute=fleet.cfg.execute,
            resilience=fleet.cfg.resilience,
            fault_plan=fleet.cfg.fault_plan,
            replica_id=rid,
            cache=fleet.cache,  # the SHARED tier — one instance fleetwide
        )
        self.busy_until = fleet.clock.now()
        self.inflight = False
        self.inflight_unserved: list[ServeRequest] = []
        self.warm: set = set()  # dispatch signatures with warm executables
        self.draining = False
        self.crashed = False
        self.retired = False
        self.created_s = fleet.clock.now()
        self._synced = 0  # completions already folded into the fleet ledger

    @property
    def live(self) -> bool:
        return not (self.crashed or self.retired)

    @property
    def routable(self) -> bool:
        return self.live and not self.draining

    def queue_len(self) -> int:
        return len(self.sched.queue)

    def backlog_bytes(self) -> int:
        return sum(r.bytes_priced for r in self.sched.queue)


class Fleet:
    """N replica schedulers behind a policy router on one virtual clock.

    Drive it either through ``simulate_fleet`` (seeded traffic, the
    golden path) or directly: ``submit`` routes one request (raising
    typed ``NoReplicaAvailable`` / ``QueueFullError`` backpressure),
    ``drain`` serves everything queued, ``scale_up``/``scale_down`` and
    ``crash_replica``/``drain_replica`` are the operator verbs the fault
    plan and autoscaler use internally."""

    def __init__(self, cfg: FleetConfig, engine_factory: Optional[Callable] = None):
        if cfg.replicas < 1:
            raise FleetConfigError(
                f"fleet needs >= 1 replica, got {cfg.replicas} "
                "(scale-to-zero is an outage, not a configuration)"
            )
        if cfg.policy not in ROUTER_POLICIES:
            raise FleetConfigError(
                f"unknown router policy {cfg.policy!r}: {ROUTER_POLICIES}"
            )
        if cfg.autoscaler is not None and cfg.autoscaler.min_replicas < 1:
            raise FleetConfigError(
                "autoscaler scale-to-zero rejected: min_replicas must be "
                f">= 1, got {cfg.autoscaler.min_replicas}"
            )
        self.cfg = cfg
        self.engine_factory = engine_factory or reference_engine
        self.clock = VirtualClock()
        # the shared artifact-cache tier (serving/cache.py): ONE instance
        # in front of every replica — content-identical requests hit the
        # same entries whichever replica serves them, and the router can
        # steer a request to its in-flight single-flight leader.
        self.cache = None
        self.content_routes = 0  # routes steered to an in-flight leader
        if cfg.cache is not None:
            from repro.serving.cache import ArtifactCache, CacheConfig

            self.cache = (
                cfg.cache
                if isinstance(cfg.cache, ArtifactCache)
                else ArtifactCache(
                    cfg.cache if isinstance(cfg.cache, CacheConfig) else None,
                    fault_plan=cfg.fault_plan,
                )
            )
        self.replicas: list[Replica] = []  # every replica ever created
        self.ledger: list[FleetRequest] = []
        self._fid: dict[tuple[int, int], int] = {}  # (replica, local id) -> fid
        self._next_id = 0
        self._rr = 0
        self.refused = 0  # queue-full at the routed replica
        self.no_replica = 0  # typed router backpressure
        self.redispatched = 0
        self.routes = 0
        self.affinity_hits = 0
        self.cold_compiles = 0
        # hedging state (resilience.hedge): accepted hedge submissions,
        # races won by the hedge copy, and loser copies cancelled out of
        # queues by the ledger. The latency window feeds the p99-derived
        # hedge threshold — served end-to-end seconds, newest last.
        self._hedge = getattr(cfg.resilience, "hedge", None)
        self.hedges = 0
        self.hedge_wins = 0
        self.hedge_cancelled = 0
        self._lat: list[float] = []
        self.scale_log: list[dict] = []
        self.peak_routable = 0
        self._last_scale_s = -math.inf
        self._events: list[FleetEvent] = sorted(
            cfg.events, key=lambda e: (e.t, e.action, -1 if e.replica is None else e.replica)
        )
        self._ei = 0
        for _ in range(cfg.replicas):
            self._add_replica(0.0, log=False)

    # ------------------------------------------------------------- replicas

    def _routable(self) -> list[Replica]:
        return [r for r in self.replicas if r.routable]

    def _by_id(self, rid) -> Optional[Replica]:
        for r in self.replicas:
            if r.id == rid:
                return r
        return None

    def _add_replica(self, now: float, log: bool = True, action: str = "add") -> Replica:
        rid = self._next_id
        self._next_id += 1
        rep = Replica(rid, self.engine_factory(), self)
        rep.busy_until = now
        rep.created_s = now
        self.replicas.append(rep)
        self.peak_routable = max(self.peak_routable, len(self._routable()))
        if log:
            self._log_scale(now, action, rid)
        return rep

    def _log_scale(self, now: float, action: str, rid: int) -> None:
        self.scale_log.append(
            {
                "t": _round(now),
                "action": action,
                "replica": rid,
                "replicas_after": len(self._routable()),
            }
        )

    def scale_up(self, now: Optional[float] = None) -> Replica:
        """Add one replica (fresh engine: cold jit caches, cold weights)."""
        return self._add_replica(self.clock.now() if now is None else now)

    def scale_down(self, now: Optional[float] = None) -> Replica:
        """Drain the youngest routable replica. Raises a typed
        ``FleetConfigError`` when that would leave zero routable replicas
        — the autoscaling path must never scale to zero."""
        now = self.clock.now() if now is None else now
        routable = self._routable()
        if len(routable) <= 1:
            raise FleetConfigError(
                "scale-to-zero rejected: draining the last routable "
                "replica would black-hole all traffic"
            )
        victim = max(routable, key=lambda r: r.id)
        self.drain_replica(victim.id, now)
        return victim

    def drain_replica(self, rid: int, now: Optional[float] = None) -> None:
        """Graceful removal: stop routing to the replica, re-dispatch its
        queued backlog to peers (exactly-once — each request keeps its
        fleet id and original arrival), let its in-flight batch finish,
        then retire it. With no routable peer left, the backlog stays and
        the draining replica serves it out itself (drain must not lose
        requests just because it is the last one standing)."""
        now = self.clock.now() if now is None else now
        rep = self._by_id(rid)
        if rep is None or not rep.live or rep.draining:
            return
        rep.draining = True
        self._log_scale(now, "drain", rep.id)
        if any(r.routable for r in self.replicas):
            self._redispatch(rep.sched.evacuate(now), now, rep)
        # else: keep the queue; _dispatch_idle still serves draining
        # replicas' own backlogs, so a sole drained replica self-drains.

    def crash_replica(self, rid: int, now: Optional[float] = None) -> None:
        """Hard failure: the replica dies NOW. Members of its in-flight
        batch that had not finished (run_batch_until never executed them)
        and its whole queue are re-dispatched to surviving replicas,
        exactly once each. Raises ``NoReplicaAvailable`` if no survivor
        exists to take them."""
        now = self.clock.now() if now is None else now
        rep = self._by_id(rid)
        if rep is None or not rep.live:
            return
        unserved = rep.inflight_unserved
        rep.inflight_unserved = []
        rep.inflight = False
        rep.crashed = True
        rep.busy_until = now
        # in-flight members handed back: admitted there, served elsewhere
        rep.sched.stats.evacuated += len(unserved)
        evac = unserved + rep.sched.evacuate(now)
        self._log_scale(now, "crash", rep.id)
        if evac:
            self._redispatch(evac, now, rep)

    # --------------------------------------------------------------- router

    def _load_jsq(self, r: Replica) -> tuple:
        return (r.queue_len() + (1 if r.inflight else 0), r.id)

    def _pick(
        self,
        vol,
        mode,
        executor,
        devices,
        precision,
        exclude: Optional[Replica] = None,
    ) -> Replica:
        """One routing decision under the configured policy. Only live,
        non-draining replicas are candidates — cache-affinity NEVER
        routes to a draining replica, however warm it is."""
        cands = sorted(
            (r for r in self._routable() if r is not exclude), key=lambda r: r.id
        )
        if not cands:
            raise NoReplicaAvailable(
                total=len(self.replicas),
                draining=sum(1 for r in self.replicas if r.live and r.draining),
                crashed=sum(1 for r in self.replicas if r.crashed),
            )
        self.routes += 1
        if self.cache is not None:
            # content-to-leader steering, in front of EVERY policy: a
            # request whose artifact is already being computed in flight
            # routes to the leader's replica, where the scheduler
            # attaches it as a single-flight follower instead of running
            # a duplicate forward. A miss (or an unroutable owner) falls
            # through to the configured policy untouched.
            ckey = self._content_key(vol, mode, executor, devices, precision, cands[0])
            if ckey is not None:
                owner = self.cache.inflight_owner(ckey)
                if owner is not None:
                    rep = self._by_id(owner)
                    if rep is not None and rep in cands:
                        self.content_routes += 1
                        return rep
        policy = self.cfg.policy
        if policy == "round_robin":
            chosen = cands[self._rr % len(cands)]
            self._rr += 1
        elif policy == "least_loaded":
            chosen = min(cands, key=lambda r: (r.backlog_bytes(), r.queue_len(), r.id))
        elif policy == "join_shortest_queue":
            chosen = min(cands, key=self._load_jsq)
        else:  # cache_affinity
            key, _ = cands[0].sched.peek_signature(
                vol, mode=mode, executor=executor, devices=devices, precision=precision
            )
            warm = [r for r in cands if key is not None and key in r.warm]
            if warm:
                self.affinity_hits += 1
                chosen = min(warm, key=self._load_jsq)
            else:
                chosen = min(cands, key=self._load_jsq)
        assert not chosen.draining and chosen.live
        return chosen

    def _content_key(
        self, vol, mode, executor, devices, precision, ref: Replica
    ) -> Optional[str]:
        """The artifact key a request WOULD cache under, resolved through
        ``ref``'s signature cache (every replica serves the same model,
        so any replica's resolution is authoritative). None when the
        volume has no content identity — uncacheable, route by policy."""
        from repro.serving import cache as cache_mod

        content = cache_mod.content_hash(vol)
        if content is None:
            return None
        key, _ = ref.sched.peek_signature(
            vol, mode=mode, executor=executor, devices=devices, precision=precision
        )
        if key is None:
            return None
        if ref.sched._model_fp is None:
            ref.sched._model_fp = cache_mod.model_fingerprint(
                ref.sched.engine.cfg.model
            )
        return cache_mod.artifact_key(
            content, ref.sched._model_fp, key.precision, key.mode
        )

    def submit(
        self,
        vol,
        *,
        priority: str = "standard",
        mode: Optional[str] = None,
        executor: Optional[str] = None,
        devices: Optional[int] = None,
        precision: Optional[str] = None,
        arrival_s: Optional[float] = None,
    ) -> int:
        """Route one request; returns its FLEET id (stable across
        failover re-dispatch). Raises typed ``NoReplicaAvailable`` (no
        routable replica) or ``QueueFullError`` (the routed replica's
        queue is at depth) — both are counted and ledgered as terminal
        refusals, so the fleet conservation sum still covers them."""
        now = self.clock.now() if arrival_s is None else float(arrival_s)
        fid = len(self.ledger)
        entry = FleetRequest(fid=fid, arrival_s=now, priority=priority)
        self.ledger.append(entry)
        try:
            target = self._pick(vol, mode, executor, devices, precision)
        except NoReplicaAvailable:
            entry.outcome = "no_replica"
            self.no_replica += 1
            raise
        try:
            lid = target.sched.submit(
                vol,
                priority=priority,
                mode=mode,
                executor=executor,
                devices=devices,
                precision=precision,
                arrival_s=now,
            )
        except QueueFullError:
            entry.outcome = "refused"
            self.refused += 1
            raise
        self._fid[(target.id, lid)] = fid
        entry.replica = target.id
        entry.dispatches = 1
        entry.copies[(target.id, lid)] = False
        return fid

    def _redispatch(self, reqs: list, now: float, source: Replica) -> None:
        """Exactly-once failover: each evacuated request keeps its fleet
        id and ORIGINAL arrival time (queue age travels with it), and is
        force-admitted at its new replica — depth limits must not turn
        an admitted request into a lost one. A copy whose fleet entry was
        already SERVED (its hedge twin won the race before the crash) or
        that still has a live twin queued elsewhere is simply dropped:
        re-admitting it would be the double-serve the ledger forbids."""
        for req in sorted(reqs, key=lambda r: (r.arrival_s, r.id)):
            fid = self._fid.pop((source.id, req.id))
            entry = self.ledger[fid]
            was_hedge = entry.copies.pop((source.id, req.id), False)
            if entry.outcome in ("completed", "demoted", "coalesced") or entry.copies:
                self.hedge_cancelled += 1
                continue
            target = self._pick(
                req.vol, req.mode, req.executor, req.devices, req.precision,
                exclude=source,
            )
            lid = target.sched.submit(
                req.vol,
                priority=req.priority_class.name,
                mode=req.mode,
                executor=req.executor,
                devices=req.devices,
                precision=req.precision,
                arrival_s=req.arrival_s,
                force=True,
            )
            self._fid[(target.id, lid)] = fid
            entry.replica = target.id
            entry.dispatches += 1
            entry.copies[(target.id, lid)] = was_hedge
            self.redispatched += 1

    # ----------------------------------------------------------- event loop

    def _sync(self, rep: Replica) -> None:
        """Fold the replica's new completions into the fleet ledger and
        stamp their telemetry with the replica id. With hedging on, a
        fleet request can hold several live copies; the first SERVED
        completion wins the entry and cancels the twins — a loser that
        was merely evacuated (cancelled in queue) must not overwrite the
        winner's outcome. ``completions_seen`` counts only served
        completions, so it remains the double-serve detector."""
        comps = rep.sched.completions
        for c in comps[rep._synced:]:
            c.record.replica_id = rep.id
            fid = self._fid.get((rep.id, c.id))
            if fid is None:
                continue
            entry = self.ledger[fid]
            was_hedge = entry.copies.pop((rep.id, c.id), False)
            served = c.outcome in ("completed", "demoted", "coalesced")
            already_served = entry.outcome in ("completed", "demoted", "coalesced")
            if already_served and not served:
                continue  # losing copy shed after its twin won
            entry.outcome = c.outcome
            entry.finish_s = c.finish_s
            entry.completion = c
            if served:
                entry.completions_seen += 1
                if was_hedge:
                    self.hedge_wins += 1
                self._observe_latency(c.finish_s - entry.arrival_s)
                self._cancel_copies(entry)
        rep._synced = len(comps)

    # ------------------------------------------------------------- hedging

    def _observe_latency(self, e2e_s: float) -> None:
        if self._hedge is None:
            return
        self._lat.append(e2e_s)
        if len(self._lat) > self._hedge.window:
            del self._lat[: len(self._lat) - self._hedge.window]

    def _cancel_copies(self, entry: FleetRequest) -> None:
        """Cancel every still-queued copy of a fleet request whose twin
        just won: the scheduler counts the removal as an evacuation, so
        each replica's own conservation ledger stays balanced."""
        for (rid, lid) in list(entry.copies):
            rep = self._by_id(rid)
            if rep is None or not rep.live:
                continue
            got = rep.sched.cancel(lid)
            if got is not None:
                self._fid.pop((rid, lid), None)
                entry.copies.pop((rid, lid), None)
                self.hedge_cancelled += 1

    def _hedge_threshold(self) -> Optional[float]:
        h = self._hedge
        if h is None or len(self._lat) < h.min_samples:
            return None
        return max(h.min_age_s, h.p99_factor * nearest_rank(self._lat, 99))

    def _maybe_hedge(self, now: float) -> None:
        """Tail-latency hedging: when a queued request's age crosses the
        p99-derived threshold, dispatch a second copy to the least-loaded
        replica NOT already holding one. First served completion wins;
        the loser is cancelled through the ledger (zero double-serves).
        Hedge copies are deliberately NOT counted as re-dispatches —
        they are speculative, not failover."""
        thr = self._hedge_threshold()
        if thr is None:
            return
        for rep in sorted(self.replicas, key=lambda r: r.id):
            if not rep.live:
                continue
            for req in list(rep.sched.queue):
                if req.key is None:
                    continue
                fid = self._fid.get((rep.id, req.id))
                if fid is None:
                    continue
                entry = self.ledger[fid]
                if (
                    now - entry.arrival_s < thr
                    or entry.hedges >= self._hedge.max_hedges
                    or entry.outcome is not None
                ):
                    continue
                holders = {rid for (rid, _lid) in entry.copies}
                cands = [
                    r for r in self._routable() if r.id not in holders
                ]
                if not cands:
                    continue
                target = min(cands, key=self._load_jsq)
                try:
                    lid = target.sched.submit(
                        req.vol,
                        priority=req.priority_class.name,
                        mode=req.mode,
                        executor=req.executor,
                        devices=req.devices,
                        precision=req.precision,
                        arrival_s=entry.arrival_s,
                    )
                except QueueFullError:
                    continue
                self._fid[(target.id, lid)] = fid
                entry.copies[(target.id, lid)] = True
                entry.hedges += 1
                self.hedges += 1

    def _next_crash_t(self, rep: Replica) -> Optional[float]:
        for ev in self._events[self._ei:]:
            if ev.action == "crash" and ev.replica == rep.id:
                return ev.t
        return None

    def _dispatch_idle(self, now: float) -> bool:
        """Form and launch one batch on every idle replica that has
        queued work (draining replicas included — their queue is only
        non-empty when no peer could absorb it). Returns whether anything
        progressed. A batch on a replica with a scheduled crash is served
        only up to the crash instant (``run_batch_until``); the un-served
        tail waits on the replica for the crash event to evacuate it."""
        progressed = False
        for rep in sorted(self.replicas, key=lambda r: r.id):
            if not rep.live or rep.inflight or rep.busy_until > now:
                continue
            if not rep.sched.queue:
                continue
            batch = rep.sched.next_batch(now=now)
            if batch is None:
                # Either everything queued just expired (typed rejects —
                # new completions appeared) or the whole queue is gated
                # behind retry backoff (no progress possible NOW: claiming
                # progress would spin the event loop forever; the run loop
                # instead sleeps to the queue's next_ready_s).
                before = rep._synced
                self._sync(rep)
                if rep._synced != before:
                    progressed = True
                continue
            key = batch.requests[0].key
            start = now
            if key is not None and key not in rep.warm:
                # first executable of this signature on THIS replica:
                # trace+compile stall, then the jit cache is warm
                start += self.cfg.service.cold_compile_s
                self.cold_compiles += 1
                rep.warm.add(key)
            crash_t = self._next_crash_t(rep)
            t_end, unserved = rep.sched.run_batch_until(batch, crash_t, now=start)
            self._sync(rep)
            rep.inflight = True
            if unserved:
                rep.inflight_unserved = unserved
                rep.busy_until = crash_t  # doomed: dies mid-batch
            else:
                rep.busy_until = t_end
            progressed = True
        return progressed

    def _autoscale(self, t: float) -> None:
        a = self.cfg.autoscaler
        window = []
        for entry in self.ledger:
            if entry.priority != a.slo_class or entry.outcome is None:
                continue
            fin = entry.finish_s if entry.finish_s is not None else entry.arrival_s
            if t - a.interval_s < fin <= t:
                window.append(entry)
        if window:
            met = sum(
                1
                for e in window
                if e.outcome in ("completed", "demoted", "coalesced")
                and (e.finish_s - e.arrival_s) <= a.slo_latency_s
            )
            attainment = met / len(window)
        else:
            attainment = None  # idle window: no SLO pressure either way
        routable = self._routable()
        if t - self._last_scale_s < a.cooldown_s:
            return
        if (
            attainment is not None
            and attainment < a.up_attainment
            and len(routable) < a.max_replicas
        ):
            self._add_replica(t)
            self._last_scale_s = t
        elif (
            (attainment is None or attainment >= a.down_attainment)
            and sum(r.queue_len() for r in routable) == 0
            and len(routable) > a.min_replicas
        ):
            self.scale_down(t)
            self._last_scale_s = t

    def run(self, arrivals: list, vols: list) -> None:
        """The multi-server discrete-event loop: deliver arrivals through
        the router, serve batches on every idle replica in parallel
        virtual time, fire the fault plan and autoscaler ticks, retire
        drained replicas — until the trace and every queue are empty."""
        cfg = self.cfg
        auto = cfg.autoscaler
        next_tick = auto.interval_s if auto else math.inf
        i, n = 0, len(arrivals)
        now = 0.0
        while True:
            # retire drained replicas that finished their backlog
            for rep in self.replicas:
                if (
                    rep.live
                    and rep.draining
                    and not rep.inflight
                    and not rep.sched.queue
                    and rep.busy_until <= now
                ):
                    rep.retired = True
            self._maybe_hedge(now)
            if self._dispatch_idle(now):
                continue
            cand = []
            if i < n:
                cand.append(arrivals[i][0])
            for rep in self.replicas:
                if rep.live and rep.inflight:
                    cand.append(rep.busy_until)
                elif rep.live and rep.sched.queue:
                    # queue fully gated behind retry backoff: wake when
                    # the earliest not_before_s elapses
                    wake = rep.sched.next_ready_s(now)
                    if wake is not None:
                        cand.append(wake)
            if self._ei < len(self._events):
                cand.append(self._events[self._ei].t)
            if auto and next_tick <= cfg.horizon_s:
                cand.append(next_tick)
            if not cand:
                break
            now = max(now, min(cand))
            self.clock.advance_to(now)
            for rep in self.replicas:
                if rep.live and rep.inflight and rep.busy_until <= now:
                    rep.inflight = False
            while self._ei < len(self._events) and self._events[self._ei].t <= now:
                ev = self._events[self._ei]
                self._ei += 1
                if ev.action == "add":
                    self._add_replica(now)
                elif ev.action == "crash":
                    self.crash_replica(ev.replica, now)
                elif ev.action == "drain":
                    self.drain_replica(ev.replica, now)
                else:
                    raise FleetConfigError(f"unknown fleet event {ev.action!r}")
            while auto and next_tick <= now:
                self._autoscale(next_tick)
                next_tick += auto.interval_s
            while i < n and arrivals[i][0] <= now:
                t, spec = arrivals[i]
                try:
                    self.submit(
                        vols[i],
                        priority=spec.priority,
                        mode=spec.mode,
                        executor=spec.executor,
                        devices=spec.devices,
                        precision=spec.precision,
                        arrival_s=t,
                    )
                except (QueueFullError, NoReplicaAvailable):
                    pass  # counted + ledgered as typed terminal refusals
                i += 1
        for rep in self.replicas:
            self._sync(rep)
            assert rep.sched.stats.conserved(), (
                f"replica {rep.id} conservation violated: {rep.sched.stats}"
            )

    def drain(self) -> None:
        """Serve everything currently queued (no new arrivals): the
        direct-API counterpart of ``RequestScheduler.drain``."""
        self.run([], [])

    # -------------------------------------------------------------- rollups

    def conserved(self) -> bool:
        """The fleet-wide conservation law: every arrival has exactly one
        terminal outcome, per-replica ledgers balance (including
        evacuations), and nothing was served twice."""
        if any(e.outcome is None for e in self.ledger):
            return False
        if any(e.completions_seen > 1 for e in self.ledger):
            return False
        return all(r.sched.stats.conserved() for r in self.replicas)


@dataclasses.dataclass
class FleetReport:
    cfg: FleetConfig
    fleet: Fleet
    arrived: int

    def summary(self) -> dict:
        """The deterministic fleet rollup — golden-trace / BENCH payload:
        counts and conservation (fleet + per replica), fleet-wide and
        per-class virtual-latency percentiles over ORIGINAL arrival
        times (failover latency includes the time lost to the dead
        replica), router/affinity counters, and the autoscaler/fault
        timeline."""
        fl = self.fleet
        entries = fl.ledger
        served = [
            e
            for e in entries
            if e.outcome in ("completed", "demoted", "coalesced")
        ]
        rejected: dict[str, int] = {}
        for rep in fl.replicas:
            for reason, cnt in rep.sched.stats.rejected.items():
                rejected[reason] = rejected.get(reason, 0) + cnt
        classes: dict[str, dict] = {}
        by_class: dict[str, list[FleetRequest]] = {}
        for e in entries:
            by_class.setdefault(e.priority, []).append(e)
        for name in sorted(by_class):
            es = by_class[name]
            sv = [
                e
                for e in es
                if e.outcome in ("completed", "demoted", "coalesced")
            ]
            classes[name] = {
                "requests": len(es),
                "served": len(sv),
                "demoted": sum(1 for e in es if e.outcome == "demoted"),
                "rejected": sum(1 for e in es if e.outcome == "rejected"),
                "refused": sum(
                    1 for e in es if e.outcome in ("refused", "no_replica")
                ),
                "redispatched": sum(1 for e in sv if e.dispatches > 1),
                "latency_ms": _pctls_ms([e.finish_s - e.arrival_s for e in sv]),
                "queue_wait_ms": _pctls_ms(
                    [e.completion.record.queue_wait_s or 0.0 for e in sv]
                ),
            }
        per_replica = []
        for rep in sorted(fl.replicas, key=lambda r: r.id):
            st = rep.sched.stats
            row = {
                "id": rep.id,
                "admitted": st.admitted,
                "completed": st.completed,
                "demoted": st.demoted,
                "rejected": st.rejected_total(),
                "evacuated": st.evacuated,
                "refused": st.refused,
                "batches": st.batches,
                "max_queue_depth": st.max_queue_depth,
                "warm_signatures": len(rep.warm),
                "crashed": rep.crashed,
                "drained": rep.retired,
            }
            if fl.cache is not None:
                # the fifth terminal state — only stamped on cached runs
                # so pre-cache goldens stay byte-exact
                row["coalesced"] = st.coalesced
                row["cache_hits"] = st.cache_hits
            per_replica.append(row)
        total_batches = sum(r.sched.stats.batches for r in fl.replicas)
        out = {
            "scenario": self.cfg.name,
            "seed": self.cfg.seed,
            "horizon_s": _round(self.cfg.horizon_s),
            "process": self.cfg.process,
            "policy": self.cfg.policy,
            "requests": {
                "arrived": self.arrived,
                "refused": fl.refused,
                "no_replica": fl.no_replica,
                "admitted": sum(r.sched.stats.admitted for r in fl.replicas),
                "completed": sum(1 for e in entries if e.outcome == "completed"),
                "demoted": sum(1 for e in entries if e.outcome == "demoted"),
                "rejected": dict(sorted(rejected.items())),
                "evacuated": sum(r.sched.stats.evacuated for r in fl.replicas),
                "redispatched": fl.redispatched,
                "served_twice": sum(
                    1 for e in entries if e.completions_seen > 1
                ),
                "conserved": fl.conserved(),
            },
            "batches": total_batches,
            "mean_batch_size": _round(len(served) / max(total_batches, 1)),
            "max_queue_depth": max(
                (r.sched.stats.max_queue_depth for r in fl.replicas), default=0
            ),
            "throughput_rps": _round(len(served) / self.cfg.horizon_s),
            "latency_ms": _pctls_ms(
                [e.finish_s - e.arrival_s for e in served]
            ),
            "classes": classes,
            "affinity": {
                "policy": self.cfg.policy,
                "routes": fl.routes,
                "warm_hits": fl.affinity_hits,
                "hit_rate": _round(fl.affinity_hits / max(fl.routes, 1)),
                "cold_compiles": fl.cold_compiles,
            },
            "replicas": {
                "initial": self.cfg.replicas,
                "created": len(fl.replicas),
                "peak_routable": fl.peak_routable,
                "final_routable": len(fl._routable()),
                "crashed": sum(1 for r in fl.replicas if r.crashed),
                "drained": sum(1 for r in fl.replicas if r.retired),
            },
            "scale_events": fl.scale_log,
            "per_replica": per_replica,
        }
        # Resilience rollup only when the run was configured with a
        # policy or a fault plan — pre-resilience goldens stay byte-exact.
        if self.cfg.resilience is not None or self.cfg.fault_plan is not None:
            out["resilience"] = self._resilience_block(served)
        # Same discipline for the cache rollup: only cache-configured
        # runs carry it, so pre-cache fleet goldens stay byte-exact.
        if self.cfg.cache is not None:
            out["cache"] = self._cache_block(served)
        return out

    def _cache_block(self, served: list) -> dict:
        """The fleet-wide artifact-cache rollup: the shared tier's own
        counters plus the per-replica terminal cache accounting summed —
        admission hits, single-flight coalesced completions, requests
        served without a forward, and router steers to in-flight
        leaders. ``quarantined_served`` MUST stay 0 (the corrupt-bytes-
        never-served guarantee); the regression gate pins it."""
        fl = self.fleet
        out = dict(fl.cache.summary())
        out["admission_hits"] = sum(
            r.sched.stats.cache_hits for r in fl.replicas
        )
        out["coalesced"] = sum(r.sched.stats.coalesced for r in fl.replicas)
        out["served_from_cache"] = sum(
            1
            for e in served
            if e.completion is not None and e.completion.record.cache_hit
        )
        out["content_routes"] = fl.content_routes
        return out

    def _resilience_block(self, served: list) -> dict:
        fl = self.fleet
        stats = [rep.sched.stats for rep in fl.replicas]
        faulted = sum(s.faulted_requests for s in stats)
        recovered = sum(s.recovered_requests for s in stats)
        block: dict = {
            "retries": sum(s.retries for s in stats),
            "faults": {
                "transient": sum(s.transient_faults for s in stats),
                "permanent": sum(s.permanent_faults for s in stats),
                "timeout": sum(s.timeouts for s in stats),
            },
            "faulted_requests": faulted,
            "recovered_requests": recovered,
            "recovery_rate": _round(recovered / max(faulted, 1)),
            "hedges": fl.hedges,
            "hedge_wins": fl.hedge_wins,
            "hedge_cancelled": fl.hedge_cancelled,
        }
        breakers = [
            (rep.id, rep.sched.breaker)
            for rep in sorted(fl.replicas, key=lambda r: r.id)
            if rep.sched.breaker is not None
        ]
        if breakers:
            transitions = []
            for rid, br in breakers:
                for tr in br.transitions:
                    transitions.append({**tr, "replica": rid})
            transitions.sort(key=lambda tr: (tr["t"], tr["replica"]))
            block["breaker"] = {
                "trips": sum(br.trips for _, br in breakers),
                "restores": sum(br.restores for _, br in breakers),
                "probes": sum(br.probes for _, br in breakers),
                "open_signatures": sorted(
                    {s for _, br in breakers for s in br.open_signature_labels()}
                ),
                "transitions": transitions,
            }
        else:
            block["breaker"] = None
        rungs: dict[str, int] = {}
        for e in served:
            rec = e.completion.record
            label = f"{rec.mode}/{rec.executor or '-'}"
            rungs[label] = rungs.get(label, 0) + 1
        block["rungs"] = dict(sorted(rungs.items()))
        return block

    def to_json(self) -> str:
        return json.dumps(self.summary(), indent=1, sort_keys=True)


def simulate_fleet(
    cfg: FleetConfig, engine_factory: Optional[Callable] = None
) -> FleetReport:
    """Drive a fresh fleet through one seeded load trace — same arrival
    discipline as the single-server ``simulate`` (arrivals and mix drawn
    before volumes, so payloads never perturb the trace), same
    bit-reproducibility claim, N servers wide."""
    rng = np.random.default_rng(cfg.seed)
    proc = ARRIVAL_PROCESSES[cfg.process]
    times = proc(horizon_s=cfg.horizon_s, rng=rng, **cfg.process_kwargs)
    arrivals = [(t, _sample_mix(cfg.mix, rng)) for t in times]
    vols = [_make_volume(spec, rng, cfg.execute) for _, spec in arrivals]
    if cfg.content_skew is not None:
        # per-index counter-hash identities (simulator.zipf_content_id):
        # enabling skew cannot perturb the arrival/mix draws above
        for idx, ((_, spec), v) in enumerate(zip(arrivals, vols)):
            if isinstance(v, _ShapeStub) and not spec.garbage:
                v.content_id = zipf_content_id(
                    cfg.seed, idx, cfg.content_skew, cfg.content_universe
                )
    fleet = Fleet(cfg, engine_factory)
    fleet.run(arrivals, vols)
    assert fleet.conserved(), "fleet conservation violated"
    return FleetReport(cfg=cfg, fleet=fleet, arrived=len(arrivals))


# ------------------------------------------------------- scenario presets ---


def fleet_preset(
    name: str, seed: int = 0, horizon_s: Optional[float] = None
) -> FleetConfig:
    """The four committed fleet scenarios (golden traces + BENCH rows):

    ``fleet_steady``   — 3 replicas under 4x the single-server steady
                         rate: the horizontal-scale latency floor, and
                         the affinity hit-rate baseline.
    ``fleet_overload`` — the single-server killer (diurnal 12 Hz peak,
                         tight admission, short queues) on a 4-replica
                         cache-affinity fleet: the ROADMAP's ~28 s p99
                         must fall to interactive-class seconds with
                         strictly fewer queue-full refusals.
    ``fleet_failover`` — burst traffic with a replica crash in the middle
                         of the second storm: in-flight + queued work is
                         re-dispatched exactly once, zero lost requests.
    ``fleet_autoscale``— one compressed virtual day of diurnal traffic on
                         an autoscaled fleet (min 1, max 6): scale-up
                         through the morning ramp, scale-down after the
                         evening tail.
    ``fleet_faultstorm`` — the resilience acceptance scenario: a
                         4-replica fleet under a seeded fault storm
                         (≥5% transient faults everywhere, one permanent-
                         fault signature, one straggler replica, a rare
                         stuck-forever fault) served under the full
                         ResiliencePolicy: retries recover the
                         transients, timeouts reap the stuck batches,
                         the breaker demotes the poisoned signature down
                         the executor ladder, and aged requests hedge to
                         a second replica — zero lost, zero double-served.
    """
    from repro.serving.resilience import (
        BreakerConfig,
        FaultPlan,
        FaultRule,
        HedgePolicy,
        ResiliencePolicy,
        RetryPolicy,
    )
    from repro.serving.scheduler import PriorityClass
    from repro.serving.simulator import STANDARD_MIX

    overload_classes = {
        "interactive": PriorityClass("interactive", 0, deadline_s=10.0),
        "standard": PriorityClass("standard", 1, deadline_s=2.5),
        "batch": PriorityClass("batch", 2, deadline_s=30.0),
    }
    if name == "fleet_steady":
        return FleetConfig(
            name="fleet_steady",
            seed=seed,
            horizon_s=horizon_s or 600.0,
            process="poisson",
            process_kwargs={"rate_hz": 2.0},
            mix=STANDARD_MIX,
            replicas=3,
            policy="cache_affinity",
            scheduler=SchedulerConfig(
                max_queue_depth=64,
                admission_hbm_bytes=512 * 1024 * 1024,
                max_batch_requests=8,
                native_shapes=True,
            ),
        )
    if name == "fleet_overload":
        return FleetConfig(
            name="fleet_overload",
            seed=seed,
            horizon_s=horizon_s or 600.0,
            # the exact traffic + admission regime that drives the
            # committed single-server overload golden to a ~28 s p99 and
            # 693 queue-full refusals — now 4 replicas wide behind
            # cache-affinity routing (the acceptance comparison).
            process="diurnal",
            process_kwargs={"peak_hz": 12.0},
            mix=STANDARD_MIX,
            replicas=4,
            policy="cache_affinity",
            scheduler=SchedulerConfig(
                max_queue_depth=32,
                admission_hbm_bytes=1 * 1024 * 1024,
                max_batch_requests=8,
                native_shapes=True,
                classes=dict(overload_classes),
            ),
            service=FleetServiceModel(base_s=0.1, batch_overhead_s=0.05),
        )
    if name == "fleet_failover":
        return FleetConfig(
            name="fleet_failover",
            seed=seed,
            horizon_s=horizon_s or 360.0,
            process="burst",
            process_kwargs={
                "base_hz": 0.2,
                "burst_hz": 40.0,
                "period_s": 120.0,
                "burst_len_s": 15.0,
            },
            mix=STANDARD_MIX,
            replicas=3,
            policy="cache_affinity",
            scheduler=SchedulerConfig(
                max_queue_depth=64,
                admission_hbm_bytes=512 * 1024 * 1024,
                max_batch_requests=8,
                native_shapes=True,
            ),
            # slow enough that a 40 Hz storm outruns 3 replicas and
            # queues actually build before the crash
            service=FleetServiceModel(base_s=0.1, batch_overhead_s=0.05),
            # replica 1 dies in the middle of the second storm (bursts
            # cover [120, 135]): its queue is deepest exactly then, so
            # the re-dispatch path is exercised under pressure — an
            # in-flight batch truncated mid-service plus a queued backlog.
            events=(FleetEvent(t=127.0, action="crash", replica=1),),
        )
    if name == "fleet_autoscale":
        return FleetConfig(
            name="fleet_autoscale",
            seed=seed,
            horizon_s=horizon_s or 1800.0,
            # one compressed virtual day: the diurnal ramp peaks mid-
            # horizon well above one replica's capacity, then fades
            process="diurnal",
            process_kwargs={"peak_hz": 12.0},
            mix=STANDARD_MIX,
            replicas=1,
            policy="cache_affinity",
            scheduler=SchedulerConfig(
                max_queue_depth=64,
                admission_hbm_bytes=512 * 1024 * 1024,
                max_batch_requests=8,
                native_shapes=True,
            ),
            service=FleetServiceModel(base_s=0.1, batch_overhead_s=0.05),
            autoscaler=AutoscalerConfig(
                interval_s=60.0,
                min_replicas=1,
                max_replicas=6,
                slo_class="interactive",
                slo_latency_s=2.0,
                up_attainment=0.9,
                down_attainment=0.98,
                cooldown_s=120.0,
            ),
        )
    if name == "fleet_faultstorm":
        return FleetConfig(
            name="fleet_faultstorm",
            seed=seed,
            horizon_s=horizon_s or 600.0,
            process="poisson",
            process_kwargs={"rate_hz": 6.0},
            mix=STANDARD_MIX,
            replicas=4,
            policy="cache_affinity",
            scheduler=SchedulerConfig(
                max_queue_depth=64,
                admission_hbm_bytes=512 * 1024 * 1024,
                max_batch_requests=8,
                native_shapes=True,
            ),
            service=FleetServiceModel(base_s=0.1, batch_overhead_s=0.05),
            resilience=ResiliencePolicy(
                retry=RetryPolicy(
                    max_attempts=3,
                    backoff_base_s=0.1,
                    backoff_mult=2.0,
                    backoff_max_s=2.0,
                    jitter_frac=0.25,
                    seed=seed,
                ),
                service_timeout_s={
                    "interactive": 4.0,
                    "standard": 8.0,
                    "batch": 20.0,
                },
                hedge=HedgePolicy(
                    p99_factor=3.0,
                    min_age_s=1.0,
                    min_samples=30,
                    window=200,
                    max_hedges=1,
                ),
                breaker=BreakerConfig(trip_after=3, cooldown_s=120.0),
            ),
            fault_plan=FaultPlan(
                seed=seed,
                rules=(
                    # baseline transient noise everywhere (≥5%)
                    FaultRule(kind="transient", rate=0.06),
                    # one poisoned signature: xla int8w 32³ always dies
                    # until the breaker walks it down the ladder
                    FaultRule(
                        kind="permanent",
                        rate=1.0,
                        executor_substr="xla",
                        shape=(32, 32, 32),
                        precision="int8w",
                    ),
                    # replica 2 is a 6x straggler: hedging + timeouts
                    FaultRule(
                        kind="straggler",
                        rate=1.0,
                        replica=2,
                        slow_factor=6.0,
                    ),
                    # a rare stuck-forever batch member: only the
                    # per-class service timeout reaps it
                    FaultRule(kind="stuck", rate=0.004),
                ),
            ),
        )
    if name == "fleet_cached":
        from repro.serving.cache import CacheConfig

        return FleetConfig(
            name="fleet_cached",
            seed=seed,
            horizon_s=horizon_s or 600.0,
            # burst traffic IS the stampede scenario: each storm floods
            # the fleet with Zipf-hot content faster than it can serve,
            # queues build, and identical requests pile onto in-flight
            # single-flight leaders instead of running duplicate forwards
            process="burst",
            process_kwargs={
                "base_hz": 2.0,
                "burst_hz": 60.0,
                "period_s": 120.0,
                "burst_len_s": 15.0,
            },
            mix=STANDARD_MIX,
            replicas=4,
            policy="cache_affinity",
            scheduler=SchedulerConfig(
                max_queue_depth=64,
                admission_hbm_bytes=512 * 1024 * 1024,
                max_batch_requests=8,
                native_shapes=True,
            ),
            service=FleetServiceModel(base_s=0.1, batch_overhead_s=0.05),
            # the artifact-cache acceptance scenario: Zipf(1.1) content
            # skew over 64 distinct volumes makes the hot head cacheable
            # and stampede-prone; 2% of consults land on a bit-flipped
            # entry (quarantine + transparent recompute, NEVER served);
            # the tier goes dark for [240, 300) (every consult
            # unavailable -> breaker opens after 3, half-open probe at
            # +30 re-opens mid-outage, the +60 probe closes it) — all of
            # it fail-open: outage-window requests serve via compute.
            # 2 MiB capacity against a ~250-artifact working set: LRU
            # eviction runs hot (pinned in-flight entries are never
            # victims — the property tests pin that), and the Zipf head
            # survives eviction pressure because recency tracks heat
            cache=CacheConfig(
                capacity_bytes=2 * 1024 * 1024,
                breaker_trip_after=3,
                breaker_cooldown_s=30.0,
            ),
            content_skew=1.1,
            content_universe=256,
            fault_plan=FaultPlan(
                seed=seed,
                rules=(
                    FaultRule(kind="corrupt_entry", rate=0.02),
                    FaultRule(
                        kind="cache_unavailable", rate=1.0, t0=240.0, t1=300.0
                    ),
                ),
            ),
        )
    raise KeyError(
        f"unknown fleet preset {name!r}: fleet_steady | fleet_overload | "
        "fleet_failover | fleet_autoscale | fleet_faultstorm | fleet_cached"
    )


FLEET_PRESETS = (
    "fleet_steady",
    "fleet_overload",
    "fleet_failover",
    "fleet_autoscale",
    "fleet_faultstorm",
    "fleet_cached",
)
