"""Deterministic discrete-event load simulator for the serving scheduler.

The paper validates Brainchop against a fleet of 1336 heterogeneous
browser sessions; this module is the serving-tier analogue — a seeded,
virtual-clock traffic generator that drives ``RequestScheduler`` through
the load shapes a segmentation service actually sees, so every latency /
throughput / shed-rate number is **bit-reproducible in CI on CPU**:

  * arrivals come from seeded processes on a *virtual* clock —
    ``poisson`` (steady Erlang traffic), ``burst`` (a quiet baseline with
    periodic request storms), ``diurnal`` (a thinned inhomogeneous
    Poisson ramp, the clinic-hours curve);
  * each arrival samples a **scenario mix** entry (shape, precision,
    device count, priority class, deliberately-garbage volumes) from the
    same seeded generator;
  * service time is *modeled*, not measured: ``ServiceModel`` converts
    each request's modeled HBM + collective bytes (telemetry/traffic.py)
    into virtual seconds at configured bandwidths, with a per-batch
    dispatch overhead that makes grouping visible in the numbers — the
    same bytes-are-the-cost methodology as the budget model (DESIGN.md
    §1, §5);
  * the event loop is single-server: batches serve back-to-back, arrivals
    landing mid-service queue behind them, deadlines expire on the
    virtual clock. No wall-clock value enters any decision or summary.

``simulate`` returns a ``SimReport`` whose ``summary()`` dict (rounded,
key-sorted) is what the golden-trace regression tests and the gated
``serving`` rows of BENCH_2.json serialize — two runs with one seed are
byte-identical, so scheduler behavior changes show up as review diffs,
never as flakes. ``benchmarks/bench_serving.py`` is the CLI.
"""

from __future__ import annotations

import bisect
import dataclasses
import json
import math
from typing import Optional

import numpy as np

from repro.serving.scheduler import (
    Completion,
    PriorityClass,
    QueueFullError,
    RequestScheduler,
    SchedulerConfig,
)
from repro.telemetry.analysis import nearest_rank


class VirtualClock:
    """A settable clock: ``now()`` is whatever the event loop last set.
    The scheduler only ever *reads* it, so scheduling decisions are pure
    functions of event times."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def now(self) -> float:
        return self.t

    def advance_to(self, t: float) -> None:
        self.t = max(self.t, float(t))


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Virtual service time from modeled bytes — deterministic by
    construction. Bandwidths default to v5e-ish magnitudes; the absolute
    scale matters less than being *fixed*, because the gated numbers are
    compared against a committed baseline, not against hardware.

    ``service_s = base + hbm_bytes/hbm_bw + collective_bytes/ici_bw``,
    and a failed request costs ``fail_s`` (admission work, no forward).
    ``batch_overhead_s`` is charged once per dispatch group — the
    compile-cache/dispatch cost grouping amortizes.

    Under ``SchedulerConfig.batched_dispatch`` the scheduler evaluates
    ``service_s`` ONCE per dispatch group, on a single batch-N modeled
    record whose byte models amortize the weight stream across the
    batch (telemetry/traffic.py) — so the launch interval is
    sub-additive in group size and the overload throughput cliff moves.
    That amortization lives in the byte models; no formula here changes.
    """

    hbm_gbps: float = 819.0
    ici_gbps: float = 90.0
    base_s: float = 0.010
    fail_s: float = 0.002
    batch_overhead_s: float = 0.040

    def service_s(self, record) -> float:
        if record.status != "ok":
            return self.fail_s
        hbm = record.hbm_bytes_modeled or 0
        ici = record.collective_bytes_modeled or 0
        return self.base_s + hbm / (self.hbm_gbps * 1e9) + ici / (self.ici_gbps * 1e9)


# ------------------------------------------------------------- arrivals ---


def poisson_arrivals(rate_hz: float, horizon_s: float, rng: np.random.Generator):
    """Homogeneous Poisson process: exponential inter-arrival gaps."""
    t, out = 0.0, []
    while True:
        t += float(rng.exponential(1.0 / rate_hz))
        if t >= horizon_s:
            return out
        out.append(t)


def burst_arrivals(
    base_hz: float,
    burst_hz: float,
    period_s: float,
    burst_len_s: float,
    horizon_s: float,
    rng: np.random.Generator,
):
    """Quiet Poisson baseline plus periodic storms: every ``period_s`` a
    window of ``burst_len_s`` runs at ``burst_hz`` on top of the base."""
    out = list(poisson_arrivals(base_hz, horizon_s, rng))
    start = 0.0
    while start < horizon_s:
        end = min(start + burst_len_s, horizon_s)
        t = start
        while True:
            t += float(rng.exponential(1.0 / burst_hz))
            if t >= end:
                break
            out.append(t)
        start += period_s
    return sorted(out)


def diurnal_arrivals(peak_hz: float, horizon_s: float, rng: np.random.Generator):
    """Inhomogeneous Poisson by thinning: rate ramps 0 -> peak -> 0 over
    the horizon (one 'day' of clinic traffic compressed into it)."""
    out = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / peak_hz))
        if t >= horizon_s:
            return out
        accept = 0.5 * (1.0 - math.cos(2.0 * math.pi * t / horizon_s))
        if float(rng.random()) < accept:
            out.append(t)


ARRIVAL_PROCESSES = {
    "poisson": poisson_arrivals,
    "burst": burst_arrivals,
    "diurnal": diurnal_arrivals,
}


# -------------------------------------------------------- content skew ---

#: memoized Zipf CDFs keyed on (s, n) — the CDF is a pure function of
#: the distribution parameters, so sharing it across runs cannot couple
#: their draws (each draw's coin is an independent unit_hash).
_ZIPF_CDF_CACHE: dict = {}


def zipf_content_id(seed: int, index: int, s: float, n: int) -> int:
    """The ``index``-th arrival's content identity under a Zipf(s)
    popularity law over ``n`` distinct volumes — id 0 is the hottest.

    Deterministic by construction: the uniform coin is
    ``unit_hash("zipf", seed, index)`` (the counter-hash discipline of
    serving/resilience.py), NOT a shared RNG stream — so adding or
    removing OTHER randomness in a scenario cannot perturb which content
    arrives when, and two runs with one seed draw byte-identical content
    traces. Inverse-CDF over the memoized normalized Zipf weights."""
    from repro.serving.resilience import unit_hash

    key = (float(s), int(n))
    cdf = _ZIPF_CDF_CACHE.get(key)
    if cdf is None:
        weights = [1.0 / (k ** float(s)) for k in range(1, int(n) + 1)]
        total = sum(weights)
        acc, cdf = 0.0, []
        for w in weights:
            acc += w / total
            cdf.append(acc)
        _ZIPF_CDF_CACHE[key] = cdf
    u = unit_hash("zipf", seed, index)
    return min(bisect.bisect_left(cdf, u), int(n) - 1)


# ------------------------------------------------------------ scenarios ---


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One entry of the traffic mix: what an arriving request asks for.
    ``weight`` is its sampling probability mass; ``garbage=True`` ships a
    malformed volume (the fault-injection lane — must fail typed, alone)."""

    shape: tuple = (16, 16, 16)
    mode: Optional[str] = None
    executor: Optional[str] = None
    devices: Optional[int] = None
    precision: Optional[str] = None
    priority: str = "standard"
    weight: float = 1.0
    garbage: bool = False


@dataclasses.dataclass
class SimConfig:
    """One simulator run: seeded arrivals over a scenario mix, through a
    scheduler configured for the experiment."""

    name: str = "steady"
    seed: int = 0
    horizon_s: float = 600.0
    process: str = "poisson"
    process_kwargs: dict = dataclasses.field(default_factory=lambda: {"rate_hz": 0.5})
    mix: tuple = (ScenarioSpec(),)
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    execute: bool = False
    service: ServiceModel = dataclasses.field(default_factory=ServiceModel)
    # resilience policy + seeded fault injection (serving/resilience.py);
    # both None keeps the PR 5 behavior — and the committed golden
    # traces — bit-for-bit unchanged.
    resilience: Optional[object] = None
    fault_plan: Optional[object] = None
    # content-addressed artifact cache (serving/cache.py): a CacheConfig
    # here puts the cache tier in front of admission; None (default)
    # keeps every pre-cache scenario — and its golden trace — untouched.
    cache: Optional[object] = None
    # Zipf popularity skew over request *content*: ``content_skew`` is
    # the Zipf exponent s (None disables content identity entirely) and
    # ``content_universe`` the number of distinct volumes. Only modeled
    # (stub) volumes get identities — the skew machinery is a cache
    # workload generator, not an MRI synthesizer.
    content_skew: Optional[float] = None
    content_universe: int = 64


@dataclasses.dataclass
class SimReport:
    cfg: SimConfig
    scheduler: RequestScheduler
    completions: list
    arrived: int
    refused: int

    def summary(self) -> dict:
        """The deterministic rollup: counts, conservation, and per-class
        virtual-latency percentiles (nearest-rank; rounded to fixed
        decimals so serialization is byte-stable). This dict IS the
        golden-trace / BENCH_2.json payload."""
        st = self.scheduler.stats
        by_class: dict[str, list[Completion]] = {}
        for c in self.completions:
            by_class.setdefault(c.record.priority_class or "?", []).append(c)
        classes = {}
        for name in sorted(by_class):
            cs = by_class[name]
            served = [
                c
                for c in cs
                if c.outcome in ("completed", "demoted", "coalesced")
            ]
            e2e = [c.finish_s - c.arrival_s for c in served]
            wait = [c.record.queue_wait_s or 0.0 for c in served]
            classes[name] = {
                "requests": len(cs),
                "served": len(served),
                "demoted": sum(1 for c in cs if c.outcome == "demoted"),
                "rejected": sum(1 for c in cs if c.outcome == "rejected"),
                "ok_rate": _round(
                    sum(1 for c in served if c.record.status == "ok")
                    / max(len(served), 1)
                ),
                "latency_ms": _pctls_ms(e2e),
                "queue_wait_ms": _pctls_ms(wait),
            }
        served_all = [
            c
            for c in self.completions
            if c.outcome in ("completed", "demoted", "coalesced")
        ]
        out = {
            "scenario": self.cfg.name,
            "seed": self.cfg.seed,
            "horizon_s": _round(self.cfg.horizon_s),
            "process": self.cfg.process,
            "requests": {
                "arrived": self.arrived,
                "refused": self.refused,
                "admitted": st.admitted,
                "completed": st.completed,
                "demoted": st.demoted,
                "rejected": dict(sorted(st.rejected.items())),
                "conserved": st.conserved(),
            },
            "batches": st.batches,
            "mean_batch_size": _round(len(served_all) / max(st.batches, 1)),
            "max_queue_depth": st.max_queue_depth,
            "throughput_rps": _round(len(served_all) / self.cfg.horizon_s),
            "latency_ms": _pctls_ms([c.finish_s - c.arrival_s for c in served_all]),
            "classes": classes,
        }
        if self.cfg.resilience is not None or self.cfg.fault_plan is not None:
            # only stamped when the resilience layer is configured, so
            # the PR 5 golden summaries stay byte-identical
            out["resilience"] = resilience_block(self.scheduler, served_all)
        if self.cfg.cache is not None:
            # same discipline: the cache rollup exists only for cache
            # scenarios, so pre-cache goldens stay byte-identical
            out["cache"] = cache_block(self.scheduler, served_all)
        return out

    def to_json(self) -> str:
        return json.dumps(self.summary(), indent=1, sort_keys=True)


def _round(x: float, nd: int = 4) -> float:
    return round(float(x), nd)


def _pctls_ms(values) -> dict:
    ms = [v * 1e3 for v in values]
    return {
        "p50": _round(nearest_rank(ms, 50)),
        "p99": _round(nearest_rank(ms, 99)),
        "mean": _round(sum(ms) / len(ms) if ms else 0.0),
        "max": _round(max(ms) if ms else 0.0),
    }


def resilience_block(sched, served) -> dict:
    """The deterministic resilience rollup of ONE scheduler — retry /
    fault / recovery counters, breaker state machine history, and
    per-rung serve counts (which executor rung actually answered each
    served request — the degradation ladder made visible). Shared by the
    single-server summary here and the per-replica aggregation in
    serving/fleet.py."""
    st = sched.stats
    rungs: dict[str, int] = {}
    for c in served:
        label = f"{c.record.mode}/{c.record.executor or '-'}"
        rungs[label] = rungs.get(label, 0) + 1
    br = sched.breaker
    return {
        "retries": st.retries,
        "faults": {
            "transient": st.transient_faults,
            "permanent": st.permanent_faults,
            "timeout": st.timeouts,
        },
        "faulted_requests": st.faulted_requests,
        "recovered_requests": st.recovered_requests,
        "recovery_rate": _round(
            st.recovered_requests / max(st.faulted_requests, 1)
        ),
        "breaker": None
        if br is None
        else {
            "trips": br.trips,
            "restores": br.restores,
            "probes": br.probes,
            "open_signatures": br.open_signature_labels(),
            "transitions": br.transitions,
        },
        "rungs": dict(sorted(rungs.items())),
    }


def cache_block(sched, served) -> dict:
    """The deterministic artifact-cache rollup of ONE scheduler: the
    cache tier's own counters (hits, quarantines, breaker trips, bytes)
    plus the scheduler's terminal cache accounting — admission-time
    hits, single-flight coalesced completions, and how many served
    requests never touched a device. Shared by the single-server
    summary and the fleet aggregation (serving/fleet.py)."""
    st = sched.stats
    out = dict(sched.cache.summary()) if sched.cache is not None else {}
    out["admission_hits"] = st.cache_hits
    out["coalesced"] = st.coalesced
    out["served_from_cache"] = sum(1 for c in served if c.record.cache_hit)
    return out


def _sample_mix(mix, rng: np.random.Generator) -> ScenarioSpec:
    weights = np.array([s.weight for s in mix], dtype=np.float64)
    idx = int(rng.choice(len(mix), p=weights / weights.sum()))
    return mix[idx]


class _ShapeStub:
    """What an ``execute=False`` request carries instead of voxels: the
    modeled path only ever reads ``.shape``, so a 21k-arrival soak must
    not allocate gigabytes of random volumes nobody reads.

    ``content_id`` is the stub's content identity for the artifact cache
    (serving/cache.py): two stubs with equal (shape, content_id) hash to
    the same content — the modeled stand-in for byte-equal volumes.
    ``None`` (the default, and every pre-cache scenario) means "no
    content identity": the cache consult bypasses, so legacy traces are
    untouched."""

    __slots__ = ("shape", "content_id")

    def __init__(self, shape, content_id=None):
        self.shape = tuple(shape)
        self.content_id = content_id


def _make_volume(spec: ScenarioSpec, rng: np.random.Generator, execute: bool):
    """A cheap deterministic volume (numpy, not MRI synthesis — the
    simulator load-tests the scheduler, not the segmenter); a shape-only
    stub when nothing will execute. Garbage specs ship a 1-D payload the
    pipeline cannot conform — the typed-failure lane."""
    if spec.garbage:
        return np.zeros((3,), np.float32) if execute else _ShapeStub((3,))
    if not execute:
        return _ShapeStub(spec.shape)
    return rng.random(spec.shape, dtype=np.float32)


def simulate(engine, cfg: SimConfig) -> SimReport:
    """Drive ``engine`` through one simulated load trace. Single-server
    discrete-event loop: deliver arrivals up to the clock, dispatch the
    next admission group, advance the clock by its modeled service, shed
    whatever expired meanwhile — until both the trace and the queue are
    empty."""
    rng = np.random.default_rng(cfg.seed)
    proc = ARRIVAL_PROCESSES[cfg.process]
    times = proc(horizon_s=cfg.horizon_s, rng=rng, **cfg.process_kwargs)
    arrivals = [(t, _sample_mix(cfg.mix, rng)) for t in times]
    # volumes drawn AFTER the full arrival/mix sequence so request payloads
    # never perturb arrival sampling (keeps traces comparable across mixes
    # and between execute modes — stubs simply skip the unread draws)
    vols = [_make_volume(spec, rng, cfg.execute) for _, spec in arrivals]
    if cfg.content_skew is not None:
        # content identities are per-index counter-hash draws (NOT the
        # shared rng), so enabling skew cannot perturb the arrival or
        # mix sequences above; garbage volumes stay identity-less
        for idx, ((_, spec), v) in enumerate(zip(arrivals, vols)):
            if isinstance(v, _ShapeStub) and not spec.garbage:
                v.content_id = zipf_content_id(
                    cfg.seed, idx, cfg.content_skew, cfg.content_universe
                )

    cache = None
    if cfg.cache is not None:
        from repro.serving.cache import ArtifactCache, CacheConfig

        cache = (
            cfg.cache
            if isinstance(cfg.cache, ArtifactCache)
            else ArtifactCache(
                cfg.cache if isinstance(cfg.cache, CacheConfig) else None,
                fault_plan=cfg.fault_plan,
            )
        )
    clock = VirtualClock()
    sched = RequestScheduler(
        engine,
        cfg.scheduler,
        clock=clock,
        service_model=cfg.service,
        execute=cfg.execute,
        resilience=cfg.resilience,
        fault_plan=cfg.fault_plan,
        cache=cache,
    )
    i = 0
    refused = 0
    n = len(arrivals)
    while i < n or sched.has_work():
        if not sched.has_work():
            # idle: jump to the next arrival
            clock.advance_to(arrivals[i][0])
        # deliver everything that has arrived by now
        while i < n and arrivals[i][0] <= clock.now():
            t, spec = arrivals[i]
            try:
                sched.submit(
                    vols[i],
                    priority=spec.priority,
                    mode=spec.mode,
                    executor=spec.executor,
                    devices=spec.devices,
                    precision=spec.precision,
                    arrival_s=t,
                )
            except QueueFullError:
                refused += 1
            i += 1
        batch = sched.next_batch(now=clock.now())
        if batch is None:
            wake = sched.next_ready_s(clock.now())
            if wake is not None:
                # every queued request is in retry backoff: advance to
                # whichever comes first — the next arrival or the
                # earliest backoff expiry (the virtual clock must jump;
                # it cannot busy-wait)
                if i < n and arrivals[i][0] < wake:
                    clock.advance_to(arrivals[i][0])
                else:
                    clock.advance_to(wake)
            continue  # else: everything queued just expired; next arrival
        finish = sched.run_batch(batch)
        clock.advance_to(finish)
    completions = sorted(sched.completions, key=lambda c: c.id)
    assert sched.stats.conserved(), f"conservation violated: {sched.stats}"
    return SimReport(
        cfg=cfg, scheduler=sched, completions=completions, arrived=n, refused=refused
    )


def reference_engine():
    """The canonical engine the committed traces are generated against:
    a tiny CPU-friendly configuration (the simulator load-tests the
    scheduler, not the kernels). Used by benchmarks/bench_serving.py,
    the golden-trace tests, and the CI soak — all three MUST price
    admission off the same model or the byte-identical claim breaks."""
    import jax

    from repro.core import meshnet
    from repro.core.meshnet import MeshNetConfig
    from repro.core.pipeline import PipelineConfig
    from repro.serving.engine import SegmentationEngine

    cfg = MeshNetConfig()
    params = meshnet.init(jax.random.PRNGKey(0), cfg)
    pc = PipelineConfig(
        model=cfg,
        volume_shape=(16, 16, 16),
        cube=8,
        overlap=4,
        min_component_size=4,
        executor="xla",
    )
    return SegmentationEngine(params, pc)


# ------------------------------------------------------- scenario presets ---

#: heterogeneous mix exercised by every preset (single-server AND fleet,
#: serving/fleet.py): two shapes, two storage policies, all three
#: priority classes, and a garbage lane.
STANDARD_MIX = (
    ScenarioSpec(shape=(16, 16, 16), priority="interactive", weight=3.0),
    ScenarioSpec(shape=(16, 16, 16), precision="bf16", priority="standard", weight=3.0),
    ScenarioSpec(shape=(32, 32, 32), precision="int8w", priority="standard", weight=2.0),
    # the fp32 heavyweight lane: ~1.7 MiB streaming working set — the one
    # the overload preset's 1 MiB admission budget demotes to the failsafe
    ScenarioSpec(shape=(32, 32, 32), priority="standard", weight=1.0),
    ScenarioSpec(shape=(32, 32, 32), mode="subvolume", priority="batch", weight=1.5),
    ScenarioSpec(shape=(16, 16, 16), garbage=True, priority="standard", weight=0.5),
)


def preset(name: str, seed: int = 0, horizon_s: Optional[float] = None) -> SimConfig:
    """The three committed load scenarios (golden traces + BENCH rows):

    ``steady``   — Poisson arrivals well under capacity: the queue stays
                   shallow, nothing sheds; the latency floor.
    ``burst``    — quiet baseline with 20x request storms: queues spike,
                   deadlines hold, grouping absorbs most of it.
    ``overload`` — sustained arrivals beyond service capacity into a
                   short queue with a tight admission budget: the
                   scheduler must shed via typed rejection + demotion,
                   and conservation must still hold (zero lost requests).

    Any preset also exists in a ``<name>_batched`` variant: the same
    trace, same seed, same service model, with
    ``SchedulerConfig.batched_dispatch=True`` — each dispatch group
    serves as ONE batched launch whose weight stream amortizes across
    the members. Comparing ``overload`` vs ``overload_batched`` on one
    seed isolates the batching win (BENCH's ``batched`` section).
    """
    if name.endswith("_batched"):
        cfg = preset(name[: -len("_batched")], seed=seed, horizon_s=horizon_s)
        cfg.name = name
        cfg.scheduler = dataclasses.replace(cfg.scheduler, batched_dispatch=True)
        return cfg
    if name == "steady":
        return SimConfig(
            name="steady",
            seed=seed,
            horizon_s=horizon_s or 600.0,
            process="poisson",
            process_kwargs={"rate_hz": 0.5},
            mix=STANDARD_MIX,
            scheduler=SchedulerConfig(
                max_queue_depth=64,
                admission_hbm_bytes=512 * 1024 * 1024,
                max_batch_requests=8,
                native_shapes=True,
            ),
        )
    if name == "burst":
        return SimConfig(
            name="burst",
            seed=seed,
            horizon_s=horizon_s or 600.0,
            process="burst",
            process_kwargs={
                "base_hz": 0.2,
                "burst_hz": 20.0,
                "period_s": 120.0,
                "burst_len_s": 15.0,
            },
            mix=STANDARD_MIX,
            scheduler=SchedulerConfig(
                max_queue_depth=64,
                admission_hbm_bytes=512 * 1024 * 1024,
                max_batch_requests=8,
                native_shapes=True,
            ),
        )
    if name == "overload":
        return SimConfig(
            name="overload",
            seed=seed,
            horizon_s=horizon_s or 600.0,
            # the diurnal ramp's midday peak runs well past service
            # capacity (slower service model below), so the scheduler MUST
            # shed: queue-full refusals, expired deadlines, and sub-volume
            # demotions — with conservation still exact.
            process="diurnal",
            process_kwargs={"peak_hz": 12.0},
            mix=STANDARD_MIX,
            scheduler=SchedulerConfig(
                max_queue_depth=32,
                # tight: a 32^3 fp32 streaming working set (~1.7 MiB) does
                # not fit -> those requests demote to the failsafe
                admission_hbm_bytes=1 * 1024 * 1024,
                max_batch_requests=8,
                native_shapes=True,
                # tighter deadlines than the default ladder: midday queue
                # waits overrun them, so expiry shedding is exercised too
                # (interactive stays protected by priority; standard sheds
                # its tail; batch trades starvation for a staleness bound)
                classes={
                    "interactive": PriorityClass("interactive", 0, deadline_s=10.0),
                    "standard": PriorityClass("standard", 1, deadline_s=2.5),
                    "batch": PriorityClass("batch", 2, deadline_s=30.0),
                },
            ),
            service=ServiceModel(base_s=0.1, batch_overhead_s=0.05),
        )
    raise KeyError(f"unknown scenario preset {name!r}: steady | burst | overload")


PRESETS = ("steady", "burst", "overload")
