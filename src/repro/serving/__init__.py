"""Serving tier: engines (engine.py), the continuous-batching request
scheduler (scheduler.py), and the deterministic load simulator
(simulator.py). DESIGN.md §5."""

from repro.serving.scheduler import (  # noqa: F401
    DEFAULT_CLASSES,
    PriorityClass,
    QueueFullError,
    RequestScheduler,
    SchedulerConfig,
)
from repro.serving.simulator import (  # noqa: F401
    ScenarioSpec,
    ServiceModel,
    SimConfig,
    VirtualClock,
    preset,
    simulate,
)
