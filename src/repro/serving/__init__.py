"""Serving tier: engines (engine.py), the continuous-batching request
scheduler (scheduler.py), the deterministic load simulator
(simulator.py), and the replicated fleet behind a cache-affinity router
(fleet.py). DESIGN.md §5-§6."""

from repro.serving.fleet import (  # noqa: F401
    FLEET_PRESETS,
    ROUTER_POLICIES,
    AutoscalerConfig,
    Fleet,
    FleetConfig,
    FleetConfigError,
    FleetEvent,
    FleetServiceModel,
    NoReplicaAvailable,
    fleet_preset,
    simulate_fleet,
)
from repro.serving.scheduler import (  # noqa: F401
    DEFAULT_CLASSES,
    PriorityClass,
    QueueFullError,
    RequestScheduler,
    SchedulerConfig,
)
from repro.serving.simulator import (  # noqa: F401
    ScenarioSpec,
    ServiceModel,
    SimConfig,
    VirtualClock,
    preset,
    simulate,
)
