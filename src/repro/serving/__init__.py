"""Serving tier: engines (engine.py), the continuous-batching request
scheduler (scheduler.py), the deterministic load simulator
(simulator.py), the replicated fleet behind a cache-affinity router
(fleet.py), the resilience layer — typed faults, retry/backoff,
timeouts, hedging, and the executor degradation ladder (errors.py,
resilience.py) — and the content-addressed artifact cache with
integrity quarantine, single-flight coalescing, and a fail-open
breaker (cache.py). DESIGN.md §5-§8."""

from repro.serving.cache import (  # noqa: F401
    ArtifactCache,
    CacheConfig,
    CacheStats,
    ConformMemo,
    artifact_key,
    content_hash,
)
from repro.serving.errors import (  # noqa: F401
    EXECUTION_FAULT_TYPES,
    PERMANENT_FAULT,
    RETRYABLE_FAIL_TYPES,
    SERVICE_TIMEOUT,
    TRANSIENT_FAULT,
    CacheCorruptionError,
    CacheFault,
    CacheUnavailableError,
    ExecutorFault,
    FleetConfigError,
    NoReplicaAvailable,
    PermanentExecutorError,
    QueueFullError,
    ResilienceConfigError,
    ServingError,
    TransientExecutorError,
    classify,
)
from repro.serving.fleet import (  # noqa: F401
    FLEET_PRESETS,
    ROUTER_POLICIES,
    AutoscalerConfig,
    Fleet,
    FleetConfig,
    FleetEvent,
    FleetServiceModel,
    fleet_preset,
    simulate_fleet,
)
from repro.serving.resilience import (  # noqa: F401
    FAULT_KINDS,
    LADDER,
    BreakerConfig,
    FaultPlan,
    FaultRule,
    HedgePolicy,
    ResiliencePolicy,
    RetryPolicy,
    SignatureBreaker,
    demote_rung,
)
from repro.serving.scheduler import (  # noqa: F401
    DEFAULT_CLASSES,
    PriorityClass,
    RequestScheduler,
    SchedulerConfig,
)
from repro.serving.simulator import (  # noqa: F401
    ScenarioSpec,
    ServiceModel,
    SimConfig,
    VirtualClock,
    preset,
    simulate,
)
