"""Integrity-verified content-addressed artifact cache for the serving tier.

At the ROADMAP's millions-of-users scale, segmentation traffic is heavily
redundant: retries and hedges re-submit the same scan, model sweeps run
one atlas volume through every precision, and shared reference volumes
arrive from thousands of clients. element-zstack/BossDB is the volumetric
content-store pattern and CHIPS (PAPERS.md, arXiv:1710.00734) the cloud-
service version; this module builds that tier natively on the PR 5-7
deterministic serving stack, with robustness as the structure rather than
an afterthought:

  * **Content-addressed keys** — an artifact is keyed by
    ``blake2b(conformed volume bytes) + model fingerprint + precision +
    mode``: two byte-identical volumes served under the same model card,
    storage policy, and inference mode MUST produce the same
    segmentation, so the second one never touches a device. The key
    derivation is pure (`content_hash`/`artifact_key`), so hit rates are
    a function of (code, seed) like every other serving number.
  * **Integrity re-verification on every hit** — the stored artifact's
    checksum is recomputed *at serve time* and compared against the
    checksum recorded at store time. A mismatch (bit rot, a torn write,
    an injected ``corrupt_entry`` fault) quarantines the entry — evicted,
    counted in ``stats.quarantined`` — and the request transparently
    recomputes. Corrupt bytes can NEVER reach a completion:
    ``stats.quarantined_served`` counts serves of unverified bytes and is
    guarded to stay 0 by tests and the BENCH gate.
  * **Single-flight stampede collapsing** — a miss registers an in-flight
    *pinned* placeholder; concurrent identical requests on the same
    replica attach to it as followers and complete with the leader's
    artifact (scheduler outcome ``coalesced``; conservation extends to
    ``admitted == completed + demoted + rejected + evacuated +
    coalesced``). N identical concurrent requests cost exactly ONE
    device execution.
  * **Negative caching** — a permanent-fault result is cached with a TTL
    so a poisoned signature does not re-burn retry budgets on every
    arrival; the verdict expires and is re-tested.
  * **Byte-accounted LRU** — capacity is a ``telemetry/budget.py``
    ``MemoryBudget``; every entry is charged its modeled artifact bytes
    (one label byte per voxel plus metadata), eviction walks
    least-recently-used first and may NEVER evict a pinned in-flight
    entry (the leader's store must land).
  * **Fail-open degradation** — an unavailable or slow tier (injected
    ``cache_unavailable`` / ``slow_cache`` faults, same counter-hash
    discipline as PR 7's FaultPlan) degrades to the compute path: every
    request still serves, conservation holds, and a consecutive-failure
    breaker stops consulting a persistently faulty tier until a cooldown
    probe finds it healthy.

Consulted at admission by ``serving/scheduler.py`` (a hit completes in
O(hash) and is stamped ``cache_hit`` in telemetry) and shared fleet-wide
by ``serving/fleet.py`` (one tier in front of routing; identical content
routes to the in-flight leader's replica so stampedes collapse).
DESIGN.md §8; golden: tests/golden/fleet_cached.json.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Any, Optional

from repro.serving.errors import (
    CacheCorruptionError,
    CacheUnavailableError,  # noqa: F401  (re-exported: the taxonomy pair)
    PERMANENT_FAULT,
)
from repro.telemetry.budget import MemoryBudget

#: artifact metadata overhead modeled per entry, on top of the label body.
_META_OVERHEAD_BYTES = 256


# ---------------------------------------------------------- key derivation ---


def content_hash(vol) -> Optional[str]:
    """The content identity of a volume, or None when it has none.

    Real arrays hash their bytes (plus shape/dtype, so a reshaped view
    cannot alias a different geometry). The load simulator's shape stubs
    carry an explicit ``content_id`` token instead of bytes — the Zipf
    content-skew process assigns them — and hash (shape, token). A stub
    with no token is uncacheable: returning None makes the cache bypass
    it rather than invent an identity that would alias every request of
    one shape onto one artifact."""
    shape = getattr(vol, "shape", None)
    if shape is None:
        return None
    token = getattr(vol, "content_id", None)
    if token is not None:
        payload = repr(("stub", tuple(shape), token)).encode("utf-8")
        return hashlib.blake2b(payload, digest_size=16).hexdigest()
    tobytes = getattr(vol, "tobytes", None)
    if tobytes is None:
        return None
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((tuple(shape), str(getattr(vol, "dtype", "?")))).encode())
    h.update(tobytes())
    return h.hexdigest()


def model_fingerprint(model_cfg) -> str:
    """Deterministic fingerprint of a model card: the cache must never
    serve one model's segmentation for another's request, so the whole
    architecture config is in the key."""
    payload = repr(model_cfg).encode("utf-8")
    return hashlib.blake2b(payload, digest_size=16).hexdigest()


def artifact_key(content: str, model_fp: str, precision: str, mode: str) -> str:
    """The full cache key: content + model + precision + mode. Precision
    and mode are in the key because they change the *artifact* (an int8w
    subvolume segmentation is not the fp32 full-volume one), not just
    the cost of producing it."""
    payload = "|".join((content, model_fp, precision, mode)).encode("utf-8")
    return hashlib.blake2b(payload, digest_size=16).hexdigest()


def artifact_bytes_modeled(shape) -> int:
    """Modeled stored size of one segmentation artifact: one label byte
    per voxel plus serialized metadata — the byte account LRU eviction
    charges against the cache's MemoryBudget."""
    return int(math.prod(tuple(shape)[:3])) + _META_OVERHEAD_BYTES


# ------------------------------------------------------------ configuration ---


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Artifact-cache policy knobs.

    ``capacity_bytes`` feeds a ``MemoryBudget`` (telemetry/budget.py) —
    the byte account every store charges and every eviction credits.
    ``verify_s`` is the modeled O(hash) cost of a lookup + integrity
    re-verification on the virtual clock (what a hit's ``service_s``
    records; a ``slow_cache`` fault multiplies it). ``negative_ttl_s``
    bounds how long a cached permanent-fault verdict suppresses
    recomputation. ``breaker_trip_after`` consecutive unavailable
    consults stop the tier being consulted for ``breaker_cooldown_s``."""

    capacity_bytes: int = 64 * 1024 * 1024
    negative_ttl_s: float = 120.0
    verify_s: float = 0.0005
    breaker_trip_after: int = 3
    breaker_cooldown_s: float = 60.0


@dataclasses.dataclass
class CacheStats:
    """The cache's observable ledger — every counter the golden traces,
    ``telemetry/analysis.cache_summary``, and the BENCH gate pin."""

    lookups: int = 0
    hits: int = 0
    misses: int = 0
    inflight_hits: int = 0  # lookups answered "attach to the leader"
    negative_hits: int = 0
    stores: int = 0
    store_skips: int = 0  # stores dropped (tier down / nothing evictable)
    negative_stores: int = 0
    evictions: int = 0
    quarantined: int = 0  # corrupt entries caught by verification
    quarantined_served: int = 0  # corrupt bytes SERVED — must stay 0
    unavailable: int = 0  # consults lost to an unavailable tier
    slow_consults: int = 0
    breaker_trips: int = 0
    breaker_skips: int = 0  # consults skipped while the breaker is open
    bytes_stored: int = 0  # current byte account
    bytes_evicted: int = 0

    def hit_rate(self) -> float:
        consults = self.hits + self.misses + self.inflight_hits
        return (self.hits + self.inflight_hits) / max(consults, 1)


@dataclasses.dataclass
class _Entry:
    """One stored artifact (or negative verdict, or in-flight placeholder)."""

    key: str
    artifact: bytes
    checksum: str
    nbytes: int
    stored_s: float
    last_used_s: float
    meta: dict = dataclasses.field(default_factory=dict)
    result: Any = None  # in-memory PipelineResult for execute-mode hits
    pending: bool = False  # in-flight placeholder: pinned, not servable
    negative: bool = False
    fail_type: Optional[str] = None
    expires_s: float = math.inf


@dataclasses.dataclass(frozen=True)
class Lookup:
    """One consult's verdict. ``status``:

    ``hit``          — verified artifact in ``entry``; serve in O(hash).
    ``negative``     — cached permanent-fault verdict (non-expired).
    ``inflight``     — a leader owns this key; ``owner`` is its replica
                       (attach as a follower when it is the caller's).
    ``miss``         — compute; the caller may ``begin`` a leader entry.
    ``unavailable``  — the tier did not answer: fail open to compute.
    ``bypass``       — the cache breaker is open: fail open to compute.

    ``slow_factor`` scales the modeled verify cost under a ``slow_cache``
    fault (latency degradation, never correctness)."""

    status: str
    entry: Optional[_Entry] = None
    owner: Optional[int] = None
    slow_factor: float = 1.0


class _CacheBreaker:
    """Consecutive-unavailability breaker for the cache tier itself: a
    persistently faulty tier must not tax every request with a doomed
    consult. ``trip_after`` consecutive unavailable answers open it;
    after ``cooldown_s`` the next consult probes the tier and a healthy
    answer closes it. One breaker per cache — the tier is shared, so
    its health is too."""

    def __init__(self, trip_after: int, cooldown_s: float):
        self.trip_after = trip_after
        self.cooldown_s = cooldown_s
        self.consec = 0
        self.open = False
        self.opened_s = 0.0
        self.trips = 0

    def allow(self, now: float) -> bool:
        if not self.open:
            return True
        return now - self.opened_s >= self.cooldown_s  # half-open probe

    def on_unavailable(self, now: float) -> None:
        self.consec += 1
        if self.open:
            self.opened_s = now  # failed probe: fresh cooldown
            return
        if self.consec >= self.trip_after:
            self.open = True
            self.opened_s = now
            self.trips += 1

    def on_ok(self) -> None:
        self.consec = 0
        self.open = False


# ------------------------------------------------------------ the cache ---


class ArtifactCache:
    """The shared content-addressed artifact tier. One instance serves
    one scheduler or (via ``serving/fleet.py``) a whole fleet — the
    instance IS the shared tier.

    All state transitions are pure in (calls, fault plan, seed): the
    injected fault decisions come from ``FaultPlan.decide_cache`` (a
    counter-hash, no RNG), timestamps come from the caller's virtual
    clock, and LRU order is tracked with explicit floats — so any
    scenario over this cache is byte-reproducible from (code, seed)."""

    def __init__(
        self,
        cfg: Optional[CacheConfig] = None,
        *,
        budget: Optional[MemoryBudget] = None,
        fault_plan=None,
    ):
        self.cfg = cfg or CacheConfig()
        self.budget = budget or MemoryBudget(
            bytes_limit=self.cfg.capacity_bytes, name="artifact_cache"
        )
        self.fault_plan = fault_plan
        self.entries: dict[str, _Entry] = {}
        self.inflight: dict[str, int] = {}  # key -> leader replica id
        self.stats = CacheStats()
        self.breaker = _CacheBreaker(
            self.cfg.breaker_trip_after, self.cfg.breaker_cooldown_s
        )

    # ---------------------------------------------------------- fault plumbing

    def _decide(self, op: str, *, now, replica, request_id, group_key):
        if self.fault_plan is None:
            return None
        decide = getattr(self.fault_plan, "decide_cache", None)
        if decide is None:
            return None
        return decide(
            t=now, replica=replica, key=group_key, request_id=request_id, op=op
        )

    # ------------------------------------------------------------- integrity

    @staticmethod
    def _checksum(artifact: bytes) -> str:
        return hashlib.blake2b(artifact, digest_size=16).hexdigest()

    @staticmethod
    def _corrupt(entry: _Entry) -> None:
        """Flip one byte of the stored artifact (deterministic position)
        — the injected bit-rot a ``corrupt_entry`` fault models. The
        verification path must catch this; nothing else may."""
        if not entry.artifact:
            return
        pos = entry.nbytes % len(entry.artifact)
        flipped = bytearray(entry.artifact)
        flipped[pos] ^= 0xFF
        entry.artifact = bytes(flipped)

    def _verified(self, entry: _Entry) -> bool:
        return self._checksum(entry.artifact) == entry.checksum

    def _quarantine(self, entry: _Entry) -> None:
        """Remove a corrupt entry from service: evicted, counted, and
        its bytes credited back. The caller recomputes transparently."""
        self.entries.pop(entry.key, None)
        self.stats.quarantined += 1
        self.stats.bytes_stored -= entry.nbytes

    def serve_payload(self, entry: _Entry) -> dict:
        """The artifact's metadata payload for synthesizing a hit record
        — re-verified AT SERVE TIME as a second independent guard: if
        corrupt bytes ever got this far, ``quarantined_served`` counts
        the breach, the entry is quarantined (so the store is clean when
        the caller's breach path recomputes as a fresh miss, and no
        other lookup can keep hitting the corrupt bytes), and a typed
        error aborts the serve. The counter is pinned to 0 by tests and
        the BENCH gate."""
        if not self._verified(entry):
            self.stats.quarantined_served += 1
            self._quarantine(entry)
            raise CacheCorruptionError(
                entry.key, entry.checksum, self._checksum(entry.artifact)
            )
        return json.loads(entry.artifact.decode("utf-8"))

    # --------------------------------------------------------------- consult

    def lookup(
        self,
        key: str,
        *,
        now: float,
        replica: int = 0,
        request_id: int = 0,
        group_key=None,
    ) -> Lookup:
        """One admission-time consult. Never raises: every fault answer
        is a typed ``Lookup`` status the caller degrades on fail-open."""
        slow = 1.0
        # breaker first: an open breaker means the tier is NOT consulted,
        # so no fault decision (which models a consult's outcome) is even
        # drawn — "stop consulting a persistently faulty tier" is literal.
        # decide_cache is a pure counter-hash, so skipping a draw cannot
        # perturb any other decision.
        if not self.breaker.allow(now):
            self.stats.breaker_skips += 1
            return Lookup(status="bypass")
        decision = self._decide(
            "lookup",
            now=now,
            replica=replica,
            request_id=request_id,
            group_key=group_key,
        )
        if decision is not None and decision.kind == "cache_unavailable":
            self.stats.unavailable += 1
            self.breaker.on_unavailable(now)
            return Lookup(status="unavailable")
        self.breaker.on_ok()
        if decision is not None and decision.kind == "slow_cache":
            slow = decision.slow_factor
            self.stats.slow_consults += 1
        self.stats.lookups += 1
        entry = self.entries.get(key)
        if entry is not None and not entry.pending:
            if entry.negative:
                if now < entry.expires_s:
                    entry.last_used_s = now
                    self.stats.negative_hits += 1
                    return Lookup(
                        status="negative", entry=entry, slow_factor=slow
                    )
                # verdict expired: drop it and re-test via compute
                self.entries.pop(key, None)
                self.stats.bytes_stored -= entry.nbytes
                entry = None
            else:
                if decision is not None and decision.kind == "corrupt_entry":
                    self._corrupt(entry)
                if self._verified(entry):
                    entry.last_used_s = now
                    self.stats.hits += 1
                    return Lookup(status="hit", entry=entry, slow_factor=slow)
                # integrity breach: quarantine + transparent recompute
                self._quarantine(entry)
                entry = None
        owner = self.inflight.get(key)
        if owner is not None:
            self.stats.inflight_hits += 1
            return Lookup(status="inflight", owner=owner, slow_factor=slow)
        self.stats.misses += 1
        return Lookup(status="miss", slow_factor=slow)

    # -------------------------------------------------------------- lifecycle

    def begin(
        self, key: str, *, replica: int, now: float, est_bytes: int
    ) -> None:
        """Register an in-flight leader: a PINNED placeholder entry
        reserving ``est_bytes`` that eviction may never touch — the
        leader's store must land even under byte pressure. Idempotent
        per key (a second leader for the same key on another replica
        keeps the first pin; stores are last-writer-wins)."""
        if key in self.inflight:
            return
        self.inflight[key] = replica
        if key not in self.entries:
            self._make_room(est_bytes, now)
            self.entries[key] = _Entry(
                key=key,
                artifact=b"",
                checksum="",
                nbytes=est_bytes,
                stored_s=now,
                last_used_s=now,
                pending=True,
            )
            self.stats.bytes_stored += est_bytes

    def abandon(self, key: str) -> None:
        """Drop an in-flight registration without a result (leader
        evacuated, cancelled, or crashed): unpin, and remove the
        placeholder so the byte account balances. Tolerant of unknown
        keys — failover paths may abandon twice."""
        self.inflight.pop(key, None)
        entry = self.entries.get(key)
        if entry is not None and entry.pending:
            self.entries.pop(key, None)
            self.stats.bytes_stored -= entry.nbytes

    def inflight_owner(self, key: str) -> Optional[int]:
        return self.inflight.get(key)

    def complete(
        self,
        key: str,
        *,
        now: float,
        record,
        result=None,
        shape=(0, 0, 0),
        replica: int = 0,
        request_id: int = 0,
    ) -> Optional[str]:
        """Fold a leader's terminal record into the store: a served
        ``ok`` record becomes a verified artifact, a permanent fault
        becomes a negative entry with TTL, anything else (exhausted
        transient, timeout) just unpins — retrying later may succeed,
        so no verdict is cached. Returns the stored artifact checksum
        (None when nothing was stored).

        The unpin is OWNER-CHECKED: a stale leader (its pin abandoned by
        drain/evacuate, the lead since re-taken by another replica) may
        still complete here, and it must not steal the current leader's
        pin or placeholder — it only stores (last-writer-wins), with the
        displaced entry's bytes credited by ``_displace``."""
        if self.inflight.get(key) == replica:
            self.inflight.pop(key, None)
            placeholder = self.entries.get(key)
            if placeholder is not None and placeholder.pending:
                self.entries.pop(key, None)
                self.stats.bytes_stored -= placeholder.nbytes
        decision = self._decide(
            "store",
            now=now,
            replica=replica,
            request_id=request_id,
            group_key=None,
        )
        if decision is not None and decision.kind == "cache_unavailable":
            self.stats.unavailable += 1
            self.stats.store_skips += 1
            self.breaker.on_unavailable(now)
            return None
        if record.status == "ok":
            payload = {
                "status": record.status,
                "mode": record.mode,
                "executor": record.executor,
                "precision": record.precision,
                "params_bytes": record.params_bytes,
                "hbm_bytes_modeled": record.hbm_bytes_modeled,
                "collective_bytes_modeled": record.collective_bytes_modeled,
            }
            artifact = json.dumps(payload, sort_keys=True).encode("utf-8")
            nbytes = artifact_bytes_modeled(shape) + len(artifact)
            if not self._make_room(nbytes, now):
                self.stats.store_skips += 1  # everything pinned: no room
                return None
            self._displace(key)
            checksum = self._checksum(artifact)
            entry = _Entry(
                key=key,
                artifact=artifact,
                checksum=checksum,
                nbytes=nbytes,
                stored_s=now,
                last_used_s=now,
                meta=payload,
                result=result,
            )
            self.entries[key] = entry
            self.stats.bytes_stored += nbytes
            self.stats.stores += 1
            if decision is not None and decision.kind == "corrupt_entry":
                # poison at rest: a later hit MUST quarantine this entry
                self._corrupt(entry)
            return checksum
        if record.fail_type == PERMANENT_FAULT:
            nbytes = _META_OVERHEAD_BYTES
            if not self._make_room(nbytes, now):
                self.stats.store_skips += 1
                return None
            self._displace(key)
            self.entries[key] = _Entry(
                key=key,
                artifact=b"",
                checksum="",
                nbytes=nbytes,
                stored_s=now,
                last_used_s=now,
                negative=True,
                fail_type=record.fail_type,
                expires_s=now + self.cfg.negative_ttl_s,
            )
            self.stats.bytes_stored += nbytes
            self.stats.negative_stores += 1
        return None

    # -------------------------------------------------------------- eviction

    def _displace(self, key: str) -> None:
        """Credit and remove whatever entry currently sits at ``key``
        immediately before a store lands there: last-writer-wins must
        not leak the displaced entry's bytes from the account (a stale
        entry surviving a quarantine race, or another leader's pending
        placeholder being overwritten — its PIN stays with its owner,
        only the bytes move). Called after ``_make_room``, so the room
        check is conservative by the displaced entry's size — it may
        evict one extra LRU entry, never under-reserve."""
        existing = self.entries.pop(key, None)
        if existing is not None:
            self.stats.bytes_stored -= existing.nbytes

    def _make_room(self, need: int, now: float) -> bool:
        """Evict least-recently-used entries until ``need`` fits the
        MemoryBudget. Pinned in-flight placeholders are NEVER victims —
        if only pinned entries remain and the budget still does not fit,
        the store is refused instead (the caller counts a skip). Ties on
        last-use break on key, so eviction order is deterministic."""
        limit = self.budget.bytes_limit
        if need > limit:
            return False  # one artifact larger than the whole tier
        while self.stats.bytes_stored + need > limit:
            victims = [
                e
                for k, e in self.entries.items()
                if k not in self.inflight and not e.pending
            ]
            if not victims:
                return False
            victim = min(victims, key=lambda e: (e.last_used_s, e.key))
            self.entries.pop(victim.key, None)
            self.stats.bytes_stored -= victim.nbytes
            self.stats.evictions += 1
            self.stats.bytes_evicted += victim.nbytes
        return True

    # --------------------------------------------------------------- rollups

    def summary(self) -> dict:
        """Deterministic counter rollup — the golden-trace face of the
        cache tier (merged into FleetReport.summary's ``cache`` block)."""
        s = self.stats
        return {
            "lookups": s.lookups,
            "hits": s.hits,
            "misses": s.misses,
            "inflight_hits": s.inflight_hits,
            "hit_rate": round(s.hit_rate(), 4),
            "negative_hits": s.negative_hits,
            "stores": s.stores,
            "store_skips": s.store_skips,
            "negative_stores": s.negative_stores,
            "evictions": s.evictions,
            "quarantined": s.quarantined,
            "quarantined_served": s.quarantined_served,
            "unavailable": s.unavailable,
            "slow_consults": s.slow_consults,
            "breaker_trips": s.breaker_trips + self.breaker.trips,
            "breaker_skips": s.breaker_skips,
            "bytes_stored": s.bytes_stored,
            "bytes_evicted": s.bytes_evicted,
            "entries": len(self.entries),
            "inflight": len(self.inflight),
        }


# ---------------------------------------------------------- conform memo ---


class ConformMemo:
    """Content-keyed memo for the conform stage (core/conform.py): the
    most expensive preprocessing step is pure in (volume bytes, target
    shape), so repeated submissions of one scan pay it once. Bounded by
    entry count with FIFO replacement — conformed volumes are large and
    this memo is a preprocessing accelerator, not the artifact store."""

    def __init__(self, max_entries: int = 8):
        self.max_entries = max_entries
        self.entries: dict[tuple, Any] = {}
        self._order: list[tuple] = []
        self.hits = 0
        self.misses = 0

    def _key(self, vol, out_shape) -> Optional[tuple]:
        content = content_hash(vol)
        if content is None:
            return None
        return (content, tuple(out_shape))

    def get(self, vol, out_shape):
        key = self._key(vol, out_shape)
        if key is not None and key in self.entries:
            self.hits += 1
            return self.entries[key]
        self.misses += 1
        return None

    def put(self, vol, out_shape, conformed) -> None:
        key = self._key(vol, out_shape)
        if key is None:
            return
        if key not in self.entries and len(self._order) >= self.max_entries:
            oldest = self._order.pop(0)
            self.entries.pop(oldest, None)
        if key not in self.entries:
            self._order.append(key)
        self.entries[key] = conformed
