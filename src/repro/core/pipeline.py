"""The Brainchop pipeline (Fig. 1): conform -> [brain-mask -> crop] ->
inference (full-volume | sub-volume | streamed | sharded) -> connected-
components filtering -> uncrop.

Inference dispatches through the pluggable executor registry
(core/executors.py): ``PipelineConfig.mode`` picks the spatial strategy
(full / subvolume / streaming) and ``PipelineConfig.executor`` picks the
forward implementation that runs on each block of work — ``"xla"`` (the
reference graph), ``"pallas_fused"`` (one fused conv+BN+ReLU Pallas call
per layer), ``"pallas_megakernel"`` (the whole stack per VMEM-resident
tile, the production TPU path), or ``"streaming"`` (scan-over-layers).
The default ``"auto"`` resolves per host: the megakernel on TPU when its
tile plan fits VMEM, else the fused kernel; XLA on CPU hosts. The executor
that actually ran — and the modeled HBM bytes its schedule moves for this
volume (telemetry/traffic.py) — is recorded in the telemetry record.

Each stage is timed into a telemetry record, mirroring Table IV's
per-stage columns (Preprocessing / Cropping / Inference / Merging /
Postprocessing), and the whole run is guarded by the memory-budget model
(telemetry/budget.py) that simulates the browser's failure modes on
TPU-equivalent limits.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import components, conform as conform_mod, cropping, executors, patching
from repro.core.meshnet import MeshNetConfig
from repro.telemetry.record import StageTimes, TelemetryRecord
from repro.telemetry.budget import MemoryBudget, BudgetExceeded


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """End-to-end pipeline options (one Brainchop 'model card')."""

    name: str = "gwm_light"
    model: MeshNetConfig = dataclasses.field(default_factory=MeshNetConfig)
    volume_shape: tuple[int, int, int] = (256, 256, 256)
    # inference mode: "full" | "subvolume" | "streaming"
    mode: str = "full"
    # forward implementation: "auto" | "xla" | "pallas_fused" |
    # "pallas_megakernel" | "streaming" (core/executors.py; "auto" ->
    # megakernel on TPU when its tile plan fits VMEM, else pallas_fused;
    # xla on CPU hosts)
    executor: str = executors.AUTO
    cube: int = 64
    overlap: int = patching.MESHNET_RF_RADIUS
    batch_cubes: int = 1
    use_cropping: bool = False
    crop_margin: int = 4
    min_component_size: int = 64
    postprocess: bool = True
    budget: Optional[MemoryBudget] = None


@dataclasses.dataclass
class PipelineResult:
    segmentation: Optional[jax.Array]
    record: TelemetryRecord


def _now() -> float:
    return time.perf_counter()


def run(
    cfg: PipelineConfig,
    params: Any,
    vol: jax.Array,
    *,
    mask_model: Optional[tuple[Any, MeshNetConfig]] = None,
    voxel_size=(1.0, 1.0, 1.0),
) -> PipelineResult:
    """Run the full pipeline on one raw volume. Never raises on budget
    failures — returns a failed TelemetryRecord (status='fail'), matching
    the tool's telemetry semantics."""
    times = StageTimes()
    exec_name = executors.resolve(cfg.executor, cfg.model, cfg.volume_shape)
    rec = TelemetryRecord(
        model=cfg.name, mode=cfg.mode, status="ok", times=times, executor=exec_name
    )
    try:
        # Price the inference schedule's HBM traffic for this request: the
        # per-forward model times the number of forwards the mode implies.
        # For the megakernel this also *plans* the schedule, so an
        # infeasible plan (working set over VMEM at any tile) surfaces
        # here — before any compute — rather than at trace time inside
        # the budget-guarded region below.
        if cfg.mode == "subvolume":
            ncubes = math.prod(
                -(-s // cfg.cube) for s in cfg.volume_shape
            )
            per_cube = executors.modeled_hbm_bytes(
                exec_name, cfg.model, (cfg.cube + 2 * cfg.overlap,) * 3
            )
            rec.hbm_bytes_modeled = None if per_cube is None else ncubes * per_cube
        else:
            rec.hbm_bytes_modeled = executors.modeled_hbm_bytes(
                exec_name, cfg.model, cfg.volume_shape
            )
        if cfg.use_cropping and mask_model is not None:
            # the mask forward runs under the same executor; probe it too
            executors.modeled_hbm_bytes(exec_name, mask_model[1], cfg.volume_shape)
    except ValueError:
        # Unplannable schedule: the forward itself would raise the same
        # error, so keep the never-raises telemetry contract and report a
        # failed run (the VMEM analogue of the budget fail types).
        rec.status = "fail"
        rec.fail_type = "vmem_oom"
        return PipelineResult(segmentation=None, record=rec)
    budget = cfg.budget or MemoryBudget.unlimited()

    try:
        # --- Stage 1: preprocessing (conform) -------------------------------
        t0 = _now()
        x = conform_mod.conform(vol, cfg.volume_shape, voxel_size)
        x.block_until_ready()
        times.preprocessing = _now() - t0

        crop_start = None
        full_shape = x.shape
        # --- Stage 2: cropping (optional) ------------------------------------
        if cfg.use_cropping and mask_model is not None:
            t0 = _now()
            mparams, mcfg = mask_model
            budget.charge_inference(x.shape, mcfg)
            mask_logits = executors.jitted_apply(exec_name)(mparams, x[None], mcfg)
            mask = jnp.argmax(mask_logits[0], -1) > 0
            mask = components.largest_component(mask)
            size = cropping.pick_crop_size(mask, margin=cfg.crop_margin)
            x, crop_start = cropping.crop_to(x, mask, size)
            x.block_until_ready()
            times.cropping = _now() - t0
            rec.crop_size = size

        # --- Stage 3: inference ----------------------------------------------
        t0 = _now()
        if cfg.mode == "subvolume":
            budget.charge_subvolume(cfg.cube, cfg.overlap, cfg.model)
            logits = patching.subvolume_inference(
                x,
                params=params,
                model_cfg=cfg.model,
                executor=exec_name,
                cube=cfg.cube,
                overlap=cfg.overlap,
                batch_cubes=cfg.batch_cubes,
            )
            logits.block_until_ready()
            # The trimmed write-back merge happens inside subvolume_inference
            # (host-side numpy copies, not separately timed); the whole
            # split -> infer -> merge span is attributed to 'inference'.
            times.inference = _now() - t0
            times.merging = 0.0
        elif cfg.mode == "streaming":
            budget.charge_streaming(x.shape, cfg.model)
            logits = executors.jitted_apply(exec_name, "streaming")(params, x[None], cfg.model)[0]
            logits.block_until_ready()
            times.inference = _now() - t0
        else:  # full
            budget.charge_inference(x.shape, cfg.model)
            logits = executors.jitted_apply(exec_name)(params, x[None], cfg.model)[0]
            logits.block_until_ready()
            times.inference = _now() - t0

        seg = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        # --- Stage 4: postprocessing (connected components) -------------------
        if cfg.postprocess:
            t0 = _now()
            seg = components.filter_segmentation(seg, cfg.model.num_classes, cfg.min_component_size)
            seg.block_until_ready()
            times.postprocessing = _now() - t0

        if crop_start is not None:
            seg = cropping.uncrop(seg, crop_start, full_shape)

        rec.status = "ok"
        return PipelineResult(segmentation=seg, record=rec)

    except BudgetExceeded as e:
        rec.status = "fail"
        rec.fail_type = e.fail_type
        return PipelineResult(segmentation=None, record=rec)
