"""The Brainchop pipeline (Fig. 1): conform -> [brain-mask -> crop] ->
inference (full-volume | sub-volume | streamed | sharded) -> connected-
components filtering -> uncrop.

Inference dispatches through the pluggable executor registry
(core/executors.py): ``PipelineConfig.mode`` picks the spatial strategy
(full / subvolume / streaming) and ``PipelineConfig.executor`` picks the
forward implementation that runs on each block of work — ``"xla"`` (the
reference graph), ``"pallas_fused"`` (one fused conv+BN+ReLU Pallas call
per layer), ``"pallas_megakernel"`` (the whole stack per VMEM-resident
tile, the production TPU path), ``"streaming"`` (scan-over-layers), or
the multi-device ``"sharded_<inner>[@n]"`` family (halo-exchange Z-slab
sharding, core/spatial_shard.py; ``PipelineConfig.shard_devices`` pins
the slab count for any executor). The default ``"auto"`` resolves per
host: the sharded megakernel on multi-device TPU when the per-slab tile
plan fits VMEM, the megakernel on one TPU device, else the fused kernel;
XLA on CPU hosts. ``PipelineConfig.precision`` picks the storage policy
(kernels/quantize.py: fp32 | bf16 | int8w; "auto" -> bf16 on TPU, int8w
for wide models, fp32 on CPU) — the conformed volume leaves
preprocessing in the policy's storage dtype and every backend runs its
precision-matched kernels. The executor and precision that actually ran
— plus the modeled HBM, inter-device halo, and streamed-weight bytes
their schedule moves for this volume (telemetry/traffic.py,
quantize.model_params_bytes) — are recorded in the telemetry record.

Each stage is timed into a telemetry record, mirroring Table IV's
per-stage columns (Preprocessing / Cropping / Inference / Merging /
Postprocessing), and the whole run is guarded by the memory-budget model
(telemetry/budget.py) that simulates the browser's failure modes on
TPU-equivalent limits.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import components, conform as conform_mod, cropping, executors, patching
from repro.core.meshnet import MeshNetConfig
from repro.core.spatial_shard import ShardGeometryError
from repro.kernels import quantize
from repro.telemetry.record import StageTimes, TelemetryRecord
from repro.telemetry.budget import MemoryBudget, BudgetExceeded


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """End-to-end pipeline options (one Brainchop 'model card')."""

    name: str = "gwm_light"
    model: MeshNetConfig = dataclasses.field(default_factory=MeshNetConfig)
    volume_shape: tuple[int, int, int] = (256, 256, 256)
    # inference mode: "full" | "subvolume" | "streaming"
    mode: str = "full"
    # forward implementation: "auto" | "xla" | "pallas_fused" |
    # "pallas_megakernel" | "streaming" | "sharded_<inner>[@n]"
    # (core/executors.py; "auto" -> the sharded megakernel on multi-device
    # TPU when the per-slab plan fits VMEM, the megakernel on one TPU
    # device, else pallas_fused; xla on CPU hosts)
    executor: str = executors.AUTO
    # run the (resolved) executor Z-sharded over this many devices
    # (core/spatial_shard.py): the executor is re-wrapped as
    # sharded_<inner>@<n>. None = leave the executor as resolved; 1 =
    # force single-device (unwraps a sharded default). Executors with no
    # sharded form (streaming) keep running single-device.
    shard_devices: Optional[int] = None
    # storage policy (kernels/quantize.py): "fp32" | "bf16" | "int8w" |
    # "auto" ("auto" -> bf16 on TPU, int8w for wide models, fp32 on CPU
    # hosts where the XLA oracle serves). The conformed volume is cast /
    # int8-quantized once at the end of preprocessing, so the inference
    # schedule streams the policy's storage dtypes end to end; the
    # resolved policy and the weight footprint are stamped on telemetry.
    precision: str = quantize.AUTO
    cube: int = 64
    overlap: int = patching.MESHNET_RF_RADIUS
    batch_cubes: int = 1
    use_cropping: bool = False
    crop_margin: int = 4
    min_component_size: int = 64
    postprocess: bool = True
    budget: Optional[MemoryBudget] = None
    # optional content-keyed memo for the conform stage (e.g.
    # serving.cache.ConformMemo): any object with get(vol, out_shape) ->
    # conformed-or-None and put(vol, out_shape, conformed). The memo holds
    # the conformed [0, 1] volume *before* the precision cast, so one
    # conform can feed requests running under different storage policies.
    conform_memo: Optional[Any] = None


@dataclasses.dataclass
class PipelineResult:
    segmentation: Optional[jax.Array]
    record: TelemetryRecord


def _now() -> float:
    return time.perf_counter()


def _geometry_fail_type(e: ValueError) -> str:
    """Telemetry fail type for a ValueError out of the pre-flight models:
    slab-geometry problems (ShardGeometryError: non-divisible Z, missing
    devices) get their own label; any other planning ValueError is an
    unplannable-VMEM schedule."""
    return "shard_geometry" if isinstance(e, ShardGeometryError) else "vmem_oom"


def run(
    cfg: PipelineConfig,
    params: Any,
    vol: jax.Array,
    *,
    mask_model: Optional[tuple[Any, MeshNetConfig]] = None,
    voxel_size=(1.0, 1.0, 1.0),
) -> PipelineResult:
    """Run the full pipeline on one raw volume. Never raises on budget
    failures — returns a failed TelemetryRecord (status='fail'), matching
    the tool's telemetry semantics."""
    times = StageTimes()
    # Resolve against the geometry each forward actually sees: failsafe
    # mode runs the executor on padded cubes, not the whole volume — so
    # "auto" must judge slab divisibility / VMEM plans on the cube shape
    # (a sharded default that can't slice the cube would fail every
    # failsafe request).
    work_shape = (
        (cfg.cube + 2 * cfg.overlap,) * 3
        if cfg.mode == "subvolume"
        else cfg.volume_shape
    )
    precision = quantize.resolve_precision(cfg.precision, cfg.model)
    exec_name = executors.resolve(cfg.executor, cfg.model, work_shape, precision)
    if cfg.shard_devices is not None:
        inner = executors.inner_of(exec_name)
        parsed = executors.parse_sharded(exec_name)
        already_pinned = parsed is not None and parsed[1] is not None
        if (
            cfg.shard_devices > 1
            and executors.shardable(inner)
            and not already_pinned
        ):
            # per-request slab count: re-wrap the resolved backend (or the
            # sharded family's unpinned form) pinned to this many Z-slabs.
            # An executor name that pins its own count ("sharded_xla@8")
            # is an explicit request and wins over this default.
            exec_name = executors.ensure_sharded(inner, cfg.shard_devices)
        elif cfg.shard_devices <= 1:
            # devices=1 forces single-device, unwrapping a sharded default
            exec_name = inner
        # executors with no sharded form (streaming) keep running
        # single-device rather than failing the request.
    rec = TelemetryRecord(
        model=cfg.name,
        mode=cfg.mode,
        status="ok",
        times=times,
        executor=exec_name,
        precision=precision,
        params_bytes=quantize.model_params_bytes(cfg.model, precision),
        # the simulated device limit this run was admitted against — the
        # column the paper's texture-size tables condition on, and what
        # the serving scheduler's fleet rollups group by. None when the
        # run is unguarded (no budget configured).
        memory_budget_bytes=None if cfg.budget is None else cfg.budget.bytes_limit,
    )
    try:
        # Pre-flight the sharded family's hard requirements: the host must
        # actually have the slab count's devices (mesh_for raises the same
        # ValueError the forward would, but before any compute).
        parsed = executors.parse_sharded(exec_name)
        if parsed is not None:
            from repro.core import spatial_shard

            spatial_shard.mesh_for(parsed[1])
        # Price the inference schedule's HBM traffic for this request: the
        # per-forward model times the number of forwards the mode implies.
        # For the megakernel this also *plans* the schedule, so an
        # infeasible plan (working set over VMEM at any tile) surfaces
        # here — before any compute — rather than at trace time inside
        # the budget-guarded region below.
        if cfg.mode == "subvolume":
            ncubes = math.prod(
                -(-s // cfg.cube) for s in cfg.volume_shape
            )
            cube_shape = (cfg.cube + 2 * cfg.overlap,) * 3
            per_cube = executors.modeled_hbm_bytes(
                exec_name, cfg.model, cube_shape, precision=precision
            )
            rec.hbm_bytes_modeled = None if per_cube is None else ncubes * per_cube
            rec.collective_bytes_modeled = ncubes * executors.modeled_collective_bytes(
                exec_name, cfg.model, cube_shape, precision=precision
            )
        else:
            rec.hbm_bytes_modeled = executors.modeled_hbm_bytes(
                exec_name, cfg.model, cfg.volume_shape, precision=precision
            )
            rec.collective_bytes_modeled = executors.modeled_collective_bytes(
                exec_name, cfg.model, cfg.volume_shape, precision=precision
            )
        if cfg.use_cropping and mask_model is not None:
            # the mask forward runs under the same executor; probe it too
            executors.modeled_hbm_bytes(
                exec_name, mask_model[1], cfg.volume_shape, precision=precision
            )
    except ValueError as e:
        # Unplannable schedule: the forward itself would raise the same
        # error, so keep the never-raises telemetry contract and report a
        # failed run (the VMEM analogue of the budget fail types). A Z dim
        # that doesn't divide into the requested slabs — or a slab count
        # the host lacks devices for — surfaces the same way, under its
        # own fail type.
        rec.status = "fail"
        rec.fail_type = _geometry_fail_type(e)
        return PipelineResult(segmentation=None, record=rec)
    budget = cfg.budget or MemoryBudget.unlimited()

    act_bytes = quantize.act_bytes(precision)
    try:
        # --- Stage 1: preprocessing (conform + precision cast) --------------
        t0 = _now()
        x = None
        if cfg.conform_memo is not None:
            x = cfg.conform_memo.get(vol, cfg.volume_shape)
        if x is None:
            x = conform_mod.conform(vol, cfg.volume_shape, voxel_size)
            if cfg.conform_memo is not None:
                cfg.conform_memo.put(vol, cfg.volume_shape, x)
        # The policy cast is conform's output write, not an inference
        # cost: the conformed [0, 1] volume leaves preprocessing in the
        # policy's storage dtype (int8-quantized under int8w — faithful
        # to Brainchop, whose conformed volumes are uint8), so the
        # inference schedule below streams it at that width.
        if precision == "int8w":
            x = quantize.quantize_input(x)
        elif precision == "bf16":
            x = x.astype(quantize.act_dtype(precision))
        x.block_until_ready()
        times.preprocessing = _now() - t0

        crop_start = None
        full_shape = x.shape
        # --- Stage 2: cropping (optional) ------------------------------------
        if cfg.use_cropping and mask_model is not None:
            t0 = _now()
            mparams, mcfg = mask_model
            budget.charge_inference(x.shape, mcfg, dtype_bytes=act_bytes)
            mask_logits = executors.jitted_apply(exec_name, precision=precision)(
                mparams, x[None], mcfg
            )
            mask = jnp.argmax(mask_logits[0], -1) > 0
            mask = components.largest_component(mask)
            size = cropping.pick_crop_size(mask, margin=cfg.crop_margin)
            x, crop_start = cropping.crop_to(x, mask, size)
            x.block_until_ready()
            times.cropping = _now() - t0
            rec.crop_size = size

        # --- Stage 3: inference ----------------------------------------------
        t0 = _now()
        if cfg.mode == "subvolume":
            budget.charge_subvolume(
                cfg.cube, cfg.overlap, cfg.model, dtype_bytes=act_bytes
            )
            logits = patching.subvolume_inference(
                x,
                params=params,
                model_cfg=cfg.model,
                executor=exec_name,
                cube=cfg.cube,
                overlap=cfg.overlap,
                batch_cubes=cfg.batch_cubes,
                precision=precision,
            )
            logits.block_until_ready()
            # The trimmed write-back merge happens inside subvolume_inference
            # (host-side numpy copies, not separately timed); the whole
            # split -> infer -> merge span is attributed to 'inference'.
            times.inference = _now() - t0
            times.merging = 0.0
        elif cfg.mode == "streaming":
            budget.charge_streaming(x.shape, cfg.model, dtype_bytes=act_bytes)
            logits = executors.jitted_apply(exec_name, "streaming", precision)(
                params, x[None], cfg.model
            )[0]
            logits.block_until_ready()
            times.inference = _now() - t0
        else:  # full
            budget.charge_inference(x.shape, cfg.model, dtype_bytes=act_bytes)
            logits = executors.jitted_apply(exec_name, precision=precision)(
                params, x[None], cfg.model
            )[0]
            logits.block_until_ready()
            times.inference = _now() - t0

        seg = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        # --- Stage 4: postprocessing (connected components) -------------------
        if cfg.postprocess:
            t0 = _now()
            seg = components.filter_segmentation(seg, cfg.model.num_classes, cfg.min_component_size)
            seg.block_until_ready()
            times.postprocessing = _now() - t0

        if crop_start is not None:
            seg = cropping.uncrop(seg, crop_start, full_shape)

        rec.status = "ok"
        return PipelineResult(segmentation=seg, record=rec)

    except BudgetExceeded as e:
        rec.status = "fail"
        rec.fail_type = e.fail_type
        return PipelineResult(segmentation=None, record=rec)
    except conform_mod.DegenerateVolumeError:
        # A well-formed 3-D volume with no intensity dynamic range
        # (all-zero / constant / all-non-finite): conform refuses it
        # host-side before any compute, and the never-raises contract
        # turns that into a typed preprocessing failure. Malformed
        # payloads (wrong rank) are NOT intercepted — they still blow up
        # in resample and propagate, so the serving tier's
        # garbage-volume classification is unchanged.
        times.preprocessing = _now() - t0
        rec.status = "fail"
        rec.fail_type = "degenerate_volume"
        return PipelineResult(segmentation=None, record=rec)
    except ShardGeometryError:
        # The forward can still hit slab geometry the pre-flight could not
        # see — cropping picks its shape at run time, and a crop size need
        # not divide into a sharded executor's slabs. Same contract: a
        # failed record, never an exception. (Other ValueErrors — bad
        # input, bugs — propagate with their tracebacks.)
        rec.status = "fail"
        rec.fail_type = "shard_geometry"
        return PipelineResult(segmentation=None, record=rec)
