"""3-D U-Net baseline (Table II comparison model).

The paper contrasts MeshNet (0.022–0.89 MB) against a 288 MB U-Net at equal
Dice (0.96). We implement a standard 3-level volumetric U-Net so the
comparison can be re-run on the synthetic task: encoder (conv-conv-pool) x3,
bottleneck, decoder with transposed-conv upsampling + skip concats.

Channels-last (B, D, H, W, C), same as meshnet.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class UNet3DConfig:
    in_channels: int = 1
    num_classes: int = 3
    base_channels: int = 16
    levels: int = 3
    dtype: Any = jnp.float32

    def channel_plan(self) -> Sequence[int]:
        return [self.base_channels * (2 ** i) for i in range(self.levels)]

    def param_count(self) -> int:
        leaves = jax.tree.leaves(init(jax.random.PRNGKey(0), self))
        return int(sum(np.prod(l.shape) for l in leaves))


def _conv_init(key, kshape, dtype):
    fan_in = int(np.prod(kshape[:-1]))
    return jax.random.normal(key, kshape, dtype) * np.sqrt(2.0 / fan_in)


def _double_conv_init(key, cin, cout, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w1": _conv_init(k1, (3, 3, 3, cin, cout), dtype),
        "b1": jnp.zeros((cout,), dtype),
        "w2": _conv_init(k2, (3, 3, 3, cout, cout), dtype),
        "b2": jnp.zeros((cout,), dtype),
    }


def init(key: jax.Array, cfg: UNet3DConfig):
    plan = cfg.channel_plan()
    keys = jax.random.split(key, 2 * cfg.levels + 2)
    enc, dec = [], []
    cin = cfg.in_channels
    for i, ch in enumerate(plan):
        enc.append(_double_conv_init(keys[i], cin, ch, cfg.dtype))
        cin = ch
    bott_ch = plan[-1] * 2
    bott = _double_conv_init(keys[cfg.levels], plan[-1], bott_ch, cfg.dtype)
    cin = bott_ch
    for i, ch in enumerate(reversed(plan)):
        kk = jax.random.split(keys[cfg.levels + 1 + i])
        dec.append(
            {
                "up_w": _conv_init(kk[0], (2, 2, 2, cin, ch), cfg.dtype),
                "up_b": jnp.zeros((ch,), cfg.dtype),
                "conv": _double_conv_init(kk[1], ch * 2, ch, cfg.dtype),
            }
        )
        cin = ch
    head_key = keys[-1]
    head = {
        "w": _conv_init(head_key, (1, 1, 1, plan[0], cfg.num_classes), cfg.dtype),
        "b": jnp.zeros((cfg.num_classes,), cfg.dtype),
    }
    return {"enc": enc, "bottleneck": bott, "dec": dec, "head": head}


def _conv3(x, w, b):
    out = jax.lax.conv_general_dilated(
        x, w, (1, 1, 1), [(1, 1)] * 3, dimension_numbers=("NDHWC", "DHWIO", "NDHWC")
    )
    return out + b


def _double_conv(p, x):
    x = jax.nn.relu(_conv3(x, p["w1"], p["b1"]))
    return jax.nn.relu(_conv3(x, p["w2"], p["b2"]))


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 2, 1), (1, 2, 2, 2, 1), "VALID"
    )


def _upconv(x, w, b):
    out = jax.lax.conv_transpose(
        x, w, (2, 2, 2), "SAME", dimension_numbers=("NDHWC", "DHWIO", "NDHWC")
    )
    return out + b


def apply(params, x, cfg: UNet3DConfig) -> jax.Array:
    """Forward -> logits (B, D, H, W, num_classes). D,H,W must be / 2^levels."""
    if x.ndim == 4:
        x = x[..., None]
    skips = []
    for p in params["enc"]:
        x = _double_conv(p, x)
        skips.append(x)
        x = _maxpool(x)
    x = _double_conv(params["bottleneck"], x)
    for p, skip in zip(params["dec"], reversed(skips)):
        x = _upconv(x, p["up_w"], p["up_b"])
        x = jnp.concatenate([x, skip], axis=-1)
        x = _double_conv(p["conv"], x)
    # 1x1x1 head: pointwise projection (no padding!)
    head = params["head"]
    return jnp.einsum("bdhwi,io->bdhwo", x, head["w"][0, 0, 0]) + head["b"]


def predict(params, x, cfg: UNet3DConfig) -> jax.Array:
    return jnp.argmax(apply(params, x, cfg), axis=-1).astype(jnp.int32)
