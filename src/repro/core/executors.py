"""Pluggable MeshNet inference executors — the registry behind the pipeline.

The pipeline (core/pipeline.py) separates two orthogonal choices:

  * **mode** — the spatial strategy: ``full`` (whole volume in one forward),
    ``subvolume`` (overlap-patched cubes, the paper's failsafe), or
    ``streaming`` (layer-by-layer schedule, the paper's progressive
    inference with disposal).
  * **executor** — the forward-pass *implementation* that runs on each
    block of work. Every executor exposes the same uniform interface
    ``apply(params, x, cfg) -> logits`` with ``x: (B, D, H, W[, C])`` and
    logits ``(B, D, H, W, num_classes)``, numerically equal to
    ``meshnet.apply`` in eval mode (tests/test_executors.py enforces this).

Built-in executors (DESIGN.md §2):

  ``xla``          — the reference path: ``meshnet.apply``, one XLA op per
                     conv/BN/ReLU stage. Always available; the parity oracle.
  ``pallas_fused`` — per-layer fusion: ``ops.meshnet_apply``, each hidden
                     layer is ONE fused Pallas call (conv+BN+ReLU epilogue),
                     so activations make a single HBM round-trip per layer
                     (EXPERIMENTS.md §Perf H1). Compiled Mosaic on TPU;
                     interpret mode (slow, correctness-path) on CPU hosts.
  ``pallas_megakernel`` — depth-first tiling: ``ops.meshnet_apply_megakernel``
                     runs the *whole* hidden stack (and the head) per
                     VMEM-resident tile, so hidden activations never touch
                     HBM within a segment — the traffic floor and the
                     production TPU path (EXPERIMENTS.md §Perf H9).
  ``streaming``    — the memory-floor path: ``streaming.streaming_apply``,
                     a lax.scan over stacked layers keeping two live
                     activations regardless of depth (DESIGN.md §4).

``executor="auto"`` (the PipelineConfig default) resolves per backend: on
TPU it prefers ``pallas_megakernel`` whenever the depth-first tile plan
fits the VMEM budget (kernels/megakernel.py), falling back to
``pallas_fused``; on CPU hosts it resolves to ``xla``, where Pallas
interpret mode is a correctness tool, not a serving backend. Pass an
explicit name to force a path (benchmarks and parity tests do).

Each spec also carries ``hbm_bytes`` — the modeled HBM traffic of one
forward under that executor's schedule (telemetry/traffic.py) — which the
pipeline stamps into every telemetry record and the benchmarks report
next to wall-clock.

Extending: ``register(ExecutorSpec(...))`` adds a backend (e.g. a sharded
or quantised forward) without touching the pipeline, engine, or benchmarks
— they all dispatch through this registry.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from repro.core import meshnet, streaming
from repro.core.meshnet import MeshNetConfig
from repro.kernels import megakernel, ops
from repro.telemetry import traffic

# (params, x, cfg) -> logits; x (B, D, H, W[, C]) -> (B, D, H, W, classes)
ApplyFn = Callable[[Any, jax.Array, MeshNetConfig], jax.Array]

# (cfg, volume_shape, batch) -> modeled HBM bytes per forward, or None.
BytesFn = Callable[..., Optional[int]]


@dataclasses.dataclass(frozen=True)
class ExecutorSpec:
    """One inference backend.

    ``apply`` is the uniform whole-batch forward. ``streaming_apply`` is the
    schedule mode="streaming" uses — for the fused paths it is the same
    function, because per-layer/per-tile fusion already yields the
    two-live-buffer schedule (each layer's activation is consumed by
    exactly one next call). ``hbm_bytes(cfg, vol, batch=1)`` prices the
    schedule's HBM traffic (telemetry/traffic.py); None if unmodeled.
    """

    name: str
    apply: ApplyFn
    streaming_apply: ApplyFn
    description: str = ""
    hbm_bytes: Optional[BytesFn] = None


_REGISTRY: dict[str, ExecutorSpec] = {}

#: the name PipelineConfig defaults to; resolved per-backend at run time.
AUTO = "auto"


def register(spec: ExecutorSpec) -> ExecutorSpec:
    _REGISTRY[spec.name] = spec
    # Evict only this spec's compiled wrappers; other backends stay hot.
    for schedule in ("apply", "streaming"):
        _JIT_CACHE.pop((spec.name, schedule), None)
    return spec


def names() -> list[str]:
    """Registered executor names (stable order of registration)."""
    return list(_REGISTRY)


def default_executor(
    model: Optional[MeshNetConfig] = None,
    volume_shape: Optional[tuple[int, int, int]] = None,
) -> str:
    """The production default. On TPU: the depth-first megakernel when a
    tile plan fits the VMEM budget for this (model, volume), else the
    per-layer fused path; without a model to plan for, the fused path.
    On CPU hosts: XLA (Pallas interpret mode is a correctness path, far
    too slow to serve)."""
    if jax.default_backend() != "tpu":
        return "xla"
    if model is None:
        return "pallas_fused"
    try:
        megakernel.plan_for_config(model, volume_shape or (256, 256, 256))
        return "pallas_megakernel"
    except ValueError:
        return "pallas_fused"


def resolve(
    name: Optional[str],
    model: Optional[MeshNetConfig] = None,
    volume_shape: Optional[tuple[int, int, int]] = None,
) -> str:
    """Map None/"auto" to the backend default (model/shape aware when the
    caller can supply them); validate explicit names."""
    if name is None or name == AUTO:
        return default_executor(model, volume_shape)
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown executor {name!r}; registered: {sorted(_REGISTRY)} (or 'auto')"
        )
    return name


def get(name: Optional[str]) -> ExecutorSpec:
    """Fetch an executor spec, resolving "auto"."""
    return _REGISTRY[resolve(name)]


def apply(name: Optional[str], params, x: jax.Array, cfg: MeshNetConfig) -> jax.Array:
    """One-shot dispatch: run ``x`` through the named executor (eager —
    composable under an outer jit; use ``jitted_apply`` on hot paths)."""
    return get(name).apply(params, x, cfg)


def modeled_hbm_bytes(
    name: Optional[str],
    cfg: MeshNetConfig,
    volume_shape: tuple[int, int, int],
    batch: int = 1,
) -> Optional[int]:
    """Modeled HBM bytes of one forward under the named executor's
    schedule, or None if the backend has no traffic model."""
    spec = _REGISTRY[resolve(name, cfg, volume_shape)]
    if spec.hbm_bytes is None:
        return None
    return spec.hbm_bytes(cfg, volume_shape, batch=batch)


_JIT_CACHE: dict[tuple[str, str], Callable] = {}


def _jitted(name: str, schedule: str):
    key = (name, schedule)
    if key not in _JIT_CACHE:
        spec = _REGISTRY[name]
        fn = spec.apply if schedule == "apply" else spec.streaming_apply
        # cfg is a frozen (hashable) dataclass -> static, so one executable
        # is compiled per (executor, schedule, cfg, input shape) and shared
        # by every pipeline run and serving request that matches.
        _JIT_CACHE[key] = jax.jit(fn, static_argnums=(2,))
    return _JIT_CACHE[key]


def jitted_apply(
    name: Optional[str], schedule: str = "apply"
) -> Callable[[Any, jax.Array, MeshNetConfig], jax.Array]:
    """Jit-compiled executor forward, cached per (executor, schedule).

    This is the dispatch point for hot paths (pipeline.run, the engine,
    sub-volume closures): repeated calls — and batched serving requests in
    any order — reuse one compiled executable per input shape instead of
    re-tracing a fresh ``jax.jit(lambda ...)`` each run.
    ``schedule="streaming"`` selects the spec's layer-streamed variant.
    """
    if schedule not in ("apply", "streaming"):
        raise ValueError(f"schedule must be 'apply' or 'streaming', got {schedule!r}")
    return _jitted(resolve(name), schedule)


def make_infer(name: Optional[str], params, cfg: MeshNetConfig) -> Callable[[jax.Array], jax.Array]:
    """Build the per-block closure used by sub-volume patching: maps
    (B, d, h, w[, C]) cubes -> (B, d, h, w, classes). Backed by the shared
    ``jitted_apply`` cache, and compiled once per cube shape because all
    cubes in a CubeDivider share a static shape."""
    fn = jitted_apply(resolve(name, cfg))

    def infer(c: jax.Array) -> jax.Array:
        return fn(params, c, cfg)

    return infer


def _xla_apply(params, x, cfg):
    return meshnet.apply(params, x, cfg)


register(
    ExecutorSpec(
        name="xla",
        apply=_xla_apply,
        streaming_apply=streaming.streaming_apply,
        description="reference XLA graph (meshnet.apply); parity oracle",
        hbm_bytes=traffic.meshnet_xla_bytes,
    )
)

register(
    ExecutorSpec(
        name="pallas_fused",
        apply=ops.meshnet_apply,
        streaming_apply=ops.meshnet_apply,
        description="fused Pallas conv+BN+ReLU per layer",
        hbm_bytes=traffic.meshnet_fused_bytes,
    )
)

register(
    ExecutorSpec(
        name="pallas_megakernel",
        apply=ops.meshnet_apply_megakernel,
        streaming_apply=ops.meshnet_apply_megakernel,
        description="depth-first tiled whole-stack Pallas megakernel; "
        "production TPU path when the tile plan fits VMEM",
        hbm_bytes=traffic.meshnet_megakernel_bytes,
    )
)

register(
    ExecutorSpec(
        name="streaming",
        apply=streaming.streaming_apply,
        streaming_apply=streaming.streaming_apply,
        description="lax.scan over stacked layers; memory-floor schedule",
        hbm_bytes=traffic.meshnet_streaming_bytes,
    )
)
