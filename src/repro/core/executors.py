"""Pluggable MeshNet inference executors — the registry behind the pipeline.

The pipeline (core/pipeline.py) separates two orthogonal choices:

  * **mode** — the spatial strategy: ``full`` (whole volume in one forward),
    ``subvolume`` (overlap-patched cubes, the paper's failsafe), or
    ``streaming`` (layer-by-layer schedule, the paper's progressive
    inference with disposal).
  * **executor** — the forward-pass *implementation* that runs on each
    block of work. Every executor exposes the same uniform interface
    ``apply(params, x, cfg) -> logits`` with ``x: (B, D, H, W[, C])`` and
    logits ``(B, D, H, W, num_classes)``, numerically equal to
    ``meshnet.apply`` in eval mode (tests/test_executors.py enforces this).
    The leading ``B`` is a true N-volume batch axis on every backend — a
    leading dim the XLA/fused kernels carry through, the innermost grid
    axis of the megakernel (per-segment weight DMA amortizes across the
    whole batch), and a second mesh axis for the sharded family when the
    host has spare devices beyond the slab count — and each batch member's
    logits equal its unbatched forward (tests/test_batched.py). The traffic
    models price the amortization: ``hbm_bytes(batch=N) < N *
    hbm_bytes(batch=1)`` whenever a weight-stream term exists.

Built-in executors (DESIGN.md §2):

  ``xla``          — the reference path: ``meshnet.apply``, one XLA op per
                     conv/BN/ReLU stage. Always available; the parity oracle.
  ``pallas_fused`` — per-layer fusion: ``ops.meshnet_apply``, each hidden
                     layer is ONE fused Pallas call (conv+BN+ReLU epilogue),
                     so activations make a single HBM round-trip per layer
                     (EXPERIMENTS.md §Perf H1). Compiled Mosaic on TPU;
                     interpret mode (slow, correctness-path) on CPU hosts.
  ``pallas_megakernel`` — depth-first tiling: ``ops.meshnet_apply_megakernel``
                     runs the *whole* hidden stack (and the head) per
                     VMEM-resident tile, so hidden activations never touch
                     HBM within a segment — the traffic floor and the
                     production TPU path (EXPERIMENTS.md §Perf H9).
  ``streaming``    — the memory-floor path: ``streaming.streaming_apply``,
                     a lax.scan over stacked layers keeping two live
                     activations regardless of depth (DESIGN.md §4).

  ``sharded_<inner>`` — the multi-device family (DESIGN.md §2.2): wraps a
                     single-device backend (``xla`` | ``pallas_fused`` |
                     ``pallas_megakernel``) and runs it per Z-slab under
                     ``shard_map`` over a 1-D mesh, halos exchanged with
                     ``spatial_shard.halo_exchange_z`` (layer-wise for the
                     XLA/fused inners; one RF-radius fetch feeding the
                     megakernel's haloed-tile planner for the Pallas
                     inner). ``sharded_<inner>@<n>`` pins the slab count;
                     without ``@n`` all local devices are used. Specs are
                     registered on demand — any such name resolves.

``executor="auto"`` (the PipelineConfig default) resolves per backend: on
TPU with more than one device it prefers ``sharded_pallas_megakernel``
when the *per-slab* (slab + RF halo) tile plan fits the VMEM budget; on a
single TPU device, ``pallas_megakernel`` when its plan fits, else
``pallas_fused``; on CPU hosts it resolves to ``xla``, where Pallas
interpret mode is a correctness tool, not a serving backend. Pass an
explicit name to force a path (benchmarks and parity tests do).

Each spec also carries ``hbm_bytes`` — the modeled HBM traffic of one
forward under that executor's schedule (telemetry/traffic.py) — and, for
the sharded family, ``collective_bytes`` — the modeled inter-device halo
bytes. The pipeline stamps both into every telemetry record
(``hbm_bytes_modeled`` / ``collective_bytes_modeled``) and the benchmarks
report them next to wall-clock.

Extending: ``register(ExecutorSpec(...))`` adds a backend (e.g. a
quantised or remote forward) without touching the pipeline, engine, or
benchmarks — they all dispatch through this registry.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from repro.core import meshnet, spatial_shard, streaming
from repro.core.meshnet import MeshNetConfig
from repro.kernels import megakernel, ops, quantize
from repro.telemetry import traffic

# (params, x, cfg, precision) -> logits; x (B, D, H, W[, C]) ->
# (B, D, H, W, classes). B is an arbitrary batch size (>= 1): backends
# MUST treat the leading dim as independent volumes whose per-member
# logits match the unbatched forward. ``precision`` is the storage policy
# (kernels/quantize.py: "fp32" | "bf16" | "int8w"); params may arrive raw
# fp32 or already prepared (quantize.prepare_params is idempotent).
ApplyFn = Callable[[Any, jax.Array, MeshNetConfig, str], jax.Array]

# (cfg, volume_shape, batch, precision) -> modeled HBM bytes, or None.
BytesFn = Callable[..., Optional[int]]


@dataclasses.dataclass(frozen=True)
class ExecutorSpec:
    """One inference backend.

    ``apply`` is the uniform whole-batch forward — every backend takes a
    ``precision`` keyword (the policy of kernels/quantize.py) and must
    hold the parity gates per policy: bf16 logits within 1e-2 of fp32,
    int8w segmentation-dice >= 0.99 of fp32 (tests/test_precision.py).
    ``streaming_apply`` is the schedule mode="streaming" uses — for the
    fused paths it is the same function, because per-layer/per-tile
    fusion already yields the two-live-buffer schedule (each layer's
    activation is consumed by exactly one next call).
    ``hbm_bytes(cfg, vol, batch=1, precision="fp32")`` prices the
    schedule's HBM traffic at the policy's byte widths
    (telemetry/traffic.py); None if unmodeled.
    ``collective_bytes(cfg, vol, batch=1, precision="fp32")`` prices
    inter-device halo traffic — None for single-device backends (modeled
    as zero); reduced precisions ship bf16/int8 halos.
    """

    name: str
    apply: ApplyFn
    streaming_apply: ApplyFn
    description: str = ""
    hbm_bytes: Optional[BytesFn] = None
    collective_bytes: Optional[BytesFn] = None


_REGISTRY: dict[str, ExecutorSpec] = {}

#: the name PipelineConfig defaults to; resolved per-backend at run time.
AUTO = "auto"


def register(spec: ExecutorSpec) -> ExecutorSpec:
    _REGISTRY[spec.name] = spec
    # Evict only this spec's compiled wrappers; other backends stay hot.
    for key in [k for k in _JIT_CACHE if k[0] == spec.name]:
        _JIT_CACHE.pop(key, None)
    return spec


def names() -> list[str]:
    """Registered executor names (stable order of registration)."""
    return list(_REGISTRY)


# --------------------------------------------------------- sharded family ---

#: name prefix of the Z-sharded wrapper family (core/spatial_shard.py).
SHARDED_PREFIX = "sharded_"


def sharded_name(inner: str, num_devices: Optional[int] = None) -> str:
    """Registry name of the sharded wrapper around ``inner``:
    ``sharded_<inner>`` (all local devices) or ``sharded_<inner>@<n>``."""
    base = SHARDED_PREFIX + inner
    return base if num_devices is None else f"{base}@{num_devices}"


def parse_sharded(name: str) -> Optional[tuple[str, Optional[int]]]:
    """(inner, num_devices) for a sharded-family name, else None.
    Raises KeyError for a sharded name whose inner backend is unknown or
    whose slab count is not a positive integer."""
    if not name.startswith(SHARDED_PREFIX):
        return None
    rest = name[len(SHARDED_PREFIX):]
    inner, _, n = rest.partition("@")
    if inner not in spatial_shard.SHARDED_INNERS:
        raise KeyError(
            f"unknown executor {name!r}: sharded inner must be one of "
            f"{sorted(spatial_shard.SHARDED_INNERS)}"
        )
    if n and (not n.isdigit() or int(n) < 1):
        raise KeyError(
            f"unknown executor {name!r}: slab count after '@' must be a "
            "positive integer"
        )
    return inner, (int(n) if n else None)


def inner_of(name: str) -> str:
    """The single-device backend behind a sharded name (identity for
    non-sharded names) — what a device-count override re-wraps."""
    parsed = parse_sharded(name)
    return parsed[0] if parsed else name


def shardable(name: str) -> bool:
    """Whether the (inner of the) named executor has a sharded form."""
    return inner_of(name) in spatial_shard.SHARDED_INNERS


def _make_sharded_spec(inner: str, num_devices: Optional[int]) -> ExecutorSpec:
    def _apply(params, x, cfg, precision: str = "fp32"):
        return spatial_shard.sharded_executor_apply(
            inner, params, x, cfg, num_devices=num_devices, precision=precision
        )

    def _hbm(cfg, vol, batch: int = 1, precision: str = "fp32"):
        n = num_devices or jax.device_count()
        return traffic.meshnet_sharded_bytes(
            inner, cfg, vol, n, batch=batch, precision=precision
        )

    def _collective(cfg, vol, batch: int = 1, precision: str = "fp32"):
        n = num_devices or jax.device_count()
        return traffic.meshnet_collective_bytes(
            cfg, vol, n, batch=batch, precision=precision
        )

    slabs = f"{num_devices} Z-slabs" if num_devices else "one Z-slab per device"
    return ExecutorSpec(
        name=sharded_name(inner, num_devices),
        apply=_apply,
        streaming_apply=_apply,
        description=f"shard_map halo-exchange wrapper over {inner!r} ({slabs})",
        hbm_bytes=_hbm,
        collective_bytes=_collective,
    )


def ensure_sharded(inner_or_name: str, num_devices: Optional[int] = None) -> str:
    """Register (idempotently) and return the sharded wrapper's name.

    Accepts a bare inner backend (``"pallas_fused"``) or an existing
    sharded name (``"sharded_pallas_fused"``, re-pinned to ``num_devices``
    when given). This is how the pipeline's ``shard_devices`` and the
    engine's per-request device-count overrides materialise specs.
    """
    inner = inner_of(inner_or_name)
    if inner not in spatial_shard.SHARDED_INNERS:
        raise KeyError(
            f"executor {inner!r} cannot be sharded; supported inners: "
            f"{sorted(spatial_shard.SHARDED_INNERS)}"
        )
    name = sharded_name(inner, num_devices)
    if name not in _REGISTRY:
        register(_make_sharded_spec(inner, num_devices))
    return name


def default_executor(
    model: Optional[MeshNetConfig] = None,
    volume_shape: Optional[tuple[int, int, int]] = None,
    *,
    backend: Optional[str] = None,
    num_devices: Optional[int] = None,
    precision: str = "fp32",
) -> str:
    """The production default. On TPU: the sharded depth-first megakernel
    when more than one device is attached, the volume's Z dim divides
    evenly, and the *per-slab* (slab + RF-radius halo) tile plan fits the
    VMEM budget; on a single device, the megakernel when its plan fits,
    else the per-layer fused path; without a model to plan for, the fused
    path. On CPU hosts: XLA (Pallas interpret mode is a correctness path,
    far too slow to serve). Plans are judged at the request's resolved
    ``precision`` — a bf16/int8 working set can fit where fp32 does not.
    ``backend``/``num_devices`` override the host introspection (tests
    pin them)."""
    backend = backend or jax.default_backend()
    if backend != "tpu":
        return "xla"
    if model is None:
        return "pallas_fused"
    vol = volume_shape or (256, 256, 256)
    n = jax.device_count() if num_devices is None else num_devices
    if n > 1 and vol[0] % n == 0:
        radius = sum(model.dilations)
        slab = (vol[0] // n + 2 * radius, vol[1], vol[2])
        try:
            megakernel.plan_for_config(model, slab, precision=precision)
            # an explicit device count pins the spec ("@n"), so the
            # geometry validated here is the geometry that executes; the
            # introspected count stays unpinned (same n at run time).
            return ensure_sharded("pallas_megakernel", num_devices)
        except ValueError:
            pass
    try:
        megakernel.plan_for_config(model, vol, precision=precision)
        return "pallas_megakernel"
    except ValueError:
        return "pallas_fused"


def resolve(
    name: Optional[str],
    model: Optional[MeshNetConfig] = None,
    volume_shape: Optional[tuple[int, int, int]] = None,
    precision: str = "fp32",
) -> str:
    """Map None/"auto" to the backend default (model/shape/precision aware
    when the caller can supply them); validate explicit names. Sharded-
    family names (``sharded_<inner>[@n]``) register their spec on first
    use."""
    if name is None or name == AUTO:
        return default_executor(model, volume_shape, precision=precision)
    if name not in _REGISTRY:
        parsed = parse_sharded(name)  # KeyError on a bad sharded inner
        if parsed is not None:
            return ensure_sharded(parsed[0], parsed[1])
        raise KeyError(
            f"unknown executor {name!r}; registered: {sorted(_REGISTRY)} (or 'auto')"
        )
    return name


def get(name: Optional[str]) -> ExecutorSpec:
    """Fetch an executor spec, resolving "auto"."""
    return _REGISTRY[resolve(name)]


def apply(
    name: Optional[str],
    params,
    x: jax.Array,
    cfg: MeshNetConfig,
    precision: str = "fp32",
) -> jax.Array:
    """One-shot dispatch: run ``x`` through the named executor (eager —
    composable under an outer jit; use ``jitted_apply`` on hot paths)."""
    return get(name).apply(params, x, cfg, precision=precision)


def modeled_hbm_bytes(
    name: Optional[str],
    cfg: MeshNetConfig,
    volume_shape: tuple[int, int, int],
    batch: int = 1,
    precision: str = "fp32",
) -> Optional[int]:
    """Modeled HBM bytes of one forward under the named executor's
    schedule at the given precision policy, or None if the backend has no
    traffic model."""
    spec = _REGISTRY[resolve(name, cfg, volume_shape, precision)]
    if spec.hbm_bytes is None:
        return None
    return spec.hbm_bytes(cfg, volume_shape, batch=batch, precision=precision)


def modeled_collective_bytes(
    name: Optional[str],
    cfg: MeshNetConfig,
    volume_shape: tuple[int, int, int],
    batch: int = 1,
    precision: str = "fp32",
) -> int:
    """Modeled inter-device halo bytes of one forward under the named
    executor — 0 for single-device backends, the
    ``traffic.meshnet_collective_bytes`` model for the sharded family
    (reduced precisions ship narrower halos). Stamped on every pipeline
    run next to ``hbm_bytes_modeled``."""
    spec = _REGISTRY[resolve(name, cfg, volume_shape, precision)]
    if spec.collective_bytes is None:
        return 0
    return spec.collective_bytes(
        cfg, volume_shape, batch=batch, precision=precision
    )


_JIT_CACHE: dict[tuple[str, str, str], Callable] = {}


def _jitted(name: str, schedule: str, precision: str):
    key = (name, schedule, precision)
    if key not in _JIT_CACHE:
        spec = _REGISTRY[name]
        fn = spec.apply if schedule == "apply" else spec.streaming_apply

        def bound(params, x, cfg, _fn=fn, _p=precision):
            return _fn(params, x, cfg, precision=_p)

        # cfg is a frozen (hashable) dataclass -> static, so one executable
        # is compiled per (executor, schedule, precision, cfg, input shape)
        # and shared by every pipeline run and serving request that matches.
        _JIT_CACHE[key] = jax.jit(bound, static_argnums=(2,))
    return _JIT_CACHE[key]


def jitted_apply(
    name: Optional[str], schedule: str = "apply", precision: str = "fp32"
) -> Callable[[Any, jax.Array, MeshNetConfig], jax.Array]:
    """Jit-compiled executor forward, cached per (executor, schedule,
    precision) — the returned callable keeps the 3-arg ``(params, x,
    cfg)`` signature, with the precision policy bound in.

    This is the dispatch point for hot paths (pipeline.run, the engine,
    sub-volume closures): repeated calls — and batched serving requests in
    any order — reuse one compiled executable per input shape instead of
    re-tracing a fresh ``jax.jit(lambda ...)`` each run.
    ``schedule="streaming"`` selects the spec's layer-streamed variant.
    """
    if schedule not in ("apply", "streaming"):
        raise ValueError(f"schedule must be 'apply' or 'streaming', got {schedule!r}")
    quantize.validate(precision)
    return _jitted(resolve(name), schedule, precision)


def make_infer(
    name: Optional[str],
    params,
    cfg: MeshNetConfig,
    volume_shape: Optional[tuple[int, int, int]] = None,
    precision: str = "fp32",
) -> Callable[[jax.Array], jax.Array]:
    """Build the per-block closure used by sub-volume patching: maps
    (B, d, h, w[, C]) cubes -> (B, d, h, w, classes). Backed by the shared
    ``jitted_apply`` cache, and compiled once per cube shape because all
    cubes in a CubeDivider share a static shape. ``volume_shape`` is the
    *cube* shape the closure will serve — "auto" judges slab divisibility
    and VMEM plans on it, not on the full-volume default."""
    fn = jitted_apply(resolve(name, cfg, volume_shape, precision),
                      precision=precision)

    def infer(c: jax.Array) -> jax.Array:
        return fn(params, c, cfg)

    return infer


def _xla_apply(params, x, cfg, precision: str = "fp32"):
    if precision == "fp32":
        return meshnet.apply(params, x, cfg)
    return quantize.reference_apply(
        quantize.prepare_params(params, cfg, precision), x, cfg, precision
    )


register(
    ExecutorSpec(
        name="xla",
        apply=_xla_apply,
        streaming_apply=streaming.streaming_apply,
        description="reference XLA graph (meshnet.apply); parity oracle",
        hbm_bytes=traffic.meshnet_xla_bytes,
    )
)

register(
    ExecutorSpec(
        name="pallas_fused",
        apply=ops.meshnet_apply,
        streaming_apply=ops.meshnet_apply,
        description="fused Pallas conv+BN+ReLU per layer",
        hbm_bytes=traffic.meshnet_fused_bytes,
    )
)

register(
    ExecutorSpec(
        name="pallas_megakernel",
        apply=ops.meshnet_apply_megakernel,
        streaming_apply=ops.meshnet_apply_megakernel,
        description="depth-first tiled whole-stack Pallas megakernel; "
        "production TPU path when the tile plan fits VMEM",
        hbm_bytes=traffic.meshnet_megakernel_bytes,
    )
)

register(
    ExecutorSpec(
        name="streaming",
        apply=streaming.streaming_apply,
        streaming_apply=streaming.streaming_apply,
        description="lax.scan over stacked layers; memory-floor schedule",
        hbm_bytes=traffic.meshnet_streaming_bytes,
    )
)

# The sharded wrapper family (all-local-devices variants; pinned "@n"
# variants register on demand through resolve/ensure_sharded).
for _inner in spatial_shard.SHARDED_INNERS:
    ensure_sharded(_inner)
del _inner
