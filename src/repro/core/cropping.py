"""Cropping intervention — run a cheap brain-mask model, crop the bounding
box, run the expensive model on the crop (Tables VI/VII: cropping raises the
success rate by ~18% via IPTW and cuts inference time by ~5 s, because the
background air around the head is ~2/3 of the 256^3 volume).

JIT-friendliness: a data-dependent bounding box produces dynamic shapes, so
we crop to a *static* target size centred on the mask's bounding box with
``dynamic_slice`` — the Brainchop trick of "requested texture size" becomes
a static crop-shape picked from a ladder of compiled sizes.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class CropInfo(NamedTuple):
    start: jax.Array  # (3,) int32 crop origin in the source volume
    size: tuple[int, int, int]  # static crop shape


def mask_bounding_box(mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(lo, hi) inclusive-exclusive bounds of the True region, per axis."""
    mask = mask.astype(bool)
    bounds_lo, bounds_hi = [], []
    for axis in range(3):
        other = tuple(a for a in range(3) if a != axis)
        line = jnp.any(mask, axis=other)
        idx = jnp.arange(line.shape[0])
        lo = jnp.min(jnp.where(line, idx, line.shape[0]))
        hi = jnp.max(jnp.where(line, idx + 1, 0))
        # Empty mask -> full volume.
        lo = jnp.where(jnp.any(line), lo, 0)
        hi = jnp.where(jnp.any(line), hi, line.shape[0])
        bounds_lo.append(lo)
        bounds_hi.append(hi)
    return jnp.stack(bounds_lo), jnp.stack(bounds_hi)


@functools.partial(jax.jit, static_argnames=("size",))
def crop_to(vol: jax.Array, mask: jax.Array, size: tuple[int, int, int]) -> tuple[jax.Array, jax.Array]:
    """Crop ``vol`` to a static ``size`` box centred on ``mask``'s bbox.

    Returns (crop, start). The box is clamped inside the volume; if the mask
    is larger than ``size`` the crop centre still tracks the bbox centre
    (the caller picks ``size`` from the ladder via :func:`pick_crop_size`).
    """
    lo, hi = mask_bounding_box(mask)
    centre = (lo + hi) // 2
    start = centre - jnp.asarray(size) // 2
    start = jnp.clip(start, 0, jnp.asarray(vol.shape[:3]) - jnp.asarray(size))
    crop = jax.lax.dynamic_slice(vol, tuple(start), size)
    return crop, start


def uncrop(crop: jax.Array, start: jax.Array, full_shape: tuple[int, ...], fill=0) -> jax.Array:
    """Paste a cropped result back into a full-size volume."""
    out = jnp.full(full_shape, fill, dtype=crop.dtype)
    return jax.lax.dynamic_update_slice(out, crop, tuple(start) + (0,) * (len(full_shape) - 3))


# The "texture-size ladder": compiled crop sizes, one executable each.
CROP_LADDER: tuple[tuple[int, int, int], ...] = (
    (128, 128, 128),
    (160, 160, 160),
    (192, 192, 192),
    (224, 224, 224),
    (256, 256, 256),
)


def pick_crop_size(mask, ladder=CROP_LADDER, margin: int = 4) -> tuple[int, int, int]:
    """Smallest ladder entry that contains the mask bbox + margin.

    Runs on host (concrete values) — it chooses *which* compiled executable
    to dispatch, exactly like Brainchop choosing the texture size.
    """
    lo, hi = mask_bounding_box(mask)
    extent = jax.device_get(hi - lo) + 2 * margin
    vol_shape = mask.shape
    for size in ladder:
        size = tuple(min(s, v) for s, v in zip(size, vol_shape))
        if all(int(e) <= s for e, s in zip(extent, size)):
            return size
    return tuple(min(s, v) for s, v in zip(ladder[-1], vol_shape))
