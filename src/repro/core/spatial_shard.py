"""Distributed full-volume inference: the paper's sub-volume patching mapped
onto a device mesh.

Brainchop splits the volume into sub-cubes *in time* (sequential WebGL jobs)
because a browser has one GPU. A TPU pod has hundreds of chips, so the same
decomposition becomes *spatial sharding*: each device owns a Z-slab of the
volume, and instead of re-reading overlapping context from HBM per cube, the
overlap ("halo") is exchanged between neighbouring devices with
``collective_permute`` before every dilated conv layer.

Exactness: with a halo of ``dilation`` voxels per side per layer, the slab
conv equals the full-volume conv — the distributed analogue of the
``overlap >= RF`` rule in core/patching.py, paid incrementally per layer
(total exchanged per side = sum(dilations) = RF radius). Pod-edge devices
receive *zeros* from the void, which is exactly the volume's per-layer
'same' zero padding, so — unlike sub-volume patching — sharding has **no
boundary-band accuracy loss** (EXPERIMENTS.md §Perf H6).

Slabs thinner than the halo (small volumes over many devices, or the
one-shot RF-radius fetch below) are handled by *multi-hop* exchange:
``halo_exchange_z`` chains ``ppermute`` fetches through as many neighbours
as the halo spans, so any (volume, device-count) geometry with
``D % num_devices == 0`` is exact.

This module also implements the **sharded executor family** of the
registry (core/executors.py, DESIGN.md §2.2): ``sharded_executor_apply``
wraps any single-device backend and runs it per-slab under ``shard_map``
over a 1-D Z mesh —

  * ``xla`` inner — per-layer halo exchange + valid-Z conv (the original
    layer-wise schedule of this module);
  * ``pallas_fused`` inner — per-layer halo exchange + the fused Pallas
    conv+BN+ReLU kernel run 'same' on the extended slab, cropped back;
  * ``pallas_megakernel`` inner — ONE multi-hop exchange of the full
    RF radius (sum(dilations) = 46), then the depth-first megakernel runs
    on the slab+halo window (its DP tile plan computed on that shape) with
    dynamic Z mask bounds so per-layer 'same' zero padding is reproduced
    at the true volume edges, not the window edges.

All three are numerically equal to their single-device inner executor
(tests/test_sharded_executor.py enforces <=1e-4 across PAPER_MODELS at
2/4/8 slabs, including slabs thinner than the RF radius).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import meshnet
from repro.core.meshnet import MeshNetConfig
from repro.kernels import ops, quantize

# jax.shard_map landed after 0.4.x; fall back to the experimental home.
try:  # pragma: no cover - version-dependent
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

#: the Z-mesh axis name the sharded executors use.
SPATIAL_AXIS = "z"

#: the batch-mesh axis name used when device counts allow a second axis.
BATCH_AXIS = "b"


class ShardGeometryError(ValueError):
    """The requested slab geometry cannot run: the Z dim does not divide
    into the slab count, or the host lacks the devices. The pipeline maps
    this to a failed telemetry record (fail_type='shard_geometry') instead
    of letting it escape — unlike other ValueErrors, which indicate bugs
    or bad input and propagate."""


def _axis_size(axis_name: str) -> int:
    """Static size of a shard_map axis (compat across jax versions)."""
    try:
        return jax.lax.axis_size(axis_name)  # jax >= 0.4.32-ish
    except AttributeError:
        size = jax.core.axis_frame(axis_name)  # 0.4.37: returns the int
        return getattr(size, "size", size)


@functools.lru_cache(maxsize=32)
def mesh_for(num_devices: int | None = None, axis: str = SPATIAL_AXIS) -> Mesh:
    """A 1-D Z mesh over the first ``num_devices`` local devices, cached so
    every pipeline run / engine request with the same slab count shares one
    Mesh object (and one compiled executable via the registry's jit cache).
    """
    n = num_devices or jax.device_count()
    devs = jax.devices()
    if n > len(devs):
        raise ShardGeometryError(
            f"sharded executor wants {n} devices; host has {len(devs)}"
        )
    return Mesh(np.array(devs[:n]), (axis,))


@functools.lru_cache(maxsize=32)
def mesh_for_batched(
    batch_shards: int,
    num_devices: int,
    axis: str = SPATIAL_AXIS,
    batch_axis: str = BATCH_AXIS,
) -> Mesh:
    """A 2-D (batch, Z) mesh: ``batch_shards`` rows of ``num_devices``
    Z-slab columns. Each batch row runs the full slab pipeline on its
    share of the leading dim; halo ``ppermute``s stay within a row (the
    named Z axis), so the slab numerics are identical to the 1-D mesh.
    Cached like ``mesh_for`` so repeat (batch, slab) signatures share one
    Mesh object and one compiled executable."""
    total = batch_shards * num_devices
    devs = jax.devices()
    if total > len(devs):
        raise ShardGeometryError(
            f"batched sharded executor wants {batch_shards}x{num_devices} "
            f"devices; host has {len(devs)}"
        )
    return Mesh(
        np.array(devs[:total]).reshape(batch_shards, num_devices),
        (batch_axis, axis),
    )


def auto_batch_shards(batch: int, num_devices: int) -> int:
    """The largest batch-axis size a host can add on top of ``num_devices``
    Z slabs: the biggest divisor of ``batch`` with ``k * num_devices``
    devices available. 1 when the host has no spare devices (the 1-D
    mesh), so single-device containers and exactly-sized hosts keep the
    legacy layout."""
    spare = jax.device_count() // max(num_devices, 1)
    for k in range(min(int(batch), spare), 1, -1):
        if batch % k == 0:
            return k
    return 1


def _fetch_slab(x: jax.Array, offset: int, axis_name: str, n: int) -> jax.Array:
    """The slab of the device ``offset`` positions before me (offset > 0)
    or after me (offset < 0); zeros where no such device exists (the pod
    edge — exactly the volume's zero padding)."""
    off = abs(offset)
    if off >= n:
        return jnp.zeros_like(x)
    if offset > 0:  # from device i - offset: i - offset sends to i
        perm = [(i, i + off) for i in range(n - off)]
    else:  # from device i + offset
        perm = [(i, i - off) for i in range(off, n)]
    return jax.lax.ppermute(x, axis_name, perm)


def halo_exchange_z(x: jax.Array, halo: int, axis_name: str) -> jax.Array:
    """Concatenate ``halo`` Z-slices from both neighbour chains onto a slab.

    x: (B, Dz_local, H, W, C) -> (B, Dz_local + 2*halo, H, W, C).
    Pod edges receive zeros (the volume's zero 'same' padding). Halos wider
    than the local slab are fetched *multi-hop*: ceil(halo / Dz_local)
    chained ``ppermute`` steps per side, the farthest hop trimmed to the
    remainder — so one exchange of ``n*h`` provides exactly the context of
    ``n`` per-layer exchanges of ``h`` (tests/test_properties.py).
    """
    if halo == 0:
        return x
    n = _axis_size(axis_name)
    if n == 1:
        pad = [(0, 0), (halo, halo), (0, 0), (0, 0), (0, 0)]
        return jnp.pad(x, pad)
    dloc = x.shape[1]
    hops = -(-halo // dloc)  # ceil
    rem = halo - (hops - 1) * dloc  # slices needed from the farthest hop
    left = []  # farthest neighbour first, so axis-1 order is global order
    right = []
    for j in range(hops, 0, -1):
        src = x[:, -rem:] if j == hops and rem < dloc else x
        left.append(_fetch_slab(src, j, axis_name, n))
    for j in range(1, hops + 1):
        src = x[:, :rem] if j == hops and rem < dloc else x
        right.append(_fetch_slab(src, -j, axis_name, n))
    return jnp.concatenate(left + [x] + right, axis=1)


def _conv_layer_slab(
    layer, x, dilation: int, cfg: MeshNetConfig, axis_name: str,
    precision: str = "fp32",
):
    """One MeshNet block on a Z-slab: halo exchange + valid-Z conv. At
    reduced precision the exchanged halos ship in the activation storage
    dtype (bf16), the conv accumulates fp32 on the (possibly int8) taps,
    and the dequant/BN epilogue runs fp32 — the same rounding points as
    the single-device backends, so slab parity holds per policy."""
    x = halo_exchange_z(x, dilation, axis_name)
    pad = dilation  # 'same' padding in H, W; Z context comes from the halo
    if precision == "fp32":
        out = jax.lax.conv_general_dilated(
            x,
            layer["w"],
            (1, 1, 1),
            [(0, 0), (pad, pad), (pad, pad)],
            rhs_dilation=(dilation,) * 3,
            dimension_numbers=("NDHWC", "DHWIO", "NDHWC"),
        ) + layer["b"]
        if cfg.use_batchnorm:
            out = (out - layer["bn_mean"]) * jax.lax.rsqrt(layer["bn_var"] + 1e-5)
            out = out * layer["bn_scale"] + layer["bn_bias"]
        return jax.nn.relu(out)
    # the one shared reduced-precision block (z_same=False: Z context
    # came from the halo exchange above) — same rounding points as the
    # xla oracle and the streaming first layer, by construction
    return quantize.conv_block_reduced(
        x, layer, dilation, cfg.use_batchnorm,
        quantize.act_dtype(precision), z_same=False,
    )


def _head(params, x: jax.Array, precision: str = "fp32") -> jax.Array:
    head = params["head"]
    if precision == "fp32":
        return jnp.einsum("bdhwi,io->bdhwo", x, head["w"][0, 0, 0]) + head["b"]
    adt = quantize.act_dtype(precision)
    logits = (
        jnp.einsum(
            "bdhwi,io->bdhwo",
            x,
            head["w"][0, 0, 0].astype(adt),
            preferred_element_type=jnp.float32,
        )
        + head["b"].astype(jnp.float32)
    )
    return logits.astype(adt)


def _dequant_slab_input(x, precision: str):
    """Bring a slab into the policy's activation dtype before the layer-
    wise schedules: pre-quantized int8 input dequants by the fixed
    conformed-volume scale; float input is (for int8w) first snapped to
    the same int8 grid so slab parity with the single-device backends is
    exact rather than approximate."""
    if precision == "fp32":
        return x
    adt = quantize.act_dtype(precision)
    if precision == "int8w":
        if x.dtype != jnp.int8:
            x = quantize.quantize_input(x)
        return x.astype(adt) * jnp.asarray(quantize.INPUT_SCALE, adt)
    return x.astype(adt)


def _slab_xla(
    params, x, cfg: MeshNetConfig, axis_name: str, precision: str = "fp32"
) -> jax.Array:
    """Layer-wise schedule, XLA inner: exchange d, valid-Z conv, repeat."""
    x = _dequant_slab_input(x, precision)
    for i, d in enumerate(cfg.dilations):
        x = _conv_layer_slab(params["layers"][i], x, d, cfg, axis_name, precision)
    return _head(params, x, precision)


def _slab_fused(
    params, x, cfg: MeshNetConfig, axis_name: str, precision: str = "fp32"
) -> jax.Array:
    """Layer-wise schedule, fused Pallas inner: exchange d, run the fused
    conv+BN+ReLU kernel 'same' on the extended slab, crop the polluted
    d-band back off. 'Same' output at positions >= d from the extended
    edge only taps in-window data, so the crop is exact; pod edges hold
    zero halos == the volume's per-layer zero padding. Reduced precisions
    exchange bf16 halos and stream bf16/int8 weights into the kernel,
    whose dequant epilogue is the same as the unsharded fused path."""
    x = _dequant_slab_input(x, precision)
    # params arrive already prepared: sharded_executor_apply quantizes
    # once outside shard_map so the prep is not replicated per device
    use_quant = precision != "fp32"
    for i, d in enumerate(cfg.dilations):
        layer = params["layers"][i]
        if use_quant:
            bias, scale, offset = quantize.fold_epilogue(layer, cfg.use_batchnorm)
        elif cfg.use_batchnorm:
            bias = layer["b"]
            scale, offset = ops.fold_batchnorm(layer)
        else:
            bias = layer["b"]
            scale = offset = None
        xe = halo_exchange_z(x, d, axis_name)
        out = ops.dilated_conv3d(
            xe, layer["w"], bias,
            dilation=d, scale=scale, offset=offset, fuse_affine=True,
        )
        x = out[:, d:-d]
    return _head(params, x, precision)


def _slab_megakernel(
    params, x, cfg: MeshNetConfig, axis_name: str, precision: str = "fp32"
) -> jax.Array:
    """One-shot schedule, megakernel inner: a single multi-hop exchange of
    the full RF radius feeds the depth-first megakernel, whose tile plan is
    computed on the slab+halo window. Dynamic Z mask bounds tell the kernel
    where the *true* volume ends inside the window, so per-layer 'same'
    zero padding is reproduced at pod edges (bit-exact boundary), while
    interior window edges only pollute the halo band the final crop drops.
    For int8w the exchange ships the *quantized* slab (int8 halos — the
    cheapest collectives of the family) and the kernel dequants in VMEM.
    """
    n = _axis_size(axis_name)
    dloc = x.shape[1]
    radius = sum(cfg.dilations)
    if precision == "int8w" and x.dtype != jnp.int8:
        # quantize before exchanging: pointwise, so quantize-then-exchange
        # equals exchange-then-quantize, and the halo bytes quarter
        x = quantize.quantize_input(x)
    elif precision == "bf16":
        x = x.astype(quantize.act_dtype(precision))
    xe = halo_exchange_z(x, radius, axis_name)
    g = jax.lax.axis_index(axis_name) * dloc  # my slab's global Z start
    # local coord z holds global z = g - radius + z; valid global range
    # [0, n * dloc) maps to local [radius - g, radius - g + n * dloc).
    z_bounds = jnp.stack(
        [radius - g, radius - g + n * dloc]
    ).astype(jnp.int32)
    out = ops.meshnet_apply_megakernel(
        params, xe, cfg, z_bounds=z_bounds, precision=precision
    )
    return out[:, radius : radius + dloc]


_SLAB_FNS = {
    "xla": _slab_xla,
    "pallas_fused": _slab_fused,
    "pallas_megakernel": _slab_megakernel,
}

#: single-device backends the sharded wrapper accepts as inners.
SHARDED_INNERS = tuple(_SLAB_FNS)


def sharded_executor_apply(
    inner: str,
    params,
    x: jax.Array,
    cfg: MeshNetConfig,
    *,
    num_devices: int | None = None,
    axis: str = SPATIAL_AXIS,
    precision: str = "fp32",
    batch_shards: int | None = None,
) -> jax.Array:
    """Z-sharded MeshNet forward through the named inner backend.

    x: (B, D, H, W) or (B, D, H, W, C); D must divide by the slab count.
    The registry's ``sharded_<inner>`` specs (core/executors.py) are thin
    closures over this function; parity with the single-device inner is
    the sharded family's contract (tests/test_sharded_executor.py),
    per precision policy: the layer-wise inners exchange bf16 halos, the
    megakernel inner's one-shot RF fetch ships the int8 input under
    "int8w" (tests/test_precision.py).

    ``batch_shards`` adds the batch as a second mesh axis where device
    counts allow: ``batch_shards * num_devices`` devices arranged as a
    (batch, Z) grid, each row serving ``B / batch_shards`` volumes.
    ``None`` picks ``auto_batch_shards`` (1 unless the host has spare
    devices beyond the slab count); pass 1 to force the legacy 1-D mesh.
    """
    if inner not in _SLAB_FNS:
        raise KeyError(
            f"unknown sharded inner {inner!r}; supported: {sorted(_SLAB_FNS)}"
        )
    n = num_devices or jax.device_count()
    if x.ndim == 4:
        x = x[..., None]
    if x.shape[1] % n:
        raise ShardGeometryError(
            f"Z dim {x.shape[1]} not divisible by {n} slabs — pick a device "
            "count that divides the volume depth"
        )
    bs = auto_batch_shards(x.shape[0], n) if batch_shards is None else int(batch_shards)
    if bs > 1:
        if x.shape[0] % bs:
            raise ShardGeometryError(
                f"batch {x.shape[0]} not divisible by {bs} batch shards"
            )
        mesh = mesh_for_batched(bs, n, axis)
        in_spec = P(BATCH_AXIS, axis, None, None, None)
    else:
        mesh = mesh_for(n, axis)
        in_spec = P(None, axis, None, None, None)
    slab_fn = _SLAB_FNS[inner]
    if precision != "fp32":
        # prepare once, outside shard_map, so every slab streams the same
        # quantized weights (and the prep is not replicated per device)
        params = quantize.prepare_params(params, cfg, precision)

    fn = _shard_map(
        lambda p, xs: slab_fn(p, xs, cfg, axis, precision),
        mesh=mesh,
        in_specs=(P(), in_spec),
        out_specs=in_spec,
        # pallas_call has no replication rule; all our outputs are honestly
        # P(None, "z", ...)-sharded, so skipping the rep check is sound.
        check_rep=False,
    )
    # Lay inputs out to match the specs (callers may pass single-device arrays).
    params = jax.device_put(params, NamedSharding(mesh, P()))
    x = jax.device_put(x, NamedSharding(mesh, in_spec))
    return fn(params, x)


def sharded_apply(
    params,
    x: jax.Array,
    cfg: MeshNetConfig,
    mesh: Mesh,
    *,
    spatial_axis: str = "model",
    batch_axis: str | None = "data",
) -> jax.Array:
    """Full-volume MeshNet inference with the volume Z-sharded over
    ``spatial_axis`` and the batch over ``batch_axis`` (the standalone
    2-D-mesh demo; the executor registry path is ``sharded_executor_apply``).

    x: (B, D, H, W) or (B, D, H, W, 1); D must divide the spatial axis size.
    """
    if x.ndim == 4:
        x = x[..., None]
    batch_spec = batch_axis if batch_axis else None
    in_spec = P(batch_spec, spatial_axis, None, None, None)

    def slab_fn(params, xs):
        for i, d in enumerate(cfg.dilations):
            xs = _conv_layer_slab(params["layers"][i], xs, d, cfg, spatial_axis)
        head = params["head"]
        return meshnet.dilated_conv3d(xs, head["w"], head["b"], dilation=1)

    fn = _shard_map(
        slab_fn,
        mesh=mesh,
        in_specs=(P(), in_spec),
        out_specs=in_spec,
    )
    # Lay inputs out to match the specs (callers may pass single-device arrays).
    params = jax.device_put(params, NamedSharding(mesh, P()))
    x = jax.device_put(x, NamedSharding(mesh, in_spec))
    return fn(params, x)


def make_sharded_infer(params, cfg: MeshNetConfig, mesh: Mesh, **kw):
    """jit-compiled sharded inference fn: (B, D, H, W) -> logits."""

    @functools.partial(jax.jit, donate_argnums=(0,))
    def infer(x):
        return sharded_apply(params, x, cfg, mesh, **kw)

    return infer


def replicate_params(params, mesh: Mesh):
    """MeshNet weights are ~kB-scale: replicate everywhere (the paper ships
    them to every client; we ship them to every chip)."""
    sharding = NamedSharding(mesh, P())
    return jax.device_put(params, sharding)
