"""Distributed full-volume inference: the paper's sub-volume patching mapped
onto a device mesh.

Brainchop splits the volume into sub-cubes *in time* (sequential WebGL jobs)
because a browser has one GPU. A TPU pod has hundreds of chips, so the same
decomposition becomes *spatial sharding*: each device owns a Z-slab of the
volume, and instead of re-reading overlapping context from HBM per cube, the
overlap ("halo") is exchanged between neighbouring devices with
``collective_permute`` before every dilated conv layer.

Exactness: with a halo of ``dilation`` voxels per side per layer, the slab
conv equals the full-volume conv — the distributed analogue of the
``overlap >= RF`` rule in core/patching.py, paid incrementally per layer
(total exchanged per side = sum(dilations) = RF radius).

Implemented with ``shard_map`` so every collective is explicit — this is
the module the dry-run exercises for the meshnet configs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import meshnet
from repro.core.meshnet import MeshNetConfig


def halo_exchange_z(x: jax.Array, halo: int, axis_name: str) -> jax.Array:
    """Concatenate `halo` Z-slices from both neighbours onto a local slab.

    x: (B, Dz_local, H, W, C) -> (B, Dz_local + 2*halo, H, W, C).
    Pod edges receive zeros (the volume's zero 'same' padding).
    """
    n = jax.lax.axis_size(axis_name)
    if n == 1:
        pad = [(0, 0), (halo, halo), (0, 0), (0, 0), (0, 0)]
        return jnp.pad(x, pad)
    if x.shape[1] < halo:
        raise ValueError(
            f"local Z-slab ({x.shape[1]}) smaller than halo ({halo}): "
            "use fewer spatial shards or a larger volume (need "
            "D/shards >= max dilation)."
        )
    # No wraparound pairs: devices with no sender receive zeros, which is
    # exactly the volume's zero 'same' padding at the pod edges.
    fwd = [(i, i + 1) for i in range(n - 1)]  # send my tail to next
    bwd = [(i, i - 1) for i in range(1, n)]  # send my head to prev
    from_prev = jax.lax.ppermute(x[:, -halo:], axis_name, fwd)
    from_next = jax.lax.ppermute(x[:, :halo], axis_name, bwd)
    return jnp.concatenate([from_prev, x, from_next], axis=1)


def _conv_layer_slab(layer, x, dilation: int, cfg: MeshNetConfig, axis_name: str):
    """One MeshNet block on a Z-slab: halo exchange + valid-Z conv."""
    x = halo_exchange_z(x, dilation, axis_name)
    pad = dilation  # 'same' padding in H, W; Z context comes from the halo
    out = jax.lax.conv_general_dilated(
        x,
        layer["w"],
        (1, 1, 1),
        [(0, 0), (pad, pad), (pad, pad)],
        rhs_dilation=(dilation,) * 3,
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"),
    ) + layer["b"]
    if cfg.use_batchnorm:
        out = (out - layer["bn_mean"]) * jax.lax.rsqrt(layer["bn_var"] + 1e-5)
        out = out * layer["bn_scale"] + layer["bn_bias"]
    return jax.nn.relu(out)


def sharded_apply(
    params,
    x: jax.Array,
    cfg: MeshNetConfig,
    mesh: Mesh,
    *,
    spatial_axis: str = "model",
    batch_axis: str | None = "data",
) -> jax.Array:
    """Full-volume MeshNet inference with the volume Z-sharded over
    ``spatial_axis`` and the batch over ``batch_axis``.

    x: (B, D, H, W) or (B, D, H, W, 1); D must divide the spatial axis size.
    """
    if x.ndim == 4:
        x = x[..., None]
    batch_spec = batch_axis if batch_axis else None
    in_spec = P(batch_spec, spatial_axis, None, None, None)

    def slab_fn(params, xs):
        for i, d in enumerate(cfg.dilations):
            xs = _conv_layer_slab(params["layers"][i], xs, d, cfg, spatial_axis)
        head = params["head"]
        return meshnet.dilated_conv3d(xs, head["w"], head["b"], dilation=1)

    fn = jax.shard_map(
        slab_fn,
        mesh=mesh,
        in_specs=(P(), in_spec),
        out_specs=in_spec,
    )
    # Lay inputs out to match the specs (callers may pass single-device arrays).
    params = jax.device_put(params, NamedSharding(mesh, P()))
    x = jax.device_put(x, NamedSharding(mesh, in_spec))
    return fn(params, x)


def make_sharded_infer(params, cfg: MeshNetConfig, mesh: Mesh, **kw):
    """jit-compiled sharded inference fn: (B, D, H, W) -> logits."""

    @functools.partial(jax.jit, donate_argnums=(0,))
    def infer(x):
        return sharded_apply(params, x, cfg, mesh, **kw)

    return infer


def replicate_params(params, mesh: Mesh):
    """MeshNet weights are ~kB-scale: replicate everywhere (the paper ships
    them to every client; we ship them to every chip)."""
    sharding = NamedSharding(mesh, P())
    return jax.device_put(params, sharding)
