"""Conform preprocessing — the FastSurfer `conform` step Brainchop runs via
Pyodide (mriconvert.js): reshape the raw T1 to a cubic grid (256^3 in the
paper), resample to 1 mm isotropic, and rescale intensities to uint8-like
[0, 255] with robust quantile clipping.

Pure JAX (trilinear resampling via gather), jit-able with static output
shape, so it can run on-device as stage 1 of the pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _trilinear_sample(vol: jax.Array, coords: jax.Array) -> jax.Array:
    """Sample `vol` (D,H,W) at float coords (3, N) with edge clamping."""
    d, h, w = vol.shape
    cz, cy, cx = coords
    z0 = jnp.clip(jnp.floor(cz).astype(jnp.int32), 0, d - 1)
    y0 = jnp.clip(jnp.floor(cy).astype(jnp.int32), 0, h - 1)
    x0 = jnp.clip(jnp.floor(cx).astype(jnp.int32), 0, w - 1)
    z1, y1, x1 = jnp.minimum(z0 + 1, d - 1), jnp.minimum(y0 + 1, h - 1), jnp.minimum(x0 + 1, w - 1)
    fz = jnp.clip(cz - z0, 0.0, 1.0)
    fy = jnp.clip(cy - y0, 0.0, 1.0)
    fx = jnp.clip(cx - x0, 0.0, 1.0)

    def at(zi, yi, xi):
        return vol[zi, yi, xi]

    c000, c001 = at(z0, y0, x0), at(z0, y0, x1)
    c010, c011 = at(z0, y1, x0), at(z0, y1, x1)
    c100, c101 = at(z1, y0, x0), at(z1, y0, x1)
    c110, c111 = at(z1, y1, x0), at(z1, y1, x1)
    c00 = c000 * (1 - fx) + c001 * fx
    c01 = c010 * (1 - fx) + c011 * fx
    c10 = c100 * (1 - fx) + c101 * fx
    c11 = c110 * (1 - fx) + c111 * fx
    c0 = c00 * (1 - fy) + c01 * fy
    c1 = c10 * (1 - fy) + c11 * fy
    return c0 * (1 - fz) + c1 * fz


@functools.partial(jax.jit, static_argnames=("out_shape",))
def resample(vol: jax.Array, out_shape: tuple[int, int, int], voxel_size=(1.0, 1.0, 1.0)) -> jax.Array:
    """Trilinearly resample `vol` onto an `out_shape` grid.

    `voxel_size` is the source voxel size in mm; the target grid is 1 mm
    isotropic centred on the source volume (the conform convention).
    """
    d, h, w = out_shape
    src = jnp.asarray(vol, jnp.float32)
    sd, sh, sw = src.shape
    # Target voxel (i,j,k) in mm -> source index = mm / src_voxel_size,
    # with both grids centred.
    zs = (jnp.arange(d) - (d - 1) / 2.0) / voxel_size[0] + (sd - 1) / 2.0
    ys = (jnp.arange(h) - (h - 1) / 2.0) / voxel_size[1] + (sh - 1) / 2.0
    xs = (jnp.arange(w) - (w - 1) / 2.0) / voxel_size[2] + (sw - 1) / 2.0
    zz, yy, xx = jnp.meshgrid(zs, ys, xs, indexing="ij")
    coords = jnp.stack([zz.ravel(), yy.ravel(), xx.ravel()])
    return _trilinear_sample(src, coords).reshape(out_shape)


@jax.jit
def rescale_intensity(vol: jax.Array, lo_q: float = 0.01, hi_q: float = 0.99) -> jax.Array:
    """Robust rescale to [0, 1] by quantile clipping (conform's uint8 rescale,
    kept in float). Also zeroes non-finite voxels ("eliminate noisy voxels")."""
    vol = jnp.where(jnp.isfinite(vol), vol, 0.0)
    lo = jnp.quantile(vol, lo_q)
    hi = jnp.quantile(vol, hi_q)
    out = (vol - lo) / jnp.maximum(hi - lo, 1e-6)
    return jnp.clip(out, 0.0, 1.0)


class DegenerateVolumeError(ValueError):
    """The input volume has no intensity dynamic range — all-zero, a
    constant fill, or nothing but non-finite voxels. The quantile
    rescale would collapse it to a flat field and the network would
    "segment" pure noise, so conform refuses it with a typed error the
    pipeline converts into a failed telemetry record (never a crash):
    the preprocessing analogue of the serving tier's typed fault
    taxonomy."""

    def __init__(self, lo: float, hi: float):
        super().__init__(
            "degenerate input volume: finite intensity range "
            f"[{lo!r}, {hi!r}] has no dynamic range to conform"
        )
        self.lo = lo
        self.hi = hi


def conform(
    vol: jax.Array,
    out_shape: tuple[int, int, int] = (256, 256, 256),
    voxel_size=(1.0, 1.0, 1.0),
) -> jax.Array:
    """Full conform: resample to cubic isotropic grid + intensity rescale.

    Raises ``DegenerateVolumeError`` (host-side, before any resampling
    compute) when a well-formed 3-D volume is constant / all-zero /
    all-non-finite — the jitted stages stay jit-able; this wrapper is
    the host entry point and may look at values. Malformed (non-3-D)
    payloads are NOT intercepted: they fail in resample exactly as
    before, so the serving tier's garbage-volume classification is
    untouched."""
    vol = jnp.asarray(vol, jnp.float32)
    if vol.ndim == 3:
        finite = jnp.where(jnp.isfinite(vol), vol, 0.0)
        lo = float(jnp.min(finite))
        hi = float(jnp.max(finite))
        if not (hi - lo > 0.0):
            raise DegenerateVolumeError(lo, hi)
    if vol.shape != out_shape:
        vol = resample(vol, out_shape, voxel_size)
    return rescale_intensity(vol)
