"""Layer-by-layer progressive inference — Brainchop's memory strategy.

The paper: "progressive utilization of the MeshNet model on a layer-by-layer
basis, coupled with the strategic disposal of the MRI tensor from the
preceding layer" — i.e. at any instant only one layer's weights + one
activation live in memory.

TPU/JAX adaptation: MeshNet's hidden layers 2..L are shape-uniform
(C -> C, 3^3 kernels), so we *stack* their weights and run a
``jax.lax.scan`` whose carry is the single live activation. XLA then
allocates exactly one activation buffer (double-buffered) regardless of
depth, and the per-layer dilation rides along as a scanned operand.
Input/output buffers are donated by the jit wrapper in ops-level callers.

This module is also the template for the transformer zoo: every assigned
architecture scans over stacked layer params for the same reason.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import meshnet
from repro.core.meshnet import MeshNetConfig
from repro.kernels import quantize


def stack_layer_params(params) -> tuple[dict, dict, dict]:
    """Split MeshNet params into (first_layer, stacked_middle, head).

    Layer 1 has in_channels != channels, so it stays unstacked; layers
    2..L-1 are stacked leaf-wise into arrays with a leading layer axis.
    """
    layers = params["layers"]
    first = layers[0]
    middle = jax.tree.map(lambda *xs: jnp.stack(xs), *layers[1:])
    return first, middle, params["head"]


def streaming_apply(
    params, x: jax.Array, cfg: MeshNetConfig, precision: str = "fp32"
) -> jax.Array:
    """Memory-streamed forward pass: logits (B, D, H, W, classes).

    Mathematically identical to ``meshnet.apply`` (inference mode); the
    difference is the execution schedule: scan keeps one live activation.

    ``precision`` (kernels/quantize.py): "fp32" is the legacy path below;
    the reduced policies keep the identical scan schedule but carry the
    live activation in bf16 with fp32 tap accumulation, and for "int8w"
    scan over *stacked int8 weights* with the per-output-channel dequant
    (and folded BN) applied as the fp32 epilogue — the streamed weight
    footprint, this schedule's defining cost, shrinks 4x.
    """
    if precision != "fp32":
        return _streaming_apply_precision(params, x, cfg, precision)
    if x.ndim == 4:
        x = x[..., None]
    first, middle, head = stack_layer_params(params)
    dilations = jnp.asarray(cfg.dilations[1:], jnp.int32)

    x, _ = meshnet.apply_layer(first, x, cfg.dilations[0], cfg, training=False)

    dmax = int(max(cfg.dilations))

    def step(carry, inp):
        layer, dilation = inp
        # Dilation is a *traced* scanned operand, so we cannot pass it to
        # conv_general_dilated (static). Instead the 3^3 dilated conv is 27
        # shifted taps: out[p] = sum_t w[t] * x[p + dilation*t]. Shifts are
        # realised as dynamic_slice into a once-padded buffer (zero 'same'
        # padding semantics; dynamic_slice accepts traced starts).
        xp = jnp.pad(carry, [(0, 0)] + [(dmax, dmax)] * 3 + [(0, 0)])
        w3 = layer["w"]  # (3, 3, 3, Cin, Cout)
        acc = jnp.zeros(carry.shape[:-1] + (w3.shape[-1],), carry.dtype)
        for tz in (-1, 0, 1):
            for ty in (-1, 0, 1):
                for tx in (-1, 0, 1):
                    start = (
                        0,
                        dmax + dilation * tz,
                        dmax + dilation * ty,
                        dmax + dilation * tx,
                        0,
                    )
                    tap = jax.lax.dynamic_slice(xp, start, carry.shape)
                    acc = acc + jnp.einsum(
                        "bdhwi,io->bdhwo", tap, w3[tz + 1, ty + 1, tx + 1]
                    )
        out = acc + layer["b"]
        if cfg.use_batchnorm:
            out = (out - layer["bn_mean"]) * jax.lax.rsqrt(layer["bn_var"] + 1e-5)
            out = out * layer["bn_scale"] + layer["bn_bias"]
        return jax.nn.relu(out), None

    x, _ = jax.lax.scan(step, x, (middle, dilations))
    return meshnet.dilated_conv3d(x, head["w"], head["b"], dilation=1)


def _streaming_apply_precision(
    params, x: jax.Array, cfg: MeshNetConfig, precision: str
) -> jax.Array:
    """The scan schedule at bf16/int8w storage (see streaming_apply)."""
    quantize.validate(precision)
    params = quantize.prepare_params(params, cfg, precision)
    adt = quantize.act_dtype(precision)
    if x.ndim == 4:
        x = x[..., None]
    if precision == "int8w":
        if x.dtype != jnp.int8:
            x = quantize.quantize_input(x)
        x = x.astype(adt) * jnp.asarray(quantize.INPUT_SCALE, adt)
    else:
        x = x.astype(adt)
    first, middle, head = stack_layer_params(params)
    dilations = jnp.asarray(cfg.dilations[1:], jnp.int32)
    # layer 1 runs unstacked through the one shared reduced-precision
    # block (static dilation); the scanned middle layers below must keep
    # the same rounding points by hand — their dilation is traced, so the
    # conv is 27 dynamic-slice taps instead of lax.conv.
    x = quantize.conv_block_reduced(
        x, first, cfg.dilations[0], cfg.use_batchnorm, adt
    )
    # fold_epilogue is elementwise over the channel axis, so it maps over
    # the stacked (L, C) leaves unchanged.
    mid_epilogue = quantize.fold_epilogue(middle, cfg.use_batchnorm)

    dmax = int(max(cfg.dilations))

    def step(carry, inp):
        layer, (bias, scale, offset), dilation = inp
        xp = jnp.pad(carry, [(0, 0)] + [(dmax, dmax)] * 3 + [(0, 0)])
        w3 = layer["w"]
        if w3.dtype == jnp.int8:
            w3 = w3.astype(adt)
        acc = jnp.zeros(
            carry.shape[:-1] + (w3.shape[-1],), jnp.float32
        )
        for tz in (-1, 0, 1):
            for ty in (-1, 0, 1):
                for tx in (-1, 0, 1):
                    start = (
                        0,
                        dmax + dilation * tz,
                        dmax + dilation * ty,
                        dmax + dilation * tx,
                        0,
                    )
                    tap = jax.lax.dynamic_slice(xp, start, carry.shape)
                    acc = acc + jnp.einsum(
                        "bdhwi,io->bdhwo",
                        tap,
                        w3[tz + 1, ty + 1, tx + 1],
                        preferred_element_type=jnp.float32,
                    )
        out = jnp.maximum((acc + bias) * scale + offset, 0.0)
        return out.astype(adt), None

    x, _ = jax.lax.scan(step, x, (middle, mid_epilogue, dilations))
    logits = (
        jnp.einsum(
            "bdhwi,io->bdhwo",
            x,
            head["w"][0, 0, 0].astype(adt),
            preferred_element_type=jnp.float32,
        )
        + head["b"].astype(jnp.float32)
    )
    return logits.astype(adt)


def streaming_apply_unrolled(params, x: jax.Array, cfg: MeshNetConfig) -> jax.Array:
    """Variant without the padded-kernel trick: a Python loop over layers
    with explicit buffer donation between steps via jit boundaries.

    Closest to what Brainchop literally does (one WebGL program per layer,
    dispose the previous tensor). Used for comparison in benchmarks; the
    scan version is the production path.
    """
    if x.ndim == 4:
        x = x[..., None]

    @jax.jit
    def run_first(layer, x):
        out, _ = meshnet.apply_layer(layer, x, cfg.dilations[0], cfg, training=False)
        return out

    x = run_first(params["layers"][0], x)
    for i, d in enumerate(cfg.dilations[1:], start=1):
        # donate_argnums frees the previous activation as soon as the layer
        # kernel has consumed it — the "strategic disposal".
        step = jax.jit(
            lambda layer, x, d=d: meshnet.apply_layer(layer, x, d, cfg, training=False)[0],
            donate_argnums=(1,),
        )
        x = step(params["layers"][i], x)
    head = params["head"]
    return meshnet.dilated_conv3d(x, head["w"], head["b"], dilation=1)
