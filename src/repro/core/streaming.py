"""Layer-by-layer progressive inference — Brainchop's memory strategy.

The paper: "progressive utilization of the MeshNet model on a layer-by-layer
basis, coupled with the strategic disposal of the MRI tensor from the
preceding layer" — i.e. at any instant only one layer's weights + one
activation live in memory.

TPU/JAX adaptation: MeshNet's hidden layers 2..L are shape-uniform
(C -> C, 3^3 kernels), so we *stack* their weights and run a
``jax.lax.scan`` whose carry is the single live activation. XLA then
allocates exactly one activation buffer (double-buffered) regardless of
depth, and the per-layer dilation rides along as a scanned operand.
Input/output buffers are donated by the jit wrapper in ops-level callers.

This module is also the template for the transformer zoo: every assigned
architecture scans over stacked layer params for the same reason.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import meshnet
from repro.core.meshnet import MeshNetConfig


def stack_layer_params(params) -> tuple[dict, dict, dict]:
    """Split MeshNet params into (first_layer, stacked_middle, head).

    Layer 1 has in_channels != channels, so it stays unstacked; layers
    2..L-1 are stacked leaf-wise into arrays with a leading layer axis.
    """
    layers = params["layers"]
    first = layers[0]
    middle = jax.tree.map(lambda *xs: jnp.stack(xs), *layers[1:])
    return first, middle, params["head"]


def streaming_apply(params, x: jax.Array, cfg: MeshNetConfig) -> jax.Array:
    """Memory-streamed forward pass: logits (B, D, H, W, classes).

    Mathematically identical to ``meshnet.apply`` (inference mode); the
    difference is the execution schedule: scan keeps one live activation.
    """
    if x.ndim == 4:
        x = x[..., None]
    first, middle, head = stack_layer_params(params)
    dilations = jnp.asarray(cfg.dilations[1:], jnp.int32)

    x, _ = meshnet.apply_layer(first, x, cfg.dilations[0], cfg, training=False)

    dmax = int(max(cfg.dilations))

    def step(carry, inp):
        layer, dilation = inp
        # Dilation is a *traced* scanned operand, so we cannot pass it to
        # conv_general_dilated (static). Instead the 3^3 dilated conv is 27
        # shifted taps: out[p] = sum_t w[t] * x[p + dilation*t]. Shifts are
        # realised as dynamic_slice into a once-padded buffer (zero 'same'
        # padding semantics; dynamic_slice accepts traced starts).
        xp = jnp.pad(carry, [(0, 0)] + [(dmax, dmax)] * 3 + [(0, 0)])
        w3 = layer["w"]  # (3, 3, 3, Cin, Cout)
        acc = jnp.zeros(carry.shape[:-1] + (w3.shape[-1],), carry.dtype)
        for tz in (-1, 0, 1):
            for ty in (-1, 0, 1):
                for tx in (-1, 0, 1):
                    start = (
                        0,
                        dmax + dilation * tz,
                        dmax + dilation * ty,
                        dmax + dilation * tx,
                        0,
                    )
                    tap = jax.lax.dynamic_slice(xp, start, carry.shape)
                    acc = acc + jnp.einsum(
                        "bdhwi,io->bdhwo", tap, w3[tz + 1, ty + 1, tx + 1]
                    )
        out = acc + layer["b"]
        if cfg.use_batchnorm:
            out = (out - layer["bn_mean"]) * jax.lax.rsqrt(layer["bn_var"] + 1e-5)
            out = out * layer["bn_scale"] + layer["bn_bias"]
        return jax.nn.relu(out), None

    x, _ = jax.lax.scan(step, x, (middle, dilations))
    return meshnet.dilated_conv3d(x, head["w"], head["b"], dilation=1)


def streaming_apply_unrolled(params, x: jax.Array, cfg: MeshNetConfig) -> jax.Array:
    """Variant without the padded-kernel trick: a Python loop over layers
    with explicit buffer donation between steps via jit boundaries.

    Closest to what Brainchop literally does (one WebGL program per layer,
    dispose the previous tensor). Used for comparison in benchmarks; the
    scan version is the production path.
    """
    if x.ndim == 4:
        x = x[..., None]

    @jax.jit
    def run_first(layer, x):
        out, _ = meshnet.apply_layer(layer, x, cfg.dilations[0], cfg, training=False)
        return out

    x = run_first(params["layers"][0], x)
    for i, d in enumerate(cfg.dilations[1:], start=1):
        # donate_argnums frees the previous activation as soon as the layer
        # kernel has consumed it — the "strategic disposal".
        step = jax.jit(
            lambda layer, x, d=d: meshnet.apply_layer(layer, x, d, cfg, training=False)[0],
            donate_argnums=(1,),
        )
        x = step(params["layers"][i], x)
    head = params["head"]
    return meshnet.dilated_conv3d(x, head["w"], head["b"], dilation=1)
