"""3-D connected components — Brainchop's postprocessing stage (Fig. 1).

Inference can leave small disconnected "noisy regions" (the paper attributes
them to bias/variance/irreducible noise); Brainchop filters them with a 3-D
connected-components pass. We implement label propagation entirely in JAX:

  1. seed every foreground voxel with its unique linear index,
  2. iterate ``label = min over 6-neighbourhood`` (masked) to fixpoint
     via ``lax.while_loop`` — each sweep halves the worst-case diameter
     because we propagate with doubling (pointer-jumping style sweeps).

This is the classic data-parallel CC algorithm; it is TPU-friendly (pure
elementwise min + shifts, no scatter) unlike the serial union-find used in
CPU back-ends.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_BIG = jnp.iinfo(jnp.int32).max


def _neighbor_min(labels: jax.Array) -> jax.Array:
    """Min over the 6-neighbourhood (face adjacency), edge-clamped."""
    out = labels
    for axis in range(3):
        fwd = jnp.concatenate(
            [
                jax.lax.slice_in_dim(labels, 1, labels.shape[axis], axis=axis),
                jax.lax.slice_in_dim(labels, labels.shape[axis] - 1, labels.shape[axis], axis=axis),
            ],
            axis=axis,
        )
        bwd = jnp.concatenate(
            [
                jax.lax.slice_in_dim(labels, 0, 1, axis=axis),
                jax.lax.slice_in_dim(labels, 0, labels.shape[axis] - 1, axis=axis),
            ],
            axis=axis,
        )
        out = jnp.minimum(out, jnp.minimum(fwd, bwd))
    return out


@jax.jit
def connected_components(mask: jax.Array) -> jax.Array:
    """Label connected components of a boolean (D, H, W) mask.

    Returns int32 labels: background = -1, each component labelled by the
    minimum linear index of its voxels (stable, permutation-invariant).
    """
    mask = mask.astype(bool)
    n = mask.size
    seed = jnp.arange(n, dtype=jnp.int32).reshape(mask.shape)
    labels = jnp.where(mask, seed, _BIG)

    def body(state):
        labels, _ = state
        new = jnp.where(mask, _neighbor_min(labels), _BIG)
        # Pointer-jumping: jump each voxel to its current root's label.
        # labels hold linear indices, so a gather contracts long chains.
        jumped = jnp.where(mask, new.ravel()[jnp.clip(new.ravel(), 0, n - 1)].reshape(mask.shape), _BIG)
        new = jnp.minimum(new, jumped)
        return new, jnp.any(new != labels)

    def cond(state):
        return state[1]

    labels, _ = jax.lax.while_loop(cond, body, (labels, jnp.array(True)))
    return jnp.where(mask, labels, -1)


@jax.jit
def component_sizes(labels: jax.Array) -> jax.Array:
    """Voxel count per label id (flat, length = labels.size; sparse)."""
    flat = labels.ravel()
    valid = flat >= 0
    return jnp.zeros((labels.size,), jnp.int32).at[jnp.where(valid, flat, 0)].add(
        valid.astype(jnp.int32)
    )


@jax.jit
def largest_component(mask: jax.Array) -> jax.Array:
    """Keep only the largest connected component of a boolean mask."""
    labels = connected_components(mask)
    sizes = component_sizes(labels)
    best = jnp.argmax(sizes)
    return labels == best


@functools.partial(jax.jit, static_argnames=("min_size",))
def remove_small_components(mask: jax.Array, min_size: int) -> jax.Array:
    """Drop components with fewer than ``min_size`` voxels (noise filter)."""
    labels = connected_components(mask)
    sizes = component_sizes(labels)
    keep = sizes >= min_size
    return jnp.where(labels >= 0, keep[jnp.clip(labels, 0)], False)


def filter_segmentation(seg: jax.Array, num_classes: int, min_size: int = 64) -> jax.Array:
    """Per-class noise filtering of a hard segmentation (D, H, W) int map.

    Brainchop's postprocessing: for each non-background class, remove
    connected regions smaller than ``min_size`` (reassigned to background 0).
    """
    out = seg
    for c in range(1, num_classes):
        mask = seg == c
        kept = remove_small_components(mask, min_size)
        out = jnp.where(mask & ~kept, 0, out)
    return out
