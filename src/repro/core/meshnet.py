"""MeshNet — the paper's volumetric segmentation model (Table I / Fig. 2).

A feed-forward 3-D CNN whose layers are 3x3x3 *dilated* convolutions with
dilation schedule 1,2,4,8,16,8,4,2,1 followed by a 1x1x1 classifier head.
Each hidden layer = Conv3d -> BatchNorm3d -> ReLU -> Dropout3d.

The network is intentionally tiny (the paper's GWM full-volume model is
0.022 MB / 5.6k params) — the whole point of Brainchop is that a model this
small, with a receptive field this large, segments a full 256^3 volume in
one pass inside a memory-constrained runtime.

Layout convention: volumes are channels-last ``(B, D, H, W, C)`` — channels
on the minor (lane) axis, which is what the Pallas kernel wants on TPU.

Params are a list-of-dicts pytree (one entry per layer) so the streaming
executor (core/streaming.py) can stack them and ``lax.scan`` layer-by-layer,
mirroring Brainchop's progressive layer-wise inference with disposal.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # pytree


@dataclasses.dataclass(frozen=True)
class MeshNetConfig:
    """Hyperparameters for a MeshNet model.

    Defaults reproduce Table I (the "typical GWM" stride-1 model):
    9 dilated 3^3 conv layers at 5 channels + a 1^3 head to 3 classes
    (background / gray matter / white matter).
    """

    in_channels: int = 1
    channels: int = 5
    num_classes: int = 3
    dilations: Sequence[int] = (1, 2, 4, 8, 16, 8, 4, 2, 1)

    def __post_init__(self):
        # Keep the config hashable (it crosses jit boundaries as a static
        # argument in core/executors.py) even when dilations arrive as a list.
        object.__setattr__(self, "dilations", tuple(self.dilations))
    kernel_size: int = 3
    dropout_rate: float = 0.0  # inference default; training uses >0
    use_batchnorm: bool = True
    dtype: Any = jnp.float32

    @property
    def num_layers(self) -> int:
        return len(self.dilations) + 1  # + classifier head

    def param_count(self) -> int:
        """Conv parameters only — the paper's convention: the GWM light
        model reports 5598 = 140 + 8*680 + 18 (Table IV excludes BN)."""
        k = self.kernel_size ** 3
        n = self.in_channels * self.channels * k + self.channels  # layer 1
        for _ in self.dilations[1:]:
            n += self.channels * self.channels * k + self.channels
        n += self.channels * self.num_classes + self.num_classes  # 1x1x1 head
        return n


# Paper model zoo (Table IV): name -> (channels, dilations, classes).
# Layer counts in Table IV count BN/activation stages; here a "layer" is one
# conv block. 5.6k ~= channels=5 GWM; 23k ~= channels=10 "large"; the
# failsafe/subvolume variants use wider channels (96k ~= 21ch).
PAPER_MODELS = {
    "gwm_light": MeshNetConfig(channels=5, num_classes=3),
    "gwm_large": MeshNetConfig(channels=10, num_classes=3),
    "brain_mask_fast": MeshNetConfig(channels=5, num_classes=2),
    "brain_mask_high_acc": MeshNetConfig(channels=10, num_classes=2),
    "extract_brain_fast": MeshNetConfig(channels=5, num_classes=2),
    "subvolume_gwm_failsafe": MeshNetConfig(channels=21, num_classes=3),
    "atlas_50": MeshNetConfig(channels=10, num_classes=50),
    "atlas_104": MeshNetConfig(channels=18, num_classes=104),
}


def _conv_init(key, kshape, dtype):
    fan_in = int(np.prod(kshape[:-1]))
    std = float(np.sqrt(2.0 / fan_in))  # He init for ReLU nets
    return jax.random.normal(key, kshape, dtype) * jnp.asarray(std, dtype)


def init(key: jax.Array, cfg: MeshNetConfig) -> Params:
    """Initialize MeshNet params: list of per-layer dicts."""
    k = cfg.kernel_size
    layers = []
    in_ch = cfg.in_channels
    keys = jax.random.split(key, len(cfg.dilations) + 1)
    for i, _ in enumerate(cfg.dilations):
        layer = {
            "w": _conv_init(keys[i], (k, k, k, in_ch, cfg.channels), cfg.dtype),
            "b": jnp.zeros((cfg.channels,), cfg.dtype),
        }
        if cfg.use_batchnorm:
            layer["bn_scale"] = jnp.ones((cfg.channels,), cfg.dtype)
            layer["bn_bias"] = jnp.zeros((cfg.channels,), cfg.dtype)
            # Running stats (inference-mode BN). Updated by the trainer.
            layer["bn_mean"] = jnp.zeros((cfg.channels,), cfg.dtype)
            layer["bn_var"] = jnp.ones((cfg.channels,), cfg.dtype)
        layers.append(layer)
        in_ch = cfg.channels
    head = {
        "w": _conv_init(keys[-1], (1, 1, 1, cfg.channels, cfg.num_classes), cfg.dtype),
        "b": jnp.zeros((cfg.num_classes,), cfg.dtype),
    }
    return {"layers": layers, "head": head}


def dilated_conv3d(x: jax.Array, w: jax.Array, b: jax.Array, dilation: int) -> jax.Array:
    """'Same'-padded 3-D dilated convolution, channels-last.

    x: (B, D, H, W, Cin); w: (k, k, k, Cin, Cout). Padding = dilation so the
    output shape equals the input shape for k=3 (Table I pads == dilations).
    """
    k = w.shape[0]
    pad = dilation * (k - 1) // 2
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1, 1),
        padding=[(pad, pad)] * 3,
        rhs_dilation=(dilation,) * 3,
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"),
    )
    return out + b


def batchnorm(x, layer, *, training: bool, eps: float = 1e-5):
    """BatchNorm3d over (B, D, H, W); returns (y, batch_mean, batch_var)."""
    if training:
        mean = jnp.mean(x, axis=(0, 1, 2, 3))
        var = jnp.var(x, axis=(0, 1, 2, 3))
    else:
        mean, var = layer["bn_mean"], layer["bn_var"]
    y = (x - mean) * jax.lax.rsqrt(var + eps) * layer["bn_scale"] + layer["bn_bias"]
    return y, mean, var


def apply_layer(layer, x, dilation, cfg: MeshNetConfig, *, training=False, rng=None):
    """One MeshNet block: conv -> BN -> ReLU -> dropout."""
    x = dilated_conv3d(x, layer["w"], layer["b"], dilation)
    new_stats = None
    if cfg.use_batchnorm:
        x, mean, var = batchnorm(x, layer, training=training)
        new_stats = (mean, var)
    x = jax.nn.relu(x)
    if training and cfg.dropout_rate > 0.0 and rng is not None:
        keep = 1.0 - cfg.dropout_rate
        # Dropout3d: drop whole channels (per sample), like torch Dropout3d.
        mask = jax.random.bernoulli(rng, keep, (x.shape[0], 1, 1, 1, x.shape[-1]))
        x = x * mask / keep
    return x, new_stats


def apply(
    params: Params,
    x: jax.Array,
    cfg: MeshNetConfig,
    *,
    training: bool = False,
    rng: jax.Array | None = None,
) -> jax.Array:
    """Full forward pass -> logits (B, D, H, W, num_classes).

    The plain (non-streaming) executor; core/streaming.py provides the
    scan-over-layers version used for memory-constrained inference.
    """
    if x.ndim == 4:  # (B, D, H, W) -> add channel
        x = x[..., None]
    rngs = (
        jax.random.split(rng, len(cfg.dilations))
        if (rng is not None and training and cfg.dropout_rate > 0)
        else [None] * len(cfg.dilations)
    )
    for i, dilation in enumerate(cfg.dilations):
        x, _ = apply_layer(params["layers"][i], x, dilation, cfg, training=training, rng=rngs[i])
    head = params["head"]
    logits = dilated_conv3d(x, head["w"], head["b"], dilation=1)
    return logits


def apply_with_stats(params, x, cfg: MeshNetConfig, rng=None):
    """Training forward that also returns fresh BN batch statistics.

    Returns (logits, stats) where stats is a list of (mean, var) per layer —
    the trainer folds these into the running estimates with momentum.
    """
    if x.ndim == 4:
        x = x[..., None]
    rngs = (
        jax.random.split(rng, len(cfg.dilations))
        if (rng is not None and cfg.dropout_rate > 0)
        else [None] * len(cfg.dilations)
    )
    stats = []
    for i, dilation in enumerate(cfg.dilations):
        x, st = apply_layer(params["layers"][i], x, dilation, cfg, training=True, rng=rngs[i])
        stats.append(st)
    head = params["head"]
    return dilated_conv3d(x, head["w"], head["b"], dilation=1), stats


def predict(params, x, cfg: MeshNetConfig) -> jax.Array:
    """Hard segmentation labels (B, D, H, W) int32."""
    return jnp.argmax(apply(params, x, cfg), axis=-1).astype(jnp.int32)
