"""core — the paper's contribution: MeshNet volumetric segmentation and the
memory-constrained inference pipeline (patching / cropping / streaming /
spatial sharding / connected components)."""

from repro.core.meshnet import MeshNetConfig, PAPER_MODELS
from repro.core.unet3d import UNet3DConfig
from repro.core.pipeline import PipelineConfig, PipelineResult

__all__ = [
    "MeshNetConfig",
    "PAPER_MODELS",
    "UNet3DConfig",
    "PipelineConfig",
    "PipelineResult",
]
