"""Sub-volume patching — Brainchop's "failsafe" inference mode (Fig. 1,
Tables V/VI).

When the full volume does not fit in memory, the volume is divided into
overlapping sub-cubes (the paper's ``CubeDivider``), each cube is inferred
independently, and the per-cube outputs are merged back. The paper observes
patching raises the success rate (+6.23% IPTW) at the cost of inference
time (+24.31 s) and accuracy near cube borders; we make the accuracy loss
precise: with ``overlap >= receptive_field/2`` the trimmed merge is
mathematically exact for every voxel at distance >= RF from the *volume*
boundary (MeshNet's Table-I schedule has RF radius
``sum(dilations) * (k-1)/2 = 46``). Voxels within RF of the volume boundary
can still differ: full-volume 'same' convs re-introduce zero padding at
every layer, whereas a window only zero-pads at its own edge — this
boundary-band divergence is exactly the sub-volume accuracy loss the paper
reports, now characterised instead of hand-waved. (The *distributed*
analogue in core/spatial_shard.py does not suffer from it: its per-layer
halo exchange reproduces per-layer zero padding bit-exactly.)

Shapes are static per (volume_shape, cube, overlap) so each cube inference
hits one compiled executable.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

MESHNET_RF_RADIUS = 46  # sum((1,2,4,8,16,8,4,2,1)) * (3-1)/2


@dataclasses.dataclass(frozen=True)
class CubeSpec:
    """Static description of one sub-cube: where it reads and writes."""

    src_start: tuple[int, int, int]  # read origin in the padded volume
    dst_start: tuple[int, int, int]  # write origin in the output volume
    trim_lo: tuple[int, int, int]  # voxels to trim from cube output (low side)
    core: tuple[int, int, int]  # size of the region written back


class CubeDivider:
    """Splits a (D, H, W[, C]) volume into overlapping cubes and merges back.

    ``cube`` is the *core* (written-back) size per axis; each cube is read
    with ``overlap`` extra context on every side (zero-padded at volume
    borders), so the model sees ``core + 2*overlap`` per axis.
    """

    def __init__(self, shape: tuple[int, int, int], cube: int = 64, overlap: int = MESHNET_RF_RADIUS):
        self.shape = tuple(shape)
        self.cube = cube
        self.overlap = overlap
        self.specs: list[CubeSpec] = []
        grids = [range(0, s, cube) for s in self.shape]
        for z0 in grids[0]:
            for y0 in grids[1]:
                for x0 in grids[2]:
                    core = tuple(
                        min(cube, s - o) for s, o in zip(self.shape, (z0, y0, x0))
                    )
                    self.specs.append(
                        CubeSpec(
                            src_start=(z0, y0, x0),  # origin in padded volume == core origin
                            dst_start=(z0, y0, x0),
                            trim_lo=(overlap, overlap, overlap),
                            core=core,
                        )
                    )

    @property
    def num_cubes(self) -> int:
        return len(self.specs)

    @property
    def read_size(self) -> tuple[int, int, int]:
        return tuple(self.cube + 2 * self.overlap for _ in range(3))

    def split(self, vol: jax.Array) -> list[jax.Array]:
        """Extract padded cubes. vol: (D, H, W) or (D, H, W, C)."""
        has_c = vol.ndim == 4
        pad = [(self.overlap, self.overlap + self.cube)] * 3  # extra tail pad so every read is full-size
        padded = jnp.pad(vol, pad + ([(0, 0)] if has_c else []))
        out = []
        rs = self.read_size
        for spec in self.specs:
            idx = tuple(slice(s, s + r) for s, r in zip(spec.src_start, rs))
            out.append(padded[idx + ((slice(None),) if has_c else ())])
        return out

    def merge(self, cubes: list[jax.Array], out_channels: int | None = None) -> jax.Array:
        """Merge per-cube model outputs back into a full volume.

        Each cube output must be shaped ``read_size (+ C)``; only the core
        (trimmed by ``overlap`` on each side) is written back — the exact
        merge, no averaging needed when overlap >= RF radius.
        """
        c = cubes[0].shape[-1] if cubes[0].ndim == 4 else None
        if out_channels is not None:
            c = out_channels
        shape = self.shape + ((c,) if c else ())
        out = np.zeros(shape, dtype=np.asarray(cubes[0]).dtype)
        for spec, cube in zip(self.specs, cubes):
            t = spec.trim_lo
            core = np.asarray(
                cube[
                    t[0] : t[0] + spec.core[0],
                    t[1] : t[1] + spec.core[1],
                    t[2] : t[2] + spec.core[2],
                ]
            )
            dst = tuple(slice(s, s + n) for s, n in zip(spec.dst_start, spec.core))
            out[dst] = core
        return jnp.asarray(out)


def subvolume_inference(
    vol: jax.Array,
    infer_fn: Callable[[jax.Array], jax.Array] | None = None,
    *,
    params=None,
    model_cfg=None,
    executor: str | None = None,
    cube: int = 64,
    overlap: int = MESHNET_RF_RADIUS,
    batch_cubes: int = 1,
    precision: str = "fp32",
) -> jax.Array:
    """Run per-cube inference over sub-cubes of ``vol`` and merge (failsafe).

    The per-cube forward is either an explicit ``infer_fn`` mapping
    (B, d, h, w) -> (B, d, h, w, C), or — when ``params``/``model_cfg`` are
    given instead — a closure built from the executor registry
    (``executors.make_infer``), so failsafe mode runs the same backend
    ("xla" | "pallas_fused" | "pallas_megakernel" | "streaming" |
    "sharded_<inner>[@n]", or "auto") as every other mode — a sharded
    backend Z-slices each padded cube over the device mesh, so the cube's
    read size must divide by the slab count.
    Either way it is compiled once because all cubes share a static shape.
    ``batch_cubes`` packs cubes into the batch dim — the TPU analogue of
    Brainchop queuing cube jobs on the WebGL queue.
    """
    if infer_fn is None:
        if params is None or model_cfg is None:
            raise ValueError("pass infer_fn, or params + model_cfg (+ executor)")
        from repro.core import executors

        # resolve "auto" against the padded-cube geometry the closure will
        # actually serve (slab divisibility, per-cube VMEM plans); the
        # precision policy rides the registry's jit cache, and zero-padded
        # cube borders are exact at every policy (0 is exactly
        # representable in bf16 and is int8 quantization's zero point)
        read = (cube + 2 * overlap,) * 3
        infer_fn = executors.make_infer(
            executor, params, model_cfg, read, precision=precision
        )
    elif params is not None or model_cfg is not None or executor is not None:
        raise ValueError(
            "pass either infer_fn or params/model_cfg/executor, not both — "
            "an explicit infer_fn would silently shadow the executor choice"
        )
    divider = CubeDivider(vol.shape[:3], cube=cube, overlap=overlap)
    cubes = divider.split(vol)
    outs: list[jax.Array] = []
    for i in range(0, len(cubes), batch_cubes):
        chunk = cubes[i : i + batch_cubes]
        n = len(chunk)
        if n < batch_cubes:  # pad the tail batch to keep the shape static
            chunk = chunk + [jnp.zeros_like(chunk[0])] * (batch_cubes - n)
        res = infer_fn(jnp.stack(chunk))
        outs.extend(jnp.asarray(r) for r in res[:n])
    return divider.merge(outs)


def memory_bytes_full_volume(shape, channels, num_classes, dtype_bytes=4) -> int:
    """Peak activation bytes of full-volume MeshNet inference (two live
    activation buffers under layer-streaming + the logits buffer)."""
    vox = math.prod(shape)
    return vox * channels * dtype_bytes * 2 + vox * num_classes * dtype_bytes


def memory_bytes_subvolume(cube, overlap, channels, num_classes, dtype_bytes=4) -> int:
    side = cube + 2 * overlap
    return memory_bytes_full_volume((side,) * 3, channels, num_classes, dtype_bytes)
