"""Trainer — the §III training loop for MeshNet (and U-Net baseline).

jit-compiled train step (CE + soft-Dice), AdamW, BN running-stat updates,
periodic eval (macro Dice on held-out synthetic subjects), checkpointing.
Works on CPU for the integration tests / examples and shards over a mesh
('data' batch axis) when one is provided.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import meshnet
from repro.core.meshnet import MeshNetConfig
from repro.data import mri
from repro.training import checkpoint as ckpt_mod
from repro.training import losses
from repro.training import optimizer as opt_mod


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    model: MeshNetConfig = dataclasses.field(default_factory=MeshNetConfig)
    data: mri.DataLoaderConfig = dataclasses.field(default_factory=mri.DataLoaderConfig)
    opt: opt_mod.AdamWConfig = dataclasses.field(default_factory=opt_mod.AdamWConfig)
    steps: int = 300
    dice_weight: float = 1.0
    bn_momentum: float = 0.1
    eval_every: int = 50
    eval_subjects: int = 4
    log_every: int = 25
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    seed: int = 0


def make_train_step(cfg: TrainConfig) -> Callable:
    """Build the jit'd train step: (params, opt_state, batch, rng) -> ..."""

    def loss_fn(params, vol, lab, rng):
        logits, stats = meshnet.apply_with_stats(params, vol, cfg.model, rng=rng)
        loss, metrics = losses.segmentation_loss(logits, lab, cfg.model.num_classes, cfg.dice_weight)
        return loss, (metrics, stats)

    @jax.jit
    def train_step(params, opt_state, vol, lab, rng):
        (loss, (metrics, stats)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, vol, lab, rng
        )
        params, opt_state, opt_metrics = opt_mod.adamw_update(grads, opt_state, params, cfg.opt)
        # Fold fresh batch statistics into BN running estimates.
        if cfg.model.use_batchnorm:
            m = cfg.bn_momentum
            new_layers = []
            for layer, st in zip(params["layers"], stats):
                if st is not None:
                    mean, var = st
                    layer = dict(
                        layer,
                        bn_mean=(1 - m) * layer["bn_mean"] + m * mean,
                        bn_var=(1 - m) * layer["bn_var"] + m * var,
                    )
                new_layers.append(layer)
            params = dict(params, layers=new_layers)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def evaluate(params, cfg: TrainConfig, num_subjects: int | None = None, seed: int = 10_000) -> float:
    """Mean macro-Dice over held-out synthetic subjects."""
    n = num_subjects or cfg.eval_subjects
    key = jax.random.PRNGKey(seed)
    pred_fn = jax.jit(lambda v: meshnet.predict(params, v, cfg.model))
    dices = []
    for i in range(n):
        key, sk = jax.random.split(key)
        vol, lab = mri.generate(sk, cfg.data.mri)
        pred = pred_fn(vol[None])[0]
        dices.append(float(losses.dice_score(pred, lab, cfg.model.num_classes)))
    return sum(dices) / len(dices)


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    history: list
    final_dice: float


def train(cfg: TrainConfig, *, verbose: bool = True, init_params=None) -> TrainResult:
    key = jax.random.PRNGKey(cfg.seed)
    key, pkey = jax.random.split(key)
    params = init_params if init_params is not None else meshnet.init(pkey, cfg.model)
    opt_state = opt_mod.adamw_init(params, cfg.opt)
    step_fn = make_train_step(cfg)
    loader = iter(mri.DataLoader(cfg.data))
    history = []
    t0 = time.perf_counter()
    for step in range(1, cfg.steps + 1):
        key, rk = jax.random.split(key)
        vol, lab = next(loader)
        params, opt_state, metrics = step_fn(params, opt_state, vol, lab, rk)
        if step % cfg.log_every == 0 or step == 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            if verbose:
                print(
                    f"step {step:5d}  loss {m['loss']:.4f}  dice {m['dice']:.4f}  "
                    f"ce {m['ce']:.4f}  ({m['wall_s']:.1f}s)"
                )
        if cfg.ckpt_dir and step % cfg.ckpt_every == 0:
            ckpt_mod.save(
                f"{cfg.ckpt_dir}/step_{step:06d}",
                {"params": params, "opt_state": opt_state},
                step=step,
            )
    final_dice = evaluate(params, cfg)
    if verbose:
        print(f"final held-out macro dice: {final_dice:.4f}")
    return TrainResult(params=params, opt_state=opt_state, history=history, final_dice=final_dice)
