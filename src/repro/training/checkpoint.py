"""Checkpointing — flat-key .npz shards + JSON manifest.

No orbax in this container, so we implement the substrate: a pytree is
flattened to path-keyed arrays, split into bounded-size shards, written
atomically (tmp + rename) with a manifest carrying step/metadata and the
treedef. Restore rebuilds the exact pytree (dtypes/shapes checked) and
supports partial loads (e.g. params only, skip optimizer state).
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}

    def visit(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                visit(f"{prefix}{_SEP}{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                visit(f"{prefix}{_SEP}{i}" if prefix else str(i), v)
            if hasattr(node, "_fields"):  # NamedTuple: remember the type name
                pass
        else:
            flat[prefix] = np.asarray(node)

    visit("", tree)
    return flat


def _treedef_spec(tree) -> Any:
    """JSON-able structure spec mirroring _flatten's traversal."""
    if isinstance(tree, dict):
        return {"__kind__": "dict", "keys": {k: _treedef_spec(v) for k, v in sorted(tree.items())}}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return {
            "__kind__": "namedtuple",
            "name": type(tree).__name__,
            "fields": [[f, _treedef_spec(getattr(tree, f))] for f in tree._fields],
        }
    if isinstance(tree, (list, tuple)):
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_treedef_spec(v) for v in tree]}
    return {"__kind__": "leaf"}


def save(path: str, tree, *, step: int | None = None, metadata: dict | None = None,
         shard_bytes: int = 1 << 30) -> None:
    """Write checkpoint dir: manifest.json + shard_*.npz (atomic)."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    # Pack into shards under shard_bytes each.
    shards: list[dict[str, np.ndarray]] = [{}]
    sizes = [0]
    for k, v in flat.items():
        if sizes[-1] + v.nbytes > shard_bytes and shards[-1]:
            shards.append({})
            sizes.append(0)
        shards[-1][k] = v
        sizes[-1] += v.nbytes
    index = {}
    for i, shard in enumerate(shards):
        fname = f"shard_{i:05d}.npz"
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".npz")
        os.close(fd)
        np.savez(tmp, **{k.replace("/", "|"): v for k, v in shard.items()})
        os.replace(tmp, os.path.join(path, fname))
        for k in shard:
            index[k] = fname
    manifest = {
        "step": step,
        "metadata": metadata or {},
        "index": index,
        "spec": _treedef_spec(tree),
        "num_shards": len(shards),
    }
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(path, "manifest.json"))


def _unflatten(spec, flat: dict[str, np.ndarray], prefix: str = ""):
    kind = spec["__kind__"]
    if kind == "leaf":
        return jax.numpy.asarray(flat[prefix])
    if kind == "dict":
        return {
            k: _unflatten(s, flat, f"{prefix}{_SEP}{k}" if prefix else str(k))
            for k, s in spec["keys"].items()
        }
    if kind in ("list", "tuple"):
        items = [
            _unflatten(s, flat, f"{prefix}{_SEP}{i}" if prefix else str(i))
            for i, s in enumerate(spec["items"])
        ]
        return items if kind == "list" else tuple(items)
    if kind == "namedtuple":
        vals = {
            f: _unflatten(s, flat, f"{prefix}{_SEP}{i}" if prefix else str(i))
            for i, (f, s) in enumerate(spec["fields"])
        }
        # Rebuild as a plain namedtuple-compatible dict if the class is not
        # importable; AdamWState/SGDState callers re-wrap via from_dict.
        from repro.training import optimizer as _opt

        cls = getattr(_opt, spec["name"], None)
        return cls(**vals) if cls else vals
    raise ValueError(f"bad spec kind {kind}")


def restore(path: str) -> tuple[Any, dict]:
    """-> (tree, manifest). Raises FileNotFoundError if absent."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat: dict[str, np.ndarray] = {}
    for i in range(manifest["num_shards"]):
        with np.load(os.path.join(path, f"shard_{i:05d}.npz")) as z:
            for k in z.files:
                flat[k.replace("|", "/")] = z[k]
    tree = _unflatten(manifest["spec"], flat)
    return tree, manifest


def latest_step_dir(root: str) -> str | None:
    """Find the newest step_NNNN dir under root (train.py resume helper)."""
    if not os.path.isdir(root):
        return None
    steps = [d for d in os.listdir(root) if d.startswith("step_")]
    if not steps:
        return None
    return os.path.join(root, max(steps, key=lambda s: int(s.split("_")[1])))
