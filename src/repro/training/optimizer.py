"""Optimizers + LR schedules, pure JAX pytree implementations.

AdamW (used for both MeshNet training and the architecture-zoo train_step
lowered in the dry-run), SGD+momentum, cosine/warmup schedules, global-norm
clipping. State is a pytree matching params, so it shards with the same
PartitionSpecs (optimizer-state sharding = FSDP-style when params are
sharded over 'data').
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip_norm: float | None = 1.0
    schedule: Callable[[jax.Array], jax.Array] | None = None
    # dtype of the first/second-moment accumulators (f32 master states)
    state_dtype: Any = jnp.float32


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """One AdamW step -> (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.grad_clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
        metrics["grad_norm"] = gnorm
    step = state.step + 1
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule is not None else 1.0)
    metrics["lr"] = lr
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(cfg.state_dtype)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(cfg.state_dtype)
        return (p.astype(cfg.state_dtype) - lr * delta).astype(p.dtype), m, v

    # Flatten/unflatten (not tuple-packed tree.map): param trees may contain
    # tuple nodes, which would confuse an is_leaf=tuple trick.
    g_leaves, treedef = jax.tree.flatten(grads)
    m_leaves = treedef.flatten_up_to(state.mu)
    v_leaves = treedef.flatten_up_to(state.nu)
    p_leaves = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(g_leaves, m_leaves, v_leaves, p_leaves)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 0.0
    schedule: Callable[[jax.Array], jax.Array] | None = None


class SGDState(NamedTuple):
    step: jax.Array
    velocity: Any


def sgd_init(params, cfg: SGDConfig) -> SGDState:
    return SGDState(
        step=jnp.zeros((), jnp.int32),
        velocity=jax.tree.map(jnp.zeros_like, params),
    )


def sgd_update(grads, state: SGDState, params, cfg: SGDConfig):
    step = state.step + 1
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule is not None else 1.0)

    def upd(g, v, p):
        g = g + cfg.weight_decay * p
        v = cfg.momentum * v + g
        return p - lr * v, v

    g_leaves, treedef = jax.tree.flatten(grads)
    v_leaves = treedef.flatten_up_to(state.velocity)
    p_leaves = treedef.flatten_up_to(params)
    out = [upd(g, v, p) for g, v, p in zip(g_leaves, v_leaves, p_leaves)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_params, SGDState(step=step, velocity=new_v), {"lr": lr}


# --- schedules ---------------------------------------------------------------


def warmup_cosine(warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched


def constant():
    return lambda step: jnp.ones((), jnp.float32)
