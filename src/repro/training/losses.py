"""Training losses & metrics — §III-B of the paper: Dice + CrossEntropy.

The paper trains MeshNet with cross-entropy loss and tracks macro Dice
computed from binary masks per label. We provide both, plus a combined
loss (CE + soft-Dice) commonly used for the class-imbalanced GWM task.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def one_hot(labels: jax.Array, num_classes: int, dtype=jnp.float32) -> jax.Array:
    return jax.nn.one_hot(labels, num_classes, dtype=dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over all voxels/tokens. logits (..., C), labels (...) int."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32), axis=-1)
    return -jnp.mean(ll)


def dice_score(pred: jax.Array, truth: jax.Array, num_classes: int, eps: float = 1e-7) -> jax.Array:
    """Macro Dice over classes from *hard* labels (eq. 2 of the paper).

    DICE_c = 2|X_c ∩ Y_c| / (|X_c| + |Y_c|); classes absent from both
    pred and truth score 1 (they are perfectly segmented as empty).
    """
    scores = []
    for c in range(num_classes):
        x = pred == c
        y = truth == c
        inter = jnp.sum(x & y)
        denom = jnp.sum(x) + jnp.sum(y)
        scores.append(jnp.where(denom == 0, 1.0, 2.0 * inter / (denom + eps)))
    return jnp.mean(jnp.stack(scores))


def soft_dice_loss(logits: jax.Array, labels: jax.Array, num_classes: int, eps: float = 1e-7) -> jax.Array:
    """Differentiable (soft) macro Dice loss: 1 - mean_c dice(p_c, y_c)."""
    probs = jax.nn.softmax(logits, axis=-1)
    y = one_hot(labels, num_classes, probs.dtype)
    axes = tuple(range(probs.ndim - 1))
    inter = jnp.sum(probs * y, axis=axes)
    denom = jnp.sum(probs, axis=axes) + jnp.sum(y, axis=axes)
    dice = (2.0 * inter + eps) / (denom + eps)
    return 1.0 - jnp.mean(dice)


def segmentation_loss(
    logits: jax.Array,
    labels: jax.Array,
    num_classes: int,
    dice_weight: float = 1.0,
) -> tuple[jax.Array, dict]:
    """CE + dice_weight * soft-Dice; returns (loss, metrics dict)."""
    ce = cross_entropy(logits, labels)
    sd = soft_dice_loss(logits, labels, num_classes)
    loss = ce + dice_weight * sd
    hard = jnp.argmax(logits, axis=-1)
    return loss, {
        "ce": ce,
        "soft_dice_loss": sd,
        "dice": dice_score(hard, labels, num_classes),
    }


def lm_loss(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """Token-level CE for the architecture zoo's train_step.

    logits (B, T, V), labels (B, T); mask optional (B, T) weights.
    """
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
