"""Production meshes for the dry-run target: TPU v5e pods.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod: 2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with "data" for batch/FSDP sharding; gradient
all-reduces cross the pod boundary (DCN in a real deployment; the
collective roofline term prices it).

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes the global batch (and FSDP dim) shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_axis(mesh) -> str:
    return "model"


def axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
