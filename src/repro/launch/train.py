"""Training launcher.

Two modes:
  * MeshNet (the paper): real CPU/TPU training on synthetic MRI —
      PYTHONPATH=src python -m repro.launch.train --model meshnet --steps 300
  * Architecture zoo: run N real steps of any assigned arch at a reduced
    (smoke) or full config on the available devices —
      PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
          --smoke --steps 10 --batch 2 --seq 128

The production-mesh path (--mesh) shards params/batch with the same rules
the dry-run proves out; on this CPU container it is exercised with the
reduced configs.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_meshnet(args):
    from repro.core.meshnet import MeshNetConfig
    from repro.data import mri
    from repro.training import trainer

    cfg = trainer.TrainConfig(
        model=MeshNetConfig(channels=args.channels, dropout_rate=0.1),
        data=mri.DataLoaderConfig(
            mri=mri.SyntheticMRIConfig(shape=(args.volume,) * 3),
            batch_size=args.batch,
            subvolumes=args.subvolumes,
        ),
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        seed=args.seed,
    )
    res = trainer.train(cfg)
    print(f"final dice {res.final_dice:.4f}")
    return res


def train_arch(args):
    from repro import configs
    from repro.launch import steps as steps_mod
    from repro.models import model as MD
    from repro.training import optimizer as opt_mod

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    import dataclasses

    cfg = dataclasses.replace(cfg, dtype=jnp.float32 if args.f32 else cfg.dtype)
    key = jax.random.PRNGKey(args.seed)
    params = MD.init(key, cfg)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.2f}M params")
    opt_state = opt_mod.adamw_init(params, steps_mod.OPT_CONFIG)
    step_fn = jax.jit(steps_mod.make_train_step(cfg))

    B, T = args.batch, args.seq
    t0 = time.perf_counter()
    for step in range(1, args.steps + 1):
        key, k1, k2 = jax.random.split(key, 3)
        batch = {
            "tokens": jax.random.randint(k1, (B, T), 0, cfg.vocab_size),
            "labels": jax.random.randint(k2, (B, T), 0, cfg.vocab_size),
        }
        if cfg.frontend == "vision_stub":
            batch["patches"] = jax.random.normal(k1, (B, cfg.num_patches, cfg.d_model), cfg.dtype)
        if cfg.kind == "encdec":
            batch["frames"] = jax.random.normal(k1, (B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == 1:
            print(
                f"step {step:4d} loss {float(metrics['loss']):.4f} "
                f"({time.perf_counter()-t0:.1f}s)"
            )
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="meshnet", choices=["meshnet", "arch"])
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--f32", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--volume", type=int, default=48)
    ap.add_argument("--channels", type=int, default=5)
    ap.add_argument("--subvolumes", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.model == "meshnet":
        train_meshnet(args)
    else:
        train_arch(args)


if __name__ == "__main__":
    main()
