import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers, compiles, and fits — without TPU hardware.

For each combination this script:
  1. builds the step (train_step / prefill_step / serve_step),
  2. lowers + compiles it against sharded ShapeDtypeStructs (no allocation),
  3. records memory_analysis(), cost_analysis(), and the collective bytes
     parsed from the partitioned HLO,
and appends the record to results/dryrun_{mesh}.json (resumable; reruns
skip completed combinations unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # single pod, all 40
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # 2-pod mesh
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro import configs
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind, from partitioned HLO.

    We price each op by its *result* shape (= received bytes per device),
    summed over all program points. Fusion can't hide collectives, so this
    is a faithful census of the communication the partitioner inserted.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)", line)
        if m:
            kind = m.group(2)
            # skip -start/-done duplicates (count the -start only)
            if f"{kind}-done" in line:
                continue
            out[kind] += _shape_bytes(m.group(1))
            counts[kind] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts, "total": sum(out[k] for k in _COLLECTIVES)}


def run_one(
    arch: str,
    shape_name: str,
    mesh,
    *,
    verbose: bool = True,
    census: bool = True,
    cfg_override=None,
) -> dict:
    cfg, mode, args = steps_mod.input_specs(arch, shape_name, mesh, cfg_override=cfg_override)
    _, global_batch, _ = configs.INPUT_SHAPES[shape_name]
    act_spec = steps_mod.act_spec_for(mesh, global_batch)
    step = steps_mod.build_step(cfg, mode, act_spec=act_spec)
    donate = steps_mod.donate_argnums(mode)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    chips = mesh.devices.size

    # Census pass: a rolled while body is costed once by cost_analysis, not
    # x trip count, so the production numbers above underreport per-layer
    # FLOPs/bytes/collectives by ~num_repeats. The census fixes this with a
    # SECANT method: lower the same step at R=1 and R=2 repeats with the
    # (tiny) scan fully unrolled and loop-free attention; per-repeat cost =
    # cost(2) - cost(1) (exact — repeats contribute identical ops), so
    # total = cost(1) + per_repeat * (R - 1). Memory numbers still come
    # from the production compile; census compiles are never executed.
    # Known residual undercount: mamba/rwkv per-timestep recurrence einsums
    # stay inside chunk loops (<2% of block FLOPs — projections dominate
    # and sit outside the loop).
    census_rec = {}
    if census:
        import dataclasses as _dc

        plen = len(cfg.block_pattern())
        reps = cfg.num_repeats
        t0 = time.time()

        def census_cost(n_rep):
            cfg_c = _dc.replace(cfg, scan_unroll=True, num_layers=plen * n_rep)
            cfg_spec, _, args_c = steps_mod.input_specs(arch, shape_name, mesh, cfg_override=cfg_c)
            step_c = steps_mod.build_step(cfg_c, mode, act_spec=act_spec)
            with mesh:
                compiled_c = jax.jit(step_c, donate_argnums=donate).lower(*args_c).compile()
            cost_c = compiled_c.cost_analysis() or {}
            coll_c = collective_bytes(compiled_c.as_text())
            return (
                float(cost_c.get("flops", 0.0)),
                float(cost_c.get("bytes accessed", 0.0)),
                coll_c,
            )

        if reps == 1:
            flops, bytes_acc, coll = census_cost(1)
        else:
            f1, b1, c1 = census_cost(1)
            f2, b2, c2 = census_cost(2)
            flops = f1 + (f2 - f1) * (reps - 1)
            bytes_acc = b1 + (b2 - b1) * (reps - 1)
            coll = {
                k: (c1[k] + (c2[k] - c1[k]) * (reps - 1)) if isinstance(c1[k], (int, float)) else c1[k]
                for k in c1
            }
        census_rec = {
            "census_flops": flops,
            "census_bytes_accessed": bytes_acc,
            "census_collectives": coll,
            "census_compile_s": round(time.time() - t0, 2),
        }

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mode": mode,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # per-device bytes (the partitioned module is per-device)
        "arg_bytes": int(mem.argument_size_in_bytes),
        "out_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        # per-device HLO cost
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "params": cfg.param_counts(),
        "status": "ok",
        **census_rec,
    }
    if verbose:
        peak = rec["arg_bytes"] + rec["temp_bytes"] + rec["out_bytes"] - rec["alias_bytes"]
        cf = rec.get("census_flops", rec["flops"])
        cc = rec.get("census_collectives", coll)["total"]
        print(
            f"  lower {t_lower:6.1f}s compile {t_compile:6.1f}s | "
            f"args {rec['arg_bytes']/2**30:7.2f} GiB  temp {rec['temp_bytes']/2**30:7.2f} GiB "
            f"peak~{peak/2**30:7.2f} GiB/dev | census flops/dev {cf:.3e} | "
            f"census coll {cc/2**20:.1f} MiB/dev"
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    mesh = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = args.out or os.path.join(RESULTS_DIR, f"dryrun_{mesh_name}.json")
    results: dict[str, dict] = {}
    if os.path.exists(out_path) and not args.force:
        with open(out_path) as f:
            results = json.load(f)

    archs = [args.arch] if args.arch else configs.ARCHS
    shapes = [args.shape] if args.shape else list(configs.INPUT_SHAPES)

    for arch in archs:
        for shape_name in shapes:
            key = f"{arch}|{shape_name}"
            if key in results and results[key].get("status") == "ok" and not args.force:
                print(f"[skip] {key}")
                continue
            print(f"[{mesh_name}] {arch} x {shape_name} ...", flush=True)
            try:
                rec = run_one(arch, shape_name, mesh)
            except Exception as e:  # noqa: BLE001 — record failures, keep going
                traceback.print_exc()
                rec = {
                    "arch": arch,
                    "shape": shape_name,
                    "mesh": mesh_name,
                    "status": "fail",
                    "error": f"{type(e).__name__}: {e}",
                }
            results[key] = rec
            with open(out_path, "w") as f:
                json.dump(results, f, indent=1)

    ok = sum(1 for r in results.values() if r.get("status") == "ok")
    print(f"\n{ok}/{len(results)} combinations compiled on {mesh_name}; -> {out_path}")


if __name__ == "__main__":
    main()
