"""Sharding rules: param/opt/batch/cache pytrees -> PartitionSpecs.

Scheme (DESIGN.md §4): tensor-parallel over "model" for the contraction-
adjacent dims (heads/d_ff/vocab), FSDP over the batch axes ("data", plus
"pod" when multi-pod) for the d_model-adjacent dims, batch over the batch
axes. Stacked scan params (leading repeats dim) are handled by left-padding
the rule's spec with None. Any dim not divisible by its axis size falls
back to replication (e.g. whisper's 51865 vocab over 16-way model).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import mesh as mesh_mod

# Rule table: leaf name -> spec template for the *trailing* dims.
# FSDP = the batch/FSDP axis tuple, TP = "model".
_F, _T = "__fsdp__", "__tp__"

_RULES: dict[str, tuple] = {
    # embeddings
    "embed": (_T, _F),
    "unembed": (_F, _T),
    "projector": (None, _T),
    # attention
    "wq": (_F, _T),
    "wk": (_F, _T),
    "wv": (_F, _T),
    "wo": (_T, _F),
    "bq": (_T,),
    "bk": (_T,),
    "bv": (_T,),
    # mlp (also matches moe stacked variants via left-padding)
    "w_gate": (_F, _T),
    "w_up": (_F, _T),
    "w_down": (_T, _F),
    "b_up": (_T,),
    "b_down": (None,),
    "router": (_F, None),
    # mamba
    "w_in": (_F, _T),
    "conv_w": (None, _T),
    "conv_b": (_T,),
    "w_bcdt": (_T, None),
    "w_dt": (None, _T),
    "log_a": (_T, None),
    "d_skip": (_T,),
    # rwkv
    "w_r": (_F, _T),
    "w_k": (_F, _T),
    "w_v": (_T, _F),  # (f, d) in cmix; tmix w_v (d,d) also fine transposed
    "w_g": (_F, _T),
    "w_o": (_T, _F),
    "w_dec1": (_F, None),
    "w_dec2": (None, None),
    "w_out": (_T, _F),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def param_specs(params, mesh) -> Any:
    """PartitionSpec pytree for params (or matching-structure opt state)."""
    fsdp = mesh_mod.batch_axes(mesh)
    tp = "model"

    fsdp_size = mesh_mod.axis_size(mesh, fsdp)
    fsdp_ax: Any = fsdp if len(fsdp) > 1 else fsdp[0]

    def spec_of(path, leaf):
        name = _leaf_name(path)
        rule = _RULES.get(name)
        if rule is None:
            return P()  # norms, biases, mus, bonuses: replicate
        # MoE expert tensors w_up (E, d, f) / w_down (E, f, d) — stacked to
        # 4-D under the repeats axis. Expert-parallel over the FSDP axis
        # when E divides it: dispatch moves activations (all-to-all-sized),
        # not expert weights (the FSDP-gather pathology: ~1.5 TB/step on
        # jamba). Fallback: TP only, replicated over data.
        if name in ("w_up", "w_down") and leaf.ndim >= 3 and "router" not in str(path):
            trailing = leaf.shape[-3:]
            e = trailing[0]
            tp_dim = 2 if name == "w_up" else 1  # f position in (E, d, f)/(E, f, d)
            tp_ok = trailing[tp_dim] % mesh.shape[tp] == 0
            # All block params carry a leading repeats axis, so MoE expert
            # tensors are exactly the 4-D case ((R, E, d, f)); 3-D here is a
            # stacked *dense* (R, d, f) which the generic rule handles.
            is_moe = leaf.ndim == 4
            if is_moe:
                dims = [None] * (leaf.ndim - 3)
                inner = [None, None]
                inner[tp_dim - 1] = tp if tp_ok else None
                if e % fsdp_size == 0:
                    dims.append(fsdp_ax)  # expert-parallel
                else:
                    # E indivisible (grok's 8 over 16): FSDP the d dim so the
                    # 2x-larger-than-HBM expert stack still shards somewhere.
                    dims.append(None)
                    d_pos = 0 if tp_dim == 2 else 1  # d position within inner
                    if trailing[1 + d_pos] % fsdp_size == 0:
                        inner[d_pos] = fsdp_ax
                return P(*(dims + inner))
        # rwkv tmix w_v is (d, d) with rule (_T, _F) from cmix; both dims d —
        # sharding (tp, fsdp) is equally valid, so no special-casing needed.
        dims: list = [None] * (leaf.ndim - len(rule))
        for ax_tmpl, size in zip(rule, leaf.shape[leaf.ndim - len(rule):]):
            if ax_tmpl == _F:
                ax: Any = fsdp_ax
                div = fsdp_size
            elif ax_tmpl == _T:
                ax = tp
                div = mesh.shape[tp]
            else:
                ax, div = None, 1
            dims.append(ax if (ax is not None and size % div == 0) else None)
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec_of, params)


def opt_specs(opt_state, pspecs) -> Any:
    """Optimizer state specs: mu/nu mirror params; step replicated."""
    from repro.training.optimizer import AdamWState

    return AdamWState(step=P(), mu=pspecs, nu=pspecs)


def batch_specs(batch_shapes: dict, mesh, global_batch: int) -> dict:
    """Specs for the input batch dict: batch dim over the batch axes when
    divisible, else replicated (long_500k's B=1)."""
    axes = mesh_mod.batch_axes(mesh)
    dp = mesh_mod.axis_size(mesh, axes)
    bax: Any = axes if len(axes) > 1 else axes[0]
    b = bax if global_batch % dp == 0 else None
    return {k: P(*([b] + [None] * (len(shp) - 1))) for k, shp in batch_shapes.items()}


def cache_specs(cache, mesh, batch: int) -> Any:
    """Decode-cache specs. Batch dim over batch axes when divisible; for
    B=1 (long_500k) the KV sequence dim shards over "data" instead —
    sequence-sharded cache, the paper's patching idea in sequence space."""
    axes = mesh_mod.batch_axes(mesh)
    dp = mesh_mod.axis_size(mesh, axes)
    bax: Any = axes if len(axes) > 1 else axes[0]
    shard_batch = batch % dp == 0 and batch >= dp
    tp = "model"
    tp_size = mesh.shape[tp]

    def spec_of(path, leaf):
        name = _leaf_name(path)
        b = bax if shard_batch else None
        if name in ("k", "v", "ek", "ev", "ks", "vs"):  # (R, B, S, KV, hd|1)
            # Sequence dim over "model" (partial-softmax decode attention);
            # additionally over "data" when the batch is not sharded
            # (long_500k's B=1) — the sequence-sharded cache of DESIGN.md §4.
            s_candidates = [tp] if shard_batch else ["data", tp]
            s_ax: Any = None
            for cand in ([tuple(s_candidates)] if len(s_candidates) > 1 else s_candidates):
                size = mesh_mod.axis_size(mesh, cand) if not isinstance(cand, str) else mesh.shape[cand]
                if leaf.shape[2] % size == 0:
                    s_ax = cand
                    break
            if s_ax is None:
                for cand in s_candidates:
                    if leaf.shape[2] % mesh.shape[cand] == 0:
                        s_ax = cand
                        break
            return P(None, b, s_ax, None, None)
        if name == "conv":  # (R, B, dc-1, din)
            din_ax = tp if leaf.shape[-1] % tp_size == 0 else None
            return P(None, b, None, din_ax)
        if name == "h":  # (R, B, din, ds)
            din_ax = tp if leaf.shape[-2] % tp_size == 0 else None
            return P(None, b, din_ax, None)
        if name == "s":  # (R, B, H, hs, hs)
            h_ax = tp if leaf.shape[2] % tp_size == 0 else None
            return P(None, b, h_ax, None, None)
        if name in ("last", "last_c"):  # (R, B, 1, d)
            return P(None, b, None, None)
        return P()

    return jax.tree_util.tree_map_with_path(spec_of, cache)


def to_named(specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def with_sharding(shapes, specs, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct pytree."""
    return jax.tree.map(
        lambda sh, sp: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=NamedSharding(mesh, sp)),
        shapes,
        specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )
