"""Step builders + abstract input specs for the dry-run and the drivers.

For every (arch, input-shape) pair this module gives:
  build_step(cfg, mode)    -> the jit-able python callable
  input_specs(cfg, shape_name, mesh) -> pytree of sharded ShapeDtypeStructs
so the dry-run is exactly:
  jax.jit(step).lower(*input_specs(...)).compile()
No parameter tensors are ever materialised: shapes come from
``jax.eval_shape`` over the init functions.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import mesh as mesh_mod
from repro.launch import sharding
from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.training import losses
from repro.training import optimizer as opt_mod

OPT_CONFIG = opt_mod.AdamWConfig(lr=3e-4, weight_decay=0.1, grad_clip_norm=1.0)


# ------------------------------------------------------------------ steps ---


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: opt_mod.AdamWConfig = OPT_CONFIG,
    act_spec=None,
    microbatches: int = 1,
):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    Full training step: forward (CE + MoE aux), backward, global-norm clip,
    AdamW update. Layer stacks are scanned; the loss is computed in f32.

    ``microbatches > 1`` accumulates gradients over M sequential slices of
    the global batch (the paper's patching discipline applied to the train
    working set: per-device activation + MoE capacity buffers shrink by M
    at the cost of M x weight re-gathers). EXPERIMENTS.md §Perf H7.
    """

    def loss_fn(params, batch):
        logits, aux = MD.forward(params, batch, cfg, act_spec=act_spec)
        loss = losses.lm_loss(logits, batch["labels"])
        return loss + cfg.router_aux_weight * aux, (loss, aux)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            grads, (loss, aux) = jax.grad(loss_fn, has_aux=True)(params, batch)
        else:
            m = microbatches

            def slice_mb(i, t):
                mb = t.shape[0] // m
                return jax.lax.dynamic_slice_in_dim(t, i * mb, mb, axis=0)

            def acc_step(carry, i):
                g_acc, l_acc, a_acc = carry
                mb = {k: slice_mb(i, v) for k, v in batch.items()}
                g, (l, a) = jax.grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda ga, gi: ga + gi.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l, a_acc + a), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc_step, (g0, jnp.zeros(()), jnp.zeros(())), jnp.arange(m)
            )
            grads = jax.tree.map(lambda g: g / m, grads)
            loss, aux = loss / m, aux / m
        params, opt_state, om = opt_mod.adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, "aux": aux, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, act_spec=None):
    """(params, batch) -> last-position logits (B, V).

    Flash (online-softmax) attention keeps the 32k prefill working set
    linear in sequence — patching in sequence space.
    """

    def prefill_step(params, batch):
        logits, _ = MD.forward(params, batch, cfg, act_spec=act_spec)
        return logits[:, -1]

    return prefill_step


def make_serve_step(cfg: ModelConfig, act_spec=None):
    """(params, token, cache, pos) -> (next_token, logits, cache).

    One new token against a seq_len KV cache / recurrent state — what the
    decode_32k / long_500k shapes lower.
    """

    def serve_step(params, token, cache, pos):
        logits, cache = MD.decode_step(params, token, cache, pos, cfg, act_spec=act_spec)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, cache

    return serve_step


def act_spec_for(mesh, global_batch: int):
    """Batch-over-data activation anchor (None batch dim when B=1)."""
    from jax.sharding import PartitionSpec as P

    axes = mesh_mod.batch_axes(mesh)
    dp = mesh_mod.axis_size(mesh, axes)
    b = (axes if len(axes) > 1 else axes[0]) if global_batch % dp == 0 else None
    return P(b, None, None)


def build_step(cfg: ModelConfig, mode: str, act_spec=None):
    if mode == "train":
        return make_train_step(cfg, act_spec=act_spec)
    if mode == "prefill":
        return make_prefill_step(cfg, act_spec=act_spec)
    if mode == "decode":
        return make_serve_step(cfg, act_spec=act_spec)
    raise ValueError(mode)


# ------------------------------------------------------------ input specs ---


def _abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: MD.init(jax.random.PRNGKey(0), cfg))


def _abstract_opt(params_shapes):
    return jax.eval_shape(
        lambda p: opt_mod.adamw_init(p, OPT_CONFIG), params_shapes
    )


def _batch_shapes(cfg: ModelConfig, mode: str, batch: int, seq: int) -> dict:
    shapes: dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.kind == "encdec":
        # seq budget belongs to the decoder; encoder sees the stub frames
        shapes["frames"] = jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.frontend == "vision_stub":
        shapes["patches"] = jax.ShapeDtypeStruct((batch, cfg.num_patches, cfg.d_model), cfg.dtype)
    shapes["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if mode == "train":
        shapes["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return shapes


def input_specs(
    arch: str, shape_name: str, mesh, cfg_override: ModelConfig | None = None
) -> tuple[ModelConfig, str, tuple]:
    """-> (cfg, mode, args) where args are sharded ShapeDtypeStructs for
    build_step(cfg, mode). ``cfg_override`` substitutes a modified config
    (the dry-run census uses reduced-repeat variants)."""
    cfg = cfg_override if cfg_override is not None else configs.for_shape(arch, shape_name)
    seq, global_batch, mode = configs.INPUT_SHAPES[shape_name]

    pshapes = _abstract_params(cfg)
    pspecs = sharding.param_specs(pshapes, mesh)
    params = sharding.with_sharding(pshapes, pspecs, mesh)

    if mode == "train":
        oshapes = _abstract_opt(pshapes)
        ospecs = sharding.opt_specs(oshapes, pspecs)
        opt = sharding.with_sharding(oshapes, ospecs, mesh)
        bshapes = _batch_shapes(cfg, mode, global_batch, seq)
        bspecs = sharding.batch_specs(
            {k: v.shape for k, v in bshapes.items()}, mesh, global_batch
        )
        batch = sharding.with_sharding(bshapes, bspecs, mesh)
        return cfg, mode, (params, opt, batch)

    if mode == "prefill":
        bshapes = _batch_shapes(cfg, mode, global_batch, seq)
        bspecs = sharding.batch_specs(
            {k: v.shape for k, v in bshapes.items()}, mesh, global_batch
        )
        batch = sharding.with_sharding(bshapes, bspecs, mesh)
        return cfg, mode, (params, batch)

    # decode: one token + a seq_len cache
    cshapes = jax.eval_shape(lambda: MD.init_cache(cfg, global_batch, seq))
    cspecs = sharding.cache_specs(cshapes, mesh, global_batch)
    cache = sharding.with_sharding(cshapes, cspecs, mesh)
    baxes = mesh_mod.batch_axes(mesh)
    dp = mesh_mod.axis_size(mesh, baxes)
    bspec = (baxes if len(baxes) > 1 else baxes[0]) if global_batch % dp == 0 else None
    token = jax.ShapeDtypeStruct(
        (global_batch, 1),
        jnp.int32,
        sharding=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(bspec, None)),
    )
    pos = jax.ShapeDtypeStruct(
        (), jnp.int32, sharding=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    )
    return cfg, mode, (params, token, cache, pos)


def donate_argnums(mode: str) -> tuple[int, ...]:
    """Buffer donation (the paper's 'strategic disposal'): train donates
    params+opt, decode donates the cache."""
    return {"train": (0, 1), "prefill": (), "decode": (2,)}[mode]
