"""Serving launcher: batched MRI segmentation (the paper's deployment) or
LM generation for any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --engine segmentation -n 4
  PYTHONPATH=src python -m repro.launch.serve --engine lm --arch rwkv6-3b -n 3
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def serve_segmentation(args):
    import dataclasses

    from repro.core import meshnet
    from repro.core.meshnet import MeshNetConfig
    from repro.core.pipeline import PipelineConfig
    from repro.data import mri
    from repro.serving.engine import SegmentationEngine
    from repro.telemetry.budget import MemoryBudget

    shape = (args.volume,) * 3
    cfg_m = MeshNetConfig()
    params = meshnet.init(jax.random.PRNGKey(0), cfg_m)
    pc = PipelineConfig(model=cfg_m, volume_shape=shape, min_component_size=8)
    eng = SegmentationEngine(params, pc, budget=MemoryBudget.v5e())
    key = jax.random.PRNGKey(1)
    for i in range(args.n):
        key, k = jax.random.split(key)
        vol, _ = mri.generate(k, mri.SyntheticMRIConfig(shape=shape))
        res = eng.submit(vol)
        t = res.record.times
        print(
            f"req {i}: {res.record.status} mode={res.record.mode} "
            f"pre {t.preprocessing:.2f}s inf {t.inference:.2f}s post {t.postprocessing:.2f}s"
        )
    print(f"success rate: {eng.log.success_rate()*100:.1f}%")


def serve_lm(args):
    import dataclasses

    from repro import configs
    from repro.models import model as MD
    from repro.serving.engine import LMEngine, Request

    cfg = configs.get_smoke(args.arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = MD.init(jax.random.PRNGKey(0), cfg)
    eng = LMEngine(params, cfg, slots=args.slots, max_seq=args.max_seq, prefill_chunk=8)
    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.n):
        key, k = jax.random.split(key)
        plen = int(jax.random.randint(k, (), 3, 12))
        prompt = jax.random.randint(k, (plen,), 0, cfg.vocab_size).tolist()
        reqs.append(Request(prompt=prompt, max_new_tokens=args.max_new, id=i))
    t0 = time.perf_counter()
    outs = eng.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(c.tokens) for c in outs)
    for c in outs:
        print(f"req {c.id}: {len(c.tokens)} tokens, prefill {c.prefill_s:.2f}s")
    print(f"{total} tokens in {dt:.2f}s = {total/dt:.1f} tok/s ({args.arch} reduced)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="segmentation", choices=["segmentation", "lm"])
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("-n", type=int, default=4)
    ap.add_argument("--volume", type=int, default=48)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()
    if args.engine == "segmentation":
        serve_segmentation(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
