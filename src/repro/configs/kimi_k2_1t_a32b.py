"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE, 32B active
[arXiv:2501.kimi2 paper table].

61L, d_model 7168, 64 heads GQA kv=8, per-expert d_ff 2048, vocab 163840,
MoE with 384 experts top-8 on every layer (DeepSeek-V3-style fine-grained
experts).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    kind="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163_840,
    mlp="swiglu",
    num_experts=384,
    top_k=8,
    moe_every=1,
    moe_offset=0,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="kimi-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=64,
        vocab_size=512,
        num_experts=4,
        top_k=2,
    )
