"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1].

64L, d_model 6144, 48 heads GQA kv=8, d_ff 32768, vocab 131072, MoE on
every layer (8 experts, top-2).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    kind="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32_768,
    vocab_size=131_072,
    mlp="geglu",  # grok-1 experts are gated (3-matrix) FFNs — 2-matrix GELU
    # would give ~213B total; gated gives ~320B, matching the 314B card.
    num_experts=8,
    top_k=2,
    moe_every=1,
    moe_offset=0,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="grok-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        num_experts=4,
        top_k=2,
    )
