"""whisper-small [audio] — encoder-decoder speech model [arXiv:2212.04356].

Decoder backbone: 12L, d_model 768, 12 heads (MHA), d_ff 3072 (GELU),
vocab 51865, LayerNorm, sinusoidal positions. 12-layer encoder consumes the
conv-frontend STUB's frame embeddings (B, 1500, 768) — the mel-spectrogram +
conv feature extractor is stubbed per the brief (input_specs provides frame
embeddings of the right shape).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    kind="encdec",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    mlp="gelu",
    encoder_layers=12,
    encoder_seq=1500,
    frontend="audio_stub",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="whisper-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        encoder_layers=2,
        encoder_seq=60,
    )
