"""Architecture registry: the 10 assigned configs (+ MeshNet paper configs).

``get(arch_id)`` -> full ModelConfig; ``get_smoke(arch_id)`` -> the reduced
same-family variant (<=2 repeats of the pattern, d_model<=512, <=4 experts)
used by the CPU smoke tests. ``INPUT_SHAPES`` are the four assigned shapes.
"""

from __future__ import annotations

import dataclasses
import importlib

ARCHS = [
    "tinyllama-1.1b",
    "qwen1.5-32b",
    "jamba-1.5-large-398b",
    "whisper-small",
    "kimi-k2-1t-a32b",
    "qwen3-14b",
    "internvl2-2b",
    "rwkv6-3b",
    "grok-1-314b",
    "gemma-7b",
]

# name -> (seq_len, global_batch, mode)
INPUT_SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def _module(arch_id: str):
    return importlib.import_module(f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")


def get(arch_id: str, **overrides):
    cfg = _module(arch_id).CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke(arch_id: str):
    return _module(arch_id).smoke()


def for_shape(arch_id: str, shape_name: str):
    """Config specialised for an input shape (long_500k switches dense
    archs to their sliding-window variant — DESIGN.md §4)."""
    cfg = get(arch_id)
    if shape_name == "long_500k" and cfg.kind in ("dense", "moe", "vlm", "encdec", "hybrid"):
        cfg = dataclasses.replace(cfg, sliding_window=8_192)
    return cfg
