"""rwkv6-3b [ssm] — RWKV-6 "Finch" with data-dependent decay
[arXiv:2404.05892].

32L, d_model 2560 (attention-free; 40 heads of size 64), channel-mix
d_ff 8960, vocab 65536. O(1)-state decode -> runs long_500k natively.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    kind="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,  # informational; mixer uses rwkv_head_size
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65_536,
    rwkv_head_size=64,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="rwkv6-smoke",
        num_layers=2,
        d_model=128,
        num_heads=2,
        num_kv_heads=2,
        d_ff=448,
        vocab_size=512,
        rwkv_head_size=64,
    )
