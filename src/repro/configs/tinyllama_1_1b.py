"""tinyllama-1.1b [dense] — Llama-2-architecture small model [arXiv:2401.02385].

22L, d_model 2048, 32 heads with GQA kv=4, d_ff 5632 (SwiGLU), vocab 32000,
RoPE, RMSNorm.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    kind="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32_000,
    mlp="swiglu",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="tinyllama-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=352,
        vocab_size=512,
    )
