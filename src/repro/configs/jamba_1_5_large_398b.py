"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7 interleave with MoE
[arXiv:2403.19887].

72L, d_model 8192, 64 heads GQA kv=8, d_ff 24576, vocab 65536; MoE with 16
experts top-2 on every other layer; attention on 1 of every 8 layers
(position 4 of the period, per the Jamba paper), Mamba elsewhere.
Pattern period = lcm(8, 2) = 8 -> 9 scanned repeats.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    kind="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    mlp="swiglu",
    num_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="jamba-smoke",
        num_layers=8,  # one full pattern period
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        num_experts=4,
        top_k=2,
    )
