"""qwen1.5-32b [dense] — Qwen1.5 family with QKV bias [hf:Qwen/Qwen1.5-0.5B].

64L, d_model 5120, 40 heads (GQA kv=40 — i.e. MHA), d_ff 27392 (SwiGLU),
vocab 152064, QKV projection bias.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    kind="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27_392,
    vocab_size=152_064,
    qkv_bias=True,
    mlp="swiglu",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen1.5-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=344,
        vocab_size=512,
    )
