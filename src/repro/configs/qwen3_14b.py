"""qwen3-14b [dense] — Qwen3 with qk_norm and GQA [hf:Qwen/Qwen3-8B].

40L, d_model 5120, 40 heads GQA kv=8, d_ff 17408 (SwiGLU), vocab 151936,
per-head RMSNorm on Q and K (qk_norm), no QKV bias.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    kind="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17_408,
    vocab_size=151_936,
    qk_norm=True,
    mlp="swiglu",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen3-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=352,
        vocab_size=512,
    )
