"""gemma-7b [dense] — GeGLU MLP, head_dim 256, tied embeddings
[arXiv:2403.08295]. (MQA is the 2b variant; 7b uses 16 heads MHA.)

28L, d_model 3072, 16 heads kv=16, head_dim 256 (16*256 = 4096 > d_model),
d_ff 24576 (GeGLU), vocab 256000, embeddings scaled by sqrt(d_model) and
tied with the output head.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    kind="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24_576,
    vocab_size=256_000,
    head_dim=256,
    mlp="geglu",
    tie_embeddings=True,
    embed_scale=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="gemma-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
    )
