"""internvl2-2b [vlm] — InternViT vision encoder + InternLM2 LM
[arXiv:2404.16821].

LM backbone: 24L, d_model 2048, 16 heads GQA kv=8, d_ff 8192 (SwiGLU),
vocab 92553. The InternViT encoder + MLP projector are STUBBED per the
brief: input_specs provides 256 precomputed patch embeddings (B, 256, 2048)
which the model prepends to the token sequence through a learned projector.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    kind="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    mlp="swiglu",
    frontend="vision_stub",
    num_patches=256,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="internvl2-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        num_patches=16,
    )
