"""Synthetic structural-MRI data pipeline.

The paper trains on HCP T1 volumes with FreeSurfer-derived GWM labels —
a gated dataset we cannot ship (DESIGN.md §1 simulates this gate). This
module generates procedural "brains" whose GWM ground truth is known by
construction, with T1-like intensities + bias field + Rician-ish noise, so
the whole train->segment->postprocess loop (and the MeshNet-vs-U-Net
comparison) runs end-to-end with a real learning signal.

Anatomy model (crude but label-faithful):
  an ellipsoidal head; inside it a smooth radial field r(v) deformed by
  low-frequency noise defines nested shells:
    r < r_wm            -> white matter (label 2, bright ~0.75)
    r_wm <= r < r_gm    -> gray matter  (label 1, mid ~0.45)
    r >= r_gm           -> background/CSF/skull (label 0, dark)
  plus ventricles (dark holes inside WM, label 0) — gives the classic
  GM-envelope-around-WM topology MeshNet must learn with context.

Also provides the paper's DataLoader (§III-A): nibabel loading is replaced
by the generator; CubeDivider sub-volume extraction, one-hot prep and
batching are implemented as described.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticMRIConfig:
    shape: tuple[int, int, int] = (64, 64, 64)
    noise_sigma: float = 0.04
    bias_field_strength: float = 0.15
    deform_strength: float = 0.12  # low-frequency radius deformation
    ventricle_prob: float = 1.0
    dtype: np.dtype = np.float32


def _smooth_noise(key, shape, cutoff: int = 6) -> jax.Array:
    """Low-frequency noise: random coarse grid, trilinearly upsampled."""
    coarse_shape = tuple(max(2, s // cutoff) for s in shape)
    coarse = jax.random.normal(key, coarse_shape)
    return jax.image.resize(coarse, shape, method="trilinear")


def generate(key: jax.Array, cfg: SyntheticMRIConfig = SyntheticMRIConfig()) -> tuple[jax.Array, jax.Array]:
    """One synthetic (T1 volume, GWM labels) pair.

    Returns vol (D,H,W) float in [0,1], labels (D,H,W) int32 in {0,1,2}.
    """
    d, h, w = cfg.shape
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    zz, yy, xx = jnp.meshgrid(
        jnp.linspace(-1, 1, d), jnp.linspace(-1, 1, h), jnp.linspace(-1, 1, w), indexing="ij"
    )
    # Random per-subject head axes (anisotropy ±15%).
    axes = 0.78 + 0.12 * jax.random.uniform(k1, (3,))
    r = jnp.sqrt((zz / axes[0]) ** 2 + (yy / axes[1]) ** 2 + (xx / axes[2]) ** 2)
    r = r + cfg.deform_strength * _smooth_noise(k2, cfg.shape)

    r_wm, r_gm = 0.55, 0.8
    wm = r < r_wm
    gm = (r >= r_wm) & (r < r_gm)

    # Ventricles: a small ellipsoid pair deep in WM relabelled background.
    vz = 0.12 * (jax.random.uniform(k4, ()) - 0.5)
    vent_r = jnp.sqrt(((zz - vz) / 0.18) ** 2 + (yy / 0.28) ** 2 + (xx / 0.12) ** 2)
    vent = (vent_r < 1.0) & wm
    wm = wm & ~vent

    labels = jnp.zeros(cfg.shape, jnp.int32)
    labels = jnp.where(gm, 1, labels)
    labels = jnp.where(wm, 2, labels)

    # T1-like intensities: WM bright, GM mid, CSF/vent dark, skull shell dim.
    vol = jnp.zeros(cfg.shape, jnp.float32)
    vol = jnp.where(gm, 0.45, vol)
    vol = jnp.where(wm, 0.75, vol)
    vol = jnp.where(vent, 0.12, vol)
    skull = (r >= r_gm) & (r < r_gm + 0.08)
    vol = jnp.where(skull, 0.25, vol)

    bias = 1.0 + cfg.bias_field_strength * _smooth_noise(k3, cfg.shape)
    vol = vol * bias + cfg.noise_sigma * jax.random.normal(k5, cfg.shape)
    return jnp.clip(vol, 0.0, 1.0), labels


@dataclasses.dataclass(frozen=True)
class DataLoaderConfig:
    """§III-A DataLoader: batching + optional sub-volume generation."""

    mri: SyntheticMRIConfig = SyntheticMRIConfig()
    batch_size: int = 2
    subvolumes: bool = False  # CubeDivider path
    cube: int = 32
    overlap: int = 0
    num_classes: int = 3
    one_hot: bool = False
    seed: int = 0


class DataLoader:
    """Streams (volume, labels) batches; optionally sub-cube batches.

    Mirrors the paper's DataLoaderClass: (1) load, (2) optional CubeDivider
    split, (3) reshape/one-hot prep, (4) batching.
    """

    def __init__(self, cfg: DataLoaderConfig):
        self.cfg = cfg
        self._gen = jax.jit(lambda k: generate(k, cfg.mri))

    def __iter__(self) -> Iterator[tuple[jax.Array, jax.Array]]:
        return self.batches()

    def batches(self) -> Iterator[tuple[jax.Array, jax.Array]]:
        key = jax.random.PRNGKey(self.cfg.seed)
        while True:
            key, *subkeys = jax.random.split(key, self.cfg.batch_size + 1)
            vols, labs = zip(*(self._gen(k) for k in subkeys))
            vol = jnp.stack(vols)
            lab = jnp.stack(labs)
            if self.cfg.subvolumes:
                vol, lab = self._to_subvolumes(vol, lab, key)
            if self.cfg.one_hot:
                lab = jax.nn.one_hot(lab, self.cfg.num_classes)
            yield vol, lab

    def _to_subvolumes(self, vol, lab, key):
        """Random aligned sub-cube per sample (training-time patching)."""
        c = self.cfg.cube
        b, d, h, w = vol.shape
        keys = jax.random.split(key, 3)
        z0 = jax.random.randint(keys[0], (b,), 0, d - c + 1)
        y0 = jax.random.randint(keys[1], (b,), 0, h - c + 1)
        x0 = jax.random.randint(keys[2], (b,), 0, w - c + 1)

        def cut(v, l, z, y, x):
            vv = jax.lax.dynamic_slice(v, (z, y, x), (c, c, c))
            ll = jax.lax.dynamic_slice(l, (z, y, x), (c, c, c))
            return vv, ll

        return jax.vmap(cut)(vol, lab, z0, y0, x0)
