"""RWKV-6 "Finch" block [arXiv:2404.05892] — attention-free token mixer with
data-dependent decay.

Time-mixing: per-head matrix-valued state S in R^{hd x hd}; for each step
    S_t = diag(w_t) S_{t-1} + k_t^T (v_t)        (outer-product update)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)      (bonus for current token)
with w_t = exp(-exp(ww_t)) computed from the token (the "data-dependent
decay" that distinguishes v6 from v5), and r/k/v/g from token-shifted
interpolations (simplified: one learned lerp per projection instead of the
paper's 5-way LoRA stack; noted in DESIGN.md).

Channel-mixing: squared-ReLU MLP with token-shift, as in the paper.

Training uses the same chunked-scan memory discipline as mamba.py. Decode
carries (last_token, S) — O(1) in context, so rwkv6 runs long_500k natively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import _winit

CHUNK = 128


def init_rwkv(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = cfg.rwkv_num_heads
    ks = jax.random.split(key, 10)
    decay_speed = jnp.array(
        [-6.0 + 5.0 * (i / max(d - 1, 1)) ** 0.7 for i in range(d)], jnp.float32
    )
    return {
        # token-shift lerp factors per projection
        "mu_r": jnp.full((d,), 0.5, cfg.dtype),
        "mu_k": jnp.full((d,), 0.5, cfg.dtype),
        "mu_v": jnp.full((d,), 0.5, cfg.dtype),
        "mu_g": jnp.full((d,), 0.5, cfg.dtype),
        "mu_w": jnp.full((d,), 0.5, cfg.dtype),
        "w_r": _winit(ks[0], (d, d), cfg.dtype),
        "w_k": _winit(ks[1], (d, d), cfg.dtype),
        "w_v": _winit(ks[2], (d, d), cfg.dtype),
        "w_g": _winit(ks[3], (d, d), cfg.dtype),
        # data-dependent decay: low-rank ww = tanh(x W1) W2 + bias
        "w_dec1": _winit(ks[4], (d, 64), cfg.dtype),
        "w_dec2": _winit(ks[5], (64, d), cfg.dtype),
        "b_dec": decay_speed,  # (d,) f32
        "u_bonus": jnp.zeros((nh, hs), jnp.float32),
        "w_o": _winit(ks[6], (d, d), cfg.dtype),
        "ln_x": {"scale": jnp.ones((d,), cfg.dtype), "bias": jnp.zeros((d,), cfg.dtype)},
    }


def _token_shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """Shift sequence right by one; position 0 sees `last` (or zeros)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def _wkv_chunked(r, k, v, w, u, s0):
    """Chunked linear-attention scan.

    r,k,v: (B, T, H, hs); w: (B, T, H, hs) decay in (0,1); u: (H, hs) bonus;
    s0: (B, H, hs, hs). Returns (out (B,T,H,hs), sT).
    """
    B, T, H, hs = r.shape

    def chunk_body(s, args):
        rc, kc, vc, wc = args  # (B, Tc, H, hs)

        def step(s, ins):
            rt, kt, vt, wt = ins  # (B, H, hs)
            kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)  # outer product
            out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
            s = wt[..., None] * s + kv
            return s, out

        s, ys = jax.lax.scan(
            step,
            s,
            tuple(jnp.moveaxis(t, 1, 0) for t in (rc, kc, vc, wc)),
        )
        return s, jnp.moveaxis(ys, 0, 1)

    chunk_body = jax.checkpoint(chunk_body)
    if T % CHUNK == 0 and T > CHUNK:
        nc = T // CHUNK
        args = tuple(
            jnp.moveaxis(t.reshape(B, nc, CHUNK, H, hs), 1, 0) for t in (r, k, v, w)
        )
        sT, ys = jax.lax.scan(lambda s, a_: chunk_body(s, a_), s0, args)
        out = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, hs)
    else:
        sT, out = chunk_body(s0, (r, k, v, w))
    return out, sT


def _projections(p, x, xs, cfg: ModelConfig):
    B, T, d = x.shape
    H, hs = cfg.rwkv_num_heads, cfg.rwkv_head_size
    r = (_mix(x, xs, p["mu_r"]) @ p["w_r"]).reshape(B, T, H, hs).astype(jnp.float32)
    k = (_mix(x, xs, p["mu_k"]) @ p["w_k"]).reshape(B, T, H, hs).astype(jnp.float32)
    v = (_mix(x, xs, p["mu_v"]) @ p["w_v"]).reshape(B, T, H, hs).astype(jnp.float32)
    g = jax.nn.silu(_mix(x, xs, p["mu_g"]) @ p["w_g"])
    xw = _mix(x, xs, p["mu_w"])
    ww = jnp.tanh(xw @ p["w_dec1"]) @ p["w_dec2"]
    w = jnp.exp(-jnp.exp(ww.astype(jnp.float32) + p["b_dec"]))  # (B,T,d) in (0,1)
    w = w.reshape(B, T, H, hs)
    return r, k, v, g, w


def rwkv_time_mix(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence time-mixing. x: (B, T, d)."""
    B, T, d = x.shape
    H, hs = cfg.rwkv_num_heads, cfg.rwkv_head_size
    xs = _token_shift(x)
    r, k, v, g, w = _projections(p, x, xs, cfg)
    s0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    out, _ = _wkv_chunked(r, k, v, w, p["u_bonus"], s0)
    out = out.reshape(B, T, d).astype(x.dtype)
    # group-norm per head approximated by layernorm over d (paper uses GN(H))
    from repro.models.layers import layernorm

    out = layernorm(p["ln_x"], out, 1e-5)
    return (out * g) @ p["w_o"]


def init_rwkv_state(cfg: ModelConfig, batch: int) -> dict:
    H, hs = cfg.rwkv_num_heads, cfg.rwkv_head_size
    return {
        "last": jnp.zeros((batch, 1, cfg.d_model), cfg.dtype),
        "s": jnp.zeros((batch, H, hs, hs), jnp.float32),
    }


def rwkv_decode(p: dict, x: jax.Array, state: dict, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, d)."""
    B = x.shape[0]
    H, hs = cfg.rwkv_num_heads, cfg.rwkv_head_size
    xs = state["last"]
    r, k, v, g, w = _projections(p, x, xs, cfg)
    rt, kt, vt, wt = r[:, 0], k[:, 0], v[:, 0], w[:, 0]
    s = state["s"]
    kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
    out = jnp.einsum("bhk,bhkv->bhv", rt, s + p["u_bonus"][None, :, :, None] * kv)
    s = wt[..., None] * s + kv
    out = out.reshape(B, 1, -1).astype(x.dtype)
    from repro.models.layers import layernorm

    out = layernorm(p["ln_x"], out, 1e-5)
    out = (out * g) @ p["w_o"]
    return out, {"last": x, "s": s}


# ---------------------------------------------------------- channel mixing ---


def init_rwkv_cmix(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, cfg.dtype),
        "mu_r": jnp.full((d,), 0.5, cfg.dtype),
        "w_k": _winit(ks[0], (d, f), cfg.dtype),
        "w_v": _winit(ks[1], (f, d), cfg.dtype),
        "w_r": _winit(ks[2], (d, d), cfg.dtype),
    }


def rwkv_channel_mix(p: dict, x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    xs = _token_shift(x, last)
    k = jnp.square(jax.nn.relu(_mix(x, xs, p["mu_k"]) @ p["w_k"]))
    r = jax.nn.sigmoid(_mix(x, xs, p["mu_r"]) @ p["w_r"])
    return r * (k @ p["w_v"])


def rwkv_channel_mix_decode(p: dict, x: jax.Array, last: jax.Array) -> tuple[jax.Array, jax.Array]:
    out = rwkv_channel_mix(p, x, last)
    return out, x
