"""Unified LM assembly for the architecture zoo.

Families share one skeleton: embeddings -> lax.scan over repeats of the
config's block *pattern* (DESIGN.md §4) -> final norm -> unembed. Block
kinds: attention / mamba / rwkv mixers, dense or MoE MLPs, plus cross-
attention for the enc-dec (whisper) family and prefix-embedding frontends
for VLM/audio stubs.

Three entry points per family (what the dry-run lowers):
  forward(params, batch, cfg)                  -> (logits, aux) training/prefill
  init_cache(cfg, batch, max_seq)              -> decode cache pytree
  decode_step(params, token, cache, pos, cfg)  -> (logits, new_cache)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import rwkv6 as R
from repro.models.config import ModelConfig

# ----------------------------------------------------------------- blocks ---


def _init_block(key, kind: str, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    mixer = kind.split("_")[0]
    p: dict[str, Any] = {"ln1": L.init_rmsnorm(cfg.d_model, cfg.dtype)}
    if mixer == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
    elif mixer == "mamba":
        p["mamba"] = M.init_mamba(ks[0], cfg)
    elif mixer == "rwkv":
        p = {"ln1": L.init_layernorm(cfg.d_model, cfg.dtype)}
        p["tmix"] = R.init_rwkv(ks[0], cfg)
        p["ln2"] = L.init_layernorm(cfg.d_model, cfg.dtype)
        p["cmix"] = R.init_rwkv_cmix(ks[1], cfg)
        return p
    if cfg.kind == "encdec" and mixer == "attn":
        p["ln_cross"] = L.init_layernorm(cfg.d_model, cfg.dtype)
        p["cross"] = L.init_attention(ks[2], cfg)
        p["ln1"] = L.init_layernorm(cfg.d_model, cfg.dtype)
        p["ln2"] = L.init_layernorm(cfg.d_model, cfg.dtype)
    else:
        p["ln2"] = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    if kind.endswith("_moe"):
        p["moe"] = L.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def _apply_mlp_part(bp: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    h = L.apply_norm(bp["ln2"], x, cfg.norm_eps)
    if "moe" in bp:
        out, aux = L.moe(bp["moe"], h, cfg)
    else:
        out, aux = L.mlp(bp["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    return x + out, aux


def _apply_block(
    bp: dict,
    kind: str,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    enc: jax.Array | None = None,
    causal: bool = True,
) -> tuple[jax.Array, jax.Array]:
    mixer = kind.split("_")[0]
    h = L.apply_norm(bp["ln1"], x, cfg.norm_eps)
    if mixer == "attn":
        rope = cfg.kind != "encdec"  # whisper uses absolute (sinusoidal) pos
        x = x + L.attention(bp["attn"], h, cfg, positions, causal=causal, rope=rope)
        if "cross" in bp and enc is not None:
            h2 = L.apply_norm(bp["ln_cross"], x, cfg.norm_eps)
            x = x + _cross_attention(bp["cross"], h2, enc, cfg)
    elif mixer == "mamba":
        x = x + M.mamba_forward(bp["mamba"], h, cfg)
    elif mixer == "rwkv":
        x = x + R.rwkv_time_mix(bp["tmix"], h, cfg)
        h2 = L.apply_norm(bp["ln2"], x, cfg.norm_eps)
        return x + R.rwkv_channel_mix(bp["cmix"], h2), jnp.zeros((), jnp.float32)
    return _apply_mlp_part(bp, x, cfg)


def _cross_attention(p: dict, x: jax.Array, enc: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, T, cfg.num_heads, hd)
    k = (enc @ p["wk"]).reshape(B, enc.shape[1], cfg.num_kv_heads, hd)
    v = (enc @ p["wv"]).reshape(B, enc.shape[1], cfg.num_kv_heads, hd)
    k = L._repeat_kv(k, cfg.num_heads)
    v = L._repeat_kv(v, cfg.num_heads)
    out = L.sdpa(q, k, v, causal=False)
    return out.reshape(B, T, -1) @ p["wo"]


# ------------------------------------------------------------------- init ---


def init(key: jax.Array, cfg: ModelConfig):
    pattern = cfg.block_pattern()
    reps = cfg.num_repeats
    ks = jax.random.split(key, len(pattern) + 4)
    blocks = []
    for pi, kind in enumerate(pattern):
        layer_keys = jax.random.split(ks[pi], reps)
        blocks.append(jax.vmap(lambda k, kind=kind: _init_block(k, kind, cfg))(layer_keys))
    params: dict[str, Any] = {
        "embed": L._winit(ks[-1], (cfg.vocab_size, cfg.d_model), cfg.dtype, scale=0.02),
        "final_norm": (
            L.init_layernorm(cfg.d_model, cfg.dtype)
            if cfg.kind == "encdec"
            else L.init_rmsnorm(cfg.d_model, cfg.dtype)
        ),
        "blocks": tuple(blocks),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L._winit(ks[-2], (cfg.d_model, cfg.vocab_size), cfg.dtype)
    if cfg.frontend is not None:
        params["projector"] = L._winit(ks[-3], (cfg.d_model, cfg.d_model), cfg.dtype)
    if cfg.encoder_layers:
        enc_cfg = cfg  # same widths; bidirectional attention, dense MLP
        enc_keys = jax.random.split(ks[-4], cfg.encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _init_encoder_block(k, enc_cfg))(enc_keys),
            "final_norm": L.init_layernorm(cfg.d_model, cfg.dtype),
        }
    return params


def _init_encoder_block(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_layernorm(cfg.d_model, cfg.dtype),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_layernorm(cfg.d_model, cfg.dtype),
        "mlp": L.init_mlp(ks[1], cfg),
    }


# ------------------------------------------------------------ positional ----


def sinusoidal(positions: jax.Array, d: int, dtype) -> jax.Array:
    """Whisper-style sinusoidal embeddings for arbitrary positions."""
    half = d // 2
    freqs = jnp.exp(-np.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------- forward ---


def _constrain(x, spec):
    """Activation sharding anchor (no-op when spec is None)."""
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _scan_blocks(params, cfg: ModelConfig, x, positions, enc=None, causal=True, act_spec=None):
    pattern = cfg.block_pattern()

    def body(carry, rep_params):
        h, aux = carry
        h = _constrain(h, act_spec)
        for pi, kind in enumerate(pattern):
            h, a = _apply_block(rep_params[pi], kind, h, cfg, positions, enc, causal)
            aux = aux + a
        return (_constrain(h, act_spec), aux), None

    if cfg.remat == "full":
        # Layer-streaming for training: backward recomputes each repeat, so
        # only the repeat-boundary activations are saved across the scan.
        body = jax.checkpoint(body, prevent_cse=False)
    unroll = cfg.num_repeats if cfg.scan_unroll else 1
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["blocks"], unroll=unroll
    )
    return x, aux


def forward_sharded(params, batch: dict, cfg: ModelConfig, act_spec):
    """forward() with an activation-sharding anchor (batch over the data
    axes) applied at the embedding and at every scan repeat — prevents the
    partitioner from propagating the embedding table's vocab/d sharding
    into a batch-replicated activation layout."""
    return forward(params, batch, cfg, act_spec=act_spec)


def encode(params, frames: jax.Array, cfg: ModelConfig, act_spec=None) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, S_enc, d)."""
    x = frames @ params["projector"] if "projector" in params else frames
    pos = jnp.arange(x.shape[1])
    x = _constrain(x + sinusoidal(pos, cfg.d_model, x.dtype)[None], act_spec)

    def body(h, bp):
        a = L.apply_norm(bp["ln1"], h, cfg.norm_eps)
        h = h + L.attention(bp["attn"], a, cfg, pos, causal=False, rope=False)
        m = L.apply_norm(bp["ln2"], h, cfg.norm_eps)
        return _constrain(h + L.mlp(bp["mlp"], m, cfg), act_spec), None

    x, _ = jax.lax.scan(
        body, x, params["encoder"]["blocks"],
        unroll=cfg.encoder_layers if cfg.scan_unroll else 1,
    )
    return L.apply_norm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def forward(params, batch: dict, cfg: ModelConfig, act_spec=None) -> tuple[jax.Array, jax.Array]:
    """Training / prefill forward.

    batch keys by family: tokens (all); patches (vlm, (B,P,d) stub
    embeddings); frames (audio, (B,S_enc,d) stub embeddings).
    Returns (logits over the token positions, moe aux loss).
    """
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = _constrain(params["embed"][tokens], act_spec)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    prefix = 0
    enc = None
    if cfg.frontend == "vision_stub":
        patches = batch["patches"] @ params["projector"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        x = _constrain(x, act_spec)
        prefix = patches.shape[1]
    if cfg.kind == "encdec":
        enc = encode(params, batch["frames"], cfg, act_spec=act_spec)
        x = x + sinusoidal(jnp.arange(T), cfg.d_model, x.dtype)[None]
    positions = jnp.arange(x.shape[1])
    x, aux = _scan_blocks(params, cfg, x, positions, enc=enc, causal=True, act_spec=act_spec)
    x = L.apply_norm(params["final_norm"], x, cfg.norm_eps)
    if prefix:
        x = x[:, prefix:]
    logits = unembed(params, x, cfg)
    return logits, aux


def unembed(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = x @ w
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


# ----------------------------------------------------------------- decode ---


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, enc_out: jax.Array | None = None):
    """Decode cache pytree, stacked over repeats per pattern position.

    For attention positions: (R, B, S, KV, hd) K/V rings (S = sliding window
    if set, else max_seq). Mamba/RWKV positions carry O(1) recurrent state.
    """
    pattern = cfg.block_pattern()
    reps = cfg.num_repeats
    hd = cfg.resolved_head_dim
    S = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq

    def stack(make):
        one = make()
        return jax.tree.map(lambda l: jnp.broadcast_to(l, (reps,) + l.shape), one)

    caches = []
    for kind in pattern:
        mixer = kind.split("_")[0]
        if mixer == "attn":
            if cfg.kv_quant:
                entry = stack(
                    lambda: {
                        "k": jnp.zeros((batch, S, cfg.num_kv_heads, hd), jnp.int8),
                        "v": jnp.zeros((batch, S, cfg.num_kv_heads, hd), jnp.int8),
                        "ks": jnp.zeros((batch, S, cfg.num_kv_heads, 1), jnp.float32),
                        "vs": jnp.zeros((batch, S, cfg.num_kv_heads, 1), jnp.float32),
                    }
                )
            else:
                entry = stack(
                    lambda: {
                        "k": jnp.zeros((batch, S, cfg.num_kv_heads, hd), cfg.dtype),
                        "v": jnp.zeros((batch, S, cfg.num_kv_heads, hd), cfg.dtype),
                    }
                )
            if cfg.kind == "encdec":
                assert enc_out is not None or True
                Se = cfg.encoder_seq
                entry["ek"] = jnp.zeros((reps, batch, Se, cfg.num_kv_heads, hd), cfg.dtype)
                entry["ev"] = jnp.zeros((reps, batch, Se, cfg.num_kv_heads, hd), cfg.dtype)
            caches.append(entry)
        elif mixer == "mamba":
            caches.append(stack(lambda: M.init_mamba_state(cfg, batch)))
        else:  # rwkv
            caches.append(
                stack(
                    lambda: dict(
                        R.init_rwkv_state(cfg, batch),
                        last_c=jnp.zeros((batch, 1, cfg.d_model), cfg.dtype),
                    )
                )
            )
    return tuple(caches)


def fill_cross_cache(params, cache, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output into the cache."""
    B, Se, _ = enc_out.shape
    hd = cfg.resolved_head_dim

    def per_rep(bp):
        k = (enc_out @ bp["cross"]["wk"]).reshape(B, Se, cfg.num_kv_heads, hd)
        v = (enc_out @ bp["cross"]["wv"]).reshape(B, Se, cfg.num_kv_heads, hd)
        return k, v

    new_cache = []
    for pi, entry in enumerate(cache):
        if "ek" in entry:
            ks, vs = jax.vmap(per_rep)(jax.tree.map(lambda a: a, params["blocks"][pi]))
            entry = dict(entry, ek=ks.astype(entry["ek"].dtype), ev=vs.astype(entry["ev"].dtype))
        new_cache.append(entry)
    return tuple(new_cache)


def _decode_block(bp, kind, x, state, pos, cfg: ModelConfig):
    mixer = kind.split("_")[0]
    h = L.apply_norm(bp["ln1"], x, cfg.norm_eps)
    if mixer == "attn":
        if cfg.kv_quant:
            out, ck, cv, cks, cvs = L.attention_decode(
                bp["attn"], h, cfg, state["k"], state["v"], pos,
                rope=cfg.kind != "encdec", cache_ks=state["ks"], cache_vs=state["vs"],
            )
            state = dict(state, k=ck, v=cv, ks=cks, vs=cvs)
        else:
            out, ck, cv = L.attention_decode(
                bp["attn"], h, cfg, state["k"], state["v"], pos, rope=cfg.kind != "encdec"
            )
            state = dict(state, k=ck, v=cv)
        x = x + out
        if "cross" in bp:
            h2 = L.apply_norm(bp["ln_cross"], x, cfg.norm_eps)
            x = x + _cross_attention_cached(bp["cross"], h2, state["ek"], state["ev"], cfg)
    elif mixer == "mamba":
        out, state = M.mamba_decode(bp["mamba"], h, state, cfg)
        x = x + out
    else:  # rwkv
        out, tstate = R.rwkv_decode(bp["tmix"], h, {"last": state["last"], "s": state["s"]}, cfg)
        x = x + out
        h2 = L.apply_norm(bp["ln2"], x, cfg.norm_eps)
        out2, new_last_c = R.rwkv_channel_mix_decode(bp["cmix"], h2, state["last_c"])
        x = x + out2
        return x, {"last": tstate["last"], "s": tstate["s"], "last_c": new_last_c}
    x, _ = _apply_mlp_part(bp, x, cfg)
    return x, state


def _cross_attention_cached(p, x, ek, ev, cfg: ModelConfig):
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, T, cfg.num_heads, hd)
    k = L._repeat_kv(ek, cfg.num_heads)
    v = L._repeat_kv(ev, cfg.num_heads)
    out = L.sdpa(q, k, v, causal=False)
    return out.reshape(B, T, -1) @ p["wo"]


def decode_step(params, token: jax.Array, cache, pos: jax.Array, cfg: ModelConfig, act_spec=None):
    """One decode step. token: (B, 1) int32; pos: scalar int32 (current
    sequence position). Returns (logits (B, 1, V), new cache)."""
    x = _constrain(params["embed"][token], act_spec)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.kind == "encdec":
        x = x + sinusoidal(pos[None], cfg.d_model, x.dtype)[None]
    pattern = cfg.block_pattern()

    def body(carry, rep):
        h = _constrain(carry, act_spec)
        rep_params, rep_cache = rep
        new_states = []
        for pi, kind in enumerate(pattern):
            h, st = _decode_block(rep_params[pi], kind, h, rep_cache[pi], pos, cfg)
            new_states.append(st)
        return h, tuple(new_states)

    x, new_cache = jax.lax.scan(
        body, x, (params["blocks"], cache),
        unroll=cfg.num_repeats if cfg.scan_unroll else 1,
    )
    x = L.apply_norm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params, x, cfg), new_cache
