"""Flash attention (pure JAX, custom VJP) — O(T) memory for 4k–32k training
and prefill.

Forward: online-softmax streaming over KV blocks (exact), saving only
(out, logsumexp) per row. Backward: recomputes score blocks tile-by-tile
(the flash-attention-2 backward), so neither pass materialises the
(T x T) matrix. This is the sequence-space version of the paper's
sub-volume patching: bound the working set, merge exactly.

A Pallas TPU kernel would push this further (VMEM-resident tiles); the
pure-JAX version keeps the dry-run portable while giving XLA fusion-sized
blocks. Validated against the naive oracle in tests/test_models.py for
values AND gradients.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

Q_BLOCK = 512
K_BLOCK = 1024
NEG = -1e30


def _mask(qpos, kpos, causal, window, tk):
    m = kpos[None, :] < tk
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, window=None, q_block=Q_BLOCK, k_block=K_BLOCK):
    """q: (B, Tq, H, hd); k/v: (B, Tk, H, hd) -> (B, Tq, H, hd)."""
    out, _ = _forward(q, k, v, causal, window, q_block, k_block)
    return out


def _forward(q, k, v, causal, window, q_block, k_block):
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    qp = (-Tq) % q_block
    kp = (-Tk) % k_block
    qpad = jnp.pad(q, ((0, 0), (0, qp), (0, 0), (0, 0)))
    kpad = jnp.pad(k, ((0, 0), (0, kp), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (0, kp), (0, 0), (0, 0)))
    nq, nk = qpad.shape[1] // q_block, kpad.shape[1] // k_block
    scale = 1.0 / np.sqrt(hd)
    kb = jnp.moveaxis(kpad.reshape(B, nk, k_block, H, hd), 1, 0)
    vb = jnp.moveaxis(vpad.reshape(B, nk, k_block, H, hd), 1, 0)

    def q_row(qi, qblk):
        qpos = qi * q_block + jnp.arange(q_block)

        def kv_step(carry, inp):
            acc, m, denom = carry
            ki, kblk, vblk = inp
            kpos = ki * k_block + jnp.arange(k_block)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(jnp.float32) * scale
            s = jnp.where(_mask(qpos, kpos, causal, window, Tk), s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            denom = denom * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((B, H, q_block, hd), jnp.float32)
        m0 = jnp.full((B, H, q_block), -jnp.inf, jnp.float32)
        d0 = jnp.zeros((B, H, q_block), jnp.float32)
        (acc, m, denom), _ = jax.lax.scan(kv_step, (acc0, m0, d0), (jnp.arange(nk), kb, vb))
        denom = jnp.maximum(denom, 1e-30)
        out = (acc / denom[..., None]).astype(q.dtype)  # (B, H, qb, hd)
        lse = m + jnp.log(denom)  # (B, H, qb)
        return jnp.moveaxis(out, 1, 2), lse

    outs, lses = jax.lax.map(lambda i: q_row(i, qpad.reshape(B, nq, q_block, H, hd)[:, i]), jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_block, H, hd)[:, :Tq]
    lse = jnp.moveaxis(lses, 0, 2).reshape(B, H, nq * q_block)[..., :Tq]  # (B, H, Tq)
    return out, lse


def _fwd(q, k, v, causal, window, q_block, k_block):
    out, lse = _forward(q, k, v, causal, window, q_block, k_block)
    return out, (q, k, v, out, lse)


def _bwd(causal, window, q_block, k_block, res, dout):
    q, k, v, out, lse = res
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    qp = (-Tq) % q_block
    kp = (-Tk) % k_block
    scale = 1.0 / np.sqrt(hd)
    # D_i = rowsum(dout * out) — the softmax-jacobian diagonal term.
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)  # (B,Tq,H)
    delta = jnp.moveaxis(delta, -1, 1)  # (B,H,Tq)

    qpad = jnp.pad(q, ((0, 0), (0, qp), (0, 0), (0, 0)))
    dopad = jnp.pad(dout, ((0, 0), (0, qp), (0, 0), (0, 0)))
    kpad = jnp.pad(k, ((0, 0), (0, kp), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (0, kp), (0, 0), (0, 0)))
    lsep = jnp.pad(lse, ((0, 0), (0, 0), (0, qp)), constant_values=0.0)
    delp = jnp.pad(delta, ((0, 0), (0, 0), (0, qp)), constant_values=0.0)
    nq, nk = qpad.shape[1] // q_block, kpad.shape[1] // k_block
    qb = jnp.moveaxis(qpad.reshape(B, nq, q_block, H, hd), 1, 0)
    dob = jnp.moveaxis(dopad.reshape(B, nq, q_block, H, hd), 1, 0)
    lseb = jnp.moveaxis(lsep.reshape(B, H, nq, q_block), 2, 0)  # (nq,B,H,qb)
    delb = jnp.moveaxis(delp.reshape(B, H, nq, q_block), 2, 0)

    def k_col(ki):
        kpos = ki * k_block + jnp.arange(k_block)
        kblk = jax.lax.dynamic_index_in_dim(
            jnp.moveaxis(kpad.reshape(B, nk, k_block, H, hd), 1, 0), ki, 0, keepdims=False
        )
        vblk = jax.lax.dynamic_index_in_dim(
            jnp.moveaxis(vpad.reshape(B, nk, k_block, H, hd), 1, 0), ki, 0, keepdims=False
        )

        def q_step(carry, inp):
            dk_acc, dv_acc = carry
            qi, qblk, doblk, lse_b, del_b = inp
            qpos = qi * q_block + jnp.arange(q_block)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(jnp.float32) * scale
            s = jnp.where(_mask(qpos, kpos, causal, window, Tk), s, NEG)
            p = jnp.exp(s - lse_b[..., None])  # (B,H,qb,kb)
            do32 = doblk.astype(jnp.float32)
            dv_acc = dv_acc + jnp.einsum("bhqk,bqhd->bkhd", p, do32)
            dp = jnp.einsum("bqhd,bkhd->bhqk", do32, vblk.astype(jnp.float32))
            ds = p * (dp - del_b[..., None]) * scale
            dk_acc = dk_acc + jnp.einsum("bhqk,bqhd->bkhd", ds, qblk.astype(jnp.float32))
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, k_block, H, hd), jnp.float32)
        (dk_b, dv_b), _ = jax.lax.scan(
            q_step, (z, z), (jnp.arange(nq), qb, dob, lseb, delb)
        )
        return dk_b, dv_b

    dks, dvs = jax.lax.map(k_col, jnp.arange(nk))  # (nk, B, kb, H, hd)
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, nk * k_block, H, hd)[:, :Tk]
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, nk * k_block, H, hd)[:, :Tk]

    def q_row_grad(qi):
        qpos = qi * q_block + jnp.arange(q_block)
        qblk = jax.lax.dynamic_index_in_dim(qb, qi, 0, keepdims=False)
        doblk = jax.lax.dynamic_index_in_dim(dob, qi, 0, keepdims=False)
        lse_b = jax.lax.dynamic_index_in_dim(lseb, qi, 0, keepdims=False)
        del_b = jax.lax.dynamic_index_in_dim(delb, qi, 0, keepdims=False)

        def k_step(dq_acc, inp):
            ki, kblk, vblk = inp
            kpos = ki * k_block + jnp.arange(k_block)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(jnp.float32) * scale
            s = jnp.where(_mask(qpos, kpos, causal, window, Tk), s, NEG)
            p = jnp.exp(s - lse_b[..., None])
            dp = jnp.einsum("bqhd,bkhd->bhqk", doblk.astype(jnp.float32), vblk.astype(jnp.float32))
            ds = p * (dp - del_b[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds, kblk.astype(jnp.float32))
            return dq_acc, None

        kbs = jnp.moveaxis(kpad.reshape(B, nk, k_block, H, hd), 1, 0)
        vbs = jnp.moveaxis(vpad.reshape(B, nk, k_block, H, hd), 1, 0)
        dq_b, _ = jax.lax.scan(
            k_step, jnp.zeros((B, q_block, H, hd), jnp.float32), (jnp.arange(nk), kbs, vbs)
        )
        return dq_b

    dqs = jax.lax.map(q_row_grad, jnp.arange(nq))  # (nq, B, qb, H, hd)
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, nq * q_block, H, hd)[:, :Tq]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd, _bwd)
