"""Unified model configuration for the assigned architecture zoo.

One ``ModelConfig`` drives every family (dense / MoE / hybrid / SSM /
enc-dec / VLM): the layer stack is described by a repeating *pattern* of
block kinds (see ``block_pattern``), each block's params are stacked over
pattern repeats, and the forward pass scans over repeats — the
scan-over-layers memory discipline inherited from the paper's layer-by-layer
streaming (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    kind: str = "dense"  # dense | moe | hybrid | ssm | encdec | vlm | audio
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1000
    head_dim: int | None = None  # default d_model // num_heads (gemma: 256)
    qkv_bias: bool = False  # qwen1.5
    qk_norm: bool = False  # qwen3
    mlp: str = "swiglu"  # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)
    logit_softcap: float | None = None

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # layer i is MoE iff i % moe_every == moe_offset
    moe_offset: int = 0
    router_aux_weight: float = 0.01  # load-balance loss weight
    moe_capacity_factor: float = 1.25  # per-expert slot headroom (GShard)

    # --- hybrid (Jamba): layer i is attention iff i % attn_every == attn_offset
    attn_every: int = 0  # 0 -> all layers are attention
    attn_offset: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- RWKV6 ---------------------------------------------------------------
    rwkv_head_size: int = 64

    # --- encoder-decoder (whisper) -------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 1500  # 30 s of mel frames after the conv stub

    # --- modality frontend stubs (vlm / audio) --------------------------------
    frontend: str | None = None  # "vision_stub" | "audio_stub"
    num_patches: int = 0  # vision tokens prepended to the text sequence

    # --- long-context variant -------------------------------------------------
    sliding_window: int | None = None  # set for the long_500k dense variant
    # int8 KV cache (beyond-paper, EXPERIMENTS.md §Perf H8): K/V stored as
    # int8 with per-slot/per-kv-head f32 scales — halves decode cache bytes.
    kv_quant: bool = False

    dtype: Any = jnp.bfloat16
    # remat policy for the scan-over-layers: "full" recomputes each block in
    # backward (the paper's layer-streaming discipline applied to training),
    # "none" saves everything (small models / debugging).
    remat: str = "full"
    # Unroll the scan-over-layers at lowering time. Production lowering keeps
    # the rolled scan (compact HLO, double-buffered weights); the dry-run's
    # *census* pass unrolls so XLA cost_analysis counts every layer's ops and
    # collectives exactly (a rolled while body is costed once, not x trips).
    scan_unroll: bool = False

    # -------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.mamba_expand * self.d_model

    @property
    def rwkv_num_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def block_pattern(self) -> list[str]:
        """The repeating unit of the layer stack.

        Block kinds: 'attn' | 'mamba' | 'rwkv', suffixed '_moe' when the
        position uses a MoE MLP. len(pattern) divides num_layers; params for
        position p are stacked over num_layers/len(pattern) repeats.
        """
        if self.kind == "ssm":
            return ["rwkv"]
        period = 1
        if self.attn_every:
            period = max(period, self.attn_every)
        if self.num_experts and self.moe_every > 1:
            period = max(period, self.moe_every)
        if self.attn_every and self.num_experts and self.moe_every > 1:
            import math

            period = math.lcm(self.attn_every, self.moe_every)
        pattern = []
        for i in range(period):
            mixer = "attn"
            if self.attn_every and i % self.attn_every != self.attn_offset:
                mixer = "mamba"
            moe = bool(self.num_experts) and (i % max(self.moe_every, 1) == self.moe_offset)
            pattern.append(mixer + ("_moe" if moe else ""))
        return pattern

    @property
    def num_repeats(self) -> int:
        pat = len(self.block_pattern())
        assert self.num_layers % pat == 0, (self.num_layers, pat)
        return self.num_layers // pat

    # --- parameter counting (for roofline MODEL_FLOPS) -------------------------
    def param_counts(self) -> dict:
        """Returns dict(total=..., active=...) — active counts top_k experts."""
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim
        q = self.num_heads * hd
        kv = self.num_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d
        if self.qkv_bias:
            attn += q + 2 * kv
        n_in = 2 if self.mlp in ("swiglu", "geglu") else 1
        dense_mlp = d * f * n_in + f * d
        moe_mlp = self.num_experts * dense_mlp + d * self.num_experts
        active_mlp = self.top_k * dense_mlp + d * self.num_experts if self.num_experts else dense_mlp
        din, ds = self.d_inner, self.mamba_d_state
        mamba = d * 2 * din + din * self.mamba_d_conv + din * (2 * ds + 1) + din + din * d
        rwkv_h = self.rwkv_num_heads if self.kind == "ssm" else 0
        rwkv = 6 * d * d + 2 * d  # time-mix projections (r,k,v,g,w,o) approx
        total = active = 0
        for blk in self.block_pattern():
            mixer = blk.split("_")[0]
            mix_p = {"attn": attn, "mamba": mamba, "rwkv": rwkv}[mixer]
            mlp_p = moe_mlp if blk.endswith("_moe") else (dense_mlp if mixer != "rwkv" else dense_mlp)
            act_p = active_mlp if blk.endswith("_moe") else mlp_p
            total += mix_p + mlp_p + 2 * d
            active += mix_p + act_p + 2 * d
        total *= self.num_repeats
        active *= self.num_repeats
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total += emb + d
        active += emb + d
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + dense_mlp + 2 * d)
            total += enc
            active += enc
        return {"total": int(total), "active": int(active)}
