"""Shared transformer layers: norms, RoPE, GQA attention (full / sliding /
blockwise-streamed / cached-decode), dense & MoE MLPs.

All functions are pure; params are dicts created by the matching init_*.
Memory discipline: long sequences use blockwise (online-softmax) attention —
the sequence-space analogue of the paper's sub-volume patching (DESIGN.md
§4): split the iteration space, keep the working set bounded, merge with an
exact (rescaled) reduction instead of an overlap halo.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

# ------------------------------------------------------------------ norms ---


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    return layernorm(p, x, eps) if "bias" in p else rmsnorm(p, x, eps)


# ------------------------------------------------------------------- RoPE ---


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); positions: (B, T) or (T,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, T, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention ---


def _winit(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) == 2 else int(np.prod(shape[:-1]))
    std = scale if scale is not None else (1.0 / np.sqrt(fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def init_attention(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    q_out, kv_out = cfg.num_heads * hd, cfg.num_kv_heads * hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _winit(ks[0], (d, q_out), cfg.dtype),
        "wk": _winit(ks[1], (d, kv_out), cfg.dtype),
        "wv": _winit(ks[2], (d, kv_out), cfg.dtype),
        "wo": _winit(ks[3], (q_out, d), cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((q_out,), cfg.dtype)
        p["bk"] = jnp.zeros((kv_out,), cfg.dtype)
        p["bv"] = jnp.zeros((kv_out,), cfg.dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, cfg.dtype)
        p["k_norm"] = init_rmsnorm(hd, cfg.dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions, *, rope: bool = True):
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, cfg.num_heads, hd)
    k = k.reshape(B, T, cfg.num_kv_heads, hd)
    v = v.reshape(B, T, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, T, KV, hd) -> (B, T, H, hd) by repeating each kv head."""
    kv = k.shape[2]
    if kv == num_heads:
        return k
    return jnp.repeat(k, num_heads // kv, axis=2)


def sdpa(q, k, v, *, causal: bool, sliding_window: int | None = None,
         q_offset: int = 0) -> jax.Array:
    """Naive attention. q: (B, Tq, H, hd), k/v: (B, Tk, H, hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / np.sqrt(hd)
    tq, tk = q.shape[1], k.shape[1]
    qpos = jnp.arange(tq) + q_offset
    kpos = jnp.arange(tk)
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if sliding_window is not None:
        mask &= kpos[None, :] > qpos[:, None] - sliding_window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def blockwise_sdpa(q, k, v, *, causal: bool, sliding_window: int | None = None,
                   q_block: int = 512, k_block: int = 1024) -> jax.Array:
    """Online-softmax attention: O(T) memory, exact. Streams KV blocks per
    Q block with running (max, denom) — 'patching' in sequence space."""
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    q_pad = (-Tq) % q_block
    k_pad = (-Tk) % k_block
    qp = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_block, kp.shape[1] // k_block
    qb = qp.reshape(B, nq, q_block, H, hd)
    kb = kp.reshape(B, nk, k_block, H, hd)
    vb = vp.reshape(B, nk, k_block, H, hd)
    scale = 1.0 / np.sqrt(hd)

    def one_q_block(qi, qblk):
        # qblk: (B, q_block, H, hd)
        qpos = qi * q_block + jnp.arange(q_block)

        def kv_step(carry, inp):
            acc, m, denom = carry
            ki, kblk, vblk = inp
            kpos = ki * k_block + jnp.arange(k_block)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(jnp.float32) * scale
            mask = kpos[None, :] < Tk  # mask K padding
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if sliding_window is not None:
                mask &= kpos[None, :] > qpos[:, None] - sliding_window
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            denom = denom * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32)
            )
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((B, H, q_block, hd), jnp.float32)
        m0 = jnp.full((B, H, q_block), -jnp.inf, jnp.float32)
        d0 = jnp.zeros((B, H, q_block), jnp.float32)
        inds = jnp.arange(nk)
        (acc, m, denom), _ = jax.lax.scan(
            kv_step, (acc0, m0, d0), (inds, jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0))
        )
        out = acc / jnp.maximum(denom[..., None], 1e-30)
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, q_block, H, hd)

    outs = jax.lax.map(
        lambda i: one_q_block(i, qb[:, i]), jnp.arange(nq)
    )  # (nq, B, q_block, H, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_block, H, hd)
    return out[:, :Tq]


# Threshold above which training/prefill attention switches to blockwise.
BLOCKWISE_THRESHOLD = 2048


def attention(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    causal: bool = True,
    rope: bool = True,
) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    q, k, v = _project_qkv(p, x, cfg, positions, rope=rope)
    k = _repeat_kv(k, cfg.num_heads)
    v = _repeat_kv(v, cfg.num_heads)
    if x.shape[1] > BLOCKWISE_THRESHOLD and not cfg.scan_unroll:
        from repro.models.flash import flash_attention

        out = flash_attention(q, k, v, causal, cfg.sliding_window)
    else:
        # Short sequences — and the dry-run census pass (scan_unroll), which
        # needs loop-free attention so cost_analysis counts the full T^2
        # FLOPs (flash's internal scans would be costed once, not x trips).
        out = sdpa(q, k, v, causal=causal, sliding_window=cfg.sliding_window)
    B, T = x.shape[:2]
    return out.reshape(B, T, -1) @ p["wo"]


def quantize_kv(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(token, kv-head) symmetric int8 quantization of (B, T, KV, hd)."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def attention_decode(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    *,
    rope: bool = True,
    cache_ks: jax.Array | None = None,
    cache_vs: jax.Array | None = None,
):
    """One-token decode against a (B, S, KV, hd) cache.

    ``pos`` (scalar int32): current position; the new K/V are written at
    ``pos % S`` — plain append for full attention (S = max seq), ring-buffer
    overwrite for sliding-window caches (S = window). With ``cfg.kv_quant``
    the cache is int8 + per-slot scales (``cache_ks``/``cache_vs``).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(p, x, cfg, jnp.full((B, 1), pos), rope=rope)
    S = cache_k.shape[1]
    slot = (pos % S).astype(jnp.int32)
    if cfg.kv_quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        cache_k = jax.lax.dynamic_update_slice(cache_k, kq, (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, vq, (0, slot, 0, 0))
        cache_ks = jax.lax.dynamic_update_slice(cache_ks, ks, (0, slot, 0, 0))
        cache_vs = jax.lax.dynamic_update_slice(cache_vs, vs, (0, slot, 0, 0))
        kk = cache_k.astype(x.dtype) * cache_ks.astype(x.dtype)
        vv = cache_v.astype(x.dtype) * cache_vs.astype(x.dtype)
        kk = _repeat_kv(kk, cfg.num_heads)
        vv = _repeat_kv(vv, cfg.num_heads)
    else:
        cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
        kk = _repeat_kv(cache_k, cfg.num_heads)
        vv = _repeat_kv(cache_v, cfg.num_heads)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) / np.sqrt(hd)
    kpos = jnp.arange(S)
    if cfg.sliding_window is not None and S == cfg.sliding_window:
        # Ring buffer: every resident slot is within the window once pos >= S;
        # before that, mask slots beyond the current position.
        valid = kpos <= jnp.minimum(pos, S - 1)
    else:
        valid = kpos <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    out = out.reshape(B, 1, -1) @ p["wo"]
    if cfg.kv_quant:
        return out, cache_k, cache_v, cache_ks, cache_vs
    return out, cache_k, cache_v


# ------------------------------------------------------------------- MLPs ---


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": _winit(ks[0], (d, f), cfg.dtype),
            "w_up": _winit(ks[1], (d, f), cfg.dtype),
            "w_down": _winit(ks[2], (f, d), cfg.dtype),
        }
    return {
        "w_up": _winit(ks[0], (d, f), cfg.dtype),
        "b_up": jnp.zeros((f,), cfg.dtype),
        "w_down": _winit(ks[1], (f, d), cfg.dtype),
        "b_down": jnp.zeros((d,), cfg.dtype),
    }


def mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if cfg.mlp == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])) @ p["w_down"]
    return (jax.nn.gelu(x @ p["w_up"] + p["b_up"], approximate=True)) @ p["w_down"] + p["b_down"]


# -------------------------------------------------------------------- MoE ---


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    n_in = 2 if cfg.mlp in ("swiglu", "geglu") else 1
    p = {
        "router": _winit(ks[0], (d, e), jnp.float32),  # router in f32
        "w_up": _winit(ks[1], (e, d, n_in * f), cfg.dtype),
        "w_down": _winit(ks[2], (e, f, d), cfg.dtype),
    }
    return p


def moe(
    p: dict, x: jax.Array, cfg: ModelConfig, capacity_factor: float | None = None
) -> tuple[jax.Array, jax.Array]:
    """Top-k routed MoE MLP -> (out, aux_loss).

    Capacity-based sort dispatch with *per-sequence routing groups*: each
    sequence routes its own tokens into per-expert capacity slots
    (C = ceil(top_k * T / E * cf)), so the routing (argsort + scatter) stays
    local to the 'data'-sharded batch axis — no cross-device communication
    for dispatch, and expert FLOPs are proportional to *activated* params
    (unlike a dense all-experts einsum, which would inflate HLO_FLOPs by
    E/top_k — 48x for kimi-k2). Overflowing tokens are dropped (standard
    GShard semantics); the combine weight renormalizes over kept choices.
    """
    B, T, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cf = cfg.moe_capacity_factor if capacity_factor is None else capacity_factor
    C = max(1, int(np.ceil(k * T / e * cf)))
    logits = x.astype(jnp.float32) @ p["router"]  # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)  # (B, T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    n_in = 2 if cfg.mlp in ("swiglu", "geglu") else 1

    def route_group(xg, ei, wi):
        # xg: (T, d); ei/wi: (T, k). Choice-major priority: all 1st choices
        # claim capacity before any 2nd choice (GShard ordering).
        flat_e = ei.T.reshape(-1)  # (k*T,)
        flat_w = wi.T.reshape(-1)
        flat_tok = jnp.tile(jnp.arange(T), k)
        order = jnp.argsort(flat_e, stable=True)
        se = flat_e[order]
        first = jnp.searchsorted(se, se, side="left")
        rank = jnp.arange(k * T) - first
        slot = jnp.where(rank < C, se * C + rank, e * C)  # e*C = overflow bin
        buf = jnp.zeros((e * C + 1, d), x.dtype).at[slot].add(xg[flat_tok[order]])
        h = buf[:-1].reshape(e, C, d)
        up = jnp.einsum("ecd,edf->ecf", h, p["w_up"])  # (e, C, n_in*f)
        if n_in == 2:
            g, u = jnp.split(up, 2, axis=-1)
            act = jax.nn.silu(g) if cfg.mlp == "swiglu" else jax.nn.gelu(g, approximate=True)
            hh = act * u
        else:
            hh = jax.nn.gelu(up, approximate=True)
        down = jnp.einsum("ecf,efd->ecd", hh, p["w_down"]).reshape(e * C, d)
        down = jnp.concatenate([down, jnp.zeros((1, d), down.dtype)])
        contrib = down[slot] * flat_w[order][:, None].astype(down.dtype)
        return jnp.zeros((T, d), x.dtype).at[flat_tok[order]].add(contrib.astype(x.dtype))

    out = jax.vmap(route_group)(x, top_i, top_p)

    # load-balance auxiliary loss (global over the batch)
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    disp = jax.nn.one_hot(top_i.reshape(-1, k), e, dtype=jnp.float32)
    ce = jnp.mean(jnp.sum(disp, axis=1), axis=0)
    aux = e * jnp.sum(me * ce)
    return out, aux
