"""Mamba (S6) block — the SSM mixer of Jamba's hybrid stack [arXiv:2403.19887].

Selective state-space layer: in_proj -> (x, z); causal depthwise conv;
data-dependent (dt, B, C) from x; diagonal SSM recurrence
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t;  y_t = C_t h_t + D x_t
gated by silu(z), out_proj back to d_model.

Memory discipline (DESIGN.md §4): training scans over *chunks* of the
sequence (chunk the iteration space — the paper's patching idea applied to
time): the inter-chunk carry is just the (B, d_inner, d_state) state, and
``jax.checkpoint`` on the chunk body keeps backward residuals at chunk
boundaries only, so the (B, T, d_inner, d_state) tensor never materializes.

Decode is a single recurrence step against a carried (conv window, h) state
— O(1) in context length, which is why Jamba runs the long_500k shape
natively (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import _winit

CHUNK = 128  # time-chunk for the training scan


def init_mamba(key, cfg: ModelConfig) -> dict:
    d, din, ds, dc = cfg.d_model, cfg.d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    dt_rank = max(1, d // 16)
    # S4D-real initialization of A (negative reals), kept in log space.
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (din, 1))
    return {
        "w_in": _winit(ks[0], (d, 2 * din), cfg.dtype),
        "conv_w": _winit(ks[1], (dc, din), cfg.dtype),  # depthwise causal conv
        "conv_b": jnp.zeros((din,), cfg.dtype),
        "w_bcdt": _winit(ks[2], (din, 2 * ds + dt_rank), cfg.dtype),
        "w_dt": _winit(ks[3], (dt_rank, din), cfg.dtype),
        "b_dt": jnp.log(jnp.expm1(jnp.full((din,), 0.01))).astype(jnp.float32),
        "log_a": jnp.log(a),  # (din, ds) f32
        "d_skip": jnp.ones((din,), jnp.float32),
        "w_out": _winit(ks[4], (din, d), cfg.dtype),
    }


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def _causal_conv(x, w, b):
    """Depthwise causal conv along T. x: (B, T, din); w: (dc, din)."""
    dc = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(dc))
    return out + b


def _ssm_scan(u, dt, bb, cc, log_a, d_skip, h0):
    """Chunked diagonal SSM scan.

    u: (B, T, din); dt: (B, T, din); bb/cc: (B, T, ds); h0: (B, din, ds).
    Returns (y (B, T, din), hT).
    """
    B, T, din = u.shape
    ds = bb.shape[-1]
    a = -jnp.exp(log_a)  # (din, ds) negative reals

    def chunk_body(h, args):
        uc, dtc, bc, ccc = args  # (B, Tc, ...)

        def step(h, ins):
            ut, dtt, bt, ct = ins  # (B, din), (B, din), (B, ds), (B, ds)
            da = jnp.exp(dtt[..., None] * a)  # (B, din, ds)
            h = da * h + (dtt * ut)[..., None] * bt[:, None, :]
            y = jnp.einsum("bds,bs->bd", h, ct)
            return h, y

        h, ys = jax.lax.scan(
            step,
            h,
            (
                jnp.moveaxis(uc, 1, 0),
                jnp.moveaxis(dtc, 1, 0),
                jnp.moveaxis(bc, 1, 0),
                jnp.moveaxis(ccc, 1, 0),
            ),
        )
        return h, jnp.moveaxis(ys, 0, 1)  # (B, Tc, din)

    chunk_body = jax.checkpoint(chunk_body)
    if T % CHUNK == 0 and T > CHUNK:
        nc = T // CHUNK
        args = tuple(
            jnp.moveaxis(t.reshape(B, nc, CHUNK, *t.shape[2:]), 1, 0)
            for t in (u, dt, bb, cc)
        )
        hT, ys = jax.lax.scan(lambda h, a_: chunk_body(h, a_), h0, args)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, T, din)
    else:
        hT, y = chunk_body(h0, (u, dt, bb, cc))
    y = y + u * d_skip
    return y, hT


def mamba_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence forward (training / prefill). x: (B, T, d)."""
    B, T, _ = x.shape
    din, ds = cfg.d_inner, cfg.mamba_d_state
    xz = x @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
    bcdt = xi @ p["w_bcdt"]
    bb = bcdt[..., :ds].astype(jnp.float32)
    cc = bcdt[..., ds : 2 * ds].astype(jnp.float32)
    dt_in = bcdt[..., 2 * ds :]
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) @ p["w_dt"].astype(jnp.float32) + p["b_dt"])
    h0 = jnp.zeros((B, din, ds), jnp.float32)
    y, _ = _ssm_scan(xi.astype(jnp.float32), dt, bb, cc, p["log_a"], p["d_skip"], h0)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    """Decode-time carried state: conv tail + SSM state."""
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.d_inner), cfg.dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.mamba_d_state), dtype),
    }


def mamba_decode(p: dict, x: jax.Array, state: dict, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, d) -> (out (B, 1, d), new state)."""
    B = x.shape[0]
    ds = cfg.mamba_d_state
    xz = x[:, 0] @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state["conv"], xi[:, None]], axis=1)  # (B, dc, din)
    conv = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"]
    xi = jax.nn.silu(conv)
    bcdt = xi @ p["w_bcdt"]
    bb = bcdt[..., :ds].astype(jnp.float32)
    cc = bcdt[..., ds : 2 * ds].astype(jnp.float32)
    dt_in = bcdt[..., 2 * ds :]
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) @ p["w_dt"].astype(jnp.float32) + p["b_dt"])
    a = -jnp.exp(p["log_a"])
    da = jnp.exp(dt[..., None] * a)
    h = da * state["h"] + (dt * xi.astype(jnp.float32))[..., None] * bb[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, cc) + xi.astype(jnp.float32) * p["d_skip"]
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    new_state = {"conv": window[:, 1:], "h": h}
    return out[:, None], new_state
