"""jit'd public wrappers around the Pallas kernels.

Handles what the raw kernels don't: arbitrary spatial shapes (pad to block
multiples + slice back), dtype policy, BatchNorm folding, backend dispatch
(interpret on CPU hosts, compiled on TPU), and a kernel-backed MeshNet
forward pass (`meshnet_apply`) that fuses conv+BN+ReLU per layer.

``meshnet_apply`` is the "pallas_fused" backend of the executor registry
(core/executors.py); ``meshnet_apply_megakernel`` is the depth-first
"pallas_megakernel" backend (kernels/megakernel.py) — the pipeline's
production path on TPU when the tile plan fits VMEM ("auto" prefers it),
benchmarked head-to-head against the XLA reference in
benchmarks/bench_kernels.py. Parity with ``meshnet.apply`` (eval mode) is
enforced by tests/test_executors.py and tests/test_megakernel.py across
the PAPER_MODELS sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dice as dice_kernel
from repro.kernels import dilated_conv3d as conv_kernel
from repro.kernels import megakernel as mega_kernel
from repro.kernels import quantize

# interpret=True on CPU (this container); compiled Mosaic on real TPU.
_INTERPRET = jax.default_backend() != "tpu"


def _pad_to_multiple(x: jax.Array, m: int):
    pads = [(0, (-s) % m) for s in x.shape[1:4]]
    if not any(p[1] for p in pads):
        return x, x.shape
    return jnp.pad(x, [(0, 0)] + pads + [(0, 0)]), x.shape


def dilated_conv3d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    dilation: int = 1,
    scale=None,
    offset=None,
    fuse_affine: bool = False,
    block: int = 16,
    interpret: bool | None = None,
) -> jax.Array:
    """'Same' 3-D dilated conv for any (B, D, H, W, Cin)."""
    interpret = _INTERPRET if interpret is None else interpret
    if x.ndim == 4:
        x = x[..., None]
    xp, orig_shape = _pad_to_multiple(x, block)
    out = conv_kernel.dilated_conv3d(
        xp, w, b,
        dilation=dilation, scale=scale, offset=offset,
        block=block, interpret=interpret, fuse_affine=fuse_affine,
    )
    if xp.shape != x.shape:
        out = out[:, : orig_shape[1], : orig_shape[2], : orig_shape[3], :]
    return out


def fold_batchnorm(layer: dict, eps: float = 1e-5) -> tuple[jax.Array, jax.Array]:
    """Fold inference BN into (scale, offset) for the fused epilogue."""
    inv = jax.lax.rsqrt(layer["bn_var"] + eps)
    scale = layer["bn_scale"] * inv
    offset = layer["bn_bias"] - layer["bn_mean"] * scale
    return scale, offset


def meshnet_apply(
    params,
    x: jax.Array,
    cfg,
    *,
    block: int = 16,
    interpret: bool | None = None,
    precision: str = "fp32",
) -> jax.Array:
    """Kernel-backed MeshNet inference forward (== meshnet.apply, eval mode).

    Each hidden layer is ONE fused Pallas call (conv+BN+ReLU epilogue):
    activations make a single HBM round-trip per layer instead of three.

    ``precision`` (kernels/quantize.py): "fp32" is the legacy bit-exact
    path; "bf16" ships bf16 activations/weights with fp32 accumulate in
    the kernel; "int8w" streams per-output-channel int8 weights whose
    dequant scale rides the (always-fused) affine epilogue — the conv
    bias moves into the epilogue offset because the raw accumulator is in
    quantized-weight units. Activations stay bf16 on this per-layer path
    (inter-layer staging is the schedule itself; only the megakernel has
    int8 staging boundaries).
    """
    if x.ndim == 4:
        x = x[..., None]
    if precision == "fp32":
        for i, d in enumerate(cfg.dilations):
            layer = params["layers"][i]
            if cfg.use_batchnorm:
                scale, offset = fold_batchnorm(layer)
            else:
                scale = offset = None
            x = dilated_conv3d(
                x, layer["w"], layer["b"],
                dilation=d, scale=scale, offset=offset, fuse_affine=True,
                block=block, interpret=interpret,
            )
        head = params["head"]
        # 1x1x1 head: a plain einsum (pointwise) — no spatial kernel needed.
        return jnp.einsum("bdhwi,io->bdhwo", x, head["w"][0, 0, 0]) + head["b"]

    quantize.validate(precision)
    params = quantize.prepare_params(params, cfg, precision)
    adt = quantize.act_dtype(precision)
    if precision == "int8w":
        # match the megakernel/reference rounding: the input is quantized
        # to the conformed volume's int8 grid, then computed in bf16
        if x.dtype != jnp.int8:
            x = quantize.quantize_input(x)
        x = x.astype(adt) * jnp.asarray(quantize.INPUT_SCALE, adt)
    else:
        x = x.astype(adt)
    for i, d in enumerate(cfg.dilations):
        layer = params["layers"][i]
        bias, scale, offset = quantize.fold_epilogue(layer, cfg.use_batchnorm)
        x = dilated_conv3d(
            x, layer["w"], bias,
            dilation=d, scale=scale, offset=offset, fuse_affine=True,
            block=block, interpret=interpret,
        )
    head = params["head"]
    logits = (
        jnp.einsum(
            "bdhwi,io->bdhwo",
            x,
            head["w"][0, 0, 0].astype(adt),
            preferred_element_type=jnp.float32,
        )
        + head["b"].astype(jnp.float32)
    )
    return logits.astype(adt)


def meshnet_apply_megakernel(
    params,
    x: jax.Array,
    cfg,
    *,
    vmem_budget: int | None = None,
    interpret: bool | None = None,
    z_bounds: jax.Array | None = None,
    precision: str = "fp32",
    staging_scales=None,
) -> jax.Array:
    """Depth-first tiled MeshNet forward (== meshnet.apply, eval mode).

    The whole hidden stack (and the 1x1x1 head) runs per VMEM-resident
    tile inside a handful of ``pallas_call``s — hidden activations never
    round-trip HBM within a segment (kernels/megakernel.py, EXPERIMENTS.md
    §Perf H9). The "pallas_megakernel" backend of the executor registry.

    ``z_bounds`` (dynamic (2,)-int32) narrows the per-layer zero-masked
    Z-valid interval — the sharded executor's slab+halo windows pass the
    true volume extent here (core/spatial_shard.py).

    ``precision``/``staging_scales`` select the storage policy and (for
    int8w) the calibrated per-channel staging scales — see
    kernels/megakernel.py and kernels/quantize.py.
    """
    interpret = _INTERPRET if interpret is None else interpret
    return mega_kernel.meshnet_apply(
        params,
        x,
        cfg,
        vmem_budget=vmem_budget or mega_kernel.VMEM_BUDGET,
        interpret=interpret,
        fold_affine=fold_batchnorm if cfg.use_batchnorm else None,
        z_bounds=z_bounds,
        precision=precision,
        staging_scales=staging_scales,
    )


def dice(pred: jax.Array, truth: jax.Array, num_classes: int, *, interpret: bool | None = None) -> jax.Array:
    """Macro Dice score via the fused count-accumulator kernel."""
    interpret = _INTERPRET if interpret is None else interpret
    counts = dice_kernel.dice_counts(pred, truth, num_classes, interpret=interpret)
    return dice_kernel.dice_from_counts(counts)
