"""Depth-first tiled MeshNet megakernel — the whole stack per VMEM tile.

The per-layer fused kernel (kernels/dilated_conv3d.py) still writes and
re-reads the full activation volume once per layer, so a 9-layer MeshNet
forward moves ~10 full volumes of HBM traffic even with a perfect conv.
This module inverts the loop order: instead of *breadth-first* (each layer
over the whole volume), it runs *depth-first* — partition the output into
tiles, and for each tile run **all** hidden layers back-to-back inside a
single ``pallas_call``, keeping the activation tile in VMEM across layers.
Each tile loads its haloed input region once (halo inflated by the sum of
the 3^3 dilations it crosses, so the final tile is exact), and hidden
activations never touch HBM at all. The 1x1x1 classifier head folds into
the last call, so a whole forward is: read the haloed input tiles, write
the logits. See DESIGN.md §2 (depth-first tiling & HBM traffic model) and
EXPERIMENTS.md §Perf H9.

Exactness (including the volume boundary)
-----------------------------------------
A window that zero-pads only at its own edge diverges from the full-volume
forward near the *volume* boundary, because 'same' convs re-introduce zero
padding at every layer (the sub-volume accuracy loss characterised in
core/patching.py). The megakernel does not inherit that loss: after the
haloed DMA and after every in-tile layer, positions outside the true
volume are masked back to zero, reproducing per-layer 'same' padding
bit-for-bit — the same trick as core/spatial_shard.py's halo exchange,
applied inside VMEM. This also means the HBM staging buffers between
segments can carry uninitialised (never-written) halo borders: whatever
garbage they hold is masked out at the next DMA, so no staging pad copies
are needed.

Segmentation — the overlap-add fallback
---------------------------------------
The full schedule's halo (sum(1,2,4,8,16,8,4,2,1) = 46 per side) inflates
a tile's working set past the ~16 MB VMEM budget for realistic channel
widths, so ``plan`` splits the layer stack into consecutive *segments*,
each run depth-first with its own (smaller) halo, with one full activation
round-trip between segments — the cheap end of the overlap-add spectrum:
one segment per layer degenerates to the per-layer fused path; one segment
for the whole stack is the pure megakernel. The planner chooses segment
boundaries and per-axis tile shapes by dynamic programming over the
modeled HBM traffic, subject to ``_segment_vmem_bytes`` staying under the
budget (tiles need not be cubes: the d=16 layer fits best as e.g.
24x64x64). ``MegakernelPlan.hbm_bytes`` is the traffic model the
benchmarks and telemetry report (telemetry/traffic.py).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: Default planning budget: 16 MiB VMEM per core minus Mosaic headroom.
VMEM_BUDGET = 14 * 1024 * 1024

#: Per-axis tile-size candidates (sublane-friendly multiples of 8).
TILE_CANDIDATES = (8, 16, 24, 32, 48, 64, 96, 128, 256)


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class Segment:
    """A consecutive run of hidden layers executed depth-first per tile."""

    start: int  # index of the first layer in cfg.dilations
    dilations: tuple[int, ...]
    cin: int  # input channels (in_channels for the first segment)
    channels: int  # hidden width C
    tile: tuple[int, int, int]
    fuse_head: bool = False  # apply the 1x1x1 head after the last layer
    num_classes: int = 0

    @property
    def halo(self) -> int:
        return sum(self.dilations)

    @property
    def cout(self) -> int:
        return self.num_classes if self.fuse_head else self.channels

    def buffer_sizes(self) -> list[tuple[int, int, int]]:
        """Per-layer valid-region sizes: S_0 = tile + 2*halo shrinking by
        2*d per layer down to S_k = tile exactly."""
        sizes = [tuple(t + 2 * self.halo for t in self.tile)]
        for d in self.dilations:
            sizes.append(tuple(s - 2 * d for s in sizes[-1]))
        assert sizes[-1] == self.tile, (sizes, self)
        return sizes


def _segment_vmem_bytes(seg: Segment, dtype_bytes: int = 4) -> int:
    """VMEM working set of one grid step: the statically allocated scratch
    (DMA'd input buffer + ping/pong activation buffers + logits staging
    when the head is fused + weights) **plus** the transient f32
    accumulator of the widest layer — scratch lives for the whole kernel,
    and the tap loop's ``acc`` is live alongside it, so omitting it would
    admit plans that exceed real VMEM (the tap reads themselves stream
    from the resident buffers and need no second copy)."""
    sizes = seg.buffer_sizes()
    buf_in = math.prod(sizes[0]) * seg.cin * dtype_bytes
    ping = max(math.prod(s) for s in sizes[1::2]) * seg.channels * dtype_bytes
    pong = (
        max(math.prod(s) for s in sizes[2::2]) * seg.channels * dtype_bytes
        if len(sizes) > 2
        else 0
    )
    wgt = 27 * seg.cin * seg.channels * dtype_bytes
    wgt += 27 * seg.channels**2 * dtype_bytes * (len(seg.dilations) - 1)
    logits = (
        math.prod(seg.tile) * seg.num_classes * dtype_bytes if seg.fuse_head else 0
    )
    acc = max(math.prod(s) for s in sizes[1:]) * seg.channels * 4  # f32
    if seg.fuse_head:
        acc = max(acc, math.prod(seg.tile) * seg.num_classes * 4)
    return buf_in + ping + pong + wgt + logits + acc


def _segment_hbm_bytes(
    seg: Segment, padded: tuple[int, int, int], dtype_bytes: int
) -> int:
    """Modeled HBM bytes of one segment: haloed tile reads, per-grid-step
    weight streams, and the central-region write. The ONE formula shared
    by ``MegakernelPlan.hbm_bytes`` (what telemetry/benchmarks report) and
    the planner's DP objective — so the plan the DP picks is the minimum
    of the model it reports."""
    ntiles = math.prod(pp // t for pp, t in zip(padded, seg.tile))
    window = math.prod(t + 2 * seg.halo for t in seg.tile)
    wgt = 27 * seg.cin * seg.channels * dtype_bytes
    wgt += 27 * seg.channels**2 * dtype_bytes * (len(seg.dilations) - 1)
    if seg.fuse_head:
        wgt += seg.channels * seg.num_classes * dtype_bytes
    total = ntiles * (window * seg.cin * dtype_bytes + wgt)
    total += math.prod(padded) * seg.cout * dtype_bytes
    return total


@dataclasses.dataclass(frozen=True)
class MegakernelPlan:
    """Static execution plan: segments + geometry for one (cfg, volume)."""

    segments: tuple[Segment, ...]
    vol: tuple[int, int, int]  # true volume dims (pre-padding)
    vmem_budget: int

    def padded(self, seg: Segment) -> tuple[int, int, int]:
        """Tile-multiple dims of the region this segment computes."""
        return tuple(_ceil_to(v, t) for v, t in zip(self.vol, seg.tile))

    def out_dims(self, i: int) -> tuple[int, int, int]:
        """Spatial dims of segment i's HBM output array. Sized for the
        *next* segment's haloed DMA windows: max of both segments' padded
        extents plus the next halo per side (the halo border is never
        written — its garbage is masked out after the next DMA)."""
        cur = self.padded(self.segments[i])
        if i + 1 == len(self.segments):
            return cur
        nxt = self.segments[i + 1]
        pad = self.padded(nxt)
        return tuple(max(c, p) + 2 * nxt.halo for c, p in zip(cur, pad))

    def hbm_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        """Modeled HBM traffic of one forward: the input pad round-trip,
        then per segment the haloed tile reads, the weight streams, and the
        central-region writes (staging halo borders are allocated but never
        written, so they cost nothing)."""
        total = 0
        first = self.segments[0]
        p0 = self.padded(first)
        # host-side zero-pad of the raw input (read + padded write)
        total += math.prod(self.vol) * first.cin * dtype_bytes
        total += math.prod(t + 2 * first.halo for t in p0) * first.cin * dtype_bytes
        for seg in self.segments:
            total += _segment_hbm_bytes(seg, self.padded(seg), dtype_bytes)
        return batch * total


def plan(
    dilations: Sequence[int],
    in_channels: int,
    channels: int,
    num_classes: int,
    vol: tuple[int, int, int],
    *,
    vmem_budget: int = VMEM_BUDGET,
    dtype_bytes: int = 4,
) -> MegakernelPlan:
    """Choose segment boundaries and per-axis tiles by DP over modeled
    HBM traffic, subject to each segment's working set fitting VMEM.

    Raises with an actionable message when even a single layer at the
    smallest tile exceeds the budget (channel width is the only lever
    left at that point). Memoized: the DP costs ~0.4 s in Python at the
    paper volume, and the serving path replans the same (model, volume)
    on every request ("auto" resolution, traffic telemetry, the forward
    itself).
    """
    return _plan_cached(
        tuple(int(d) for d in dilations),
        int(in_channels),
        int(channels),
        int(num_classes),
        tuple(int(v) for v in vol),
        int(vmem_budget),
        int(dtype_bytes),
    )


@functools.lru_cache(maxsize=256)
def _plan_cached(
    dils: tuple[int, ...],
    in_channels: int,
    channels: int,
    num_classes: int,
    vol: tuple[int, int, int],
    vmem_budget: int,
    dtype_bytes: int,
) -> MegakernelPlan:
    n = len(dils)
    # Oversize tiles only waste padding: cap candidates near the volume.
    cands = [
        [t for t in TILE_CANDIDATES if t <= _ceil_to(v, 8)] or [8] for v in vol
    ]
    tiles = [
        (tz, ty, tx) for tz in cands[0] for ty in cands[1] for tx in cands[2]
    ]

    def seg_for(i: int, j: int, tile) -> Segment:
        return Segment(
            start=i,
            dilations=dils[i:j],
            cin=in_channels if i == 0 else channels,
            channels=channels,
            tile=tile,
            fuse_head=(j == n),
            num_classes=num_classes,
        )

    def traffic(seg: Segment, plan_: MegakernelPlan) -> int:
        p = plan_.padded(seg)
        pad = 0
        if seg.start == 0:
            pad = math.prod(vol) * seg.cin * dtype_bytes
            pad += math.prod(t + 2 * seg.halo for t in p) * seg.cin * dtype_bytes
        return pad + _segment_hbm_bytes(seg, p, dtype_bytes)

    probe = MegakernelPlan(segments=(), vol=vol, vmem_budget=vmem_budget)
    INF = float("inf")
    best: list[float] = [INF] * (n + 1)
    best[n] = 0.0
    choice: list[tuple[int, tuple[int, int, int]] | None] = [None] * (n + 1)
    for i in range(n - 1, -1, -1):
        for j in range(i + 1, n + 1):
            for tile in tiles:
                seg = seg_for(i, j, tile)
                if _segment_vmem_bytes(seg, dtype_bytes) > vmem_budget:
                    continue
                cost = traffic(seg, probe) + best[j]
                if cost < best[i]:
                    best[i] = cost
                    choice[i] = (j, tile)
    if best[0] == INF:
        one = seg_for(0, 1, (8, 8, 8))
        raise ValueError(
            f"megakernel plan infeasible: one layer at tile (8,8,8) needs "
            f"{_segment_vmem_bytes(one, dtype_bytes) / 2**20:.1f} MiB of VMEM, "
            f"over the {vmem_budget / 2**20:.0f} MiB budget — reduce channel "
            f"width ({channels}) or raise vmem_budget"
        )
    segments = []
    i = 0
    while i < n:
        j, tile = choice[i]  # type: ignore[misc]
        segments.append(seg_for(i, j, tile))
        i = j
    return MegakernelPlan(segments=tuple(segments), vol=vol, vmem_budget=vmem_budget)


def plan_for_config(
    cfg, vol: tuple[int, int, int], *, vmem_budget: int = VMEM_BUDGET, dtype_bytes: int = 4
) -> MegakernelPlan:
    """``plan`` from a MeshNetConfig-shaped object."""
    return plan(
        cfg.dilations,
        cfg.in_channels,
        cfg.channels,
        cfg.num_classes,
        vol,
        vmem_budget=vmem_budget,
        dtype_bytes=dtype_bytes,
    )


def _segment_kernel(
    *refs,
    seg: Segment,
    vol: tuple[int, int, int],
    out_halo: int,
    use_affine: bool,
    has_z_bounds: bool = False,
):
    """Kernel body: DMA the haloed input window, run ``seg``'s layers
    back-to-back in VMEM (masking out-of-volume positions after every
    layer so per-layer 'same' zero padding is reproduced exactly), then
    DMA the finished tile (or fused-head logits) back out.

    ``has_z_bounds`` adds a dynamic (2,)-int32 SMEM input narrowing the
    valid Z interval below ``[0, vol[0])`` — the sharded executor
    (core/spatial_shard.py) uses it to place the *true* volume boundary
    inside a slab+halo window, so pod-edge slabs re-zero their
    out-of-volume halo per layer exactly like full-volume 'same' padding.
    """
    k = len(seg.dilations)
    per_layer = 4 if use_affine else 2
    n_head = 2 if seg.fuse_head else 0
    n_in = 1 + k * per_layer + n_head + (1 if has_z_bounds else 0)
    x_ref = refs[0]
    layer_refs = [
        refs[1 + i * per_layer : 1 + (i + 1) * per_layer] for i in range(k)
    ]
    head_refs = (
        refs[1 + k * per_layer : 1 + k * per_layer + n_head]
        if seg.fuse_head
        else None
    )
    zb_ref = refs[n_in - 1] if has_z_bounds else None
    out_ref = refs[n_in]
    scratch = refs[n_in + 1 :]
    buf_in, ping = scratch[0], scratch[1]
    idx = 2
    pong = scratch[idx] if k >= 2 else None
    idx += 1 if k >= 2 else 0
    logits_buf = scratch[idx] if seg.fuse_head else None
    idx += 1 if seg.fuse_head else 0
    sem = scratch[idx]

    bi, zi, yi, xi = (pl.program_id(i) for i in range(4))
    ids = (zi, yi, xi)
    tile = seg.tile
    h = seg.halo
    sizes = seg.buffer_sizes()

    dma = pltpu.make_async_copy(
        x_ref.at[
            bi,
            pl.ds(zi * tile[0], sizes[0][0]),
            pl.ds(yi * tile[1], sizes[0][1]),
            pl.ds(xi * tile[2], sizes[0][2]),
            :,
        ],
        buf_in,
        sem.at[0],
    )
    dma.start()
    dma.wait()

    def mask(v, size, r):
        """Zero positions whose global coord (tile origin - r + local) lies
        outside the true volume — per-layer 'same' padding, and the
        neutraliser for the staging arrays' unwritten halo borders. With
        ``z_bounds`` the Z-valid interval is the intersection of
        ``[0, vol[0])`` and the dynamic ``[zb[0], zb[1])``."""
        ok = None
        for ax in range(3):
            i = jax.lax.broadcasted_iota(jnp.int32, size + (1,), ax)
            lo = r - ids[ax] * tile[ax]
            m = (i >= lo) & (i < vol[ax] + lo)
            if ax == 0 and zb_ref is not None:
                m = m & (i >= zb_ref[0] + lo) & (i < zb_ref[1] + lo)
            ok = m if ok is None else (ok & m)
        return jnp.where(ok, v, jnp.zeros((), v.dtype))

    buf_in[...] = mask(buf_in[...], sizes[0], h)

    prev, prev_size = buf_in, sizes[0]
    cum = 0
    for li, d in enumerate(seg.dilations):
        w_ref, b_ref = layer_refs[li][0], layer_refs[li][1]
        size = sizes[li + 1]
        cum += d
        w = w_ref[...]
        acc = jnp.zeros(size + (w.shape[-1],), jnp.float32)
        for tz in (-1, 0, 1):
            for ty in (-1, 0, 1):
                for tx in (-1, 0, 1):
                    sl = prev[
                        d + tz * d : d + tz * d + size[0],
                        d + ty * d : d + ty * d + size[1],
                        d + tx * d : d + tx * d + size[2],
                        :,
                    ]
                    acc = acc + jnp.einsum(
                        "zyxi,io->zyxo",
                        sl.astype(jnp.float32),
                        w[tz + 1, ty + 1, tx + 1].astype(jnp.float32),
                        preferred_element_type=jnp.float32,
                    )
        out = acc + b_ref[...].astype(jnp.float32)
        if use_affine:
            s_ref, o_ref = layer_refs[li][2], layer_refs[li][3]
            out = out * s_ref[...].astype(jnp.float32) + o_ref[...].astype(
                jnp.float32
            )
        out = jnp.maximum(out, 0.0)
        if li + 1 < k:
            out = mask(out, size, h - cum)
        dst = ping if li % 2 == 0 else pong
        dst[0 : size[0], 0 : size[1], 0 : size[2], :] = out.astype(dst.dtype)
        prev, prev_size = dst, size

    if seg.fuse_head:
        hw_ref, hb_ref = head_refs
        x = prev[0 : tile[0], 0 : tile[1], 0 : tile[2], :]
        logits = (
            jnp.einsum(
                "zyxi,io->zyxo",
                x.astype(jnp.float32),
                hw_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            + hb_ref[...].astype(jnp.float32)
        )
        logits_buf[...] = logits.astype(logits_buf.dtype)
        src = logits_buf
    else:
        src = prev.at[
            pl.ds(0, tile[0]), pl.ds(0, tile[1]), pl.ds(0, tile[2]), :
        ]
    odma = pltpu.make_async_copy(
        src,
        out_ref.at[
            bi,
            pl.ds(out_halo + zi * tile[0], tile[0]),
            pl.ds(out_halo + yi * tile[1], tile[1]),
            pl.ds(out_halo + xi * tile[2], tile[2]),
            :,
        ],
        sem.at[1],
    )
    odma.start()
    odma.wait()


def _run_segment(
    act: jax.Array,
    seg: Segment,
    pln: MegakernelPlan,
    i: int,
    params: dict,
    use_affine: bool,
    fold_affine,
    interpret: bool,
    z_bounds: jax.Array | None = None,
) -> jax.Array:
    B = act.shape[0]
    padded = pln.padded(seg)
    out_dims = pln.out_dims(i)
    out_halo = (
        pln.segments[i + 1].halo if i + 1 < len(pln.segments) else 0
    )

    args = [act]
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)]

    def add_full(a):
        args.append(a)
        in_specs.append(pl.BlockSpec(a.shape, lambda *_, n=a.ndim: (0,) * n))

    for li in range(len(seg.dilations)):
        layer = params["layers"][seg.start + li]
        add_full(layer["w"])
        add_full(layer["b"])
        if use_affine:
            scale, offset = fold_affine(layer)
            add_full(scale)
            add_full(offset)
    if seg.fuse_head:
        add_full(params["head"]["w"][0, 0, 0])  # (C, num_classes)
        add_full(params["head"]["b"])
    if z_bounds is not None:
        args.append(z_bounds)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))

    sizes = seg.buffer_sizes()
    scratch = [
        pltpu.VMEM(sizes[0] + (seg.cin,), act.dtype),
        pltpu.VMEM(sizes[1] + (seg.channels,), act.dtype),
    ]
    if len(seg.dilations) >= 2:
        scratch.append(pltpu.VMEM(sizes[2] + (seg.channels,), act.dtype))
    if seg.fuse_head:
        scratch.append(pltpu.VMEM(seg.tile + (seg.num_classes,), act.dtype))
    scratch.append(pltpu.SemaphoreType.DMA((2,)))

    kernel = functools.partial(
        _segment_kernel,
        seg=seg,
        vol=pln.vol,
        out_halo=out_halo,
        use_affine=use_affine,
        has_z_bounds=z_bounds is not None,
    )
    grid = (B,) + tuple(p // t for p, t in zip(padded, seg.tile))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((B,) + out_dims + (seg.cout,), act.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)


def meshnet_apply(
    params,
    x: jax.Array,
    cfg,
    *,
    pln: MegakernelPlan | None = None,
    vmem_budget: int = VMEM_BUDGET,
    interpret: bool = True,
    fold_affine=None,
    z_bounds: jax.Array | None = None,
) -> jax.Array:
    """Depth-first MeshNet forward (== meshnet.apply, eval mode).

    ``fold_affine`` maps a layer dict to the folded inference-BN
    (scale, offset); ops.meshnet_apply_megakernel supplies it (kept
    injectable so this module does not import ops).

    ``z_bounds`` (optional (2,)-int32) narrows the valid Z interval below
    ``[0, D)``: positions outside it are re-zeroed per layer exactly like
    positions outside the volume. The sharded executor passes the true
    volume's extent inside a slab+halo window (core/spatial_shard.py).
    """
    if x.ndim == 4:
        x = x[..., None]
    B, D, H, W, Cin = x.shape
    vol = (D, H, W)
    if pln is None:
        pln = plan_for_config(
            cfg, vol, vmem_budget=vmem_budget, dtype_bytes=x.dtype.itemsize
        )
    use_affine = bool(cfg.use_batchnorm)
    if use_affine and fold_affine is None:
        raise ValueError("fold_affine is required when cfg.use_batchnorm")

    first = pln.segments[0]
    p0 = pln.padded(first)
    h0 = first.halo
    act = jnp.pad(
        x,
        [(0, 0)]
        + [(h0, h0 + p - v) for p, v in zip(p0, vol)]
        + [(0, 0)],
    )
    for i, seg in enumerate(pln.segments):
        act = _run_segment(
            act, seg, pln, i, params, use_affine, fold_affine, interpret,
            z_bounds=z_bounds,
        )
    return act[:, :D, :H, :W, :]
