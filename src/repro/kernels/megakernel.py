"""Depth-first tiled MeshNet megakernel — the whole stack per VMEM tile.

The per-layer fused kernel (kernels/dilated_conv3d.py) still writes and
re-reads the full activation volume once per layer, so a 9-layer MeshNet
forward moves ~10 full volumes of HBM traffic even with a perfect conv.
This module inverts the loop order: instead of *breadth-first* (each layer
over the whole volume), it runs *depth-first* — partition the output into
tiles, and for each tile run **all** hidden layers back-to-back inside a
single ``pallas_call``, keeping the activation tile in VMEM across layers.
Each tile loads its haloed input region once (halo inflated by the sum of
the 3^3 dilations it crosses, so the final tile is exact), and hidden
activations never touch HBM at all. The 1x1x1 classifier head folds into
the last call, so a whole forward is: read the haloed input tiles, write
the logits. See DESIGN.md §2 (depth-first tiling & HBM traffic model) and
EXPERIMENTS.md §Perf H9.

Exactness (including the volume boundary)
-----------------------------------------
A window that zero-pads only at its own edge diverges from the full-volume
forward near the *volume* boundary, because 'same' convs re-introduce zero
padding at every layer (the sub-volume accuracy loss characterised in
core/patching.py). The megakernel does not inherit that loss: after the
haloed DMA and after every in-tile layer, positions outside the true
volume are masked back to zero, reproducing per-layer 'same' padding
bit-for-bit — the same trick as core/spatial_shard.py's halo exchange,
applied inside VMEM. This also means the HBM staging buffers between
segments can carry uninitialised (never-written) halo borders: whatever
garbage they hold is masked out at the next DMA, so no staging pad copies
are needed.

Segmentation — the overlap-add fallback
---------------------------------------
The full schedule's halo (sum(1,2,4,8,16,8,4,2,1) = 46 per side) inflates
a tile's working set past the ~16 MB VMEM budget for realistic channel
widths, so ``plan`` splits the layer stack into consecutive *segments*,
each run depth-first with its own (smaller) halo, with one full activation
round-trip between segments — the cheap end of the overlap-add spectrum:
one segment per layer degenerates to the per-layer fused path; one segment
for the whole stack is the pure megakernel. The planner chooses segment
boundaries and per-axis tile shapes by dynamic programming over the
modeled HBM traffic, subject to ``_segment_vmem_bytes`` staying under the
budget (tiles need not be cubes: the d=16 layer fits best as e.g.
24x64x64). ``MegakernelPlan.hbm_bytes`` is the traffic model the
benchmarks and telemetry report (telemetry/traffic.py).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import quantize

#: Default planning budget: 16 MiB VMEM per core minus Mosaic headroom.
VMEM_BUDGET = 14 * 1024 * 1024

#: Per-axis tile-size candidates (sublane-friendly multiples of 8). The
#: intermediate sizes (40, 56, 80, 160, 192) exist for the reduced-
#: precision working sets: a bf16/int8 tile often fits at e.g. 40 where
#: 48 busts the budget and 32 wastes halo — the fp32 plans are unchanged
#: by the finer grid (tests/test_precision.py pins the paper-volume fp32
#: bytes).
TILE_CANDIDATES = (8, 16, 24, 32, 40, 48, 56, 64, 80, 96, 128, 160, 192, 256)


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


#: per-role HBM/VMEM byte widths of one plan: (activation/compute,
#: weights, input volume, inter-segment staging). ``None`` anywhere a
#: legacy uniform ``dtype_bytes`` is meant.
Widths = tuple[int, int, int, int]


def plan_widths(
    precision: Optional[str],
    dtype_bytes: int = 4,
    int8_staging: Optional[bool] = None,
) -> Widths:
    """The (act, weight, input, staging) byte widths a plan prices.

    ``precision=None`` reproduces the legacy uniform-``dtype_bytes``
    model exactly (what fp32 also does at dtype_bytes=4). int8w stages
    int8 only when activation bounds exist (``int8_staging`` — BatchNorm
    statistics or a calibration pass, kernels/quantize.py); without them
    staging stays at the bf16 compute width.
    """
    if precision is None:
        return (dtype_bytes,) * 4
    act = quantize.act_bytes(precision)
    stg = quantize.staging_bytes(precision)
    if precision == "int8w" and int8_staging is False:
        stg = act
    return (act, quantize.weight_bytes(precision), quantize.input_bytes(precision), stg)


@dataclasses.dataclass(frozen=True)
class Segment:
    """A consecutive run of hidden layers executed depth-first per tile."""

    start: int  # index of the first layer in cfg.dilations
    dilations: tuple[int, ...]
    cin: int  # input channels (in_channels for the first segment)
    channels: int  # hidden width C
    tile: tuple[int, int, int]
    fuse_head: bool = False  # apply the 1x1x1 head after the last layer
    num_classes: int = 0

    @property
    def halo(self) -> int:
        return sum(self.dilations)

    @property
    def cout(self) -> int:
        return self.num_classes if self.fuse_head else self.channels

    def buffer_sizes(self) -> list[tuple[int, int, int]]:
        """Per-layer valid-region sizes: S_0 = tile + 2*halo shrinking by
        2*d per layer down to S_k = tile exactly."""
        sizes = [tuple(t + 2 * self.halo for t in self.tile)]
        for d in self.dilations:
            sizes.append(tuple(s - 2 * d for s in sizes[-1]))
        assert sizes[-1] == self.tile, (sizes, self)
        return sizes


def _segment_vmem_bytes(
    seg: Segment, dtype_bytes: int = 4, widths: Optional[Widths] = None
) -> int:
    """VMEM working set of one grid step: the statically allocated scratch
    (DMA'd input buffer + ping/pong activation buffers + logits staging
    when the head is fused + weights) **plus** the transient f32
    accumulator of the widest layer — scratch lives for the whole kernel,
    and the tap loop's ``acc`` is live alongside it, so omitting it would
    admit plans that exceed real VMEM (the tap reads themselves stream
    from the resident buffers and need no second copy). With per-role
    ``widths`` the DMA'd buffer is priced at the input/staging width, the
    compute buffers at the activation width, and int8-staging segments
    additionally hold the quantized output tile they DMA out.
    """
    act, wt, inp, stg = widths or (dtype_bytes,) * 4
    ib = inp if seg.start == 0 else stg
    sizes = seg.buffer_sizes()
    buf_in = math.prod(sizes[0]) * seg.cin * ib
    ping = max(math.prod(s) for s in sizes[1::2]) * seg.channels * act
    pong = (
        max(math.prod(s) for s in sizes[2::2]) * seg.channels * act
        if len(sizes) > 2
        else 0
    )
    wgt = 27 * seg.cin * seg.channels * wt
    wgt += 27 * seg.channels**2 * wt * (len(seg.dilations) - 1)
    logits = (
        math.prod(seg.tile) * seg.num_classes * act if seg.fuse_head else 0
    )
    qout = (
        math.prod(seg.tile) * seg.channels * stg
        if (not seg.fuse_head and stg < act)
        else 0
    )
    acc = max(math.prod(s) for s in sizes[1:]) * seg.channels * 4  # f32
    if seg.fuse_head:
        acc = max(acc, math.prod(seg.tile) * seg.num_classes * 4)
    return buf_in + ping + pong + wgt + logits + qout + acc


def _segment_hbm_bytes(
    seg: Segment,
    padded: tuple[int, int, int],
    dtype_bytes: int,
    widths: Optional[Widths] = None,
    batch: int = 1,
) -> int:
    """Modeled HBM bytes of one segment: haloed tile reads, per-spatial-
    tile weight streams, and the central-region write. The ONE formula
    shared by ``MegakernelPlan.hbm_bytes`` (what telemetry/benchmarks
    report) and the planner's DP objective — so the plan the DP picks is
    the minimum of the model it reports. ``widths`` prices each tensor
    role at its policy byte width: window reads at the input width for the
    first segment and the staging width after, weight streams at the
    weight width, the write at the staging width (activation width for the
    fused-head logits).

    ``batch`` scales only the data terms (every batch element's windows
    are read and its central region written), NOT the weight stream: the
    launch grid iterates batch innermost, so each segment's weight blocks
    stay resident across the whole batch loop and are re-fetched only
    when the spatial tile advances — one weight stream per launch,
    amortized over all N members."""
    act, wt, inp, stg = widths or (dtype_bytes,) * 4
    ib = inp if seg.start == 0 else stg
    ob = act if seg.fuse_head else stg
    ntiles = math.prod(pp // t for pp, t in zip(padded, seg.tile))
    window = math.prod(t + 2 * seg.halo for t in seg.tile)
    wgt = 27 * seg.cin * seg.channels * wt
    wgt += 27 * seg.channels**2 * wt * (len(seg.dilations) - 1)
    if seg.fuse_head:
        wgt += seg.channels * seg.num_classes * wt
    data = ntiles * window * seg.cin * ib
    data += math.prod(padded) * seg.cout * ob
    return batch * data + ntiles * wgt


@dataclasses.dataclass(frozen=True)
class MegakernelPlan:
    """Static execution plan: segments + geometry for one (cfg, volume).

    ``widths`` carries the precision policy's per-role byte widths the
    plan was optimized for (None = the legacy uniform-``dtype_bytes``
    fp32 model); ``hbm_bytes`` prices with them, so the planner's DP
    objective and the reported model stay one formula per precision."""

    segments: tuple[Segment, ...]
    vol: tuple[int, int, int]  # true volume dims (pre-padding)
    vmem_budget: int
    widths: Optional[Widths] = None

    def padded(self, seg: Segment) -> tuple[int, int, int]:
        """Tile-multiple dims of the region this segment computes."""
        return tuple(_ceil_to(v, t) for v, t in zip(self.vol, seg.tile))

    def out_dims(self, i: int) -> tuple[int, int, int]:
        """Spatial dims of segment i's HBM output array. Sized for the
        *next* segment's haloed DMA windows: max of both segments' padded
        extents plus the next halo per side (the halo border is never
        written — its garbage is masked out after the next DMA)."""
        cur = self.padded(self.segments[i])
        if i + 1 == len(self.segments):
            return cur
        nxt = self.segments[i + 1]
        pad = self.padded(nxt)
        return tuple(max(c, p) + 2 * nxt.halo for c, p in zip(cur, pad))

    def hbm_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        """Modeled HBM traffic of one batched forward: the input pad
        round-trip, then per segment the haloed tile reads, the weight
        streams, and the central-region writes (staging halo borders are
        allocated but never written, so they cost nothing). Data terms
        scale with ``batch``; the per-segment weight stream is charged
        once per launch — batch iterates innermost in the kernel grid, so
        weights DMA'd for a spatial tile serve every batch element
        (subadditive: ``hbm_bytes(N) < N * hbm_bytes(1)`` whenever the
        weight term is nonzero; ``batch=1`` is byte-identical to the
        pre-batching model). A plan optimized for a precision policy
        prices with its own per-role widths (``dtype_bytes`` is the
        legacy uniform knob and is ignored when ``widths`` is set)."""
        widths = self.widths or (dtype_bytes,) * 4
        inp = widths[2]
        total = 0
        first = self.segments[0]
        p0 = self.padded(first)
        # host-side zero-pad of the input volume (read + padded write, at
        # the policy's input storage width) — per batch element
        total += batch * math.prod(self.vol) * first.cin * inp
        total += batch * math.prod(t + 2 * first.halo for t in p0) * first.cin * inp
        for seg in self.segments:
            total += _segment_hbm_bytes(
                seg, self.padded(seg), dtype_bytes, widths, batch=batch
            )
        return total


def plan(
    dilations: Sequence[int],
    in_channels: int,
    channels: int,
    num_classes: int,
    vol: tuple[int, int, int],
    *,
    vmem_budget: int = VMEM_BUDGET,
    dtype_bytes: int = 4,
    precision: Optional[str] = None,
    int8_staging: Optional[bool] = None,
    batch: int = 1,
) -> MegakernelPlan:
    """Choose segment boundaries and per-axis tiles by DP over modeled
    HBM traffic, subject to each segment's working set fitting VMEM.

    ``precision`` prices every tensor role at its policy width
    (``plan_widths``), which is where the second-order traffic win comes
    from: a bf16/int8 working set is 2-4x smaller, so the DP affords
    larger tiles and fewer halo re-fetches on top of the per-byte cut.
    ``precision=None`` keeps the legacy uniform-``dtype_bytes`` model
    (byte-identical fp32 plans).

    ``batch`` co-optimizes the tile shape against the batch size: the DP
    objective scales the data terms by N while charging the weight stream
    once per launch, so at larger batches the planner leans toward the
    tile that minimizes halo re-reads rather than weight re-streams. The
    VMEM constraint is unchanged — the grid iterates one (batch element,
    tile) at a time, so the working set never grows with batch and a plan
    feasible at batch 1 stays feasible at any batch.

    Raises with an actionable message when even a single layer at the
    smallest tile exceeds the budget (channel width is the only lever
    left at that point). Memoized: the serving path replans the same
    (model, volume, precision) on every request ("auto" resolution,
    traffic telemetry, the forward itself).
    """
    return _plan_cached(
        tuple(int(d) for d in dilations),
        int(in_channels),
        int(channels),
        int(num_classes),
        tuple(int(v) for v in vol),
        int(vmem_budget),
        plan_widths(precision, dtype_bytes, int8_staging),
        int(batch),
    )


@functools.lru_cache(maxsize=256)
def _plan_cached(
    dils: tuple[int, ...],
    in_channels: int,
    channels: int,
    num_classes: int,
    vol: tuple[int, int, int],
    vmem_budget: int,
    widths: Widths,
    batch: int = 1,
) -> MegakernelPlan:
    n = len(dils)
    act, wt, inp, stg = widths
    # Oversize tiles only waste padding: cap candidates near the volume.
    cands = [
        np.array(
            [t for t in TILE_CANDIDATES if t <= _ceil_to(v, 8)] or [8],
            dtype=np.float64,
        )
        for v in vol
    ]

    def seg_for(i: int, j: int, tile) -> Segment:
        return Segment(
            start=i,
            dilations=dils[i:j],
            cin=in_channels if i == 0 else channels,
            channels=channels,
            tile=tile,
            fuse_head=(j == n),
            num_classes=num_classes,
        )

    # Vectorized DP: for each (i, j) the per-axis buffer sizes are affine
    # in the tile candidate, so every per-tile quantity (VMEM working set,
    # modeled segment traffic) is evaluated over the whole candidate grid
    # with numpy broadcasting — the plan is exact, only ~100x faster than
    # constructing a Segment per (i, j, tile). All intermediates are
    # integer-valued float64 (< 2**53), so comparisons are exact; the
    # chosen plan's bytes are re-derived in int arithmetic by hbm_bytes.
    INF = float("inf")
    best: list[float] = [INF] * (n + 1)
    best[n] = 0.0
    choice: list[tuple[int, tuple[int, int, int]] | None] = [None] * (n + 1)
    grids = np.meshgrid(*cands, indexing="ij")  # (A,B,C) per axis
    vol_np = [float(v) for v in vol]
    for i in range(n - 1, -1, -1):
        cin = in_channels if i == 0 else channels
        ib = inp if i == 0 else stg
        for j in range(i + 1, n + 1):
            d_ij = dils[i:j]
            h = sum(d_ij)
            k = j - i
            fuse_head = j == n
            cout = num_classes if fuse_head else channels
            ob = act if fuse_head else stg
            # per-layer valid-region products P_l over the tile grid
            cum = 0
            prods = []
            for l in range(k + 1):
                s = 2 * (h - cum)
                prods.append(
                    (grids[0] + s) * (grids[1] + s) * (grids[2] + s)
                )
                if l < k:
                    cum += d_ij[l]
            wgt = 27 * cin * channels * wt + 27 * channels**2 * wt * (k - 1)
            wgt_h = wgt + (channels * num_classes * wt if fuse_head else 0)
            buf_in = prods[0] * (cin * ib)
            ping = np.maximum.reduce(prods[1::2]) * (channels * act)
            pong = (
                np.maximum.reduce(prods[2::2]) * (channels * act)
                if k >= 2
                else 0.0
            )
            acc = np.maximum.reduce(prods[1:]) * (channels * 4)
            tilep = prods[k]  # sizes[-1] == tile exactly
            logits = tilep * (num_classes * act) if fuse_head else 0.0
            if fuse_head:
                acc = np.maximum(acc, tilep * (num_classes * 4))
            qout = (
                tilep * (channels * stg)
                if (not fuse_head and stg < act)
                else 0.0
            )
            vmem = buf_in + ping + pong + wgt + logits + qout + acc
            padded = [np.ceil(v / g) * g for v, g in zip(vol_np, grids)]
            ntiles = (
                (padded[0] / grids[0]) * (padded[1] / grids[1]) * (padded[2] / grids[2])
            )
            # data terms × batch, weight stream once per launch — the
            # same split hbm_bytes reports (batch innermost in the grid)
            cost = batch * ntiles * (prods[0] * (cin * ib)) + ntiles * wgt_h
            cost += batch * padded[0] * padded[1] * padded[2] * (cout * ob)
            if i == 0:
                cost += batch * math.prod(vol) * (cin * inp)
                cost += batch * (
                    (padded[0] + 2 * h) * (padded[1] + 2 * h) * (padded[2] + 2 * h)
                ) * (cin * inp)
            cost = np.where(vmem <= vmem_budget, cost, INF)
            flat = int(np.argmin(cost))
            c = float(cost.reshape(-1)[flat]) + best[j]
            if c < best[i]:
                best[i] = c
                idx = np.unravel_index(flat, cost.shape)
                choice[i] = (
                    j,
                    tuple(int(cands[ax][idx[ax]]) for ax in range(3)),
                )
    if best[0] == INF:
        one = seg_for(0, 1, (8, 8, 8))
        need = _segment_vmem_bytes(one, widths=widths)
        raise ValueError(
            f"megakernel plan infeasible: one layer at tile (8,8,8) needs "
            f"{need / 2**20:.1f} MiB of VMEM, "
            f"over the {vmem_budget / 2**20:.0f} MiB budget — reduce channel "
            f"width ({channels}) or raise vmem_budget"
        )
    segments = []
    i = 0
    while i < n:
        j, tile = choice[i]  # type: ignore[misc]
        segments.append(seg_for(i, j, tile))
        i = j
    return MegakernelPlan(
        segments=tuple(segments),
        vol=vol,
        vmem_budget=vmem_budget,
        widths=None if widths == (4, 4, 4, 4) else widths,
    )


def plan_for_config(
    cfg,
    vol: tuple[int, int, int],
    *,
    vmem_budget: int = VMEM_BUDGET,
    dtype_bytes: int = 4,
    precision: Optional[str] = None,
    int8_staging: Optional[bool] = None,
    batch: int = 1,
) -> MegakernelPlan:
    """``plan`` from a MeshNetConfig-shaped object. With a ``precision``,
    int8 staging defaults to whether the config has BatchNorm statistics
    to bound the staging scales with (kernels/quantize.py)."""
    if precision is not None and int8_staging is None:
        int8_staging = bool(cfg.use_batchnorm)
    return plan(
        cfg.dilations,
        cfg.in_channels,
        cfg.channels,
        cfg.num_classes,
        vol,
        vmem_budget=vmem_budget,
        dtype_bytes=dtype_bytes,
        precision=precision,
        int8_staging=int8_staging,
        batch=batch,
    )


def _segment_kernel(
    *refs,
    seg: Segment,
    vol: tuple[int, int, int],
    out_halo: int,
    use_affine: bool,
    has_z_bounds: bool = False,
    deq_in: bool = False,
    quant_out: bool = False,
):
    """Kernel body: DMA the haloed input window, run ``seg``'s layers
    back-to-back in VMEM (masking out-of-volume positions after every
    layer so per-layer 'same' zero padding is reproduced exactly), then
    DMA the finished tile (or fused-head logits) back out.

    ``has_z_bounds`` adds a dynamic (2,)-int32 SMEM input narrowing the
    valid Z interval below ``[0, vol[0])`` — the sharded executor
    (core/spatial_shard.py) uses it to place the *true* volume boundary
    inside a slab+halo window, so pod-edge slabs re-zero their
    out-of-volume halo per layer exactly like full-volume 'same' padding.

    int8w staging (kernels/quantize.py): ``deq_in`` adds a per-channel
    fp32 vector that dequantizes the DMA'd int8 staging window on the fly
    in VMEM (applied per tap slice of the segment's first layer — the
    only layer that reads the buffer); ``quant_out`` adds the symmetric
    per-channel scale the segment's last-layer output is quantized with
    before the output DMA, so what crosses HBM between segments is int8.
    Per-output-channel int8 *weights* need neither: their dequant scale
    is already folded into the affine epilogue (quantize.fold_epilogue).
    """
    k = len(seg.dilations)
    per_layer = 4 if use_affine else 2
    n_head = 2 if seg.fuse_head else 0
    n_extra = int(deq_in) + int(quant_out) + int(has_z_bounds)
    n_in = 1 + k * per_layer + n_head + n_extra
    x_ref = refs[0]
    layer_refs = [
        refs[1 + i * per_layer : 1 + (i + 1) * per_layer] for i in range(k)
    ]
    head_refs = (
        refs[1 + k * per_layer : 1 + k * per_layer + n_head]
        if seg.fuse_head
        else None
    )
    pos = 1 + k * per_layer + n_head
    deq_ref = refs[pos] if deq_in else None
    pos += int(deq_in)
    qscale_ref = refs[pos] if quant_out else None
    pos += int(quant_out)
    zb_ref = refs[pos] if has_z_bounds else None
    out_ref = refs[n_in]
    scratch = refs[n_in + 1 :]
    buf_in, ping = scratch[0], scratch[1]
    idx = 2
    pong = scratch[idx] if k >= 2 else None
    idx += 1 if k >= 2 else 0
    logits_buf = scratch[idx] if seg.fuse_head else None
    idx += 1 if seg.fuse_head else 0
    qout_buf = scratch[idx] if quant_out else None
    idx += 1 if quant_out else 0
    sem = scratch[idx]

    # batch is the INNERMOST grid axis: the weight/bias/affine blocks use
    # constant index maps, so between consecutive batch steps no input
    # block index changes and the segment's weights stay VMEM-resident —
    # one weight stream per spatial tile, amortized over the whole batch
    # (the split _segment_hbm_bytes prices).
    zi, yi, xi, bi = (pl.program_id(i) for i in range(4))
    ids = (zi, yi, xi)
    tile = seg.tile
    h = seg.halo
    sizes = seg.buffer_sizes()

    dma = pltpu.make_async_copy(
        x_ref.at[
            bi,
            pl.ds(zi * tile[0], sizes[0][0]),
            pl.ds(yi * tile[1], sizes[0][1]),
            pl.ds(xi * tile[2], sizes[0][2]),
            :,
        ],
        buf_in,
        sem.at[0],
    )
    dma.start()
    dma.wait()

    def mask(v, size, r):
        """Zero positions whose global coord (tile origin - r + local) lies
        outside the true volume — per-layer 'same' padding, and the
        neutraliser for the staging arrays' unwritten halo borders. With
        ``z_bounds`` the Z-valid interval is the intersection of
        ``[0, vol[0])`` and the dynamic ``[zb[0], zb[1])``."""
        ok = None
        for ax in range(3):
            i = jax.lax.broadcasted_iota(jnp.int32, size + (1,), ax)
            lo = r - ids[ax] * tile[ax]
            m = (i >= lo) & (i < vol[ax] + lo)
            if ax == 0 and zb_ref is not None:
                m = m & (i >= zb_ref[0] + lo) & (i < zb_ref[1] + lo)
            ok = m if ok is None else (ok & m)
        return jnp.where(ok, v, jnp.zeros((), v.dtype))

    buf_in[...] = mask(buf_in[...], sizes[0], h)

    prev, prev_size = buf_in, sizes[0]
    cum = 0
    for li, d in enumerate(seg.dilations):
        w_ref, b_ref = layer_refs[li][0], layer_refs[li][1]
        size = sizes[li + 1]
        cum += d
        w = w_ref[...]
        acc = jnp.zeros(size + (w.shape[-1],), jnp.float32)
        for tz in (-1, 0, 1):
            for ty in (-1, 0, 1):
                for tx in (-1, 0, 1):
                    sl = prev[
                        d + tz * d : d + tz * d + size[0],
                        d + ty * d : d + ty * d + size[1],
                        d + tx * d : d + tx * d + size[2],
                        :,
                    ].astype(jnp.float32)
                    if li == 0 and deq_ref is not None:
                        # dequant the int8 staging window on the fly: the
                        # per-channel scale of the previous segment's
                        # quantized output (only layer 0 reads buf_in).
                        sl = sl * deq_ref[...]
                    acc = acc + jnp.einsum(
                        "zyxi,io->zyxo",
                        sl,
                        w[tz + 1, ty + 1, tx + 1].astype(jnp.float32),
                        preferred_element_type=jnp.float32,
                    )
        out = acc + b_ref[...].astype(jnp.float32)
        if use_affine:
            s_ref, o_ref = layer_refs[li][2], layer_refs[li][3]
            out = out * s_ref[...].astype(jnp.float32) + o_ref[...].astype(
                jnp.float32
            )
        out = jnp.maximum(out, 0.0)
        if li + 1 < k:
            out = mask(out, size, h - cum)
            dst = ping if li % 2 == 0 else pong
            dst[0 : size[0], 0 : size[1], 0 : size[2], :] = out.astype(dst.dtype)
            prev, prev_size = dst, size
        elif quant_out:
            # last layer of an int8-staging segment: quantize the (exactly
            # tile-sized) output in VMEM so int8 is what crosses HBM.
            qout_buf[...] = jnp.clip(
                jnp.round(out / qscale_ref[...]), -127, 127
            ).astype(jnp.int8)
            prev, prev_size = qout_buf, size
        else:
            dst = ping if li % 2 == 0 else pong
            dst[0 : size[0], 0 : size[1], 0 : size[2], :] = out.astype(dst.dtype)
            prev, prev_size = dst, size

    if seg.fuse_head:
        hw_ref, hb_ref = head_refs
        x = prev[0 : tile[0], 0 : tile[1], 0 : tile[2], :]
        logits = (
            jnp.einsum(
                "zyxi,io->zyxo",
                x.astype(jnp.float32),
                hw_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            + hb_ref[...].astype(jnp.float32)
        )
        logits_buf[...] = logits.astype(logits_buf.dtype)
        src = logits_buf
    else:
        src = prev.at[
            pl.ds(0, tile[0]), pl.ds(0, tile[1]), pl.ds(0, tile[2]), :
        ]
    odma = pltpu.make_async_copy(
        src,
        out_ref.at[
            bi,
            pl.ds(out_halo + zi * tile[0], tile[0]),
            pl.ds(out_halo + yi * tile[1], tile[1]),
            pl.ds(out_halo + xi * tile[2], tile[2]),
            :,
        ],
        sem.at[1],
    )
    odma.start()
    odma.wait()


def _run_segment(
    act: jax.Array,
    seg: Segment,
    pln: MegakernelPlan,
    i: int,
    params: dict,
    use_affine: bool,
    fold_affine,
    interpret: bool,
    z_bounds: jax.Array | None = None,
    layer_epilogue=None,
    compute_dtype=None,
    staging_scales: Sequence[jax.Array] | None = None,
) -> jax.Array:
    """Run one plan segment. The legacy fp32 path passes ``fold_affine``;
    the precision paths pass ``layer_epilogue(layer, global_index) ->
    (bias, scale, offset)`` (quantize.fold_epilogue with the input scale
    folded into layer 0) plus ``compute_dtype`` (the ping/pong and logits
    width) and, for int8 staging, the per-layer ``staging_scales`` that
    pick this segment's boundary dequant/quant vectors."""
    B = act.shape[0]
    padded = pln.padded(seg)
    out_dims = pln.out_dims(i)
    out_halo = (
        pln.segments[i + 1].halo if i + 1 < len(pln.segments) else 0
    )
    cdt = act.dtype if compute_dtype is None else compute_dtype
    int8_stage = staging_scales is not None
    deq_in = int8_stage and seg.start > 0
    quant_out = int8_stage and not seg.fuse_head

    args = [act]
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)]

    def add_full(a):
        args.append(a)
        in_specs.append(pl.BlockSpec(a.shape, lambda *_, n=a.ndim: (0,) * n))

    for li in range(len(seg.dilations)):
        layer = params["layers"][seg.start + li]
        add_full(layer["w"])
        if layer_epilogue is not None:
            bias, scale, offset = layer_epilogue(layer, seg.start + li)
            add_full(bias)
            add_full(scale)
            add_full(offset)
        else:
            add_full(layer["b"])
            if use_affine:
                scale, offset = fold_affine(layer)
                add_full(scale)
                add_full(offset)
    if seg.fuse_head:
        add_full(params["head"]["w"][0, 0, 0])  # (C, num_classes)
        add_full(params["head"]["b"])
    if deq_in:
        add_full(staging_scales[seg.start - 1].astype(jnp.float32))
    if quant_out:
        last = seg.start + len(seg.dilations) - 1
        add_full(staging_scales[last].astype(jnp.float32))
    if z_bounds is not None:
        args.append(z_bounds)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))

    sizes = seg.buffer_sizes()
    scratch = [
        pltpu.VMEM(sizes[0] + (seg.cin,), act.dtype),
        pltpu.VMEM(sizes[1] + (seg.channels,), cdt),
    ]
    if len(seg.dilations) >= 2:
        scratch.append(pltpu.VMEM(sizes[2] + (seg.channels,), cdt))
    if seg.fuse_head:
        scratch.append(pltpu.VMEM(seg.tile + (seg.num_classes,), cdt))
    if quant_out:
        scratch.append(pltpu.VMEM(seg.tile + (seg.channels,), jnp.int8))
    scratch.append(pltpu.SemaphoreType.DMA((2,)))

    kernel = functools.partial(
        _segment_kernel,
        seg=seg,
        vol=pln.vol,
        out_halo=out_halo,
        use_affine=use_affine or layer_epilogue is not None,
        has_z_bounds=z_bounds is not None,
        deq_in=deq_in,
        quant_out=quant_out,
    )
    out_dtype = jnp.int8 if quant_out else cdt
    grid = tuple(p // t for p, t in zip(padded, seg.tile)) + (B,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((B,) + out_dims + (seg.cout,), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)


def meshnet_apply(
    params,
    x: jax.Array,
    cfg,
    *,
    pln: MegakernelPlan | None = None,
    vmem_budget: int = VMEM_BUDGET,
    interpret: bool = True,
    fold_affine=None,
    z_bounds: jax.Array | None = None,
    precision: str = "fp32",
    staging_scales: Sequence[jax.Array] | None = None,
) -> jax.Array:
    """Depth-first MeshNet forward (== meshnet.apply, eval mode).

    ``fold_affine`` maps a layer dict to the folded inference-BN
    (scale, offset); ops.meshnet_apply_megakernel supplies it (kept
    injectable so this module does not import ops).

    ``z_bounds`` (optional (2,)-int32) narrows the valid Z interval below
    ``[0, D)``: positions outside it are re-zeroed per layer exactly like
    positions outside the volume. The sharded executor passes the true
    volume's extent inside a slab+halo window (core/spatial_shard.py).

    ``precision`` selects the storage policy (kernels/quantize.py):
    "fp32" is the legacy bit-exact path below; "bf16" runs the same
    schedule with bf16 buffers/weights and fp32 accumulate (rounding only
    at HBM crossings); "int8w" additionally streams per-output-channel
    int8 weights (dequant folded into the affine epilogue), the int8-
    quantized conformed input, and — when BatchNorm statistics or the
    given ``staging_scales`` (quantize.calibrate) bound the activations —
    int8 inter-segment staging, dequantized on the fly in VMEM. The DP
    plan is re-optimized for the policy's byte widths, so smaller working
    sets buy larger tiles and fewer halo re-fetches on top of the per-
    byte cut (EXPERIMENTS.md H11).
    """
    if x.ndim == 4:
        x = x[..., None]
    B, D, H, W, Cin = x.shape
    vol = (D, H, W)
    # branch-specific setup; the pad-and-run-segments tail below is shared
    if precision == "fp32":
        if pln is None:
            pln = plan_for_config(
                cfg, vol, vmem_budget=vmem_budget, dtype_bytes=x.dtype.itemsize
            )
        use_affine = bool(cfg.use_batchnorm)
        if use_affine and fold_affine is None:
            raise ValueError("fold_affine is required when cfg.use_batchnorm")
        layer_epilogue = compute_dtype = staging_scales = None
    else:
        quantize.validate(precision)
        params = quantize.prepare_params(params, cfg, precision)
        compute_dtype = quantize.act_dtype(precision)
        if precision == "int8w":
            if x.dtype != jnp.int8:
                x = quantize.quantize_input(x)
            if staging_scales is None:
                staging_scales = quantize.staging_scales_from_bn(params, cfg)
        else:
            x = x.astype(compute_dtype)
            staging_scales = None
        if pln is None:
            pln = plan_for_config(
                cfg,
                vol,
                vmem_budget=vmem_budget,
                precision=precision,
                int8_staging=staging_scales is not None,
            )
        use_affine = True
        fold_affine = None

        def layer_epilogue(layer, gi, _prec=precision):
            bias, scale, offset = quantize.fold_epilogue(
                layer, cfg.use_batchnorm
            )
            if gi == 0 and _prec == "int8w":
                # the conformed volume's fixed int8 dequant scale rides
                # the first layer's epilogue (conv is linear in its
                # input scale)
                scale = scale * quantize.INPUT_SCALE
            return bias, scale, offset

    first = pln.segments[0]
    p0 = pln.padded(first)
    h0 = first.halo
    act = jnp.pad(
        x,
        [(0, 0)]
        + [(h0, h0 + p - v) for p, v in zip(p0, vol)]
        + [(0, 0)],
    )
    for i, seg in enumerate(pln.segments):
        act = _run_segment(
            act, seg, pln, i, params, use_affine, fold_affine, interpret,
            z_bounds=z_bounds,
            layer_epilogue=layer_epilogue,
            compute_dtype=compute_dtype,
            staging_scales=staging_scales,
        )
    return act[:, :D, :H, :W, :]
