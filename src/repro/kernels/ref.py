"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dilated_conv3d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    dilation: int = 1,
    scale: jax.Array | None = None,
    offset: jax.Array | None = None,
    fuse_affine: bool = False,
) -> jax.Array:
    """Reference 'same'-padded 3-D dilated conv (+ optional affine+ReLU)."""
    k = w.shape[0]
    pad = dilation * (k - 1) // 2
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1, 1),
        padding=[(pad, pad)] * 3,
        rhs_dilation=(dilation,) * 3,
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"),
    ) + b.astype(jnp.float32)
    if fuse_affine:
        s = jnp.ones((w.shape[-1],), jnp.float32) if scale is None else scale.astype(jnp.float32)
        o = jnp.zeros((w.shape[-1],), jnp.float32) if offset is None else offset.astype(jnp.float32)
        out = jnp.maximum(out * s + o, 0.0)
    return out.astype(x.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, pos) -> jax.Array:
    """Reference single-token GQA decode attention over a KV cache.

    q: (B, 1, H, hd); k/v: (B, S, KV, hd); attends to slots [0, pos].
    """
    import numpy as np

    B, _, H, hd = q.shape
    KV = k.shape[2]
    kk = jnp.repeat(k, H // KV, axis=2)
    vv = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) / np.sqrt(hd)
    valid = jnp.arange(k.shape[1]) <= pos
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32)).astype(q.dtype)


def dice_counts(pred: jax.Array, truth: jax.Array, num_classes: int) -> jax.Array:
    """Per-class (intersection, |pred_c|, |truth_c|) counts, shape (C, 3)."""
    rows = []
    for c in range(num_classes):
        x = pred == c
        y = truth == c
        rows.append(
            jnp.stack(
                [
                    jnp.sum(x & y).astype(jnp.int32),
                    jnp.sum(x).astype(jnp.int32),
                    jnp.sum(y).astype(jnp.int32),
                ]
            )
        )
    return jnp.stack(rows)
