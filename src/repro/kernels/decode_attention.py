"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

The serving hot-spot (decode_32k / long_500k shapes): one query token
attends to a (B, S, KV, hd) cache. HBM traffic is the roofline term
(§Roofline: decode is memory-bound), so the kernel streams the cache in
S-blocks exactly once, keeping the online-softmax state (acc, max, denom)
resident in VMEM across the sequential grid — no (S,) score vector ever
round-trips to HBM, and the GQA head-group replication happens in-register
instead of materialising repeated K/V (which `jnp.repeat` would write to
HBM: H/KV x cache-size of avoidable traffic).

Grid: (B, S/block_s); TPU grids execute sequentially over the minor axis,
so the accumulator outputs (constant index_map) implement the cross-block
reduction — the same pattern as kernels/dice.py.

VMEM per step: block_s x KV x hd x 2 (K+V) + q (H x hd) + state.
At block_s=512, KV=8, hd=128 bf16: 1.05 MB — far under the ~16 MB budget;
block_s can grow to amortise grid overhead on long caches.

Masking: positions > pos (ring-buffer semantics are handled by the caller's
`valid_len`) are masked with -1e30 before the running max update.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_attn_kernel(pos_ref, q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                        *, block_s: int, groups: int, scale: float):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (H, hd)
    k = k_ref[0].astype(jnp.float32)  # (block_s, KV, hd)
    v = v_ref[0].astype(jnp.float32)
    kv = k.shape[1]
    hd = k.shape[2]
    qg = q.reshape(kv, groups, hd)  # GQA: H = KV * groups

    # scores[s, kv, g] = <q[kv, g], k[s, kv]>
    s = jnp.einsum("kgd,skd->skg", qg, k) * scale  # (block_s, KV, G)
    kpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (block_s, 1, 1), 0)
    valid = kpos <= pos_ref[0]
    s = jnp.where(valid, s, -1e30)

    m_prev = m_ref[0]  # (KV, G)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=0))
    p = jnp.exp(s - m_new[None])  # (block_s, KV, G)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0] = l_ref[0] * corr + jnp.sum(p, axis=0)
    acc_ref[0] = acc_ref[0] * corr[..., None] + jnp.einsum("skg,skd->kgd", p, v)
    m_ref[0] = m_new


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, 1, H, hd); k/v_cache: (B, S, KV, hd); pos: scalar int32 —
    attends to cache slots [0, pos]. Returns (B, 1, H, hd)."""
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    assert H % KV == 0, (H, KV)
    groups = H // KV
    pad = (-S) % block_s
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nS = k_cache.shape[1] // block_s
    scale = 1.0 / (hd ** 0.5)
    pos_arr = jnp.full((1,), pos, jnp.int32)

    acc, m, l = pl.pallas_call(
        functools.partial(
            _decode_attn_kernel, block_s=block_s, groups=groups, scale=scale
        ),
        grid=(B, nS),
        in_specs=[
            pl.BlockSpec((1,), lambda b, s: (0,)),
            pl.BlockSpec((1, 1, H, hd), lambda b, s: (b, 0, 0, 0)),
            pl.BlockSpec((1, block_s, KV, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, block_s, KV, hd), lambda b, s: (b, s, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, KV, groups, hd), lambda b, s: (b, 0, 0, 0)),
            pl.BlockSpec((1, KV, groups), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, KV, groups), lambda b, s: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, groups, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, groups), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, groups), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q, k_cache, v_cache)

    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, 1, H, hd).astype(q.dtype)
