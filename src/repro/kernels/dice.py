"""Pallas kernel: fused per-class Dice count accumulator.

Brainchop computes Dice from binary masks per label (eq. 2). Materialising
C one-hot masks of a 256^3 volume costs C x 67 MB of HBM traffic; this
kernel streams the two int label volumes once, accumulating per-class
(intersection, |pred|, |truth|) counts across sequential grid steps into a
single VMEM-resident (C, 3) block (grid-carried accumulation — TPU grids
execute sequentially, the canonical Pallas reduction pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dice_kernel(pred_ref, truth_ref, out_ref, *, num_classes: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pred = pred_ref[...]
    truth = truth_ref[...]
    # classes on a new minor axis -> three (C,) count vectors per block
    cls = jax.lax.broadcasted_iota(jnp.int32, (1, num_classes), 1)
    p1 = (pred.reshape(-1, 1) == cls).astype(jnp.int32)  # (N, C)
    t1 = (truth.reshape(-1, 1) == cls).astype(jnp.int32)
    inter = jnp.sum(p1 * t1, axis=0)
    psum = jnp.sum(p1, axis=0)
    tsum = jnp.sum(t1, axis=0)
    out_ref[...] += jnp.stack([inter, psum, tsum], axis=1)  # (C, 3)


@functools.partial(jax.jit, static_argnames=("num_classes", "block", "interpret"))
def dice_counts(
    pred: jax.Array,
    truth: jax.Array,
    num_classes: int,
    *,
    block: int = 65536,
    interpret: bool = True,
) -> jax.Array:
    """(C, 3) int32 counts [intersection, |pred_c|, |truth_c|] per class."""
    pred = pred.reshape(-1).astype(jnp.int32)
    truth = truth.reshape(-1).astype(jnp.int32)
    n = pred.shape[0]
    pad = (-n) % block
    if pad:
        # Pad with class -1 (matches no class) on both sides.
        pred = jnp.concatenate([pred, jnp.full((pad,), -1, jnp.int32)])
        truth = jnp.concatenate([truth, jnp.full((pad,), -2, jnp.int32)])
    grid = (pred.shape[0] // block,)
    return pl.pallas_call(
        functools.partial(_dice_kernel, num_classes=num_classes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_classes, 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_classes, 3), jnp.int32),
        interpret=interpret,
    )(pred, truth)


def dice_from_counts(counts: jax.Array, eps: float = 1e-7) -> jax.Array:
    """Macro Dice from (C, 3) counts; empty classes score 1."""
    inter = counts[:, 0].astype(jnp.float32)
    denom = (counts[:, 1] + counts[:, 2]).astype(jnp.float32)
    per_class = jnp.where(denom == 0, 1.0, 2.0 * inter / (denom + eps))
    return jnp.mean(per_class)
