"""Pallas TPU kernel for MeshNet's hot-spot: the 3^3 *dilated* 3-D conv.

Why a custom kernel (hardware adaptation, DESIGN.md §2)
-------------------------------------------------------
Brainchop's WebGL backend runs this conv as fragment-shader passes over 2-D
texture tilings of the volume; the cost model there is texture bandwidth.
On TPU the equivalent wall is HBM->VMEM traffic: a 256^3 x 5ch f32 volume is
335 MB, read 27x by a naive gather-per-tap schedule. This kernel tiles the
volume into VMEM-resident cubes and reads each input voxel exactly once per
neighbourhood (27 disjoint blocks streamed per output block), computing all
27 taps from VMEM.

TPU-native design notes
  * channels-last layout: C rides the lane dimension. MeshNet's C=5 is far
    below the 128-lane MXU contraction, so the einsum per tap is a VPU
    (8x128 vreg) FMA, not an MXU matmul — a C<=8 model is *memory-bound* on
    TPU and the win comes from the blocking, not systolic compute. The
    kernel is still correct (and becomes MXU-bound) for wide variants
    (failsafe 21ch / atlas 18ch) where Cin x Cout taps start to matter.
  * block size: `block` (default 16 = max MeshNet dilation) gives
    27 x block^3 x C x 4 B of VMEM-resident input — 2.2 MB at C=5 f32,
    comfortably under the ~16 MB VMEM budget, with hardware-aligned
    (8, 128) tiles when W*C is padded to the lane multiple by Mosaic.
  * halo handling: BlockSpec tiles are disjoint, so the +-dilation
    neighbourhood is expressed as 27 *offset views of the same padded
    input* (index maps i+dz-1 etc.), the canonical Pallas halo pattern.
  * optional fused affine+ReLU epilogue: folds inference-mode BatchNorm and
    activation into the conv's output block while it is still in VMEM
    (saves one full HBM round-trip per layer — see EXPERIMENTS.md §Perf).

Validated in interpret mode on CPU against kernels/ref.py for every
(shape, dtype, dilation, channels) in the test sweep.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(*refs, dilation: int, block: int, fuse_affine: bool):
    """Kernel body. refs = 27 input views + w + b (+ scale, offset) + out."""
    if fuse_affine:
        *xs, w_ref, b_ref, s_ref, o_ref, out_ref = refs
    else:
        *xs, w_ref, b_ref, out_ref = refs
        s_ref = o_ref = None
    # Assemble the (3b, 3b, 3b, Cin) neighbourhood from 27 (b,b,b,Cin) views.
    # Loads stay in VMEM; concatenate is a register/VMEM reshuffle.
    planes = []
    for zi in range(3):
        rows = []
        for yi in range(3):
            cols = [xs[zi * 9 + yi * 3 + xi][0] for xi in range(3)]
            rows.append(jnp.concatenate(cols, axis=2))
        planes.append(jnp.concatenate(rows, axis=1))
    nb = jnp.concatenate(planes, axis=0)  # (3b, 3b, 3b, Cin)

    w = w_ref[...]  # (3, 3, 3, Cin, Cout)
    acc = jnp.zeros((block, block, block, w.shape[-1]), jnp.float32)
    d = dilation
    b = block
    for tz in (-1, 0, 1):
        for ty in (-1, 0, 1):
            for tx in (-1, 0, 1):
                # Output voxel p reads input p + t*d (correlation, as XLA).
                sl = nb[
                    b + tz * d : 2 * b + tz * d,
                    b + ty * d : 2 * b + ty * d,
                    b + tx * d : 2 * b + tx * d,
                    :,
                ]
                acc = acc + jnp.einsum(
                    "zyxi,io->zyxo",
                    sl.astype(jnp.float32),
                    w[tz + 1, ty + 1, tx + 1].astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
    out = acc + b_ref[...].astype(jnp.float32)
    if fuse_affine:
        out = out * s_ref[...].astype(jnp.float32) + o_ref[...].astype(jnp.float32)
        out = jnp.maximum(out, 0.0)
    out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("dilation", "block", "interpret", "fuse_affine"),
)
def dilated_conv3d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    dilation: int = 1,
    scale: jax.Array | None = None,
    offset: jax.Array | None = None,
    block: int = 16,
    interpret: bool = True,
    fuse_affine: bool = False,
) -> jax.Array:
    """'Same'-padded 3-D dilated conv via Pallas.

    x: (B, D, H, W, Cin); w: (3, 3, 3, Cin, Cout); b: (Cout,).
    If ``fuse_affine``: returns relu(conv(x) * scale + offset) — the folded
    inference BatchNorm epilogue. Requires ``dilation <= block`` and spatial
    dims divisible by ``block`` (the ops wrapper pads as needed).
    """
    if dilation > block:
        raise ValueError(f"dilation {dilation} > block {block}")
    B, D, H, W, Cin = x.shape
    Cout = w.shape[-1]
    assert D % block == H % block == W % block == 0, (x.shape, block)
    # One extra block of zero padding per side supplies the halo.
    xp = jnp.pad(x, [(0, 0)] + [(block, block)] * 3 + [(0, 0)])

    grid = (B, D // block, H // block, W // block)
    blk = (1, block, block, block, Cin)

    def mk_index(dz, dy, dx):
        return lambda bi, zi, yi, xi: (bi, zi + dz, yi + dy, xi + dx, 0)

    in_specs = [
        pl.BlockSpec(blk, mk_index(dz, dy, dx))
        for dz in range(3)
        for dy in range(3)
        for dx in range(3)
    ]
    in_specs.append(pl.BlockSpec(w.shape, lambda *_: (0,) * 5))  # weights
    in_specs.append(pl.BlockSpec(b.shape, lambda *_: (0,)))  # bias
    args = [xp] * 27 + [w, b]
    if fuse_affine:
        if scale is None:
            scale = jnp.ones((Cout,), x.dtype)
        if offset is None:
            offset = jnp.zeros((Cout,), x.dtype)
        in_specs.append(pl.BlockSpec(scale.shape, lambda *_: (0,)))
        in_specs.append(pl.BlockSpec(offset.shape, lambda *_: (0,)))
        args += [scale, offset]

    out_spec = pl.BlockSpec(
        (1, block, block, block, Cout), lambda bi, zi, yi, xi: (bi, zi, yi, xi, 0)
    )
    kernel = functools.partial(
        _conv_kernel, dilation=dilation, block=block, fuse_affine=fuse_affine
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, D, H, W, Cout), x.dtype),
        interpret=interpret,
    )(*args)


def vmem_bytes(block: int, cin: int, cout: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set: 27 input views + weights + out block."""
    inp = 27 * block**3 * cin * dtype_bytes
    out = block**3 * cout * 4  # f32 accumulator
    wgt = 27 * cin * cout * dtype_bytes
    return inp + out + wgt
