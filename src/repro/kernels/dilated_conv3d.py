"""Pallas TPU kernel for MeshNet's hot-spot: the 3^3 *dilated* 3-D conv.

Why a custom kernel (hardware adaptation, DESIGN.md §2)
-------------------------------------------------------
Brainchop's WebGL backend runs this conv as fragment-shader passes over 2-D
texture tilings of the volume; the cost model there is texture bandwidth.
On TPU the equivalent wall is HBM->VMEM traffic: a 256^3 x 5ch f32 volume is
335 MB, read 27x by a naive gather-per-tap schedule. This kernel tiles the
volume into VMEM-resident cubes and reads, per output block, exactly the
haloed input neighbourhood it needs — a single (block+2*dilation)^3 DMA.

Two schedules, selected by ``variant``:

  ``halo`` (default) — the input stays in HBM (``memory_space=ANY``) and the
    kernel DMAs one haloed window per output block into a VMEM scratch
    buffer. Per-block traffic is ``(block+2d)^3`` — the read floor for a
    blocked dilated conv. This replaced the original 27-view schedule,
    whose traffic was a full ``27*block^3`` per output block regardless of
    dilation (~28x the floor at d=1, see DESIGN.md §2 traffic table).
  ``views`` — the original schedule: the +-dilation neighbourhood expressed
    as 27 disjoint offset views of the same padded input (the canonical
    BlockSpec halo pattern). Kept as a bit-exactness oracle for the halo
    schedule (tests/test_kernels.py) and as the reference point for the
    traffic model in telemetry/traffic.py.

TPU-native design notes
  * channels-last layout: C rides the lane dimension. MeshNet's C=5 is far
    below the 128-lane MXU contraction, so the einsum per tap is a VPU
    (8x128 vreg) FMA, not an MXU matmul — a C<=8 model is *memory-bound* on
    TPU and the win comes from the blocking, not systolic compute. The
    kernel is still correct (and becomes MXU-bound) for wide variants
    (failsafe 21ch / atlas 18ch) where Cin x Cout taps start to matter.
  * block size: ``block`` (default 16 = max MeshNet dilation) keeps the
    haloed window at most (3*block)^3 * C * 4 B of VMEM — 2.2 MB at C=5
    f32, comfortably under the ~16 MB VMEM budget. ``vmem_bytes`` prices
    the working set exactly and ``dilated_conv3d`` refuses (with a
    suggested smaller block) before a call would exceed ``VMEM_BUDGET``.
  * optional fused affine+ReLU epilogue: folds inference-mode BatchNorm and
    activation into the conv's output block while it is still in VMEM
    (saves one full HBM round-trip per layer — see EXPERIMENTS.md §Perf).
  * whole-stack fusion: kernels/megakernel.py goes one step further and
    runs *all* hidden layers per VMEM-resident tile (EXPERIMENTS.md §Perf
    H9); this module remains the per-layer building block.

Validated in interpret mode on CPU against kernels/ref.py for every
(shape, dtype, dilation, channels) in the test sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: VMEM ceiling per core the guard prices against (v4/v5e have 16 MiB).
VMEM_BUDGET = 16 * 1024 * 1024


def _halo_kernel(*refs, dilation: int, block: int, fuse_affine: bool):
    """Haloed-load kernel body. refs = x(ANY) + w + b (+ s, o) + out + scratch."""
    if fuse_affine:
        x_ref, w_ref, b_ref, s_ref, o_ref, out_ref, buf, sem = refs
    else:
        x_ref, w_ref, b_ref, out_ref, buf, sem = refs
        s_ref = o_ref = None
    bi, zi, yi, xi = (pl.program_id(i) for i in range(4))
    d, b = dilation, block
    size = b + 2 * d
    # One DMA per output block: exactly the (b+2d)^3 neighbourhood, from the
    # d-padded input resident in HBM.
    dma = pltpu.make_async_copy(
        x_ref.at[
            bi,
            pl.ds(zi * b, size),
            pl.ds(yi * b, size),
            pl.ds(xi * b, size),
            :,
        ],
        buf,
        sem,
    )
    dma.start()
    dma.wait()

    w = w_ref[...]  # (3, 3, 3, Cin, Cout)
    acc = jnp.zeros((b, b, b, w.shape[-1]), jnp.float32)
    for tz in (-1, 0, 1):
        for ty in (-1, 0, 1):
            for tx in (-1, 0, 1):
                # Output voxel p reads input p + t*d (correlation, as XLA);
                # buffer index 0 is global block origin minus d.
                sl = buf[
                    d + tz * d : d + tz * d + b,
                    d + ty * d : d + ty * d + b,
                    d + tx * d : d + tx * d + b,
                    :,
                ]
                acc = acc + jnp.einsum(
                    "zyxi,io->zyxo",
                    sl.astype(jnp.float32),
                    w[tz + 1, ty + 1, tx + 1].astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
    out = acc + b_ref[...].astype(jnp.float32)
    if fuse_affine:
        out = out * s_ref[...].astype(jnp.float32) + o_ref[...].astype(jnp.float32)
        out = jnp.maximum(out, 0.0)
    out_ref[0] = out.astype(out_ref.dtype)


def _views_kernel(*refs, dilation: int, block: int, fuse_affine: bool):
    """27-view kernel body. refs = 27 input views + w + b (+ scale, offset) + out."""
    if fuse_affine:
        *xs, w_ref, b_ref, s_ref, o_ref, out_ref = refs
    else:
        *xs, w_ref, b_ref, out_ref = refs
        s_ref = o_ref = None
    # Assemble the (3b, 3b, 3b, Cin) neighbourhood from 27 (b,b,b,Cin) views.
    # Loads stay in VMEM; concatenate is a register/VMEM reshuffle (and is
    # why this variant's working set is ~2x the halo schedule's —
    # ``vmem_bytes`` prices the assembled buffer).
    planes = []
    for zi in range(3):
        rows = []
        for yi in range(3):
            cols = [xs[zi * 9 + yi * 3 + xi][0] for xi in range(3)]
            rows.append(jnp.concatenate(cols, axis=2))
        planes.append(jnp.concatenate(rows, axis=1))
    nb = jnp.concatenate(planes, axis=0)  # (3b, 3b, 3b, Cin)

    w = w_ref[...]  # (3, 3, 3, Cin, Cout)
    acc = jnp.zeros((block, block, block, w.shape[-1]), jnp.float32)
    d = dilation
    b = block
    for tz in (-1, 0, 1):
        for ty in (-1, 0, 1):
            for tx in (-1, 0, 1):
                # Output voxel p reads input p + t*d (correlation, as XLA).
                sl = nb[
                    b + tz * d : 2 * b + tz * d,
                    b + ty * d : 2 * b + ty * d,
                    b + tx * d : 2 * b + tx * d,
                    :,
                ]
                acc = acc + jnp.einsum(
                    "zyxi,io->zyxo",
                    sl.astype(jnp.float32),
                    w[tz + 1, ty + 1, tx + 1].astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
    out = acc + b_ref[...].astype(jnp.float32)
    if fuse_affine:
        out = out * s_ref[...].astype(jnp.float32) + o_ref[...].astype(jnp.float32)
        out = jnp.maximum(out, 0.0)
    out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("dilation", "block", "interpret", "fuse_affine", "variant"),
)
def dilated_conv3d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    dilation: int = 1,
    scale: jax.Array | None = None,
    offset: jax.Array | None = None,
    block: int = 16,
    interpret: bool = True,
    fuse_affine: bool = False,
    variant: str = "halo",
) -> jax.Array:
    """'Same'-padded 3-D dilated conv via Pallas.

    x: (B, D, H, W, Cin); w: (3, 3, 3, Cin, Cout); b: (Cout,).
    If ``fuse_affine``: returns relu(conv(x) * scale + offset) — the folded
    inference BatchNorm epilogue. Requires ``dilation <= block`` and spatial
    dims divisible by ``block`` (the ops wrapper pads as needed).
    ``variant`` picks the schedule: "halo" (single haloed DMA per block,
    the production path) or "views" (27 offset BlockSpec views, the
    bit-exact legacy oracle).
    """
    if dilation > block:
        raise ValueError(f"dilation {dilation} > block {block}")
    if variant not in ("halo", "views"):
        raise ValueError(f"variant must be 'halo' or 'views', got {variant!r}")
    B, D, H, W, Cin = x.shape
    Cout = w.shape[-1]
    assert D % block == H % block == W % block == 0, (x.shape, block)
    check_vmem(block, Cin, Cout, dilation=dilation,
               dtype_bytes=x.dtype.itemsize, variant=variant,
               weight_bytes=w.dtype.itemsize)

    grid = (B, D // block, H // block, W // block)

    if fuse_affine:
        if scale is None:
            scale = jnp.ones((Cout,), x.dtype)
        if offset is None:
            offset = jnp.zeros((Cout,), x.dtype)

    out_spec = pl.BlockSpec(
        (1, block, block, block, Cout), lambda bi, zi, yi, xi: (bi, zi, yi, xi, 0)
    )
    out_shape = jax.ShapeDtypeStruct((B, D, H, W, Cout), x.dtype)

    if variant == "halo":
        # d of zero padding per side supplies the halo; the padded volume
        # stays in HBM and each block DMAs its (b+2d)^3 window once.
        xp = jnp.pad(x, [(0, 0)] + [(dilation, dilation)] * 3 + [(0, 0)])
        in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)]
        args = [xp]
        size = block + 2 * dilation
        scratch = [
            pltpu.VMEM((size, size, size, Cin), x.dtype),
            pltpu.SemaphoreType.DMA,
        ]
        kernel = functools.partial(
            _halo_kernel, dilation=dilation, block=block, fuse_affine=fuse_affine
        )
    else:
        # One extra block of zero padding per side supplies the halo.
        xp = jnp.pad(x, [(0, 0)] + [(block, block)] * 3 + [(0, 0)])
        blk = (1, block, block, block, Cin)

        def mk_index(dz, dy, dx):
            return lambda bi, zi, yi, xi: (bi, zi + dz, yi + dy, xi + dx, 0)

        in_specs = [
            pl.BlockSpec(blk, mk_index(dz, dy, dx))
            for dz in range(3)
            for dy in range(3)
            for dx in range(3)
        ]
        args = [xp] * 27
        scratch = []
        kernel = functools.partial(
            _views_kernel, dilation=dilation, block=block, fuse_affine=fuse_affine
        )

    in_specs.append(pl.BlockSpec(w.shape, lambda *_: (0,) * 5))  # weights
    in_specs.append(pl.BlockSpec(b.shape, lambda *_: (0,)))  # bias
    args += [w, b]
    if fuse_affine:
        in_specs.append(pl.BlockSpec(scale.shape, lambda *_: (0,)))
        in_specs.append(pl.BlockSpec(offset.shape, lambda *_: (0,)))
        args += [scale, offset]

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)


def vmem_bytes(
    block: int,
    cin: int,
    cout: int,
    dilation: int = 16,
    dtype_bytes: int = 4,
    variant: str = "halo",
    weight_bytes: int | None = None,
) -> int:
    """Exact VMEM working set of one grid step, bytes.

    ``halo``: the (block+2d)^3 DMA'd window + f32 accumulator + output
    block + weights. ``views``: the 27 streamed views *plus* the assembled
    (3*block)^3 neighbourhood buffer the original estimate omitted (it
    undercounted the working set ~2x), + accumulator + output + weights.
    ``dtype_bytes``/``weight_bytes`` come from the actual array dtypes
    (the precision policy, kernels/quantize.py): bf16 activations halve
    the window, int8 weights quarter the tap block — ``weight_bytes``
    defaults to ``dtype_bytes`` for the uniform legacy case.
    """
    acc = block**3 * cout * 4  # f32 accumulator
    out = block**3 * cout * dtype_bytes
    wgt = 27 * cin * cout * (weight_bytes or dtype_bytes)
    if variant == "halo":
        inp = (block + 2 * dilation) ** 3 * cin * dtype_bytes
    else:
        views = 27 * block**3 * cin * dtype_bytes
        assembled = (3 * block) ** 3 * cin * dtype_bytes
        inp = views + assembled
    return inp + acc + out + wgt


def suggest_block(
    cin: int,
    cout: int,
    dilation: int,
    dtype_bytes: int = 4,
    variant: str = "halo",
    budget: int = VMEM_BUDGET,
    weight_bytes: int | None = None,
) -> int | None:
    """Largest block (multiple of 8, >= dilation) whose working set fits."""
    for cand in (64, 56, 48, 40, 32, 24, 16, 8):
        if cand < dilation:
            break
        if vmem_bytes(cand, cin, cout, dilation, dtype_bytes, variant,
                      weight_bytes) <= budget:
            return cand
    return None


def check_vmem(
    block: int,
    cin: int,
    cout: int,
    dilation: int,
    dtype_bytes: int = 4,
    variant: str = "halo",
    budget: int = VMEM_BUDGET,
    weight_bytes: int | None = None,
) -> int:
    """Raise (with a suggested smaller block) before a pallas_call that
    would exceed the ~16 MB VMEM budget; returns the priced working set."""
    need = vmem_bytes(block, cin, cout, dilation, dtype_bytes, variant,
                      weight_bytes)
    if need > budget:
        hint = suggest_block(cin, cout, dilation, dtype_bytes, variant,
                             budget, weight_bytes)
        fix = f"try block={hint}" if hint else "no block fits; shard channels"
        raise ValueError(
            f"dilated_conv3d[{variant}] block={block} cin={cin} cout={cout} "
            f"dilation={dilation} needs {need / 2**20:.1f} MiB of VMEM, over "
            f"the {budget / 2**20:.0f} MiB budget — {fix}"
        )
    return need
