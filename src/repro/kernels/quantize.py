"""Precision-policy engine: fp32 | bf16 | int8w across every executor.

MeshNet inference on TPU is memory-bound at every paper channel width
(kernels/dilated_conv3d.py): the wall is HBM bytes, not FLOPs, so halving
or quartering the bytes each schedule moves is a direct speedup on the
exact metric the bench gate enforces (``hbm_bytes_modeled``). This module
defines the three storage policies and owns every dtype decision the
kernels, planner, traffic models, pipeline, and serving engine make:

  ``fp32``  — the legacy bit-exact path. Nothing is cast; every existing
              fp32 test, benchmark baseline, and plan is unchanged.
  ``bf16``  — weights and activations cross HBM as bfloat16; every kernel
              accumulates in fp32 and rounds once per HBM crossing
              (per-layer for the fused path, per-segment for the
              megakernel). ~2x byte cut; logits stay within 1e-2 of fp32
              (tests/test_precision.py).
  ``int8w`` — per-output-channel *symmetric* int8 weights with the
              inference BatchNorm folded into the dequant scale, bf16
              activation compute, fp32 accumulate. The megakernel backend
              additionally streams the conformed input volume and its
              inter-segment staging activations as int8 (calibrated
              per-channel scales, below), so int8 is what crosses HBM on
              the production path: >=3x modeled byte cut at 256^3.

Why the accumulate stays fp32: MeshNet's 3^3 x C taps sum up to 135
(C=5) .. 567 (C=21) products per output; bf16's 8-bit mantissa loses ~3
bits to a sum that long, and int8 products need 18+ bits. Accumulating in
fp32 keeps the only rounding at the HBM boundary, which is what makes the
bf16-vs-fp32 parity bound (1e-2) hold across nine stacked layers.

Weight quantization (``quantize_symmetric``) is per-OUTPUT-channel so the
dequant scale rides the conv epilogue: ``conv(x, q) * (wscale * bn_scale)
+ (b * bn_scale + bn_offset)`` — one fused multiply the kernels already
perform for folded BatchNorm (``fold_epilogue``). The round-trip error is
bounded by ``scale / 2`` per element (``roundtrip_bound``), so int8w
logits converge to fp32 as weight magnitude shrinks
(tests/test_quantize.py property test).

Activation staging scales (int8w, megakernel only): inter-segment staging
is quantized with *static per-channel* scales so the reader can dequant
without a global reduction. ``staging_scales_from_bn`` derives a bound
from the folded BatchNorm statistics (post-BN activations are ~N(bias,
scale^2), ReLU-clipped: bound = relu(bias) + K*|scale|) — accurate
exactly when the running stats describe the activations, i.e. for trained
or BN-calibrated models, the production regime. ``calibrate`` tightens
the scales to observed per-channel maxima from a probe forward; the dice
gate in tests/test_precision.py uses it. Models without BatchNorm have no
bound to derive, so the megakernel stages bf16 for them.

The conformed input is [0, 1] by construction (core/conform.py's uint8
rescale), so its int8 scale is the fixed ``INPUT_SCALE = 1/127`` —
faithful to Brainchop, whose conformed volumes literally are uint8.
"""

from __future__ import annotations

import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

#: the three storage policies, plus the sentinel the pipeline resolves.
PRECISIONS = ("fp32", "bf16", "int8w")
AUTO = "auto"

#: fixed dequant scale of the int8-quantized conformed input volume
#: (conform guarantees [0, 1]; symmetric int8 over that range).
INPUT_SCALE = 1.0 / 127.0

#: sigma multiplier of the BN-derived staging bound: P(|z| > 6) over a
#: 256^3 volume is ~1e-2 voxels, so saturation is practically impossible.
BN_BOUND_SIGMA = 6.0

_ACT_DTYPE = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "int8w": jnp.bfloat16}
#: bytes per element crossing HBM, by tensor role. ``act`` is the compute/
#: VMEM width (and the logits write); ``weight`` the streamed conv taps;
#: ``input`` the conformed volume; ``staging`` the megakernel's
#: inter-segment activation arrays. fp32 keeps every legacy width.
_ACT_BYTES = {"fp32": 4, "bf16": 2, "int8w": 2}
_WEIGHT_BYTES = {"fp32": 4, "bf16": 2, "int8w": 1}
_INPUT_BYTES = {"fp32": 4, "bf16": 2, "int8w": 1}
_STAGING_BYTES = {"fp32": 4, "bf16": 2, "int8w": 1}


def validate(precision: str) -> str:
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of {PRECISIONS} "
            f"(or {AUTO!r} where a resolver is available)"
        )
    return precision


def act_dtype(precision: str):
    """Activation compute/storage dtype (bf16 for both reduced policies)."""
    return _ACT_DTYPE[validate(precision)]


def act_bytes(precision: str) -> int:
    return _ACT_BYTES[validate(precision)]


def weight_bytes(precision: str) -> int:
    return _WEIGHT_BYTES[validate(precision)]


def input_bytes(precision: str) -> int:
    return _INPUT_BYTES[validate(precision)]


def staging_bytes(precision: str) -> int:
    return _STAGING_BYTES[validate(precision)]


def resolve_precision(
    name: Optional[str],
    model: Any = None,
    *,
    backend: Optional[str] = None,
) -> str:
    """Map None/"auto" to the device+model default; validate explicit names.

    Policy: CPU hosts serve fp32 — the Pallas paths there are interpret-
    mode correctness tools and the XLA fp32 graph is the oracle every
    parity test compares against. TPU serves bf16 by default (the 2x
    byte cut is numerically free at our parity bound), stepping up to
    int8w for the wide failsafe/atlas models (channels >= 16) whose
    weight taps and staging volumes are large enough that the extra
    quantization machinery pays for itself. An explicit name always wins.
    """
    if name is not None and name != AUTO:
        return validate(name)
    backend = backend or jax.default_backend()
    if backend != "tpu":
        return "fp32"
    if model is not None and getattr(model, "channels", 0) >= 16:
        return "int8w"
    return "bf16"


# ------------------------------------------------------------- weights ---


def quantize_symmetric(w: jax.Array, axis: int = -1):
    """Per-slice symmetric int8 quantization along ``axis``.

    Returns ``(q, scale)`` with ``q = round(w / scale)`` in [-127, 127]
    and ``scale = max|w| / 127`` per slice of ``axis`` (conv weights:
    axis=-1 is the output channel). Zero slices get scale 1 so the
    round-trip stays exact (all-zero q).
    """
    reduce_axes = tuple(i for i in range(w.ndim) if i != axis % w.ndim)
    amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, jnp.squeeze(scale).astype(jnp.float32).reshape(w.shape[axis])


def dequantize(q: jax.Array, scale: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse of ``quantize_symmetric``: float weights, error <= scale/2."""
    shape = [1] * q.ndim
    shape[axis % q.ndim] = q.shape[axis]
    return q.astype(jnp.float32) * scale.reshape(shape)


def roundtrip_bound(scale: jax.Array) -> jax.Array:
    """Element-wise bound on |w - dequantize(quantize(w))|: half a step."""
    return scale / 2.0


def quantize_input(x: jax.Array) -> jax.Array:
    """Quantize a conformed ([0, 1]) volume to int8 with the fixed
    ``INPUT_SCALE`` (symmetric over [-1, 1]; conform never goes negative,
    so the spare sign half of the range is the zero 'same' padding's)."""
    return (
        jnp.clip(jnp.round(x.astype(jnp.float32) / INPUT_SCALE), -127, 127)
        .astype(jnp.int8)
    )


# ------------------------------------------------------- params pytrees ---


def is_prepared(params: Any, precision: str) -> bool:
    """Whether ``params`` already carry ``precision``'s storage dtypes —
    ``prepare_params`` is idempotent through this check, so serving
    engines can cache prepared pytrees and executors accept either form."""
    if validate(precision) == "fp32":
        return True
    w = params["layers"][0]["w"]
    if precision == "bf16":
        return w.dtype == jnp.bfloat16
    return w.dtype == jnp.int8


def prepare_params(params: Any, cfg: Any, precision: str) -> Any:
    """Cast/quantize a MeshNet params pytree into ``precision`` storage.

    bf16: conv and head weights become bfloat16 (biases and BN statistics
    stay fp32 — they are folded into the fp32 epilogue and are KB-scale).
    int8w: each hidden layer's ``w`` becomes int8 with a per-output-
    channel ``wscale``; the 1x1x1 head stays bf16 (no BN to fold, its
    bytes are negligible, and its error lands directly on the logits).
    Idempotent: already-prepared params pass through unchanged.
    """
    if validate(precision) == "fp32" or is_prepared(params, precision):
        return params
    layers = []
    for layer in params["layers"]:
        new = dict(layer)
        if precision == "bf16":
            new["w"] = layer["w"].astype(jnp.bfloat16)
        else:
            q, scale = quantize_symmetric(layer["w"], axis=-1)
            new["w"] = q
            new["wscale"] = scale
        layers.append(new)
    head = dict(params["head"])
    head["w"] = head["w"].astype(jnp.bfloat16)
    return {"layers": layers, "head": head}


def fold_epilogue(layer: dict, use_batchnorm: bool, eps: float = 1e-5):
    """The per-layer fused epilogue ``relu(acc * scale + offset)`` for a
    (possibly quantized) layer, with the conv bias — and for int8w the
    weight dequant scale — folded in.

    Returns ``(bias, scale, offset)`` where ``bias`` is what the kernel
    adds to the raw accumulator *before* the affine. For fp32/bf16 layers
    this reproduces ops.fold_batchnorm exactly (bias = layer b); for
    int8w layers the accumulator is in quantized-weight units, so the
    bias moves inside the affine: ``bias = 0``, ``scale = wscale *
    bn_scale``, ``offset = b * bn_scale + bn_offset``.
    """
    if use_batchnorm:
        inv = jax.lax.rsqrt(layer["bn_var"].astype(jnp.float32) + eps)
        bn_scale = layer["bn_scale"].astype(jnp.float32) * inv
        bn_offset = (
            layer["bn_bias"].astype(jnp.float32)
            - layer["bn_mean"].astype(jnp.float32) * bn_scale
        )
    else:
        bn_scale = jnp.ones(layer["b"].shape, jnp.float32)
        bn_offset = jnp.zeros(layer["b"].shape, jnp.float32)
    b = layer["b"].astype(jnp.float32)
    if "wscale" in layer:  # int8w: dequant rides the affine
        zero = jnp.zeros_like(b)
        return zero, layer["wscale"] * bn_scale, b * bn_scale + bn_offset
    return b, bn_scale, bn_offset


def params_bytes(params: Any) -> int:
    """Actual bytes of a (possibly prepared) params pytree — the streamed
    weight footprint stamped on TelemetryRecord.params_bytes."""
    return sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(params)
    )


def model_params_bytes(cfg: Any, precision: str = "fp32") -> int:
    """Analytic ``params_bytes`` from a MeshNetConfig: conv weights at the
    policy's weight width, the bf16 head for reduced precisions, fp32
    biases/BN vectors/dequant scales."""
    validate(precision)
    wb = weight_bytes(precision)
    hb = 4 if precision == "fp32" else 2
    k = cfg.kernel_size ** 3
    total = 0
    cin = cfg.in_channels
    for _ in cfg.dilations:
        total += k * cin * cfg.channels * wb  # conv taps
        total += cfg.channels * 4  # bias
        if cfg.use_batchnorm:
            total += 4 * cfg.channels * 4  # scale/bias/mean/var
        if precision == "int8w":
            total += cfg.channels * 4  # wscale
        cin = cfg.channels
    total += cfg.channels * cfg.num_classes * hb + cfg.num_classes * 4
    return total


# --------------------------------------------------- staging activation ---


def staging_scales_from_bn(params: Any, cfg: Any) -> Optional[list]:
    """Per-layer per-channel int8 staging scales from folded BN statistics.

    Post-BN activations are ~N(bn_bias, bn_scale^2) when the running
    stats describe the data (trained / BN-calibrated models); after ReLU
    the observable range is [0, relu(bias) + K*|scale|]. Returns one
    (C,) fp32 scale per hidden layer, or None when the config has no
    BatchNorm to bound with (the megakernel stages bf16 instead).
    """
    if not cfg.use_batchnorm:
        return None
    scales = []
    for layer in params["layers"]:
        bound = jax.nn.relu(layer["bn_bias"].astype(jnp.float32))
        bound = bound + BN_BOUND_SIGMA * jnp.abs(
            layer["bn_scale"].astype(jnp.float32)
        )
        scales.append(jnp.maximum(bound, 1e-6) / 127.0)
    return scales


def calibrate(params: Any, cfg: Any, x: jax.Array, margin: float = 1.25) -> list:
    """Observed per-layer per-channel staging scales from a probe forward.

    Runs the fp32 reference forward on ``x`` and returns ``max_c *
    margin / 127`` per hidden layer — tighter than the BN bound by the
    ratio of the observed max to the K-sigma bound, at the cost of one
    forward. The margin absorbs probe-vs-serve distribution drift.
    """
    from repro.core import meshnet

    if x.ndim == 4:
        x = x[..., None]
    x = x.astype(jnp.float32)
    scales = []
    for i, d in enumerate(cfg.dilations):
        x, _ = meshnet.apply_layer(
            params["layers"][i], x, d, cfg, training=False
        )
        amax = jnp.max(jnp.abs(x), axis=tuple(range(x.ndim - 1)))
        scales.append(jnp.maximum(amax * margin, 1e-6) / 127.0)
    return scales


def quantize_staging(x: jax.Array, scale: jax.Array) -> jax.Array:
    """ReLU activations -> int8 with a per-channel static scale (values
    beyond the calibrated bound saturate at 127)."""
    return (
        jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        .astype(jnp.int8)
    )


# ------------------------------------------------------------ reference ---


def conv_block_reduced(
    x: jax.Array,
    layer: dict,
    dilation: int,
    use_batchnorm: bool,
    adt,
    *,
    z_same: bool = True,
) -> jax.Array:
    """One reduced-precision MeshNet conv block — THE shared rounding
    points of every non-Pallas backend: fp32-accumulated lax conv over
    the (bf16-cast, possibly int8) taps, the fused fp32 epilogue
    (``fold_epilogue`` — dequant/bias/BN), one round to ``adt`` at the
    layer boundary. The xla reference, the streaming first layer, and the
    sharded layer-wise slabs all call this one function, so cross-backend
    bit-closeness within a policy is structural, not copy-paste
    (tests/test_precision.py). ``z_same=False`` drops the Z padding — the
    sharded slab schedule supplies Z context via the halo exchange.
    """
    bias, scale, offset = fold_epilogue(layer, use_batchnorm)
    pad = [(dilation, dilation)] * 3
    if not z_same:
        pad[0] = (0, 0)
    acc = jax.lax.conv_general_dilated(
        x,
        layer["w"].astype(adt),
        (1, 1, 1),
        pad,
        rhs_dilation=(dilation,) * 3,
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"),
        preferred_element_type=jnp.float32,
    )
    return jnp.maximum((acc + bias) * scale + offset, 0.0).astype(adt)


def reference_apply(params: Any, x: jax.Array, cfg: Any, precision: str) -> jax.Array:
    """Precision-aware XLA reference forward — the parity oracle the
    "xla" executor serves for non-fp32 policies.

    Mirrors the kernels' rounding points exactly: weights dequantized /
    cast once, activations rounded to bf16 at each layer boundary (the
    HBM crossing), every conv and the head accumulating in fp32. No
    staging quantization — int8 staging is a megakernel schedule detail,
    gated by dice agreement rather than elementwise parity.
    """
    from repro.core import meshnet

    if validate(precision) == "fp32":
        return meshnet.apply(params, x, cfg)
    if x.ndim == 4:
        x = x[..., None]
    adt = act_dtype(precision)
    if x.dtype == jnp.int8:  # pre-quantized conformed input
        x = x.astype(adt) * jnp.asarray(INPUT_SCALE, adt)
    elif precision == "int8w":
        x = quantize_input(x).astype(adt) * jnp.asarray(INPUT_SCALE, adt)
    else:
        x = x.astype(adt)
    for i, d in enumerate(cfg.dilations):
        # int8 taps are exact in bf16 (integers <= 127); their dequant
        # scale rides the fold_epilogue affine inside conv_block_reduced.
        x = conv_block_reduced(
            x, params["layers"][i], d, cfg.use_batchnorm, adt
        )
    head = params["head"]
    logits = (
        jnp.einsum(
            "bdhwi,io->bdhwo",
            x,
            head["w"][0, 0, 0].astype(adt),
            preferred_element_type=jnp.float32,
        )
        + head["b"].astype(jnp.float32)
    )
    return logits.astype(adt)
