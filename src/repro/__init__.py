"""repro — production-grade JAX reproduction of Brainchop/MeshNet.

Layers:
  core/      the paper's contribution (MeshNet, patching, cropping,
             streaming inference, connected components, conform)
  models/    assigned architecture zoo (dense/MoE/SSM/hybrid/VLM/audio)
  data/      synthetic MRI + token pipelines
  training/  losses, optimizers, trainer, checkpointing
  serving/   batched segmentation + LM serving engines
  kernels/   Pallas TPU kernels (validated in interpret mode on CPU)
  launch/    production mesh, multi-pod dry-run, train/serve drivers
"""

__version__ = "1.0.0"
