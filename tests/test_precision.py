"""Precision-policy gates across every executor backend (EXPERIMENTS.md
H11; kernels/quantize.py).

Three families of claims:

  * **Parity** — per policy, every backend computes the same function:
    bf16 logits are bit-close across backends (they share the rounding
    points: bf16 at HBM crossings, fp32 accumulate) and within 1e-2 of
    the fp32 logits on conform-distributed inputs; int8w backends agree
    with the int8w xla oracle, and their *segmentations* track fp32.
  * **Accuracy** — on a briefly *trained* model (real decision margins —
    quantization gates on random-init logits measure coin flips), int8w
    dice >= 0.99x the fp32 dice, for every backend including the
    megakernel with int8 staging forced through a tiny VMEM budget.
  * **Traffic** — the analytic models at the paper volume: megakernel
    int8w <= 0.4x and bf16 <= 0.55x the fp32 bytes for every
    PAPER_MODEL, with the committed fp32 baselines unchanged by the
    precision-aware planner.

Multi-device (sharded family) parity runs wherever >= 2 devices exist —
the CI ``distributed`` job forces 8 host devices and REPRO_SMALL_SHAPES=1.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executors, meshnet, pipeline
from repro.core.meshnet import MeshNetConfig, PAPER_MODELS
from repro.core.pipeline import PipelineConfig
from repro.data import mri
from repro.kernels import megakernel, ops, quantize
from repro.telemetry import traffic

KEY = jax.random.PRNGKey(11)

SMALL = os.environ.get("REPRO_SMALL_SHAPES") == "1"

#: odd (non-block-multiple) spatial shape, conform-distributed data
ODD_SHAPE = (1, 10, 12, 14)

SINGLE_DEVICE_BACKENDS = (
    "xla", "pallas_fused", "pallas_megakernel", "streaming", "sharded_xla@1"
)


def _mri_input(shape=ODD_SHAPE, seed=11):
    vol, _ = mri.generate(
        jax.random.PRNGKey(seed), mri.SyntheticMRIConfig(shape=shape[1:4])
    )
    return vol[None]


def _f32(a):
    return np.asarray(a, np.float32)


class TestBf16Parity:
    """bf16 <= 1e-2 max-abs vs fp32 logits, on every backend."""

    @pytest.mark.parametrize("name", sorted(PAPER_MODELS))
    def test_paper_models_vs_fp32(self, name):
        cfg = PAPER_MODELS[name]
        p = meshnet.init(KEY, cfg)
        x = _mri_input()
        ref = _f32(executors.apply("xla", p, x, cfg))
        for backend in SINGLE_DEVICE_BACKENDS:
            got = executors.apply(backend, p, x, cfg, precision="bf16")
            assert got.dtype == jnp.bfloat16
            err = np.max(np.abs(_f32(got) - ref))
            assert err <= 1e-2, (backend, err)

    def test_backends_agree_bitwise_tight(self):
        # all bf16 backends share rounding points -> near-identical logits
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        p = meshnet.init(KEY, cfg)
        x = _mri_input()
        oracle = _f32(executors.apply("xla", p, x, cfg, precision="bf16"))
        for backend in SINGLE_DEVICE_BACKENDS[1:]:
            got = _f32(executors.apply(backend, p, x, cfg, precision="bf16"))
            np.testing.assert_allclose(got, oracle, atol=1e-3)

    def test_no_batchnorm(self):
        # without BN the activations grow unnormalized layer-over-layer,
        # so the absolute bf16 gap scales with them — the 1e-2 gate is a
        # claim about the (all-BatchNorm) paper zoo; here we only require
        # the same order of magnitude and cross-backend agreement
        cfg = MeshNetConfig(dilations=(1, 2), use_batchnorm=False)
        p = meshnet.init(KEY, cfg)
        x = _mri_input()
        ref = _f32(executors.apply("xla", p, x, cfg))
        for backend in SINGLE_DEVICE_BACKENDS:
            got = _f32(executors.apply(backend, p, x, cfg, precision="bf16"))
            assert np.max(np.abs(got - ref)) <= 3e-2, backend


class TestInt8wParity:
    def test_backends_agree_with_oracle(self):
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        p = meshnet.init(KEY, cfg)
        x = _mri_input()
        oracle = _f32(executors.apply("xla", p, x, cfg, precision="int8w"))
        for backend in SINGLE_DEVICE_BACKENDS[1:]:
            got = _f32(executors.apply(backend, p, x, cfg, precision="int8w"))
            # the megakernel folds the input scale exactly instead of
            # rounding the dequantized input to bf16 — a one-ulp-of-bf16
            # family difference; everything else is bit-close
            np.testing.assert_allclose(got, oracle, atol=2e-2)

    def test_megakernel_int8_staging_matches_oracle(self):
        """Force a multi-segment plan (tiny VMEM budget) so the int8
        staging write/dequant path is exercised, then check logits stay
        near the (non-staged) oracle and the segmentation tracks fp32."""
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        p = meshnet.init(KEY, cfg)
        x = _mri_input()
        budget = 96 * 1024
        pln = megakernel.plan_for_config(
            cfg, x.shape[1:4], vmem_budget=budget, precision="int8w"
        )
        assert len(pln.segments) >= 2, "budget did not force staging"
        got = ops.meshnet_apply_megakernel(
            p, x, cfg, precision="int8w", vmem_budget=budget
        )
        oracle = executors.apply("xla", p, x, cfg, precision="int8w")
        np.testing.assert_allclose(_f32(got), _f32(oracle), atol=8e-2)
        ref = executors.apply("xla", p, x, cfg)
        agree = float(
            jnp.mean(jnp.argmax(got, -1) == jnp.argmax(ref, -1))
        )
        assert agree >= 0.95, agree

    def test_calibrated_scales_tighten_staging(self):
        """quantize.calibrate scales (observed maxima) must not be worse
        than the BN 6-sigma bound on the data they were calibrated on."""
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        p = meshnet.init(KEY, cfg)
        x = _mri_input()
        budget = 96 * 1024
        ref = executors.apply("xla", p, x, cfg)

        def staged_err(scales):
            got = ops.meshnet_apply_megakernel(
                p, x, cfg, precision="int8w", vmem_budget=budget,
                staging_scales=scales,
            )
            return float(jnp.max(jnp.abs(got.astype(jnp.float32) - ref)))

        bn_err = staged_err(quantize.staging_scales_from_bn(p, cfg))
        cal_err = staged_err(quantize.calibrate(p, cfg, x))
        assert cal_err <= bn_err + 1e-3, (cal_err, bn_err)

    def test_no_batchnorm_falls_back_to_bf16_staging(self):
        # without BN stats there is no staging bound: the megakernel must
        # still run (staging stays bf16) and match its oracle
        cfg = MeshNetConfig(dilations=(1, 2), use_batchnorm=False)
        p = meshnet.init(KEY, cfg)
        x = _mri_input()
        got = ops.meshnet_apply_megakernel(
            p, x, cfg, precision="int8w", vmem_budget=96 * 1024
        )
        oracle = executors.apply("xla", p, x, cfg, precision="int8w")
        np.testing.assert_allclose(_f32(got), _f32(oracle), atol=2e-2)


@pytest.fixture(scope="module")
def trained_gwm():
    """A briefly trained gwm-style model: real decision margins make the
    dice gate meaningful (random-init logits are coin flips at every
    precision). Same deterministic recipe as the tier-1 training test."""
    from repro.training import trainer

    cfg = trainer.TrainConfig(
        model=MeshNetConfig(channels=5, dropout_rate=0.0),
        data=mri.DataLoaderConfig(
            mri=mri.SyntheticMRIConfig(shape=(24, 24, 24)), batch_size=2
        ),
        steps=40,
        eval_subjects=1,
        log_every=1000,
        seed=1,
    )
    res = trainer.train(cfg, verbose=False)
    vol, labels = mri.generate(
        jax.random.PRNGKey(10_000), mri.SyntheticMRIConfig(shape=(24, 24, 24))
    )
    return res.params, cfg.model, vol, labels


def _dice(seg, labels, num_classes):
    from repro.training import losses

    return float(losses.dice_score(seg, labels, num_classes))


class TestInt8wDiceGate:
    """int8w dice >= 0.99x fp32 dice on a trained model — the acceptance
    gate, per backend (megakernel with staging forced)."""

    def test_dice_ratio_every_backend(self, trained_gwm):
        params, cfg, vol, labels = trained_gwm
        x = vol[None]
        ref_seg = jnp.argmax(executors.apply("xla", params, x, cfg), -1)[0]
        d_ref = _dice(ref_seg.astype(jnp.int32), labels, cfg.num_classes)
        assert d_ref > 0.4, f"training failed to produce a usable model: {d_ref}"
        for backend in SINGLE_DEVICE_BACKENDS:
            for prec in ("bf16", "int8w"):
                seg = jnp.argmax(
                    executors.apply(backend, params, x, cfg, precision=prec), -1
                )[0]
                d = _dice(seg.astype(jnp.int32), labels, cfg.num_classes)
                assert d >= 0.99 * d_ref, (backend, prec, d, d_ref)

    def test_dice_ratio_with_forced_int8_staging(self, trained_gwm):
        params, cfg, vol, labels = trained_gwm
        x = vol[None]
        ref_seg = jnp.argmax(executors.apply("xla", params, x, cfg), -1)[0]
        d_ref = _dice(ref_seg.astype(jnp.int32), labels, cfg.num_classes)
        budget = 512 * 1024
        pln = megakernel.plan_for_config(
            cfg, x.shape[1:4], vmem_budget=budget, precision="int8w"
        )
        assert len(pln.segments) >= 2, "budget did not force staging"
        got = ops.meshnet_apply_megakernel(
            params, x, cfg, precision="int8w", vmem_budget=budget
        )
        seg = jnp.argmax(got, -1)[0]
        d = _dice(seg.astype(jnp.int32), labels, cfg.num_classes)
        assert d >= 0.99 * d_ref, (d, d_ref)


class TestShardedPrecisionParity:
    """The sharded family per policy: bf16 halos / int8 one-shot fetch
    must reproduce the single-device backend per precision. Multi-device
    claims — skipped below 2 devices (the CI distributed job forces 8)."""

    pytestmark = pytest.mark.skipif(
        jax.device_count() < 2,
        reason="sharded precision parity is a multi-device claim",
    )

    VOL = (16, 8, 8) if SMALL else (32, 12, 12)

    def _slab_counts(self):
        n = jax.device_count()
        return [s for s in (2, 4, 8) if s <= n and self.VOL[0] % s == 0]

    @pytest.mark.parametrize("inner", ["xla", "pallas_fused", "pallas_megakernel"])
    def test_sharded_matches_single_device_per_precision(self, inner):
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        p = meshnet.init(KEY, cfg)
        x = _mri_input((1,) + self.VOL)
        for prec in ("bf16", "int8w"):
            want = _f32(executors.apply(inner, p, x, cfg, precision=prec))
            for n in self._slab_counts():
                got = _f32(
                    executors.apply(
                        executors.sharded_name(inner, n), p, x, cfg, precision=prec
                    )
                )
                # slab schedules re-round at exchange boundaries; allow a
                # few bf16 ulps on top of exact fp32 sharded parity
                np.testing.assert_allclose(got, want, atol=2e-2,
                                           err_msg=f"{inner}@{n}@{prec}")

    def test_collective_bytes_shrink_with_precision(self):
        cfg = MeshNetConfig()
        full = traffic.meshnet_collective_bytes(cfg, (64, 16, 16), 4)
        half = traffic.meshnet_collective_bytes(
            cfg, (64, 16, 16), 4, precision="bf16"
        )
        assert half * 2 == full


class TestTrafficGates:
    """The acceptance numbers, from the analytic models (no compute)."""

    @pytest.mark.parametrize("name", sorted(PAPER_MODELS))
    def test_megakernel_gates_at_paper_volume(self, name):
        cfg = PAPER_MODELS[name]
        vol = (256, 256, 256)
        fp32 = traffic.meshnet_megakernel_bytes(cfg, vol)
        bf16 = traffic.meshnet_megakernel_bytes(cfg, vol, precision="bf16")
        int8 = traffic.meshnet_megakernel_bytes(cfg, vol, precision="int8w")
        assert bf16 <= 0.55 * fp32, (name, bf16 / fp32)
        assert int8 <= 0.40 * fp32, (name, int8 / fp32)

    def test_fp32_baseline_unchanged_by_precision_planner(self):
        """The finer tile grid and per-role widths must not move the
        committed fp32 numbers (the bench regression gate compares
        like-for-like precision keys)."""
        path = os.path.join(os.path.dirname(__file__), "..", "BENCH_2.json")
        with open(path) as f:
            committed = {
                r["name"]: r["hbm_bytes_modeled"]
                for r in json.load(f)["traffic"]
            }
        for name in ("gwm_light", "subvolume_gwm_failsafe"):
            key = f"hbm_{name}_256_pallas_megakernel"
            if key not in committed:  # baseline regenerated without it
                pytest.skip("no committed fp32 megakernel baseline")
            got = traffic.meshnet_megakernel_bytes(
                PAPER_MODELS[name], (256, 256, 256)
            )
            assert got == committed[key], (name, got, committed[key])

    @pytest.mark.parametrize("backend", ["xla", "pallas_fused", "streaming"])
    def test_layerwise_backends_monotone_in_precision(self, backend):
        cfg = PAPER_MODELS["gwm_light"]
        vol = (64, 64, 64)
        fp32 = traffic.executor_hbm_bytes(backend, cfg, vol)
        bf16 = traffic.executor_hbm_bytes(backend, cfg, vol, precision="bf16")
        int8 = traffic.executor_hbm_bytes(backend, cfg, vol, precision="int8w")
        assert int8 <= bf16 < fp32
        assert bf16 <= 0.55 * fp32

    def test_sharded_bytes_precision_aware(self):
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        vol = (32, 16, 16)
        for inner in ("xla", "pallas_megakernel"):
            full = traffic.meshnet_sharded_bytes(inner, cfg, vol, 4)
            red = traffic.meshnet_sharded_bytes(
                inner, cfg, vol, 4, precision="bf16"
            )
            assert red < full

    def test_vmem_model_derives_from_dtypes(self):
        from repro.kernels import dilated_conv3d as conv_kernel

        wide = conv_kernel.vmem_bytes(16, 21, 21, dilation=8, dtype_bytes=4)
        bf16 = conv_kernel.vmem_bytes(16, 21, 21, dilation=8, dtype_bytes=2)
        int8w = conv_kernel.vmem_bytes(
            16, 21, 21, dilation=8, dtype_bytes=2, weight_bytes=1
        )
        assert int8w < bf16 < wide

    def test_precision_plans_cached_separately(self):
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        a = megakernel.plan_for_config(cfg, (32, 32, 32))
        b = megakernel.plan_for_config(cfg, (32, 32, 32), precision="int8w")
        assert a.widths is None and b.widths is not None
        assert b.hbm_bytes() < a.hbm_bytes()


class TestPipelineAndEngine:
    def _setup(self):
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        params = meshnet.init(KEY, cfg)
        vol, _ = mri.generate(KEY, mri.SyntheticMRIConfig(shape=(16, 16, 16)))
        return cfg, params, vol

    @pytest.mark.parametrize("prec", ["fp32", "bf16", "int8w"])
    @pytest.mark.parametrize("mode", ["full", "subvolume", "streaming"])
    def test_pipeline_serves_every_mode_at_every_precision(self, mode, prec):
        cfg, params, vol = self._setup()
        pc = PipelineConfig(
            model=cfg, volume_shape=(16, 16, 16), mode=mode, cube=8, overlap=4,
            min_component_size=4, executor="xla", precision=prec,
        )
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "ok", res.record.fail_type
        assert res.segmentation.shape == (16, 16, 16)
        assert res.record.precision == prec
        assert res.record.params_bytes == quantize.model_params_bytes(cfg, prec)
        assert res.record.hbm_bytes_modeled > 0

    def test_precision_cuts_modeled_bytes_end_to_end(self):
        cfg, params, vol = self._setup()
        bytes_by_prec = {}
        for prec in ("fp32", "bf16"):
            pc = PipelineConfig(
                model=cfg, volume_shape=(16, 16, 16), mode="full",
                min_component_size=4, executor="xla", precision=prec,
            )
            bytes_by_prec[prec] = pipeline.run(pc, params, vol).record.hbm_bytes_modeled
        assert bytes_by_prec["bf16"] * 2 == bytes_by_prec["fp32"]

    def test_auto_resolves_fp32_on_cpu(self):
        cfg, params, vol = self._setup()
        pc = PipelineConfig(
            model=cfg, volume_shape=(16, 16, 16), mode="full",
            min_component_size=4, executor="xla",
        )
        assert pc.precision == "auto"
        res = pipeline.run(pc, params, vol)
        want = quantize.resolve_precision("auto", cfg)
        assert res.record.precision == want

    def test_engine_per_request_precision_and_prepared_cache(self):
        from repro.serving.engine import SegmentationEngine
        from repro.telemetry.budget import MemoryBudget

        cfg, params, vol = self._setup()
        pc = PipelineConfig(
            model=cfg, volume_shape=(16, 16, 16), cube=8, overlap=4,
            min_component_size=4,
        )
        engine = SegmentationEngine(
            params, pc, budget=MemoryBudget(8 * 1024 * 1024, name="tight")
        )
        results = engine.submit_many(
            [vol, vol, vol], precisions=[None, "bf16", "int8w"]
        )
        assert [r.record.status for r in results] == ["ok"] * 3
        assert results[1].record.precision == "bf16"
        assert results[2].record.precision == "int8w"
        # prepared-params cache: one pytree per policy, reused on repeat
        assert engine._params_for("int8w") is engine._params_for("int8w")
        again = engine.submit(vol, precision="int8w")
        assert again.record.precision == "int8w"

    def test_precision_summary_rollup(self):
        from repro.serving.engine import SegmentationEngine
        from repro.telemetry import analysis
        from repro.telemetry.budget import MemoryBudget

        cfg, params, vol = self._setup()
        pc = PipelineConfig(
            model=cfg, volume_shape=(16, 16, 16), cube=8, overlap=4,
            min_component_size=4, executor="xla",
        )
        engine = SegmentationEngine(
            params, pc, budget=MemoryBudget(8 * 1024 * 1024, name="tight")
        )
        engine.submit_many([vol, vol], precisions=["bf16", "bf16"])
        engine.submit(vol, precision="int8w")
        cells = {
            (s.executor, s.precision): s
            for s in analysis.precision_summary(engine.log.records)
        }
        assert cells[("xla", "bf16")].runs == 2
        assert cells[("xla", "int8w")].runs == 1
        assert cells[("xla", "int8w")].mean_params_bytes < cells[
            ("xla", "bf16")
        ].mean_params_bytes
        assert cells[("xla", "bf16")].ok_rate == 1.0
