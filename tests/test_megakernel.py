"""Depth-first megakernel: parity with the XLA reference across the paper
model zoo (including the volume-boundary band the sub-volume path gets
wrong — the in-tile masking must reproduce per-layer 'same' padding), the
planner's VMEM discipline, and the modeled-traffic claims of
EXPERIMENTS.md §Perf H9."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executors, meshnet
from repro.core.meshnet import MeshNetConfig, PAPER_MODELS
from repro.kernels import megakernel
from repro.telemetry import traffic

KEY = jax.random.PRNGKey(11)

# Small odd (non-block-multiple) spatial shape: exercises tile padding,
# halo masking at every face, and multi-segment staging, while keeping
# interpret-mode Pallas runtime tolerable on CPU.
ODD_SHAPE = (1, 10, 12, 14)

SMALL = MeshNetConfig(dilations=(1, 2, 4))

#: the paper's full Table-I schedule — forces a multi-segment plan on CPU.
FULL_SCHEDULE = (1, 2, 4, 8, 16, 8, 4, 2, 1)


def _parity(cfg: MeshNetConfig, shape=ODD_SHAPE, atol=1e-4, seed=3):
    p = meshnet.init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), shape)
    got = executors.apply("pallas_megakernel", p, x, cfg)
    expect = executors.apply("xla", p, x, cfg)
    assert got.shape == expect.shape == shape + (cfg.num_classes,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=atol)


class TestParity:
    """ops.meshnet_apply_megakernel == meshnet.apply (eval) to <= 1e-4."""

    @pytest.mark.parametrize("name", sorted(PAPER_MODELS))
    def test_paper_models(self, name):
        _parity(PAPER_MODELS[name])

    def test_full_dilation_schedule_multi_segment(self):
        cfg = MeshNetConfig(dilations=FULL_SCHEDULE)
        pln = megakernel.plan_for_config(cfg, ODD_SHAPE[1:4])
        assert len(pln.segments) > 1  # the halo cannot fit in one segment
        _parity(cfg)

    def test_no_batchnorm(self):
        _parity(MeshNetConfig(use_batchnorm=False))

    def test_nontrivial_bn_stats(self):
        # Fold-correctness is invisible with init stats (mean 0 / var 1).
        cfg = SMALL
        p = meshnet.init(KEY, cfg)
        k = jax.random.PRNGKey(5)
        for layer in p["layers"]:
            k, k1, k2 = jax.random.split(k, 3)
            layer["bn_mean"] = jax.random.normal(k1, layer["bn_mean"].shape) * 0.3
            layer["bn_var"] = 0.5 + jax.random.uniform(k2, layer["bn_var"].shape)
        x = jax.random.normal(jax.random.PRNGKey(6), ODD_SHAPE)
        got = executors.apply("pallas_megakernel", p, x, cfg)
        expect = executors.apply("xla", p, x, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=1e-4)

    @pytest.mark.parametrize("shape", [(1, 16, 16, 16), (2, 9, 17, 13)])
    def test_block_multiple_and_batched_odd(self, shape):
        _parity(SMALL, shape=shape)

    def test_registry_jitted_dispatch(self):
        # the exact cached callable pipeline/engine serve with
        p = meshnet.init(KEY, SMALL)
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 8, 8))
        got = executors.jitted_apply("pallas_megakernel")(p, x, SMALL)
        expect = meshnet.apply(p, x, SMALL)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=1e-4)


class TestPlanner:
    def test_segments_partition_schedule(self):
        cfg = MeshNetConfig(dilations=FULL_SCHEDULE)
        pln = megakernel.plan_for_config(cfg, (256, 256, 256))
        covered = []
        for seg in pln.segments:
            assert seg.start == len(covered)
            covered.extend(seg.dilations)
        assert tuple(covered) == FULL_SCHEDULE
        # only the last segment fuses the head
        assert [s.fuse_head for s in pln.segments] == (
            [False] * (len(pln.segments) - 1) + [True]
        )

    def test_working_sets_fit_budget(self):
        for name in ("gwm_light", "subvolume_gwm_failsafe", "atlas_104"):
            pln = megakernel.plan_for_config(PAPER_MODELS[name], (256, 256, 256))
            for seg in pln.segments:
                assert megakernel._segment_vmem_bytes(seg) <= pln.vmem_budget

    def test_halo_arithmetic_final_tile_exact(self):
        # S_0 = tile + 2*halo shrinks by 2d per layer down to exactly tile
        pln = megakernel.plan_for_config(MeshNetConfig(), (64, 64, 64))
        for seg in pln.segments:
            sizes = seg.buffer_sizes()
            assert sizes[0] == tuple(t + 2 * seg.halo for t in seg.tile)
            assert sizes[-1] == seg.tile

    def test_infeasible_budget_raises_with_hint(self):
        with pytest.raises(ValueError, match="megakernel plan infeasible"):
            megakernel.plan_for_config(
                MeshNetConfig(channels=512), (64, 64, 64), vmem_budget=2**20
            )

    def test_vmem_model_counts_accumulator(self):
        # The f32 tap-loop accumulator is live alongside the static scratch;
        # a plan priced without it would exceed real VMEM on TPU.
        pln = megakernel.plan_for_config(PAPER_MODELS["gwm_light"], (256, 256, 256))
        for seg in pln.segments:
            sizes = seg.buffer_sizes()
            acc = max(
                (s[0] * s[1] * s[2] for s in sizes[1:]),
            ) * seg.channels * 4
            assert megakernel._segment_vmem_bytes(seg) >= acc

    def test_pipeline_reports_infeasible_plan_as_failed_run(self):
        # Never-raises contract: an explicitly requested megakernel whose
        # plan cannot fit VMEM yields a status='fail' telemetry record
        # (fail_type vmem_oom), not an exception out of pipeline.run.
        from repro.core import pipeline
        from repro.core.pipeline import PipelineConfig

        wide = MeshNetConfig(channels=4096, dilations=(16,))
        pc = PipelineConfig(
            model=wide, volume_shape=(64, 64, 64), executor="pallas_megakernel"
        )
        res = pipeline.run(pc, None, jnp.zeros((64, 64, 64)))
        assert res.segmentation is None
        assert res.record.status == "fail"
        assert res.record.fail_type == "vmem_oom"

    def test_tiles_need_not_be_cubes(self):
        # at the paper volume the d=16 layer fits best as a non-cubic tile
        pln = megakernel.plan_for_config(PAPER_MODELS["gwm_light"], (256, 256, 256))
        assert any(len(set(seg.tile)) > 1 for seg in pln.segments)


class TestTrafficModel:
    def test_megakernel_5x_under_fused_at_paper_volume(self):
        # EXPERIMENTS.md §Perf H9 / the PR's acceptance bar: the headline
        # full-volume models move >= 5x fewer modeled HBM bytes.
        vol = (256, 256, 256)
        for name in ("gwm_light", "brain_mask_fast", "extract_brain_fast"):
            cfg = PAPER_MODELS[name]
            fused = traffic.meshnet_fused_bytes(cfg, vol)
            mega = traffic.meshnet_megakernel_bytes(cfg, vol)
            assert fused >= 5 * mega, (name, fused / mega)

    def test_ordering_views_worst_fused_middle_mega_best(self):
        cfg = PAPER_MODELS["gwm_light"]
        vol = (256, 256, 256)
        views = traffic.meshnet_views_bytes(cfg, vol)
        fused = traffic.meshnet_fused_bytes(cfg, vol)
        mega = traffic.meshnet_megakernel_bytes(cfg, vol)
        assert views > fused > mega

    def test_registry_exposes_bytes_for_all_builtins(self):
        for name in executors.names():
            if "@" in name:
                # pinned sharded specs ("sharded_xla@64") are registered
                # on demand by requests/tests, not builtins — their slab
                # count need not divide this probe volume
                continue
            b = executors.modeled_hbm_bytes(name, SMALL, (32, 32, 32))
            assert b is not None and b > 0, name

    def test_plan_traffic_matches_model(self):
        cfg = PAPER_MODELS["gwm_light"]
        pln = megakernel.plan_for_config(cfg, (256, 256, 256))
        assert pln.hbm_bytes() == traffic.meshnet_megakernel_bytes(cfg, (256, 256, 256))

    def test_batch_is_subadditive(self):
        # a batched launch streams each weight tensor ONCE (batch loop
        # innermost), so bytes(N) < N*bytes(1): the data terms scale,
        # the weight term does not. Strict — SMALL has nonzero weights.
        b1 = traffic.meshnet_megakernel_bytes(SMALL, (32, 32, 32), batch=1)
        b3 = traffic.meshnet_megakernel_bytes(SMALL, (32, 32, 32), batch=3)
        assert b1 < b3 < 3 * b1
