"""The N-volume batch axis, end to end (ISSUE: batched byte-model fix +
one-launch dispatch groups).

Three claims, each pinned here:

  1. **Numerics** — every backend treats the leading dim as independent
     volumes: member ``m`` of a batched forward equals the unbatched
     forward of volume ``m`` (bit-exact for fp32 xla; <= 1e-4 for the
     Pallas backends and reduced precisions). The grid/vmap mechanics of
     batching must be invisible to accuracy.
  2. **Traffic** — the byte models stream each weight tensor ONCE per
     launch (batch loop innermost), so ``bytes(N) < N * bytes(1)`` with
     a batch-invariant amortized weight term, and ``batch=1`` is
     byte-identical to the pre-batching models (the headline bugfix:
     ``Plan.hbm_bytes`` used to return ``batch * total``, double-counting
     the weight stream N times).
  3. **Serving** — under ``SchedulerConfig.batched_dispatch`` a dispatch
     group is ONE launch: admission prices the group with the weights
     charged once, every member shares the launch's service interval
     while ``queue_wait_s + service_s == finish - arrival`` still holds
     exactly per member, and batch-size-1 traces are unchanged.

The Pallas sweeps run in interpret mode on CPU, so the numeric matrix is
covered economically: fp32 xla runs the full model zoo x batch 1/2/4;
the Pallas backends run every model at batch 4 with the precision
rotating through {fp32, bf16, int8w} across the zoo (every cell of the
backend x precision matrix is exercised without running the full cross
product per model), plus an all-precision batch-1/2 pass on one model.
"""

import math

import jax
import numpy as np
import pytest

from repro.core import executors, meshnet
from repro.core.meshnet import PAPER_MODELS, MeshNetConfig
from repro.core.pipeline import PipelineConfig
from repro.core.spatial_shard import (
    ShardGeometryError,
    auto_batch_shards,
    mesh_for_batched,
)
from repro.kernels import megakernel, quantize
from repro.serving.engine import SegmentationEngine
from repro.serving.scheduler import RequestScheduler, SchedulerConfig
from repro.serving.simulator import (
    ServiceModel,
    VirtualClock,
    preset,
    reference_engine,
    simulate,
)
from repro.telemetry import traffic

KEY = jax.random.PRNGKey(0)
PRECS = ("fp32", "bf16", "int8w")
MODEL_NAMES = tuple(sorted(PAPER_MODELS))
#: rotate the precision through the zoo so every (backend, precision)
#: cell runs without the full per-model cross product
PALLAS_CASES = [(n, PRECS[i % len(PRECS)]) for i, n in enumerate(MODEL_NAMES)]
SHAPE = (8, 8, 8)


def _batched_vs_solo(backend, cfg, prec, batch, atol):
    p = meshnet.init(KEY, cfg)
    xb = jax.random.normal(jax.random.PRNGKey(1), (batch,) + SHAPE)
    yb = np.asarray(executors.apply(backend, p, xb, cfg, precision=prec))
    assert yb.shape == (batch,) + SHAPE + (cfg.num_classes,)
    for m in range(batch):
        ys = np.asarray(
            executors.apply(backend, p, xb[m : m + 1], cfg, precision=prec)
        )[0]
        if atol == 0.0:
            assert np.array_equal(yb[m], ys), f"member {m} not bit-exact"
        else:
            np.testing.assert_allclose(
                np.asarray(yb[m], np.float32),
                np.asarray(ys, np.float32),
                atol=atol,
                err_msg=f"member {m}",
            )


class TestBatchedParity:
    """Member m of a batched forward == the unbatched forward of volume m."""

    @pytest.mark.parametrize("prec", PRECS)
    @pytest.mark.parametrize("name", MODEL_NAMES)
    def test_xla_members_match_solo(self, name, prec):
        # fp32 is bit-exact (batch is a parallel axis, not a reduction);
        # reduced precisions round per element, same tolerance as the
        # backend-parity suite
        atol = 0.0 if prec == "fp32" else 1e-4
        for batch in (1, 2, 4):
            _batched_vs_solo("xla", PAPER_MODELS[name], prec, batch, atol)

    @pytest.mark.parametrize("backend", ("pallas_fused", "pallas_megakernel"))
    @pytest.mark.parametrize("name,prec", PALLAS_CASES)
    def test_pallas_batch4_members_match_solo(self, backend, name, prec):
        _batched_vs_solo(backend, PAPER_MODELS[name], prec, 4, 1e-4)

    @pytest.mark.parametrize("backend", ("pallas_fused", "pallas_megakernel"))
    @pytest.mark.parametrize("prec", PRECS)
    def test_pallas_small_batches_match_solo(self, backend, prec):
        cfg = PAPER_MODELS["gwm_light"]
        for batch in (1, 2):
            _batched_vs_solo(backend, cfg, prec, batch, 1e-4)

    @pytest.mark.skipif(
        jax.device_count() < 2,
        reason="sharded parity is a multi-device claim; CI's distributed "
        "job forces 8 host devices",
    )
    @pytest.mark.parametrize("prec", PRECS)
    def test_sharded_members_match_solo(self, prec):
        cfg = PAPER_MODELS["gwm_light"]
        n = 2
        name = executors.ensure_sharded("xla", n)
        p = meshnet.init(KEY, cfg)
        for batch in (1, 2, 4):
            xb = jax.random.normal(jax.random.PRNGKey(1), (batch, 16, 8, 8))
            yb = np.asarray(executors.apply(name, p, xb, cfg, precision=prec))
            for m in range(batch):
                ys = np.asarray(
                    executors.apply(name, p, xb[m : m + 1], cfg, precision=prec)
                )[0]
                np.testing.assert_allclose(
                    np.asarray(yb[m], np.float32),
                    np.asarray(ys, np.float32),
                    atol=1e-4 if prec != "fp32" else 1e-6,
                )


class TestBatchGeometry:
    """The (batch, Z) mesh helpers are pure geometry — testable anywhere."""

    def test_auto_batch_shards_single_device_host_is_legacy(self):
        # no spare devices -> no batch axis -> the legacy 1-D layout
        assert auto_batch_shards(4, jax.device_count()) == 1

    def test_auto_batch_shards_divides_batch(self):
        # auto sharding must pick a divisor of the batch (non-divisors
        # would need padding the executor contract does not allow)
        for batch in (1, 2, 3, 4, 6, 8):
            k = auto_batch_shards(batch, 1)
            assert batch % k == 0

    def test_mesh_for_batched_rejects_oversubscription(self):
        with pytest.raises(ShardGeometryError):
            mesh_for_batched(jax.device_count() + 1, 1)

    @pytest.mark.skipif(
        jax.device_count() < 4, reason="needs >= 4 devices for a 2x2 mesh"
    )
    def test_mesh_for_batched_axes(self):
        m = mesh_for_batched(2, 2)
        assert m.devices.shape == (2, 2)
        assert m.axis_names == ("b", "z")


class TestBatchedTraffic:
    """bytes(N) < N*bytes(1): the weight stream amortizes; data does not."""

    MODELS = {
        "xla": traffic.meshnet_xla_bytes,
        "pallas_fused": traffic.meshnet_fused_bytes,
        "views": traffic.meshnet_views_bytes,
        "streaming": traffic.meshnet_streaming_bytes,
        "pallas_megakernel": traffic.meshnet_megakernel_bytes,
    }

    @pytest.mark.parametrize("backend", sorted(MODELS))
    @pytest.mark.parametrize("name", MODEL_NAMES)
    def test_subadditive_with_batch_invariant_weight_term(self, backend, name):
        fn = self.MODELS[backend]
        cfg = PAPER_MODELS[name]
        b1 = fn(cfg, (32, 32, 32))
        b2 = fn(cfg, (32, 32, 32), batch=2)
        b4 = fn(cfg, (32, 32, 32), batch=4)
        # strict: every paper model has a nonzero weight stream (equality
        # could only occur for a zero-parameter network)
        assert b1 < b2 < 2 * b1
        assert b2 < b4 < 4 * b1
        # bytes(N) = N*data + weights  =>  N*b1 - bN == (N-1)*weights:
        # the amortized weight term must be the SAME whichever batch
        # size you solve it from — the models agree on what amortized
        w2 = 2 * b1 - b2
        w4 = (4 * b1 - b4) / 3
        assert w2 == pytest.approx(w4)
        assert w2 > 0

    @pytest.mark.parametrize("name", MODEL_NAMES)
    def test_megakernel_batch4_strictly_cheaper_than_4x(self, name):
        # the acceptance criterion verbatim: batch-4 megakernel modeled
        # bytes strictly below 4x batch-1 for every paper model
        cfg = PAPER_MODELS[name]
        b1 = traffic.meshnet_megakernel_bytes(cfg, (256, 256, 256), batch=1)
        b4 = traffic.meshnet_megakernel_bytes(cfg, (256, 256, 256), batch=4)
        assert b4 < 4 * b1

    def test_sharded_inherits_amortization(self):
        cfg = PAPER_MODELS["gwm_light"]
        b1 = traffic.meshnet_sharded_bytes("xla", cfg, (256, 256, 256), 4)
        b4 = traffic.meshnet_sharded_bytes("xla", cfg, (256, 256, 256), 4, batch=4)
        assert b1 < b4 < 4 * b1

    def test_plan_hbm_bytes_batch1_identity(self):
        # the headline bugfix regression: hbm_bytes(batch=1) must equal
        # the committed single-volume number (BENCH batch-1 rows are
        # byte-identical), and the traffic facade must agree with the plan
        cfg = PAPER_MODELS["gwm_light"]
        pln = megakernel.plan_for_config(cfg, (256, 256, 256))
        assert pln.hbm_bytes() == pln.hbm_bytes(batch=1)
        assert pln.hbm_bytes(batch=1) == traffic.meshnet_megakernel_bytes(
            cfg, (256, 256, 256)
        )


class TestBatchedPlanner:
    """The DP co-optimizes tile shape against batch size under VMEM."""

    def test_vmem_constrained_plan_trades_tiles_not_refusal(self):
        # a budget tight enough to force small tiles must still plan at
        # batch 4: the grid iterates one (batch element, tile) at a time,
        # so feasibility is batch-independent — the planner trades tile
        # shape, it never refuses a batch it could serve serially
        cfg = PAPER_MODELS["gwm_light"]
        vol = (64, 64, 64)
        tight = 2 * 1024 * 1024  # a third of the 64^3 default-budget plan
        p1 = megakernel.plan(
            cfg.dilations, 1, cfg.channels, cfg.num_classes, vol,
            vmem_budget=tight, batch=1,
        )
        p4 = megakernel.plan(
            cfg.dilations, 1, cfg.channels, cfg.num_classes, vol,
            vmem_budget=tight, batch=4,
        )
        assert p1.segments and p4.segments

    @pytest.mark.parametrize("name", ("gwm_light", "atlas_50"))
    def test_batch_aware_plan_never_worse(self, name):
        # pricing the batch-1 plan at batch=4 bounds the co-optimized
        # plan from above: the DP that SAW the batch can only do better
        cfg = PAPER_MODELS[name]
        vol = (128, 128, 128)
        base = megakernel.plan_for_config(cfg, vol)
        opt = megakernel.plan_for_config(cfg, vol, batch=4)
        assert opt.hbm_bytes(batch=4) <= base.hbm_bytes(batch=4)


def _mk_engine():
    cfg = MeshNetConfig(dilations=(1, 2, 4), channels=5)
    params = meshnet.init(KEY, cfg)
    pc = PipelineConfig(
        model=cfg, volume_shape=(16, 16, 16), cube=8, overlap=4,
        min_component_size=4, executor="xla",
    )
    return SegmentationEngine(params, pc)


def _mk_sched(batched, **cfg_kwargs):
    cfg_kwargs.setdefault("native_shapes", True)
    return RequestScheduler(
        _mk_engine(),
        SchedulerConfig(batched_dispatch=batched, **cfg_kwargs),
        clock=VirtualClock(),
        service_model=ServiceModel(),
        execute=False,
    )


def _stub(shape=(16, 16, 16)):
    return np.zeros(shape, np.float32)


class TestBatchedDispatch:
    """A dispatch group under batched_dispatch is ONE launch."""

    def test_group_admission_prices_weights_once(self):
        # cap sits between the batched-group price (3*work + weights)
        # and the per-member sum (3*(work + weights)): the old summing
        # admission would stop growing the group at two members; pricing
        # the group as one launch fits all three
        sched = _mk_sched(True, max_batch_requests=8)
        for _ in range(3):
            sched.submit(_stub(), arrival_s=0.0)
        per = sched.queue[0].bytes_priced
        w = sched._group_weight_bytes(sched.queue[0].key)
        assert w == quantize.model_params_bytes(sched.engine.cfg.model, "fp32")
        cap = 3 * per - 2 * w + 1  # group price + 1, below the member sum
        assert cap < 3 * per
        sched.cfg.admission_hbm_bytes = cap
        batch = sched.next_batch(now=0.0)
        assert len(batch.requests) == 3

    def test_serialized_admission_would_have_shed(self):
        # the same cap WITHOUT group pricing (weights summed per member)
        # only fits two — the contrast that makes the fix observable
        sched = _mk_sched(True, max_batch_requests=8)
        for _ in range(3):
            sched.submit(_stub(), arrival_s=0.0)
        per = sched.queue[0].bytes_priced
        w = sched._group_weight_bytes(sched.queue[0].key)
        sched.cfg.admission_hbm_bytes = 3 * per - 2 * w + 1
        sched.cfg.batched_dispatch = False  # re-run growth with summing
        batch = sched.next_batch(now=0.0)
        assert len(batch.requests) < 3

    def test_members_share_launch_interval_and_identity_holds(self):
        sched = _mk_sched(True, max_batch_requests=8)
        arrivals = (0.0, 0.1, 0.2)
        for a in arrivals:
            sched.submit(_stub(), arrival_s=a)
        batch = sched.next_batch(now=0.5)
        assert len(batch.requests) == 3
        finish = sched.run_batch(batch, now=0.5)
        comps = sched.completions
        assert len(comps) == 3
        services = {c.record.service_s for c in comps}
        assert len(services) == 1, "members must share the launch interval"
        for c in comps:
            assert c.finish_s == finish
            assert c.record.batch_size == 3
            # the SLO identity, exactly, per member
            assert c.record.queue_wait_s + c.record.service_s == pytest.approx(
                c.finish_s - c.arrival_s, abs=1e-12
            )

    def test_launch_service_beats_serialized_sum(self):
        # the throughput cliff mechanism: one batch-3 launch's interval
        # is under the 3 serialized intervals because the weight stream
        # amortizes in the byte model feeding ServiceModel
        def run(batched):
            sched = _mk_sched(batched, max_batch_requests=8)
            for _ in range(3):
                sched.submit(_stub(), arrival_s=0.0)
            b = sched.next_batch(now=0.0)
            assert len(b.requests) == 3
            return sched.run_batch(b, now=0.0)

        assert run(True) < run(False)

    def test_batch_size_one_traces_unchanged(self):
        # max_batch_requests=1 forces singleton groups: the batched
        # branch never takes (len > 1 required), so every percentile in
        # the class summary must be identical with the flag on or off
        def summary(batched):
            cfg = preset("steady", seed=3, horizon_s=120.0)
            cfg.scheduler.max_batch_requests = 1
            cfg.scheduler.batched_dispatch = batched
            return simulate(reference_engine(), cfg).summary()

        a, b = summary(False), summary(True)
        assert a["classes"] == b["classes"]
        assert a["latency_ms"] == b["latency_ms"]

    def test_overload_batched_conserves_and_moves_the_cliff(self):
        # the BENCH acceptance in miniature: same seed/trace, batching
        # on, conservation exact and the overload p99 no worse
        base = simulate(
            reference_engine(), preset("overload", seed=0, horizon_s=150.0)
        ).summary()
        bat = simulate(
            reference_engine(), preset("overload_batched", seed=0, horizon_s=150.0)
        ).summary()
        assert bat["requests"]["conserved"]
        assert bat["latency_ms"]["p99"] <= base["latency_ms"]["p99"]

    def test_execute_true_keeps_serial_members(self):
        # real execution has no batched forward in the engine pipeline
        # (conform/postprocess are per-volume): the flag must not change
        # results, only the modeled path — waits still strictly increase
        sched = RequestScheduler(
            _mk_engine(),
            SchedulerConfig(batched_dispatch=True, native_shapes=True,
                            max_batch_requests=4),
            clock=VirtualClock(),
            execute=True,
        )
        rng = np.random.default_rng(0)
        for a in (0.0, 0.0, 0.0):
            sched.submit(rng.random((16, 16, 16), dtype=np.float32), arrival_s=a)
        batch = sched.next_batch(now=0.0)
        sched.run_batch(batch)
        comps = sched.completions
        assert len(comps) == 3
        assert all(c.record.status == "ok" for c in comps)
