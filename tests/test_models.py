"""Architecture-zoo tests: per-arch smoke (reduced config, one forward +
one train step), decode-vs-forward equivalence, flash attention vs oracle,
and family-specific invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers as L
from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.models.flash import flash_attention
from repro.training import losses
from repro.training import optimizer as opt_mod

KEY = jax.random.PRNGKey(0)


def _batch(cfg: ModelConfig, B=2, T=16, with_labels=False):
    batch = {"tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = jax.random.normal(KEY, (B, cfg.num_patches, cfg.d_model), cfg.dtype)
    if cfg.kind == "encdec":
        batch["frames"] = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if with_labels:
        batch["labels"] = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = configs.get_smoke(arch)
        params = MD.init(KEY, cfg)
        batch = _batch(cfg)
        logits, aux = MD.forward(params, batch, cfg)
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        assert bool(jnp.isfinite(aux))

    def test_one_train_step_reduces_loss_structurally(self, arch):
        """One AdamW step runs, produces finite loss/grads and changes params."""
        from repro.launch import steps as steps_mod

        cfg = dataclasses.replace(configs.get_smoke(arch), dtype=jnp.float32)
        params = MD.init(KEY, cfg)
        opt_state = opt_mod.adamw_init(params, steps_mod.OPT_CONFIG)
        step = steps_mod.make_train_step(cfg)
        batch = _batch(cfg, with_labels=True)
        new_params, new_opt, metrics = jax.jit(step)(params, opt_state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert int(new_opt.step) == 1
        # at least one leaf moved
        moved = any(
            float(jnp.abs(a - b).max()) > 0
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
        )
        assert moved

    def test_decode_matches_forward(self, arch):
        cfg = dataclasses.replace(
            configs.get_smoke(arch), dtype=jnp.float32, moe_capacity_factor=8.0
        )
        params = MD.init(KEY, cfg)
        B, T = 2, 10
        batch = _batch(cfg, B=B, T=T)
        full_logits, _ = MD.forward(params, batch, cfg)
        cache = MD.init_cache(cfg, B, T)
        if cfg.kind == "encdec":
            enc = MD.encode(params, batch["frames"], cfg)
            cache = MD.fill_cross_cache(params, cache, enc, cfg)
        if cfg.frontend == "vision_stub":
            pytest.skip("decode equivalence needs the patch prefix prefilled")
        errs = []
        for t in range(T):
            dl, cache = MD.decode_step(
                params, batch["tokens"][:, t : t + 1], cache, jnp.asarray(t, jnp.int32), cfg
            )
            errs.append(float(jnp.abs(dl[:, 0] - full_logits[:, t]).max()))
        assert max(errs) < 1e-3, errs


class TestFlashAttention:
    @pytest.mark.parametrize("causal,window", [(True, None), (True, 64), (False, None)])
    def test_forward_matches_naive(self, causal, window):
        B, T, H, hd = 2, 200, 4, 32
        q = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, hd))
        k = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, hd))
        v = jax.random.normal(jax.random.PRNGKey(3), (B, T, H, hd))
        ref = L.sdpa(q, k, v, causal=causal, sliding_window=window)
        out = flash_attention(q, k, v, causal, window, 64, 96)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=2e-5)

    def test_gradients_match_naive(self):
        B, T, H, hd = 1, 130, 2, 16
        q = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, hd))
        k = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, hd))
        v = jax.random.normal(jax.random.PRNGKey(3), (B, T, H, hd))
        f_ref = lambda *a: (L.sdpa(*a, causal=True) ** 2).sum()
        f_fl = lambda *a: (flash_attention(*a, True, None, 32, 64).astype(jnp.float32) ** 2).sum()
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        gf = jax.grad(f_fl, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gr, gf):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)

    def test_rectangular_kv(self):
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 50, 2, 16))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 170, 2, 16))
        v = jax.random.normal(jax.random.PRNGKey(3), (1, 170, 2, 16))
        ref = L.sdpa(q, k, v, causal=False)
        out = flash_attention(q, k, v, False, None, 32, 64)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=2e-5)


class TestFamilyInvariants:
    def test_gqa_repeat_kv(self):
        k = jax.random.normal(KEY, (2, 8, 2, 16))
        out = L._repeat_kv(k, 8)
        assert out.shape == (2, 8, 8, 16)
        np.testing.assert_array_equal(np.asarray(out[:, :, 0]), np.asarray(out[:, :, 3]))

    def test_rope_relative_position_property(self):
        """RoPE: <q_i, k_j> depends only on i - j."""
        hd = 32
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, hd))
        def dot_at(qi, kj):
            qr = L.apply_rope(q, jnp.asarray([qi]), 10_000.0)
            kr = L.apply_rope(k, jnp.asarray([kj]), 10_000.0)
            return float(jnp.sum(qr * kr))
        assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-4
        assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6  # but not position-free

    def test_moe_aux_loss_balanced_routing(self):
        """With uniform router probs the load-balance loss sits at its
        minimum, top_k (Σ_e me·ce·E = E·(1/E)·k); a collapsed router that
        sends everything to expert 0 scores ~ E·k·(1/k) = E x worse."""
        cfg = configs.get_smoke("kimi-k2-1t-a32b")
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)
        p = L.init_moe(KEY, cfg)
        # positive activations so a +100 router column really is a collapse
        x = jnp.abs(jax.random.normal(KEY, (2, 32, cfg.d_model)))
        p_uniform = dict(p, router=jnp.zeros_like(p["router"]))
        _, aux_u = L.moe(p_uniform, x, cfg)
        collapse = jnp.zeros_like(p["router"]).at[:, 0].set(100.0)
        _, aux_c = L.moe(dict(p, router=collapse), x, cfg)
        assert abs(float(aux_u) - cfg.top_k) < 0.2, aux_u
        assert float(aux_c) > float(aux_u) * 1.5

    def test_moe_capacity_drops_tokens(self):
        cfg = dataclasses.replace(
            configs.get_smoke("grok-1-314b"), dtype=jnp.float32, moe_capacity_factor=0.25
        )
        p = L.init_moe(KEY, cfg)
        x = jax.random.normal(KEY, (1, 32, cfg.d_model))
        out_small, _ = L.moe(p, x, cfg)
        out_big, _ = L.moe(p, x, cfg, capacity_factor=8.0)
        assert float(jnp.abs(out_small - out_big).max()) > 1e-6

    def test_rwkv_state_decay_bounded(self):
        cfg = dataclasses.replace(configs.get_smoke("rwkv6-3b"), dtype=jnp.float32)
        from repro.models import rwkv6 as R

        p = R.init_rwkv(KEY, cfg)
        x = jax.random.normal(KEY, (1, 8, cfg.d_model))
        xs = R._token_shift(x)
        _, _, _, _, w = R._projections(p, x, xs, cfg)
        assert float(w.min()) > 0.0 and float(w.max()) < 1.0

    def test_mamba_decode_matches_forward(self):
        cfg = dataclasses.replace(configs.get_smoke("jamba-1.5-large-398b"), dtype=jnp.float32)
        from repro.models import mamba as M

        p = M.init_mamba(KEY, cfg)
        x = jax.random.normal(KEY, (2, 9, cfg.d_model))
        full = M.mamba_forward(p, x, cfg)
        state = M.init_mamba_state(cfg, 2)
        outs = []
        for t in range(9):
            o, state = M.mamba_decode(p, x[:, t : t + 1], state, cfg)
            outs.append(o)
        step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(step), atol=1e-4)

    def test_sliding_window_ring_cache(self):
        cfg = dataclasses.replace(
            configs.get_smoke("tinyllama-1.1b"), dtype=jnp.float32, sliding_window=6
        )
        params = MD.init(KEY, cfg)
        B, T = 2, 14
        batch = {"tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)}
        full_logits, _ = MD.forward(params, batch, cfg)
        cache = MD.init_cache(cfg, B, T)
        assert cache[0]["k"].shape[2] == 6  # ring buffer = window size
        errs = []
        for t in range(T):
            dl, cache = MD.decode_step(
                params, batch["tokens"][:, t : t + 1], cache, jnp.asarray(t, jnp.int32), cfg
            )
            errs.append(float(jnp.abs(dl[:, 0] - full_logits[:, t]).max()))
        assert max(errs) < 1e-3

    def test_int8_kv_cache_decode(self):
        """Beyond-paper H8: int8 KV cache halves cache bytes with near-exact
        decode (argmax-identical on the smoke model)."""
        cfg = dataclasses.replace(configs.get_smoke("qwen1.5-32b"), dtype=jnp.float32)
        cfg_q = dataclasses.replace(cfg, kv_quant=True)
        params = MD.init(KEY, cfg)
        B, T = 2, 10
        batch = {"tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)}
        full, _ = MD.forward(params, batch, cfg)
        cache = MD.init_cache(cfg_q, B, T)
        assert cache[0]["k"].dtype == jnp.int8
        errs, agree = [], []
        for t in range(T):
            dl, cache = MD.decode_step(
                params, batch["tokens"][:, t : t + 1], cache, jnp.asarray(t, jnp.int32), cfg_q
            )
            errs.append(float(jnp.abs(dl[:, 0] - full[:, t]).max()))
            agree.append(bool((jnp.argmax(dl[:, 0], -1) == jnp.argmax(full[:, t], -1)).all()))
        assert max(errs) < 0.5, errs  # small logit perturbation
        assert all(agree)  # greedy decode unchanged

    def test_param_counts_sane(self):
        # full configs: param_counts() total must land near the named scale
        expect = {
            "tinyllama-1.1b": (0.9e9, 1.4e9),
            "gemma-7b": (7e9, 10e9),
            "grok-1-314b": (250e9, 380e9),
            "kimi-k2-1t-a32b": (0.7e12, 1.3e12),
        }
        for arch, (lo, hi) in expect.items():
            n = configs.get(arch).param_counts()["total"]
            assert lo <= n <= hi, (arch, n)


class TestServingEngine:
    def test_batched_engine_matches_manual_greedy(self):
        from repro.serving.engine import LMEngine, Request

        cfg = dataclasses.replace(configs.get_smoke("tinyllama-1.1b"), dtype=jnp.float32)
        params = MD.init(KEY, cfg)
        eng = LMEngine(params, cfg, slots=2, max_seq=48, prefill_chunk=4)
        reqs = [Request(prompt=[1, 2, 3, 4, 5], max_new_tokens=5, id=i) for i in range(3)]
        outs = eng.run(reqs)
        assert len(outs) == 3
        assert outs[0].tokens == outs[1].tokens == outs[2].tokens  # same prompt
        # manual reference
        cache = MD.init_cache(cfg, 1, 48)
        pos = 0
        for t in [1, 2, 3, 4]:
            _, cache = MD.decode_step(params, jnp.asarray([[t]], jnp.int32), cache, jnp.asarray(pos, jnp.int32), cfg)
            pos += 1
        cur, manual = 5, []
        for _ in range(5):
            lg, cache = MD.decode_step(params, jnp.asarray([[cur]], jnp.int32), cache, jnp.asarray(pos, jnp.int32), cfg)
            cur = int(jnp.argmax(lg[0, -1]))
            manual.append(cur)
            pos += 1
        assert outs[0].tokens == manual
