"""Sharded executor family: parity with the single-device inner backends.

The contract (DESIGN.md §2.2, EXPERIMENTS.md §Perf H10): for every model in
the zoo, ``sharded_<inner>`` output equals the single-device ``<inner>``
executor to <=1e-4 at 2, 4 and 8 Z-slabs — including slabs *thinner than
the receptive-field radius* (46), where the halo exchange goes multi-hop
through several neighbours — so slab count is purely a throughput decision.

The module runs in-process on whatever devices the host exposes: the CI
``distributed`` job forces ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(and ``REPRO_SMALL_SHAPES=1`` to keep interpret-mode Pallas tolerable);
on single-device hosts it skips, like tests/test_distributed.py — the
claims under test are multi-device claims.

Parity params are perturbed (non-zero conv bias, non-trivial BN stats) on
purpose: with zero biases, out-of-volume activations stay zero for free
and pod-edge masking bugs are invisible.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executors, meshnet, pipeline
from repro.core.meshnet import MeshNetConfig, PAPER_MODELS
from repro.core.pipeline import PipelineConfig
from repro.serving.engine import SegmentationEngine

pytestmark = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="sharded-executor parity is a multi-device claim; CI runs it "
    "under forced host devices (EXPERIMENTS.md H10)",
)

#: CI knob: small spatial shapes so interpret-mode Pallas stays tolerable.
SMALL = os.environ.get("REPRO_SMALL_SHAPES") == "1"

# D divides 2/4/8 and is < 2*RF, so 8 slabs are far thinner than the
# 46-voxel RF radius — every sweep exercises the multi-hop halo path.
VOL = (32, 8, 8) if SMALL else (64, 16, 16)

KEY = jax.random.PRNGKey(7)


def _slab_counts():
    n = jax.device_count()
    return [s for s in (2, 4, 8) if s <= n and VOL[0] % s == 0]


def _perturbed_params(cfg: MeshNetConfig, seed: int = 3):
    """init() + non-zero biases and BN stats, so per-layer zero masking at
    pod edges is load-bearing (conv(0) != 0 after bias/BN/ReLU)."""
    p = meshnet.init(KEY, cfg)
    k = jax.random.PRNGKey(seed)
    for layer in p["layers"]:
        k, k1, k2, k3 = jax.random.split(k, 4)
        layer["b"] = jax.random.normal(k1, layer["b"].shape) * 0.1
        if cfg.use_batchnorm:
            layer["bn_mean"] = jax.random.normal(k2, layer["bn_mean"].shape) * 0.3
            layer["bn_var"] = 0.5 + jax.random.uniform(k3, layer["bn_var"].shape)
            layer["bn_bias"] = jax.random.normal(k1, layer["bn_bias"].shape) * 0.1
    return p


def _parity(inner: str, cfg: MeshNetConfig, slabs, atol=1e-4, seed=5):
    p = _perturbed_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1,) + VOL)
    ref = np.asarray(executors.apply(inner, p, x, cfg))
    radius = sum(cfg.dilations)
    for n in slabs:
        got = executors.apply(executors.ensure_sharded(inner, n), p, x, cfg)
        np.testing.assert_allclose(
            np.asarray(got), ref, atol=atol,
            err_msg=f"sharded_{inner}@{n} vs {inner}, slab={VOL[0] // n} "
            f"(RF radius {radius})",
        )


class TestShardedParity:
    @pytest.mark.parametrize("name", sorted(PAPER_MODELS))
    def test_xla_inner_all_paper_models(self, name):
        """Every PAPER_MODELS config through the layer-wise halo-exchange
        wrapper, at every slab count — the canonical inner is cheap enough
        to sweep the whole zoo."""
        _parity("xla", PAPER_MODELS[name], _slab_counts())

    def test_fused_inner(self):
        """Per-layer fused Pallas inner (interpret mode on CPU hosts)."""
        _parity("pallas_fused", PAPER_MODELS["gwm_light"], _slab_counts())

    def test_megakernel_inner(self):
        """One-shot RF-radius fetch + depth-first megakernel planned on
        the slab+halo window, at every slab count."""
        _parity("pallas_megakernel", PAPER_MODELS["gwm_light"], _slab_counts())

    def test_megakernel_inner_wide_channels(self):
        """The 21-channel failsafe model: multi-segment plans on the
        slab+halo window (the VMEM budget forces segmentation)."""
        _parity("pallas_megakernel", PAPER_MODELS["subvolume_gwm_failsafe"], [2])

    def test_megakernel_inner_no_batchnorm(self):
        _parity("pallas_megakernel", MeshNetConfig(use_batchnorm=False), [2])

    def test_thin_slab_is_multi_hop(self):
        """The max slab count leaves slabs thinner than the RF radius (and
        thinner than the widest per-layer halo), so the parity sweeps above
        genuinely cross several neighbours per exchange."""
        n = max(_slab_counts())
        cfg = PAPER_MODELS["gwm_light"]
        assert VOL[0] // n < sum(cfg.dilations)
        assert VOL[0] // n < max(cfg.dilations) or n < 8


class TestShardedDispatch:
    def _setup(self, executor, **kw):
        cfg = PAPER_MODELS["gwm_light"]
        params = _perturbed_params(cfg)
        vol = jax.random.normal(KEY, VOL)
        pc = PipelineConfig(
            model=cfg, volume_shape=VOL, mode="full", min_component_size=4,
            executor=executor, **kw,
        )
        return pc, params, vol

    def test_pipeline_full_mode_records_collective_bytes(self):
        pc, params, vol = self._setup("sharded_xla@2")
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "ok", res.record.fail_type
        assert res.record.executor == "sharded_xla@2"
        assert res.record.hbm_bytes_modeled > 0
        assert res.record.collective_bytes_modeled > 0
        # sharded == single-device, through the whole pipeline
        ref = pipeline.run(self._setup("xla")[0], params, vol)
        np.testing.assert_array_equal(
            np.asarray(res.segmentation), np.asarray(ref.segmentation)
        )

    def test_pipeline_shard_devices_wraps_resolved_executor(self):
        pc, params, vol = self._setup("xla", shard_devices=2)
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "ok", res.record.fail_type
        assert res.record.executor == "sharded_xla@2"

    def test_pipeline_subvolume_mode_sharded(self):
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        params = _perturbed_params(cfg)
        vol = jax.random.normal(KEY, (16, 16, 16))
        pc = PipelineConfig(
            model=cfg, volume_shape=(16, 16, 16), mode="subvolume",
            cube=8, overlap=4, min_component_size=4, executor="sharded_xla@2",
        )
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "ok", res.record.fail_type
        # per-cube collective bytes, times the number of cubes
        assert res.record.collective_bytes_modeled > 0

    def test_engine_per_request_device_override(self):
        cfg = PAPER_MODELS["gwm_light"]
        params = _perturbed_params(cfg)
        pc = PipelineConfig(model=cfg, volume_shape=VOL, min_component_size=4)
        engine = SegmentationEngine(params, pc, devices=2)
        vol = jax.random.normal(KEY, VOL)
        r_default = engine.submit(vol, mode="full", executor="xla")
        r_override = engine.submit(vol, mode="full", executor="xla", devices=1)
        assert r_default.record.executor == "sharded_xla@2"
        assert r_default.record.collective_bytes_modeled > 0
        assert r_override.record.executor == "xla"
        assert r_override.record.collective_bytes_modeled == 0
        np.testing.assert_array_equal(
            np.asarray(r_default.segmentation), np.asarray(r_override.segmentation)
        )

    def test_auto_prefers_sharded_megakernel_on_multidevice_tpu(self):
        """The "auto" policy (pinned backend/device introspection): sharded
        megakernel when >1 device and the per-slab plan fits; plain
        megakernel on one device; xla on CPU hosts."""
        cfg = PAPER_MODELS["gwm_light"]
        got = executors.default_executor(
            cfg, (256, 256, 256), backend="tpu", num_devices=4
        )
        assert got == "sharded_pallas_megakernel@4"
        # the introspected (unpinned) count keeps the unpinned name
        assert executors.sharded_name("pallas_megakernel") in executors.names()
        assert executors.default_executor(cfg, (256, 256, 256), backend="cpu") == "xla"
