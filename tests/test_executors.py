"""Executor registry: parity of the fused Pallas backend with the XLA
reference across the paper model zoo, plus pipeline/engine dispatch smoke
tests for every registered backend.

The parity contract is the whole point of the registry: every executor's
``apply(params, x, cfg)`` must equal ``meshnet.apply`` (eval mode) within
float tolerance, so mode/backend selection is purely a performance and
memory decision, never an accuracy one.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executors, meshnet, patching, pipeline
from repro.core.meshnet import MeshNetConfig, PAPER_MODELS
from repro.core.pipeline import PipelineConfig
from repro.data import mri
from repro.serving.engine import SegmentationEngine
from repro.telemetry.budget import MemoryBudget

KEY = jax.random.PRNGKey(11)

# Small odd (non-block-multiple) spatial shape: exercises the ops wrapper's
# pad-to-block + slice-back on every layer while keeping interpret-mode
# Pallas runtime tolerable on CPU.
ODD_SHAPE = (1, 10, 12, 14)

# A short-schedule config cheap enough for per-executor pipeline smokes.
SMALL = MeshNetConfig(dilations=(1, 2, 4))


def _parity(cfg: MeshNetConfig, shape=ODD_SHAPE, atol=2e-4, seed=3):
    p = meshnet.init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), shape)
    got = executors.apply("pallas_fused", p, x, cfg)
    expect = executors.apply("xla", p, x, cfg)
    assert got.shape == expect.shape == shape + (cfg.num_classes,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=atol)


class TestRegistry:
    def test_builtin_names(self):
        assert {"xla", "pallas_fused", "pallas_megakernel", "streaming"} <= set(
            executors.names()
        )

    def test_auto_resolves_to_registered_backend(self):
        assert executors.resolve("auto") in executors.names()
        assert executors.resolve(None) == executors.resolve("auto")

    def test_unknown_executor_raises(self):
        with pytest.raises(KeyError, match="unknown executor"):
            executors.resolve("webgl")
        # ...and the pipeline surfaces it as a config error, not a telemetry
        # 'fail' record (resolution happens before the budget-guarded region).
        with pytest.raises(KeyError, match="unknown executor"):
            pipeline.run(
                PipelineConfig(model=SMALL, volume_shape=(8, 8, 8), executor="webgl"),
                meshnet.init(KEY, SMALL),
                jnp.zeros((8, 8, 8)),
            )

    def test_default_executor_matches_backend(self):
        # Without a model to plan for: fused on TPU, xla on CPU hosts.
        want = "pallas_fused" if jax.default_backend() == "tpu" else "xla"
        assert executors.default_executor() == want
        # With a plannable model, a TPU host prefers the megakernel; CPU
        # hosts still serve with xla (interpret mode is a correctness path).
        cfg = MeshNetConfig()
        want = "pallas_megakernel" if jax.default_backend() == "tpu" else "xla"
        assert executors.default_executor(cfg, (256, 256, 256)) == want
        assert executors.resolve("auto", cfg, (256, 256, 256)) == want

    def test_modeled_hbm_bytes_none_for_unmodeled_backend(self):
        executors.register(
            executors.ExecutorSpec(
                name="_test_unmodeled",
                apply=executors._xla_apply,
                streaming_apply=executors._xla_apply,
            )
        )
        try:
            assert (
                executors.modeled_hbm_bytes("_test_unmodeled", SMALL, (8, 8, 8))
                is None
            )
        finally:
            executors._REGISTRY.pop("_test_unmodeled")

    def test_list_dilations_config_crosses_jit_boundary(self):
        # cfg is a static jit argument in jitted_apply; list dilations must
        # be normalised to a hashable tuple by MeshNetConfig.__post_init__.
        cfg = MeshNetConfig(dilations=[1, 2])
        assert cfg.dilations == (1, 2)
        p = meshnet.init(KEY, cfg)
        x = jax.random.normal(KEY, (1, 8, 8, 8))
        out = executors.jitted_apply("xla")(p, x, cfg)
        assert out.shape == (1, 8, 8, 8, cfg.num_classes)


class TestFusedParity:
    """ops.meshnet_apply == meshnet.apply (eval) across the model zoo."""

    @pytest.mark.parametrize("name", sorted(PAPER_MODELS))
    def test_paper_models(self, name):
        _parity(PAPER_MODELS[name])

    def test_no_batchnorm(self):
        _parity(MeshNetConfig(use_batchnorm=False))

    def test_nontrivial_bn_stats(self):
        # Fold-correctness is invisible with init stats (mean 0 / var 1):
        # perturb the running stats so the fused scale/offset path is real.
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        p = meshnet.init(KEY, cfg)
        k = jax.random.PRNGKey(5)
        for layer in p["layers"]:
            k, k1, k2 = jax.random.split(k, 3)
            layer["bn_mean"] = jax.random.normal(k1, layer["bn_mean"].shape) * 0.3
            layer["bn_var"] = 0.5 + jax.random.uniform(k2, layer["bn_var"].shape)
        x = jax.random.normal(jax.random.PRNGKey(6), ODD_SHAPE)
        got = executors.apply("pallas_fused", p, x, cfg)
        expect = executors.apply("xla", p, x, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=2e-4)

    @pytest.mark.parametrize("shape", [(1, 16, 16, 16), (2, 9, 17, 13)])
    def test_block_multiple_and_batched_odd(self, shape):
        _parity(MeshNetConfig(dilations=(1, 2, 4)), shape=shape)

    def test_streaming_executor_parity(self):
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        p = meshnet.init(KEY, cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), ODD_SHAPE)
        np.testing.assert_allclose(
            np.asarray(executors.apply("streaming", p, x, cfg)),
            np.asarray(executors.apply("xla", p, x, cfg)),
            atol=1e-4,
        )


class TestPipelineDispatch:
    def _setup(self):
        params = meshnet.init(KEY, SMALL)
        vol, _ = mri.generate(KEY, mri.SyntheticMRIConfig(shape=(16, 16, 16)))
        return params, vol

    @pytest.mark.parametrize(
        "executor", ["xla", "pallas_fused", "pallas_megakernel", "streaming"]
    )
    @pytest.mark.parametrize("mode", ["full", "subvolume", "streaming"])
    def test_all_modes_all_executors(self, mode, executor):
        params, vol = self._setup()
        pc = PipelineConfig(
            model=SMALL, volume_shape=(16, 16, 16), mode=mode, cube=8, overlap=4,
            min_component_size=4, executor=executor,
        )
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "ok", res.record.fail_type
        assert res.segmentation.shape == (16, 16, 16)
        assert res.record.executor == executor  # recorded in telemetry
        assert res.record.hbm_bytes_modeled > 0  # bytes-moved telemetry

    def test_executors_agree_on_segmentation(self):
        params, vol = self._setup()
        segs = {}
        for executor in ("xla", "pallas_fused", "pallas_megakernel"):
            pc = PipelineConfig(
                model=SMALL, volume_shape=(16, 16, 16), mode="full",
                min_component_size=4, executor=executor,
            )
            segs[executor] = np.asarray(pipeline.run(pc, params, vol).segmentation)
        np.testing.assert_array_equal(segs["xla"], segs["pallas_fused"])
        np.testing.assert_array_equal(segs["xla"], segs["pallas_megakernel"])

    def test_subvolume_executor_closure_matches_explicit_infer_fn(self):
        params, vol = self._setup()
        via_registry = patching.subvolume_inference(
            vol, params=params, model_cfg=SMALL, executor="xla", cube=8, overlap=7
        )
        via_closure = patching.subvolume_inference(
            vol, jax.jit(lambda c: meshnet.apply(params, c, SMALL)), cube=8, overlap=7
        )
        np.testing.assert_allclose(
            np.asarray(via_registry), np.asarray(via_closure), atol=1e-6
        )

    def test_subvolume_requires_model_or_fn(self):
        with pytest.raises(ValueError, match="infer_fn"):
            patching.subvolume_inference(jnp.zeros((8, 8, 8)), cube=4)


class TestEngineDispatch:
    def _engine(self):
        params = meshnet.init(KEY, SMALL)
        pc = PipelineConfig(
            model=SMALL, volume_shape=(16, 16, 16), cube=8, overlap=4,
            min_component_size=4,
        )
        # Tight budget: streaming fits, the naive full graph would not.
        engine = SegmentationEngine(
            params, pc, budget=MemoryBudget(8 * 1024 * 1024, name="tight")
        )
        return engine

    def test_submit_many_records_mode_and_executor(self):
        engine = self._engine()
        vols = [
            mri.generate(jax.random.PRNGKey(i), mri.SyntheticMRIConfig(shape=(16, 16, 16)))[0]
            for i in range(3)
        ]
        results = engine.submit_many(
            vols,
            modes=[None, "subvolume", None],
            executors=[None, "xla", "streaming"],
        )
        assert len(results) == len(engine.log.records) == 3
        # results come back in submission order with telemetry attribution
        for i, res in enumerate(results):
            assert res.record.status == "ok"
            assert res.record.extra["request_index"] == i
            assert res.record.executor in executors.names()
        assert results[1].record.mode == "subvolume"
        assert results[2].record.executor == "streaming"
        # default requests keep the budget-driven failsafe selection
        assert results[0].record.mode == engine.pick_mode((16, 16, 16))

    def test_submit_many_length_mismatch(self):
        engine = self._engine()
        with pytest.raises(ValueError, match="must match"):
            engine.submit_many([jnp.zeros((16, 16, 16))], modes=["full", "full"])
