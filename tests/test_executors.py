"""Executor registry: parity of the fused Pallas backend with the XLA
reference across the paper model zoo, plus pipeline/engine dispatch smoke
tests for every registered backend.

The parity contract is the whole point of the registry: every executor's
``apply(params, x, cfg)`` must equal ``meshnet.apply`` (eval mode) within
float tolerance, so mode/backend selection is purely a performance and
memory decision, never an accuracy one.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executors, meshnet, patching, pipeline
from repro.core.meshnet import MeshNetConfig, PAPER_MODELS
from repro.core.pipeline import PipelineConfig
from repro.data import mri
from repro.serving.engine import SegmentationEngine
from repro.telemetry.budget import MemoryBudget

KEY = jax.random.PRNGKey(11)

# Small odd (non-block-multiple) spatial shape: exercises the ops wrapper's
# pad-to-block + slice-back on every layer while keeping interpret-mode
# Pallas runtime tolerable on CPU.
ODD_SHAPE = (1, 10, 12, 14)

# A short-schedule config cheap enough for per-executor pipeline smokes.
SMALL = MeshNetConfig(dilations=(1, 2, 4))


def _parity(cfg: MeshNetConfig, shape=ODD_SHAPE, atol=2e-4, seed=3):
    p = meshnet.init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), shape)
    got = executors.apply("pallas_fused", p, x, cfg)
    expect = executors.apply("xla", p, x, cfg)
    assert got.shape == expect.shape == shape + (cfg.num_classes,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=atol)


class TestRegistry:
    def test_builtin_names(self):
        assert {"xla", "pallas_fused", "pallas_megakernel", "streaming"} <= set(
            executors.names()
        )

    def test_auto_resolves_to_registered_backend(self):
        assert executors.resolve("auto") in executors.names()
        assert executors.resolve(None) == executors.resolve("auto")

    def test_unknown_executor_raises(self):
        with pytest.raises(KeyError, match="unknown executor"):
            executors.resolve("webgl")
        # ...and the pipeline surfaces it as a config error, not a telemetry
        # 'fail' record (resolution happens before the budget-guarded region).
        with pytest.raises(KeyError, match="unknown executor"):
            pipeline.run(
                PipelineConfig(model=SMALL, volume_shape=(8, 8, 8), executor="webgl"),
                meshnet.init(KEY, SMALL),
                jnp.zeros((8, 8, 8)),
            )

    def test_default_executor_matches_backend(self):
        # Without a model to plan for: fused on TPU, xla on CPU hosts.
        want = "pallas_fused" if jax.default_backend() == "tpu" else "xla"
        assert executors.default_executor() == want
        # With a plannable model, a TPU host prefers the megakernel; CPU
        # hosts still serve with xla (interpret mode is a correctness path).
        cfg = MeshNetConfig()
        want = "pallas_megakernel" if jax.default_backend() == "tpu" else "xla"
        assert executors.default_executor(cfg, (256, 256, 256)) == want
        assert executors.resolve("auto", cfg, (256, 256, 256)) == want

    def test_modeled_hbm_bytes_none_for_unmodeled_backend(self):
        executors.register(
            executors.ExecutorSpec(
                name="_test_unmodeled",
                apply=executors._xla_apply,
                streaming_apply=executors._xla_apply,
            )
        )
        try:
            assert (
                executors.modeled_hbm_bytes("_test_unmodeled", SMALL, (8, 8, 8))
                is None
            )
        finally:
            executors._REGISTRY.pop("_test_unmodeled")

    def test_sharded_family_registered(self):
        assert {
            "sharded_xla", "sharded_pallas_fused", "sharded_pallas_megakernel"
        } <= set(executors.names())

    def test_sharded_name_parse_roundtrip(self):
        assert executors.sharded_name("xla") == "sharded_xla"
        assert executors.sharded_name("pallas_fused", 4) == "sharded_pallas_fused@4"
        assert executors.parse_sharded("sharded_pallas_fused@4") == ("pallas_fused", 4)
        assert executors.parse_sharded("sharded_xla") == ("xla", None)
        assert executors.parse_sharded("xla") is None
        assert executors.inner_of("sharded_pallas_megakernel@8") == "pallas_megakernel"
        assert executors.inner_of("streaming") == "streaming"

    def test_pinned_sharded_name_registers_on_demand(self):
        # "@n" names are valid executor strings anywhere a name is accepted
        name = executors.resolve("sharded_xla@4")
        assert name == "sharded_xla@4" and name in executors.names()

    def test_unknown_sharded_inner_raises(self):
        with pytest.raises(KeyError, match="sharded inner"):
            executors.resolve("sharded_webgl")
        with pytest.raises(KeyError, match="cannot be sharded"):
            executors.ensure_sharded("streaming", 2)

    @pytest.mark.parametrize("bad", ["sharded_xla@two", "sharded_xla@0", "sharded_xla@-2"])
    def test_bad_sharded_slab_count_raises_keyerror(self, bad):
        with pytest.raises(KeyError, match="positive integer"):
            executors.resolve(bad)

    def test_sharded_auto_policy(self):
        # multi-device TPU with a plannable per-slab window -> sharded
        # megakernel (pinned to the validated count when the caller pins
        # one); indivisible Z falls back to the single-device ladder.
        cfg = MeshNetConfig()
        assert (
            executors.default_executor(cfg, (256, 256, 256), backend="tpu", num_devices=8)
            == "sharded_pallas_megakernel@8"
        )
        assert (
            executors.default_executor(cfg, (250, 256, 256), backend="tpu", num_devices=8)
            == "pallas_megakernel"
        )
        assert (
            executors.default_executor(cfg, (256, 256, 256), backend="tpu", num_devices=1)
            == "pallas_megakernel"
        )

    def test_sharded_modeled_bytes(self):
        # HBM: n x the inner model on the per-device window; collective:
        # zero at one slab, positive per extra boundary, zero for
        # single-device backends. Pure models — no devices needed.
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        vol = (32, 16, 16)
        assert executors.modeled_collective_bytes("xla", cfg, vol) == 0
        assert executors.modeled_collective_bytes("sharded_xla@4", cfg, vol) > 0
        hbm = executors.modeled_hbm_bytes("sharded_pallas_megakernel@4", cfg, vol)
        assert hbm is not None and hbm > 0

    def test_sharded_requires_divisible_z(self):
        # the geometry check fires before any device/mesh is touched, so
        # this runs on single-device hosts too
        cfg = MeshNetConfig(dilations=(1, 2))
        p = meshnet.init(KEY, cfg)
        x = jnp.zeros((1, 9, 8, 8))
        with pytest.raises(ValueError, match="divisible"):
            executors.apply("sharded_xla@2", p, x, cfg)

    def test_sharded_single_device_parity(self):
        # The degenerate one-slab mesh still runs the whole wrapper path
        # (exchange == zero padding), so tier-1 covers the plumbing.
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        p = meshnet.init(KEY, cfg)
        x = jax.random.normal(jax.random.PRNGKey(8), (1, 12, 10, 10))
        ref = executors.apply("xla", p, x, cfg)
        got = executors.apply("sharded_xla@1", p, x, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)

    def test_list_dilations_config_crosses_jit_boundary(self):
        # cfg is a static jit argument in jitted_apply; list dilations must
        # be normalised to a hashable tuple by MeshNetConfig.__post_init__.
        cfg = MeshNetConfig(dilations=[1, 2])
        assert cfg.dilations == (1, 2)
        p = meshnet.init(KEY, cfg)
        x = jax.random.normal(KEY, (1, 8, 8, 8))
        out = executors.jitted_apply("xla")(p, x, cfg)
        assert out.shape == (1, 8, 8, 8, cfg.num_classes)


class TestFusedParity:
    """ops.meshnet_apply == meshnet.apply (eval) across the model zoo."""

    @pytest.mark.parametrize("name", sorted(PAPER_MODELS))
    def test_paper_models(self, name):
        _parity(PAPER_MODELS[name])

    def test_no_batchnorm(self):
        _parity(MeshNetConfig(use_batchnorm=False))

    def test_nontrivial_bn_stats(self):
        # Fold-correctness is invisible with init stats (mean 0 / var 1):
        # perturb the running stats so the fused scale/offset path is real.
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        p = meshnet.init(KEY, cfg)
        k = jax.random.PRNGKey(5)
        for layer in p["layers"]:
            k, k1, k2 = jax.random.split(k, 3)
            layer["bn_mean"] = jax.random.normal(k1, layer["bn_mean"].shape) * 0.3
            layer["bn_var"] = 0.5 + jax.random.uniform(k2, layer["bn_var"].shape)
        x = jax.random.normal(jax.random.PRNGKey(6), ODD_SHAPE)
        got = executors.apply("pallas_fused", p, x, cfg)
        expect = executors.apply("xla", p, x, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=2e-4)

    @pytest.mark.parametrize("shape", [(1, 16, 16, 16), (2, 9, 17, 13)])
    def test_block_multiple_and_batched_odd(self, shape):
        _parity(MeshNetConfig(dilations=(1, 2, 4)), shape=shape)

    def test_streaming_executor_parity(self):
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        p = meshnet.init(KEY, cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), ODD_SHAPE)
        np.testing.assert_allclose(
            np.asarray(executors.apply("streaming", p, x, cfg)),
            np.asarray(executors.apply("xla", p, x, cfg)),
            atol=1e-4,
        )


class TestPipelineDispatch:
    def _setup(self):
        params = meshnet.init(KEY, SMALL)
        vol, _ = mri.generate(KEY, mri.SyntheticMRIConfig(shape=(16, 16, 16)))
        return params, vol

    @pytest.mark.parametrize(
        "executor",
        ["xla", "pallas_fused", "pallas_megakernel", "streaming", "sharded_xla"],
    )
    @pytest.mark.parametrize("mode", ["full", "subvolume", "streaming"])
    def test_all_modes_all_executors(self, mode, executor):
        params, vol = self._setup()
        pc = PipelineConfig(
            model=SMALL, volume_shape=(16, 16, 16), mode=mode, cube=8, overlap=4,
            min_component_size=4, executor=executor,
        )
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "ok", res.record.fail_type
        assert res.segmentation.shape == (16, 16, 16)
        assert res.record.executor == executor  # recorded in telemetry
        assert res.record.hbm_bytes_modeled > 0  # bytes-moved telemetry
        # collective bytes stamped on every run: 0 unless >1 slab is real
        if executor == "sharded_xla" and jax.device_count() > 1:
            assert res.record.collective_bytes_modeled > 0
        else:
            assert res.record.collective_bytes_modeled == 0

    def test_sharded_without_devices_fails_record_not_raises(self):
        # a slab count the host can't provide keeps the never-raises
        # telemetry contract: status='fail', not an exception
        params, vol = self._setup()
        pc = PipelineConfig(
            model=SMALL, volume_shape=(16, 16, 16), mode="full",
            min_component_size=4, executor="sharded_xla@64",
        )
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "fail"
        assert res.record.fail_type == "shard_geometry"
        assert res.segmentation is None

    def test_pinned_executor_wins_over_shard_devices_default(self):
        # an explicitly pinned "@n" is not silently re-wrapped by the
        # engine/pipeline default slab count — it fails honestly instead
        params, vol = self._setup()
        pc = PipelineConfig(
            model=SMALL, volume_shape=(16, 16, 16), mode="full",
            min_component_size=4, executor="sharded_xla@64", shard_devices=1,
        )
        # devices=1 explicitly forces single-device, even over a pin
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "ok" and res.record.executor == "xla"
        pc2 = PipelineConfig(
            model=SMALL, volume_shape=(16, 16, 16), mode="full",
            min_component_size=4, executor="sharded_xla@64", shard_devices=2,
        )
        res2 = pipeline.run(pc2, params, vol)
        assert res2.record.executor == "sharded_xla@64"
        assert res2.record.status == "fail"
        assert res2.record.fail_type == "shard_geometry"

    def test_shard_devices_one_forces_single_device(self):
        # devices=1 unwraps a sharded executor back to its inner backend
        params, vol = self._setup()
        pc = PipelineConfig(
            model=SMALL, volume_shape=(16, 16, 16), mode="full",
            min_component_size=4, executor="sharded_xla", shard_devices=1,
        )
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "ok"
        assert res.record.executor == "xla"

    def test_shard_devices_keeps_unshardeable_executor_single_device(self):
        # streaming has no sharded form: a slab-count request runs it
        # single-device instead of failing the request
        params, vol = self._setup()
        pc = PipelineConfig(
            model=SMALL, volume_shape=(16, 16, 16), mode="full",
            min_component_size=4, executor="streaming", shard_devices=2,
        )
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "ok"
        assert res.record.executor == "streaming"
        assert res.record.collective_bytes_modeled == 0

    def test_executors_agree_on_segmentation(self):
        params, vol = self._setup()
        segs = {}
        for executor in ("xla", "pallas_fused", "pallas_megakernel"):
            pc = PipelineConfig(
                model=SMALL, volume_shape=(16, 16, 16), mode="full",
                min_component_size=4, executor=executor,
            )
            segs[executor] = np.asarray(pipeline.run(pc, params, vol).segmentation)
        np.testing.assert_array_equal(segs["xla"], segs["pallas_fused"])
        np.testing.assert_array_equal(segs["xla"], segs["pallas_megakernel"])

    def test_subvolume_executor_closure_matches_explicit_infer_fn(self):
        params, vol = self._setup()
        via_registry = patching.subvolume_inference(
            vol, params=params, model_cfg=SMALL, executor="xla", cube=8, overlap=7
        )
        via_closure = patching.subvolume_inference(
            vol, jax.jit(lambda c: meshnet.apply(params, c, SMALL)), cube=8, overlap=7
        )
        np.testing.assert_allclose(
            np.asarray(via_registry), np.asarray(via_closure), atol=1e-6
        )

    def test_subvolume_requires_model_or_fn(self):
        with pytest.raises(ValueError, match="infer_fn"):
            patching.subvolume_inference(jnp.zeros((8, 8, 8)), cube=4)


class TestEngineDispatch:
    def _engine(self):
        params = meshnet.init(KEY, SMALL)
        pc = PipelineConfig(
            model=SMALL, volume_shape=(16, 16, 16), cube=8, overlap=4,
            min_component_size=4,
        )
        # Tight budget: streaming fits, the naive full graph would not.
        engine = SegmentationEngine(
            params, pc, budget=MemoryBudget(8 * 1024 * 1024, name="tight")
        )
        return engine

    def test_submit_many_records_mode_and_executor(self):
        engine = self._engine()
        vols = [
            mri.generate(jax.random.PRNGKey(i), mri.SyntheticMRIConfig(shape=(16, 16, 16)))[0]
            for i in range(3)
        ]
        results = engine.submit_many(
            vols,
            modes=[None, "subvolume", None],
            executors=[None, "xla", "streaming"],
        )
        assert len(results) == len(engine.log.records) == 3
        # results come back in submission order with telemetry attribution
        for i, res in enumerate(results):
            assert res.record.status == "ok"
            assert res.record.extra["request_index"] == i
            assert res.record.executor in executors.names()
        assert results[1].record.mode == "subvolume"
        assert results[2].record.executor == "streaming"
        # default requests keep the budget-driven failsafe selection
        assert results[0].record.mode == engine.pick_mode((16, 16, 16))

    def test_submit_many_length_mismatch(self):
        engine = self._engine()
        with pytest.raises(ValueError, match="must match"):
            engine.submit_many([jnp.zeros((16, 16, 16))], modes=["full", "full"])
