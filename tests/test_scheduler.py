"""Scheduler invariants (serving/scheduler.py + serving/simulator.py).

Unit tests pin the admission mechanics — typed queue-full backpressure,
HBM-budget admission, shed-to-subvolume demotion, priority order,
deadline expiry, grouping, and the resolution/quantize-once dedupe the
scheduler gives ``submit_many``. The hypothesis section drives random
request mixes through the virtual-clock simulator and asserts the
system-level properties the ISSUE names: conservation (admitted ==
completed + demoted + rejected — zero lost requests), no starvation,
admission never exceeding the configured budget, FIFO within a priority
class, and bit-determinism of the telemetry stream.

Everything here runs on the virtual clock with modeled execution
(``execute=False``) except the explicitly-real engine tests, so the
whole file is seconds on CPU.
"""

import jax
import numpy as np
import pytest

from repro.core import meshnet
from repro.core.meshnet import MeshNetConfig
from repro.core.pipeline import PipelineConfig
from repro.serving.engine import SegmentationEngine
from repro.serving.scheduler import (
    PriorityClass,
    QueueFullError,
    RequestScheduler,
    SchedulerConfig,
)
from repro.serving.simulator import (
    ScenarioSpec,
    ServiceModel,
    SimConfig,
    VirtualClock,
    simulate,
)

KEY = jax.random.PRNGKey(0)
SMALL = MeshNetConfig(dilations=(1, 2, 4), channels=5)


def make_engine(volume_shape=(16, 16, 16), **cfg_kwargs):
    params = meshnet.init(KEY, SMALL)
    pc = PipelineConfig(
        model=SMALL,
        volume_shape=volume_shape,
        cube=8,
        overlap=4,
        min_component_size=4,
        executor="xla",
        **cfg_kwargs,
    )
    return SegmentationEngine(params, pc)


def make_sched(engine=None, *, clock=None, execute=False, **cfg_kwargs):
    engine = engine or make_engine()
    # unit tests exercise shape-driven admission -> native-shape serving
    cfg_kwargs.setdefault("native_shapes", True)
    cfg = SchedulerConfig(**cfg_kwargs)
    return RequestScheduler(
        engine,
        cfg,
        clock=clock or VirtualClock(),
        service_model=ServiceModel(),
        execute=execute,
    )


def vol(shape=(16, 16, 16), seed=0):
    return np.random.default_rng(seed).random(shape, dtype=np.float32)


# ------------------------------------------------------------ unit tests ---


class TestAdmission:
    def test_queue_full_is_typed_and_logged(self):
        sched = make_sched(max_queue_depth=2)
        sched.submit(vol(), arrival_s=0.0)
        sched.submit(vol(), arrival_s=0.0)
        with pytest.raises(QueueFullError) as ei:
            sched.submit(vol(), arrival_s=0.0)
        assert ei.value.limit == 2
        assert sched.stats.refused == 1
        # the refusal left a typed record in the fleet telemetry
        shed = [r for r in sched.engine.log.records if r.fail_type == "queue_full"]
        assert len(shed) == 1 and shed[0].status == "fail"
        # refused requests are NOT part of the conservation ledger
        assert sched.stats.admitted == 2

    def test_admission_budget_never_exceeded_per_batch(self):
        # streaming 16^3 fp32 ~= 0.2 MiB; cap at 2 requests' worth
        per = make_sched()._price("streaming", (16, 16, 16), "fp32")
        sched = make_sched(
            admission_hbm_bytes=2 * per + per // 2,
            max_batch_requests=8,
            allow_demotion=False,
        )
        for i in range(5):
            sched.submit(vol(seed=i), mode="streaming", arrival_s=0.0)
        sizes = []
        while True:
            b = sched.next_batch(now=1.0)
            if b is None:
                break
            total = sum(r.bytes_priced for r in b.requests)
            assert total <= sched.cfg.admission_hbm_bytes
            sizes.append(len(b.requests))
            sched.run_batch(b)
        assert sizes == [2, 2, 1]  # grouped up to the budget, never past it
        assert sched.stats.conserved()

    def test_oversized_request_demotes_to_subvolume(self):
        sched = make_sched(admission_hbm_bytes=300_000)  # < 32^3 streaming
        sched.submit(vol((32, 32, 32)), mode="streaming", arrival_s=0.0)
        b = sched.next_batch(now=0.0)
        assert len(b.requests) == 1
        req = b.requests[0]
        assert req.demoted and req.key.mode == "subvolume"
        sched.run_batch(b)
        assert sched.stats.demoted == 1 and sched.stats.completed == 0
        rec = sched.completions[0].record
        assert rec.demoted and rec.mode == "subvolume"

    def test_demoted_requests_still_group(self):
        """Shed-to-subvolume demotion must not break continuous batching:
        requests that demote to the SAME failsafe signature dispatch as
        one group (regression: demotion used to rewrite only the seed's
        key, so every demoted request paid a solo dispatch)."""
        # < one 32^3 streaming set (1.7 MiB), >= three failsafe cubes
        sched = make_sched(admission_hbm_bytes=700_000, max_batch_requests=8)
        for i in range(3):
            sched.submit(vol((32, 32, 32), seed=i), mode="streaming", arrival_s=0.0)
        b = sched.next_batch(now=0.0)
        assert len(b.requests) == 3
        assert all(r.demoted and r.key.mode == "subvolume" for r in b.requests)
        sched.run_batch(b)
        assert sched.stats.demoted == 3
        assert sched.completions[0].record.batch_size == 3
        assert sched.stats.conserved()

    def test_unservable_request_rejected_typed(self):
        # cap below even the subvolume working set -> typed admission_oom
        sched = make_sched(admission_hbm_bytes=1024)
        sched.submit(vol(), arrival_s=0.0)
        assert sched.next_batch(now=0.0) is None
        assert sched.stats.rejected == {"admission_oom": 1}
        comp = sched.completions[0]
        assert comp.outcome == "rejected"
        assert comp.record.fail_type == "admission_oom"
        assert sched.stats.conserved()

    def test_deadline_expiry_sheds_typed(self):
        clock = VirtualClock()
        sched = make_sched(
            clock=clock,
            classes={"rt": PriorityClass("rt", 0, deadline_s=1.0)},
        )
        sched.submit(vol(), priority="rt", arrival_s=0.0)
        clock.advance_to(5.0)  # the deadline passed while queued
        assert sched.next_batch() is None
        assert sched.stats.rejected == {"deadline_expired": 1}
        assert sched.completions[0].record.priority_class == "rt"


class TestModeledExecution:
    def test_modeled_record_carries_bytes_and_status(self):
        sched = make_sched()
        sched.submit(vol(), arrival_s=0.0)
        sched.run_batch(sched.next_batch(now=0.0))
        rec = sched.completions[0].record
        assert rec.status == "ok"
        assert rec.hbm_bytes_modeled and rec.hbm_bytes_modeled > 0
        assert rec.params_bytes and rec.params_bytes > 0

    def test_modeled_geometry_failure_is_typed(self):
        if jax.device_count() > 2:
            pytest.skip("needs a host with <= 2 devices to force the failure")
        sched = make_sched()
        sched.submit(vol(), devices=3, arrival_s=0.0)
        sched.run_batch(sched.next_batch(now=0.0))
        rec = sched.completions[0].record
        assert rec.status == "fail" and rec.fail_type == "shard_geometry"
        assert sched.stats.conserved()

    def test_modeled_garbage_failure_is_typed_and_solo(self):
        sched = make_sched()
        sched.submit(np.zeros((5,), np.float32), arrival_s=0.0)
        sched.submit(vol(), arrival_s=0.0)
        b = sched.next_batch(now=0.0)
        assert len(b.requests) == 1  # garbage never groups
        sched.run_batch(b)
        assert sched.completions[0].record.fail_type == "permanent_fault"


class TestOrdering:
    def test_priority_preempts_arrival_order(self):
        sched = make_sched()
        a = sched.submit(vol(seed=1), priority="batch", arrival_s=0.0)
        b = sched.submit(vol(seed=2), priority="interactive", arrival_s=1.0)
        batch = sched.next_batch(now=2.0)
        assert [r.id for r in batch.requests] == [b]  # class mismatch: no group
        sched.run_batch(batch)
        batch2 = sched.next_batch(now=3.0)
        assert [r.id for r in batch2.requests] == [a]

    def test_fifo_within_class_and_signature(self):
        sched = make_sched(max_batch_requests=2)
        ids = [sched.submit(vol(seed=i), arrival_s=float(i)) for i in range(5)]
        served = []
        while True:
            b = sched.next_batch(now=10.0)
            if b is None:
                break
            served.extend(r.id for r in b.requests)
            sched.run_batch(b)
        assert served == ids  # same class + same signature -> strict FIFO

    def test_grouping_merges_compatible_requests_only(self):
        sched = make_sched(max_batch_requests=8)
        sched.submit(vol(seed=0), precision="bf16", arrival_s=0.0)
        sched.submit(vol(seed=1), precision="fp32", arrival_s=0.0)
        sched.submit(vol(seed=2), precision="bf16", arrival_s=0.0)
        b = sched.next_batch(now=0.0)
        # seed is the oldest request; only the same-precision one groups
        assert [r.key.precision for r in b.requests] == ["bf16", "bf16"]
        assert len(b.requests) == 2
        sched.run_batch(b)
        assert sched.completions[0].record.batch_size == 2


class TestTelemetryStamping:
    def test_queue_and_service_stamps(self):
        clock = VirtualClock()
        sched = make_sched(clock=clock)
        sched.submit(vol(), arrival_s=0.0)
        clock.advance_to(2.0)
        b = sched.next_batch()
        finish = sched.run_batch(b)
        rec = sched.completions[0].record
        assert rec.arrival_s == 0.0
        # wait runs to the member's own service start (batch overhead
        # included), so wait + service == finish - arrival exactly
        assert rec.queue_wait_s == pytest.approx(2.0 + ServiceModel().batch_overhead_s)
        assert rec.service_s > 0
        assert rec.batch_size == 1
        assert rec.priority_class == "standard"
        assert finish == pytest.approx(rec.arrival_s + rec.queue_wait_s + rec.service_s)

    def test_wait_plus_service_is_end_to_end_for_every_batch_member(self):
        sched = make_sched(max_batch_requests=4)
        for i in range(4):
            sched.submit(vol(seed=i), arrival_s=0.0)
        sched.run_batch(sched.next_batch(now=1.0))
        for c in sched.completions:
            r = c.record
            assert c.finish_s - c.arrival_s == pytest.approx(
                r.queue_wait_s + r.service_s
            )
        # members serve back-to-back, so later members waited longer
        waits = [
            c.record.queue_wait_s
            for c in sorted(sched.completions, key=lambda c: c.id)
        ]
        assert waits == sorted(waits) and waits[-1] > waits[0]

    def test_slo_attainment_counts_failures_as_misses(self):
        from repro.telemetry import analysis

        engine = make_engine()
        sched = make_sched(engine)
        sched.submit(vol(), arrival_s=0.0)
        sched.submit(np.zeros((5,), np.float32), arrival_s=0.0)  # typed fail
        sched.drain()
        att = analysis.slo_attainment(engine.log.records, {"standard": 1e9})
        assert att["standard"] == pytest.approx(0.5)

    def test_resolution_cached_per_signature(self):
        """The submit_many fix: N same-signature requests cost ONE
        mode/executor/precision resolution + pricing, not N."""
        engine = make_engine()
        calls = {"pick_mode": 0}
        orig = engine.pick_mode

        def counting(shape, precision=None):
            calls["pick_mode"] += 1
            return orig(shape, precision)

        engine.pick_mode = counting
        sched = make_sched(engine)
        for i in range(6):
            sched.submit(vol(seed=i), arrival_s=0.0)
        for i in range(3):
            sched.submit(vol((32, 32, 32), seed=i), arrival_s=0.0)
        assert calls["pick_mode"] == 2  # one per unique signature
        assert sched.stats.resolutions == 2


class TestEngineQueuedAPI:
    """submit_async/drain + scheduler-backed submit_many on the REAL
    pipeline (tiny volumes; xla on CPU)."""

    def test_submit_async_drain_real_execution(self):
        engine = make_engine()
        ids = [engine.submit_async(vol(seed=i)) for i in range(3)]
        comps = engine.drain()
        assert [c.id for c in comps] == ids
        for c in comps:
            assert c.outcome == "completed"
            assert c.result.record.status == "ok"
            assert c.result.segmentation.shape == (16, 16, 16)
            assert c.record.batch_size >= 1
            assert c.record.service_s is not None  # real-clock measured

    def test_drain_returns_only_new_completions(self):
        """A submit/drain service loop must never re-deliver results
        (regression: drain used to return the full completion ledger)."""
        engine = make_engine()
        first = engine.submit_async(vol(seed=0))
        comps1 = engine.drain()
        assert [c.id for c in comps1] == [first]
        second = engine.submit_async(vol(seed=1))
        comps2 = engine.drain()
        assert [c.id for c in comps2] == [second]
        assert engine.drain() == []  # nothing new

    def test_submit_many_never_sheds_on_wall_clock(self, monkeypatch):
        """submit_many is a synchronous batch API: however long earlier
        groups take in real time, later requests must still run
        (regression: the default class ladder's wall-clock deadlines
        leaked into submit_many and shed the tail of slow batches)."""
        from repro.serving import scheduler as sched_mod

        class JumpyClock:  # every reading is 500 s later than the last
            def __init__(self):
                self.t = 0.0

            def now(self):
                self.t += 500.0
                return self.t

        monkeypatch.setattr(sched_mod, "_MonotonicClock", JumpyClock)
        engine = make_engine()
        results = engine.submit_many(
            [vol(seed=i) for i in range(3)], precisions=[None, "bf16", None]
        )
        assert [r.record.status for r in results] == ["ok"] * 3

    def test_scheduler_config_after_creation_raises(self):
        engine = make_engine()
        engine.submit_async(vol())  # lazily creates a default scheduler
        with pytest.raises(ValueError, match="first use"):
            engine.scheduler(SchedulerConfig(max_queue_depth=4))
        engine.drain()

    def test_submit_many_quantize_once_per_policy(self):
        """Mixed-precision submit_many quantizes each policy exactly once
        (the prepared-params cache, exercised through the scheduler's
        grouping)."""
        from repro.kernels import quantize

        engine = make_engine()
        calls = {"n": 0}
        orig = quantize.prepare_params

        def counting(params, cfg, precision):
            calls["n"] += 1
            return orig(params, cfg, precision)

        quantize.prepare_params, prev = counting, quantize.prepare_params
        try:
            engine.submit_many(
                [vol(seed=i) for i in range(6)],
                precisions=[None, "bf16", "int8w", "bf16", "int8w", None],
            )
        finally:
            quantize.prepare_params = prev
        # engine-level preparation: one call per distinct resolved policy
        # (executors may re-call on already-prepared pytrees at trace
        # time — those are idempotent no-ops, not re-quantizations, and
        # happen at most once per compiled (executor, precision) cell)
        assert len(engine._prepared) == 3
        distinct = len(engine._prepared)
        assert calls["n"] <= 2 * distinct
        # and the cached pytrees are reused by identity on a second sweep
        before = {k: id(v) for k, v in engine._prepared.items()}
        engine.submit_many([vol(seed=9)], precisions=["int8w"])
        assert {k: id(v) for k, v in engine._prepared.items()} == before

    def test_submit_many_grouping_dedupes_resolution(self):
        engine = make_engine()
        calls = {"n": 0}
        orig = engine.pick_mode

        def counting(shape, precision=None):
            calls["n"] += 1
            return orig(shape, precision)

        engine.pick_mode = counting
        results = engine.submit_many([vol(seed=i) for i in range(5)])
        assert calls["n"] == 1  # five identical signatures -> one resolution
        assert [r.record.extra["request_index"] for r in results] == list(range(5))
        assert all(r.record.status == "ok" for r in results)
        # all five shared one dispatch group
        assert results[0].record.batch_size == 5
