"""Property suite for the resilience layer under arbitrary seeded fault
storms — the invariants that keep retries, timeouts, breakers, and
hedging from corrupting the PR 5/6 conservation guarantees:

  * **conservation under faults**: whatever the FaultPlan injects
    (transient storms, poisoned signatures, stragglers, stuck members)
    and however many retries/hedges/re-dispatches happen, every arrival
    still reaches exactly ONE terminal ledger outcome and every
    replica's own ledger balances;
  * **exactly-once under hedge races**: a hedged request has two live
    copies racing on two replicas — whichever wins, ``completions_seen
    <= 1`` on every entry (the loser is cancelled via the ledger, even
    when a crash evacuates one copy mid-race);
  * **arrival-stamp preservation**: ``queue_wait_s + service_s ==
    finish - ORIGINAL arrival`` exactly, on every attempt of every
    request — retries (backoff included) and crash re-dispatches both
    carry the original arrival, so SLO math never flatters a failure;
  * **determinism**: same (code, seed) -> byte-identical summaries with
    faults, breakers, and hedging all active.

Same double-drive structure as tests/test_fleet_properties.py: each
``_check_*`` body runs under hypothesis when importable (CI) AND under
an always-on deterministic grid (bare installs never skip)."""

import pytest

from repro.serving.fleet import (
    FleetConfig,
    FleetEvent,
    FleetServiceModel,
    simulate_fleet,
)
from repro.serving.resilience import (
    BreakerConfig,
    FaultPlan,
    FaultRule,
    HedgePolicy,
    ResiliencePolicy,
    RetryPolicy,
)
from repro.serving.scheduler import PriorityClass, SchedulerConfig
from repro.serving.simulator import STANDARD_MIX

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: the grid fallback below still runs
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=10, deadline=None)


def _storm_cfg(
    seed,
    rate,
    replicas,
    transient_rate,
    stuck_rate,
    poison=True,
    hedge=False,
    crash_t=None,
    trip_after=3,
    cooldown_s=30.0,
    horizon_s=90.0,
):
    """A fleet under a seeded storm: tunable transient noise, an
    optionally poisoned signature, a straggler replica, rare stuck
    members — with retries, timeouts, a breaker, and optional aggressive
    hedging all active."""
    rules = [FaultRule(kind="transient", rate=transient_rate)]
    if poison:
        rules.append(
            FaultRule(kind="permanent", rate=1.0, executor_substr="xla",
                      shape=(32, 32, 32), precision="int8w")
        )
    if replicas > 1:
        rules.append(
            FaultRule(kind="straggler", rate=1.0, replica=replicas - 1,
                      slow_factor=5.0)
        )
    if stuck_rate > 0:
        rules.append(FaultRule(kind="stuck", rate=stuck_rate))
    events = ()
    if crash_t is not None and replicas > 1:
        events = (FleetEvent(t=crash_t, action="crash", replica=replicas // 2),)
    return FleetConfig(
        name="resilience-prop",
        seed=seed,
        horizon_s=horizon_s,
        process="poisson",
        process_kwargs={"rate_hz": rate},
        mix=STANDARD_MIX,
        replicas=replicas,
        policy="cache_affinity",
        scheduler=SchedulerConfig(
            max_queue_depth=32,
            admission_hbm_bytes=512 * 1024 * 1024,
            max_batch_requests=4,
            native_shapes=True,
            classes={
                "interactive": PriorityClass("interactive", 0, deadline_s=None),
                "standard": PriorityClass("standard", 1, deadline_s=None),
                "batch": PriorityClass("batch", 2, deadline_s=None),
            },
        ),
        service=FleetServiceModel(base_s=0.05, batch_overhead_s=0.02),
        events=events,
        resilience=ResiliencePolicy(
            retry=RetryPolicy(max_attempts=3, backoff_base_s=0.05, seed=seed),
            service_timeout_s={"interactive": 2.0, "standard": 4.0,
                               "batch": 8.0},
            # aggressive hedging when asked: hedge almost immediately so
            # the race window is exercised hard, not occasionally
            hedge=HedgePolicy(p99_factor=1.0, min_age_s=0.05, min_samples=5,
                              window=50, max_hedges=1) if hedge else None,
            breaker=BreakerConfig(trip_after=trip_after, cooldown_s=cooldown_s),
        ),
        fault_plan=FaultPlan(seed=seed, rules=tuple(rules)),
    )


# ------------------------------------------------------ invariant bodies ---


def _check_conservation_under_faults(seed, rate, replicas, transient_rate,
                                     stuck_rate, hedge, crash_t):
    """The load-balancing conservation law survives the full storm:
    every arrival gets exactly one terminal outcome, per-replica ledgers
    balance (hedge losers and crash evacuations both count as
    evacuations), and admissions exceed unique admissions by exactly the
    re-dispatches plus the hedge copies."""
    rep = simulate_fleet(_storm_cfg(seed, rate, replicas, transient_rate,
                                    stuck_rate, hedge=hedge, crash_t=crash_t))
    fl = rep.fleet
    assert fl.conserved()
    for r in fl.replicas:
        assert r.sched.stats.conserved(), f"replica {r.id}: {r.sched.stats}"
    s = rep.summary()
    req = s["requests"]
    unique_terminal = (
        req["refused"]
        + req["no_replica"]
        + req["completed"]
        + req["demoted"]
        + sum(req["rejected"].values())
    )
    assert req["arrived"] == unique_terminal
    assert req["admitted"] == (
        req["arrived"] - req["refused"] - req["no_replica"]
        + req["redispatched"] + s["resilience"]["hedges"]
    )


def _check_exactly_once_under_hedge_races(seed, rate, replicas, crash_t):
    """Hedge copies race; crashes evacuate copies mid-race; breakers trip
    mid-batch. Whatever wins, no ledger entry is ever served twice, and
    every served entry was served exactly once."""
    rep = simulate_fleet(_storm_cfg(seed, rate, replicas, 0.1, 0.003,
                                    hedge=True, crash_t=crash_t))
    fl = rep.fleet
    assert all(e.completions_seen <= 1 for e in fl.ledger)
    served = [e for e in fl.ledger if e.outcome in ("completed", "demoted")]
    assert all(e.completions_seen == 1 for e in served)
    # no orphaned copies: every surviving copy belongs to an unserved
    # entry (served entries cancel their twins on the spot)
    for e in served:
        for (rid, lid) in e.copies:
            r = next((x for x in fl.replicas if x.id == rid), None)
            assert r is None or not r.live or all(
                q.id != lid for q in r.sched.queue
            ), "served entry left a live queued copy behind"


def _check_arrival_stamp_preserved(seed, rate, replicas, transient_rate,
                                   crash_t):
    """wait + service == finish - ORIGINAL arrival exactly, for every
    attempt record of every request — across retries (whose backoff
    shows up as queue wait, never as forgiven age) and across crash
    re-dispatches (the dead replica's lost time is charged too)."""
    rep = simulate_fleet(_storm_cfg(seed, rate, replicas, transient_rate,
                                    0.0, crash_t=crash_t))
    fl = rep.fleet
    arrival_of = {}
    for e in fl.ledger:
        if e.outcome in ("completed", "demoted"):
            rec = e.completion.record
            assert rec.arrival_s == e.arrival_s  # original, not re-submit time
            assert rec.queue_wait_s + rec.service_s == pytest.approx(
                e.finish_s - e.arrival_s, abs=1e-9
            )
            arrival_of[(rec.replica_id, rec.request_id)] = e.arrival_s
    # every intermediate attempt carries the same original arrival
    retried = [
        r
        for repl in fl.replicas
        for r in repl.sched.engine.log.records
        if r.attempt and r.attempt > 0 and r.request_id is not None
    ]
    for rec in retried:
        key = (rec.replica_id, rec.request_id)
        if key in arrival_of:
            assert rec.arrival_s == arrival_of[key]
    redispatched = [e for e in fl.ledger if e.dispatches > 1]
    if crash_t is not None and replicas > 1:
        assert redispatched or fl.redispatched == 0


def _check_storm_determinism(seed, replicas, hedge, crash_t):
    """Same (code, seed) -> byte-identical storm summaries, with faults,
    breakers, and hedging all live."""
    runs = [
        simulate_fleet(
            _storm_cfg(seed, 6.0, replicas, 0.1, 0.002, hedge=hedge,
                       crash_t=crash_t)
        ).to_json()
        for _ in range(2)
    ]
    assert runs[0] == runs[1]


def _check_breaker_trips_mid_batch_conserve(seed, rate):
    """A poisoned signature tripping its breaker mid-trace (including
    mid-batch: its group members re-form at the demoted rung on the next
    batch) never breaks conservation, and the demoted rung actually
    serves what the poisoned rung could not."""
    rep = simulate_fleet(_storm_cfg(seed, rate, 2, 0.0, 0.0, poison=True,
                                    trip_after=1, cooldown_s=1e9,
                                    horizon_s=120.0))
    fl = rep.fleet
    assert fl.conserved()
    s = rep.summary()
    r = s["resilience"]
    if r["faults"]["permanent"] > 0:
        assert r["breaker"]["trips"] >= 1
        # demotion reached a rung that completes requests
        assert r["rungs"].get("streaming/streaming", 0) > 0


# ------------------------------------------------- hypothesis exploration ---

if HAVE_HYPOTHESIS:

    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        rate=st.floats(2.0, 10.0),
        replicas=st.integers(1, 4),
        transient_rate=st.floats(0.0, 0.3),
        stuck_rate=st.floats(0.0, 0.01),
        hedge=st.booleans(),
        crash_t=st.one_of(st.none(), st.floats(10.0, 60.0)),
    )
    def test_conservation_under_faults(seed, rate, replicas, transient_rate,
                                       stuck_rate, hedge, crash_t):
        _check_conservation_under_faults(seed, rate, replicas, transient_rate,
                                         stuck_rate, hedge, crash_t)

    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        rate=st.floats(4.0, 12.0),
        replicas=st.integers(2, 4),
        crash_t=st.one_of(st.none(), st.floats(10.0, 60.0)),
    )
    def test_exactly_once_under_hedge_races(seed, rate, replicas, crash_t):
        _check_exactly_once_under_hedge_races(seed, rate, replicas, crash_t)

    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        rate=st.floats(2.0, 8.0),
        replicas=st.integers(2, 4),
        transient_rate=st.floats(0.05, 0.3),
        crash_t=st.one_of(st.none(), st.floats(10.0, 60.0)),
    )
    def test_arrival_stamp_preserved(seed, rate, replicas, transient_rate,
                                     crash_t):
        _check_arrival_stamp_preserved(seed, rate, replicas, transient_rate,
                                       crash_t)

    @settings(max_examples=5, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        replicas=st.integers(1, 3),
        hedge=st.booleans(),
        crash_t=st.one_of(st.none(), st.floats(10.0, 60.0)),
    )
    def test_storm_determinism(seed, replicas, hedge, crash_t):
        _check_storm_determinism(seed, replicas, hedge, crash_t)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), rate=st.floats(2.0, 8.0))
    def test_breaker_trips_mid_batch_conserve(seed, rate):
        _check_breaker_trips_mid_batch_conserve(seed, rate)


# ------------------------------------------------- deterministic fallback ---


class TestGridFallback:
    """Pinned corners of the fault-storm property space — always
    executed, with or without hypothesis, so no environment silently
    skips the resilience invariants."""

    @pytest.mark.parametrize(
        "seed,rate,replicas,transient_rate,stuck_rate,hedge,crash_t",
        [
            (0, 4.0, 1, 0.15, 0.0, False, None),
            (1, 8.0, 3, 0.1, 0.005, True, 30.0),
            (2, 6.0, 4, 0.25, 0.0, True, None),
            (3, 10.0, 2, 0.05, 0.01, False, 20.0),
        ],
    )
    def test_conservation_under_faults(self, seed, rate, replicas,
                                       transient_rate, stuck_rate, hedge,
                                       crash_t):
        _check_conservation_under_faults(seed, rate, replicas, transient_rate,
                                         stuck_rate, hedge, crash_t)

    @pytest.mark.parametrize(
        "seed,rate,replicas,crash_t",
        [(0, 8.0, 3, None), (1, 10.0, 2, 25.0), (2, 6.0, 4, 45.0)],
    )
    def test_exactly_once_under_hedge_races(self, seed, rate, replicas,
                                            crash_t):
        _check_exactly_once_under_hedge_races(seed, rate, replicas, crash_t)

    @pytest.mark.parametrize(
        "seed,rate,replicas,transient_rate,crash_t",
        [(0, 4.0, 2, 0.2, None), (1, 6.0, 3, 0.1, 30.0)],
    )
    def test_arrival_stamp_preserved(self, seed, rate, replicas,
                                     transient_rate, crash_t):
        _check_arrival_stamp_preserved(seed, rate, replicas, transient_rate,
                                       crash_t)

    @pytest.mark.parametrize(
        "seed,replicas,hedge,crash_t",
        [(0, 2, True, None), (5, 3, False, 25.0)],
    )
    def test_storm_determinism(self, seed, replicas, hedge, crash_t):
        _check_storm_determinism(seed, replicas, hedge, crash_t)

    @pytest.mark.parametrize("seed,rate", [(0, 4.0), (7, 6.0)])
    def test_breaker_trips_mid_batch_conserve(self, seed, rate):
        _check_breaker_trips_mid_batch_conserve(seed, rate)
