"""Unit tests for the precision-policy engine (kernels/quantize.py):
pack/unpack round-trip bounds, per-channel scale correctness with and
without BatchNorm folding, params preparation, the analytic weight-
footprint model, and the property that int8w logits converge to fp32 as
weight magnitude shrinks (the quantization step is proportional to the
per-channel max, so the absolute error vanishes with it)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import meshnet
from repro.core.meshnet import MeshNetConfig
from repro.kernels import ops, quantize

KEY = jax.random.PRNGKey(7)


class TestSymmetricQuantization:
    def test_roundtrip_error_within_half_step(self):
        w = jax.random.normal(KEY, (3, 3, 3, 5, 8)) * 0.3
        q, scale = quantize.quantize_symmetric(w, axis=-1)
        assert q.dtype == jnp.int8
        assert scale.shape == (8,)
        back = quantize.dequantize(q, scale)
        err = np.abs(np.asarray(back - w))
        bound = np.asarray(quantize.roundtrip_bound(scale))
        assert (err <= bound[None, None, None, None, :] + 1e-7).all()

    def test_per_channel_scale_is_max_over_127(self):
        w = jnp.zeros((3, 3, 3, 2, 3)).at[0, 0, 0, 0, 1].set(2.54)
        w = w.at[1, 1, 1, 1, 0].set(-1.27)
        q, scale = quantize.quantize_symmetric(w, axis=-1)
        np.testing.assert_allclose(
            np.asarray(scale), [1.27 / 127, 2.54 / 127, 1.0], rtol=1e-6
        )
        # extreme values map to exactly +-127
        assert int(q[0, 0, 0, 0, 1]) == 127
        assert int(q[1, 1, 1, 1, 0]) == -127

    def test_zero_channel_roundtrips_exactly(self):
        w = jnp.zeros((3, 3, 3, 2, 2)).at[..., 0].set(0.5)
        q, scale = quantize.quantize_symmetric(w, axis=-1)
        np.testing.assert_array_equal(np.asarray(q[..., 1]), 0)
        np.testing.assert_array_equal(
            np.asarray(quantize.dequantize(q, scale)[..., 1]), 0.0
        )

    def test_input_quantization_fixed_scale(self):
        x = jnp.linspace(0.0, 1.0, 11)
        q = quantize.quantize_input(x)
        assert q.dtype == jnp.int8
        back = q.astype(jnp.float32) * quantize.INPUT_SCALE
        assert float(jnp.max(jnp.abs(back - x))) <= quantize.INPUT_SCALE / 2 + 1e-7


class TestFoldEpilogue:
    def _layer(self, c=5, key=KEY, quantized=False):
        cfg = MeshNetConfig(channels=c, dilations=(1,))
        p = meshnet.init(key, cfg)
        layer = dict(p["layers"][0])
        k1, k2, k3, k4 = jax.random.split(key, 4)
        layer["bn_mean"] = jax.random.normal(k1, (c,)) * 0.3
        layer["bn_var"] = 0.5 + jax.random.uniform(k2, (c,))
        layer["bn_scale"] = 1.0 + 0.2 * jax.random.normal(k3, (c,))
        layer["bn_bias"] = 0.1 * jax.random.normal(k4, (c,))
        if quantized:
            q, scale = quantize.quantize_symmetric(layer["w"], axis=-1)
            layer["w"], layer["wscale"] = q, scale
        return layer

    def test_matches_ops_fold_batchnorm_for_float_layers(self):
        layer = self._layer()
        bias, scale, offset = quantize.fold_epilogue(layer, True)
        s_ref, o_ref = ops.fold_batchnorm(layer)
        np.testing.assert_allclose(np.asarray(bias), np.asarray(layer["b"]))
        np.testing.assert_allclose(np.asarray(scale), np.asarray(s_ref), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(offset), np.asarray(o_ref), rtol=1e-6)

    def test_int8_fold_reproduces_dequant_then_bn(self):
        """(acc + 0) * (wscale * bn_s) + (b * bn_s + bn_o) must equal
        BN(conv(x, dequant(q)) + b) for any accumulator value."""
        layer = self._layer(quantized=True)
        bias, scale, offset = quantize.fold_epilogue(layer, True)
        np.testing.assert_array_equal(np.asarray(bias), 0.0)
        acc = jax.random.normal(KEY, (4, layer["w"].shape[-1]))
        got = acc * scale + offset
        # reference: dequant the accumulator, add bias, apply inference BN
        s_ref, o_ref = ops.fold_batchnorm(layer)
        want = (acc * layer["wscale"] + layer["b"]) * s_ref + o_ref
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_no_batchnorm_fold(self):
        layer = self._layer(quantized=True)
        bias, scale, offset = quantize.fold_epilogue(layer, False)
        np.testing.assert_array_equal(np.asarray(bias), 0.0)
        np.testing.assert_allclose(np.asarray(scale), np.asarray(layer["wscale"]))
        np.testing.assert_allclose(np.asarray(offset), np.asarray(layer["b"]))


class TestPrepareParams:
    def test_idempotent_and_dtypes(self):
        cfg = MeshNetConfig(dilations=(1, 2))
        p = meshnet.init(KEY, cfg)
        for prec, wdt in (("bf16", jnp.bfloat16), ("int8w", jnp.int8)):
            prepared = quantize.prepare_params(p, cfg, prec)
            assert prepared["layers"][0]["w"].dtype == wdt
            assert prepared["head"]["w"].dtype == jnp.bfloat16
            again = quantize.prepare_params(prepared, cfg, prec)
            assert again is prepared  # idempotent: no re-quantization
        assert quantize.prepare_params(p, cfg, "fp32") is p

    def test_params_bytes_match_analytic_model(self):
        cfg = MeshNetConfig()  # gwm_light
        p = meshnet.init(KEY, cfg)
        for prec in quantize.PRECISIONS:
            prepared = quantize.prepare_params(p, cfg, prec)
            assert quantize.params_bytes(prepared) == quantize.model_params_bytes(
                cfg, prec
            ), prec
        # the footprint ordering is the whole point: int8w < bf16 < fp32
        sizes = [quantize.model_params_bytes(cfg, pr) for pr in quantize.PRECISIONS]
        assert sizes[2] < sizes[1] < sizes[0]

    def test_unknown_precision_raises(self):
        with pytest.raises(ValueError, match="unknown precision"):
            quantize.validate("fp16")

    def test_resolve_precision_policy(self):
        assert quantize.resolve_precision("int8w") == "int8w"
        assert quantize.resolve_precision(None, backend="cpu") == "fp32"
        assert quantize.resolve_precision("auto", backend="cpu") == "fp32"
        assert quantize.resolve_precision("auto", backend="tpu") == "bf16"
        wide = MeshNetConfig(channels=21)
        assert quantize.resolve_precision("auto", wide, backend="tpu") == "int8w"
        assert (
            quantize.resolve_precision("auto", MeshNetConfig(), backend="tpu")
            == "bf16"
        )


class TestStagingScales:
    def test_bn_bound_covers_observed_activations(self):
        # with BN stats matching the data, the 6-sigma bound must dominate
        # the observed per-channel maxima (no saturation in practice)
        cfg = MeshNetConfig(dilations=(1, 2, 4))
        p = meshnet.init(KEY, cfg)
        x = jax.random.uniform(jax.random.PRNGKey(5), (1, 12, 12, 12))
        observed = quantize.calibrate(p, cfg, x, margin=1.0)
        bn = quantize.staging_scales_from_bn(p, cfg)
        assert bn is not None and len(bn) == len(observed) == 3
        for o, b in zip(observed, bn):
            assert (np.asarray(o) <= np.asarray(b) + 1e-6).all()

    def test_no_batchnorm_has_no_bn_scales(self):
        cfg = MeshNetConfig(dilations=(1,), use_batchnorm=False)
        p = meshnet.init(KEY, cfg)
        assert quantize.staging_scales_from_bn(p, cfg) is None

    def test_staging_roundtrip_error_bound(self):
        x = jax.nn.relu(jax.random.normal(KEY, (64, 5)))
        scale = jnp.maximum(jnp.max(x, axis=0), 1e-6) / 127.0
        q = quantize.quantize_staging(x, scale)
        back = q.astype(jnp.float32) * scale
        err = np.abs(np.asarray(back - x))
        assert (err <= np.asarray(scale)[None, :] / 2 + 1e-7).all()


class TestConvergenceProperty:
    @pytest.mark.parametrize("shrink", [1.0, 1e-1, 1e-2, 1e-3])
    def test_int8w_logits_converge_to_fp32_as_weights_shrink(self, shrink):
        """The int8 step is max|w|/127 per channel, so the absolute weight
        error — and with it the logit gap — scales linearly with weight
        magnitude. Verified on the xla reference executor (the same
        quantizer feeds every backend)."""
        from repro.core import executors

        cfg = MeshNetConfig(dilations=(1, 2), use_batchnorm=False)
        p = meshnet.init(KEY, cfg)
        p = jax.tree.map(lambda a: a * shrink, p)
        x = jax.random.uniform(jax.random.PRNGKey(9), (1, 8, 8, 8))
        ref = executors.apply("xla", p, x, cfg)
        got = executors.apply("xla", p, x, cfg, precision="int8w")
        gap = float(jnp.max(jnp.abs(got.astype(jnp.float32) - ref)))
        # bf16 activation rounding also scales with the activations, so
        # the whole gap is proportional to the weight scale
        assert gap <= 0.05 * shrink, (shrink, gap)
