"""Golden-scenario regression tests for the serving scheduler.

Three committed simulator traces — steady, burst, overload — asserted
EXACTLY against checked-in JSON summaries (tests/golden/serving_*.json).
The simulator is bit-deterministic (virtual clock, seeded arrivals,
modeled service), so any scheduler-behavior change shows up here as a
reviewable golden diff instead of a silent drift; regenerate with:

    PYTHONPATH=src python -m benchmarks.bench_serving --seed 0 \
        --json-out /tmp/serving.json
    # then split per scenario into tests/golden/serving_<name>.json

(or just update the failing file with the printed fresh summary). The
same numbers feed the gated ``serving`` section of BENCH_2.json, so the
golden and the bench baseline must move together in one PR.
"""

import json
import os

import pytest

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


def _fresh_summary(name: str) -> dict:
    from repro.serving import simulator as sim

    rep = sim.simulate(sim.reference_engine(), sim.preset(name, seed=0))
    return rep.summary()


@pytest.mark.parametrize("name", ["steady", "burst", "overload"])
def test_golden_trace_matches(name):
    path = os.path.join(GOLDEN_DIR, f"serving_{name}.json")
    with open(path) as f:
        golden = json.load(f)
    fresh = _fresh_summary(name)
    # byte-level comparison via canonical dumps — the strongest claim the
    # virtual clock supports, and the one CI's determinism gate relies on
    assert json.dumps(fresh, sort_keys=True) == json.dumps(golden, sort_keys=True), (
        f"serving scenario {name!r} diverged from its golden trace; "
        f"fresh summary:\n{json.dumps(fresh, indent=1, sort_keys=True)}"
    )


def test_overload_golden_actually_sheds():
    """The committed overload trace must keep exercising every shed lane
    (otherwise the scenario silently stopped testing backpressure)."""
    with open(os.path.join(GOLDEN_DIR, "serving_overload.json")) as f:
        golden = json.load(f)
    req = golden["requests"]
    assert req["conserved"] is True
    assert req["refused"] > 0, "no queue-full backpressure in the overload golden"
    assert req["demoted"] > 0, "no shed-to-subvolume demotion in the overload golden"
    assert sum(req["rejected"].values()) > 0, "no typed rejection in the overload golden"
    # zero lost requests: everything arrived is accounted for
    assert req["arrived"] == req["refused"] + req["admitted"]
    assert req["admitted"] == (
        req["completed"] + req["demoted"] + sum(req["rejected"].values())
    )


def test_steady_golden_is_calm():
    """Steady-state must stay the latency floor: nothing shed, shallow
    queue — so a scheduler change that introduces gratuitous queuing is a
    visible golden diff, not an 'expected' one."""
    with open(os.path.join(GOLDEN_DIR, "serving_steady.json")) as f:
        golden = json.load(f)
    req = golden["requests"]
    assert req["refused"] == 0 and req["demoted"] == 0
    assert golden["requests"]["rejected"] == {}
    assert golden["max_queue_depth"] <= 4
