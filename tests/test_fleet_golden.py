"""Golden-trace regression tests for the replicated serving fleet.

Four committed fleet traces — fleet_steady, fleet_overload,
fleet_failover, fleet_autoscale — asserted EXACTLY against checked-in
JSON summaries (tests/golden/fleet_*.json). The fleet simulator is
bit-deterministic end to end (one virtual clock across N replicas,
seeded arrivals/mix, modeled service + cold-compile), so router,
failover, or autoscaler behavior changes show up here as reviewable
golden diffs, never as flakes. Regenerate with:

    PYTHONPATH=src python -m benchmarks.bench_serving --fleet --seed 0 \
        --json-out /tmp/fleet.json
    # then split per scenario into tests/golden/fleet_<name>.json

The same numbers feed the gated ``serving_fleet`` section of
BENCH_2.json, so the goldens and the bench baseline must move together
in one PR. The semantic tests below pin what each golden must *show* —
the acceptance claims of the fleet tier — so a regenerated golden that
silently stopped exercising failover or autoscaling fails review here.
"""

import json
import os

import pytest

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

FLEET_SCENARIOS = ["fleet_steady", "fleet_overload", "fleet_failover", "fleet_autoscale"]


def _golden(name: str) -> dict:
    with open(os.path.join(GOLDEN_DIR, f"{name}.json")) as f:
        return json.load(f)


def _fresh_summary(name: str) -> dict:
    from repro.serving.fleet import fleet_preset, simulate_fleet

    return simulate_fleet(fleet_preset(name, seed=0)).summary()


def _unique_terminal_total(req: dict) -> int:
    """Arrivals accounted for by a unique terminal outcome (the fleet
    ledger view — per-replica admissions double-count re-dispatches)."""
    return (
        req["refused"]
        + req["no_replica"]
        + req["completed"]
        + req["demoted"]
        + sum(req["rejected"].values())
    )


@pytest.mark.parametrize("name", FLEET_SCENARIOS + ["fleet_cached"])
def test_fleet_golden_trace_matches(name):
    golden = _golden(name)
    fresh = _fresh_summary(name)
    # byte-level comparison via canonical dumps — the strongest claim the
    # virtual clock supports, and the one CI's determinism gate relies on
    assert json.dumps(fresh, sort_keys=True) == json.dumps(golden, sort_keys=True), (
        f"fleet scenario {name!r} diverged from its golden trace; "
        f"fresh summary:\n{json.dumps(fresh, indent=1, sort_keys=True)}"
    )


@pytest.mark.parametrize("name", FLEET_SCENARIOS)
def test_fleet_goldens_conserve(name):
    """Fleet conservation on every committed trace: every arrival has
    exactly one terminal outcome, nothing is served twice, and each
    replica's own ledger balances (evacuations included)."""
    golden = _golden(name)
    req = golden["requests"]
    assert req["conserved"] is True
    assert req["served_twice"] == 0
    assert req["arrived"] == _unique_terminal_total(req)
    for rep in golden["per_replica"]:
        assert rep["admitted"] == (
            rep["completed"] + rep["demoted"] + rep["rejected"] + rep["evacuated"]
        ), f"replica {rep['id']} ledger does not balance"


def test_failover_golden_loses_nothing():
    """The failover trace must show a replica crashing MID-BURST with
    work in hand — and every one of those requests re-dispatched exactly
    once and served elsewhere (zero lost)."""
    golden = _golden("fleet_failover")
    req = golden["requests"]
    assert golden["replicas"]["crashed"] == 1
    crash_events = [e for e in golden["scale_events"] if e["action"] == "crash"]
    assert len(crash_events) == 1
    # mid-burst: the preset's second storm covers [120, 135]
    assert 120.0 < crash_events[0]["t"] < 135.0
    # the crash actually evacuated work (queue + truncated in-flight batch)
    assert req["evacuated"] > 0
    assert req["redispatched"] == req["evacuated"]
    # exactly-once: nothing double-served, nothing lost
    assert req["served_twice"] == 0
    assert req["arrived"] == _unique_terminal_total(req)
    dead = [r for r in golden["per_replica"] if r["crashed"]]
    assert len(dead) == 1 and dead[0]["evacuated"] > 0


def test_autoscale_golden_scales_up_then_down():
    """One compressed virtual day: the autoscaler must ADD capacity on
    the morning ramp and DRAIN it after the evening tail — both
    directions in one committed trace."""
    golden = _golden("fleet_autoscale")
    events = golden["scale_events"]
    adds = [e["t"] for e in events if e["action"] == "add"]
    drains = [e["t"] for e in events if e["action"] == "drain"]
    assert adds, "autoscale golden never scaled up"
    assert drains, "autoscale golden never scaled down"
    assert min(adds) < min(drains), "scale-down before any scale-up"
    assert golden["replicas"]["peak_routable"] > golden["replicas"]["initial"]
    assert golden["replicas"]["drained"] == len(drains)
    # never below the floor, never above the ceiling (preset: 1..6)
    assert 1 <= golden["replicas"]["final_routable"] <= 6
    after = [e["replicas_after"] for e in events]
    assert all(1 <= n <= 6 for n in after)


def test_fleet_overload_beats_single_server_golden():
    """THE acceptance claim of the fleet tier: the same diurnal 12 Hz
    overload that drives the committed single-server golden to hundreds
    of queue-full refusals is absorbed by the 4-replica cache-affinity
    fleet with an interactive-class p99 under 5 virtual seconds and
    strictly fewer refusals."""
    fleet = _golden("fleet_overload")
    with open(os.path.join(GOLDEN_DIR, "serving_overload.json")) as f:
        single = json.load(f)
    # same storm on both sides: the comparison is capacity, not traffic
    assert fleet["process"] == single["process"] == "diurnal"
    assert fleet["requests"]["arrived"] == single["requests"]["arrived"]
    assert single["requests"]["refused"] > 0  # the single server does shed
    assert fleet["requests"]["refused"] < single["requests"]["refused"]
    p99 = fleet["classes"]["interactive"]["latency_ms"]["p99"]
    assert p99 < 5_000.0, f"fleet interactive p99 {p99} ms >= 5 virtual seconds"


class TestCachedGolden:
    """Acceptance claims of the artifact-cache trace (fleet_cached:
    4 replicas, Zipf(1.1) content skew over 256 volumes, 2% corrupt-
    entry faults, a 60-virtual-second cache outage at t=240). The
    byte-exact match lives in test_fleet_golden_trace_matches; these
    tests pin what the committed numbers must SHOW, so a regenerated
    golden that silently stopped exercising the cache fails review."""

    def test_conserves_with_coalesced_fifth_state(self):
        golden = _golden("fleet_cached")
        req = golden["requests"]
        assert req["conserved"] is True
        assert req["served_twice"] == 0
        # coalesced is the fifth terminal state of the cached ledger
        assert req["arrived"] == (
            _unique_terminal_total(req) + golden["cache"]["coalesced"]
        )
        for rep in golden["per_replica"]:
            assert rep["admitted"] == (
                rep["completed"] + rep["demoted"] + rep["rejected"]
                + rep["evacuated"] + rep["coalesced"]
            ), f"replica {rep['id']} ledger does not balance"

    def test_stampedes_actually_collapse(self):
        """N identical concurrent requests == 1 execution + N-1 coalesced:
        the burst storms must produce real single-flight collapsing, and
        the router must have steered identical content to its leader."""
        cache = _golden("fleet_cached")["cache"]
        assert cache["coalesced"] > 0, "no stampede collapsing in the golden"
        assert cache["inflight_hits"] == cache["coalesced"]
        assert cache["content_routes"] > 0, "router never steered to a leader"
        # every served-from-cache answer is an admission hit or a follower
        assert cache["served_from_cache"] == (
            cache["admission_hits"] + cache["coalesced"]
        )

    def test_corruption_is_quarantined_never_served(self):
        """THE integrity claim: the 2% corrupt-entry storm really poisoned
        entries, verification caught every one, and not a single corrupt
        byte reached a completion."""
        cache = _golden("fleet_cached")["cache"]
        assert cache["quarantined"] > 0, "the corruption storm never landed"
        assert cache["quarantined_served"] == 0, "CORRUPT BYTES WERE SERVED"

    def test_outage_fails_open_through_the_breaker(self):
        """The 60 s outage must show the full degradation ladder: consults
        lost, the breaker tripping, open-state skips — and zero lost
        requests (the conservation test above covers the same trace)."""
        cache = _golden("fleet_cached")["cache"]
        assert cache["unavailable"] > 0
        assert cache["breaker_trips"] >= 1
        assert cache["breaker_skips"] > 0, "open breaker never skipped a consult"

    def test_skew_makes_the_cache_earn_its_bytes(self):
        """Zipf(1.1) traffic must produce a real hit rate AND real byte
        pressure: the 2 MiB tier holds ~hundreds of artifacts of a
        256-volume universe, so LRU eviction must actually run."""
        golden = _golden("fleet_cached")
        cache = golden["cache"]
        assert cache["hit_rate"] > 0.3
        assert cache["evictions"] > 0, "capacity never pressured LRU"
        assert cache["bytes_stored"] <= 2 * 1024 * 1024
        # cache-served answers dominate device time saved: more than a
        # third of arrivals never touched (or re-touched) a device
        assert cache["served_from_cache"] > golden["requests"]["arrived"] / 3


def test_steady_golden_affinity_is_warm():
    """Under steady load the cache-affinity router must keep the hit
    rate high and compile each signature roughly once fleet-wide —
    that is the point of affinity over plain load balancing."""
    golden = _golden("fleet_steady")
    aff = golden["affinity"]
    assert aff["policy"] == "cache_affinity"
    assert aff["hit_rate"] > 0.8
    # signatures compile ~once each, not once per (replica, signature):
    # the standard mix resolves 5 signatures across 3 replicas
    assert aff["cold_compiles"] < 3 * 5
    assert golden["requests"]["refused"] == 0
    assert golden["requests"]["rejected"] == {}
