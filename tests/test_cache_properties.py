"""Property suite for the artifact-cache tier under arbitrary seeded
drives — the invariants that keep content addressing, single-flight, and
fault injection from corrupting the PR 5-7 serving guarantees:

  * **pinned in-flight never evicted**: whatever op sequence hits the
    store (begins, stores, reads, abandons) under byte pressure, a
    pinned placeholder survives until its leader completes or abandons,
    and the byte account always equals the sum of live entries;
  * **coalesced followers are byte-identical**: N identical concurrent
    requests produce exactly ONE device execution; every follower's
    record shares the leader's artifact checksum, status, and result;
  * **Zipf determinism**: the content-skew process is a pure function of
    (seed, index) — same seed -> byte-identical id streams and fleet
    summaries, different seeds diverge;
  * **conservation under cache-fault storms**: corrupt entries, outage
    windows, and slow consults never lose a request — every arrival
    reaches exactly one terminal outcome (coalesced included) and
    corrupt bytes are NEVER served (``quarantined_served == 0``).

Same double-drive structure as tests/test_resilience_properties.py: each
``_check_*`` body runs under hypothesis when importable (CI) AND under
an always-on deterministic grid (bare installs never skip)."""

import random

import pytest

from repro.serving.cache import (
    ArtifactCache,
    CacheConfig,
    artifact_bytes_modeled,
)
from repro.serving.fleet import (
    FleetConfig,
    FleetServiceModel,
    simulate_fleet,
)
from repro.serving.resilience import FaultPlan, FaultRule
from repro.serving.scheduler import PriorityClass, SchedulerConfig
from repro.serving.simulator import STANDARD_MIX, zipf_content_id

from test_cache import ok_record
from test_scheduler import make_sched, vol

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: the grid fallback below still runs
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=10, deadline=None)


def _cached_cfg(
    seed,
    burst_hz,
    replicas,
    skew,
    universe,
    corrupt_rate=0.0,
    outage=None,
    slow_rate=0.0,
    capacity=2 * 1024 * 1024,
    horizon_s=240.0,
):
    """A fleet with the shared artifact tier live under Zipf content skew
    and an optional cache-fault storm (corruption, an outage window,
    slow consults)."""
    rules = []
    if corrupt_rate > 0:
        rules.append(FaultRule(kind="corrupt_entry", rate=corrupt_rate))
    if outage is not None:
        rules.append(
            FaultRule(kind="cache_unavailable", rate=1.0, t0=outage[0], t1=outage[1])
        )
    if slow_rate > 0:
        rules.append(FaultRule(kind="slow_cache", rate=slow_rate, slow_factor=6.0))
    return FleetConfig(
        name="cache-prop",
        seed=seed,
        horizon_s=horizon_s,
        process="burst",
        process_kwargs={
            "base_hz": 2.0,
            "burst_hz": burst_hz,
            "period_s": 80.0,
            "burst_len_s": 12.0,
        },
        mix=STANDARD_MIX,
        replicas=replicas,
        policy="cache_affinity",
        scheduler=SchedulerConfig(
            max_queue_depth=64,
            admission_hbm_bytes=512 * 1024 * 1024,
            max_batch_requests=8,
            native_shapes=True,
            classes={
                "interactive": PriorityClass("interactive", 0, deadline_s=None),
                "standard": PriorityClass("standard", 1, deadline_s=None),
                "batch": PriorityClass("batch", 2, deadline_s=None),
            },
        ),
        service=FleetServiceModel(base_s=0.1, batch_overhead_s=0.05),
        cache=CacheConfig(
            capacity_bytes=capacity,
            breaker_trip_after=3,
            breaker_cooldown_s=30.0,
        ),
        content_skew=skew,
        content_universe=universe,
        fault_plan=FaultPlan(seed=seed, rules=tuple(rules)) if rules else None,
    )


# ------------------------------------------------------ invariant bodies ---


def _check_pinned_never_evicted(seed, n_ops, capacity_entries):
    """Arbitrary seeded op soup against a byte-pressured store: a pinned
    in-flight placeholder is NEVER an eviction victim, and after every
    single op the byte account equals the sum of live entries."""
    one = artifact_bytes_modeled((8, 8, 8))
    cache = ArtifactCache(CacheConfig(capacity_bytes=capacity_entries * 2 * one))
    rng = random.Random(seed)
    pinned: set = set()
    t = 0.0
    for i in range(n_ops):
        t += 1.0
        key = f"k{rng.randrange(3 * capacity_entries)}"
        op = rng.choice(("begin", "complete", "lookup", "abandon"))
        if op == "begin":
            if key not in cache.inflight:
                cache.begin(key, replica=0, now=t, est_bytes=one)
                pinned.add(key)
        elif op == "complete" and key in pinned:
            cache.complete(key, now=t, record=ok_record(), shape=(8, 8, 8))
            pinned.discard(key)
        elif op == "abandon" and key in pinned:
            cache.abandon(key)
            pinned.discard(key)
        else:
            cache.lookup(key, now=t, request_id=i)
        # THE invariant: every live pin still has its placeholder
        for p in pinned:
            assert p in cache.entries, f"pinned {p} evicted at op {i}"
            assert cache.inflight_owner(p) == 0
        assert cache.stats.bytes_stored == sum(
            e.nbytes for e in cache.entries.values()
        ), f"byte account diverged at op {i}"
    assert cache.stats.quarantined_served == 0


def _check_coalesced_followers_byte_identical(seed, n_followers):
    """N identical concurrent requests == 1 execution + N-1 coalesced
    completions, every follower sharing the leader's artifact checksum,
    status, and the SAME result object."""
    sched = make_sched(max_queue_depth=128)
    sched.cache = ArtifactCache()
    v = vol(seed=seed)
    ids = [sched.submit(v.copy(), arrival_s=0.0) for _ in range(n_followers + 1)]
    assert len(sched.queue) == 1  # exactly one leader queued
    now = 1.0
    while (b := sched.next_batch(now=now)) is not None:
        now = sched.run_batch(b, now=now)
    comps = {c.id: c for c in sched.completions if c.id in ids}
    outcomes = sorted(c.outcome for c in comps.values())
    assert outcomes == ["coalesced"] * n_followers + ["completed"]
    assert sched.stats.conserved()
    leader = next(c for c in comps.values() if c.outcome == "completed")
    for c in comps.values():
        assert c.record.status == leader.record.status
        assert (
            c.record.extra["artifact_checksum"]
            == leader.record.extra["artifact_checksum"]
        )
        assert c.result is leader.result  # the one artifact, not a copy
        assert c.record.cache_hit or c.outcome == "completed"
    assert sched.cache.stats.stores == 1


def _check_zipf_determinism(seed, s, n, count):
    """zipf_content_id is pure in (seed, index): same seed -> identical
    streams, different seeds diverge, ids stay in range, and the skew is
    real (the head id strictly out-draws the tail id for s > 0)."""
    a = [zipf_content_id(seed, i, s, n) for i in range(count)]
    b = [zipf_content_id(seed, i, s, n) for i in range(count)]
    assert a == b
    assert all(0 <= x < n for x in a)
    c = [zipf_content_id(seed + 1, i, s, n) for i in range(count)]
    assert a != c
    head = sum(1 for x in a if x == 0)
    tail = sum(1 for x in a if x == n - 1)
    assert head >= tail


def _check_same_seed_fleet_byte_identical(seed, replicas, skew):
    """Same (code, seed) -> byte-identical fleet summaries with the cache
    tier, Zipf skew, and a full fault storm all live."""
    import json

    runs = [
        json.dumps(
            simulate_fleet(
                _cached_cfg(
                    seed,
                    30.0,
                    replicas,
                    skew,
                    128,
                    corrupt_rate=0.05,
                    outage=(60.0, 100.0),
                    slow_rate=0.02,
                    horizon_s=160.0,
                )
            ).summary(),
            sort_keys=True,
        )
        for _ in range(2)
    ]
    assert runs[0] == runs[1]


def _check_conservation_under_cache_storm(
    seed, burst_hz, replicas, skew, corrupt_rate, outage
):
    """Whatever the cache-fault storm does — corruption quarantines,
    outage windows, breaker trips — every arrival reaches exactly one
    terminal outcome (coalesced is the fifth), per-replica ledgers
    balance, and corrupt bytes are NEVER served."""
    rep = simulate_fleet(
        _cached_cfg(
            seed,
            burst_hz,
            replicas,
            skew,
            96,
            corrupt_rate=corrupt_rate,
            outage=outage,
            capacity=512 * 1024,
        )
    )
    fl = rep.fleet
    assert fl.conserved()
    for r in fl.replicas:
        assert r.sched.stats.conserved(), f"replica {r.id}: {r.sched.stats}"
    s = rep.summary()
    req = s["requests"]
    unique_terminal = (
        req["refused"]
        + req["no_replica"]
        + req["completed"]
        + req["demoted"]
        + sum(req["rejected"].values())
        + s["cache"]["coalesced"]
    )
    assert req["arrived"] == unique_terminal
    assert s["cache"]["quarantined_served"] == 0
    if corrupt_rate > 0.02:
        assert s["cache"]["quarantined"] > 0  # the storm actually corrupted
    if outage is not None:
        assert s["cache"]["unavailable"] > 0  # ...and actually went down


# ------------------------------------------------- hypothesis exploration ---

if HAVE_HYPOTHESIS:

    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n_ops=st.integers(20, 120),
        capacity_entries=st.integers(1, 6),
    )
    def test_pinned_never_evicted(seed, n_ops, capacity_entries):
        _check_pinned_never_evicted(seed, n_ops, capacity_entries)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), n_followers=st.integers(1, 8))
    def test_coalesced_followers_byte_identical(seed, n_followers):
        _check_coalesced_followers_byte_identical(seed, n_followers)

    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        s=st.floats(0.5, 2.0),
        n=st.integers(4, 512),
        count=st.integers(50, 300),
    )
    def test_zipf_determinism(seed, s, n, count):
        _check_zipf_determinism(seed, s, n, count)

    @settings(max_examples=5, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        replicas=st.integers(1, 3),
        skew=st.floats(0.8, 1.4),
    )
    def test_same_seed_fleet_byte_identical(seed, replicas, skew):
        _check_same_seed_fleet_byte_identical(seed, replicas, skew)

    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        burst_hz=st.floats(10.0, 40.0),
        replicas=st.integers(1, 4),
        skew=st.floats(0.8, 1.5),
        corrupt_rate=st.floats(0.0, 0.1),
        outage=st.one_of(st.none(), st.just((60.0, 120.0))),
    )
    def test_conservation_under_cache_storm(
        seed, burst_hz, replicas, skew, corrupt_rate, outage
    ):
        _check_conservation_under_cache_storm(
            seed, burst_hz, replicas, skew, corrupt_rate, outage
        )


# ------------------------------------------------- deterministic fallback ---


class TestGridFallback:
    """Pinned corners of the cache property space — always executed, with
    or without hypothesis, so no environment silently skips the artifact
    tier's invariants."""

    @pytest.mark.parametrize(
        "seed,n_ops,capacity_entries",
        [(0, 60, 2), (1, 120, 1), (2, 80, 4), (3, 40, 6)],
    )
    def test_pinned_never_evicted(self, seed, n_ops, capacity_entries):
        _check_pinned_never_evicted(seed, n_ops, capacity_entries)

    @pytest.mark.parametrize("seed,n_followers", [(0, 1), (1, 4), (2, 8)])
    def test_coalesced_followers_byte_identical(self, seed, n_followers):
        _check_coalesced_followers_byte_identical(seed, n_followers)

    @pytest.mark.parametrize(
        "seed,s,n,count",
        [(0, 1.1, 256, 200), (1, 0.8, 16, 100), (2, 2.0, 64, 150)],
    )
    def test_zipf_determinism(self, seed, s, n, count):
        _check_zipf_determinism(seed, s, n, count)

    @pytest.mark.parametrize("seed,replicas,skew", [(0, 2, 1.1), (5, 3, 0.9)])
    def test_same_seed_fleet_byte_identical(self, seed, replicas, skew):
        _check_same_seed_fleet_byte_identical(seed, replicas, skew)

    @pytest.mark.parametrize(
        "seed,burst_hz,replicas,skew,corrupt_rate,outage",
        [
            (0, 30.0, 2, 1.1, 0.05, (60.0, 120.0)),
            (1, 40.0, 4, 1.3, 0.1, None),
            (2, 15.0, 1, 0.9, 0.0, (40.0, 80.0)),
            (3, 25.0, 3, 1.0, 0.03, (60.0, 100.0)),
        ],
    )
    def test_conservation_under_cache_storm(
        self, seed, burst_hz, replicas, skew, corrupt_rate, outage
    ):
        _check_conservation_under_cache_storm(
            seed, burst_hz, replicas, skew, corrupt_rate, outage
        )
