"""Distributed-path tests. jax locks the device count at first init, so
these run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
and assert over its output — the same mechanism the dry-run uses at 512.

The whole module skips on single-device hosts (rather than relying on CI
deselect lists): forcing 8 host-platform devices onto one physical core
makes the subprocess workloads pathologically slow/flaky, and the claims
under test (halo exchange, GSPMD value preservation) are multi-device
claims — H6 in EXPERIMENTS.md is explicitly "requires multi-device".

The CI ``distributed`` job opts back in by forcing 8 host devices on the
pytest process itself (so ``jax.device_count() >= 2`` and the skip lifts)
and sets ``REPRO_SMALL_SHAPES=1``, which shrinks the subprocess workloads
to shapes a single shared core can turn around quickly.
"""

import os
import subprocess
import sys

import jax
import pytest

pytestmark = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="multi-device claims; needs >= 2 real devices (EXPERIMENTS.md H6)",
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_spatial_shard_halo_inference_bit_exact():
    """The paper's patching mapped to a mesh: Z-slab halo exchange MeshNet
    == single-device full-volume inference, bit-exact."""
    out = _run(
        """
import os
import jax, jax.numpy as jnp
mesh = jax.make_mesh((2, 4), ("data", "model"))
from repro.core import meshnet, spatial_shard
from repro.core.meshnet import MeshNetConfig
cfg = MeshNetConfig()
p = meshnet.init(jax.random.PRNGKey(0), cfg)
# the small shape's 8-thick slabs are thinner than the d=16 halo, so the
# CI knob also exercises the multi-hop exchange through this API
shape = (2, 32, 8, 8) if os.environ.get("REPRO_SMALL_SHAPES") == "1" else (2, 64, 16, 16)
x = jax.random.normal(jax.random.PRNGKey(1), shape)
ref = meshnet.apply(p, x, cfg)
out = jax.jit(lambda p_, x_: spatial_shard.sharded_apply(p_, x_, cfg, mesh))(p, x)
print("MAXERR", float(jnp.abs(ref - out).max()))
"""
    )
    maxerr = float(out.split("MAXERR")[1].strip())
    assert maxerr == 0.0, maxerr


def test_sharded_train_step_matches_single_device():
    """One train step of the smoke tinyllama on an 8-device mesh equals the
    single-logical-device result (GSPMD semantics are value-preserving).

    Tolerances: sharding reorders float reductions, so the loss agrees to
    ~1e-4 relative, not bitwise; and one *Adam* step amplifies any grad
    element whose sign flips under that reordering into a ±lr parameter
    delta (at step 1, update = lr*sign(g) elementwise). The param bound
    is therefore 2*lr — tight enough to catch any wrong collective (those
    diverge at O(1e-1)), loose enough for float reordering.
    (REPRO_SMALL_SHAPES deliberately does not shrink T here: T=8 exposes
    a separate short-sequence divergence in the transformer stack,
    tracked independently of the GSPMD claim.)"""
    out = _run(
        """
import dataclasses, jax, jax.numpy as jnp
mesh = jax.make_mesh((2, 4), ("data", "model"))
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.launch import sharding, steps as steps_mod
from repro.models import model as MD
from repro.training import optimizer as opt_mod

cfg = dataclasses.replace(configs.get_smoke("tinyllama-1.1b"), dtype=jnp.float32)
params = MD.init(jax.random.PRNGKey(0), cfg)
opt = opt_mod.adamw_init(params, steps_mod.OPT_CONFIG)
B, T = 8, 16
key = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (B, T), 0, cfg.vocab_size)}
step = steps_mod.make_train_step(cfg)
p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

pspecs = sharding.param_specs(params, mesh)
ps = jax.device_put(params, sharding.to_named(pspecs, mesh))
os_ = jax.device_put(opt, sharding.to_named(sharding.opt_specs(opt, pspecs), mesh))
bs = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
with mesh:
    p_sh, _, m_sh = jax.jit(step)(ps, os_, bs)
d = max(float(jnp.abs(a - b).max()) for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)))
print("LOSSDIFF", abs(float(m_ref["loss"]) - float(m_sh["loss"])))
print("PARAMDIFF", d)
"""
    )
    loss_diff = float(out.split("LOSSDIFF")[1].split()[0])
    param_diff = float(out.split("PARAMDIFF")[1].split()[0])
    assert loss_diff < 1e-3, loss_diff
    lr = 3e-4  # steps_mod.OPT_CONFIG learning rate; see docstring
    assert param_diff <= 2 * lr * 1.01, param_diff


def test_sharded_decode_matches_single_device():
    out = _run(
        """
import dataclasses, jax, jax.numpy as jnp
mesh = jax.make_mesh((2, 4), ("data", "model"))
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.launch import sharding, steps as steps_mod
from repro.models import model as MD

cfg = dataclasses.replace(configs.get_smoke("tinyllama-1.1b"), dtype=jnp.float32)
params = MD.init(jax.random.PRNGKey(0), cfg)
B, S = 8, 16
cache = MD.init_cache(cfg, B, S)
tok = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab_size)
step = steps_mod.make_serve_step(cfg)
nt_ref, lg_ref, _ = jax.jit(step)(params, tok, cache, jnp.asarray(0, jnp.int32))

pspecs = sharding.param_specs(params, mesh)
ps = jax.device_put(params, sharding.to_named(pspecs, mesh))
cs = jax.device_put(cache, sharding.to_named(sharding.cache_specs(cache, mesh, B), mesh))
ts = jax.device_put(tok, NamedSharding(mesh, P("data", None)))
with mesh:
    nt_sh, lg_sh, _ = jax.jit(step)(ps, ts, cs, jnp.asarray(0, jnp.int32))
print("TOKMATCH", bool((nt_ref == nt_sh).all()))
print("LOGITDIFF", float(jnp.abs(lg_ref - lg_sh).max()))
"""
    )
    assert "TOKMATCH True" in out
    logit_diff = float(out.split("LOGITDIFF")[1].split()[0])
    assert logit_diff < 1e-3, logit_diff
