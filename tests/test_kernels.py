"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes, dtypes, dilations and channel widths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import meshnet
from repro.core.meshnet import MeshNetConfig
from repro.kernels import dice as dice_kernel
from repro.kernels import dilated_conv3d as conv_kernel
from repro.kernels import ops, ref
from repro.training import losses

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestDilatedConv3D:
    @pytest.mark.parametrize("dilation", [1, 2, 4, 8, 16])
    def test_dilation_sweep(self, dilation):
        x = _rand(KEY, (1, 32, 32, 32, 5), jnp.float32)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 3, 5, 5), jnp.float32) * 0.2
        b = _rand(jax.random.PRNGKey(2), (5,), jnp.float32) * 0.1
        out = conv_kernel.dilated_conv3d(x, w, b, dilation=dilation, interpret=True)
        expect = ref.dilated_conv3d(x, w, b, dilation=dilation)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=5e-5)

    @pytest.mark.parametrize(
        "cin,cout", [(1, 5), (5, 5), (5, 3), (21, 21), (10, 50)]
    )
    def test_channel_sweep(self, cin, cout):
        x = _rand(KEY, (1, 16, 16, 16, cin), jnp.float32)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 3, cin, cout), jnp.float32) * 0.1
        b = jnp.zeros((cout,))
        out = conv_kernel.dilated_conv3d(x, w, b, dilation=2, interpret=True)
        expect = ref.dilated_conv3d(x, w, b, dilation=2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=5e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        x = _rand(KEY, (1, 16, 16, 16, 5), dtype)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 3, 5, 5), dtype) * 0.2
        b = jnp.zeros((5,), dtype)
        out = conv_kernel.dilated_conv3d(x, w, b, dilation=4, interpret=True)
        expect = ref.dilated_conv3d(x, w, b, dilation=4)
        tol = 5e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
        )

    def test_batched(self):
        x = _rand(KEY, (3, 16, 16, 16, 5), jnp.float32)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 3, 5, 5), jnp.float32) * 0.2
        b = jnp.zeros((5,))
        out = conv_kernel.dilated_conv3d(x, w, b, dilation=2, interpret=True)
        expect = ref.dilated_conv3d(x, w, b, dilation=2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=5e-5)

    def test_fused_affine_relu_epilogue(self):
        x = _rand(KEY, (1, 16, 16, 16, 5), jnp.float32)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 3, 5, 5), jnp.float32) * 0.2
        b = jnp.zeros((5,))
        s = jnp.asarray([1.5, 0.5, 2.0, 1.0, 0.1])
        o = jnp.asarray([0.1, -0.2, 0.0, 0.3, -0.1])
        out = conv_kernel.dilated_conv3d(
            x, w, b, dilation=8, scale=s, offset=o, fuse_affine=True, interpret=True
        )
        expect = ref.dilated_conv3d(x, w, b, dilation=8, scale=s, offset=o, fuse_affine=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=5e-5)
        assert float(out.min()) >= 0.0  # ReLU applied

    def test_odd_shapes_via_ops_wrapper(self):
        x = _rand(KEY, (1, 24, 20, 28, 5), jnp.float32)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 3, 5, 5), jnp.float32) * 0.2
        b = jnp.zeros((5,))
        out = ops.dilated_conv3d(x, w, b, dilation=4, interpret=True)
        expect = ref.dilated_conv3d(x, w, b, dilation=4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=5e-5)

    def test_kernel_backed_meshnet_matches_reference_model(self):
        cfg = MeshNetConfig()
        p = meshnet.init(KEY, cfg)
        x = _rand(jax.random.PRNGKey(3), (1, 20, 24, 16), jnp.float32)
        out = ops.meshnet_apply(p, x, cfg, interpret=True)
        expect = meshnet.apply(p, x, cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)

    def test_vmem_budget(self):
        # The default block config must stay under a 16 MB VMEM budget.
        assert conv_kernel.vmem_bytes(16, 5, 5) < 16 * 1024 * 1024
        assert conv_kernel.vmem_bytes(16, 21, 21) < 16 * 1024 * 1024

    @pytest.mark.parametrize("dilation", [1, 4, 16])
    @pytest.mark.parametrize("fuse", [False, True])
    def test_haloed_load_bit_exact_vs_27_views(self, dilation, fuse):
        # The single haloed DMA schedule must reproduce the legacy 27-view
        # schedule bit-for-bit (identical tap order and accumulation).
        x = _rand(KEY, (2, 16, 16, 16, 5), jnp.float32)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 3, 5, 5), jnp.float32) * 0.2
        b = _rand(jax.random.PRNGKey(2), (5,), jnp.float32) * 0.1
        kw = dict(dilation=dilation, interpret=True, fuse_affine=fuse)
        if fuse:
            kw.update(scale=jnp.asarray([1.5, 0.5, 2.0, 1.0, 0.1]),
                      offset=jnp.asarray([0.1, -0.2, 0.0, 0.3, -0.1]))
        halo = conv_kernel.dilated_conv3d(x, w, b, variant="halo", **kw)
        views = conv_kernel.dilated_conv3d(x, w, b, variant="views", **kw)
        np.testing.assert_array_equal(np.asarray(halo), np.asarray(views))

    def test_vmem_bytes_views_counts_assembled_neighbourhood(self):
        # The views schedule materialises a (3*block)^3 assembled buffer on
        # top of the 27 streamed views; the estimate must include it (the
        # original formula undercounted the working set ~2x).
        views = conv_kernel.vmem_bytes(16, 5, 5, dilation=16, variant="views")
        assert views >= (27 + 27) * 16**3 * 5 * 4
        # ...and the haloed load's working set shrinks with the dilation.
        assert conv_kernel.vmem_bytes(16, 5, 5, dilation=1, variant="halo") < \
            conv_kernel.vmem_bytes(16, 5, 5, dilation=16, variant="halo") < views

    def test_vmem_guard_raises_with_suggested_block(self):
        with pytest.raises(ValueError, match=r"try block=\d+"):
            conv_kernel.check_vmem(64, 21, 21, dilation=8)
        assert conv_kernel.suggest_block(21, 21, dilation=8) == 32
        # the guard fires from the kernel entrypoint too, pre-pallas_call
        x = _rand(KEY, (1, 64, 64, 64, 21), jnp.float32)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 3, 21, 21), jnp.float32)
        with pytest.raises(ValueError, match="VMEM"):
            conv_kernel.dilated_conv3d(
                x, w, jnp.zeros((21,)), dilation=8, block=64, interpret=True
            )


class TestDecodeAttentionKernel:
    @pytest.mark.parametrize(
        "B,H,KV,hd,S,pos,blk",
        [
            (2, 8, 2, 32, 100, 57, 32),  # GQA 4x, ragged S, mid pos
            (1, 4, 4, 16, 64, 63, 64),  # MHA, single block, full cache
            (3, 16, 8, 64, 200, 10, 48),  # mostly-masked cache
            (1, 8, 1, 32, 96, 95, 32),  # MQA
        ],
    )
    def test_matches_oracle(self, B, H, KV, hd, S, pos, blk):
        from repro.kernels.decode_attention import decode_attention

        q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, hd))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
        out = decode_attention(q, k, v, jnp.asarray(pos, jnp.int32), block_s=blk)
        expect = ref.decode_attention(q, k, v, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)

    def test_bf16(self):
        from repro.kernels.decode_attention import decode_attention

        mk = lambda key, shape: jax.random.normal(key, shape, jnp.float32).astype(jnp.bfloat16)
        q = mk(jax.random.PRNGKey(0), (2, 1, 8, 32))
        k = mk(jax.random.PRNGKey(1), (2, 80, 4, 32))
        v = mk(jax.random.PRNGKey(2), (2, 80, 4, 32))
        out = decode_attention(q, k, v, jnp.asarray(40, jnp.int32), block_s=32)
        expect = ref.decode_attention(q, k, v, 40)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=3e-2
        )


class TestDiceKernel:
    @pytest.mark.parametrize("shape", [(8, 8, 8), (31, 33, 17), (5, 7, 11)])
    @pytest.mark.parametrize("classes", [2, 3, 5])
    def test_counts_match_oracle(self, shape, classes):
        pred = jax.random.randint(KEY, shape, 0, classes)
        truth = jax.random.randint(jax.random.PRNGKey(1), shape, 0, classes)
        counts = dice_kernel.dice_counts(pred, truth, classes, block=64, interpret=True)
        expect = ref.dice_counts(pred, truth, classes)
        np.testing.assert_array_equal(np.asarray(counts), np.asarray(expect))

    def test_dice_score_matches_losses(self):
        pred = jax.random.randint(KEY, (16, 16, 16), 0, 3)
        truth = jax.random.randint(jax.random.PRNGKey(1), (16, 16, 16), 0, 3)
        a = float(ops.dice(pred, truth, 3, interpret=True))
        b = float(losses.dice_score(pred, truth, 3))
        assert abs(a - b) < 1e-6

    def test_perfect_overlap(self):
        x = jax.random.randint(KEY, (12, 12, 12), 0, 4)
        assert float(ops.dice(x, x, 4, interpret=True)) == 1.0
