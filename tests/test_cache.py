"""Unit tests for the content-addressed artifact cache (serving/cache.py)
and its fault taxonomy (serving/errors.py): key derivation purity,
integrity quarantine (corrupt bytes NEVER served), negative-verdict TTL,
pinned-aware LRU eviction, the cache breaker's fail-open ladder, the
scheduler's single-flight coalescing, and the degenerate-volume guard the
conform stage grew alongside the cache (a cached artifact of a garbage
volume would be a poisoned well — the guard keeps it out of the store).

Everything runs on the virtual clock with modeled execution, so the whole
file is sub-second on CPU.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import conform as conform_mod
from repro.serving import cache as cache_mod
from repro.serving.cache import (
    ArtifactCache,
    CacheConfig,
    ConformMemo,
    artifact_bytes_modeled,
    artifact_key,
    content_hash,
    model_fingerprint,
)
from repro.serving.errors import (
    PERMANENT_FAULT,
    TRANSIENT_FAULT,
    CacheCorruptionError,
    CacheFault,
    CacheUnavailableError,
    PermanentExecutorError,
    TransientExecutorError,
    classify,
)
from repro.serving.resilience import FaultPlan, FaultRule
from repro.telemetry.analysis import cache_summary
from repro.telemetry.record import StageTimes, TelemetryRecord

from test_scheduler import make_sched, vol


def ok_record(request_id=0, **kw):
    defaults = dict(
        model="m",
        mode="full",
        status="ok",
        times=StageTimes(),
        executor="xla",
        precision="fp32",
        params_bytes=1000,
        request_id=request_id,
    )
    defaults.update(kw)
    return TelemetryRecord(**defaults)


def store_one(cache, key="k0", now=0.0, shape=(8, 8, 8), **rec_kw):
    cache.begin(key, replica=0, now=now, est_bytes=artifact_bytes_modeled(shape))
    return cache.complete(key, now=now, record=ok_record(**rec_kw), shape=shape)


# --------------------------------------------------------- fault taxonomy ---


class TestClassify:
    def test_transient_and_permanent_axis(self):
        assert classify(TransientExecutorError("preempted")) == TRANSIENT_FAULT
        assert classify(PermanentExecutorError("miscompiled")) == PERMANENT_FAULT
        assert classify(ValueError("garbage volume")) == PERMANENT_FAULT
        assert classify(RuntimeError("unknown")) == PERMANENT_FAULT

    def test_cache_faults_classify_transient(self):
        # fail-open in progress: recompute fixes corruption, and compute
        # does not need the cache — a retry genuinely helps
        assert classify(CacheCorruptionError("k", "a", "b")) == TRANSIENT_FAULT
        assert classify(CacheUnavailableError()) == TRANSIENT_FAULT
        assert issubclass(CacheCorruptionError, CacheFault)
        assert issubclass(CacheUnavailableError, CacheFault)

    @pytest.mark.parametrize(
        "exc", [KeyboardInterrupt(), SystemExit(1), GeneratorExit()]
    )
    def test_control_flow_base_exceptions_reraise(self, exc):
        # Ctrl-C must never become a served "permanent_fault" record
        with pytest.raises(type(exc)):
            classify(exc)

    def test_corruption_error_carries_evidence(self):
        e = CacheCorruptionError("deadbeef" * 4, "aaaa" * 8, "bbbb" * 8)
        assert e.key == "deadbeef" * 4
        assert e.expected != e.actual


# --------------------------------------------------------- key derivation ---


class TestKeyDerivation:
    def test_content_hash_is_pure_and_shape_aware(self):
        a = vol(seed=1)
        assert content_hash(a) == content_hash(a.copy())
        assert content_hash(a) != content_hash(vol(seed=2))
        # a reshaped view of the same bytes is a DIFFERENT volume
        assert content_hash(a) != content_hash(a.reshape(16, 8, 32))

    def test_stub_identity_and_uncacheable_none(self):
        class Stub:
            def __init__(self, shape, content_id=None):
                self.shape = shape
                self.content_id = content_id

        assert content_hash(Stub((16, 16, 16), 3)) == content_hash(
            Stub((16, 16, 16), 3)
        )
        assert content_hash(Stub((16, 16, 16), 3)) != content_hash(
            Stub((16, 16, 16), 4)
        )
        # no token and no bytes -> no identity -> cache bypass, never an
        # invented identity that aliases every request of one shape
        assert content_hash(Stub((16, 16, 16))) is None
        assert content_hash(object()) is None

    def test_artifact_key_separates_every_axis(self):
        c = content_hash(vol())
        fp = model_fingerprint("model-a")
        base = artifact_key(c, fp, "fp32", "full")
        assert base == artifact_key(c, fp, "fp32", "full")
        assert base != artifact_key(c, fp, "int8w", "full")
        assert base != artifact_key(c, fp, "fp32", "subvolume")
        assert base != artifact_key(c, model_fingerprint("model-b"), "fp32", "full")

    def test_artifact_bytes_one_label_byte_per_voxel(self):
        assert artifact_bytes_modeled((8, 8, 8)) == 512 + 256


# ---------------------------------------------------- integrity/quarantine ---


class TestIntegrity:
    def test_store_then_verified_hit(self):
        cache = ArtifactCache()
        checksum = store_one(cache)
        assert checksum is not None
        look = cache.lookup("k0", now=1.0)
        assert look.status == "hit"
        assert look.entry.checksum == checksum
        payload = cache.serve_payload(look.entry)
        assert payload["status"] == "ok"
        assert cache.stats.quarantined_served == 0

    def test_corrupt_entry_quarantined_never_served(self):
        cache = ArtifactCache()
        store_one(cache)
        entry = cache.entries["k0"]
        ArtifactCache._corrupt(entry)
        look = cache.lookup("k0", now=1.0)
        # verification catches the flip at lookup: quarantined + miss
        assert look.status == "miss"
        assert cache.stats.quarantined == 1
        assert "k0" not in cache.entries
        assert cache.stats.quarantined_served == 0
        assert cache.stats.bytes_stored == 0  # bytes credited back

    def test_serve_payload_double_guard_raises_typed(self):
        cache = ArtifactCache()
        store_one(cache)
        entry = cache.entries["k0"]
        ArtifactCache._corrupt(entry)
        # bypass lookup's verification to prove the serve-time guard holds
        with pytest.raises(CacheCorruptionError):
            cache.serve_payload(entry)
        assert cache.stats.quarantined_served == 1  # the breach IS counted

    def test_serve_payload_breach_quarantines_the_entry(self):
        cache = ArtifactCache()
        store_one(cache)
        entry = cache.entries["k0"]
        ArtifactCache._corrupt(entry)
        with pytest.raises(CacheCorruptionError):
            cache.serve_payload(entry)
        # the corrupt entry left the store WITH its bytes credited: the
        # breach path's recompute begins on a clean key, and no other
        # lookup can keep hitting the corrupt bytes
        assert "k0" not in cache.entries
        assert cache.stats.bytes_stored == 0
        assert cache.stats.quarantined == 1
        assert cache.lookup("k0", now=1.0).status == "miss"

    def test_injected_corrupt_store_is_caught_on_next_hit(self):
        # the fault window covers only the store: the poison lands at
        # rest and the CLEAN read path's verification must catch it
        plan = FaultPlan(
            seed=0, rules=(FaultRule(kind="corrupt_entry", rate=1.0, t1=0.5),)
        )
        cache = ArtifactCache(fault_plan=plan)
        store_one(cache)
        look = cache.lookup("k0", now=1.0)
        assert look.status == "miss"  # poisoned at rest, quarantined at read
        assert cache.stats.quarantined == 1
        assert cache.stats.quarantined_served == 0


# ---------------------------------------------------------- negative cache ---


class TestNegativeCache:
    def test_permanent_fault_negative_cached_with_ttl(self):
        cache = ArtifactCache(CacheConfig(negative_ttl_s=10.0))
        cache.begin("k0", replica=0, now=0.0, est_bytes=512)
        cache.complete(
            "k0",
            now=0.0,
            record=ok_record(status="fail", fail_type=PERMANENT_FAULT),
        )
        assert cache.stats.negative_stores == 1
        look = cache.lookup("k0", now=5.0)
        assert look.status == "negative"
        assert look.entry.fail_type == PERMANENT_FAULT
        # verdict expires: the signature is re-tested via compute
        look = cache.lookup("k0", now=10.0 + 1e-9)
        assert look.status == "miss"
        assert "k0" not in cache.entries

    def test_retryable_outcomes_are_never_cached(self):
        cache = ArtifactCache()
        for ft in (TRANSIENT_FAULT, "service_timeout"):
            cache.begin("k_" + ft, replica=0, now=0.0, est_bytes=512)
            cache.complete(
                "k_" + ft,
                now=0.0,
                record=ok_record(status="fail", fail_type=ft),
            )
        assert cache.stats.negative_stores == 0
        assert cache.stats.stores == 0
        assert not cache.entries  # placeholders gone, bytes balanced
        assert cache.stats.bytes_stored == 0


# ------------------------------------------------------------ LRU eviction ---


class TestEviction:
    def cache_of(self, capacity):
        return ArtifactCache(CacheConfig(capacity_bytes=capacity))

    def test_lru_order_is_deterministic(self):
        one = artifact_bytes_modeled((8, 8, 8)) + 200  # ~artifact size
        cache = self.cache_of(3 * one)
        for i, t in enumerate([0.0, 1.0, 2.0]):
            store_one(cache, key=f"k{i}", now=t)
        cache.lookup("k0", now=3.0)  # refresh k0: k1 is now LRU
        store_one(cache, key="k3", now=4.0)
        assert "k1" not in cache.entries and "k0" in cache.entries
        assert cache.stats.evictions >= 1
        assert cache.stats.bytes_stored <= cache.budget.bytes_limit

    def test_pinned_inflight_never_evicted(self):
        one = artifact_bytes_modeled((8, 8, 8))
        cache = self.cache_of(2 * one)
        cache.begin("lead", replica=0, now=0.0, est_bytes=one)
        # a store that would need the pinned bytes is REFUSED, not forced
        store_one(cache, key="big", now=1.0, shape=(12, 12, 12))
        assert "lead" in cache.entries  # the pin survived
        assert cache.inflight_owner("lead") == 0
        assert cache.stats.store_skips >= 1

    def test_oversized_artifact_is_refused(self):
        cache = self.cache_of(100)
        store_one(cache, key="huge", now=0.0, shape=(64, 64, 64))
        assert cache.stats.stores == 0
        assert cache.stats.store_skips == 1
        assert cache.stats.bytes_stored == 0

    def test_abandon_balances_the_byte_account(self):
        cache = ArtifactCache()
        cache.begin("k0", replica=0, now=0.0, est_bytes=4096)
        assert cache.stats.bytes_stored == 4096
        cache.abandon("k0")
        cache.abandon("k0")  # failover paths may abandon twice
        assert cache.stats.bytes_stored == 0
        assert cache.inflight_owner("k0") is None

    def test_last_writer_wins_store_credits_the_displaced_entry(self):
        cache = ArtifactCache()
        store_one(cache, key="k0", now=0.0)
        store_one(cache, key="k0", now=1.0)  # overwrite, same key
        assert cache.stats.stores == 2
        # the displaced entry's bytes were credited back: the account
        # holds exactly one entry's worth, not two
        assert cache.stats.bytes_stored == cache.entries["k0"].nbytes

    def test_stale_leader_complete_cannot_steal_the_current_pin(self):
        one = artifact_bytes_modeled((8, 8, 8))
        cache = ArtifactCache()
        cache.begin("k", replica=0, now=0.0, est_bytes=one)
        cache.abandon("k")  # replica 0 evacuated: its pin is gone
        cache.begin("k", replica=1, now=1.0, est_bytes=one)
        # the stale leader's complete is last-writer-wins on the STORE,
        # but the current leader's pin must survive it
        cache.complete(
            "k", now=2.0, record=ok_record(), shape=(8, 8, 8), replica=0
        )
        assert cache.inflight_owner("k") == 1
        cache.complete(
            "k", now=3.0, record=ok_record(), shape=(8, 8, 8), replica=1
        )
        assert cache.inflight_owner("k") is None
        # after both stores the byte account holds exactly one entry
        assert cache.stats.bytes_stored == cache.entries["k"].nbytes
        assert cache.lookup("k", now=4.0).status == "hit"


# ------------------------------------------------------- fail-open breaker ---


class TestFailOpen:
    def outage_cache(self, t0=0.0, t1=1e9, trip_after=3, cooldown_s=30.0):
        plan = FaultPlan(
            seed=0,
            rules=(FaultRule(kind="cache_unavailable", rate=1.0, t0=t0, t1=t1),),
        )
        return ArtifactCache(
            CacheConfig(breaker_trip_after=trip_after, breaker_cooldown_s=cooldown_s),
            fault_plan=plan,
        )

    def test_unavailable_answers_fail_open_then_trip(self):
        cache = self.outage_cache()
        for i in range(3):
            assert cache.lookup("k", now=float(i), request_id=i).status == "unavailable"
        assert cache.breaker.open and cache.breaker.trips == 1
        # open breaker: consults are skipped entirely (bypass, no tax)
        assert cache.lookup("k", now=3.0, request_id=3).status == "bypass"
        assert cache.stats.breaker_skips == 1
        assert cache.stats.unavailable == 3

    def test_half_open_probe_recloses_after_outage(self):
        cache = self.outage_cache(t1=10.0, cooldown_s=5.0)
        for i in range(3):
            cache.lookup("k", now=float(i), request_id=i)
        assert cache.breaker.open
        # probe inside the outage window: still down, cooldown restarts
        assert cache.lookup("k", now=8.0, request_id=10).status == "unavailable"
        assert cache.breaker.open
        # probe after the outage: healthy answer closes the breaker
        assert cache.lookup("k", now=14.0, request_id=11).status == "miss"
        assert not cache.breaker.open

    def test_slow_cache_degrades_latency_not_correctness(self):
        plan = FaultPlan(
            seed=0,
            rules=(FaultRule(kind="slow_cache", rate=1.0, slow_factor=8.0),),
        )
        cache = ArtifactCache(fault_plan=plan)
        store_one(cache)
        look = cache.lookup("k0", now=1.0)
        assert look.status == "hit"  # the answer is still correct
        assert look.slow_factor == 8.0
        assert cache.stats.slow_consults >= 1

    def test_store_during_outage_is_skipped_not_raised(self):
        cache = self.outage_cache()
        checksum = store_one(cache)
        assert checksum is None
        assert cache.stats.store_skips == 1
        assert not cache.entries


# ------------------------------------------- scheduler integration (unit) ---


class TestSchedulerCache:
    def cached_sched(self, cache=None, **cfg_kwargs):
        cfg_kwargs.setdefault("max_queue_depth", 64)
        sched = make_sched(**cfg_kwargs)
        sched.cache = cache or ArtifactCache()
        return sched

    def drain_all(self, sched, now=10.0):
        while True:
            b = sched.next_batch(now=now)
            if b is None:
                return
            now = sched.run_batch(b, now=now)

    def test_single_flight_collapses_identical_concurrent(self):
        sched = self.cached_sched()
        v = vol(seed=7)
        ids = [sched.submit(v.copy(), arrival_s=0.0) for _ in range(3)]
        assert len(sched.queue) == 1  # one leader; followers never queue
        self.drain_all(sched)
        outcomes = {c.id: c.outcome for c in sched.completions}
        assert sorted(outcomes[i] for i in ids) == [
            "coalesced",
            "coalesced",
            "completed",
        ]
        assert sched.stats.coalesced == 2
        assert sched.stats.conserved()
        # byte-identical artifacts: one checksum on every record
        sums = {
            r.extra["artifact_checksum"]
            for r in sched.engine.log.records
            if "artifact_checksum" in r.extra
        }
        assert len(sums) == 1

    def test_later_identical_request_hits_in_o_hash(self):
        sched = self.cached_sched()
        v = vol(seed=7)
        sched.submit(v.copy(), arrival_s=0.0)
        self.drain_all(sched)
        rid = sched.submit(v.copy(), arrival_s=20.0)
        hit = next(c for c in sched.completions if c.id == rid)
        assert hit.outcome == "completed"
        assert hit.record.cache_hit is True
        assert hit.record.service_s == pytest.approx(sched.cache.cfg.verify_s)
        assert sched.stats.cache_hits == 1
        assert sched.stats.conserved()

    def test_cancelled_leader_requeues_followers(self):
        sched = self.cached_sched()
        v = vol(seed=3)
        lead = sched.submit(v.copy(), arrival_s=0.0)
        sched.submit(v.copy(), arrival_s=0.0)
        assert sched.cancel(lead) is not None
        # the follower re-entered the queue as an independent request
        assert len(sched.queue) == 1 and not sched._followers
        assert sched.cache.inflight_owner(sched.queue[0].cache_key) is None
        self.drain_all(sched)
        assert sched.stats.conserved()

    def test_evacuation_tears_down_single_flight_state(self):
        sched = self.cached_sched()
        v = vol(seed=3)
        sched.submit(v.copy(), arrival_s=0.0)
        sched.submit(v.copy(), arrival_s=0.0)
        out = sched.evacuate(now=0.0)
        assert len(out) == 2  # leader AND follower handed back
        assert not sched.cache.inflight and not sched._followers
        assert sched.stats.conserved()

    def test_demoted_leader_never_stores_under_admission_key(self):
        """The artifact key is derived from the admission-resolved
        (mode, precision); admission demotion changes the mode AFTER
        that derivation, so a demoted leader must release its lead —
        a subvolume artifact stored under the full-mode key would be
        silently served to every future full-mode request."""
        probe = make_sched()
        full = probe._price("full", (32, 32, 32), "fp32")
        sub = probe._price("subvolume", (32, 32, 32), "fp32")
        assert sub < full
        cache = ArtifactCache()
        # cap between the two prices: the seed demotes at batch formation
        sched = self.cached_sched(
            cache=cache, admission_hbm_bytes=(sub + full) // 2
        )
        v = vol(shape=(32, 32, 32), seed=11)
        sched.submit(v.copy(), mode="full", arrival_s=0.0)
        sched.submit(v.copy(), mode="full", arrival_s=0.0)  # follower
        ckey = sched.queue[0].cache_key
        assert ckey is not None
        self.drain_all(sched)
        # the lead was released at demotion time: nothing stored under
        # the full-mode key, the pin is gone, and the follower computed
        # independently instead of coalescing onto the demoted artifact
        assert ckey not in cache.entries
        assert cache.stats.stores == 0
        assert not cache.inflight
        assert sched.stats.coalesced == 0
        assert sched.stats.demoted == 2
        assert sched.stats.conserved()
        assert cache.lookup(ckey, now=100.0).status == "miss"

    def test_leader_retry_exhaustion_frees_followers(self):
        """A leader that exhausts its retry budget on TRANSIENT faults
        must not stamp its followers failed: they re-enter the queue
        with their own budgets (one leader's bad luck is not a property
        of the content). A permanent fault still coalesces — that
        verdict IS content-determined and would be negative-cached."""
        from repro.serving.resilience import ResiliencePolicy, RetryPolicy
        from repro.serving.scheduler import RequestScheduler, SchedulerConfig
        from repro.serving.simulator import ServiceModel, VirtualClock
        from test_scheduler import make_engine

        sched = RequestScheduler(
            make_engine(),
            SchedulerConfig(native_shapes=True),
            clock=VirtualClock(),
            service_model=ServiceModel(),
            execute=False,
            resilience=ResiliencePolicy(
                retry=RetryPolicy(max_attempts=2, seed=0), breaker=None
            ),
            fault_plan=FaultPlan(
                seed=0, rules=(FaultRule(kind="transient", rate=1.0),)
            ),
            cache=ArtifactCache(),
        )
        v = vol(seed=5)
        sched.submit(v.copy(), arrival_s=0.0)
        fol = sched.submit(v.copy(), arrival_s=0.0)
        assert sched._followers  # it really attached before the storm
        comps = {c.id: c for c in sched.drain()}
        assert sched.stats.coalesced == 0
        # the follower served independently and spent its OWN budget
        assert comps[fol].outcome == "completed"
        assert comps[fol].record.cache_hit is False
        assert comps[fol].record.fail_type == TRANSIENT_FAULT
        assert comps[fol].record.attempt == 1
        assert sched.stats.conserved()
        # nothing cached, nothing pinned: a retryable verdict is not
        # a verdict about the content
        assert not sched.cache.inflight
        assert sched.cache.stats.stores == 0
        assert sched.cache.stats.negative_stores == 0

    def test_cache_summary_rollup_recovers_the_split(self):
        sched = self.cached_sched()
        v = vol(seed=7)
        for _ in range(3):
            sched.submit(v.copy(), arrival_s=0.0)
        self.drain_all(sched)
        sched.submit(v.copy(), arrival_s=20.0)
        s = cache_summary(
            sched.engine.log.records, store_stats=sched.cache.summary()
        )
        assert s.requests == 4
        assert s.coalesced == 2
        assert s.admission_hits == 1
        assert s.cache_served == 3 and s.computed == 1
        assert s.store_stats["quarantined_served"] == 0


# ------------------------------------------------- degenerate-volume guard ---


class TestDegenerateVolume:
    def test_constant_3d_volume_raises_typed(self):
        for bad in (
            np.zeros((8, 8, 8), np.float32),
            np.full((8, 8, 8), 7.0, np.float32),
            np.full((8, 8, 8), np.nan, np.float32),
        ):
            with pytest.raises(conform_mod.DegenerateVolumeError):
                conform_mod.conform(bad, (8, 8, 8))

    def test_non_3d_garbage_keeps_its_legacy_path(self):
        # the serving tier's garbage classification depends on resample
        # raising a plain ValueError for malformed payloads
        with pytest.raises(ValueError) as ei:
            conform_mod.conform(np.zeros((7,), np.float32), (8, 8, 8))
        assert not isinstance(ei.value, conform_mod.DegenerateVolumeError)

    def test_pipeline_converts_to_failed_record(self):
        from repro.core import pipeline as pipeline_mod

        eng = make_sched().engine
        res = pipeline_mod.run(eng.cfg, eng.params, np.zeros((16, 16, 16), np.float32))
        assert res.segmentation is None
        assert res.record.status == "fail"
        assert res.record.fail_type == "degenerate_volume"

    def test_degenerate_volume_is_permanent_through_serving(self):
        sched = make_sched(execute=True)
        sched.cache = ArtifactCache()
        sched.submit(np.zeros((16, 16, 16), np.float32), arrival_s=0.0)
        b = sched.next_batch(now=0.0)
        sched.run_batch(b, now=0.0)
        rec = next(r for r in sched.engine.log.records if r.request_id is not None)
        assert rec.status == "fail"
        assert rec.fail_type == "degenerate_volume"
        assert sched.stats.conserved()


# ------------------------------------------------------------ conform memo ---


class TestConformMemo:
    def test_fifo_bound_and_content_keying(self):
        memo = ConformMemo(max_entries=2)
        vols = [vol(seed=i) for i in range(3)]
        for i, v in enumerate(vols):
            memo.put(v, (16, 16, 16), i)
        assert memo.get(vols[0], (16, 16, 16)) is None  # FIFO-evicted
        assert memo.get(vols[2], (16, 16, 16)) == 2
        # same bytes, different target shape: a different conform
        assert memo.get(vols[2], (8, 8, 8)) is None

    def test_identity_less_volumes_are_bypassed(self):
        memo = ConformMemo()

        class NoIdentity:
            shape = (16, 16, 16)

        memo.put(NoIdentity(), (16, 16, 16), "x")
        assert not memo.entries
        assert memo.get(NoIdentity(), (16, 16, 16)) is None
