"""Unit + golden tests for the resilience layer (serving/errors.py,
serving/resilience.py, and its threading through scheduler/fleet).

Three tiers:

  * policy-object unit tests — backoff shape and determinism, breaker
    state machine, ladder demotion through the executor registry, fault
    plans as pure functions of (seed, identity), config validation;
  * single-scheduler behavior — retries recover transients with the
    ORIGINAL arrival preserved, timeouts reap stuck members, breakers
    demote a poisoned signature and half-open probes restore it;
  * the committed fault-storm golden — tests/golden/fleet_faultstorm.json
    asserted byte-exactly, plus the semantic acceptance claims the trace
    must keep showing (recovery >= 90%, ladder demotion of the poisoned
    signature, zero lost / zero double-served).

Regenerate the golden (ONLY on intentional behavior change):

    PYTHONPATH=src python -c "
    from repro.serving.fleet import simulate_fleet, fleet_preset
    rep = simulate_fleet(fleet_preset('fleet_faultstorm', seed=0))
    open('tests/golden/fleet_faultstorm.json', 'w').write(rep.to_json() + '\\n')"
"""

import collections
import dataclasses
import json
import os

import pytest

from repro.serving.errors import (
    PERMANENT_FAULT,
    SERVICE_TIMEOUT,
    TRANSIENT_FAULT,
    PermanentExecutorError,
    ResilienceConfigError,
    TransientExecutorError,
    classify,
)
from repro.serving.resilience import (
    LADDER,
    BreakerConfig,
    FaultPlan,
    FaultRule,
    HedgePolicy,
    ResiliencePolicy,
    RetryPolicy,
    SignatureBreaker,
    demote_rung,
    unit_hash,
)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

# a stand-in dispatch signature for breaker unit tests: same attribute
# surface as scheduler.GroupKey, hashable, no engine required
Key = collections.namedtuple("Key", "mode executor devices precision shape")


def _key(executor="xla", mode="streaming", precision="fp32", shape=(32, 32, 32)):
    return Key(mode=mode, executor=executor, devices=None,
               precision=precision, shape=shape)


# ------------------------------------------------------------- taxonomy ---


def test_classify_taxonomy():
    assert classify(TransientExecutorError("blip")) == TRANSIENT_FAULT
    assert classify(PermanentExecutorError("poison")) == PERMANENT_FAULT
    # unknown exceptions classify conservatively: no blind retries
    assert classify(ValueError("who knows")) == PERMANENT_FAULT
    assert classify(RuntimeError("nor this")) == PERMANENT_FAULT


# ------------------------------------------------------------ unit_hash ---


def test_unit_hash_deterministic_and_uniform_range():
    draws = [unit_hash("fault", 0, i) for i in range(1000)]
    assert all(0.0 <= u < 1.0 for u in draws)
    assert draws == [unit_hash("fault", 0, i) for i in range(1000)]
    # different identities decorrelate (coarse sanity, not a statistics test)
    assert 0.4 < sum(draws) / len(draws) < 0.6
    assert unit_hash("a", 1) != unit_hash("a", 2)


# ---------------------------------------------------------------- retry ---


def test_backoff_grows_exponentially_and_caps():
    p = RetryPolicy(max_attempts=6, backoff_base_s=0.1, backoff_mult=2.0,
                    backoff_max_s=0.4, jitter_frac=0.0)
    assert p.backoff_s(1, 0, 0) == pytest.approx(0.1)
    assert p.backoff_s(2, 0, 0) == pytest.approx(0.2)
    assert p.backoff_s(3, 0, 0) == pytest.approx(0.4)
    assert p.backoff_s(5, 0, 0) == pytest.approx(0.4)  # capped


def test_backoff_jitter_is_bounded_and_deterministic():
    p = RetryPolicy(backoff_base_s=1.0, backoff_mult=1.0, backoff_max_s=1.0,
                    jitter_frac=0.25, seed=7)
    vals = [p.backoff_s(1, 0, rid) for rid in range(200)]
    assert all(0.75 <= v <= 1.25 for v in vals)
    assert len(set(vals)) > 100  # jitter actually varies per request
    assert vals == [p.backoff_s(1, 0, rid) for rid in range(200)]


@pytest.mark.parametrize(
    "kwargs",
    [
        {"max_attempts": 0},
        {"backoff_mult": 0.0},
        {"backoff_base_s": -1.0},
        {"jitter_frac": 1.0},
        {"jitter_frac": -0.1},
    ],
)
def test_retry_policy_validates(kwargs):
    with pytest.raises(ResilienceConfigError):
        RetryPolicy(**kwargs)


def test_hedge_and_breaker_configs_validate():
    with pytest.raises(ResilienceConfigError):
        HedgePolicy(p99_factor=0.0)
    with pytest.raises(ResilienceConfigError):
        HedgePolicy(max_hedges=0)
    with pytest.raises(ResilienceConfigError):
        BreakerConfig(trip_after=0)
    with pytest.raises(ResilienceConfigError):
        BreakerConfig(cooldown_s=-1.0)


# -------------------------------------------------------------- breaker ---


def test_breaker_trips_after_consecutive_faults_only():
    br = SignatureBreaker(BreakerConfig(trip_after=3, cooldown_s=10.0))
    k = _key()
    br.on_result(k, fault=True, probe=False, now=1.0)
    br.on_result(k, fault=True, probe=False, now=2.0)
    br.on_result(k, fault=False, probe=False, now=3.0)  # resets the streak
    br.on_result(k, fault=True, probe=False, now=4.0)
    br.on_result(k, fault=True, probe=False, now=5.0)
    assert br.trips == 0 and br.peek_rung(k, 5.0) == 0
    br.on_result(k, fault=True, probe=False, now=6.0)  # third consecutive
    assert br.trips == 1
    assert br.peek_rung(k, 6.0) == 1
    assert br.open_signature_labels() == ["streaming/xla/fp32/32x32x32"]


def test_breaker_half_open_probe_restores_or_reopens():
    br = SignatureBreaker(BreakerConfig(trip_after=1, cooldown_s=10.0))
    k = _key()
    br.on_result(k, fault=True, probe=False, now=0.0)
    assert br.effective_rung(k, 5.0) == (1, False)  # still cooling down
    # cooldown elapsed: exactly ONE probe slot at the base rung
    rung, probe = br.effective_rung(k, 10.0)
    assert (rung, probe) == (0, True)
    assert br.effective_rung(k, 10.0) == (1, False)  # slot already claimed
    # probe fails -> re-open for a fresh cooldown
    br.on_result(k, fault=True, probe=True, now=11.0)
    assert br.effective_rung(k, 15.0) == (1, False)
    # second probe succeeds -> fast path fully restored
    rung, probe = br.effective_rung(k, 21.0)
    assert (rung, probe) == (0, True)
    br.on_result(k, fault=False, probe=True, now=22.0)
    assert br.restores == 1
    assert br.effective_rung(k, 23.0) == (0, False)
    assert br.open_signature_labels() == []
    states = [tr["state"] for tr in br.transitions]
    assert states == ["open", "half_open", "open", "half_open", "closed"]


def test_breaker_peek_does_not_claim_probe_slot():
    br = SignatureBreaker(BreakerConfig(trip_after=1, cooldown_s=1.0))
    k = _key()
    br.on_result(k, fault=True, probe=False, now=0.0)
    assert br.peek_rung(k, 2.0) == 0  # a probe WOULD run...
    assert br.peek_rung(k, 2.0) == 0  # ...and peeking again still says so
    assert br.effective_rung(k, 2.0) == (0, True)  # claim
    assert br.peek_rung(k, 2.0) == 1  # now the slot is taken


def test_breaker_walks_repeated_trips_down_the_ladder():
    br = SignatureBreaker(BreakerConfig(trip_after=1, cooldown_s=1e9))
    k = _key()
    for i in range(3):
        br.on_result(k, fault=True, probe=False, now=float(i))
    assert br.trips == 3
    assert br.peek_rung(k, 3.0) == 3


# ----------------------------------------------------------------- ladder ---


def test_demote_rung_walks_executor_ladder_then_mode():
    from repro.serving.scheduler import GroupKey
    from repro.serving.simulator import reference_engine

    engine = reference_engine()
    work = (engine.cfg.cube + 2 * engine.cfg.overlap,) * 3
    key = GroupKey(mode="full", executor="pallas_fused", devices=None,
                   precision="fp32", shape=work)
    seen = [(key.mode, key.executor)]
    while True:
        key = demote_rung(key, engine)
        if key is None:
            break
        seen.append((key.mode, key.executor))
    modes = [m for m, _ in seen]
    # executor rungs first, then exactly one mode demotion to the failsafe
    assert modes[-1] == "subvolume"
    assert modes.count("subvolume") == 1
    execs = [e for m, e in seen if m != "subvolume"]
    order = [LADDER.index(e) for e in execs if e in LADDER]
    assert order == sorted(order) and len(set(order)) == len(order)


# ------------------------------------------------------------ fault plans ---


def test_fault_plan_is_pure_and_first_match_wins():
    plan = FaultPlan(seed=3, rules=(
        FaultRule(kind="permanent", rate=1.0, executor_substr="xla"),
        FaultRule(kind="transient", rate=1.0),
    ))
    k = _key(executor="xla")
    d = plan.decide(t=1.0, replica=0, key=k, request_id=5, attempt=0)
    assert d.kind == "permanent" and d.rule_index == 0
    # same identity -> same verdict, forever
    assert plan.decide(t=1.0, replica=0, key=k, request_id=5, attempt=0) == d
    # a non-matching signature falls through to the later rule
    d2 = plan.decide(t=1.0, replica=0, key=_key(executor="streaming"),
                     request_id=5, attempt=0)
    assert d2.kind == "transient" and d2.rule_index == 1


def test_fault_plan_windows_and_rate_coin():
    plan = FaultPlan(seed=0, rules=(
        FaultRule(kind="transient", rate=0.5, t0=10.0, t1=20.0),
    ))
    k = _key()
    assert plan.decide(t=5.0, replica=0, key=k, request_id=1, attempt=0) is None
    assert plan.decide(t=20.0, replica=0, key=k, request_id=1, attempt=0) is None
    hits = sum(
        plan.decide(t=15.0, replica=0, key=k, request_id=r, attempt=0)
        is not None
        for r in range(1000)
    )
    assert 400 < hits < 600  # the seeded coin respects the rate
    # retried attempts re-roll: SOME faulted first attempts pass on retry
    rerolls = sum(
        plan.decide(t=15.0, replica=0, key=k, request_id=r, attempt=0)
        is not None
        and plan.decide(t=15.0, replica=0, key=k, request_id=r, attempt=1)
        is None
        for r in range(1000)
    )
    assert rerolls > 100


def test_fault_rule_validates():
    with pytest.raises(ResilienceConfigError):
        FaultRule(kind="gremlin")
    with pytest.raises(ResilienceConfigError):
        FaultRule(kind="transient", rate=1.5)
    with pytest.raises(ResilienceConfigError):
        FaultRule(kind="straggler", slow_factor=0.5)


def test_stuck_faults_require_timeouts_everywhere():
    from repro.serving.simulator import SimConfig, reference_engine, simulate

    cfg = SimConfig(
        horizon_s=30.0,
        fault_plan=FaultPlan(seed=0, rules=(FaultRule(kind="stuck", rate=0.01),)),
        resilience=ResiliencePolicy(service_timeout_s={"interactive": 5.0}),
    )
    with pytest.raises(ResilienceConfigError, match="stuck"):
        simulate(reference_engine(), cfg)


# ------------------------------------------------- scheduler integration ---


def _sim(rules, policy, horizon_s=240.0, seed=0):
    from repro.serving.simulator import preset, reference_engine, simulate

    cfg = dataclasses.replace(
        preset("steady", seed=seed, horizon_s=horizon_s),
        resilience=policy,
        fault_plan=FaultPlan(seed=seed, rules=tuple(rules)),
    )
    return simulate(reference_engine(), cfg)


def test_transient_faults_recover_via_retry():
    rep = _sim(
        [FaultRule(kind="transient", rate=0.15)],
        ResiliencePolicy(retry=RetryPolicy(max_attempts=3, seed=0),
                         breaker=None),
    )
    s = rep.summary()
    r = s["resilience"]
    assert s["requests"]["conserved"] is True
    assert r["faults"]["transient"] > 0
    assert r["retries"] > 0
    assert r["recovery_rate"] >= 0.9
    # every terminal completion is unique per request id
    ids = [c.id for c in rep.completions]
    assert len(ids) == len(set(ids))


def test_retry_preserves_original_arrival_identity():
    """wait + service == finish - arrival must hold on EVERY attempt —
    retried attempts keep the original arrival stamp, so queue age
    travels with the request through its backoff."""
    rep = _sim(
        [FaultRule(kind="transient", rate=0.2)],
        ResiliencePolicy(retry=RetryPolicy(max_attempts=4, seed=1),
                         breaker=None),
        seed=1,
    )
    retried = [r for r in rep.scheduler.engine.log.records if r.attempt > 0]
    assert retried, "scenario produced no retried attempts"
    for rec in retried:
        assert rec.queue_wait_s + rec.service_s == pytest.approx(
            (rec.queue_wait_s + rec.arrival_s + rec.service_s) - rec.arrival_s
        )
        # a retry cannot start before its backoff gate: wait covers it
        assert rec.queue_wait_s > 0.0


def test_permanent_faults_never_retry():
    rep = _sim(
        [FaultRule(kind="permanent", rate=0.1)],
        ResiliencePolicy(retry=RetryPolicy(max_attempts=5, seed=0),
                         breaker=None),
    )
    r = rep.summary()["resilience"]
    assert r["faults"]["permanent"] > 0
    assert r["retries"] == 0
    assert r["recovery_rate"] == 0.0


def test_timeouts_reap_stuck_members_and_retry():
    rep = _sim(
        [FaultRule(kind="stuck", rate=0.05)],
        ResiliencePolicy(
            retry=RetryPolicy(max_attempts=3, seed=0),
            service_timeout_s={"interactive": 5.0, "standard": 5.0,
                               "batch": 5.0},
            breaker=None,
        ),
    )
    s = rep.summary()
    r = s["resilience"]
    assert s["requests"]["conserved"] is True
    assert r["faults"]["timeout"] > 0
    # a timed-out attempt is charged exactly the class bound
    timed = [
        rec for rec in rep.scheduler.engine.log.records
        if rec.fail_type == SERVICE_TIMEOUT
    ]
    assert timed and all(rec.service_s == 5.0 for rec in timed)
    assert r["recovery_rate"] >= 0.9  # the stuck coin re-rolls per attempt


def test_breaker_demotes_poisoned_signature_to_serving_rung():
    rep = _sim(
        [FaultRule(kind="permanent", rate=1.0, executor_substr="xla",
                   shape=(32, 32, 32), precision="int8w")],
        ResiliencePolicy(
            retry=RetryPolicy(max_attempts=2, seed=0),
            breaker=BreakerConfig(trip_after=3, cooldown_s=1e6),
        ),
        horizon_s=600.0,
    )
    s = rep.summary()
    r = s["resilience"]
    assert r["breaker"]["trips"] >= 1
    assert "streaming/xla/int8w/32x32x32" in r["breaker"]["open_signatures"]
    # after the trip, requests of the poisoned signature COMPLETE at the
    # demoted rung (xla -> streaming): that is what degradation buys
    assert r["rungs"].get("streaming/streaming", 0) > 0
    # and the storm stopped failing once demoted: late permanent faults
    # stop accumulating (cooldown is effectively infinite => no probes)
    assert r["breaker"]["probes"] == 0
    assert s["requests"]["conserved"] is True


def test_breaker_half_open_probe_restores_after_window():
    rep = _sim(
        [FaultRule(kind="permanent", rate=1.0, executor_substr="xla",
                   shape=(32, 32, 32), precision="int8w", t1=120.0)],
        ResiliencePolicy(
            retry=RetryPolicy(max_attempts=2, seed=0),
            breaker=BreakerConfig(trip_after=3, cooldown_s=60.0),
        ),
        horizon_s=600.0,
    )
    r = rep.summary()["resilience"]
    assert r["breaker"]["trips"] >= 1
    assert r["breaker"]["probes"] >= 1
    # the fault window closed at t=120: a later probe restores the rung
    assert r["breaker"]["restores"] >= 1
    assert r["breaker"]["open_signatures"] == []
    states = [tr["state"] for tr in r["breaker"]["transitions"]]
    assert "closed" in states


def test_resilience_summary_reconstructs_from_telemetry():
    from repro.telemetry.analysis import resilience_summary

    rep = _sim(
        [FaultRule(kind="transient", rate=0.15)],
        ResiliencePolicy(retry=RetryPolicy(max_attempts=3, seed=0),
                         breaker=None),
    )
    s = rep.summary()["resilience"]
    rs = resilience_summary(rep.scheduler.engine.log.records)
    # the attempt stream alone reproduces the scheduler's own counters
    assert rs.retries == s["retries"]
    assert rs.faults["transient_fault"] == s["faults"]["transient"]
    assert rs.faulted_requests == s["faulted_requests"]
    assert rs.recovered_requests == s["recovered_requests"]
    assert rs.recovery_rate == pytest.approx(s["recovery_rate"], abs=1e-4)


def test_plain_run_has_no_resilience_block():
    """Without a policy or plan the summary must stay EXACTLY the PR 5/6
    shape — that is what keeps the committed goldens byte-identical."""
    from repro.serving.simulator import preset, reference_engine, simulate

    rep = simulate(reference_engine(), preset("steady", horizon_s=60.0))
    assert "resilience" not in rep.summary()


# ------------------------------------------------------ fault-storm golden ---


def _golden():
    with open(os.path.join(GOLDEN_DIR, "fleet_faultstorm.json")) as f:
        return json.load(f)


def _fresh_faultstorm():
    from repro.serving.fleet import fleet_preset, simulate_fleet

    return simulate_fleet(fleet_preset("fleet_faultstorm", seed=0)).summary()


def test_faultstorm_golden_trace_matches():
    golden = _golden()
    fresh = _fresh_faultstorm()
    assert json.dumps(fresh, sort_keys=True) == json.dumps(golden, sort_keys=True), (
        "fleet_faultstorm diverged from its golden trace; fresh summary:\n"
        + json.dumps(fresh, indent=1, sort_keys=True)
    )


def test_faultstorm_golden_acceptance_claims():
    """The ISSUE's acceptance list, pinned against the committed trace:
    a seeded storm (>=5% transients, a straggler replica, a poisoned
    signature) where retries recover >=90% of transients, the breaker
    demotes the poisoned signature to a rung that SERVES, and the ledger
    proves zero lost / zero double-served."""
    g = _golden()
    req = g["requests"]
    r = g["resilience"]
    # zero lost: every arrival has exactly one terminal outcome
    assert req["conserved"] is True
    assert req["served_twice"] == 0
    assert req["arrived"] == (
        req["refused"] + req["no_replica"] + req["completed"]
        + req["demoted"] + sum(req["rejected"].values())
    )
    # the storm was real and recovery beat the bar
    assert r["faults"]["transient"] > 0.05 * req["arrived"] * 0.5
    assert r["retries"] > 0
    assert r["recovery_rate"] >= 0.9
    # the poisoned signature tripped its breakers and now serves demoted
    assert r["breaker"]["trips"] >= 1
    assert any("xla/int8w/32x32x32" in s for s in r["breaker"]["open_signatures"])
    assert r["rungs"].get("streaming/streaming", 0) > 0
    # hedging engaged against the straggler replica
    assert r["hedges"] > 0
    assert r["hedge_cancelled"] + r["hedge_wins"] > 0
    # per-replica ledgers balance (hedge losers count as evacuations)
    for rep in g["per_replica"]:
        assert rep["admitted"] == (
            rep["completed"] + rep["demoted"] + rep["rejected"]
            + rep["evacuated"]
        ), f"replica {rep['id']} ledger does not balance"


def test_faultstorm_is_deterministic():
    assert json.dumps(_fresh_faultstorm(), sort_keys=True) == json.dumps(
        _fresh_faultstorm(), sort_keys=True
    )
