"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: pip install hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import components, cropping, patching, spatial_shard
from repro.core.meshnet import MeshNetConfig
from repro.core import meshnet
from repro.telemetry import traffic
from repro.training import losses

SETTINGS = dict(max_examples=20, deadline=None)


# --------------------------------------------------------------- patching ---


@settings(**SETTINGS)
@given(
    d=st.integers(6, 24),
    h=st.integers(6, 24),
    w=st.integers(6, 24),
    cube=st.integers(3, 10),
    overlap=st.integers(0, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_cubedivider_split_merge_identity(d, h, w, cube, overlap, seed):
    """split -> (identity model) -> merge == identity for ANY geometry."""
    vol = jax.random.normal(jax.random.PRNGKey(seed), (d, h, w))
    divider = patching.CubeDivider((d, h, w), cube=cube, overlap=overlap)
    merged = divider.merge(divider.split(vol))
    np.testing.assert_allclose(np.asarray(merged), np.asarray(vol), atol=0)


@settings(**SETTINGS)
@given(
    cube=st.integers(4, 12),
    overlap=st.integers(0, 8),
)
def test_cubedivider_read_size_static(cube, overlap):
    divider = patching.CubeDivider((16, 16, 16), cube=cube, overlap=overlap)
    rs = divider.read_size
    assert rs == (cube + 2 * overlap,) * 3
    for c in divider.split(jnp.zeros((16, 16, 16))):
        assert c.shape == rs


# ----------------------------------------------------------- halo exchange ---


def _sharded(fn, x):
    """Run fn per-slab over all local devices (1 in tier-1; 8 in the CI
    distributed job, where the multi-hop path is real)."""
    mesh = spatial_shard.mesh_for(jax.device_count())
    from jax.sharding import PartitionSpec as P

    spec = P(None, "z", None, None, None)
    return spatial_shard._shard_map(
        fn, mesh=mesh, in_specs=(spec,), out_specs=spec
    )(x)


def _valid_tap(y, h):
    """A radius-h two-tap *valid* stencil: the linear, zero-preserving
    stand-in for a dilated conv layer (consumes h context per side)."""
    return y[:, : y.shape[1] - 2 * h] + y[:, 2 * h :]


@settings(max_examples=10, deadline=None)
@given(
    radii=st.lists(st.integers(1, 4), min_size=1, max_size=3),
    dloc=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_halo_exchange_composes(radii, dloc, seed):
    """Layer-wise exchange == one-shot exchange of the summed halo: n
    per-layer exchanges of h_i provide exactly the context of a single
    (multi-hop when sum > slab) exchange of sum(h_i), *provided* the
    one-shot schedule re-zeroes out-of-volume positions after every layer
    — a stencil layer writes combinations of in-volume data into the
    beyond-the-volume halo, which the next layer must read as zeros. This
    is the equivalence the sharded executor family is built on (XLA inner
    = layer-wise, megakernel inner = one-shot + per-layer ``z_bounds``
    masking, core/spatial_shard.py), and both must equal the unsharded
    'same'-padded stencil: pod edges receive zeros == the volume's zero
    padding."""
    n = jax.device_count()
    D = n * dloc
    total = sum(radii)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, D, 2, 2, 1))

    def layerwise(xs):
        # fresh exchange per layer: pod edges are re-zeroed for free
        for h in radii:
            xs = _valid_tap(spatial_shard.halo_exchange_z(xs, h, "z"), h)
        return xs

    def oneshot(xs):
        idx = jax.lax.axis_index("z")
        xs = spatial_shard.halo_exchange_z(xs, total, "z")
        cum = 0
        for h in radii:
            xs = _valid_tap(xs, h)
            cum += h
            # re-zero out-of-volume positions (megakernel z_bounds trick):
            # local j holds global idx*dloc - (total - cum) + j
            g = idx * dloc - (total - cum) + jnp.arange(xs.shape[1])
            mask = (g >= 0) & (g < D)
            xs = xs * mask[None, :, None, None, None]
        return xs

    ref = x
    for h in radii:  # the unsharded 'same'-padded stencil
        ref = _valid_tap(jnp.pad(ref, [(0, 0), (h, h), (0, 0), (0, 0), (0, 0)]), h)

    got_layer = _sharded(layerwise, x)
    got_oneshot = _sharded(oneshot, x)
    np.testing.assert_allclose(np.asarray(got_layer), np.asarray(ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_oneshot), np.asarray(ref), atol=1e-5)


@settings(**SETTINGS)
@given(
    h=st.integers(1, 64),
    w=st.integers(1, 64),
    channels=st.integers(1, 32),
    batch=st.integers(1, 4),
)
def test_collective_bytes_monotone_and_zero_at_one(h, w, channels, batch):
    """The sharded family's ICI model (traffic.meshnet_collective_bytes):
    zero on one device, strictly increasing with slab count (each extra
    boundary adds one halo exchange)."""
    cfg = MeshNetConfig(channels=channels)
    vals = [
        traffic.meshnet_collective_bytes(cfg, (64, h, w), n, batch=batch)
        for n in range(1, 10)
    ]
    assert vals[0] == 0
    assert all(b > a for a, b in zip(vals, vals[1:]))


# ------------------------------------------------------------- components ---


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), p=st.floats(0.05, 0.5))
def test_components_idempotent_and_stable(seed, p):
    """Labelling twice gives identical labels; labels are component-minima
    (stable under recomputation)."""
    mask = jax.random.bernoulli(jax.random.PRNGKey(seed), p, (8, 8, 8))
    l1 = components.connected_components(mask)
    l2 = components.connected_components(mask)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    # background stays -1; foreground labels are valid linear indices
    a = np.asarray(l1)
    m = np.asarray(mask)
    assert (a[~m] == -1).all()
    assert (a[m] >= 0).all()


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_components_labels_are_component_minima(seed):
    mask = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.3, (6, 6, 6))
    labels = np.asarray(components.connected_components(mask))
    m = np.asarray(mask)
    # every labelled voxel's label equals the min linear index in its label set
    for lbl in np.unique(labels[labels >= 0]):
        voxels = np.nonzero(labels == lbl)
        lin = np.ravel_multi_index(voxels, m.shape)
        assert lin.min() == lbl


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), min_size=st.integers(1, 30))
def test_remove_small_components_monotone(seed, min_size):
    """Output mask is a subset of the input; surviving components are >= min_size."""
    mask = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.25, (8, 8, 8))
    kept = components.remove_small_components(mask, min_size)
    k = np.asarray(kept)
    m = np.asarray(mask)
    assert (k <= m).all()
    labels = np.asarray(components.connected_components(jnp.asarray(k)))
    for lbl in np.unique(labels[labels >= 0]):
        assert (labels == lbl).sum() >= min_size


# ------------------------------------------------------------------- dice ---


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), classes=st.integers(2, 6))
def test_dice_bounds_and_symmetry(seed, classes):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.randint(k1, (6, 6, 6), 0, classes)
    b = jax.random.randint(k2, (6, 6, 6), 0, classes)
    d_ab = float(losses.dice_score(a, b, classes))
    d_ba = float(losses.dice_score(b, a, classes))
    assert 0.0 <= d_ab <= 1.0
    assert abs(d_ab - d_ba) < 1e-6  # symmetric
    assert float(losses.dice_score(a, a, classes)) == 1.0  # reflexive


# ------------------------------------------------------------ dilated conv ---


@settings(**SETTINGS)
@given(
    dilation=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dilated_conv_linearity(dilation, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x1 = jax.random.normal(k1, (1, 8, 8, 8, 2))
    x2 = jax.random.normal(k2, (1, 8, 8, 8, 2))
    w = jax.random.normal(k3, (3, 3, 3, 2, 3)) * 0.3
    b = jnp.zeros((3,))
    f = lambda x: meshnet.dilated_conv3d(x, w, b, dilation)
    lhs = f(x1 + 2.0 * x2)
    rhs = f(x1) + 2.0 * f(x2) - b  # bias counted once
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-4)


@settings(**SETTINGS)
@given(dilation=st.sampled_from([1, 2]), shift=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
def test_dilated_conv_translation_equivariance(dilation, shift, seed):
    """Shifting the input shifts the output (away from borders)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (1, 16, 8, 8, 1))
    w = jax.random.normal(k2, (3, 3, 3, 1, 1)) * 0.3
    b = jnp.zeros((1,))
    f = lambda x: meshnet.dilated_conv3d(x, w, b, dilation)
    y = f(x)
    y_shift = f(jnp.roll(x, shift, axis=1))
    margin = shift + dilation
    np.testing.assert_allclose(
        np.asarray(jnp.roll(y, shift, axis=1)[0, margin:-margin]),
        np.asarray(y_shift[0, margin:-margin]),
        atol=1e-4,
    )


# ---------------------------------------------------------------- cropping ---


@settings(**SETTINGS)
@given(
    z0=st.integers(0, 20), y0=st.integers(0, 20), x0=st.integers(0, 20),
    ext=st.integers(1, 8),
)
def test_crop_contains_bbox_when_it_fits(z0, y0, x0, ext):
    n = 32
    z1, y1, x1 = min(z0 + ext, n), min(y0 + ext, n), min(x0 + ext, n)
    mask = jnp.zeros((n, n, n), bool).at[z0:z1, y0:y1, x0:x1].set(True)
    size = (16, 16, 16)
    _, start = cropping.crop_to(jnp.zeros((n, n, n)), mask, size)
    s = np.asarray(start)
    lo, hi = cropping.mask_bounding_box(mask)
    lo, hi = np.asarray(lo), np.asarray(hi)
    if all(hi - lo <= 16):
        assert (lo >= s).all() and (hi <= s + 16).all()


# --------------------------------------------------------------- optimizer ---


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), clip=st.floats(0.1, 10.0))
def test_grad_clip_norm_bound(seed, clip):
    from repro.training.optimizer import clip_by_global_norm, global_norm

    tree = {
        "a": jax.random.normal(jax.random.PRNGKey(seed), (7, 3)) * 10,
        "b": [jax.random.normal(jax.random.PRNGKey(seed + 1), (5,)) * 10],
    }
    clipped, _ = clip_by_global_norm(tree, clip)
    assert float(global_norm(clipped)) <= clip * (1 + 1e-4)
