"""Property suite for the serving scheduler — the system-level
invariants of serving/scheduler.py under randomized load (conservation,
no starvation, budget admission, FIFO-within-class, virtual-clock
determinism). Unit tests live in tests/test_scheduler.py.

Each invariant is a plain ``_check_*`` body driven TWO ways:

  * a hypothesis ``@given`` wrapper exploring the parameter space — the
    real property test, defined only when hypothesis is importable (CI
    installs requirements.txt, so CI always runs these);
  * an always-on deterministic grid sweep (``TestGridFallback``) over
    pinned corners of the same space — so an environment without
    hypothesis still *executes* every invariant instead of skipping the
    whole module (the old module-level importorskip silently reduced
    this file to zero assertions on bare installs).
"""

import pytest

from repro.serving.scheduler import (
    PriorityClass,
    RequestScheduler,
    SchedulerConfig,
)
from repro.serving.simulator import ScenarioSpec, ServiceModel, SimConfig, simulate

from test_scheduler import make_engine

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: the grid fallback below still runs
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=15, deadline=None)

MIX_ENTRIES = [
    ScenarioSpec(shape=(16, 16, 16), priority="interactive"),
    ScenarioSpec(shape=(16, 16, 16), precision="bf16"),
    ScenarioSpec(shape=(32, 32, 32), precision="int8w"),
    ScenarioSpec(shape=(32, 32, 32)),
    ScenarioSpec(shape=(32, 32, 32), mode="subvolume", priority="batch"),
    ScenarioSpec(garbage=True),
]


def _sim_cfg(seed, rate, depth, cap_mib, mix):
    return SimConfig(
        name="prop",
        seed=seed,
        horizon_s=60.0,
        process="poisson",
        process_kwargs={"rate_hz": rate},
        mix=tuple(mix),
        scheduler=SchedulerConfig(
            max_queue_depth=depth,
            admission_hbm_bytes=cap_mib * 1024 * 1024,
            max_batch_requests=4,
            native_shapes=True,
            classes={
                "interactive": PriorityClass("interactive", 0, deadline_s=5.0),
                "standard": PriorityClass("standard", 1, deadline_s=20.0),
                "batch": PriorityClass("batch", 2, deadline_s=None),
            },
        ),
        service=ServiceModel(base_s=0.05, batch_overhead_s=0.02),
    )


# ------------------------------------------------------ invariant bodies ---


def _check_conservation_and_no_starvation(seed, rate, depth, cap_mib, mix):
    """Every admitted request reaches exactly one terminal state:
    admitted == completed + demoted + rejected, and nothing is left
    queued after drain — under ANY load, queue depth, and budget."""
    engine = make_engine()
    rep = simulate(engine, _sim_cfg(seed, rate, depth, cap_mib, mix))
    st_ = rep.scheduler.stats
    assert st_.conserved()
    assert not rep.scheduler.queue  # no starvation: the queue fully drains
    assert rep.arrived == rep.refused + st_.admitted
    # every admitted request id has exactly one completion
    ids = [c.id for c in rep.completions]
    assert len(ids) == len(set(ids)) == st_.admitted


def _check_admission_never_exceeds_budget(seed, rate, cap_mib):
    """Sum of priced working sets in every dispatched batch <= the
    configured admission budget (checked inside a wrapped run_batch)."""
    engine = make_engine()
    cfg = _sim_cfg(
        seed, rate, 40, cap_mib, [ScenarioSpec(), ScenarioSpec(shape=(32, 32, 32))]
    )
    cap = cfg.scheduler.admission_hbm_bytes
    seen = []
    orig = RequestScheduler.run_batch

    def checking(self, batch, now=None):
        seen.append(sum(r.bytes_priced for r in batch.requests))
        return orig(self, batch, now)

    RequestScheduler.run_batch = checking
    try:
        simulate(engine, cfg)
    finally:
        RequestScheduler.run_batch = orig
    assert seen and all(total <= cap for total in seen)


def _check_fifo_within_class_per_signature(seed, rate):
    """Among served requests of one priority class sharing a resolved
    signature, service starts in arrival order (continuous batching may
    interleave *different* signatures, never reorder within one)."""
    engine = make_engine()
    rep = simulate(
        engine,
        _sim_cfg(seed, rate, 64, 64, [ScenarioSpec(), ScenarioSpec(precision="bf16")]),
    )
    starts: dict = {}
    for c in rep.completions:
        if c.outcome == "rejected":
            continue
        r = c.record
        key = (r.priority_class, r.mode, r.executor, r.precision)
        starts.setdefault(key, []).append((c.arrival_s, c.finish_s, c.id))
    for group in starts.values():
        by_arrival = sorted(group)
        by_finish = sorted(group, key=lambda t: (t[1], t[2]))
        assert [g[2] for g in by_arrival] == [g[2] for g in by_finish]


def _check_virtual_clock_determinism(seed):
    """Same seed -> byte-identical telemetry summary AND identical
    per-request telemetry stream (the simulator's core promise)."""
    cfg = _sim_cfg(
        seed,
        6.0,
        16,
        2,
        [ScenarioSpec(), ScenarioSpec(shape=(32, 32, 32)), ScenarioSpec(garbage=True)],
    )
    engines = [make_engine(), make_engine()]
    reps = [simulate(e, cfg) for e in engines]
    assert reps[0].to_json() == reps[1].to_json()
    streams = [[r.to_json() for r in e.log.records] for e in engines]
    assert streams[0] == streams[1]


# ------------------------------------------------- hypothesis exploration ---

if HAVE_HYPOTHESIS:
    _mix_entry = st.sampled_from(MIX_ENTRIES)

    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        rate=st.floats(0.5, 12.0),
        depth=st.integers(2, 40),
        cap_mib=st.integers(1, 64),
        mix=st.lists(_mix_entry, min_size=1, max_size=4),
    )
    def test_conservation_and_no_starvation(seed, rate, depth, cap_mib, mix):
        _check_conservation_and_no_starvation(seed, rate, depth, cap_mib, mix)

    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        rate=st.floats(2.0, 12.0),
        cap_mib=st.integers(1, 8),
    )
    def test_admission_never_exceeds_budget(seed, rate, cap_mib):
        _check_admission_never_exceeds_budget(seed, rate, cap_mib)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), rate=st.floats(1.0, 10.0))
    def test_fifo_within_class_per_signature(seed, rate):
        _check_fifo_within_class_per_signature(seed, rate)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_virtual_clock_determinism(seed):
        _check_virtual_clock_determinism(seed)


# ------------------------------------------------- deterministic fallback ---


class TestGridFallback:
    """Pinned corners of the property space — always executed, with or
    without hypothesis, so no environment silently skips the invariants."""

    @pytest.mark.parametrize(
        "seed,rate,depth,cap_mib",
        [(0, 0.5, 2, 1), (1, 6.0, 8, 4), (2, 12.0, 40, 64), (3, 9.0, 3, 2)],
    )
    def test_conservation_and_no_starvation(self, seed, rate, depth, cap_mib):
        _check_conservation_and_no_starvation(seed, rate, depth, cap_mib, MIX_ENTRIES)

    @pytest.mark.parametrize("seed,rate,cap_mib", [(0, 2.0, 1), (1, 12.0, 8)])
    def test_admission_never_exceeds_budget(self, seed, rate, cap_mib):
        _check_admission_never_exceeds_budget(seed, rate, cap_mib)

    @pytest.mark.parametrize("seed,rate", [(0, 1.0), (1, 10.0)])
    def test_fifo_within_class_per_signature(self, seed, rate):
        _check_fifo_within_class_per_signature(seed, rate)

    @pytest.mark.parametrize("seed", [0, 7])
    def test_virtual_clock_determinism(self, seed):
        _check_virtual_clock_determinism(seed)
