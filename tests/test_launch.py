"""Launch-layer tests: sharding rules, input specs, roofline math.

These run on 1 CPU device with a degenerate (1,1) mesh — the rules are
pure functions of (shape, mesh axis sizes), so spec *structure* is fully
testable without 512 fake devices; the real 256/512-device compiles are
exercised by launch/dryrun.py (results in results/).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import sharding, steps as steps_mod
from repro.models import model as MD


def tiny_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestShardingRules:
    def _specs(self, arch):
        cfg = configs.get(arch)
        mesh = tiny_mesh()
        pshapes = jax.eval_shape(lambda: MD.init(jax.random.PRNGKey(0), cfg))
        return cfg, sharding.param_specs(pshapes, mesh), pshapes

    def test_dense_rules(self):
        cfg, specs, shapes = self._specs("tinyllama-1.1b")
        blk = specs["blocks"][0]
        assert blk["attn"]["wq"] == P(None, "data", "model")  # stacked (R, d, q)
        assert blk["attn"]["wo"] == P(None, "model", "data")
        assert blk["mlp"]["w_down"] == P(None, "model", "data")
        assert specs["embed"] == P("model", "data")
        assert blk["ln1"]["scale"] == P()

    def test_moe_expert_parallel_when_divisible(self):
        cfg, specs, shapes = self._specs("kimi-k2-1t-a32b")
        blk = specs["blocks"][0]
        # (R, E, d, f): experts over the fsdp axis, f over model
        assert blk["moe"]["w_up"] == P(None, "data", None, "model")
        assert blk["moe"]["w_down"] == P(None, "data", "model", None)

    def test_moe_fallback_when_experts_indivisible(self):
        # grok's 8 experts don't divide a 16-way axis; build a fake 16-wide
        # check by asserting the rule's divisibility logic directly
        cfg = configs.get("grok-1-314b")
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        pshapes = jax.eval_shape(lambda: MD.init(jax.random.PRNGKey(0), cfg))
        specs = sharding.param_specs(pshapes, mesh)
        blk = specs["blocks"][0]
        # with axis size 1 everything divides -> expert-parallel chosen
        assert blk["moe"]["w_up"][1] == "data"

    def test_vocab_indivisible_replicates(self):
        # whisper vocab 51865 is not divisible by any axis > 1; with the
        # degenerate mesh it divides (size 1) -> sharded; emulate a 16-way
        # check via the rule helper directly on a synthetic leaf
        cfg, specs, shapes = self._specs("whisper-small")
        assert specs["embed"] is not None  # structural smoke

    def test_specs_cover_every_leaf(self):
        for arch in configs.ARCHS:
            cfg, specs, shapes = self._specs(arch)
            n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
            n_params = len(jax.tree.leaves(shapes))
            assert n_specs == n_params, arch

    def test_spec_rank_matches_leaf_rank(self):
        for arch in ["jamba-1.5-large-398b", "rwkv6-3b", "whisper-small"]:
            cfg, specs, shapes = self._specs(arch)
            flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
            flat_p = jax.tree.leaves(shapes)
            for sp, lf in zip(flat_s, flat_p):
                assert len(sp) <= lf.ndim, (arch, sp, lf.shape)


class TestInputSpecs:
    @pytest.mark.parametrize("shape_name", list(configs.INPUT_SHAPES))
    def test_input_specs_structural(self, shape_name):
        mesh = tiny_mesh()
        cfg, mode, args = steps_mod.input_specs("tinyllama-1.1b", shape_name, mesh)
        seq, gb, expect_mode = configs.INPUT_SHAPES[shape_name]
        assert mode == expect_mode
        if mode == "train":
            params, opt, batch = args
            assert batch["tokens"].shape == (gb, seq)
            assert batch["labels"].shape == (gb, seq)
        elif mode == "prefill":
            params, batch = args
            assert batch["tokens"].shape == (gb, seq)
        else:
            params, token, cache, pos = args
            assert token.shape == (gb, 1)
            S = cache[0]["k"].shape[2]
            win = cfg.sliding_window
            assert S == (min(seq, win) if win else seq)

    def test_long500k_is_subquadratic_variant(self):
        cfg = configs.for_shape("gemma-7b", "long_500k")
        assert cfg.sliding_window == 8192
        cfg2 = configs.for_shape("rwkv6-3b", "long_500k")
        assert cfg2.sliding_window is None  # natively O(1)

    def test_every_arch_has_all_four_shapes(self):
        mesh = tiny_mesh()
        for arch in configs.ARCHS:
            for shape_name in configs.INPUT_SHAPES:
                cfg, mode, args = steps_mod.input_specs(arch, shape_name, mesh)
                assert args, (arch, shape_name)


class TestRooflineMath:
    def test_collective_bytes_parser(self):
        from repro.launch.dryrun import collective_bytes

        hlo = """
        %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
        %ag = bf16[4,256]{1,0} all-gather(%y), dimensions={1}
        %cp = f32[8]{0} collective-permute(%z)
        %other = f32[99]{0} add(%a, %b)
        """
        out = collective_bytes(hlo)
        assert out["all-reduce"] == 16 * 128 * 4
        assert out["all-gather"] == 4 * 256 * 2
        assert out["collective-permute"] == 8 * 4
        assert out["total"] == out["all-reduce"] + out["all-gather"] + out["collective-permute"]

    def test_model_flops_modes(self):
        import benchmarks.roofline as R

        t = R.model_flops("tinyllama-1.1b", "train_4k")
        p = R.model_flops("tinyllama-1.1b", "prefill_32k")
        d = R.model_flops("tinyllama-1.1b", "decode_32k")
        assert t > p > d
        # train = 6ND with D = 256*4096
        n = configs.get("tinyllama-1.1b").param_counts()["active"]
        assert abs(t - 6 * n * 256 * 4096) / t < 1e-9

    def test_moe_uses_active_params(self):
        import benchmarks.roofline as R

        moe_total = configs.get("kimi-k2-1t-a32b").param_counts()
        assert moe_total["active"] < moe_total["total"] / 10
        f = R.model_flops("kimi-k2-1t-a32b", "train_4k")
        assert abs(f - 6 * moe_total["active"] * 256 * 4096) / f < 1e-9

    def test_dryrun_artifacts_if_present(self):
        import json
        import os

        path = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun_2x16x16.json")
        if not os.path.exists(path):
            pytest.skip("multi-pod dry-run artifacts not generated yet")
        with open(path) as f:
            results = json.load(f)
        assert len(results) == 40
        assert all(r.get("status") == "ok" for r in results.values())
        assert all(r["chips"] == 512 for r in results.values() if "chips" in r)
