"""Property suite for the replicated serving fleet (serving/fleet.py) —
the invariants that make N schedulers behind a router trustworthy:

  * **fleet conservation**: summed over replicas (crashes and drains
    included), admitted == completed + demoted + rejected + evacuated,
    and every arrival has exactly one terminal ledger outcome;
  * **exactly-once**: after failover re-dispatch no request is ever
    served twice (``completions_seen <= 1`` on every ledger entry);
  * **router hygiene**: no policy ever routes to a draining or dead
    replica — cache affinity included, however warm the dying replica's
    jit caches are;
  * **determinism**: same seed -> byte-identical fleet summaries, across
    replica counts, policies, and mid-trace crash events.

Same double-drive structure as tests/test_scheduler_properties.py: each
``_check_*`` body runs under hypothesis when it is importable (CI) AND
under an always-on deterministic grid (bare installs never skip)."""

import pytest

from repro.serving.fleet import (
    Fleet,
    FleetConfig,
    FleetEvent,
    FleetServiceModel,
    ROUTER_POLICIES,
    fleet_preset,
    simulate_fleet,
)
from repro.serving.scheduler import PriorityClass, SchedulerConfig
from repro.serving.simulator import STANDARD_MIX

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: the grid fallback below still runs
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=10, deadline=None)


def _fleet_cfg(seed, rate, replicas, policy, crash_t=None, depth=16):
    events = ()
    if crash_t is not None and replicas > 1:
        # crash a middle replica mid-trace; survivors absorb the backlog
        events = (FleetEvent(t=crash_t, action="crash", replica=replicas // 2),)
    return FleetConfig(
        name="prop",
        seed=seed,
        horizon_s=60.0,
        process="poisson",
        process_kwargs={"rate_hz": rate},
        mix=STANDARD_MIX,
        replicas=replicas,
        policy=policy,
        scheduler=SchedulerConfig(
            max_queue_depth=depth,
            admission_hbm_bytes=4 * 1024 * 1024,
            max_batch_requests=4,
            native_shapes=True,
            classes={
                "interactive": PriorityClass("interactive", 0, deadline_s=5.0),
                "standard": PriorityClass("standard", 1, deadline_s=20.0),
                "batch": PriorityClass("batch", 2, deadline_s=None),
            },
        ),
        service=FleetServiceModel(base_s=0.05, batch_overhead_s=0.02),
        events=events,
    )


# ------------------------------------------------------ invariant bodies ---


def _check_fleet_conservation(seed, rate, replicas, policy, crash_t):
    """Admitted == completed + demoted + rejected + evacuated on every
    replica; every arrival reaches exactly one terminal outcome in the
    fleet ledger; queues fully drain — with or without a crash."""
    rep = simulate_fleet(_fleet_cfg(seed, rate, replicas, policy, crash_t))
    fl = rep.fleet
    assert fl.conserved()
    for r in fl.replicas:
        st_ = r.sched.stats
        assert st_.conserved(), f"replica {r.id}: {st_}"
        assert not r.sched.queue or r.crashed is False  # crashed queues evacuated
        if r.crashed:
            assert not r.sched.queue, "crashed replica retained queued work"
    s = rep.summary()
    req = s["requests"]
    unique_terminal = (
        req["refused"]
        + req["no_replica"]
        + req["completed"]
        + req["demoted"]
        + sum(req["rejected"].values())
    )
    assert req["arrived"] == unique_terminal
    # per-replica admissions exceed unique admissions by exactly the
    # re-dispatches (each re-dispatch re-admits one request)
    assert req["admitted"] == (
        req["arrived"] - req["refused"] - req["no_replica"] + req["redispatched"]
    )


def _check_no_request_served_twice(seed, rate, replicas, crash_t):
    """Exactly-once under failover: a crash mid-trace re-dispatches work,
    and no ledger entry ever sees a second completion."""
    rep = simulate_fleet(_fleet_cfg(seed, rate, replicas, "cache_affinity", crash_t))
    fl = rep.fleet
    assert all(e.completions_seen <= 1 for e in fl.ledger)
    served = [e for e in fl.ledger if e.outcome in ("completed", "demoted")]
    assert all(e.completions_seen == 1 for e in served)
    # the ledger's served set and the replicas' completion sets agree
    by_outcome = sum(
        r.sched.stats.completed + r.sched.stats.demoted for r in fl.replicas
    )
    assert len(served) == by_outcome


def _check_router_avoids_draining(seed, rate, replicas, policy):
    """No routing decision — any policy — ever lands on a draining or
    dead replica, even while its warm jit caches make it the affinity
    favourite. Instrumented at the router itself."""
    cfg = _fleet_cfg(seed, rate, replicas, policy)
    # drain one replica mid-trace (graceful flavour of the crash event)
    cfg = FleetConfig(
        **{
            **cfg.__dict__,
            "events": (FleetEvent(t=20.0, action="drain", replica=0),),
        }
    )
    chosen = []
    orig = Fleet._pick

    def recording(self, *a, **kw):
        r = orig(self, *a, **kw)
        chosen.append((r.id, r.draining, r.crashed))
        return r

    Fleet._pick = recording
    try:
        rep = simulate_fleet(cfg)
    finally:
        Fleet._pick = orig
    assert chosen, "router never exercised"
    assert all(not draining and not crashed for _, draining, crashed in chosen)
    # the drained replica really left the routable set
    assert rep.summary()["replicas"]["drained"] == 1


def _check_fleet_determinism(seed, replicas, policy, crash_t):
    """Same seed -> byte-identical fleet summaries (the golden-trace
    foundation), including failover timelines."""
    runs = [
        simulate_fleet(_fleet_cfg(seed, 6.0, replicas, policy, crash_t)).to_json()
        for _ in range(2)
    ]
    assert runs[0] == runs[1]


def test_replica_summary_rollup():
    """Fleet telemetry is replica-stamped, and the per-replica rollup in
    telemetry/analysis.py reconstructs each replica's ledger from the
    record stream alone — the horizontal cut class_summary can't see."""
    from repro.telemetry.analysis import replica_summary

    rep = simulate_fleet(_fleet_cfg(0, 6.0, 3, "cache_affinity", 25.0))
    fl = rep.fleet
    records = [r for repl in fl.replicas for r in repl.sched.engine.log.records]
    rows = replica_summary(records)
    by_id = {r.replica_id: r for r in rows}
    for repl in fl.replicas:
        st_ = repl.sched.stats
        terminal = st_.completed + st_.demoted + st_.rejected_total()
        if terminal == 0:
            assert repl.id not in by_id
            continue
        row = by_id[repl.id]
        assert row.served == st_.completed + st_.demoted
        assert row.demoted == st_.demoted
        assert sum(row.shed.values()) == st_.rejected_total()
    # re-dispatched requests are stamped with the replica that SERVED
    # them, so summed served equals the ledger's unique served count
    served_ledger = sum(
        1 for e in fl.ledger if e.outcome in ("completed", "demoted")
    )
    assert sum(r.served for r in rows) == served_ledger


# ------------------------------------------------- hypothesis exploration ---

if HAVE_HYPOTHESIS:

    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        rate=st.floats(1.0, 10.0),
        replicas=st.integers(1, 5),
        policy=st.sampled_from(ROUTER_POLICIES),
        crash_t=st.one_of(st.none(), st.floats(5.0, 50.0)),
    )
    def test_fleet_conservation(seed, rate, replicas, policy, crash_t):
        _check_fleet_conservation(seed, rate, replicas, policy, crash_t)

    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        rate=st.floats(4.0, 12.0),
        replicas=st.integers(2, 5),
        crash_t=st.floats(5.0, 50.0),
    )
    def test_no_request_served_twice(seed, rate, replicas, crash_t):
        _check_no_request_served_twice(seed, rate, replicas, crash_t)

    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        rate=st.floats(1.0, 8.0),
        replicas=st.integers(2, 5),
        policy=st.sampled_from(ROUTER_POLICIES),
    )
    def test_router_avoids_draining(seed, rate, replicas, policy):
        _check_router_avoids_draining(seed, rate, replicas, policy)

    @settings(max_examples=5, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        replicas=st.integers(1, 4),
        policy=st.sampled_from(ROUTER_POLICIES),
        crash_t=st.one_of(st.none(), st.floats(10.0, 40.0)),
    )
    def test_fleet_determinism(seed, replicas, policy, crash_t):
        _check_fleet_determinism(seed, replicas, policy, crash_t)


# ------------------------------------------------- deterministic fallback ---


class TestGridFallback:
    """Pinned corners of the fleet property space — always executed, with
    or without hypothesis, so no environment silently skips the fleet
    invariants."""

    @pytest.mark.parametrize(
        "seed,rate,replicas,policy,crash_t",
        [
            (0, 2.0, 1, "round_robin", None),
            (1, 8.0, 3, "cache_affinity", 25.0),
            (2, 6.0, 4, "least_loaded", None),
            (3, 10.0, 5, "join_shortest_queue", 12.0),
        ],
    )
    def test_fleet_conservation(self, seed, rate, replicas, policy, crash_t):
        _check_fleet_conservation(seed, rate, replicas, policy, crash_t)

    @pytest.mark.parametrize(
        "seed,rate,replicas,crash_t", [(0, 8.0, 3, 20.0), (1, 12.0, 2, 35.0)]
    )
    def test_no_request_served_twice(self, seed, rate, replicas, crash_t):
        _check_no_request_served_twice(seed, rate, replicas, crash_t)

    @pytest.mark.parametrize(
        "seed,rate,replicas,policy",
        [(0, 4.0, 2, "cache_affinity"), (1, 6.0, 4, "round_robin")],
    )
    def test_router_avoids_draining(self, seed, rate, replicas, policy):
        _check_router_avoids_draining(seed, rate, replicas, policy)

    @pytest.mark.parametrize(
        "seed,replicas,policy,crash_t",
        [(0, 3, "cache_affinity", 20.0), (5, 2, "join_shortest_queue", None)],
    )
    def test_fleet_determinism(self, seed, replicas, policy, crash_t):
        _check_fleet_determinism(seed, replicas, policy, crash_t)
