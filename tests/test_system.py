"""End-to-end behaviour tests for the Brainchop/MeshNet system."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import components, conform, cropping, meshnet, patching, pipeline, streaming
from repro.core.meshnet import MeshNetConfig, PAPER_MODELS
from repro.core.pipeline import PipelineConfig
from repro.data import mri
from repro.telemetry.budget import BudgetExceeded, MemoryBudget
from repro.training import losses


KEY = jax.random.PRNGKey(0)


class TestMeshNet:
    def test_paper_param_counts(self):
        # Table IV: GWM light = 5598 params; subvolume failsafe = 96078.
        assert PAPER_MODELS["gwm_light"].param_count() == 5598
        assert PAPER_MODELS["subvolume_gwm_failsafe"].param_count() == 96078

    def test_forward_shape_and_finite(self):
        cfg = MeshNetConfig()
        p = meshnet.init(KEY, cfg)
        x = jax.random.normal(KEY, (2, 16, 16, 16))
        out = meshnet.apply(p, x, cfg)
        assert out.shape == (2, 16, 16, 16, 3)
        assert bool(jnp.isfinite(out).all())

    def test_receptive_field_matches_dilation_schedule(self):
        # A unit impulse must influence exactly +-46 voxels (RF radius =
        # sum(dilations) = 46) along each axis.
        cfg = MeshNetConfig(use_batchnorm=False)
        p = meshnet.init(KEY, cfg)
        n = 96
        x0 = jnp.zeros((1, n, 3, 3))
        x1 = x0.at[0, n // 2, 1, 1].set(1.0)
        d = jnp.abs(meshnet.apply(p, x1, cfg) - meshnet.apply(p, x0, cfg))[0, :, 1, 1, :].sum(-1)
        touched = np.nonzero(np.asarray(d) > 0)[0]
        assert touched.min() >= n // 2 - patching.MESHNET_RF_RADIUS
        assert touched.max() <= n // 2 + patching.MESHNET_RF_RADIUS

    def test_streaming_matches_plain(self):
        cfg = MeshNetConfig()
        p = meshnet.init(KEY, cfg)
        x = jax.random.normal(KEY, (1, 12, 12, 12))
        np.testing.assert_allclose(
            np.asarray(meshnet.apply(p, x, cfg)),
            np.asarray(streaming.streaming_apply(p, x, cfg)),
            atol=1e-4,
        )


class TestUNetBaseline:
    def test_forward_shape_preserving(self):
        from repro.core import unet3d

        cfg = unet3d.UNet3DConfig(base_channels=4, levels=2)
        p = unet3d.init(KEY, cfg)
        x = jax.random.normal(KEY, (1, 16, 16, 16))
        out = unet3d.apply(p, x, cfg)
        assert out.shape == (1, 16, 16, 16, cfg.num_classes)
        assert bool(jnp.isfinite(out).all())

    def test_grad_flows(self):
        from repro.core import unet3d
        from repro.training import losses as L

        cfg = unet3d.UNet3DConfig(base_channels=4, levels=2)
        p = unet3d.init(KEY, cfg)
        x = jax.random.normal(KEY, (1, 8, 8, 8))
        lab = jnp.zeros((1, 8, 8, 8), jnp.int32)
        g = jax.grad(lambda p: L.segmentation_loss(unet3d.apply(p, x, cfg), lab, 3)[0])(p)
        assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))


class TestPatching:
    def test_subvolume_inference_exact_in_interior(self):
        """Failsafe mode with overlap >= RF radius is numerically exact for
        every voxel at distance >= RF from the VOLUME boundary. (Boundary
        bands differ by 'same'-padding semantics — the paper's sub-volume
        accuracy loss; see core/patching.py.)"""
        cfg = MeshNetConfig(dilations=(1, 2, 4), use_batchnorm=True)
        rf = sum(cfg.dilations)
        p = meshnet.init(KEY, cfg)
        vol = jax.random.normal(KEY, (24, 24, 24))

        @jax.jit
        def infer(c):
            return meshnet.apply(p, c, cfg)

        full = meshnet.apply(p, vol[None], cfg)[0]
        patched = patching.subvolume_inference(vol, infer, cube=8, overlap=rf)
        s = slice(rf, -rf)
        np.testing.assert_allclose(
            np.asarray(full[s, s, s]), np.asarray(patched[s, s, s]), atol=1e-4
        )
        # and the boundary band genuinely differs (the documented loss)
        assert float(jnp.abs(full - patched).max()) > 1e-3

    def test_insufficient_overlap_is_inexact(self):
        """With overlap < RF the merge has border error — the paper's
        observed sub-volume accuracy loss."""
        cfg = MeshNetConfig(dilations=(1, 2, 4), use_batchnorm=False)
        p = meshnet.init(KEY, cfg)
        vol = jax.random.normal(KEY, (24, 24, 24))

        @jax.jit
        def infer(c):
            return meshnet.apply(p, c, cfg)

        full = meshnet.apply(p, vol[None], cfg)[0]
        patched = patching.subvolume_inference(vol, infer, cube=8, overlap=0)
        err = float(jnp.abs(full - patched).max())
        assert err > 1e-3

    def test_memory_model_ordering(self):
        cfg = MeshNetConfig()
        full = patching.memory_bytes_full_volume((256,) * 3, cfg.channels, cfg.num_classes)
        sub = patching.memory_bytes_subvolume(64, 46, cfg.channels, cfg.num_classes)
        assert sub < full  # patching exists to fit smaller budgets


class TestComponents:
    def test_two_components(self):
        mask = np.zeros((10, 10, 10), bool)
        mask[1:3, 1:3, 1:3] = True
        mask[6:9, 6:9, 6:9] = True
        labels = components.connected_components(jnp.asarray(mask))
        ids = np.unique(np.asarray(labels))
        assert (ids >= 0).sum() == 2

    def test_largest_component(self):
        mask = np.zeros((10, 10, 10), bool)
        mask[1:3, 1:3, 1:3] = True  # 8 voxels
        mask[5:9, 5:9, 5:9] = True  # 64 voxels
        big = components.largest_component(jnp.asarray(mask))
        assert int(big.sum()) == 64

    def test_filter_segmentation_removes_noise(self):
        seg = np.zeros((12, 12, 12), np.int32)
        seg[2:8, 2:8, 2:8] = 1  # big region: keep
        seg[10, 10, 10] = 1  # single-voxel noise: drop
        out = components.filter_segmentation(jnp.asarray(seg), num_classes=2, min_size=4)
        assert int(out[10, 10, 10]) == 0
        assert int(out[4, 4, 4]) == 1

    def test_6_connectivity(self):
        # Diagonal voxels are NOT connected under face adjacency.
        mask = np.zeros((4, 4, 4), bool)
        mask[0, 0, 0] = True
        mask[1, 1, 1] = True
        labels = components.connected_components(jnp.asarray(mask))
        assert labels[0, 0, 0] != labels[1, 1, 1]


class TestConformAndCropping:
    def test_conform_output_range_and_shape(self):
        vol = jax.random.normal(KEY, (20, 28, 24)) * 50 + 100
        out = conform.conform(vol, (32, 32, 32))
        assert out.shape == (32, 32, 32)
        assert float(out.min()) >= 0.0 and float(out.max()) <= 1.0

    def test_resample_identity(self):
        vol = jax.random.normal(KEY, (16, 16, 16))
        out = conform.resample(vol, (16, 16, 16))
        np.testing.assert_allclose(np.asarray(out), np.asarray(vol), atol=1e-5)

    def test_crop_uncrop_roundtrip(self):
        vol = jax.random.normal(KEY, (32, 32, 32))
        mask = jnp.zeros((32, 32, 32), bool).at[8:20, 10:22, 6:18].set(True)
        crop, start = cropping.crop_to(vol, mask, (16, 16, 16))
        assert crop.shape == (16, 16, 16)
        back = cropping.uncrop(crop, start, (32, 32, 32))
        s = tuple(int(v) for v in start)
        np.testing.assert_allclose(
            np.asarray(back[s[0] : s[0] + 16, s[1] : s[1] + 16, s[2] : s[2] + 16]),
            np.asarray(crop),
        )

    def test_pick_crop_size_ladder(self):
        mask = jnp.zeros((64, 64, 64), bool).at[20:40, 20:40, 20:40].set(True)
        size = cropping.pick_crop_size(mask, ladder=((16,) * 3, (32,) * 3, (64,) * 3))
        assert size == (32, 32, 32)


class TestPipeline:
    def _setup(self):
        cfg = MeshNetConfig()
        params = meshnet.init(KEY, cfg)
        vol, _ = mri.generate(KEY, mri.SyntheticMRIConfig(shape=(32, 32, 32)))
        return cfg, params, vol

    @pytest.mark.parametrize("mode", ["full", "streaming", "subvolume"])
    def test_modes_produce_segmentation(self, mode):
        cfg, params, vol = self._setup()
        pc = PipelineConfig(
            model=cfg, volume_shape=(32, 32, 32), mode=mode, cube=16, overlap=8,
            min_component_size=4,
        )
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "ok"
        assert res.segmentation.shape == (32, 32, 32)
        assert res.record.times.inference > 0

    def test_budget_failure_recorded_not_raised(self):
        cfg, params, vol = self._setup()
        pc = PipelineConfig(model=cfg, volume_shape=(32, 32, 32), budget=MemoryBudget(1))
        res = pipeline.run(pc, params, vol)
        assert res.record.status == "fail"
        assert res.record.fail_type == "full_volume_oom"
        assert res.segmentation is None

    def test_budget_interventions_match_paper_ordering(self):
        """Tables V/VI: at a budget that kills full-volume, streaming and
        sub-volume (failsafe) still succeed — the patching intervention."""
        cfg = MeshNetConfig()
        shape = (64, 64, 64)
        budget = MemoryBudget(24 * 1024 * 1024)  # 24 MiB
        with pytest.raises(BudgetExceeded):
            budget.charge_inference(shape, cfg)
        assert budget.charge_streaming(shape, cfg) > 0
        assert budget.charge_subvolume(16, 8, cfg) > 0


class TestServingFaults:
    """Fault injection on the serving path (serving/scheduler.py): a bad
    request inside a batch must fail ALONE, with a typed telemetry
    record, while the rest of the batch completes — the serving-tier
    version of the paper's 'telemetry over crashes' stance."""

    def _engine(self):
        from repro.serving.engine import SegmentationEngine

        cfg = MeshNetConfig(dilations=(1, 2, 4), channels=5)
        params = meshnet.init(KEY, cfg)
        pc = PipelineConfig(
            model=cfg, volume_shape=(16, 16, 16), cube=8, overlap=4,
            min_component_size=4, executor="xla",
        )
        return SegmentationEngine(params, pc)

    def _vols(self, n):
        return [
            mri.generate(
                jax.random.PRNGKey(i), mri.SyntheticMRIConfig(shape=(16, 16, 16))
            )[0]
            for i in range(n)
        ]

    def test_executor_raising_mid_batch_fails_only_that_request(self, monkeypatch):
        engine = self._engine()
        vols = self._vols(3)
        poison = vols[1]
        real_run = pipeline.run

        def flaky_run(cfg, params, vol, **kw):
            if vol is poison:
                raise RuntimeError("injected executor fault")
            return real_run(cfg, params, vol, **kw)

        monkeypatch.setattr(pipeline, "run", flaky_run)
        results = engine.submit_many(vols)
        assert [r.record.status for r in results] == ["ok", "fail", "ok"]
        # an unclassified RuntimeError is conservatively permanent
        # (serving/errors.py classify): retrying an unknown fault burns
        # capacity exactly when the service is least healthy
        assert results[1].record.fail_type == "permanent_fault"
        assert "injected executor fault" in results[1].record.extra["error"]
        assert results[1].segmentation is None
        for i in (0, 2):
            assert results[i].segmentation.shape == (16, 16, 16)

    def test_garbage_volume_in_batch_fails_typed(self):
        engine = self._engine()
        vols = self._vols(2)
        batch = [vols[0], jnp.zeros((7,)), vols[1]]  # 1-D garbage mid-batch
        results = engine.submit_many(batch)
        assert [r.record.status for r in results] == ["ok", "fail", "ok"]
        assert results[1].record.fail_type == "permanent_fault"
        # the fleet ledger conserved: all three requests have records
        assert len(engine.log.records) == 3

    def test_geometry_failure_in_batch_is_isolated(self):
        """A request pinning more slab devices than the host has fails
        with the pipeline's typed shard_geometry record (never raises),
        and its batch neighbours complete."""
        if jax.device_count() > 2:
            pytest.skip("needs a host with <= 2 devices to force the failure")
        engine = self._engine()
        vols = self._vols(2)
        results = engine.submit_many(
            [vols[0], vols[1]], devices=[None, 3],
        )
        assert results[0].record.status == "ok"
        assert results[1].record.status == "fail"
        assert results[1].record.fail_type == "shard_geometry"

    def test_queue_full_backpressure_is_typed(self):
        from repro.serving.scheduler import QueueFullError, SchedulerConfig

        engine = self._engine()
        engine.scheduler(SchedulerConfig(max_queue_depth=1))
        engine.submit_async(self._vols(1)[0])
        with pytest.raises(QueueFullError):
            engine.submit_async(self._vols(1)[0])
        comps = engine.drain()
        assert len(comps) == 1 and comps[0].outcome == "completed"
        # the refusal is in the fleet telemetry, typed
        assert any(r.fail_type == "queue_full" for r in engine.log.records)


class TestFleetFaults:
    """Fault injection on the replicated fleet (serving/fleet.py): a
    raising replica must isolate to its own dispatch group, an
    all-draining router must refuse with a TYPED error, and
    scale-to-zero must be rejected at configuration time — the fleet
    tier's version of 'telemetry over crashes'."""

    def _engine(self):
        from repro.serving.engine import SegmentationEngine

        cfg = MeshNetConfig(dilations=(1, 2, 4), channels=5)
        params = meshnet.init(KEY, cfg)
        pc = PipelineConfig(
            model=cfg, volume_shape=(16, 16, 16), cube=8, overlap=4,
            min_component_size=4, executor="xla",
        )
        return SegmentationEngine(params, pc)

    def _fleet(self, replicas=2, execute=False, **cfg_kwargs):
        from repro.serving.fleet import Fleet, FleetConfig

        return Fleet(
            FleetConfig(replicas=replicas, execute=execute, **cfg_kwargs),
            engine_factory=self._engine,
        )

    def test_replica_raising_mid_batch_isolates_to_that_replica(self, monkeypatch):
        """An executor fault on one replica fails ONE request with a
        typed record; its group neighbours and the other replica's
        requests complete — and the fleet ledger still conserves."""
        fleet = self._fleet(replicas=2, execute=True, policy="round_robin")
        vols = [
            mri.generate(
                jax.random.PRNGKey(i), mri.SyntheticMRIConfig(shape=(16, 16, 16))
            )[0]
            for i in range(4)
        ]
        poison = vols[1]
        real_run = pipeline.run

        def flaky_run(cfg, params, vol, **kw):
            if vol is poison:
                raise RuntimeError("injected replica fault")
            return real_run(cfg, params, vol, **kw)

        monkeypatch.setattr(pipeline, "run", flaky_run)
        for v in vols:
            fleet.submit(v)
        fleet.drain()
        assert fleet.conserved()
        served = sorted(
            (e for e in fleet.ledger), key=lambda e: e.fid
        )
        records = [e.completion.record for e in served]
        assert [r.status for r in records] == ["ok", "fail", "ok", "ok"]
        assert records[1].fail_type == "permanent_fault"
        assert "injected replica fault" in records[1].extra["error"]
        # the fault stayed on the replica that served it; both replicas
        # still completed their groups
        assert {r.replica_id for r in records} == {0, 1}

    def test_router_with_all_replicas_draining_refuses_typed(self):
        from repro.serving.fleet import NoReplicaAvailable

        fleet = self._fleet(replicas=2)
        fleet.drain_replica(0)
        fleet.drain_replica(1)
        with pytest.raises(NoReplicaAvailable) as ei:
            fleet.submit(np.zeros((16, 16, 16), np.float32))
        assert ei.value.total == 2
        assert ei.value.draining == 2
        assert ei.value.crashed == 0
        # the refusal is ledgered as a typed terminal outcome
        assert fleet.ledger[-1].outcome == "no_replica"
        assert fleet.no_replica == 1

    def test_autoscaler_scale_to_zero_rejected_typed(self):
        from repro.serving.fleet import (
            AutoscalerConfig,
            Fleet,
            FleetConfig,
            FleetConfigError,
        )

        # at configuration time: a floor below one replica is an outage
        with pytest.raises(FleetConfigError, match="min_replicas"):
            Fleet(
                FleetConfig(
                    replicas=1,
                    autoscaler=AutoscalerConfig(min_replicas=0),
                ),
                engine_factory=self._engine,
            )
        with pytest.raises(FleetConfigError, match=">= 1 replica"):
            Fleet(FleetConfig(replicas=0), engine_factory=self._engine)
        # at runtime: draining the last routable replica is refused
        fleet = self._fleet(replicas=1)
        with pytest.raises(FleetConfigError, match="scale-to-zero"):
            fleet.scale_down()
        assert fleet.replicas[0].routable  # refusal left the fleet intact


class TestLosses:
    def test_dice_perfect_and_disjoint(self):
        a = jnp.ones((8, 8, 8), jnp.int32)
        assert float(losses.dice_score(a, a, 2)) == 1.0
        b = jnp.zeros((8, 8, 8), jnp.int32)
        assert float(losses.dice_score(a, b, 2)) == 0.0

    def test_cross_entropy_matches_manual(self):
        logits = jax.random.normal(KEY, (4, 5))
        labels = jnp.asarray([0, 1, 2, 3])
        manual = -jnp.mean(
            jnp.take_along_axis(jax.nn.log_softmax(logits), labels[:, None], axis=1)
        )
        np.testing.assert_allclose(
            float(losses.cross_entropy(logits, labels)), float(manual), rtol=1e-6
        )

    def test_soft_dice_gradient_direction(self):
        logits = jnp.zeros((4, 4, 4, 2))
        labels = jnp.ones((4, 4, 4), jnp.int32)
        g = jax.grad(lambda l: losses.soft_dice_loss(l, labels, 2))(logits)
        # pushing class-1 logits up must reduce the loss
        assert float(g[..., 1].sum()) < 0


class TestTrainingIntegration:
    def test_meshnet_learns_synthetic_gwm(self):
        """Short CPU training run reaches a meaningful held-out Dice and a
        large improvement over chance; examples/train_meshnet.py runs the
        full few-hundred-step version (Dice keeps climbing past 0.8).

        Fully deterministic: the explicit seed pins init, data order and
        eval subjects, so the Dice trajectory is reproducible run-to-run
        (seed 1 reaches ~0.70 held-out Dice in 60 CPU steps; the bar is
        0.5 to absorb cross-platform float drift). This is what lets CI
        run the test instead of deselecting it."""
        from repro.training import trainer

        cfg = trainer.TrainConfig(
            model=MeshNetConfig(channels=5, dropout_rate=0.0),
            data=mri.DataLoaderConfig(
                mri=mri.SyntheticMRIConfig(shape=(24, 24, 24)), batch_size=2
            ),
            steps=60,
            eval_subjects=2,
            log_every=1000,
            seed=1,
        )
        res = trainer.train(cfg, verbose=False)
        assert res.final_dice > 0.5, res.final_dice
        first_dice = res.history[0]["dice"]
        assert res.final_dice > first_dice + 0.25, (first_dice, res.final_dice)

    def test_checkpoint_roundtrip(self, tmp_path):
        from repro.training import checkpoint as ck
        from repro.training import optimizer as opt

        cfg = MeshNetConfig()
        params = meshnet.init(KEY, cfg)
        state = opt.adamw_init(params, opt.AdamWConfig())
        ck.save(str(tmp_path / "c"), {"params": params, "opt": state}, step=7)
        tree, manifest = ck.restore(str(tmp_path / "c"))
        assert manifest["step"] == 7
        before = jax.tree.leaves({"params": params, "opt": state})
        after = jax.tree.leaves(tree)
        assert len(before) == len(after)
        for a, b in zip(before, after):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert isinstance(tree["opt"], opt.AdamWState)
