"""Benchmark regression gate: fail CI when the fresh run regresses.

Compares a fresh ``benchmarks.run --json-out`` trajectory against the
committed baseline (``BENCH_2.json``) per (section, name) key and exits
non-zero when any measured kernel regresses:

  * ``us_per_call`` grows by more than ``--us-tol`` (default 25%, or the
    ``BENCH_US_TOL`` env var) **after machine normalization**: the
    committed baseline was produced on some developer machine, a CI
    runner can easily be several times slower wholesale, so raw ratios
    would fail every PR. Instead the *median* fresh/baseline ratio across
    all timed keys is taken as the machine-speed factor, and a key fails
    only when its own ratio exceeds the median by the tolerance — i.e.
    when one kernel got slower *relative to the rest of the suite*. (A
    uniform slowdown of every kernel is indistinguishable from a slower
    machine by construction; the per-key gate is the one wall-clock claim
    a shared runner can actually check.)
  * ``hbm_bytes_modeled`` grows at all — no normalization: the traffic
    models are analytic and deterministic, *any* growth is a real
    schedule regression;
  * a baseline key disappears (a benchmark silently dropped is a coverage
    regression, not an improvement).

New keys in the fresh run are reported but never fail — adding benchmarks
must not require a two-step dance. A per-key delta table is always
printed so the artifact log shows *what* moved, not just that something
did.

Precision keys: rows measured under a reduced storage policy carry an
``@<precision>`` suffix (``hbm_gwm_light_256_pallas_megakernel@int8w``)
while fp32 rows keep their legacy un-suffixed names — so the per-key
diff above always compares like-for-like precision (an int8w run can
never mask an fp32 regression, and vice versa).

Virtual sections (``serving``, ``serving_fleet``, ``serving_resilience``,
``serving_cache``, ``batched``): these rows are *virtual-clock* numbers
from the deterministic load simulator — identical on any machine by
construction — so they are (a) EXCLUDED from the machine-speed median
(they would drag it toward 1.0 and make real timing keys fail on slow
runners) and (b) gated ABSOLUTELY: any growth beyond ``--virtual-us-tol``
(default 0, i.e. byte-exact or better) fails, with no normalization. A
p99 that moved means scheduler behavior changed; regenerate the baseline
in the same PR so the diff is reviewed, never absorbed.

``--sections A,B`` restricts the comparison to those sections (CI's
serving job gates only its own section without re-running the kernel
benches; missing-key detection then applies within the subset).

Usage:
    python benchmarks/check_regression.py FRESH.json [--baseline BENCH_2.json]
                                          [--us-tol 0.25] [--sections serving]
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "..", "BENCH_2.json")

#: sections whose us_per_call is virtual-clock (deterministic simulator
#: output): excluded from machine normalization, gated absolutely.
VIRTUAL_SECTIONS = frozenset(
    {"serving", "serving_fleet", "serving_resilience", "serving_cache", "batched"}
)


def _load(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict):
        raise SystemExit(f"{path}: expected a section->rows mapping")
    return data


def _index(trajectory: dict) -> dict[tuple[str, str], dict]:
    out = {}
    for section, rows in trajectory.items():
        for row in rows:
            out[(section, row["name"])] = row
    return out


def _machine_factor(fresh_idx: dict, base_idx: dict) -> float:
    """Median fresh/baseline us ratio over shared timed keys — the
    wholesale speed difference between the two machines. Virtual-clock
    sections are excluded: their ratio is 1.0 by construction and would
    bias the median toward 'no drift' on genuinely slower runners."""
    ratios = [
        fresh_idx[k]["us_per_call"] / base_idx[k]["us_per_call"]
        for k in base_idx
        if k in fresh_idx
        and k[0] not in VIRTUAL_SECTIONS
        and base_idx[k]["us_per_call"] > 0
        and fresh_idx[k]["us_per_call"] > 0
    ]
    return statistics.median(ratios) if ratios else 1.0


def _filter_sections(trajectory: dict, sections) -> dict:
    if not sections:
        return trajectory
    return {k: v for k, v in trajectory.items() if k in sections}


def compare(
    fresh: dict, baseline: dict, us_tol: float, virtual_us_tol: float = 0.0
) -> tuple[list[str], list[str]]:
    """(failures, report_lines) for the fresh-vs-baseline diff."""
    fresh_idx = _index(fresh)
    base_idx = _index(baseline)
    failures: list[str] = []
    factor = _machine_factor(fresh_idx, base_idx)
    lines = [
        f"machine-speed factor (median us ratio): {factor:.2f}x — per-key "
        f"us gate is +{us_tol:.0%} relative to it; virtual sections "
        f"({', '.join(sorted(VIRTUAL_SECTIONS))}) gated absolutely at "
        f"+{virtual_us_tol:.0%}",
        f"{'section':<10} {'name':<55} {'us_base':>12} {'us_fresh':>12} "
        f"{'us_delta':>9} {'hbm_base':>16} {'hbm_fresh':>16} verdict",
    ]

    def fmt(key, b, f, us_delta, verdict):
        def hb(row):
            v = None if row is None else row.get("hbm_bytes_modeled")
            return "-" if v is None else str(v)

        def us(row):
            return "-" if row is None else f"{row['us_per_call']:.1f}"

        lines.append(
            f"{key[0]:<10} {key[1]:<55} {us(b):>12} {us(f):>12} "
            f"{us_delta:>9} {hb(b):>16} {hb(f):>16} {verdict}"
        )

    for key in sorted(base_idx):
        b = base_idx[key]
        f = fresh_idx.get(key)
        if f is None:
            failures.append(f"{key}: present in baseline, missing from fresh run")
            fmt(key, b, None, "-", "MISSING")
            continue
        verdicts = []
        us_delta = "-"
        if (
            key[0] in VIRTUAL_SECTIONS
            and b["us_per_call"] > 0
            and f["us_per_call"] == 0
        ):
            # a deterministic latency percentile collapsing to zero means
            # the scenario served nothing — that is a scheduler bug, not
            # an improvement, and must not slip past the >0 guard below
            failures.append(
                f"{key}: virtual us_per_call {b['us_per_call']:.1f} -> 0 "
                "(scenario collapsed — nothing served?)"
            )
            verdicts.append("VIRTUAL-COLLAPSED")
        elif (
            key[0] in VIRTUAL_SECTIONS
            and b["us_per_call"] == 0
            and f["us_per_call"] > 0
        ):
            # a deterministic count/latency key at zero in the baseline
            # (e.g. a fleet scenario's queue-full refusals) growing to
            # nonzero is a real behavior regression — the relative gate
            # below cannot see it (0 has no ratio), so gate it here
            failures.append(
                f"{key}: virtual us_per_call 0 -> {f['us_per_call']:.1f} "
                "(deterministic key grew from zero — regenerate the "
                "baseline if the change is intended)"
            )
            verdicts.append("VIRTUAL-REGRESSED")
        elif b["us_per_call"] > 0 and f["us_per_call"] > 0:
            if key[0] in VIRTUAL_SECTIONS:
                # virtual-clock key: deterministic, so no machine factor —
                # any growth beyond the (default zero) tolerance is a real
                # scheduler-behavior regression
                rel = f["us_per_call"] / b["us_per_call"] - 1.0
                us_delta = f"{rel:+.1%}"
                if rel > virtual_us_tol:
                    failures.append(
                        f"{key}: virtual us_per_call {b['us_per_call']:.1f} -> "
                        f"{f['us_per_call']:.1f} ({rel:+.1%} absolute "
                        f"> +{virtual_us_tol:.0%}; deterministic key — "
                        "regenerate the baseline if the change is intended)"
                    )
                    verdicts.append("VIRTUAL-REGRESSED")
            else:
                # machine-normalized: how much this key moved relative to
                # the suite-wide median drift
                rel = f["us_per_call"] / (b["us_per_call"] * factor) - 1.0
                us_delta = f"{rel:+.0%}"
                if rel > us_tol:
                    failures.append(
                        f"{key}: us_per_call {b['us_per_call']:.1f} -> "
                        f"{f['us_per_call']:.1f} ({rel:+.0%} vs suite median "
                        f"> +{us_tol:.0%})"
                    )
                    verdicts.append("US-REGRESSED")
        hb_b, hb_f = b.get("hbm_bytes_modeled"), f.get("hbm_bytes_modeled")
        if hb_b is not None and hb_f is not None and hb_f > hb_b:
            failures.append(
                f"{key}: hbm_bytes_modeled {hb_b} -> {hb_f} (any growth fails)"
            )
            verdicts.append("HBM-REGRESSED")
        fmt(key, b, f, us_delta, ",".join(verdicts) or "ok")
    for key in sorted(set(fresh_idx) - set(base_idx)):
        fmt(key, None, fresh_idx[key], "-", "new")
    return failures, lines


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="fresh --json-out trajectory to gate")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument(
        "--us-tol",
        type=float,
        default=float(os.environ.get("BENCH_US_TOL", "0.25")),
        help="allowed fractional us_per_call growth (default 0.25)",
    )
    ap.add_argument(
        "--virtual-us-tol",
        type=float,
        default=float(os.environ.get("BENCH_VIRTUAL_US_TOL", "0.0")),
        help="allowed absolute growth for virtual-clock sections "
        "(default 0.0 — deterministic keys must not regress at all)",
    )
    ap.add_argument(
        "--sections",
        help="comma-separated section subset to compare (default: all)",
    )
    args = ap.parse_args(argv)
    sections = (
        {s.strip() for s in args.sections.split(",") if s.strip()}
        if args.sections
        else None
    )
    fresh, baseline = _load(args.fresh), _load(args.baseline)
    if sections:
        # every requested section must exist in the BASELINE: a typo'd
        # or renamed-but-not-regenerated section would otherwise filter
        # the baseline to nothing and the gate would pass having
        # compared zero keys
        unknown = sections - set(baseline)
        if unknown:
            raise SystemExit(
                f"--sections {','.join(sorted(unknown))}: not present in "
                f"baseline {args.baseline} "
                f"(baseline sections: {sorted(baseline)}; regenerate the "
                "baseline if a section was renamed)"
            )
    failures, lines = compare(
        _filter_sections(fresh, sections),
        _filter_sections(baseline, sections),
        args.us_tol,
        args.virtual_us_tol,
    )
    print("\n".join(lines))
    if failures:
        print(f"\nFAIL: {len(failures)} regression(s) vs {args.baseline}:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nOK: no regressions vs {args.baseline} (us tol +{args.us_tol:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
