"""Benchmarks reproducing the paper's tables on the simulated substrate.

Table II  -> table2_model_size_and_dice(): MeshNet (full + subvolume) vs
             U-Net: parameter count, model size (MB), macro Dice after the
             same short training budget on synthetic GWM volumes.
Table IV  -> table4_pipeline_stages(): per-model pipeline stage timings
             (preprocess / crop / inference / merge / postprocess).
Table V   -> table5_fail_types(): success rate of full-volume vs sub-volume
             inference across a simulated fleet of memory budgets.
Table VI  -> table6_patching_cropping(): the patching & cropping
             interventions (exclusion groups + IPTW ATE estimates).
Table VII -> table7_cropping_effect(): cropping effect on full-volume
             inference per model size (chi-square + power).
Table VIII-> table8_texture_size(): budget ("texture size") effect.

The browser fleet is simulated as a distribution over memory budgets
(DESIGN.md §2); every number the analysis produces is regenerated from the
budget model + the pipeline's actual behaviour, not hard-coded.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import meshnet, pipeline, unet3d
from repro.core.meshnet import MeshNetConfig
from repro.core.pipeline import PipelineConfig
from repro.data import mri
from repro.telemetry import analysis
from repro.telemetry.budget import BudgetExceeded, MemoryBudget
from repro.training import losses, optimizer as opt_mod, trainer

VOL = 48  # synthetic volume side on CPU (paper: 256)
KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------- Table II ---


def _train_unet(steps=60, shape=(32, 32, 32)) -> tuple:
    cfg = unet3d.UNet3DConfig(base_channels=8, levels=2)
    params = unet3d.init(KEY, cfg)
    opt_cfg = opt_mod.AdamWConfig(lr=1e-3)
    state = opt_mod.adamw_init(params, opt_cfg)
    loader = iter(mri.DataLoader(mri.DataLoaderConfig(mri=mri.SyntheticMRIConfig(shape=shape), batch_size=2)))

    @jax.jit
    def step(params, state, vol, lab):
        def loss_fn(p):
            logits = unet3d.apply(p, vol, cfg)
            return losses.segmentation_loss(logits, lab, cfg.num_classes)[0]

        g = jax.grad(loss_fn)(params)
        params, state, _ = opt_mod.adamw_update(g, state, params, opt_cfg)
        return params, state

    for _ in range(steps):
        vol, lab = next(loader)
        params, state = step(params, state, vol, lab)
    # eval
    dices = []
    for i in range(3):
        vol, lab = mri.generate(jax.random.PRNGKey(10_000 + i), mri.SyntheticMRIConfig(shape=shape))
        pred = unet3d.predict(params, vol[None], cfg)[0]
        dices.append(float(losses.dice_score(pred, lab, cfg.num_classes)))
    return cfg, float(np.mean(dices))


def table2_model_size_and_dice(steps=30) -> list[dict]:
    # steps=30 keeps the whole benchmark suite CPU-tractable; the matched-
    # budget comparison (MeshNet ~= U-Net) is what the row validates —
    # examples/train_meshnet.py runs the long version.
    rows = []
    # MeshNet full-volume
    t_cfg = trainer.TrainConfig(
        model=MeshNetConfig(),
        data=mri.DataLoaderConfig(mri=mri.SyntheticMRIConfig(shape=(32, 32, 32)), batch_size=2),
        steps=steps, eval_subjects=3, log_every=10_000,
    )
    res = trainer.train(t_cfg, verbose=False)
    n = t_cfg.model.param_count()
    rows.append(
        {"model": "MeshNet GWM (full volume)", "params": n,
         "size_mb": round(n * 4 / 1e6, 3), "dice": round(res.final_dice, 3),
         "paper_size_mb": 0.022, "paper_dice": 0.96}
    )
    # MeshNet trained on sub-volumes (failsafe training mode)
    t_cfg2 = dataclasses.replace(
        t_cfg,
        model=MeshNetConfig(channels=21),
        data=mri.DataLoaderConfig(
            mri=mri.SyntheticMRIConfig(shape=(32, 32, 32)), batch_size=2,
            subvolumes=True, cube=24,
        ),
    )
    res2 = trainer.train(t_cfg2, verbose=False)
    n2 = t_cfg2.model.param_count()
    rows.append(
        {"model": "MeshNet GWM (sub volume)", "params": n2,
         "size_mb": round(n2 * 4 / 1e6, 3), "dice": round(res2.final_dice, 3),
         "paper_size_mb": 0.89, "paper_dice": 0.96}
    )
    # U-Net baseline
    ucfg, udice = _train_unet(steps)
    un = ucfg.param_count()
    rows.append(
        {"model": "U-Net GWM", "params": un, "size_mb": round(un * 4 / 1e6, 3),
         "dice": round(udice, 3), "paper_size_mb": 288, "paper_dice": 0.96}
    )
    return rows


# --------------------------------------------------------------- Table IV ---


def table4_pipeline_stages() -> list[dict]:
    """Per-stage timings for representative paper model cards."""
    cards = {
        "Compute Brain Mask (FAST)": ("brain_mask_fast", "full", False),
        "Full Brain GWM (light)": ("gwm_light", "full", False),
        "Full Brain GWM (large)": ("gwm_large", "full", False),
        "Subvolume GWM (failsafe)": ("subvolume_gwm_failsafe", "subvolume", False),
        "Cortical Atlas 50": ("atlas_50", "full", True),
    }
    vol, _ = mri.generate(KEY, mri.SyntheticMRIConfig(shape=(VOL,) * 3))
    mask_cfg = meshnet.PAPER_MODELS["brain_mask_fast"]
    mask_params = meshnet.init(jax.random.PRNGKey(5), mask_cfg)
    rows = []
    for name, (model_key, mode, crop) in cards.items():
        mcfg = meshnet.PAPER_MODELS[model_key]
        params = meshnet.init(KEY, mcfg)
        pc = PipelineConfig(
            name=name, model=mcfg, volume_shape=(VOL,) * 3, mode=mode,
            cube=16, overlap=8, use_cropping=crop, min_component_size=8,
        )
        res = pipeline.run(pc, params, vol, mask_model=(mask_params, mask_cfg))
        t = res.record.times
        rows.append(
            {"model": name, "layers": mcfg.num_layers, "params": mcfg.param_count(),
             "preprocess_s": round(t.preprocessing, 3), "crop_s": round(t.cropping, 3),
             "inference_s": round(t.inference, 3), "merge_s": round(t.merging, 3),
             "postprocess_s": round(t.postprocessing, 3), "status": res.record.status}
        )
    return rows


# ----------------------------------------------------- fleet simulation -----


def simulate_fleet(n=400, seed=0):
    """A fleet of simulated 'devices': log-uniform memory budgets spanning
    ~1.4 GiB .. 32 GiB (consumer-GPU-era WebGL working sets), mirroring the
    paper's device diversity (180 distinct GPU cards). Calibrated so the
    256^3 GWM full-volume requirement (~3.5 GB under naive all-layers
    allocation) lands inside the distribution — the regime where the
    paper's interventions matter."""
    rng = np.random.default_rng(seed)
    budgets = 2 ** rng.uniform(30.5, 35.0, n)
    return [MemoryBudget(int(b), name=f"dev{i}") for i, b in enumerate(budgets)]


_FLAKE = 0.05  # residual non-memory failure rate (shader-compile analogue)


def _succeeds(budget: MemoryBudget, mode: str, model: MeshNetConfig, shape, cube=64,
              overlap=46, cropped=False, rng=None) -> bool:
    s = tuple(int(x * (0.72 if cropped else 1.0)) for x in shape)  # crop shrinks ~28%/axis
    if rng is not None and rng.uniform() < _FLAKE:
        return False
    try:
        if mode == "full":
            budget.charge_inference(s, model)
        elif mode == "streaming":
            budget.charge_streaming(s, model)
        else:
            budget.charge_subvolume(cube, overlap, model)
        return True
    except BudgetExceeded:
        return False


def table5_fail_types(n=400) -> dict:
    model = MeshNetConfig()
    shape = (256, 256, 256)
    fleet = simulate_fleet(n)
    rng = np.random.default_rng(2)
    full_ok = sum(_succeeds(b, "full", model, shape, rng=rng) for b in fleet)
    sub_ok = sum(_succeeds(b, "subvolume", model, shape, rng=rng) for b in fleet)
    return {
        "full_volume": {"ok": full_ok, "fail": n - full_ok, "success_rate": full_ok / n},
        "subvolume_failsafe": {"ok": sub_ok, "fail": n - sub_ok, "success_rate": sub_ok / n},
        "paper": {"full_volume_sr": 0.8108, "subvolume_sr": 0.873},
    }


def table6_patching_cropping(n=400) -> dict:
    """Patching & cropping treatment effects: contingency + IPTW ATE."""
    model = MeshNetConfig()
    shape = (256, 256, 256)
    fleet = simulate_fleet(n)
    rng = np.random.default_rng(1)
    # randomized assignment of treatments across the fleet (RCT-style)
    patch = rng.integers(0, 2, n)
    crop = rng.integers(0, 2, n)
    outcome = np.array(
        [
            _succeeds(b, "subvolume" if p else "full", model, shape, cropped=bool(c), rng=rng)
            for b, p, c in zip(fleet, patch, crop)
        ],
        int,
    )
    budgets = np.array([np.log2(b.bytes_limit) for b in fleet])
    res_patch = analysis.contingency(
        int(((patch == 1) & (outcome == 1)).sum()), int(((patch == 1) & (outcome == 0)).sum()),
        int(((patch == 0) & (outcome == 1)).sum()), int(((patch == 0) & (outcome == 0)).sum()),
    )
    res_crop = analysis.contingency(
        int(((crop == 1) & (outcome == 1)).sum()), int(((crop == 1) & (outcome == 0)).sum()),
        int(((crop == 0) & (outcome == 1)).sum()), int(((crop == 0) & (outcome == 0)).sum()),
    )
    conf = np.column_stack([budgets, crop])
    ate_patch = analysis.iptw_ate(patch, outcome, conf)
    conf2 = np.column_stack([budgets, patch])
    ate_crop = analysis.iptw_ate(crop, outcome, conf2)
    reg_patch = analysis.regression_adjustment(patch, outcome, conf)
    return {
        "patching": {"chi2_p": res_patch.p_value, "sr_treated": res_patch.success_rate_treated,
                     "sr_control": res_patch.success_rate_control, "iptw_ate": ate_patch,
                     "regression_adjustment": reg_patch, "paper_iptw_ate": 0.0623},
        "cropping": {"chi2_p": res_crop.p_value, "sr_treated": res_crop.success_rate_treated,
                     "sr_control": res_crop.success_rate_control, "iptw_ate": ate_crop,
                     "paper_iptw_ate": 0.1812},
    }


def table7_cropping_effect(n=400) -> list[dict]:
    """Cropping effect per model size (the paper's 5598 / 23290 / 27132 /
    86372 parameter columns)."""
    rows = []
    fleet = simulate_fleet(n)
    for key in ["gwm_light", "gwm_large", "atlas_50", "atlas_104"]:
        model = meshnet.PAPER_MODELS[key]
        shape = (256, 256, 256)
        rng = np.random.default_rng(3)
        ok_plain = sum(_succeeds(b, "full", model, shape, cropped=False, rng=rng) for b in fleet)
        ok_crop = sum(_succeeds(b, "full", model, shape, cropped=True, rng=rng) for b in fleet)
        res = analysis.contingency(ok_crop, n - ok_crop, ok_plain, n - ok_plain)
        rows.append(
            {"model": key, "params": model.param_count(),
             "sr_no_crop": ok_plain / n, "sr_crop": ok_crop / n,
             "chi2_p": res.p_value, "power": res.power}
        )
    return rows


def fig7_cohort_trend(months=12, n_per_month=120) -> list[dict]:
    """Fig. 5–7 analogue: cohort success rate over time as the device fleet
    improves. The paper observes the ok/fail gap widening month over month
    ('annual advances in computational resources'); we model fleet budgets
    drifting up ~2.5%/month (GPU memory growth) and re-run the same
    full-volume workload against each cohort."""
    model = MeshNetConfig()
    shape = (256, 256, 256)
    rows = []
    rng = np.random.default_rng(7)
    for m in range(months):
        drift = 1.025 ** m
        budgets = 2 ** rng.uniform(30.5, 35.0, n_per_month) * drift
        fleet = [MemoryBudget(int(b)) for b in budgets]
        ok = sum(_succeeds(b, "full", model, shape, rng=rng) for b in fleet)
        rows.append(
            {"month": m, "ok": ok, "fail": n_per_month - ok,
             "success_rate": round(ok / n_per_month, 4),
             "gap": ok - (n_per_month - ok)}
        )
    return rows


def table8_texture_size(n=400) -> dict:
    """Texture-size ladder: bigger budget class -> higher success rate.
    16384 vs 32768 texture sizes map to 1 GiB vs 4 GiB working budgets."""
    model = meshnet.PAPER_MODELS["atlas_104"]
    shape = (256, 256, 256)
    out = {}
    for tex in (16384, 32768):
        b = MemoryBudget.from_texture_size(tex)
        ok = _succeeds(b, "full", model, shape)
        out[str(tex)] = {"budget_bytes": b.bytes_limit, "full_volume_ok": bool(ok)}
    # fleet-level: compare lower vs upper half of the budget distribution
    fleet = simulate_fleet(n)
    med = np.median([b.bytes_limit for b in fleet])
    small = [b for b in fleet if b.bytes_limit <= med]
    big = [b for b in fleet if b.bytes_limit > med]
    rng = np.random.default_rng(4)
    sr_s = sum(_succeeds(b, "full", model, shape, rng=rng) for b in small) / len(small)
    sr_b = sum(_succeeds(b, "full", model, shape, rng=rng) for b in big) / len(big)
    res = analysis.contingency(
        int(sr_b * len(big)), len(big) - int(sr_b * len(big)),
        int(sr_s * len(small)), len(small) - int(sr_s * len(small)),
    )
    out["fleet"] = {"sr_small_budgets": sr_s, "sr_large_budgets": sr_b,
                    "chi2_p": res.p_value, "power": res.power,
                    "paper": {"sr_16384": 0.8015, "sr_32768": 0.9827}}
    return out
