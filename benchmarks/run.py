# One function per paper table. Print ``name,us_per_call,hbm_bytes_modeled,derived`` CSV.
"""Benchmark harness entrypoint: PYTHONPATH=src python -m benchmarks.run

Sections:
  [kernels]       Pallas vs oracle micro-benchmarks (us_per_call)
  [executors]     registry head-to-head: xla vs pallas_fused vs
                  pallas_megakernel end-to-end MeshNet forward per paper
                  model (core/executors.py), plus megakernel spot rows at
                  each reduced precision policy ("@bf16"/"@int8w" keys)
  [traffic]       modeled HBM bytes per forward at the paper's 256^3
                  volume for every registered executor (EXPERIMENTS.md
                  §Perf H9: megakernel >= 5x under pallas_fused) and
                  every precision policy (H11: int8w <= 0.4x, bf16 <=
                  0.55x of fp32 on the megakernel; fp32 keys stay
                  un-suffixed so the gate diffs like-for-like)
  [serving]       virtual-clock p50/p99 latencies of the three committed
                  load scenarios (steady / burst / overload) on the
                  deterministic serving simulator (bench_serving.py) —
                  bit-reproducible, gated absolutely (no machine norm)
  [serving_fleet] virtual-clock p50/p99 of the six committed fleet
                  scenarios (replicated schedulers + cache-affinity
                  router, serving/fleet.py), plus the overload acceptance
                  keys (interactive p99, queue-full refusals) — gated
                  absolutely like [serving]
  [serving_resilience] lower-is-better virtual keys of the fault-storm
                  acceptance scenario (serving/resilience.py): unrecovered
                  faults, timeout reaps, lost/double-served (must stay 0),
                  and the storm's p99 — gated absolutely like [serving]
  [batched]       the N-volume batch axis: modeled bytes per forward at
                  batch 1/2/4 per backend (weight stream amortized — b4
                  strictly under 4x b1), plus virtual-clock p50/p99 of
                  every committed load scenario re-run with batched
                  dispatch on the same seed/trace — gated absolutely
                  like [serving] (bench_serving.bench_batched)
  [serving_cache] lower-is-better virtual keys of the artifact-cache
                  acceptance scenario (serving/cache.py): miss rate under
                  Zipf skew, quarantined-served (must stay 0), uncollapsed
                  stampedes, lost requests, and the cached storm's p99 —
                  gated absolutely like [serving]
  [table2]        MeshNet vs U-Net: size + Dice on the synthetic GWM task
  [table4]        per-model pipeline stage timings
  [interventions] fleet-simulation tables V-VIII (patching/cropping/texture)
  [roofline]      the three-term roofline per (arch x shape), if dry-run
                  results exist (results/dryrun_16x16.json)

Pass section names to run a subset: python -m benchmarks.run table2 roofline
Pass ``--json`` to also write the machine-readable perf trajectory
``BENCH_2.json`` at the repo root: per measured section, a list of
``{name, us_per_call, hbm_bytes_modeled}`` rows. ``--json-out PATH``
writes the trajectory somewhere else — CI's bench-smoke job writes a
fresh file next to the committed baseline and gates the diff with
``benchmarks/check_regression.py`` (>25% us_per_call or any hbm_bytes
growth per key fails the build).
"""

from __future__ import annotations

import json
import os
import sys

#: repo-root path of the machine-readable perf trajectory.
JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_2.json")

#: sections emitting (name, us_per_call, hbm_bytes_modeled, note) rows.
MEASURED_SECTIONS = (
    "kernels",
    "executors",
    "traffic",
    "serving",
    "batched",
    "serving_fleet",
    "serving_resilience",
    "serving_cache",
)


def _csv(name: str, us: float, hbm, derived: str = "") -> None:
    hb = "" if hbm is None else str(int(hbm))
    print(f"{name},{us:.1f},{hb},{derived}")


def _rows_to_json(rows):
    return [
        {
            "name": name,
            "us_per_call": round(us, 1),
            "hbm_bytes_modeled": None if hbm is None else int(hbm),
        }
        for name, us, hbm, _ in rows
    ]


def run_kernels() -> list:
    from benchmarks import bench_kernels

    rows = bench_kernels.bench()
    print("\n[kernels] name,us_per_call,hbm_bytes_modeled,derived")
    for name, us, hbm, note in rows:
        _csv(name, us, hbm, note)
    return rows


def run_executors() -> list:
    from benchmarks import bench_kernels

    rows = bench_kernels.bench_executors()
    print("\n[executors] name,us_per_call,hbm_bytes_modeled,derived")
    for name, us, hbm, note in rows:
        _csv(name, us, hbm, note)
    return rows


def run_traffic() -> list:
    from benchmarks import bench_kernels

    rows = bench_kernels.bench_traffic()
    print("\n[traffic] name,us_per_call,hbm_bytes_modeled,derived")
    for name, us, hbm, note in rows:
        _csv(name, us, hbm, note)
    return rows


def run_serving() -> list:
    from benchmarks import bench_serving

    rows = bench_serving.bench()
    print("\n[serving] name,us_per_call,hbm_bytes_modeled,derived")
    print("# virtual-clock latencies (deterministic discrete-event simulator,")
    print("# seed 0) — gated ABSOLUTELY by check_regression.py, no machine norm")
    for name, us, hbm, note in rows:
        _csv(name, us, hbm, note)
    return rows


def run_batched() -> list:
    from benchmarks import bench_serving

    rows = bench_serving.bench_batched()
    print("\n[batched] name,us_per_call,hbm_bytes_modeled,derived")
    print("# the N-volume batch axis: analytic bytes per forward at batch")
    print("# 1/2/4 (weight stream amortized) + virtual-clock latencies of")
    print("# the batched-dispatch scenarios — gated ABSOLUTELY, no machine norm")
    for name, us, hbm, note in rows:
        _csv(name, us, hbm, note)
    return rows


def run_serving_fleet() -> list:
    from benchmarks import bench_serving

    rows = bench_serving.bench_fleet()
    print("\n[serving_fleet] name,us_per_call,hbm_bytes_modeled,derived")
    print("# virtual-clock fleet latencies (replicated schedulers behind the")
    print("# cache-affinity router, seed 0) — gated ABSOLUTELY, no machine norm")
    for name, us, hbm, note in rows:
        _csv(name, us, hbm, note)
    return rows


def run_serving_resilience() -> list:
    from benchmarks import bench_serving

    rows = bench_serving.bench_resilience()
    print("\n[serving_resilience] name,us_per_call,hbm_bytes_modeled,derived")
    print("# fault-storm acceptance keys (seed 0): every key is lower-is-")
    print("# better virtual-clock, gated ABSOLUTELY — growth means the")
    print("# resilience layer recovers less, reaps later, or loses requests")
    for name, us, hbm, note in rows:
        _csv(name, us, hbm, note)
    return rows


def run_serving_cache() -> list:
    from benchmarks import bench_serving

    rows = bench_serving.bench_cache()
    print("\n[serving_cache] name,us_per_call,hbm_bytes_modeled,derived")
    print("# artifact-cache acceptance keys (seed 0): every key is lower-is-")
    print("# better virtual-clock, gated ABSOLUTELY — growth means the cache")
    print("# misses more, serves corrupt bytes, or stops collapsing stampedes")
    for name, us, hbm, note in rows:
        _csv(name, us, hbm, note)
    return rows


def run_table2() -> None:
    from benchmarks import bench_paper_tables as T

    print("\n[table2] MeshNet vs U-Net (synthetic GWM, short training budget)")
    print("model,params,size_mb,dice,paper_size_mb,paper_dice")
    for r in T.table2_model_size_and_dice():
        print(
            f"{r['model']},{r['params']},{r['size_mb']},{r['dice']},"
            f"{r['paper_size_mb']},{r['paper_dice']}"
        )


def run_table4() -> None:
    from benchmarks import bench_paper_tables as T

    print("\n[table4] pipeline stage timings (s) — 48^3 synthetic volume on CPU")
    print("model,params,preprocess,crop,inference,merge,postprocess,status")
    for r in T.table4_pipeline_stages():
        print(
            f"{r['model']},{r['params']},{r['preprocess_s']},{r['crop_s']},"
            f"{r['inference_s']},{r['merge_s']},{r['postprocess_s']},{r['status']}"
        )


def run_interventions() -> None:
    from benchmarks import bench_paper_tables as T

    print("\n[table5] full-volume vs sub-volume success across simulated fleet")
    t5 = T.table5_fail_types()
    for k, v in t5.items():
        print(f"{k}: {json.dumps(v)}")

    print("\n[table6] patching & cropping treatment effects (chi2 + IPTW)")
    t6 = T.table6_patching_cropping()
    for k, v in t6.items():
        print(
            f"{k}: "
            + json.dumps(
                {kk: round(vv, 4) if isinstance(vv, float) else vv for kk, vv in v.items()}
            )
        )

    print("\n[table7] cropping effect by model size")
    print("model,params,sr_no_crop,sr_crop,chi2_p,power")
    for r in T.table7_cropping_effect():
        print(
            f"{r['model']},{r['params']},{r['sr_no_crop']:.4f},{r['sr_crop']:.4f},"
            f"{r['chi2_p']:.2e},{r['power']:.3f}"
        )

    print("\n[table8] texture-size (memory budget) effect")
    t8 = T.table8_texture_size()
    for k, v in t8.items():
        print(f"{k}: {json.dumps(v)}")

    print("\n[fig7] cohort success-rate trend (fleet budgets drift +2.5%/month)")
    print("month,ok,fail,success_rate,gap")
    for r in T.fig7_cohort_trend():
        print(f"{r['month']},{r['ok']},{r['fail']},{r['success_rate']},{r['gap']}")


def run_roofline() -> None:
    from benchmarks import roofline

    path = os.path.join(roofline.RESULTS_DIR, "dryrun_16x16.json")
    if not os.path.exists(path):
        print("\n[roofline] skipped — run PYTHONPATH=src python -m repro.launch.dryrun first")
        return
    print("\n[roofline] three-term roofline per (arch x shape), single pod v5e-256")
    roofline.print_table("16x16")


SECTIONS = {
    "kernels": run_kernels,
    "executors": run_executors,
    "traffic": run_traffic,
    "serving": run_serving,
    "batched": run_batched,
    "serving_fleet": run_serving_fleet,
    "serving_resilience": run_serving_resilience,
    "serving_cache": run_serving_cache,
    "table2": run_table2,
    "table4": run_table4,
    "interventions": run_interventions,
    "roofline": run_roofline,
}


def main(argv: list[str] | None = None, json_path: str = JSON_PATH) -> None:
    args = list(sys.argv[1:] if argv is None else argv)
    emit_json = "--json" in args
    args = [a for a in args if a != "--json"]
    if "--json-out" in args:
        i = args.index("--json-out")
        if i + 1 >= len(args) or args[i + 1].startswith("-"):
            raise SystemExit("--json-out needs a path argument")
        json_path = args[i + 1]
        del args[i : i + 2]
        emit_json = True
    wanted = args or list(SECTIONS)
    trajectory: dict[str, list] = {}
    for name in wanted:
        rows = SECTIONS[name]()
        if emit_json and name in MEASURED_SECTIONS and rows:
            trajectory[name] = _rows_to_json(rows)
    if emit_json:
        # Merge into the existing trajectory so running a subset of
        # sections refreshes only those sections instead of clobbering
        # the rest of the committed file.
        merged: dict[str, list] = {}
        if os.path.exists(json_path):
            try:
                with open(json_path) as f:
                    merged = json.load(f)
            except (json.JSONDecodeError, OSError):
                merged = {}
        merged.update(trajectory)
        with open(json_path, "w") as f:
            json.dump(merged, f, indent=1)
        print(f"\nwrote {os.path.abspath(json_path)}")


if __name__ == "__main__":
    main()
