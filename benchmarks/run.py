# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entrypoint: PYTHONPATH=src python -m benchmarks.run

Sections:
  [kernels]       Pallas vs oracle micro-benchmarks (us_per_call)
  [executors]     registry head-to-head: xla vs pallas_fused end-to-end
                  MeshNet forward per paper model (core/executors.py)
  [table2]        MeshNet vs U-Net: size + Dice on the synthetic GWM task
  [table4]        per-model pipeline stage timings
  [interventions] fleet-simulation tables V-VIII (patching/cropping/texture)
  [roofline]      the three-term roofline per (arch x shape), if dry-run
                  results exist (results/dryrun_16x16.json)

Pass section names to run a subset: python -m benchmarks.run table2 roofline
"""

from __future__ import annotations

import json
import sys


def _csv(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


def run_kernels() -> None:
    from benchmarks import bench_kernels

    print("\n[kernels] name,us_per_call,derived")
    for name, us, note in bench_kernels.bench():
        _csv(name, us, note)


def run_executors() -> None:
    from benchmarks import bench_kernels

    print("\n[executors] name,us_per_call,derived")
    for name, us, note in bench_kernels.bench_executors():
        _csv(name, us, note)


def run_table2() -> None:
    from benchmarks import bench_paper_tables as T

    print("\n[table2] MeshNet vs U-Net (synthetic GWM, short training budget)")
    print("model,params,size_mb,dice,paper_size_mb,paper_dice")
    for r in T.table2_model_size_and_dice():
        print(
            f"{r['model']},{r['params']},{r['size_mb']},{r['dice']},"
            f"{r['paper_size_mb']},{r['paper_dice']}"
        )


def run_table4() -> None:
    from benchmarks import bench_paper_tables as T

    print("\n[table4] pipeline stage timings (s) — 48^3 synthetic volume on CPU")
    print("model,params,preprocess,crop,inference,merge,postprocess,status")
    for r in T.table4_pipeline_stages():
        print(
            f"{r['model']},{r['params']},{r['preprocess_s']},{r['crop_s']},"
            f"{r['inference_s']},{r['merge_s']},{r['postprocess_s']},{r['status']}"
        )


def run_interventions() -> None:
    from benchmarks import bench_paper_tables as T

    print("\n[table5] full-volume vs sub-volume success across simulated fleet")
    t5 = T.table5_fail_types()
    for k, v in t5.items():
        print(f"{k}: {json.dumps(v)}")

    print("\n[table6] patching & cropping treatment effects (chi2 + IPTW)")
    t6 = T.table6_patching_cropping()
    for k, v in t6.items():
        print(
            f"{k}: "
            + json.dumps(
                {kk: round(vv, 4) if isinstance(vv, float) else vv for kk, vv in v.items()}
            )
        )

    print("\n[table7] cropping effect by model size")
    print("model,params,sr_no_crop,sr_crop,chi2_p,power")
    for r in T.table7_cropping_effect():
        print(
            f"{r['model']},{r['params']},{r['sr_no_crop']:.4f},{r['sr_crop']:.4f},"
            f"{r['chi2_p']:.2e},{r['power']:.3f}"
        )

    print("\n[table8] texture-size (memory budget) effect")
    t8 = T.table8_texture_size()
    for k, v in t8.items():
        print(f"{k}: {json.dumps(v)}")

    print("\n[fig7] cohort success-rate trend (fleet budgets drift +2.5%/month)")
    print("month,ok,fail,success_rate,gap")
    for r in T.fig7_cohort_trend():
        print(f"{r['month']},{r['ok']},{r['fail']},{r['success_rate']},{r['gap']}")


def run_roofline() -> None:
    import os

    from benchmarks import roofline

    path = os.path.join(roofline.RESULTS_DIR, "dryrun_16x16.json")
    if not os.path.exists(path):
        print("\n[roofline] skipped — run PYTHONPATH=src python -m repro.launch.dryrun first")
        return
    print("\n[roofline] three-term roofline per (arch x shape), single pod v5e-256")
    roofline.print_table("16x16")


SECTIONS = {
    "kernels": run_kernels,
    "executors": run_executors,
    "table2": run_table2,
    "table4": run_table4,
    "interventions": run_interventions,
    "roofline": run_roofline,
}


def main() -> None:
    wanted = sys.argv[1:] or list(SECTIONS)
    for name in wanted:
        SECTIONS[name]()


if __name__ == "__main__":
    main()
