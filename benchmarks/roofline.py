"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Per (arch x shape x mesh):
  compute   = HLO_FLOPs_per_device / peak_FLOP/s           [s]
  memory    = HLO_bytes_per_device / HBM_bw                [s]
  collective= collective_bytes_per_device / link_bw        [s]
(The partitioned HLO is per-device, so no further division by chips.)

Plus MODEL_FLOPS = 6*N*D (train) or 2*N*D (prefill/decode), N = active
params, D = global tokens; and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs * chips) which exposes remat/routing overhead.
"""

from __future__ import annotations

import json
import os

from repro import configs
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = configs.for_shape(arch, shape_name)
    seq, batch, mode = configs.INPUT_SHAPES[shape_name]
    n_active = cfg.param_counts()["active"]
    if mode == "train":
        tokens = batch * seq
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = batch * seq
        return 2.0 * n_active * tokens
    tokens = batch * 1  # decode: one token per sequence
    return 2.0 * n_active * tokens


def roofline_row(rec: dict) -> dict:
    # Census numbers (unrolled-scan compile) when available — exact per-layer
    # op counts; the rolled compile costs a while body once, not x trips.
    flops = rec.get("census_flops", rec["flops"])
    bytes_acc = rec.get("census_bytes_accessed", rec["bytes_accessed"])
    coll = rec.get("census_collectives", rec["collectives"])["total"]
    compute = flops / PEAK_FLOPS_BF16
    memory = bytes_acc / HBM_BW
    collective = coll / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops * rec["chips"]
    ratio = mf / hlo_total if hlo_total else 0.0
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": round(ratio, 4),
        "peak_gib_per_dev": round(
            (rec["arg_bytes"] + rec["temp_bytes"] + rec["out_bytes"] - rec["alias_bytes"]) / 2**30, 2
        ),
    }


def load(mesh_name: str = "16x16") -> dict:
    path = os.path.join(RESULTS_DIR, f"dryrun_{mesh_name}.json")
    with open(path) as f:
        return json.load(f)


def table(mesh_name: str = "16x16") -> list[dict]:
    rows = []
    for key, rec in load(mesh_name).items():
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"], "status": "fail"})
            continue
        rows.append(
            {
                "arch": rec["arch"],
                "shape": rec["shape"],
                "mesh": rec["mesh"],
                **roofline_row(rec),
            }
        )
    return rows


def print_table(mesh_name: str = "16x16") -> None:
    rows = table(mesh_name)
    hdr = f"{'arch':<22} {'shape':<12} {'compute_s':>10} {'memory_s':>10} {'collect_s':>10} {'dominant':>10} {'useful':>7} {'GiB/dev':>8}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("status") == "fail":
            print(f"{r['arch']:<22} {r['shape']:<12} FAILED")
            continue
        print(
            f"{r['arch']:<22} {r['shape']:<12} {r['compute']:>10.4f} {r['memory']:>10.4f} "
            f"{r['collective']:>10.4f} {r['dominant']:>10} {r['useful_ratio']:>7.3f} "
            f"{r['peak_gib_per_dev']:>8.2f}"
        )


if __name__ == "__main__":
    import sys

    print_table(sys.argv[1] if len(sys.argv) > 1 else "16x16")
