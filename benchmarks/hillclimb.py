"""Hillclimb measurement harness (§Perf): lower a (arch x shape) pair with
config/step overrides and report the production memory numbers + census
roofline terms, so every hypothesis->measure cycle in EXPERIMENTS.md §Perf
is one reproducible command:

  PYTHONPATH=src python benchmarks/hillclimb.py kimi-k2-1t-a32b train_4k --microbatches 4
  PYTHONPATH=src python benchmarks/hillclimb.py qwen1.5-32b decode_32k --kv-quant
  PYTHONPATH=src python benchmarks/hillclimb.py jamba-1.5-large-398b train_4k --capacity-factor 1.0
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--remat", default=None, choices=["full", "none"])
    ap.add_argument("--no-census", action="store_true")
    args = ap.parse_args()

    from repro import configs
    from repro.launch import dryrun, mesh as mesh_mod, steps as steps_mod
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

    overrides = {}
    if args.kv_quant:
        overrides["kv_quant"] = True
    if args.capacity_factor is not None:
        overrides["moe_capacity_factor"] = args.capacity_factor
    if args.remat:
        overrides["remat"] = args.remat

    mesh = mesh_mod.make_production_mesh()
    base_cfg = configs.for_shape(args.arch, args.shape)
    cfg = dataclasses.replace(base_cfg, **overrides) if overrides else base_cfg

    # monkey-patch the step builder for microbatches
    orig_make = steps_mod.make_train_step
    if args.microbatches > 1:
        steps_mod.make_train_step = lambda c, **kw: orig_make(
            c, microbatches=args.microbatches, **{k: v for k, v in kw.items() if k != "microbatches"}
        )
    try:
        rec = dryrun.run_one(
            args.arch, args.shape, mesh, verbose=True, census=not args.no_census,
            cfg_override=cfg,
        )
    finally:
        steps_mod.make_train_step = orig_make

    flops = rec.get("census_flops", rec["flops"])
    bytes_acc = rec.get("census_bytes_accessed", rec["bytes_accessed"])
    coll = rec.get("census_collectives", rec["collectives"])["total"]
    if args.microbatches > 1:
        # The microbatch loop is rolled (costed once): scale loop-carried
        # census terms by M. Slight overcount: the optimizer update runs
        # once, not M times (small vs per-token work).
        flops *= args.microbatches
        bytes_acc *= args.microbatches
        coll *= args.microbatches
    print(
        json.dumps(
            {
                "arch": args.arch,
                "shape": args.shape,
                "overrides": {**overrides, "microbatches": args.microbatches},
                "args_gib": round(rec["arg_bytes"] / 2**30, 2),
                "temp_gib": round(rec["temp_bytes"] / 2**30, 2),
                "compute_s": round(flops / PEAK_FLOPS_BF16, 4),
                "memory_s": round(bytes_acc / HBM_BW, 4),
                "collective_s": round(coll / ICI_BW, 4),
                "collective_gib": round(coll / 2**30, 2),
            },
            indent=1,
        )
    )


if __name__ == "__main__":
    main()
